//! Benchmark harness: regenerates every table and figure of the paper.
//!
//!   cargo bench -- <target> [flags]
//!
//! targets: table1 table2 table3 table4 table5 fig2 fig3 fig4 serve
//!          serve_hot_path bsa_native all
//! flags:   --steps N (training budget per model, default 120)
//!          --reps N  (timing repetitions, default 5; --reps 1 is the
//!                     smoke mode scripts/check.sh uses)
//!          --max-n N (largest sequence length for fig3/fig4)
//!          --out DIR (results directory, default bench_results)
//!          --quick   (cap bsa_native's n_sweep at N=32768 — the
//!                     CI/check.sh mode; the full sweep reaches N=1M)
//!          --trace-out FILE (enable span tracing for the whole run and
//!                     write a Chrome trace-event JSON at exit — load it
//!                     in chrome://tracing or Perfetto to see where a
//!                     bench target spends its time)
//!
//! `serve_hot_path` measures the host-side serving hot path (cold
//! ball-tree build vs BallTreeCache hit, the poll-core TCP server under
//! concurrent pipelined clients + 256 idle connections, plus end-to-end
//! router latency when artifacts are present) and writes the
//! machine-readable `BENCH_serve.json` perf-trajectory artifact. `bsa_native` measures
//! the pure-Rust BSA forward pass (p50/p95 vs N, a threads-in-{1,2,4,8}
//! throughput sweep on the paper-config forward, native vs pjrt at the
//! tiny config when artifacts exist, end-to-end native router) and
//! writes `BENCH_native.json` — it needs no artifacts at all, so the
//! perf gate runs end-to-end on artifact-free hosts, and
//! `scripts/check.sh` uses the sweep's threads=1 row as the
//! single-thread throughput regression baseline. Host-side targets
//! run even when no compiled artifacts exist; engine-dependent targets
//! are skipped with a note.
//!
//! Requires `make artifacts-bench`. Results are written both to stdout
//! (markdown tables mirroring the paper's) and to `bench_results/*.md`;
//! EXPERIMENTS.md records the committed runs. Paper-reported values are
//! printed alongside for comparison — our substrate is a CPU testbed with
//! procedural data, so *shape* (ordering, ratios, crossovers), not
//! absolute values, is the reproduction target (DESIGN.md Sec. 6).
//!
//! criterion is not vendored offline; this is an explicit harness binary
//! (Cargo `[[bench]]` with `harness = false`).

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::sync::Arc;
use std::time::Instant;

use bsa::config::{ModelConfig, TrainConfig};
use bsa::coordinator::Trainer;
use bsa::data::generator_for;
use bsa::flops::{attn_layer_flops, model_flops};
use bsa::metrics::{Accumulator, Table};
use bsa::runtime::{literal_to_tensor, scalar_i32, Engine, Executable};
use bsa::tensor::Tensor;

struct Opts {
    target: String,
    steps: usize,
    reps: usize,
    max_n: usize,
    quick: bool,
    out: PathBuf,
    trace_out: Option<PathBuf>,
}

fn parse_opts() -> Opts {
    let args: Vec<String> = std::env::args().skip(1).collect();
    // Defaults size a bare `cargo bench` to ~15 min on the 1-core CPU
    // testbed; the committed EXPERIMENTS.md runs use --steps 100 --reps 5
    // --max-n 16384 explicitly.
    let mut o = Opts {
        target: "all".into(),
        steps: 60,
        reps: 3,
        max_n: 8192,
        quick: false,
        out: PathBuf::from("bench_results"),
        trace_out: None,
    };
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        match a.as_str() {
            "--steps" => o.steps = it.next().and_then(|v| v.parse().ok()).unwrap_or(o.steps),
            "--reps" => o.reps = it.next().and_then(|v| v.parse().ok()).unwrap_or(o.reps),
            "--max-n" => o.max_n = it.next().and_then(|v| v.parse().ok()).unwrap_or(o.max_n),
            "--quick" => o.quick = true,
            "--out" => {
                if let Some(v) = it.next() {
                    o.out = PathBuf::from(v);
                }
            }
            "--trace-out" => {
                if let Some(v) = it.next() {
                    o.trace_out = Some(PathBuf::from(v));
                }
            }
            "--bench" | "--test" => {} // flags cargo bench may pass through
            t if !t.starts_with('-') => o.target = t.to_string(),
            _ => {}
        }
    }
    o
}

fn main() -> anyhow::Result<()> {
    let o = parse_opts();
    std::fs::create_dir_all(&o.out)?;
    if o.trace_out.is_some() {
        // span-trace the whole run and dump a Chrome trace at exit; the
        // trace_overhead A/B inside bsa_native toggles the level itself
        // and restores this setting when it finishes
        bsa::trace::set_level(bsa::trace::TraceLevel::Spans);
        bsa::trace::enable_chrome();
    }
    // Engine creation is best-effort: host-side targets (table4, fig2,
    // serve_hot_path's preprocessing half) have no artifact dependency
    // and must produce their perf record on any machine.
    let engine: Option<Arc<Engine>> = match Engine::new(&Engine::default_dir()) {
        Ok(e) => {
            println!("# BSA paper-reproduction benches (platform: {})\n", e.platform());
            Some(Arc::new(e))
        }
        Err(e) => {
            println!(
                "# BSA paper-reproduction benches\n\
                 # no artifacts/engine ({e}); engine-dependent targets are skipped\n"
            );
            None
        }
    };
    let require = |name: &str| -> Option<&Arc<Engine>> {
        if engine.is_none() {
            println!("  (skipping {name}: artifacts/engine unavailable — run make artifacts-bench)");
        }
        engine.as_ref()
    };

    let all = o.target == "all";
    if all || o.target == "table1" {
        if let Some(e) = require("table1") {
            table_accuracy(e, &o, "air", "table1", "Table 1 (ShapeNet MSE x100)")?;
        }
    }
    if all || o.target == "table2" {
        if let Some(e) = require("table2") {
            table_accuracy(e, &o, "ela", "table2", "Table 2 (Elasticity RMSE x100)")?;
        }
    }
    if all || o.target == "table3" {
        if let Some(e) = require("table3") {
            table3(e, &o)?;
        }
    }
    if all || o.target == "table4" {
        table4_bench(&o)?;
    }
    if all || o.target == "table5" {
        if let Some(e) = require("table5") {
            table5(e, &o)?;
        }
    }
    if all || o.target == "fig2" {
        fig2(&o)?;
    }
    if all || o.target == "fig3" {
        if let Some(e) = require("fig3") {
            fig_scaling(e, &o, &["full", "bsa"], "fig3", "Figure 3 (runtime vs N)")?;
        }
    }
    if all || o.target == "fig4" {
        if let Some(e) = require("fig4") {
            fig_scaling(
                e,
                &o,
                &["bsa", "bsa_nogs", "bsa_gc", "bta"],
                "fig4",
                "Figure 4 (BSA variants runtime vs N)",
            )?;
        }
    }
    if all || o.target == "ablation" {
        if let Some(e) = require("ablation") {
            ablation(e, &o)?;
        }
    }
    if all || o.target == "batching" {
        if let Some(e) = require("batching") {
            batching(e, &o)?;
        }
    }
    if all || o.target == "serve" {
        if let Some(e) = require("serve") {
            serve_bench(e, &o)?;
        }
    }
    if all || o.target == "serve_hot_path" {
        serve_hot_path(engine.as_ref(), &o)?;
    }
    if all || o.target == "bsa_native" {
        bsa_native(engine.as_ref(), &o)?;
    }
    if let Some(path) = &o.trace_out {
        bsa::trace::write_chrome_trace(path)?;
        println!("# chrome trace written to {} (load in chrome://tracing or Perfetto)", path.display());
    }
    Ok(())
}

fn emit(out: &Path, name: &str, content: &str) -> anyhow::Result<()> {
    println!("{content}");
    std::fs::write(out.join(format!("{name}.md")), content)?;
    Ok(())
}

// ---------------------------------------------------------------------------
// Tables 1 & 2: accuracy vs baselines (train each model, short schedule)
// ---------------------------------------------------------------------------

/// Paper-reported values for context rows.
fn paper_values(task: &str) -> Vec<(&'static str, f64)> {
    match task {
        "air" => vec![
            ("PointNet (paper)", 43.36),
            ("Erwin (paper)", 15.85),
            ("BSA (paper)", 14.31),
            ("Full Attention (paper)", 13.29),
        ],
        _ => vec![
            ("Erwin (paper)", 0.34),
            ("BSA (paper)", 0.38),
            ("Full Attention (paper)", 0.30),
        ],
    }
}

fn table_accuracy(
    engine: &Arc<Engine>,
    o: &Opts,
    task: &str,
    name: &str,
    title: &str,
) -> anyhow::Result<()> {
    let variants = ["pointnet", "erwin", "bsa", "full"];
    let mut results: Vec<(String, f64)> = vec![];
    let mut csv = String::from("model,metric\n");
    for v in variants {
        let tag = format!("{v}_{task}_n1024_b2_ref");
        if engine.manifest.get(&format!("train_{tag}")).is_err() {
            println!("  (skipping {v}: artifact train_{tag} missing — run make artifacts-bench)");
            continue;
        }
        let tc = TrainConfig {
            task: task.into(),
            steps: o.steps,
            warmup: o.steps / 10 + 1,
            train_samples: 96,
            test_samples: 24,
            log_every: o.steps.max(1),
            ..Default::default()
        };
        let t0 = Instant::now();
        let mut trainer = Trainer::new(engine.clone(), &tag, tc)?;
        trainer.run(|_| {})?;
        let mse = trainer.evaluate()?;
        let metric = if task == "ela" { mse.sqrt() * 100.0 } else { mse * 100.0 };
        println!("  {v}: {metric:.3} ({} steps, {:.0}s)", o.steps, t0.elapsed().as_secs_f64());
        results.push((v.to_string(), metric));
        csv.push_str(&format!("{v},{metric}\n"));
        trainer.save_checkpoint(&o.out.join(format!("{v}_{task}.bsackpt")))?;
    }
    std::fs::write(o.out.join(format!("{name}.csv")), csv)?;

    let metric_name = if task == "ela" { "RMSE x100" } else { "MSE x100" };
    let mut t = Table::new(&["Model", metric_name]);
    for (v, m) in &results {
        t.row(&[v.clone(), format!("{m:.3}")]);
    }
    for (v, m) in paper_values(task) {
        t.row(&[v.to_string(), format!("{m:.2}")]);
    }
    let mut content = format!("## {title} — measured ({} steps) vs paper-reported\n\n", o.steps);
    content.push_str(&t.render());
    content.push_str("\nreproduction target: Full <= BSA < Erwin < PointNet (error ordering)\n");
    emit(&o.out, name, &content)
}

// ---------------------------------------------------------------------------
// Table 3: MSE / runtime / GFLOPS at N=4096
// ---------------------------------------------------------------------------

fn time_fwd(exe: &Arc<Executable>, reps: usize) -> anyhow::Result<Accumulator> {
    // zero params: runtime is shape-, not value-, dependent for these graphs
    let mut state: Vec<xla::Literal> = Vec::with_capacity(exe.info.nparams);
    for spec in exe.info.inputs.iter().take(exe.info.nparams) {
        state.push(bsa::runtime::tensor_to_literal(&Tensor::zeros(spec.dims.clone()))?);
    }
    let n = exe.info.n;
    let f = exe.info.in_features;
    let mut rng = bsa::prng::Rng::new(n as u64);
    let x = Tensor::new(vec![exe.info.batch, n, f], rng.normals(exe.info.batch * n * f));
    let _ = exe.run_with_tensors(&state, &[&x])?; // warmup
    let mut acc = Accumulator::new();
    for _ in 0..reps {
        let t0 = Instant::now();
        let out = exe.run_with_tensors(&state, &[&x])?;
        std::hint::black_box(&out);
        acc.push(t0.elapsed().as_secs_f64() * 1e3);
    }
    Ok(acc)
}

fn table3(engine: &Arc<Engine>, o: &Opts) -> anyhow::Result<()> {
    // paper rows: (display, variant key, paper ms, paper GFLOPS)
    let rows = [
        ("Erwin", "erwin", 19.35, 14.60),
        ("Full Attention", "full", 37.82, 87.08),
        ("BSA", "bsa", 36.53, 27.91),
        ("BSA w/o group selection", "bsa_nogs", 66.92, 32.67),
        ("BSA w/ group compression", "bsa_gc", 23.42, 20.82),
    ];
    // measured MSE from the table1 run if present
    let t1_csv = o.out.join("table1.csv");
    let mut mse: BTreeMap<String, f64> = BTreeMap::new();
    if let Ok(text) = std::fs::read_to_string(&t1_csv) {
        for line in text.lines().skip(1) {
            if let Some((k, v)) = line.split_once(',') {
                if let Ok(x) = v.parse() {
                    mse.insert(k.to_string(), x);
                }
            }
        }
    }

    let cfg = ModelConfig { num_blocks: 18, seq_len: 4096, ..Default::default() };
    let mut t = Table::new(&[
        "Attention type",
        "runtime ms (XLA)",
        "runtime ms (pallas-interp)",
        "GFLOPS (analytic, paper arch)",
        "paper ms",
        "paper GFLOPS",
    ]);
    for (disp, v, pms, pgf) in rows {
        let mut xla_ms = String::from("-");
        let mut pal_ms = String::from("-");
        for (kern, slot) in [("_ref", &mut xla_ms), ("", &mut pal_ms)] {
            let name = format!("fwd_{v}_air_n4096_b1{kern}");
            match engine.load(&name) {
                Ok(exe) => {
                    let acc = time_fwd(&exe, o.reps)?;
                    *slot = format!("{:.1} +- {:.1}", acc.mean(), acc.std());
                }
                Err(_) => {
                    *slot = "missing".into();
                }
            }
        }
        let gf = model_flops(v, &cfg)?.gflops();
        t.row(&[
            disp.to_string(),
            xla_ms,
            pal_ms,
            format!("{gf:.2}"),
            format!("{pms:.2}"),
            format!("{pgf:.2}"),
        ]);
    }
    let mut content = String::from(
        "## Table 3 (N=4096 forward): measured runtime + analytic GFLOPs vs paper\n\n",
    );
    content.push_str(&t.render());
    if !mse.is_empty() {
        content.push_str("\nmeasured MSE x100 (from table1 run): ");
        for (k, v) in &mse {
            content.push_str(&format!("{k}={v:.2} "));
        }
        content.push('\n');
    }
    content.push_str(
        "\nreproduction targets: GFLOPs ordering Erwin < BSA+gc < BSA < BSA-nogs << Full;\n\
         BSA w/o group selection is the slowest BSA variant (paper: no fused selection kernel).\n",
    );
    emit(&o.out, "table3", &content)
}

// ---------------------------------------------------------------------------
// Table 4: hyperparameters (configuration reproduction)
// ---------------------------------------------------------------------------

fn table4_bench(o: &Opts) -> anyhow::Result<()> {
    let cfg = ModelConfig::paper_scale();
    cfg.validate()?;
    let content = format!("## Table 4 (configuration defaults)\n\n{}", bsa::config::table4(&cfg));
    emit(&o.out, "table4", &content)
}

// ---------------------------------------------------------------------------
// Table 5: (l, g) ablation grid
// ---------------------------------------------------------------------------

fn table5(engine: &Arc<Engine>, o: &Opts) -> anyhow::Result<()> {
    let grid: [(usize, usize, f64); 8] = [
        (4, 4, 15.43),
        (8, 8, 14.31),
        (16, 16, 14.97),
        (32, 32, 132.14),
        (4, 8, 14.81),
        (16, 8, 14.88),
        (8, 4, 14.88),
        (8, 16, 14.84),
    ];
    let mut t = Table::new(&["Compr. block", "Group sel.", "measured MSE x100", "paper MSE"]);
    for (l, g, paper) in grid {
        let suffix = if (l, g) == (8, 8) { String::new() } else { format!("_l{l}g{g}") };
        let tag = format!("bsa_air_n1024_b2{suffix}_ref");
        let cell = if engine.manifest.get(&format!("train_{tag}")).is_ok() {
            let tc = TrainConfig {
                task: "air".into(),
                steps: o.steps,
                warmup: o.steps / 10 + 1,
                train_samples: 96,
                test_samples: 24,
                log_every: o.steps.max(1),
                ..Default::default()
            };
            let mut trainer = Trainer::new(engine.clone(), &tag, tc)?;
            trainer.run(|_| {})?;
            let m = trainer.evaluate()? * 100.0;
            println!("  l={l} g={g}: {m:.3}");
            format!("{m:.3}")
        } else {
            "missing".into()
        };
        t.row(&[l.to_string(), g.to_string(), cell, format!("{paper:.2}")]);
    }
    let mut content = format!("## Table 5 (block-size ablation, {} steps)\n\n", o.steps);
    content.push_str(&t.render());
    content.push_str("\nreproduction target: l=g=8 among the best; l=g=32 degrades sharply.\n");
    emit(&o.out, "table5", &content)
}

// ---------------------------------------------------------------------------
// Figure 2: receptive field growth
// ---------------------------------------------------------------------------

fn fig2(o: &Opts) -> anyhow::Result<()> {
    use bsa::rfield::{receptive_field, RFieldParams};
    let gen = generator_for("air", 11)?;
    let car = gen.generate(0, 3584);
    let tree = bsa::balltree::BallTree::build(&car.coords, 4096, 11);
    let feats = tree.permute_features(&car.features);
    let p = RFieldParams::default();

    let mut t = Table::new(&["query pos", "ball", "+selection", "+compression"]);
    for q in [100, 1024, 2048, 3500] {
        let rf = receptive_field(&feats, q, p, 42);
        let (b, s, c) = rf.counts();
        t.row(&[q.to_string(), b.to_string(), s.to_string(), c.to_string()]);
    }
    let mut content =
        String::from("## Figure 2 (receptive field size per component, N=4096)\n\n");
    content.push_str(&t.render());
    content.push_str(
        "\nreproduction target: monotone growth ball -> +selection -> global;\n\
         selected blocks always outside the query's own ball (mask).\n\
         renders: cargo run --release --example receptive_field\n",
    );
    emit(&o.out, "fig2", &content)
}

// ---------------------------------------------------------------------------
// Figures 3 & 4: runtime scaling with sequence length
// ---------------------------------------------------------------------------

fn fig_scaling(
    engine: &Arc<Engine>,
    o: &Opts,
    kinds: &[&str],
    name: &str,
    title: &str,
) -> anyhow::Result<()> {
    let ns = [256usize, 512, 1024, 2048, 4096, 8192, 16384, 32768, 65536];
    let mut header: Vec<String> = vec!["N".into()];
    for k in kinds {
        header.push(format!("{k} ms"));
        header.push(format!("{k} GFLOP"));
    }
    let hdr: Vec<&str> = header.iter().map(|s| s.as_str()).collect();
    let mut t = Table::new(&hdr);
    let cfg = ModelConfig::default();

    let mut csv = format!("n,{}\n", kinds.join(","));
    for n in ns {
        if n > o.max_n {
            continue;
        }
        let mut row = vec![n.to_string()];
        let mut csv_row = vec![n.to_string()];
        for kind in kinds {
            let gname = format!("attn_{kind}_n{n}_ref");
            let cell = match engine.load(&gname) {
                Ok(exe) => {
                    let init = engine.load(&format!("attninit_{kind}_n{n}_ref"))?;
                    let params = init.run(&[scalar_i32(0)])?;
                    let x = {
                        let mut rng = bsa::prng::Rng::new(n as u64);
                        Tensor::new(vec![1, n, 64], rng.normals(n * 64))
                    };
                    let _ = exe.run_with_tensors(&params, &[&x])?; // warmup
                    let mut acc = Accumulator::new();
                    for _ in 0..o.reps {
                        let t0 = Instant::now();
                        let out = exe.run_with_tensors(&params, &[&x])?;
                        std::hint::black_box(&out);
                        acc.push(t0.elapsed().as_secs_f64() * 1e3);
                    }
                    format!("{:.2}", acc.mean())
                }
                Err(_) => "missing".into(),
            };
            csv_row.push(cell.clone());
            row.push(cell);
            row.push(format!("{:.2}", attn_layer_flops(kind, n, &cfg) / 1e9));
        }
        t.row(&row);
        csv.push_str(&csv_row.join(","));
        csv.push('\n');
    }
    std::fs::write(o.out.join(format!("{name}.csv")), csv)?;
    let mut content = format!(
        "## {title} — single attention layer, XLA-fused artifacts, {} reps\n\n",
        o.reps
    );
    content.push_str(&t.render());
    content.push_str(
        "\nreproduction target: Full faster at small N, crossover, BSA ~5x faster at 65536\n\
         (CPU testbed: crossover point shifts vs the paper's GPU; shape must hold).\n",
    );
    emit(&o.out, name, &content)
}

// ---------------------------------------------------------------------------
// design-choice ablations (DESIGN.md: own-ball mask, MLP phi)
// ---------------------------------------------------------------------------

fn ablation(engine: &Arc<Engine>, o: &Opts) -> anyhow::Result<()> {
    let rows = [
        ("BSA (baseline)", "bsa_air_n1024_b2_ref"),
        ("- own-ball selection mask", "bsa_nomask_air_n1024_b2_ref"),
        ("+ MLP compression phi", "bsa_mlpcmp_air_n1024_b2_ref"),
    ];
    let mut t = Table::new(&["Variant", "MSE x100"]);
    for (disp, tag) in rows {
        if engine.manifest.get(&format!("train_{tag}")).is_err() {
            t.row(&[disp.to_string(), "missing (make artifacts-bench)".into()]);
            continue;
        }
        let tc = TrainConfig {
            task: "air".into(),
            steps: o.steps,
            warmup: o.steps / 10 + 1,
            train_samples: 96,
            test_samples: 24,
            log_every: o.steps.max(1),
            ..Default::default()
        };
        let mut trainer = Trainer::new(engine.clone(), tag, tc)?;
        trainer.run(|_| {})?;
        let m = trainer.evaluate()? * 100.0;
        println!("  {disp}: {m:.3}");
        t.row(&[disp.to_string(), format!("{m:.3}")]);
    }
    let mut content = format!(
        "## Design-choice ablations ({} steps) — own-ball mask & MLP phi\n\n",
        o.steps
    );
    content.push_str(&t.render());
    content.push_str(
        "\nthe paper argues the own-ball mask prevents selection from\n\
         duplicating BTA coverage (Sec. 3.2); removing it should not help.\n",
    );
    emit(&o.out, "ablation", &content)
}

// ---------------------------------------------------------------------------
// dynamic batcher behaviour (B=4 artifact): does batching amortize?
// ---------------------------------------------------------------------------

fn batching(engine: &Arc<Engine>, o: &Opts) -> anyhow::Result<()> {
    use bsa::config::ServeConfig;
    use bsa::coordinator::Router;
    let graph = "fwd_bsa_air_n1024_b4_ref";
    if engine.manifest.get(graph).is_err() {
        println!("  (skipping batching: {graph} missing — run make artifacts-bench)");
        return Ok(());
    }
    let init = engine.load("init_bsa_air_n1024_b2_ref")
        .or_else(|_| engine.load("init_bsa_air_n1024_b2"))?;
    let params: Vec<Tensor> = init
        .run(&[scalar_i32(0)])?
        .iter()
        .map(literal_to_tensor)
        .collect::<Result<_, _>>()?;
    let gen = generator_for("air", 9)?;
    let total = 16usize;

    let mut content = String::from("## dynamic batcher (B=4 compiled batch, N=1024)\n\n");
    for (label, workers, concurrent) in [("sequential", 1usize, false), ("concurrent", 1usize, true)] {
        let sc = ServeConfig { workers, flush_us: 30_000, ..Default::default() };
        let router = Arc::new(Router::start_pjrt(engine.clone(), graph, params.clone(), sc)?);
        let t0 = Instant::now();
        if concurrent {
            // fire all requests before collecting: lets the batcher fill
            let mut rxs = vec![];
            for i in 0..total {
                let s = gen.generate(i as u64, 900);
                rxs.push(router.submit(s.coords, s.features)?);
            }
            for rx in rxs {
                let resp = rx.recv().expect("response");
                resp.result?;
            }
        } else {
            for i in 0..total {
                let s = gen.generate(i as u64, 900);
                router.infer(s.coords, s.features)?;
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let st = router.stats();
        let line = format!(
            "{label}: {total} reqs in {wall:.2}s ({:.2} req/s), batches={} mean_batch={:.2}\n",
            total as f64 / wall,
            st.batches,
            st.mean_batch
        );
        print!("  {line}");
        content.push_str(&line);
    }
    content.push_str(
        "\nexpectation: concurrent submission fills the compiled batch\n\
         (mean_batch -> 4) and beats sequential per-request dispatch.\n",
    );
    emit(&o.out, "batching", &content)
}

// ---------------------------------------------------------------------------
// serving-path microbench (coordinator hot path; used by the Perf section)
// ---------------------------------------------------------------------------

fn serve_bench(engine: &Arc<Engine>, o: &Opts) -> anyhow::Result<()> {
    use bsa::config::ServeConfig;
    use bsa::coordinator::Router;
    let init = engine.load("init_bsa_air_n1024_b2")?;
    let params: Vec<Tensor> = init
        .run(&[scalar_i32(0)])?
        .iter()
        .map(literal_to_tensor)
        .collect::<Result<_, _>>()?;
    let fwd = if engine.manifest.get("fwd_bsa_air_n4096_b1_ref").is_ok() {
        "fwd_bsa_air_n4096_b1_ref"
    } else {
        "fwd_bsa_air_n4096_b1"
    };
    let sc = ServeConfig { workers: 2, ..Default::default() };
    let router = Arc::new(Router::start_pjrt(engine.clone(), fwd, params, sc)?);

    let gen = generator_for("air", 3)?;
    let reqs = 4 * o.reps.max(2);
    // time the pre/post stages standalone
    let sample = gen.generate(0, 3584);
    let mut pre = Accumulator::new();
    for i in 0..reqs {
        let t0 = Instant::now();
        let tree = bsa::balltree::BallTree::build(&sample.coords, 4096, i as u64);
        let f = tree.permute_features(&sample.features);
        std::hint::black_box(&f);
        pre.push(t0.elapsed().as_secs_f64() * 1e3);
    }

    let t0 = Instant::now();
    for i in 0..reqs {
        let s = gen.generate(i as u64, 3584);
        let p = router.infer(s.coords, s.features)?;
        std::hint::black_box(&p);
    }
    let wall = t0.elapsed().as_secs_f64();

    let mut content = format!("## serving-path microbench ({fwd})\n\n");
    content.push_str(&format!(
        "requests: {reqs} sequential; end-to-end {:.1} ms/req ({:.2} req/s)\n",
        wall * 1e3 / reqs as f64,
        reqs as f64 / wall
    ));
    content.push_str(&format!(
        "preprocessing (ball tree + permute): {:.2} ms mean\n",
        pre.mean()
    ));
    content.push_str(&format!(
        "router p50={:.0}us p95={:.0}us\n",
        router.latency_us(50.0),
        router.latency_us(95.0)
    ));
    emit(&o.out, "serve", &content)
}

// ---------------------------------------------------------------------------
// serve_hot_path: cold-tree vs cached-tree latency + BENCH_serve.json
// ---------------------------------------------------------------------------

/// Escape a string for embedding in a JSON string literal.
fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Measure the serving hot path the way rebar measures regex engines:
/// record the numbers machine-readably so the next PR can regress against
/// them. Two levels:
///
/// 1. host-side preprocessing (no artifacts needed): fresh
///    `BallTree::build` + gather per request, vs a `BallTreeCache` hit +
///    gather — the dominant cost difference for repeated geometries.
/// 2. end-to-end through the `Router` (needs compiled artifacts): the
///    same request stream against `tree_cache = 0` and the default cache.
fn serve_hot_path(engine: Option<&Arc<Engine>>, o: &Opts) -> anyhow::Result<()> {
    use bsa::balltree::{content_hash, BallTree, BallTreeCache};
    use bsa::config::ServeConfig;
    use bsa::coordinator::Router;
    use bsa::metrics::LatencyHistogram;

    let reps = o.reps.max(1);
    let n_points = 3584usize;
    let target = 4096usize;
    let geoms = 4usize;
    let gen = generator_for("air", 7)?;
    let samples: Vec<_> = (0..geoms).map(|i| gen.generate(i as u64, n_points)).collect();
    let f = samples[0].features.cols();

    // --- level 1: preprocessing, cold build vs cache hit -----------------
    let mut buf = vec![0.0f32; target * f];
    let mut cold = LatencyHistogram::new();
    for _ in 0..reps {
        for s in &samples {
            let t0 = Instant::now();
            let tree = BallTree::build(&s.coords, target, content_hash(&s.coords));
            tree.permute_features_into(&s.features, &mut buf);
            std::hint::black_box(&buf);
            cold.record_us(t0.elapsed().as_secs_f64() * 1e6);
        }
    }
    let cache = BallTreeCache::new(16);
    for s in &samples {
        cache.get_or_build(&s.coords, target); // prime: one build per geometry
    }
    let mut cached = LatencyHistogram::new();
    for _ in 0..reps {
        for s in &samples {
            let t0 = Instant::now();
            let tree = cache.get_or_build(&s.coords, target);
            tree.permute_features_into(&s.features, &mut buf);
            std::hint::black_box(&buf);
            cached.record_us(t0.elapsed().as_secs_f64() * 1e6);
        }
    }
    let p50_speedup = if cached.percentile_us(50.0) > 0.0 {
        cold.percentile_us(50.0) / cached.percentile_us(50.0)
    } else {
        0.0
    };

    // --- level 2: end-to-end through the router (artifact-dependent) -----
    let mut e2e_json = String::from("{\"available\": false}");
    if let Some(engine) = engine {
        let run = (|| -> anyhow::Result<String> {
            let init = engine.load("init_bsa_air_n1024_b2")?;
            let params: Vec<Tensor> = init
                .run(&[scalar_i32(0)])?
                .iter()
                .map(literal_to_tensor)
                .collect::<Result<_, _>>()?;
            let fwd = if engine.manifest.get("fwd_bsa_air_n4096_b1_ref").is_ok() {
                "fwd_bsa_air_n4096_b1_ref"
            } else {
                "fwd_bsa_air_n4096_b1"
            };
            let total = (8 * reps).max(16);
            // Warm the engine's executable cache + PJRT path through a
            // throwaway router so neither measured router's latency
            // histogram contains graph load/compile time (the measured
            // routers share the compiled executable via the engine cache).
            {
                let sc = ServeConfig { workers: 1, tree_cache: 0, ..Default::default() };
                let warm = Router::start_pjrt(engine.clone(), fwd, params.clone(), sc)?;
                warm.infer(samples[0].coords.clone(), samples[0].features.clone())?;
                warm.shutdown();
            }
            let mut parts = Vec::new();
            for (label, cap) in [("cold", 0usize), ("cached", 64usize)] {
                let sc = ServeConfig { workers: 2, tree_cache: cap, ..Default::default() };
                let router = Router::start_pjrt(engine.clone(), fwd, params.clone(), sc)?;
                let t0 = Instant::now();
                for i in 0..total {
                    let s = &samples[i % samples.len()];
                    let p = router.infer(s.coords.clone(), s.features.clone())?;
                    std::hint::black_box(&p);
                }
                let wall = t0.elapsed().as_secs_f64();
                let (p50, p95) = (router.latency_us(50.0), router.latency_us(95.0));
                let st = router.shutdown();
                println!(
                    "  e2e {label}: {total} reqs, {:.2} req/s, p50={p50:.0}us p95={p95:.0}us, \
                     tree hits/misses {}/{}",
                    total as f64 / wall,
                    st.tree_hits,
                    st.tree_misses
                );
                parts.push(format!(
                    "\"{label}\": {{\"requests\": {total}, \"req_per_s\": {:.3}, \
                     \"p50_us\": {p50:.1}, \"p95_us\": {p95:.1}, \
                     \"tree_hits\": {}, \"tree_misses\": {}}}",
                    total as f64 / wall,
                    st.tree_hits,
                    st.tree_misses
                ));
            }
            Ok(format!("{{\"available\": true, \"graph\": \"{fwd}\", {}}}", parts.join(", ")))
        })();
        match run {
            Ok(j) => e2e_json = j,
            Err(e) => {
                println!("  (e2e serve bench skipped: {e})");
                e2e_json = format!(
                    "{{\"available\": false, \"reason\": \"{}\"}}",
                    json_escape(&e.to_string())
                );
            }
        }
    }

    // --- level 3: the poll-core server itself (artifact-free) ------------
    let conc_json = match serve_concurrency(o) {
        Ok(j) => j,
        Err(e) => {
            println!("  (serve_concurrency skipped: {e})");
            format!(
                "{{\"available\": false, \"reason\": \"{}\"}}",
                json_escape(&e.to_string())
            )
        }
    };

    // --- artifact assembly ------------------------------------------------
    let json = format!(
        "{{\n  \"bench\": \"serve_hot_path\",\n  \"reps\": {reps},\n  \"geometries\": {geoms},\n  \
         \"n_points\": {n_points},\n  \"target_len\": {target},\n  \"preprocess\": {{\n    \
         \"cold\": {},\n    \"cached\": {},\n    \"p50_speedup\": {p50_speedup:.2},\n    \
         \"cache_hits\": {},\n    \"cache_misses\": {}\n  }},\n  \
         \"concurrency\": {conc_json},\n  \"e2e\": {e2e_json}\n}}\n",
        cold.json(),
        cached.json(),
        cache.hits(),
        cache.misses(),
    );
    // BENCH_serve.json lives next to ROADMAP.md (the per-PR perf
    // trajectory); cargo runs benches from rust/, so look one level up.
    let dest = if Path::new("../ROADMAP.md").exists() {
        PathBuf::from("../BENCH_serve.json")
    } else {
        PathBuf::from("BENCH_serve.json")
    };
    // The `shard` section belongs to `bsa loadgen`, which merges it
    // into this artifact out of band: carry an existing section across
    // the rewrite, else seed the null placeholder (benchdiff skips
    // null leaves, so a placeholder never trips the regression gate).
    let shard = std::fs::read_to_string(&dest)
        .ok()
        .and_then(|old| bsa::shard::loadgen::extract_section(&old, "shard"))
        .unwrap_or_else(|| "null".to_string());
    let json = bsa::shard::loadgen::merge_section(&json, "shard", &shard);
    std::fs::write(&dest, &json)?;
    std::fs::write(o.out.join("serve_hot_path.json"), &json)?;

    let mut content = format!(
        "## serve_hot_path — cold vs cached ball-tree preprocessing \
         ({reps} reps x {geoms} geometries, N={n_points} padded to {target})\n\n"
    );
    content.push_str(&format!(
        "cold   (build + gather): p50={:.1}us p95={:.1}us\n",
        cold.percentile_us(50.0),
        cold.percentile_us(95.0)
    ));
    content.push_str(&format!(
        "cached (hit + gather):   p50={:.1}us p95={:.1}us  (p50 speedup {p50_speedup:.1}x)\n",
        cached.percentile_us(50.0),
        cached.percentile_us(95.0)
    ));
    content.push_str(
        "poll-core concurrency record (pipelined req/s, sheds, idle-conn thread \
         delta) embedded under the `concurrency` key of the JSON artifact\n",
    );
    content.push_str(&format!(
        "machine-readable trajectory written to {}\n",
        dest.display()
    ));
    emit(&o.out, "serve_hot_path", &content)
}

/// Live thread count from `/proc/self/status` (0 where procfs is
/// unavailable).
fn live_threads() -> usize {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0;
    };
    status
        .lines()
        .find_map(|l| l.strip_prefix("Threads:"))
        .and_then(|v| v.trim().parse().ok())
        .unwrap_or(0)
}

/// Measure the poll-core server itself, artifact-free on the native
/// backend: (a) pipelined throughput over concurrent TCP clients —
/// every frame is answered, status-0 or status-3, and both are
/// counted; (b) the thread cost of holding 256 idle connections,
/// which is the scaling contract of the single-thread poll core
/// (thread-per-connection would show +256 here). Returns the
/// `concurrency` JSON fragment of `BENCH_serve.json`.
fn serve_concurrency(o: &Opts) -> anyhow::Result<String> {
    use bsa::backend::NativeBackend;
    use bsa::config::ServeConfig;
    use bsa::coordinator::Router;
    use std::sync::atomic::{AtomicBool, Ordering};

    let addr = "127.0.0.1:17893";
    let clients = if o.quick { 8usize } else { 32 };
    let frames = if o.quick { 4usize } else { 8 };
    let idle_target = if o.quick { 64usize } else { 256 };

    let mc = ModelConfig {
        dim: 32,
        num_heads: 2,
        num_blocks: 2,
        ball_size: 64,
        seq_len: 256,
        ..Default::default()
    };
    let backend = Arc::new(NativeBackend::init(7, &mc, 6, 1, 1)?);
    let sc = ServeConfig { workers: 2, flush_us: 200, ..Default::default() };
    let router = Arc::new(Router::start(backend, sc)?);
    let stop = Arc::new(AtomicBool::new(false));
    let srv = {
        let (router, stop) = (router.clone(), stop.clone());
        std::thread::spawn(move || bsa::server::serve(addr, router, stop))
    };
    std::thread::sleep(std::time::Duration::from_millis(100));

    let gen = generator_for("syn", 7)?;
    let sample = Arc::new(gen.generate(0, 200));

    // --- pipelined throughput: C clients x K frames in flight ------------
    let t0 = Instant::now();
    let (ok, shed): (usize, usize) = std::thread::scope(|s| {
        let handles: Vec<_> = (0..clients)
            .map(|_| {
                let sample = sample.clone();
                s.spawn(move || {
                    let mut c = bsa::server::Client::connect(addr).unwrap();
                    for _ in 0..frames {
                        c.send(&sample.coords, &sample.features).unwrap();
                    }
                    let (mut ok, mut shed) = (0usize, 0usize);
                    for _ in 0..frames {
                        match c.recv_predict() {
                            Ok(_) => ok += 1,
                            Err(e) if e.downcast_ref::<bsa::server::ShedError>().is_some() => {
                                shed += 1
                            }
                            Err(e) => panic!("bench client error: {e}"),
                        }
                    }
                    (ok, shed)
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| h.join().unwrap())
            .fold((0, 0), |a, b| (a.0 + b.0, a.1 + b.1))
    });
    let wall = t0.elapsed().as_secs_f64();
    let req_per_s = (ok + shed) as f64 / wall.max(1e-9);
    let (p50, p95) = (router.latency_us(50.0), router.latency_us(95.0));

    // --- idle-connection scaling: threads must stay flat -----------------
    let before = live_threads();
    let idle: Vec<std::net::TcpStream> = (0..idle_target)
        .filter_map(|_| std::net::TcpStream::connect(addr).ok())
        .collect();
    let idle_held = idle.len();
    std::thread::sleep(std::time::Duration::from_millis(300));
    let thread_delta = live_threads().saturating_sub(before);
    drop(idle);

    stop.store(true, Ordering::SeqCst);
    srv.join().unwrap()?;
    let st = Arc::try_unwrap(router).ok().expect("sole router owner").shutdown();

    println!(
        "  concurrency: {clients} clients x {frames} pipelined frames -> {req_per_s:.1} req/s \
         (router p50={p50:.0}us p95={p95:.0}us), shed {shed}, \
         {idle_held} idle conns -> +{thread_delta} threads"
    );
    Ok(format!(
        "{{\"clients\": {clients}, \"frames_per_client\": {frames}, \"ok\": {ok}, \
         \"shed\": {shed}, \"req_per_s\": {req_per_s:.3}, \"router_p50_us\": {p50:.1}, \
         \"router_p95_us\": {p95:.1}, \"rejected\": {}, \"idle_conns\": {idle_held}, \
         \"idle_thread_delta\": {thread_delta}}}",
        st.rejected
    ))
}

// ---------------------------------------------------------------------------
// bsa_native: pure-Rust forward latency + native-vs-pjrt + BENCH_native.json
// ---------------------------------------------------------------------------

/// Process peak resident set in MB (`VmHWM` from `/proc/self/status`);
/// 0.0 where procfs is unavailable. Cumulative over the process
/// lifetime — callers order their measurements so each reading is the
/// high-water mark of the point that produced it.
fn peak_rss_mb() -> f64 {
    let Ok(status) = std::fs::read_to_string("/proc/self/status") else {
        return 0.0;
    };
    for line in status.lines() {
        if let Some(rest) = line.strip_prefix("VmHWM:") {
            if let Some(kb) = rest.split_whitespace().next().and_then(|v| v.parse::<f64>().ok()) {
                return kb / 1024.0;
            }
        }
    }
    0.0
}

/// Measure the native BSA forward pass the way `serve_hot_path` measures
/// preprocessing: machine-readable p50/p95 so the next PR can regress
/// against it, on *any* host. Ten levels:
///
/// 1. forward p50/p95 vs N for the demo-scale architecture (dim 32,
///    2 blocks — the native twin of the tiny core artifact);
/// 2. threads-vs-throughput sweep (threads in {1, 2, 4, 8}) on the
///    paper-config forward pass (Table 4 defaults: dim 64, 6 blocks,
///    N=1024) — the machine-readable record of the parallel kernels'
///    speedup, and the baseline `scripts/check.sh` regresses the
///    single-thread row against;
/// 3. SIMD microkernel A/B: per-kernel us/call and end-to-end single
///    thread fwd/s with the `backend::simd` layer forced off (scalar
///    twins) vs on (best detected level) — the `simd` record of
///    `BENCH_native.json`, i.e. the data-level-parallelism win on this
///    host (the force toggle is process-global; this harness is
///    single-threaded at that point, and mode is restored to auto);
/// 4. dispatch-overhead microbench: the persistent worker pool vs the
///    retained scoped-spawn dispatcher on a small (256x64) rowwise
///    kernel, where per-call thread spawning actually shows — the
///    `pool_dispatch` record of `BENCH_native.json` (outputs are
///    asserted bitwise-identical between the two dispatchers);
/// 5. head-parallel attention sweep: batch 2 x 4 heads = 8 independent
///    (batch, head) units across threads in {1, 2, 4, 8} — the record of
///    the head-parallel speedup (`head_parallel` in the JSON);
/// 6. large-N scaling sweep (`n_sweep` in the JSON): whole forwards at
///    N in {4k, 32k, 256k, 1M} under the streaming attention path, one
///    arm per storage precision (f16 first, then f32, N ascending, so
///    the cumulative VmHWM peak-RSS reading is meaningful per point),
///    recording fwd/s and peak RSS; plus a fixed-shape kernel A/B of
///    the streaming `attend` against the retained
///    `attend_materialized` pipeline (us/call and scratch footprint).
///    `--quick` caps the sweep at N=32768 (what scripts/check.sh
///    runs); the N=1M point is the no-nq*nk-buffer proof — the
///    materialized compression branch would need an ~16 GB score
///    matrix there, the streaming path a 64-float tile;
/// 7. native vs pjrt on the demo architecture at N=256 when the compiled
///    `fwd_bsa_syn_n256_b1` graph is present;
/// 8. end-to-end through the native `Router` (batching + ball-tree
///    cache + forward) — proof the serving stack runs artifact-free;
/// 9. tracing-overhead A/B: the demo forward at N=256 single-threaded
///    with `trace` spans off vs on — the `trace_overhead` record of
///    `BENCH_native.json` that `scripts/check.sh` gates (<3% when
///    spans are *on*; the off arm is the production default and its
///    per-site cost is one relaxed atomic load);
/// 10. native train step: `NativeTrainer` (tape forward + backward +
///    AdamW, `backend::grad`) on the demo architecture at N=256 —
///    steps/s and the backward pass's peak RSS (`train_step` in the
///    JSON; `grad_peak_rss_mb` reads VmHWM after resetting it via
///    `/proc/self/clear_refs`, so it is the training loop's own
///    high-water mark, not the earlier n_sweep's).
fn bsa_native(engine: Option<&Arc<Engine>>, o: &Opts) -> anyhow::Result<()> {
    use bsa::backend::{Backend, NativeBackend};
    use bsa::config::ServeConfig;
    use bsa::coordinator::Router;
    use bsa::metrics::LatencyHistogram;

    let reps = o.reps.max(1);
    let arch = |n: usize| ModelConfig {
        dim: 32,
        num_heads: 2,
        num_blocks: 2,
        ball_size: 64,
        seq_len: n,
        ..Default::default()
    };

    // --- level 1: forward p50/p95 vs N ----------------------------------
    let mut t = Table::new(&["N", "p50 ms", "p95 ms", "analytic GFLOP"]);
    let mut fwd_json = Vec::new();
    for n in [256usize, 512, 1024, 2048, 4096] {
        if n > o.max_n {
            continue;
        }
        let mc = arch(n);
        let be = NativeBackend::init(0, &mc, 6, 1, 1)?;
        let x = {
            let mut rng = bsa::prng::Rng::new(n as u64);
            Tensor::new(vec![1, n, 6], rng.normals(n * 6))
        };
        let _ = be.forward(&x)?; // warmup
        let mut hist = LatencyHistogram::new();
        for _ in 0..reps {
            let t0 = Instant::now();
            let out = be.forward(&x)?;
            std::hint::black_box(&out);
            hist.record_us(t0.elapsed().as_secs_f64() * 1e6);
        }
        let (p50, p95) = (hist.percentile_us(50.0), hist.percentile_us(95.0));
        let gf = model_flops("bsa", &mc)?.gflops();
        t.row(&[
            n.to_string(),
            format!("{:.2}", p50 / 1e3),
            format!("{:.2}", p95 / 1e3),
            format!("{gf:.3}"),
        ]);
        fwd_json.push(format!(
            "{{\"n\": {n}, \"p50_us\": {p50:.1}, \"p95_us\": {p95:.1}}}"
        ));
    }

    // --- level 2: threads-vs-throughput on the paper config --------------
    // Table-4 defaults (ModelConfig::default(); the arch is recorded in
    // the JSON so the trajectory stays labeled if defaults move). The
    // parallel kernels are bitwise order-preserving, so the sweep is a
    // pure latency curve; its threads=1 row is the single-thread baseline
    // scripts/check.sh guards against regression.
    let mut sweep_t = Table::new(&["threads", "p50 ms", "p95 ms", "fwd/s", "speedup vs 1T"]);
    let mut sweep_json = Vec::new();
    let sweep_mc = ModelConfig::default();
    let sweep_arch_json = format!(
        "{{\"dim\": {}, \"heads\": {}, \"blocks\": {}, \"ball\": {}, \"n\": {}}}",
        sweep_mc.dim, sweep_mc.num_heads, sweep_mc.num_blocks, sweep_mc.ball_size, sweep_mc.seq_len
    );
    {
        let mc = &sweep_mc;
        let x = {
            let mut rng = bsa::prng::Rng::new(mc.seq_len as u64);
            Tensor::new(vec![1, mc.seq_len, 6], rng.normals(mc.seq_len * 6))
        };
        let mut base_p50 = 0.0f64;
        for &t in &[1usize, 2, 4, 8] {
            let be = NativeBackend::init(0, mc, 6, 1, 1)?.with_threads(t);
            let _ = be.forward(&x)?; // warmup
            let mut hist = LatencyHistogram::new();
            let t0 = Instant::now();
            for _ in 0..reps {
                let r0 = Instant::now();
                let out = be.forward(&x)?;
                std::hint::black_box(&out);
                hist.record_us(r0.elapsed().as_secs_f64() * 1e6);
            }
            let wall = t0.elapsed().as_secs_f64();
            let (p50, p95) = (hist.percentile_us(50.0), hist.percentile_us(95.0));
            if t == 1 {
                base_p50 = p50;
            }
            let fwd_per_s = reps as f64 / wall;
            let speedup = if p50 > 0.0 { base_p50 / p50 } else { 0.0 };
            sweep_t.row(&[
                t.to_string(),
                format!("{:.2}", p50 / 1e3),
                format!("{:.2}", p95 / 1e3),
                format!("{fwd_per_s:.2}"),
                format!("{speedup:.2}x"),
            ]);
            sweep_json.push(format!(
                "{{\"threads\": {t}, \"p50_us\": {p50:.1}, \"p95_us\": {p95:.1}, \
                 \"fwd_per_s\": {fwd_per_s:.3}, \"speedup_vs_1t\": {speedup:.3}}}"
            ));
        }
    }

    // --- level 3: SIMD microkernels, scalar twins vs active level --------
    // Force the dispatch level per timing pass (Off = the scalar
    // reference loops, On = best detected AVX2/NEON/portable level).
    // The toggle is process-global, but nothing else is timing kernels
    // here and the mode is restored to Auto before the later levels.
    let mut simd_t = Table::new(&["kernel", "scalar us/call", "simd us/call", "speedup"]);
    let mut simd_json = Vec::new();
    let simd_mode;
    let simd_e2e_json;
    {
        use bsa::backend::{kernels, linalg, simd};

        simd::set_force(simd::Force::On);
        simd_mode = simd::active().name();
        simd::set_force(simd::Force::Auto);

        let calls = (200 * reps).max(200);
        {
            let mut time_pair = |label: &str, f: &mut dyn FnMut()| {
                let mut us = [0.0f64; 2];
                for (slot, force) in [(0usize, simd::Force::Off), (1, simd::Force::On)] {
                    simd::set_force(force);
                    f(); // warmup at this level
                    let t0 = Instant::now();
                    for _ in 0..calls {
                        f();
                    }
                    us[slot] = t0.elapsed().as_secs_f64() * 1e6 / calls as f64;
                }
                simd::set_force(simd::Force::Auto);
                let speedup = if us[1] > 0.0 { us[0] / us[1] } else { 0.0 };
                simd_t.row(&[
                    label.to_string(),
                    format!("{:.2}", us[0]),
                    format!("{:.2}", us[1]),
                    format!("{speedup:.2}x"),
                ]);
                simd_json.push(format!(
                    "{{\"name\": \"{label}\", \"scalar_us\": {:.3}, \"simd_us\": {:.3}, \
                     \"speedup\": {speedup:.3}}}",
                    us[0], us[1]
                ));
            };

            // attention-score GEMM (simd::dot reduction)
            let (m, kdim, n) = (128usize, 64usize, 128usize);
            let a = bsa::prng::Rng::new(31).normals(m * kdim);
            let b = bsa::prng::Rng::new(32).normals(n * kdim);
            let mut nt_out = vec![0.0f32; m * n];
            time_pair("matmul_nt_128x64x128", &mut || {
                linalg::matmul_nt(&a, &b, m, kdim, n, 1, &mut nt_out);
                std::hint::black_box(&nt_out);
            });

            // row softmax (max / exp-sum / scale panels)
            let sm_src = bsa::prng::Rng::new(33).normals(128 * 256);
            let mut sm = sm_src.clone();
            time_pair("softmax_rows_128x256", &mut || {
                sm.copy_from_slice(&sm_src);
                linalg::softmax_rows(&mut sm, 128, 256, 1);
                std::hint::black_box(&sm);
            });

            // RMSNorm (sum-of-squares reduction)
            let rn_x = bsa::prng::Rng::new(34).normals(256 * 64);
            let rn_s = bsa::prng::Rng::new(35).normals(64);
            let mut rn_out = vec![0.0f32; 256 * 64];
            time_pair("rms_norm_256x64", &mut || {
                linalg::rms_norm(&rn_x, &rn_s, 256, 64, 1, &mut rn_out);
                std::hint::black_box(&rn_out);
            });

            // ball attention (the per-unit dot/softmax/axpy panels)
            let (bn, bd, ball) = (512usize, 16usize, 64usize);
            let bq = bsa::prng::Rng::new(36).normals(bn * bd);
            let bk = bsa::prng::Rng::new(37).normals(bn * bd);
            let bv = bsa::prng::Rng::new(38).normals(bn * bd);
            let mut ball_out = vec![0.0f32; bn * bd];
            time_pair("ball_attention_n512_d16_m64", &mut || {
                kernels::ball_attention(&bq, &bk, &bv, bn, bd, ball, 1, &mut ball_out);
                std::hint::black_box(&ball_out);
            });

            // block compression (element-parallel add/scale panels)
            let cm_x = bsa::prng::Rng::new(39).normals(1024 * 64);
            let mut cm_out = vec![0.0f32; (1024 / 8) * 64];
            time_pair("compress_mean_n1024_d64_l8", &mut || {
                kernels::compress_mean(&cm_x, 1024, 64, 8, 1, &mut cm_out);
                std::hint::black_box(&cm_out);
            });
        }

        // end-to-end: the paper-config forward (threads=1, so the delta
        // is pure data-level parallelism), scalar twins vs active level
        let x = {
            let mut rng = bsa::prng::Rng::new(sweep_mc.seq_len as u64 + 1);
            Tensor::new(vec![1, sweep_mc.seq_len, 6], rng.normals(sweep_mc.seq_len * 6))
        };
        let be = NativeBackend::init(0, &sweep_mc, 6, 1, 1)?.with_threads(1);
        let mut fwd_per_s = [0.0f64; 2];
        for (slot, force) in [(0usize, simd::Force::Off), (1, simd::Force::On)] {
            simd::set_force(force);
            let _ = be.forward(&x)?; // warmup
            let t0 = Instant::now();
            for _ in 0..reps {
                let out = be.forward(&x)?;
                std::hint::black_box(&out);
            }
            fwd_per_s[slot] = reps as f64 / t0.elapsed().as_secs_f64();
        }
        simd::set_force(simd::Force::Auto);
        let e2e_speedup = if fwd_per_s[0] > 0.0 { fwd_per_s[1] / fwd_per_s[0] } else { 0.0 };
        simd_e2e_json = format!(
            "{{\"threads\": 1, \"scalar_fwd_per_s\": {:.3}, \"simd_fwd_per_s\": {:.3}, \
             \"speedup\": {e2e_speedup:.3}}}",
            fwd_per_s[0], fwd_per_s[1]
        );
        simd_t.row(&[
            "e2e_forward_paper_1t".into(),
            format!("{:.2} fwd/s", fwd_per_s[0]),
            format!("{:.2} fwd/s", fwd_per_s[1]),
            format!("{e2e_speedup:.2}x"),
        ]);
    }

    // --- level 4: dispatch overhead, persistent pool vs scoped spawn -----
    // Small kernels are where spawn cost shows: a 256-row x 64-wide
    // rowwise workload (tens of microseconds of math) dispatched
    // hundreds of times. Both dispatchers share chunk_rows, so their
    // outputs are bitwise identical — asserted before timing.
    let mut disp_t = Table::new(&["threads", "pool us/call", "scoped us/call", "saved us/call"]);
    let mut disp_json = Vec::new();
    let disp_calls = (300 * reps).max(300);
    {
        use bsa::backend::pool;
        let rows_n = 256usize;
        let width = 64usize;
        let src = bsa::prng::Rng::new(17).normals(rows_n * width);
        let work = |row0: usize, chunk: &mut [f32]| {
            for (i, row) in chunk.chunks_exact_mut(width).enumerate() {
                let s = &src[(row0 + i) * width..(row0 + i + 1) * width];
                let mut acc = 0.0f32;
                for &x in s {
                    acc += x * x;
                }
                for v in row.iter_mut() {
                    *v = acc;
                }
            }
        };
        for &t in &[2usize, 4, 8] {
            let mut pooled = vec![0.0f32; rows_n * width];
            let mut scoped = vec![0.0f32; rows_n * width];
            pool::par_rows(&mut pooled, width, t, work); // warms the pool workers
            pool::par_rows_scoped(&mut scoped, width, t, work);
            assert_eq!(pooled, scoped, "pool vs scoped diverged (threads {t})");
            let t0 = Instant::now();
            for _ in 0..disp_calls {
                pool::par_rows(&mut pooled, width, t, work);
            }
            let pool_us = t0.elapsed().as_secs_f64() * 1e6 / disp_calls as f64;
            let t0 = Instant::now();
            for _ in 0..disp_calls {
                pool::par_rows_scoped(&mut scoped, width, t, work);
            }
            let scoped_us = t0.elapsed().as_secs_f64() * 1e6 / disp_calls as f64;
            std::hint::black_box((&pooled, &scoped));
            disp_t.row(&[
                t.to_string(),
                format!("{pool_us:.2}"),
                format!("{scoped_us:.2}"),
                format!("{:.2}", scoped_us - pool_us),
            ]);
            disp_json.push(format!(
                "{{\"threads\": {t}, \"pool_us\": {pool_us:.3}, \"scoped_us\": {scoped_us:.3}, \
                 \"saved_us\": {:.3}}}",
                scoped_us - pool_us
            ));
        }
    }

    // --- level 5: head-parallel attention sweep ---------------------------
    // batch 2 x 4 heads = 8 independent (batch, head) units: the axis
    // native.rs::attention parallelizes over. Bitwise-invariant across
    // the sweep (the conformance suite asserts that; this records the
    // latency curve).
    let mut hp_t = Table::new(&["threads", "p50 ms", "p95 ms", "fwd/s", "speedup vs 1T"]);
    let mut hp_json = Vec::new();
    let hp_mc = ModelConfig {
        dim: 64,
        num_heads: 4,
        num_blocks: 2,
        ball_size: 128,
        seq_len: 512,
        ..Default::default()
    };
    let hp_batch = 2usize;
    let hp_units = hp_batch * hp_mc.num_heads;
    {
        let x = {
            let mut rng = bsa::prng::Rng::new(77);
            Tensor::new(
                vec![hp_batch, hp_mc.seq_len, 6],
                rng.normals(hp_batch * hp_mc.seq_len * 6),
            )
        };
        let mut base_p50 = 0.0f64;
        for &t in &[1usize, 2, 4, 8] {
            let be = NativeBackend::init(0, &hp_mc, 6, 1, hp_batch)?.with_threads(t);
            let _ = be.forward(&x)?; // warmup
            let mut hist = LatencyHistogram::new();
            let t0 = Instant::now();
            for _ in 0..reps {
                let r0 = Instant::now();
                let out = be.forward(&x)?;
                std::hint::black_box(&out);
                hist.record_us(r0.elapsed().as_secs_f64() * 1e6);
            }
            let wall = t0.elapsed().as_secs_f64();
            let (p50, p95) = (hist.percentile_us(50.0), hist.percentile_us(95.0));
            if t == 1 {
                base_p50 = p50;
            }
            let fwd_per_s = reps as f64 / wall;
            let speedup = if p50 > 0.0 { base_p50 / p50 } else { 0.0 };
            hp_t.row(&[
                t.to_string(),
                format!("{:.2}", p50 / 1e3),
                format!("{:.2}", p95 / 1e3),
                format!("{fwd_per_s:.2}"),
                format!("{speedup:.2}x"),
            ]);
            hp_json.push(format!(
                "{{\"threads\": {t}, \"p50_us\": {p50:.1}, \"p95_us\": {p95:.1}, \
                 \"fwd_per_s\": {fwd_per_s:.3}, \"speedup_vs_1t\": {speedup:.3}}}"
            ));
        }
    }

    // --- level 6: n_sweep — streaming forwards up to N=1M, f16 vs f32 ----
    // Per-N architecture: dim 32, 2 heads, 1 block, ball 256 (fixed, so
    // ball attention stays linear in N); cmp_block scales as
    // min(256, N/1024) so the compressed-block count nb stays bounded,
    // and top_k shrinks as cmp_block grows so the selected keys per
    // query stay ~2048 — the whole forward is then ~linear in N and the
    // fwd/s column is a real scaling curve. The streaming attention
    // path is what makes the large points *possible* at all: the
    // compression branch at N=1M attends nb=4096 keys per query, which
    // materialized would be a 1M x 4096 f32 score matrix (~16 GB);
    // streamed it is one 64-float tile per worker.
    //
    // rss_mb is the process peak (VmHWM), which only ever grows — so
    // the f16 arm runs first and N ascends within each arm, making each
    // reading the true high-water mark of its own point on any run
    // where footprints are monotone (they are: f16 staging is strictly
    // smaller than f32's at equal N).
    let mut ns_t = Table::new(&["N", "arm", "fwd/s", "peak RSS MB"]);
    let mut ns_arm_json = Vec::new();
    let ns_kernel_ab_json;
    let ns_cap: usize = if o.quick { 32_768 } else { 1_048_576 };
    {
        use bsa::backend::kernels;
        use bsa::backend::native::Precision;

        let ns_arch = |n: usize| {
            let cmp = (n / 1024).clamp(1, 256);
            ModelConfig {
                dim: 32,
                num_heads: 2,
                num_blocks: 1,
                ball_size: 256,
                cmp_block: cmp,
                sel_block: cmp,
                top_k: (2048 / cmp).max(1),
                group_size: 32,
                seq_len: n,
                ..Default::default()
            }
        };
        for (label, precision) in
            [("stream_f16", Precision::F16), ("stream_f32", Precision::F32)]
        {
            let mut pts = Vec::new();
            for &n in &[4096usize, 32_768, 262_144, 1_048_576] {
                if n > ns_cap {
                    continue;
                }
                let mc = ns_arch(n);
                mc.validate()?;
                let be = NativeBackend::init(0, &mc, 6, 1, 1)?.with_precision(precision);
                let x = {
                    let mut rng = bsa::prng::Rng::new(n as u64 + 101);
                    Tensor::new(vec![1, n, 6], rng.normals(n * 6))
                };
                // the big points are minutes of single-core work: one
                // timed pass, no warmup (steady-state jitter is small
                // next to a multi-second forward)
                let timed = if n >= 262_144 { 1 } else { reps };
                if n < 262_144 {
                    let _ = be.forward(&x)?;
                }
                let t0 = Instant::now();
                for _ in 0..timed {
                    let out = be.forward(&x)?;
                    std::hint::black_box(&out);
                }
                let fwd_per_s = timed as f64 / t0.elapsed().as_secs_f64();
                let rss_mb = peak_rss_mb();
                ns_t.row(&[
                    n.to_string(),
                    label.to_string(),
                    format!("{fwd_per_s:.3}"),
                    format!("{rss_mb:.0}"),
                ]);
                pts.push(format!(
                    "{{\"n\": {n}, \"fwd_per_s\": {fwd_per_s:.4}, \"rss_mb\": {rss_mb:.1}}}"
                ));
            }
            ns_arm_json.push(format!(
                "{{\"label\": \"{label}\", \"points\": [{}]}}",
                pts.join(", ")
            ));
        }

        // fixed-shape kernel A/B: the production streaming attend vs the
        // retained materialize-then-softmax pipeline, same inputs, both
        // against their scratch footprint (the streaming side's whole
        // point: a tile, not an nq x nk matrix)
        let (nq, nk, d) = (1024usize, 1024usize, 16usize);
        let scale = 1.0 / (d as f32).sqrt();
        let q = bsa::prng::Rng::new(61).normals(nq * d);
        let k = bsa::prng::Rng::new(62).normals(nk * d);
        let v = bsa::prng::Rng::new(63).normals(nk * d);
        let mut stream_out = vec![0.0f32; nq * d];
        let mut stream_scratch = Vec::new();
        let mut mat_out = vec![0.0f32; nq * d];
        let mut mat_scratch = Vec::new();
        let ab_calls = (3 * reps).max(3);
        kernels::attend(&q, &k, &v, nq, nk, d, scale, 1, &mut stream_out, &mut stream_scratch);
        kernels::attend_materialized(&q, &k, &v, nq, nk, d, scale, 1, &mut mat_out, &mut mat_scratch);
        for (i, (a, b)) in stream_out.iter().zip(&mat_out).enumerate() {
            assert!((a - b).abs() <= 1e-5, "stream vs materialized diverged at [{i}]");
        }
        let t0 = Instant::now();
        for _ in 0..ab_calls {
            kernels::attend(&q, &k, &v, nq, nk, d, scale, 1, &mut stream_out, &mut stream_scratch);
            std::hint::black_box(&stream_out);
        }
        let stream_us = t0.elapsed().as_secs_f64() * 1e6 / ab_calls as f64;
        let t0 = Instant::now();
        for _ in 0..ab_calls {
            kernels::attend_materialized(
                &q, &k, &v, nq, nk, d, scale, 1, &mut mat_out, &mut mat_scratch,
            );
            std::hint::black_box(&mat_out);
        }
        let mat_us = t0.elapsed().as_secs_f64() * 1e6 / ab_calls as f64;
        let stream_kb = stream_scratch.capacity() * 4 / 1024;
        let mat_kb = mat_scratch.capacity() * 4 / 1024;
        ns_kernel_ab_json = format!(
            "{{\"nq\": {nq}, \"nk\": {nk}, \"d\": {d}, \
             \"streaming_us\": {stream_us:.2}, \"materialized_us\": {mat_us:.2}, \
             \"streaming_scratch_kb\": {stream_kb}, \"materialized_scratch_kb\": {mat_kb}}}"
        );
        ns_t.row(&[
            format!("attend {nq}x{nk}"),
            "stream vs mat".into(),
            format!("{stream_us:.0} vs {mat_us:.0} us"),
            format!("scratch {stream_kb} vs {mat_kb} KB"),
        ]);
    }

    // --- level 7: native vs pjrt at the tiny config ----------------------
    let mut pjrt_json = String::from("{\"available\": false}");
    let mut pjrt_line = String::from(
        "pjrt comparison: artifacts unavailable (native-only run)\n",
    );
    if let Some(engine) = engine {
        let run = (|| -> anyhow::Result<(String, String)> {
            let init = engine.load("init_bsa_syn_n256_b1")?;
            let fwd = engine.load("fwd_bsa_syn_n256_b1")?;
            let params = init.run(&[scalar_i32(0)])?;
            let x = {
                let mut rng = bsa::prng::Rng::new(256);
                Tensor::new(vec![1, 256, 6], rng.normals(256 * 6))
            };
            let _ = fwd.run_with_tensors(&params, &[&x])?; // warmup
            let mut hist = LatencyHistogram::new();
            for _ in 0..reps {
                let t0 = Instant::now();
                let out = fwd.run_with_tensors(&params, &[&x])?;
                std::hint::black_box(&out);
                hist.record_us(t0.elapsed().as_secs_f64() * 1e6);
            }
            let (p50, p95) = (hist.percentile_us(50.0), hist.percentile_us(95.0));
            Ok((
                format!(
                    "{{\"available\": true, \"graph\": \"fwd_bsa_syn_n256_b1\", \
                     \"p50_us\": {p50:.1}, \"p95_us\": {p95:.1}}}"
                ),
                format!("pjrt fwd_bsa_syn_n256_b1: p50={p50:.0}us p95={p95:.0}us\n"),
            ))
        })();
        match run {
            Ok((j, l)) => {
                pjrt_json = j;
                pjrt_line = l;
            }
            Err(e) => println!("  (pjrt comparison skipped: {e})"),
        }
    }

    // --- level 8: end-to-end native router (artifact-free serving) ------
    let mc = arch(256);
    let backend = Arc::new(NativeBackend::init(0, &mc, 6, 1, 1)?);
    let sc = ServeConfig { workers: 2, flush_us: 200, ..Default::default() };
    let router = Router::start(backend, sc)?;
    let gen = generator_for("syn", 13)?;
    let total = (4 * reps).max(8);
    let t0 = Instant::now();
    for i in 0..total {
        let s = gen.generate((i % 4) as u64, 224);
        let p = router.infer(s.coords, s.features)?;
        std::hint::black_box(&p);
    }
    let wall = t0.elapsed().as_secs_f64();
    let (rp50, rp95) = (router.latency_us(50.0), router.latency_us(95.0));
    let st = router.shutdown();
    let router_json = format!(
        "{{\"requests\": {total}, \"req_per_s\": {:.3}, \"p50_us\": {rp50:.1}, \
         \"p95_us\": {rp95:.1}, \"tree_hits\": {}, \"tree_misses\": {}}}",
        total as f64 / wall,
        st.tree_hits,
        st.tree_misses
    );

    // --- level 9: tracing overhead, spans off vs on -----------------------
    // The trace layer's contract is near-zero cost when disabled and a
    // bounded (<3%, gated by scripts/check.sh) cost with full span
    // timing on. Demo arch at N=256, single thread: small forwards
    // maximize the *relative* cost of the per-stage span guards, so
    // this is the pessimistic arm of the contract.
    let trace_overhead_json;
    let trace_overhead_pct;
    {
        let prior = bsa::trace::level();
        let mc = arch(256);
        let be = NativeBackend::init(0, &mc, 6, 1, 1)?.with_threads(1);
        let x = {
            let mut rng = bsa::prng::Rng::new(257);
            Tensor::new(vec![1, 256, 6], rng.normals(256 * 6))
        };
        let calls = (40 * reps).max(40);
        let mut fwd_per_s = [0.0f64; 2];
        for (slot, level) in
            [(0usize, bsa::trace::TraceLevel::Off), (1, bsa::trace::TraceLevel::Spans)]
        {
            bsa::trace::set_level(level);
            let _ = be.forward(&x)?; // warmup at this level
            let t0 = Instant::now();
            for _ in 0..calls {
                let out = be.forward(&x)?;
                std::hint::black_box(&out);
            }
            fwd_per_s[slot] = calls as f64 / t0.elapsed().as_secs_f64();
        }
        bsa::trace::set_level(prior);
        trace_overhead_pct = if fwd_per_s[1] > 0.0 {
            (fwd_per_s[0] / fwd_per_s[1] - 1.0) * 100.0
        } else {
            0.0
        };
        trace_overhead_json = format!(
            "{{\"calls\": {calls}, \"fwd_per_s_off\": {:.3}, \"fwd_per_s_spans\": {:.3}, \
             \"overhead_pct\": {trace_overhead_pct:.3}}}",
            fwd_per_s[0], fwd_per_s[1]
        );
        println!(
            "  trace overhead (spans on vs off, demo N=256, 1 thread): {:.2} vs {:.2} fwd/s \
             ({trace_overhead_pct:+.2}%)",
            fwd_per_s[1], fwd_per_s[0]
        );
    }

    // --- level 10: native train step (tape forward + backward + AdamW) ---
    let train_step_json;
    {
        // VmHWM is cumulative; clear_refs "5" resets it so the reading
        // below is the training loop's own peak (Linux lets a process
        // write its own clear_refs; elsewhere the reading degrades to
        // the cumulative watermark and rss_reset records which it was).
        let rss_reset = std::fs::write("/proc/self/clear_refs", "5").is_ok();
        let mc = ModelConfig {
            dim: 32,
            num_heads: 2,
            num_blocks: 2,
            ball_size: 64,
            seq_len: 256,
            ..Default::default()
        };
        let tc = TrainConfig {
            task: "syn".into(),
            batch: 1,
            lr: 1e-3,
            warmup: 2,
            train_samples: 4,
            test_samples: 2,
            log_every: 1,
            ..Default::default()
        };
        let steps = (4 * reps).max(4);
        let mut trainer = bsa::coordinator::NativeTrainer::new(&mc, tc, 0)?;
        let first = trainer.step_once()?; // warmup + first loss
        let t0 = Instant::now();
        let mut last = first;
        for _ in 0..steps {
            last = trainer.step_once()?;
        }
        let steps_per_s = steps as f64 / t0.elapsed().as_secs_f64();
        let grad_peak_rss_mb = peak_rss_mb();
        train_step_json = format!(
            "{{\"arch\": {{\"dim\": {}, \"heads\": {}, \"blocks\": {}, \"ball\": {}, \
             \"n\": {}, \"batch\": 1}}, \"steps\": {steps}, \
             \"steps_per_s\": {steps_per_s:.3}, \"grad_peak_rss_mb\": {grad_peak_rss_mb:.1}, \
             \"rss_reset\": {rss_reset}, \
             \"loss_first\": {first:.6}, \"loss_last\": {last:.6}}}",
            mc.dim, mc.num_heads, mc.num_blocks, mc.ball_size, mc.seq_len
        );
        println!(
            "  native train step (dim {}, {} blocks, N={}): {steps_per_s:.2} steps/s, \
             loss {first:.4} -> {last:.4}, grad peak RSS {grad_peak_rss_mb:.0} MB",
            mc.dim, mc.num_blocks, mc.seq_len
        );
    }

    // --- artifact assembly ------------------------------------------------
    let json = format!(
        "{{\n  \"bench\": \"bsa_native\",\n  \"reps\": {reps},\n  \
         \"arch\": {{\"dim\": 32, \"heads\": 2, \"blocks\": 2, \"ball\": 64}},\n  \
         \"forward\": [{}],\n  \
         \"sweep_arch\": {sweep_arch_json},\n  \
         \"threads_sweep\": [{}],\n  \
         \"simd\": {{\"mode\": \"{simd_mode}\", \"kernels\": [{}], \
         \"e2e\": {simd_e2e_json}}},\n  \
         \"pool_dispatch\": {{\"rows\": 256, \"width\": 64, \"calls\": {disp_calls}, \
         \"points\": [{}]}},\n  \
         \"head_parallel\": {{\"arch\": {{\"dim\": {}, \"heads\": {}, \"blocks\": {}, \
         \"ball\": {}, \"n\": {}, \"batch\": {hp_batch}}}, \"units\": {hp_units}, \
         \"points\": [{}]}},\n  \
         \"n_sweep\": {{\"max_n\": {ns_cap}, \"arch\": {{\"dim\": 32, \"heads\": 2, \
         \"blocks\": 1, \"ball\": 256}}, \"arms\": [{}], \
         \"kernel_ab\": {ns_kernel_ab_json}}},\n  \
         \"trace_overhead\": {trace_overhead_json},\n  \
         \"train_step\": {train_step_json},\n  \
         \"pjrt\": {pjrt_json},\n  \"router\": {router_json}\n}}\n",
        fwd_json.join(", "),
        sweep_json.join(", "),
        simd_json.join(", "),
        disp_json.join(", "),
        hp_mc.dim,
        hp_mc.num_heads,
        hp_mc.num_blocks,
        hp_mc.ball_size,
        hp_mc.seq_len,
        hp_json.join(", "),
        ns_arm_json.join(", ")
    );
    // BENCH_native.json lives next to ROADMAP.md (the per-PR perf
    // trajectory); cargo runs benches from rust/, so look one level up.
    let dest = if Path::new("../ROADMAP.md").exists() {
        PathBuf::from("../BENCH_native.json")
    } else {
        PathBuf::from("BENCH_native.json")
    };
    std::fs::write(&dest, &json)?;
    std::fs::write(o.out.join("bsa_native.json"), &json)?;

    let mut content = format!(
        "## bsa_native — pure-Rust BSA forward (dim 32, 2 blocks, {reps} reps)\n\n"
    );
    content.push_str(&t.render());
    content.push_str(&format!(
        "\n### threads-vs-throughput (paper Table-4 config: dim {}, {} blocks, N={})\n\n",
        sweep_mc.dim, sweep_mc.num_blocks, sweep_mc.seq_len
    ));
    content.push_str(&sweep_t.render());
    content.push_str(&format!(
        "\n### SIMD microkernels — scalar twins vs {simd_mode} (single thread)\n\n"
    ));
    content.push_str(&simd_t.render());
    content.push_str(&format!(
        "\n### dispatch overhead — persistent pool vs per-call scoped spawn \
         (256x64 rowwise kernel, {disp_calls} calls)\n\n"
    ));
    content.push_str(&disp_t.render());
    content.push_str(&format!(
        "\n### head-parallel attention (dim {}, {} heads, batch {hp_batch} -> {hp_units} units, N={})\n\n",
        hp_mc.dim, hp_mc.num_heads, hp_mc.seq_len
    ));
    content.push_str(&hp_t.render());
    content.push_str(&format!(
        "\n### n_sweep — streaming forward scaling to N={ns_cap} \
         (dim 32, 1 block, ball 256; f16 arm first, N ascending)\n\n"
    ));
    content.push_str(&ns_t.render());
    content.push('\n');
    content.push_str(&format!(
        "trace overhead (spans on vs off, demo N=256, 1 thread): {trace_overhead_pct:+.2}%\n"
    ));
    content.push_str(&pjrt_line);
    content.push_str(&format!(
        "native router e2e: {total} reqs, {:.2} req/s, p50={rp50:.0}us p95={rp95:.0}us, \
         tree hits/misses {}/{}\n",
        total as f64 / wall,
        st.tree_hits,
        st.tree_misses
    ));
    content.push_str(&format!(
        "native train step (backend::grad, dim 32, 2 blocks, N=256): see the \
         `train_step` record of {}\n",
        dest.display()
    ));
    content.push_str(&format!(
        "machine-readable trajectory written to {}\n",
        dest.display()
    ));
    emit(&o.out, "bsa_native", &content)
}
