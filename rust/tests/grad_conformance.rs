//! Differential + finite-difference conformance for the gradient
//! kernels (`bsa::backend::grad`) — the backward-pass mirror of
//! `rust/tests/conformance.rs`.
//!
//! Three gates per kernel, per the tier table in the `grad` module
//! docs:
//!
//! 1. **Twin** — fast vs `*_reference` scalar twin: bitwise for the
//!    element-parallel kernels (`matmul_tn`, `bias_grad`,
//!    `swiglu_backward`), 1e-5 for the reduction users
//!    (`rms_norm_backward`, the `attend_backward` family). Bitwise
//!    across *thread counts* always — the same contract as the forward.
//! 2. **FD oracle** — directional derivative `dot(grad, u)` against the
//!    central difference `(L(θ+εu) − L(θ−εu)) / 2ε` of the *forward*
//!    kernel, `ε = 1e-2`, within `1e-3 · (1 + |analytic|)` (the bound
//!    was calibrated against an f32 numpy prototype; see also the numpy
//!    mirror `python/tests/test_grad_mirror.py`, which checks the same
//!    formulas against `jax.grad` of the `ref.py` oracle).
//! 3. **Whole-model** — `loss_and_grads` is bitwise across thread
//!    counts, its tape forward is bitwise identical to the serving
//!    forward (`NativeBackend::forward`), and the full loss gradient
//!    passes a (coarser) directional FD check — coarser because the
//!    straight-through top-k means a large perturbation can flip block
//!    selection, a documented non-differentiability (docs/TRAINING.md).
//!
//! Checkpoint version-skew tests for `.bsackpt` v3 (optimizer moments)
//! live at the bottom: v3 serves inference with moments skipped, and a
//! truncated moment array is a typed load error, not a panic.

use bsa::backend::grad::{self, Adam};
use bsa::backend::native::AttnHyper;
use bsa::backend::{kernels, linalg, Backend, NativeBackend, NativeParams};
use bsa::config::ModelConfig;
use bsa::proptest_lite::{forall, Gen};
use bsa::tensor::Tensor;

const TOL: f32 = 1e-5;
/// FD step: large enough that the f32 forward's rounding noise stays
/// two decades under the bound, small enough that curvature does too.
const FD_EPS: f32 = 1e-2;

fn assert_close(fast: &[f32], reference: &[f32], what: &str) {
    assert_eq!(fast.len(), reference.len(), "{what}: length mismatch");
    for (i, (a, b)) in fast.iter().zip(reference).enumerate() {
        assert!(
            (a - b).abs() <= TOL,
            "{what}[{i}]: fast {a} vs reference {b}"
        );
    }
}

fn assert_bitwise(fast: &[f32], reference: &[f32], what: &str) {
    assert_eq!(fast.len(), reference.len(), "{what}: length mismatch");
    for (i, (a, b)) in fast.iter().zip(reference).enumerate() {
        assert!(
            a.to_bits() == b.to_bits(),
            "{what}[{i}]: {a} vs {b} differ in bits"
        );
    }
}

/// The FD acceptance bound: |fd − analytic| ≤ 1e-3 · (1 + |analytic|).
fn assert_fd(analytic: f64, fd: f64, what: &str) {
    let tol = 1e-3 * (1.0 + analytic.abs());
    assert!(
        (fd - analytic).abs() <= tol,
        "{what}: analytic {analytic} vs central-difference {fd} (tol {tol})"
    );
}

/// dot in f64 so the check itself adds no f32 noise.
fn dot64(a: &[f32], b: &[f32]) -> f64 {
    a.iter().zip(b).map(|(&x, &y)| x as f64 * y as f64).sum()
}

fn pick_threads(g: &mut Gen) -> usize {
    *g.choose(&[1usize, 2, 3, 4, 8])
}

// ---------------------------------------------------------------------------
// Twin gates
// ---------------------------------------------------------------------------

#[test]
fn grad_matmul_tn_bitwise_twin() {
    // matmul_tn is built from ascending axpy chains over whole output
    // rows — the element-parallel panel recipe — so fast == reference
    // bit for bit at every SIMD level and thread count.
    forall(40, |g| {
        let m = g.usize_in(1..40);
        let k = g.usize_in(1..33);
        let n = g.usize_in(1..24);
        let threads = pick_threads(g);
        let a = g.normals(m * k);
        let b = g.normals(m * n);
        let mut fast = vec![0.0f32; k * n];
        grad::linalg::matmul_tn(&a, &b, m, k, n, threads, &mut fast);
        let mut refr = vec![0.0f32; k * n];
        grad::linalg::matmul_tn_reference(&a, &b, m, k, n, &mut refr);
        assert_bitwise(&fast, &refr, "matmul_tn");
    });
}

#[test]
fn grad_bias_grad_bitwise_twin() {
    forall(40, |g| {
        let rows = g.usize_in(1..50);
        let n = g.usize_in(1..40);
        let threads = pick_threads(g);
        let dy = g.normals(rows * n);
        let mut fast = vec![0.0f32; n];
        grad::linalg::bias_grad(&dy, rows, n, threads, &mut fast);
        let mut refr = vec![0.0f32; n];
        grad::linalg::bias_grad_reference(&dy, rows, n, &mut refr);
        assert_bitwise(&fast, &refr, "bias_grad");
    });
}

#[test]
fn grad_swiglu_backward_bitwise_twin() {
    forall(40, |g| {
        let len = g.usize_in(1..200);
        let threads = pick_threads(g);
        let h1 = g.normals(len);
        let h3 = g.normals(len);
        let dg = g.normals(len);
        let (mut f1, mut f3) = (vec![0.0f32; len], vec![0.0f32; len]);
        grad::linalg::swiglu_backward(&h1, &h3, &dg, threads, &mut f1, &mut f3);
        let (mut r1, mut r3) = (vec![0.0f32; len], vec![0.0f32; len]);
        grad::linalg::swiglu_backward_reference(&h1, &h3, &dg, &mut r1, &mut r3);
        assert_bitwise(&f1, &r1, "swiglu dh1");
        assert_bitwise(&f3, &r3, "swiglu dh3");
    });
}

#[test]
fn grad_rms_norm_backward_matches_reference() {
    forall(40, |g| {
        let rows = g.usize_in(1..30);
        let cols = g.usize_in(1..48);
        let threads = pick_threads(g);
        let x = g.normals(rows * cols);
        let scale = g.normals(cols);
        let dy = g.normals(rows * cols);
        let (mut dx, mut ds) = (vec![0.0f32; rows * cols], vec![0.0f32; cols]);
        grad::linalg::rms_norm_backward(&x, &scale, &dy, rows, cols, threads, &mut dx, &mut ds);
        let (mut rdx, mut rds) = (vec![0.0f32; rows * cols], vec![0.0f32; cols]);
        grad::linalg::rms_norm_backward_reference(&x, &scale, &dy, rows, cols, &mut rdx, &mut rds);
        assert_close(&dx, &rdx, "rms_norm_backward dx");
        assert_close(&ds, &rds, "rms_norm_backward dscale");

        // bitwise across thread counts at the active SIMD level
        let (mut dx1, mut ds1) = (vec![0.0f32; rows * cols], vec![0.0f32; cols]);
        grad::linalg::rms_norm_backward(&x, &scale, &dy, rows, cols, 1, &mut dx1, &mut ds1);
        assert_bitwise(&dx, &dx1, "rms_norm_backward dx across threads");
        assert_bitwise(&ds, &ds1, "rms_norm_backward dscale across threads");
    });
}

#[test]
fn grad_attend_backward_matches_reference() {
    forall(30, |g| {
        let nq = g.usize_in(1..24);
        let nk = g.usize_in(1..80); // crosses STREAM_TILE=64 with tails
        let d = g.usize_in(1..12);
        let scale = 1.0 / (d as f32).sqrt();
        let q = g.normals(nq * d);
        let k = g.normals(nk * d);
        let v = g.normals(nk * d);
        let dout = g.normals(nq * d);
        let mut o = vec![0.0f32; nq * d];
        let mut scratch = Vec::new();
        kernels::attend(&q, &k, &v, nq, nk, d, scale, 1, &mut o, &mut scratch);

        let mk = |len| vec![0.0f32; len];
        let (mut dq, mut dk, mut dv) = (mk(nq * d), mk(nk * d), mk(nk * d));
        grad::attention::attend_backward(
            &q, &k, &v, &o, &dout, nq, nk, d, scale, &mut dq, &mut dk, &mut dv,
        );
        let (mut rq, mut rk, mut rv) = (mk(nq * d), mk(nk * d), mk(nk * d));
        grad::attention::attend_backward_reference(
            &q, &k, &v, &o, &dout, nq, nk, d, scale, &mut rq, &mut rk, &mut rv,
        );
        assert_close(&dq, &rq, "attend_backward dq");
        assert_close(&dk, &rk, "attend_backward dk");
        assert_close(&dv, &rv, "attend_backward dv");
    });
}

#[test]
fn grad_ball_attention_backward_matches_reference() {
    forall(25, |g| {
        let ball = *g.choose(&[1usize, 2, 4, 8, 16]);
        let balls = g.usize_in(1..5);
        let n = ball * balls;
        let d = g.usize_in(1..10);
        let q = g.normals(n * d);
        let k = g.normals(n * d);
        let v = g.normals(n * d);
        let dout = g.normals(n * d);
        let mut o = vec![0.0f32; n * d];
        kernels::ball_attention(&q, &k, &v, n, d, ball, 1, &mut o);

        let mk = || vec![0.0f32; n * d];
        let (mut dq, mut dk, mut dv) = (mk(), mk(), mk());
        grad::attention::ball_attention_backward(
            &q, &k, &v, &o, &dout, n, d, ball, &mut dq, &mut dk, &mut dv,
        );
        let (mut rq, mut rk, mut rv) = (mk(), mk(), mk());
        grad::attention::ball_attention_backward_reference(
            &q, &k, &v, &o, &dout, n, d, ball, &mut rq, &mut rk, &mut rv,
        );
        assert_close(&dq, &rq, "ball_attention_backward dq");
        assert_close(&dk, &rk, "ball_attention_backward dk");
        assert_close(&dv, &rv, "ball_attention_backward dv");
    });
}

/// Real selection indices from the forward's own ranking pipeline, so
/// the backward replays exactly what a training step would.
#[allow(clippy::too_many_arguments)]
fn selection_indices(
    q: &[f32],
    k: &[f32],
    n: usize,
    d: usize,
    cmp_block: usize,
    group: usize,
    ball: usize,
    top_k: usize,
) -> (Vec<f32>, Vec<usize>) {
    let nb = n / cmp_block;
    let mut kc = vec![0.0f32; nb * d];
    kernels::compress_mean(k, n, d, cmp_block, 1, &mut kc);
    let groups = n / group;
    let mut qg = Vec::new();
    let mut scores = vec![0.0f32; groups * nb];
    kernels::group_scores(q, &kc, n, d, group, nb, 1, &mut qg, &mut scores);
    kernels::mask_own_ball(&mut scores, groups, nb, group, cmp_block, ball);
    let mut idx = Vec::new();
    kernels::topk_indices(&scores, groups, nb, top_k, 1, &mut idx);
    (kc, idx)
}

#[test]
fn grad_select_attention_backward_matches_reference() {
    forall(20, |g| {
        let cmp_block = *g.choose(&[2usize, 4]);
        let group = *g.choose(&[2usize, 4]);
        let ball = 8usize; // divisible by both choices
        let n = ball * g.usize_in(2..5);
        let d = g.usize_in(2..9);
        let top_k = g.usize_in(1..(n / cmp_block).min(4));
        let q = g.normals(n * d);
        let k = g.normals(n * d);
        let v = g.normals(n * d);
        let dout = g.normals(n * d);
        let (_, idx) = selection_indices(&q, &k, n, d, cmp_block, group, ball, top_k);
        let mut o = vec![0.0f32; n * d];
        kernels::select_attention(&q, &k, &v, &idx, n, d, cmp_block, group, top_k, 1, &mut o);

        let mk = || vec![0.0f32; n * d];
        let (mut dq, mut dk, mut dv) = (mk(), mk(), mk());
        grad::attention::select_attention_backward(
            &q, &k, &v, &o, &dout, &idx, n, d, cmp_block, group, top_k, &mut dq, &mut dk, &mut dv,
        );
        let (mut rq, mut rk, mut rv) = (mk(), mk(), mk());
        grad::attention::select_attention_backward_reference(
            &q, &k, &v, &o, &dout, &idx, n, d, cmp_block, group, top_k, &mut rq, &mut rk, &mut rv,
        );
        assert_close(&dq, &rq, "select_attention_backward dq");
        assert_close(&dk, &rk, "select_attention_backward dk");
        assert_close(&dv, &rv, "select_attention_backward dv");
    });
}

// ---------------------------------------------------------------------------
// FD oracles: dot(grad, u) vs central difference of the forward kernel
// ---------------------------------------------------------------------------

#[test]
fn fd_matmul_tn_is_gradient_of_matmul() {
    // L(b) = dot(w, a @ b)  =>  dL/db = aᵀ w = matmul_tn(a, w).
    let mut rng = bsa::prng::Rng::new(31);
    let (m, k, n) = (9usize, 14usize, 11usize);
    let a = rng.normals(m * k);
    let b = rng.normals(k * n);
    let w = rng.normals(m * n);
    let u = rng.normals(k * n);
    let mut db = vec![0.0f32; k * n];
    grad::linalg::matmul_tn(&a, &w, m, k, n, 1, &mut db);
    let loss = |bb: &[f32]| -> f64 {
        let mut y = vec![0.0f32; m * n];
        linalg::matmul(&a, bb, m, k, n, 1, &mut y);
        dot64(&y, &w)
    };
    let mut plus = b.clone();
    let mut minus = b.clone();
    for i in 0..b.len() {
        plus[i] += FD_EPS * u[i];
        minus[i] -= FD_EPS * u[i];
    }
    let fd = (loss(&plus) - loss(&minus)) / (2.0 * FD_EPS as f64);
    assert_fd(dot64(&db, &u), fd, "matmul_tn FD");
}

#[test]
fn fd_rms_norm_backward() {
    let mut rng = bsa::prng::Rng::new(32);
    let (rows, cols) = (12usize, 20usize);
    let x = rng.normals(rows * cols);
    let scale = rng.normals(cols);
    let w = rng.normals(rows * cols);
    let (mut dx, mut ds) = (vec![0.0f32; rows * cols], vec![0.0f32; cols]);
    grad::linalg::rms_norm_backward(&x, &scale, &w, rows, cols, 1, &mut dx, &mut ds);
    let loss = |xx: &[f32], ss: &[f32]| -> f64 {
        let mut y = vec![0.0f32; rows * cols];
        linalg::rms_norm(xx, ss, rows, cols, 1, &mut y);
        dot64(&y, &w)
    };
    // direction in x
    let u = rng.normals(rows * cols);
    let mut plus = x.clone();
    let mut minus = x.clone();
    for i in 0..x.len() {
        plus[i] += FD_EPS * u[i];
        minus[i] -= FD_EPS * u[i];
    }
    let fd = (loss(&plus, &scale) - loss(&minus, &scale)) / (2.0 * FD_EPS as f64);
    assert_fd(dot64(&dx, &u), fd, "rms_norm_backward dx FD");
    // direction in scale
    let us = rng.normals(cols);
    let mut splus = scale.clone();
    let mut sminus = scale.clone();
    for i in 0..cols {
        splus[i] += FD_EPS * us[i];
        sminus[i] -= FD_EPS * us[i];
    }
    let fd = (loss(&x, &splus) - loss(&x, &sminus)) / (2.0 * FD_EPS as f64);
    assert_fd(dot64(&ds, &us), fd, "rms_norm_backward dscale FD");
}

#[test]
fn fd_swiglu_backward() {
    let mut rng = bsa::prng::Rng::new(33);
    let len = 150usize;
    let h1 = rng.normals(len);
    let h3 = rng.normals(len);
    let w = rng.normals(len);
    let (mut d1, mut d3) = (vec![0.0f32; len], vec![0.0f32; len]);
    grad::linalg::swiglu_backward(&h1, &h3, &w, 1, &mut d1, &mut d3);
    let silu = |x: f32| x * linalg::sigmoid(x);
    let loss = |a: &[f32], b: &[f32]| -> f64 {
        (0..len).map(|i| (silu(a[i]) * b[i]) as f64 * w[i] as f64).sum()
    };
    for (name, theta, grad) in [("dh1", &h1, &d1), ("dh3", &h3, &d3)] {
        let u = rng.normals(len);
        let mut plus = theta.to_vec();
        let mut minus = theta.to_vec();
        for i in 0..len {
            plus[i] += FD_EPS * u[i];
            minus[i] -= FD_EPS * u[i];
        }
        let (lp, lm) = if name == "dh1" {
            (loss(&plus, &h3), loss(&minus, &h3))
        } else {
            (loss(&h1, &plus), loss(&h1, &minus))
        };
        let fd = (lp - lm) / (2.0 * FD_EPS as f64);
        assert_fd(dot64(grad, &u), fd, "swiglu_backward FD");
    }
}

#[test]
fn fd_attend_backward() {
    let mut rng = bsa::prng::Rng::new(34);
    let (nq, nk, d) = (10usize, 70usize, 8usize); // nk crosses STREAM_TILE
    let scale = 1.0 / (d as f32).sqrt();
    let q = rng.normals(nq * d);
    let k = rng.normals(nk * d);
    let v = rng.normals(nk * d);
    let w = rng.normals(nq * d);
    let mut o = vec![0.0f32; nq * d];
    let mut scratch = Vec::new();
    kernels::attend(&q, &k, &v, nq, nk, d, scale, 1, &mut o, &mut scratch);
    let (mut dq, mut dk, mut dv) =
        (vec![0.0f32; nq * d], vec![0.0f32; nk * d], vec![0.0f32; nk * d]);
    grad::attention::attend_backward(
        &q, &k, &v, &o, &w, nq, nk, d, scale, &mut dq, &mut dk, &mut dv,
    );
    let loss = |qq: &[f32], kk: &[f32], vv: &[f32]| -> f64 {
        let mut out = vec![0.0f32; nq * d];
        let mut s = Vec::new();
        kernels::attend(qq, kk, vv, nq, nk, d, scale, 1, &mut out, &mut s);
        dot64(&out, &w)
    };
    for (name, theta, grad) in [("dq", &q, &dq), ("dk", &k, &dk), ("dv", &v, &dv)] {
        let u = rng.normals(theta.len());
        let mut plus = theta.to_vec();
        let mut minus = theta.to_vec();
        for i in 0..theta.len() {
            plus[i] += FD_EPS * u[i];
            minus[i] -= FD_EPS * u[i];
        }
        let (lp, lm) = match name {
            "dq" => (loss(&plus, &k, &v), loss(&minus, &k, &v)),
            "dk" => (loss(&q, &plus, &v), loss(&q, &minus, &v)),
            _ => (loss(&q, &k, &plus), loss(&q, &k, &minus)),
        };
        let fd = (lp - lm) / (2.0 * FD_EPS as f64);
        assert_fd(dot64(grad, &u), fd, "attend_backward FD");
    }
}

#[test]
fn fd_ball_attention_backward() {
    let mut rng = bsa::prng::Rng::new(35);
    let (ball, n, d) = (8usize, 32usize, 6usize);
    let q = rng.normals(n * d);
    let k = rng.normals(n * d);
    let v = rng.normals(n * d);
    let w = rng.normals(n * d);
    let mut o = vec![0.0f32; n * d];
    kernels::ball_attention(&q, &k, &v, n, d, ball, 1, &mut o);
    let mk = || vec![0.0f32; n * d];
    let (mut dq, mut dk, mut dv) = (mk(), mk(), mk());
    grad::attention::ball_attention_backward(
        &q, &k, &v, &o, &w, n, d, ball, &mut dq, &mut dk, &mut dv,
    );
    let loss = |qq: &[f32], kk: &[f32], vv: &[f32]| -> f64 {
        let mut out = vec![0.0f32; n * d];
        kernels::ball_attention(qq, kk, vv, n, d, ball, 1, &mut out);
        dot64(&out, &w)
    };
    for (name, theta, grad) in [("dq", &q, &dq), ("dk", &k, &dk), ("dv", &v, &dv)] {
        let u = rng.normals(n * d);
        let mut plus = theta.to_vec();
        let mut minus = theta.to_vec();
        for i in 0..n * d {
            plus[i] += FD_EPS * u[i];
            minus[i] -= FD_EPS * u[i];
        }
        let (lp, lm) = match name {
            "dq" => (loss(&plus, &k, &v), loss(&minus, &k, &v)),
            "dk" => (loss(&q, &plus, &v), loss(&q, &minus, &v)),
            _ => (loss(&q, &k, &plus), loss(&q, &k, &minus)),
        };
        let fd = (lp - lm) / (2.0 * FD_EPS as f64);
        assert_fd(dot64(grad, &u), fd, "ball_attention_backward FD");
    }
}

#[test]
fn fd_select_attention_backward() {
    // idx is held fixed across the perturbation (straight-through
    // semantics: the FD probes the kernel at frozen selection, exactly
    // what the analytic backward computes).
    let mut rng = bsa::prng::Rng::new(36);
    let (n, d, cmp_block, group, ball, top_k) = (32usize, 6usize, 4usize, 4usize, 8usize, 3usize);
    let q = rng.normals(n * d);
    let k = rng.normals(n * d);
    let v = rng.normals(n * d);
    let w = rng.normals(n * d);
    let (_, idx) = selection_indices(&q, &k, n, d, cmp_block, group, ball, top_k);
    let mut o = vec![0.0f32; n * d];
    kernels::select_attention(&q, &k, &v, &idx, n, d, cmp_block, group, top_k, 1, &mut o);
    let mk = || vec![0.0f32; n * d];
    let (mut dq, mut dk, mut dv) = (mk(), mk(), mk());
    grad::attention::select_attention_backward(
        &q, &k, &v, &o, &w, &idx, n, d, cmp_block, group, top_k, &mut dq, &mut dk, &mut dv,
    );
    let loss = |qq: &[f32], kk: &[f32], vv: &[f32]| -> f64 {
        let mut out = vec![0.0f32; n * d];
        kernels::select_attention(qq, kk, vv, &idx, n, d, cmp_block, group, top_k, 1, &mut out);
        dot64(&out, &w)
    };
    for (name, theta, grad) in [("dq", &q, &dq), ("dk", &k, &dk), ("dv", &v, &dv)] {
        let u = rng.normals(n * d);
        let mut plus = theta.to_vec();
        let mut minus = theta.to_vec();
        for i in 0..n * d {
            plus[i] += FD_EPS * u[i];
            minus[i] -= FD_EPS * u[i];
        }
        let (lp, lm) = match name {
            "dq" => (loss(&plus, &k, &v), loss(&minus, &k, &v)),
            "dk" => (loss(&q, &plus, &v), loss(&q, &minus, &v)),
            _ => (loss(&q, &k, &plus), loss(&q, &k, &minus)),
        };
        let fd = (lp - lm) / (2.0 * FD_EPS as f64);
        assert_fd(dot64(grad, &u), fd, "select_attention_backward FD");
    }
}

#[test]
fn fd_compress_mean_backward() {
    let mut rng = bsa::prng::Rng::new(37);
    let (n, d, block) = (24usize, 7usize, 4usize);
    let x = rng.normals(n * d);
    let w = rng.normals((n / block) * d);
    let mut dx = vec![0.0f32; n * d];
    grad::attention::compress_mean_backward(&w, n, d, block, &mut dx);
    let loss = |xx: &[f32]| -> f64 {
        let mut c = vec![0.0f32; (n / block) * d];
        kernels::compress_mean(xx, n, d, block, 1, &mut c);
        dot64(&c, &w)
    };
    let u = rng.normals(n * d);
    let mut plus = x.clone();
    let mut minus = x.clone();
    for i in 0..n * d {
        plus[i] += FD_EPS * u[i];
        minus[i] -= FD_EPS * u[i];
    }
    let fd = (loss(&plus) - loss(&minus)) / (2.0 * FD_EPS as f64);
    assert_fd(dot64(&dx, &u), fd, "compress_mean_backward FD");
}

#[test]
fn fd_merge_backward() {
    let mut rng = bsa::prng::Rng::new(38);
    let (n, d) = (16usize, 9usize);
    let logits = rng.normals(n * 3);
    let ob = rng.normals(n * d);
    let oc = rng.normals(n * d);
    let os = rng.normals(n * d);
    let w = rng.normals(n * d);
    let mut dl = vec![0.0f32; n * 3];
    let mk = || vec![0.0f32; n * d];
    let (mut db, mut dc, mut ds) = (mk(), mk(), mk());
    grad::attention::merge_backward(
        &logits, &ob, &oc, &os, &w, n, d, &mut dl, &mut db, &mut dc, &mut ds,
    );
    let merge = |lg: &[f32], b: &[f32], c: &[f32], s: &[f32]| -> f64 {
        let mut acc = 0.0f64;
        for t in 0..n {
            for j in 0..d {
                let m = linalg::sigmoid(lg[t * 3]) * b[t * d + j]
                    + linalg::sigmoid(lg[t * 3 + 1]) * c[t * d + j]
                    + linalg::sigmoid(lg[t * 3 + 2]) * s[t * d + j];
                acc += m as f64 * w[t * d + j] as f64;
            }
        }
        acc
    };
    // logits direction
    let u = rng.normals(n * 3);
    let mut plus = logits.clone();
    let mut minus = logits.clone();
    for i in 0..n * 3 {
        plus[i] += FD_EPS * u[i];
        minus[i] -= FD_EPS * u[i];
    }
    let fd = (merge(&plus, &ob, &oc, &os) - merge(&minus, &ob, &oc, &os)) / (2.0 * FD_EPS as f64);
    assert_fd(dot64(&dl, &u), fd, "merge_backward dlogits FD");
    // branch directions
    for (name, theta, grad) in [("ball", &ob, &db), ("cmp", &oc, &dc), ("slc", &os, &ds)] {
        let u = rng.normals(n * d);
        let mut plus = theta.to_vec();
        let mut minus = theta.to_vec();
        for i in 0..n * d {
            plus[i] += FD_EPS * u[i];
            minus[i] -= FD_EPS * u[i];
        }
        let (lp, lm) = match name {
            "ball" => (merge(&logits, &plus, &oc, &os), merge(&logits, &minus, &oc, &os)),
            "cmp" => (merge(&logits, &ob, &plus, &os), merge(&logits, &ob, &minus, &os)),
            _ => (merge(&logits, &ob, &oc, &plus), merge(&logits, &ob, &oc, &minus)),
        };
        let fd = (lp - lm) / (2.0 * FD_EPS as f64);
        assert_fd(dot64(grad, &u), fd, "merge_backward dbranch FD");
    }
}

// ---------------------------------------------------------------------------
// Whole-model gates
// ---------------------------------------------------------------------------

fn tiny_hyper() -> (ModelConfig, AttnHyper) {
    let mc = ModelConfig {
        dim: 16,
        num_heads: 2,
        num_blocks: 2,
        ball_size: 16,
        cmp_block: 4,
        sel_block: 4,
        top_k: 2,
        group_size: 4,
        seq_len: 64,
        ..Default::default()
    };
    let hyper = AttnHyper::from_model(&mc);
    (mc, hyper)
}

#[test]
fn grad_tape_forward_matches_serving_forward_bitwise() {
    // The tape forward must be the *same* forward the serving path
    // runs — same kernels, same order — or training would optimize a
    // different function than serving evaluates.
    let (mc, hyper) = tiny_hyper();
    let params = NativeParams::init(5, 6, 1, mc.dim, mc.num_heads, mc.num_blocks, 4);
    let n = mc.seq_len;
    let mut rng = bsa::prng::Rng::new(77);
    let x = Tensor::new(vec![1, n, 6], rng.normals(n * 6));
    let backend = NativeBackend::new(params.clone(), hyper.clone(), n, 1)
        .unwrap()
        .with_threads(2);
    let served = backend.forward(&x).unwrap();
    let tape = grad::tape::forward(&params, &hyper, x.data(), 1, n, 2);
    assert_bitwise(&tape.pred, served.data(), "tape forward vs NativeBackend");
}

#[test]
fn grad_loss_and_grads_bitwise_across_threads() {
    let (mc, hyper) = tiny_hyper();
    let params = NativeParams::init(6, 6, 1, mc.dim, mc.num_heads, mc.num_blocks, 4);
    let n = mc.seq_len;
    let mut rng = bsa::prng::Rng::new(78);
    let x = rng.normals(n * 6);
    let y = rng.normals(n);
    let (l1, _, g1) = grad::loss_and_grads(&params, &hyper, &x, &y, 1, n, 1);
    for t in [2usize, 3, 8] {
        let (lt, _, gt) = grad::loss_and_grads(&params, &hyper, &x, &y, 1, n, t);
        assert!(l1.to_bits() == lt.to_bits(), "loss differs at threads={t}");
        for ((name, a), (_, b)) in g1.named_arrays().iter().zip(gt.named_arrays()) {
            assert_bitwise(a.data(), b.data(), &format!("grad {name} at threads={t}"));
        }
    }
}

#[test]
fn fd_full_model_loss_and_grads() {
    // Directional FD through the whole model: MSE loss, all parameters
    // perturbed along one random direction. The bound is coarser than
    // the per-kernel oracles (4e-3 vs 1e-3): six chained nonlinear
    // layers accumulate curvature, and the straight-through top-k is
    // only piecewise smooth — FD_EPS is small enough that the fixed
    // seeds here do not flip any block selection.
    let (mc, hyper) = tiny_hyper();
    let params = NativeParams::init(7, 6, 1, mc.dim, mc.num_heads, mc.num_blocks, 4);
    let n = mc.seq_len;
    let mut rng = bsa::prng::Rng::new(79);
    let x = rng.normals(n * 6);
    let y = rng.normals(n);
    let (_, _, grads) = grad::loss_and_grads(&params, &hyper, &x, &y, 1, n, 2);

    let mut dirs: Vec<Vec<f32>> = Vec::new();
    for (_, t) in params.named_arrays() {
        dirs.push(rng.normals(t.data().len()));
    }
    let mut analytic = 0.0f64;
    for ((_, g), u) in grads.named_arrays().iter().zip(&dirs) {
        analytic += dot64(g.data(), u);
    }
    let shifted = |sign: f32| -> f32 {
        let mut p = params.clone();
        for ((_, t), u) in p.named_arrays_mut().into_iter().zip(&dirs) {
            for (w, &du) in t.data_mut().iter_mut().zip(u) {
                *w += sign * FD_EPS * du;
            }
        }
        let tape = grad::tape::forward(&p, &hyper, &x, 1, n, 2);
        let mut dpred = vec![0.0f32; tape.pred.len()];
        grad::linalg::mse_loss_grad(&tape.pred, &y, &mut dpred)
    };
    let fd = (shifted(1.0) as f64 - shifted(-1.0) as f64) / (2.0 * FD_EPS as f64);
    let tol = 4e-3 * (1.0 + analytic.abs());
    assert!(
        (fd - analytic).abs() <= tol,
        "full-model FD: analytic {analytic} vs central-difference {fd} (tol {tol})"
    );
}

// ---------------------------------------------------------------------------
// Adam
// ---------------------------------------------------------------------------

#[test]
fn adam_first_step_matches_closed_form() {
    // With zeroed moments, step 1 reduces to
    //   p -= lr * (g / (|g| * sqrt(1) + eps') + wd * p)
    // i.e. approximately lr * sign(g) plus the decay term — check the
    // exact closed form element-wise.
    let mut params = NativeParams::init(1, 3, 1, 8, 2, 1, 4);
    let before = params.clone();
    let mut grads = params.zeros_like();
    for (_, t) in grads.named_arrays_mut() {
        for (i, g) in t.data_mut().iter_mut().enumerate() {
            *g = 0.5 - (i % 3) as f32 * 0.5; // mix of +0.5, 0, -0.5
        }
    }
    let (lr, wd) = (1e-3f32, 0.01f32);
    let mut opt = Adam::new(&params, wd);
    opt.step(lr, &mut params, &grads);
    assert_eq!(opt.t, 1);
    for (((_, p), (_, p0)), (_, g)) in params
        .named_arrays()
        .iter()
        .zip(before.named_arrays())
        .zip(grads.named_arrays())
    {
        for i in 0..p.data().len() {
            let gi = g.data()[i];
            // mirror the kernel's exact float expressions (f64 bias
            // corrections, f32 everything else)
            let m = (1.0 - 0.9f32) * gi;
            let v = (1.0 - 0.999f32) * gi * gi;
            let mhat = m / (1.0 - 0.9f64.powi(1)) as f32;
            let vhat = v / (1.0 - 0.999f64.powi(1)) as f32;
            let want = p0.data()[i] - lr * (mhat / (vhat.sqrt() + 1e-8) + wd * p0.data()[i]);
            let got = p.data()[i];
            assert!(
                (want - got).abs() <= 1e-6 * (1.0 + want.abs()),
                "adam step: got {got}, want {want}"
            );
        }
    }
}

#[test]
fn adam_descends_a_quadratic() {
    // min ||p||²/2: gradient is p itself; a few hundred Adam steps
    // must shrink the parameters toward zero.
    let mut params = NativeParams::init(2, 3, 1, 8, 2, 1, 4);
    let norm0: f64 = params
        .named_arrays()
        .iter()
        .map(|(_, t)| dot64(t.data(), t.data()))
        .sum();
    let mut opt = Adam::new(&params, 0.0);
    for _ in 0..300 {
        let grads = params.clone();
        opt.step(0.01, &mut params, &grads);
    }
    let norm1: f64 = params
        .named_arrays()
        .iter()
        .map(|(_, t)| dot64(t.data(), t.data()))
        .sum();
    assert!(
        norm1 < norm0 * 0.05,
        "adam failed to descend: ||p||² {norm0} -> {norm1}"
    );
}

// ---------------------------------------------------------------------------
// Checkpoint v3 version skew (see also coordinator::checkpoint tests
// and the conformance.rs params error-path suite)
// ---------------------------------------------------------------------------

fn tmp(name: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(name)
}

/// A v3 training checkpoint: model arrays + m.* / v.* moments + step.
fn v3_fixture() -> (NativeParams, Vec<(String, Tensor)>) {
    let params = NativeParams::init(3, 6, 1, 16, 2, 1, 4);
    let opt = Adam::new(&params, 0.01);
    let mut arrays: Vec<(String, Tensor)> = params
        .named_arrays()
        .into_iter()
        .map(|(n, t)| (n, t.clone()))
        .collect();
    for (n, t) in opt.m.named_arrays() {
        arrays.push((format!("m.{n}"), t.clone()));
    }
    for (n, t) in opt.v.named_arrays() {
        arrays.push((format!("v.{n}"), t.clone()));
    }
    (params, arrays)
}

#[test]
fn v3_checkpoint_with_moments_serves_inference() {
    // Inference loaders skip m.*/v.*: a full training checkpoint is a
    // valid param file, and the model arrays round-trip exactly.
    let (params, arrays) = v3_fixture();
    let path = tmp("bsa_grad_v3_serves.bsackpt");
    bsa::coordinator::checkpoint::Checkpoint { step: 41, arrays }
        .save(&path)
        .unwrap();
    let loaded = NativeParams::load(&path).unwrap();
    for ((name, a), (_, b)) in params.named_arrays().iter().zip(loaded.named_arrays()) {
        assert_bitwise(a.data(), b.data(), &format!("served param {name}"));
    }
    // and it backs a full serving construction
    let hyper = AttnHyper { ball_size: 16, cmp_block: 4, group_size: 4, top_k: 2 };
    NativeBackend::load(&path, hyper, 64, 1).unwrap();
    std::fs::remove_file(path).ok();
}

#[test]
fn v3_truncated_moment_array_is_typed_error() {
    let (_, arrays) = v3_fixture();
    let path = tmp("bsa_grad_v3_truncated.bsackpt");
    bsa::coordinator::checkpoint::Checkpoint { step: 7, arrays }
        .save(&path)
        .unwrap();
    let bytes = std::fs::read(&path).unwrap();
    // cut inside the moment tail (the second half holds m.*/v.*)
    for cut in [bytes.len() - 5, bytes.len() * 3 / 4] {
        std::fs::write(&path, &bytes[..cut]).unwrap();
        assert!(
            bsa::coordinator::checkpoint::Checkpoint::load(&path).is_err(),
            "truncation at {cut} must be a load error"
        );
    }
    std::fs::remove_file(path).ok();
}
