//! End-to-end integration tests.
//!
//! PJRT-path tests need `make artifacts` to have produced the `core`
//! suite (the tiny `bsa_syn_n256_b1` graphs are built for exactly this)
//! and skip gracefully when artifacts are missing. The `native_*` tests
//! run the same router/serving surface over the pure-Rust
//! [`NativeBackend`] and therefore run on every host — no artifacts, no
//! Python toolchain. When both are available,
//! `native_backend_matches_pjrt_forward` is the semantic parity gate
//! between the compiled graphs and the native implementation.

use std::path::{Path, PathBuf};
use std::sync::Arc;

use bsa::backend::{native::AttnHyper, Backend, NativeBackend};
use bsa::config::{ModelConfig, ServeConfig, TrainConfig};
use bsa::coordinator::{Router, Trainer};
use bsa::data::generator_for;
use bsa::runtime::{literal_to_tensor, scalar_i32, Engine};
use bsa::tensor::Tensor;

const TINY: &str = "bsa_syn_n256_b1";

/// Native twin of the tiny core artifact (same architecture dims).
fn tiny_native_config() -> ModelConfig {
    ModelConfig {
        dim: 32,
        num_heads: 2,
        num_blocks: 2,
        ball_size: 64,
        seq_len: 256,
        ..Default::default()
    }
}

fn tiny_native_backend(seed: u64) -> NativeBackend {
    NativeBackend::init(seed, &tiny_native_config(), 6, 1, 1).unwrap()
}

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

/// One PJRT client per *process*: concurrent `PjRtClient::cpu()` creation
/// from parallel test threads deadlocks inside the plugin, so every test
/// shares this engine.
fn engine() -> Option<Arc<Engine>> {
    use std::sync::OnceLock;
    static ENGINE: OnceLock<Option<Arc<Engine>>> = OnceLock::new();
    ENGINE
        .get_or_init(|| {
            let dir = artifacts_dir();
            if !dir.join("manifest.txt").exists() {
                eprintln!("skipping: no artifacts (run `make artifacts`)");
                return None;
            }
            Some(Arc::new(Engine::new(&dir).expect("engine")))
        })
        .clone()
}

fn tiny_train_config() -> TrainConfig {
    TrainConfig {
        task: "syn".into(),
        steps: 8,
        batch: 1,
        train_samples: 6,
        test_samples: 2,
        log_every: 2,
        warmup: 2,
        ..Default::default()
    }
}

#[test]
fn init_graph_is_deterministic_per_seed() {
    let Some(engine) = engine() else { return };
    let init = engine.load(&format!("init_{TINY}")).unwrap();
    let a = init.run(&[scalar_i32(7)]).unwrap();
    let b = init.run(&[scalar_i32(7)]).unwrap();
    let c = init.run(&[scalar_i32(8)]).unwrap();
    let ta = literal_to_tensor(&a[0]).unwrap();
    let tb = literal_to_tensor(&b[0]).unwrap();
    let tc = literal_to_tensor(&c[0]).unwrap();
    assert_eq!(ta, tb);
    assert_ne!(ta, tc);
    // all params finite
    for l in &a {
        assert!(literal_to_tensor(l).unwrap().all_finite());
    }
}

#[test]
fn fwd_graph_runs_and_matches_manifest_shapes() {
    let Some(engine) = engine() else { return };
    let init = engine.load(&format!("init_{TINY}")).unwrap();
    let fwd = engine.load(&format!("fwd_{TINY}")).unwrap();
    let params = init.run(&[scalar_i32(0)]).unwrap();
    assert_eq!(params.len(), fwd.info.nparams);

    let n = fwd.info.n;
    let f = fwd.info.in_features;
    let gen = generator_for("syn", 0).unwrap();
    let sample = gen.generate(0, n);
    let x = Tensor::new(vec![1, n, f], sample.features.data().to_vec());
    let out = fwd.run_with_tensors(&params, &[&x]).unwrap();
    let pred = literal_to_tensor(&out[0]).unwrap();
    assert_eq!(pred.shape(), &[1, n, fwd.info.out_features]);
    assert!(pred.all_finite());
}

#[test]
fn fwd_graph_is_deterministic() {
    let Some(engine) = engine() else { return };
    let init = engine.load(&format!("init_{TINY}")).unwrap();
    let fwd = engine.load(&format!("fwd_{TINY}")).unwrap();
    let params = init.run(&[scalar_i32(3)]).unwrap();
    let n = fwd.info.n;
    let gen = generator_for("syn", 1).unwrap();
    let x = Tensor::new(
        vec![1, n, fwd.info.in_features],
        gen.generate(0, n).features.data().to_vec(),
    );
    let a = literal_to_tensor(&fwd.run_with_tensors(&params, &[&x]).unwrap()[0]).unwrap();
    let b = literal_to_tensor(&fwd.run_with_tensors(&params, &[&x]).unwrap()[0]).unwrap();
    assert_eq!(a, b);
}

#[test]
fn trainer_reduces_loss_and_checkpoints() {
    let Some(engine) = engine() else { return };
    let tc = tiny_train_config();
    let mut trainer = Trainer::new(engine.clone(), TINY, tc).unwrap();
    let first = trainer.step_once().unwrap();
    let mut last = first;
    for _ in 0..15 {
        last = trainer.step_once().unwrap();
    }
    assert!(last.is_finite() && first.is_finite());
    assert!(last < first, "loss did not decrease: {first} -> {last}");

    // checkpoint roundtrip preserves state
    let path = std::env::temp_dir().join("bsa_it_ckpt.bsackpt");
    trainer.save_checkpoint(&path).unwrap();
    let mse_before = trainer.evaluate().unwrap();
    let mut restored = Trainer::new(engine, TINY, tiny_train_config()).unwrap();
    restored.load_checkpoint(&path).unwrap();
    assert_eq!(restored.step, trainer.step);
    let mse_after = restored.evaluate().unwrap();
    assert!((mse_before - mse_after).abs() < 1e-6, "{mse_before} vs {mse_after}");
    std::fs::remove_file(path).ok();
}

#[test]
fn trainer_eval_improves_over_random() {
    let Some(engine) = engine() else { return };
    let tc = TrainConfig { steps: 60, ..tiny_train_config() };
    let mut fresh = Trainer::new(engine.clone(), TINY, tc.clone()).unwrap();
    let mse_random = fresh.evaluate().unwrap();
    fresh.run(|_| {}).unwrap();
    let mse_trained = fresh.evaluate().unwrap();
    assert!(
        mse_trained < mse_random,
        "training did not improve eval: {mse_random} -> {mse_trained}"
    );
}

#[test]
fn router_serves_and_unpermutes() {
    let Some(engine) = engine() else { return };
    let init = engine.load(&format!("init_{TINY}")).unwrap();
    let params: Vec<Tensor> = init
        .run(&[scalar_i32(0)])
        .unwrap()
        .iter()
        .map(|l| literal_to_tensor(l).unwrap())
        .collect();
    let sc = ServeConfig { workers: 2, flush_us: 200, seq_len: 256, ..Default::default() };
    let router =
        Arc::new(Router::start_pjrt(engine, &format!("fwd_{TINY}"), params, sc).unwrap());

    // a cloud *smaller* than N exercises ball-tree padding + unpermute
    let gen = generator_for("syn", 2).unwrap();
    let sample = gen.generate(0, 200);
    let pred = router
        .infer(sample.coords.clone(), sample.features.clone())
        .unwrap();
    assert_eq!(pred.shape(), &[200, 1]);
    assert!(pred.all_finite());

    // deterministic serving: identical input => identical prediction
    // (the router seeds the ball tree from a content hash, so padding and
    // permutation are reproducible across requests)
    let pred2 = router.infer(sample.coords.clone(), sample.features).unwrap();
    for (x, y) in pred.data().iter().zip(pred2.data()) {
        assert!((x - y).abs() < 1e-5, "{x} vs {y}");
    }

    let stats = router.stats();
    assert_eq!(stats.served, 2);
    let stats = Arc::try_unwrap(router).ok().unwrap().shutdown();
    assert_eq!(stats.served, 2);
}

#[test]
fn router_tree_cache_is_semantically_invisible() {
    // Serving the same geometry with the ball-tree cache off, cold (first
    // touch with cache on), and hot (cache hit) must produce bit-identical
    // predictions — the cache only skips work, never changes it.
    let Some(engine) = engine() else { return };
    let init = engine.load(&format!("init_{TINY}")).unwrap();
    let params: Vec<Tensor> = init
        .run(&[scalar_i32(0)])
        .unwrap()
        .iter()
        .map(|l| literal_to_tensor(l).unwrap())
        .collect();
    let gen = generator_for("syn", 8).unwrap();
    let sample = gen.generate(0, 190);

    let sc_off = ServeConfig { workers: 1, flush_us: 100, tree_cache: 0, ..Default::default() };
    let r_off =
        Router::start_pjrt(engine.clone(), &format!("fwd_{TINY}"), params.clone(), sc_off).unwrap();
    let p_off = r_off
        .infer(sample.coords.clone(), sample.features.clone())
        .unwrap();
    let st_off = r_off.shutdown();
    assert_eq!((st_off.tree_hits, st_off.tree_misses), (0, 1));

    let sc_on = ServeConfig { workers: 1, flush_us: 100, tree_cache: 8, ..Default::default() };
    let r_on = Router::start_pjrt(engine, &format!("fwd_{TINY}"), params, sc_on).unwrap();
    let p_cold = r_on
        .infer(sample.coords.clone(), sample.features.clone())
        .unwrap();
    let p_hot = r_on.infer(sample.coords, sample.features).unwrap();
    let st_on = r_on.shutdown();
    assert_eq!(st_on.tree_misses, 1, "one build for the repeated geometry");
    assert!(st_on.tree_hits >= 1, "second request must hit the cache");
    assert_eq!(p_cold.data(), p_off.data(), "cache-enabled cold != cache-off");
    assert_eq!(p_hot.data(), p_cold.data(), "cache hit changed the prediction");
}

#[test]
fn router_rejects_malformed_requests() {
    let Some(engine) = engine() else { return };
    let init = engine.load(&format!("init_{TINY}")).unwrap();
    let params: Vec<Tensor> = init
        .run(&[scalar_i32(0)])
        .unwrap()
        .iter()
        .map(|l| literal_to_tensor(l).unwrap())
        .collect();
    let sc = ServeConfig { workers: 1, flush_us: 100, ..Default::default() };
    let router = Router::start_pjrt(engine, &format!("fwd_{TINY}"), params, sc).unwrap();

    // wrong feature width
    let coords = Tensor::zeros(vec![64, 3]);
    let feats = Tensor::zeros(vec![64, 3]); // graph expects 6
    let err = router.infer(coords, feats);
    assert!(err.is_err());

    // too many points for the compiled N
    let coords = Tensor::zeros(vec![512, 3]);
    let feats = Tensor::zeros(vec![512, 6]);
    assert!(router.infer(coords, feats).is_err());

    // empty point cloud errors cleanly (must not panic the worker)
    let coords = Tensor::zeros(vec![0, 3]);
    let feats = Tensor::zeros(vec![0, 6]);
    assert!(router.infer(coords, feats).is_err());

    // the (sole) worker survived all of the above and still serves
    let gen = generator_for("syn", 5).unwrap();
    let s = gen.generate(0, 200);
    let pred = router.infer(s.coords, s.features).unwrap();
    assert_eq!(pred.shape(), &[200, 1]);
}

#[test]
fn dynamic_batcher_fills_compiled_batch() {
    // With a B=4 compiled graph and concurrent submission, the batcher
    // must group requests (mean batch > 1) — the coordinator's core
    // batching invariant. Requires the bench artifact suite.
    let Some(engine) = engine() else { return };
    let graph = "fwd_bsa_air_n1024_b4_ref";
    if engine.manifest.get(graph).is_err() {
        eprintln!("skipping: {graph} not built (make artifacts-bench)");
        return;
    }
    let init = engine
        .load("init_bsa_air_n1024_b2_ref")
        .or_else(|_| engine.load("init_bsa_air_n1024_b2"))
        .unwrap();
    let params: Vec<Tensor> = init
        .run(&[scalar_i32(0)])
        .unwrap()
        .iter()
        .map(|l| literal_to_tensor(l).unwrap())
        .collect();
    let sc = ServeConfig { workers: 1, flush_us: 50_000, ..Default::default() };
    let router = Router::start_pjrt(engine, graph, params, sc).unwrap();

    let gen = generator_for("air", 4).unwrap();
    let mut rxs = vec![];
    for i in 0..8 {
        let s = gen.generate(i, 900);
        rxs.push(router.submit(s.coords, s.features).unwrap());
    }
    for rx in rxs {
        let resp = rx.recv().expect("reply");
        let pred = resp.result.expect("prediction");
        assert_eq!(pred.shape(), &[900, 1]);
        assert!(pred.all_finite());
    }
    let st = router.stats();
    assert_eq!(st.served, 8);
    assert!(
        st.mean_batch > 1.5,
        "batcher did not group: mean_batch {}",
        st.mean_batch
    );
}

#[test]
fn checkpoint_roundtrips_into_router() {
    // Train briefly, checkpoint, serve from the checkpoint: prediction
    // through the router must match the trainer's own fwd evaluation.
    let Some(engine) = engine() else { return };
    let mut trainer = Trainer::new(engine.clone(), TINY, tiny_train_config()).unwrap();
    for _ in 0..4 {
        trainer.step_once().unwrap();
    }
    let path = std::env::temp_dir().join("bsa_router_ckpt.bsackpt");
    trainer.save_checkpoint(&path).unwrap();

    let ck = bsa::coordinator::checkpoint::Checkpoint::load(&path).unwrap();
    let fwd = engine.load(&format!("fwd_{TINY}")).unwrap();
    let params: Vec<Tensor> = ck
        .arrays
        .into_iter()
        .take(fwd.info.nparams)
        .map(|(_, t)| t)
        .collect();
    let sc = ServeConfig { workers: 1, flush_us: 100, ..Default::default() };
    let router = Router::start_pjrt(engine, &format!("fwd_{TINY}"), params, sc).unwrap();
    let gen = generator_for("syn", 6).unwrap();
    let s = gen.generate(0, 220);
    let pred = router.infer(s.coords, s.features).unwrap();
    assert_eq!(pred.shape(), &[220, 1]);
    assert!(pred.all_finite());
    std::fs::remove_file(path).ok();
}

#[test]
fn tcp_server_roundtrip() {
    let Some(engine) = engine() else { return };
    let init = engine.load(&format!("init_{TINY}")).unwrap();
    let params: Vec<Tensor> = init
        .run(&[scalar_i32(0)])
        .unwrap()
        .iter()
        .map(|l| literal_to_tensor(l).unwrap())
        .collect();
    let sc = ServeConfig { workers: 1, flush_us: 100, ..Default::default() };
    let router = Arc::new(Router::start_pjrt(engine, &format!("fwd_{TINY}"), params, sc).unwrap());

    let addr = "127.0.0.1:17177";
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let srv = {
        let router = router.clone();
        let stop = stop.clone();
        let addr = addr.to_string();
        std::thread::spawn(move || bsa::server::serve(&addr, router, stop))
    };
    std::thread::sleep(std::time::Duration::from_millis(100));

    let gen = generator_for("syn", 3).unwrap();
    let sample = gen.generate(0, 180);
    let mut client = bsa::server::Client::connect(addr).unwrap();
    let pred = client.predict(&sample.coords, &sample.features).unwrap();
    assert_eq!(pred.shape(), &[180, 1]);
    assert!(pred.all_finite());

    // stats frame interleaves with predictions on the same connection
    let stats = client.stats().unwrap();
    assert!(stats.contains("\"served\""), "stats json: {stats}");
    assert!(stats.contains("\"tree_misses\""), "stats json: {stats}");
    let pred2 = client.predict(&sample.coords, &sample.features).unwrap();
    assert_eq!(pred2.shape(), &[180, 1]);

    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    srv.join().unwrap().unwrap();
}

// ---------------------------------------------------------------------------
// native backend: artifact-free serving + pjrt parity
// ---------------------------------------------------------------------------

#[test]
fn native_router_serves_without_artifacts() {
    // The full serving surface — router, ball-tree cache, zero-copy
    // batching, padding/unpermute — over the pure-Rust backend. Runs on
    // hosts with no artifacts/ directory and no Python toolchain.
    let backend = Arc::new(tiny_native_backend(0));
    let sc = ServeConfig { workers: 2, flush_us: 200, seq_len: 256, ..Default::default() };
    let router = Router::start(backend, sc).unwrap();

    // a cloud *smaller* than N exercises ball-tree padding + unpermute
    let gen = generator_for("syn", 2).unwrap();
    let sample = gen.generate(0, 200);
    let pred = router
        .infer(sample.coords.clone(), sample.features.clone())
        .unwrap();
    assert_eq!(pred.shape(), &[200, 1]);
    assert!(pred.all_finite());

    // deterministic serving: identical input => identical prediction
    let pred2 = router.infer(sample.coords, sample.features).unwrap();
    assert_eq!(pred.data(), pred2.data(), "native serving must be deterministic");

    let stats = router.shutdown();
    assert_eq!(stats.served, 2);
    assert!(stats.tree_hits >= 1, "second request must hit the tree cache");
}

#[test]
fn native_router_rejects_malformed_and_survives() {
    let backend = Arc::new(tiny_native_backend(1));
    let sc = ServeConfig { workers: 1, flush_us: 100, ..Default::default() };
    let router = Router::start(backend, sc).unwrap();

    // wrong feature width / too many points / empty cloud all error
    assert!(router.infer(Tensor::zeros(vec![64, 3]), Tensor::zeros(vec![64, 3])).is_err());
    assert!(router.infer(Tensor::zeros(vec![512, 3]), Tensor::zeros(vec![512, 6])).is_err());
    assert!(router.infer(Tensor::zeros(vec![0, 3]), Tensor::zeros(vec![0, 6])).is_err());

    // the (sole) worker survived and still serves
    let gen = generator_for("syn", 5).unwrap();
    let s = gen.generate(0, 180);
    let pred = router.infer(s.coords, s.features).unwrap();
    assert_eq!(pred.shape(), &[180, 1]);
}

#[test]
fn native_tcp_server_roundtrip() {
    // TCP frame protocol end-to-end over the native backend: the whole
    // stack is artifact-free, including the "BSST" stats surface.
    let backend = Arc::new(tiny_native_backend(2));
    let sc = ServeConfig { workers: 1, flush_us: 100, ..Default::default() };
    let router = Arc::new(Router::start(backend, sc).unwrap());

    let addr = "127.0.0.1:17179";
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let srv = {
        let router = router.clone();
        let stop = stop.clone();
        let addr = addr.to_string();
        std::thread::spawn(move || bsa::server::serve(&addr, router, stop))
    };
    std::thread::sleep(std::time::Duration::from_millis(100));

    let gen = generator_for("syn", 3).unwrap();
    let sample = gen.generate(0, 170);
    let mut client = bsa::server::Client::connect(addr).unwrap();
    let pred = client.predict(&sample.coords, &sample.features).unwrap();
    assert_eq!(pred.shape(), &[170, 1]);
    assert!(pred.all_finite());
    let stats = client.stats().unwrap();
    assert!(stats.contains("\"served\""), "stats json: {stats}");

    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    srv.join().unwrap().unwrap();
}

#[test]
fn native_tcp_interleaved_batches_roundtrip_and_stats() {
    // Two clients pipeline requests concurrently with *different* point
    // counts against the same server: every reply must carry exactly its
    // own request's length (no cross-request scatter from the shared
    // batch buffer), and the router's ball-tree cache counters must show
    // one build per distinct geometry with all repeats hitting.
    let backend = Arc::new(tiny_native_backend(4));
    let sc = ServeConfig { workers: 2, flush_us: 200, ..Default::default() };
    let router = Arc::new(Router::start(backend, sc).unwrap());

    let addr = "127.0.0.1:17181";
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let srv = {
        let router = router.clone();
        let stop = stop.clone();
        let addr = addr.to_string();
        std::thread::spawn(move || bsa::server::serve(&addr, router, stop))
    };
    std::thread::sleep(std::time::Duration::from_millis(100));

    let gen = generator_for("syn", 9).unwrap();
    let rounds = 3usize;
    let run_client = |sample_seed: u64, points: usize| {
        let sample = gen.generate(sample_seed, points);
        let mut client = bsa::server::Client::connect(addr).unwrap();
        for round in 0..rounds {
            let pred = client.predict(&sample.coords, &sample.features).unwrap();
            assert_eq!(
                pred.shape(),
                &[points, 1],
                "client {sample_seed} round {round}: reply length != request length"
            );
            assert!(pred.all_finite());
        }
    };
    std::thread::scope(|s| {
        let a = s.spawn(|| run_client(0, 150));
        let b = s.spawn(|| run_client(1, 230));
        a.join().expect("client A");
        b.join().expect("client B");
    });

    // Counters: 2 distinct geometries -> 2 builds; each client's
    // remaining requests are sequential on an already-resident tree.
    let mut client = bsa::server::Client::connect(addr).unwrap();
    let stats = client.stats().unwrap();
    assert!(stats.contains("\"served\": 6"), "stats json: {stats}");
    assert!(stats.contains("\"tree_misses\": 2"), "stats json: {stats}");
    assert!(stats.contains("\"tree_hits\": 4"), "stats json: {stats}");

    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    srv.join().unwrap().unwrap();
    let st = Arc::try_unwrap(router).ok().unwrap().shutdown();
    assert_eq!(st.served, 6);
    assert_eq!((st.tree_hits, st.tree_misses), (4, 2));
}

/// Live thread count of this process (linux: /proc/self/status
/// `Threads:`; elsewhere 0, which makes the churn assertion vacuous
/// rather than flaky).
fn live_threads() -> usize {
    if let Ok(status) = std::fs::read_to_string("/proc/self/status") {
        for line in status.lines() {
            if let Some(rest) = line.strip_prefix("Threads:") {
                if let Ok(n) = rest.trim().parse() {
                    return n;
                }
            }
        }
    }
    0
}

#[test]
fn native_tcp_connection_churn_reaps_handlers() {
    // The poll core owns every connection on one thread, so connection
    // churn must never move the process thread count: each short-lived
    // client adds a pollfd entry, not a thread, and its close (EOF) just
    // drops the entry. This end-to-end churn pins that: every request
    // answered across many short-lived connections, the thread
    // population staying flat, and shutdown staying clean.
    let backend = Arc::new(tiny_native_backend(6));
    let sc = ServeConfig { workers: 1, flush_us: 100, ..Default::default() };
    let router = Arc::new(Router::start(backend, sc).unwrap());

    let addr = "127.0.0.1:17183";
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let srv = {
        let router = router.clone();
        let stop = stop.clone();
        let addr = addr.to_string();
        std::thread::spawn(move || bsa::server::serve(&addr, router, stop))
    };
    std::thread::sleep(std::time::Duration::from_millis(100));

    let gen = generator_for("syn", 10).unwrap();
    let sample = gen.generate(0, 160);
    // warm everything the first request lazily creates (worker pool
    // growth, tree cache) so the baseline thread count is steady-state
    {
        let mut c = bsa::server::Client::connect(addr).unwrap();
        let p = c.predict(&sample.coords, &sample.features).unwrap();
        assert_eq!(p.shape(), &[160, 1]);
    }
    std::thread::sleep(std::time::Duration::from_millis(300));
    let before = live_threads();

    let churn = 24usize;
    for round in 0..churn {
        let mut c = bsa::server::Client::connect(addr).unwrap();
        let p = c.predict(&sample.coords, &sample.features).unwrap();
        assert_eq!(p.shape(), &[160, 1], "churn round {round}");
        assert!(p.all_finite());
        // client drops here: the poll core sees EOF on its next tick and
        // drops the connection entry (no thread ever existed for it)
    }
    // give the EOFs a few poll ticks to land before counting
    std::thread::sleep(std::time::Duration::from_millis(500));
    let after = live_threads();
    assert!(
        after <= before + 3,
        "connection churn grew the thread population: {before} -> {after}"
    );

    // the server still accepts and serves after the churn
    {
        let mut c = bsa::server::Client::connect(addr).unwrap();
        let p = c.predict(&sample.coords, &sample.features).unwrap();
        assert_eq!(p.shape(), &[160, 1]);
        let stats = c.stats().unwrap();
        assert!(
            stats.contains(&format!("\"served\": {}", churn + 2)),
            "stats json after churn: {stats}"
        );
    }

    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    srv.join().unwrap().unwrap();
    let st = Arc::try_unwrap(router).ok().unwrap().shutdown();
    assert_eq!(st.served as usize, churn + 2);
}

#[test]
fn native_tcp_stats_spans_roundtrip() {
    // With tracing at `spans`, the BSST stats frame must carry the
    // versioned trace sections with per-stage histograms aggregated
    // across the whole serve path — decode, router preprocess, every
    // backend stage, encode — and the payload must still round-trip
    // through the ordinary TCP client (i.e. stay under the client's
    // 64 KiB stats bound).
    let prior = bsa::trace::level();
    bsa::trace::set_level(bsa::trace::TraceLevel::Spans);

    let backend = Arc::new(tiny_native_backend(8));
    let sc = ServeConfig { workers: 1, flush_us: 100, ..Default::default() };
    let router = Arc::new(Router::start(backend, sc).unwrap());

    let addr = "127.0.0.1:17185";
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let srv = {
        let router = router.clone();
        let stop = stop.clone();
        let addr = addr.to_string();
        std::thread::spawn(move || bsa::server::serve(&addr, router, stop))
    };
    std::thread::sleep(std::time::Duration::from_millis(100));

    let gen = generator_for("syn", 12).unwrap();
    let sample = gen.generate(0, 190);
    let mut client = bsa::server::Client::connect(addr).unwrap();
    for _ in 0..2 {
        let pred = client.predict(&sample.coords, &sample.features).unwrap();
        assert_eq!(pred.shape(), &[190, 1]);
        assert!(pred.all_finite());
    }

    let stats = client.stats().unwrap();
    // versioned schema marker + level echo
    assert!(stats.contains("\"trace_version\": 1"), "stats json: {stats}");
    assert!(stats.contains("\"spans\""), "stats json: {stats}");
    // serve-path endpoints
    assert!(stats.contains("\"serve.decode\""), "stats json: {stats}");
    assert!(stats.contains("\"serve.encode\""), "stats json: {stats}");
    // router preprocess + tree cache
    assert!(stats.contains("\"router.preprocess\""), "stats json: {stats}");
    assert!(
        stats.contains("\"router.preprocess.tree_cache\""),
        "stats json: {stats}"
    );
    // backend stages (aggregated per stage path, not per layer index)
    assert!(stats.contains("\"forward.layer\""), "stats json: {stats}");
    assert!(
        stats.contains("\"forward.layer.ball_attention\""),
        "stats json: {stats}"
    );
    assert!(
        stats.contains("\"forward.layer.compression\""),
        "stats json: {stats}"
    );
    assert!(
        stats.contains("\"forward.layer.selection\""),
        "stats json: {stats}"
    );
    assert!(
        stats.contains("\"forward.layer.gated_merge\""),
        "stats json: {stats}"
    );
    assert!(stats.contains("\"forward.layer.swiglu\""), "stats json: {stats}");
    // pool gauges registered by the global pool
    assert!(stats.contains("\"gauges\""), "stats json: {stats}");
    // the frame must parse as JSON end-to-end
    let parsed = bsa::trace::parse_json(&stats).expect("stats frame is valid JSON");
    let spans = parsed.get("spans").expect("spans object present");
    assert!(
        spans.entries().map(|e| e.len()).unwrap_or(0) >= 8,
        "expected a rich span set, got: {stats}"
    );

    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    srv.join().unwrap().unwrap();
    bsa::trace::set_level(prior);
}

// ---------------------------------------------------------------------------
// poll core: pipelining, admission control, shedding, drain
// ---------------------------------------------------------------------------

/// Start a native-backend router + poll-core server on `addr` with the
/// given admission limits (`None` = defaults).
fn spawn_native_server(
    seed: u64,
    sc: ServeConfig,
    addr: &'static str,
    limits: Option<bsa::server::ServeLimits>,
) -> (
    Arc<Router>,
    Arc<std::sync::atomic::AtomicBool>,
    std::thread::JoinHandle<anyhow::Result<()>>,
) {
    let backend = Arc::new(tiny_native_backend(seed));
    let router = Arc::new(Router::start(backend, sc).unwrap());
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    let srv = {
        let router = router.clone();
        let stop = stop.clone();
        let limits = limits.unwrap_or_default();
        std::thread::spawn(move || bsa::server::serve_with(addr, router, stop, limits))
    };
    std::thread::sleep(std::time::Duration::from_millis(100));
    (router, stop, srv)
}

fn raw_request_header(n: u32, d: u32, f: u32) -> Vec<u8> {
    let mut b = Vec::with_capacity(16);
    b.extend_from_slice(b"BSRQ");
    b.extend_from_slice(&n.to_le_bytes());
    b.extend_from_slice(&d.to_le_bytes());
    b.extend_from_slice(&f.to_le_bytes());
    b
}

/// Read one BSRS frame that must be a status-1 error; return its message.
fn read_error_frame(s: &mut std::net::TcpStream) -> String {
    use std::io::Read;
    let mut head = [0u8; 12];
    s.read_exact(&mut head).unwrap();
    assert_eq!(&head[0..4], b"BSRS", "bad response magic");
    let status = u32::from_le_bytes(head[4..8].try_into().unwrap());
    assert_eq!(status, 1, "expected a status-1 error frame");
    let len = u32::from_le_bytes(head[8..12].try_into().unwrap()) as usize;
    assert!(len < 65536, "oversized error message ({len} B)");
    let mut msg = vec![0u8; len];
    s.read_exact(&mut msg).unwrap();
    String::from_utf8(msg).unwrap()
}

#[test]
fn native_tcp_pipelined_frames_roundtrip_in_order() {
    // True pipelining: many BSRQ frames written before any response is
    // read, each with a *different* point count. Responses must come
    // back strictly in request order — each reply's row count is the
    // fingerprint of its request.
    let sc = ServeConfig { workers: 2, flush_us: 200, ..Default::default() };
    let (router, stop, srv) = spawn_native_server(20, sc, "127.0.0.1:17187", None);

    let gen = generator_for("syn", 20).unwrap();
    let sizes: Vec<usize> = (0..6).map(|i| 140 + 10 * i).collect();
    let samples: Vec<_> = sizes.iter().map(|&p| gen.generate(p as u64, p)).collect();

    let mut client = bsa::server::Client::connect("127.0.0.1:17187").unwrap();
    for s in &samples {
        client.send(&s.coords, &s.features).unwrap();
    }
    for (i, &p) in sizes.iter().enumerate() {
        let pred = client.recv_predict().unwrap();
        assert_eq!(pred.shape(), &[p, 1], "response {i} out of order");
        assert!(pred.all_finite());
    }

    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    srv.join().unwrap().unwrap();
    let st = Arc::try_unwrap(router).ok().unwrap().shutdown();
    assert_eq!(st.served, sizes.len() as u64);
}

#[test]
fn native_tcp_queue_full_sheds_with_status3() {
    // Overload via a tiny router queue: a rapid pipelined burst must be
    // answered frame-for-frame — some status-0, the overflow status-3
    // (typed ShedError with a retry hint), never a dropped socket — and
    // every shed must land in the router's `rejected` stat.
    let sc = ServeConfig { workers: 1, queue_cap: 1, flush_us: 100, ..Default::default() };
    let (router, stop, srv) = spawn_native_server(21, sc, "127.0.0.1:17189", None);

    let gen = generator_for("syn", 21).unwrap();
    let sample = gen.generate(0, 200);
    let burst = 32usize;
    let mut client = bsa::server::Client::connect("127.0.0.1:17189").unwrap();
    for _ in 0..burst {
        client.send(&sample.coords, &sample.features).unwrap();
    }
    let (mut ok, mut shed) = (0usize, 0usize);
    for i in 0..burst {
        match client.recv_predict() {
            Ok(pred) => {
                assert_eq!(pred.shape(), &[200, 1], "frame {i}");
                ok += 1;
            }
            Err(e) => {
                let s = e
                    .downcast_ref::<bsa::server::ShedError>()
                    .unwrap_or_else(|| panic!("frame {i}: expected ShedError, got: {e}"));
                assert!(s.retry_after_ms > 0, "shed frame must carry a retry hint");
                shed += 1;
            }
        }
    }
    assert_eq!(ok + shed, burst, "every frame must be answered");
    assert!(shed >= 1, "queue_cap=1 under a 32-frame burst must shed");
    assert!(ok >= 1, "some requests must still be served under overload");
    // the connection survived shedding: it still serves
    let pred = client.predict(&sample.coords, &sample.features).unwrap();
    assert_eq!(pred.shape(), &[200, 1]);

    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    srv.join().unwrap().unwrap();
    let st = Arc::try_unwrap(router).ok().unwrap().shutdown();
    assert_eq!(st.rejected as usize, shed, "every shed counts as rejected");
    assert_eq!(st.served as usize, ok + 1);
}

#[test]
fn native_tcp_inflight_budget_sheds_and_keeps_connection() {
    // With a 1-byte inflight budget every request sheds deterministically:
    // the body is drained (not buffered), a status-3 frame with the
    // configured retry hint comes back, and the same connection keeps
    // working — both for more requests and for stats frames.
    let limits = bsa::server::ServeLimits {
        max_inflight_bytes: 1,
        retry_after_ms: 7,
        ..Default::default()
    };
    let sc = ServeConfig { workers: 1, flush_us: 100, ..Default::default() };
    let (router, stop, srv) = spawn_native_server(22, sc, "127.0.0.1:17191", Some(limits));

    let gen = generator_for("syn", 22).unwrap();
    let sample = gen.generate(0, 150);
    let mut client = bsa::server::Client::connect("127.0.0.1:17191").unwrap();
    for round in 0..3 {
        let e = client.predict(&sample.coords, &sample.features).unwrap_err();
        let s = e
            .downcast_ref::<bsa::server::ShedError>()
            .unwrap_or_else(|| panic!("round {round}: expected ShedError, got: {e}"));
        assert_eq!(s.retry_after_ms, 7, "configured retry hint must survive the wire");
    }
    // shed kept the stream framed: a stats query on the same connection
    let stats = client.stats().unwrap();
    assert!(stats.contains("\"rejected\": 3"), "stats json: {stats}");

    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    srv.join().unwrap().unwrap();
    let st = Arc::try_unwrap(router).ok().unwrap().shutdown();
    assert_eq!(st.rejected, 3);
    assert_eq!(st.served, 0, "nothing reached a worker");
}

#[test]
fn native_tcp_drain_completes_inflight_on_stop() {
    // Stop with responses still owed: the core must finish and flush
    // every in-flight request before closing (bounded by drain_ms), then
    // close the connection — the client sees all its answers, then EOF.
    let sc = ServeConfig { workers: 1, flush_us: 100, ..Default::default() };
    let (router, stop, srv) = spawn_native_server(23, sc, "127.0.0.1:17193", None);

    let gen = generator_for("syn", 23).unwrap();
    let sample = gen.generate(0, 180);
    let mut client = bsa::server::Client::connect("127.0.0.1:17193").unwrap();
    let inflight = 4usize;
    for _ in 0..inflight {
        client.send(&sample.coords, &sample.features).unwrap();
    }
    // one poll tick: enough for the core to take the frames in-flight
    std::thread::sleep(std::time::Duration::from_millis(30));
    stop.store(true, std::sync::atomic::Ordering::SeqCst);

    for i in 0..inflight {
        let pred = client.recv_predict().unwrap_or_else(|e| {
            panic!("drain dropped in-flight request {i}: {e}")
        });
        assert_eq!(pred.shape(), &[180, 1]);
    }
    // after the drain the server closes the connection: clean EOF
    assert!(client.recv_predict().is_err(), "connection must close after drain");
    srv.join().unwrap().unwrap();
    let st = Arc::try_unwrap(router).ok().unwrap().shutdown();
    assert_eq!(st.served as usize, inflight);
}

#[test]
fn native_tcp_poll_core_holds_many_idle_connections() {
    // The scaling contract: >= 256 concurrent idle connections on one
    // poll thread. Thread-per-connection would add ~256 threads here;
    // the poll core adds zero (the slack absorbs unrelated concurrent
    // test threads, orders of magnitude below 256). The server must
    // stay responsive while holding them all.
    let sc = ServeConfig { workers: 1, flush_us: 100, ..Default::default() };
    let (router, stop, srv) = spawn_native_server(24, sc, "127.0.0.1:17195", None);

    let gen = generator_for("syn", 24).unwrap();
    let sample = gen.generate(0, 160);
    {
        // warm the lazy worker-pool growth so the baseline is steady-state
        let mut c = bsa::server::Client::connect("127.0.0.1:17195").unwrap();
        c.predict(&sample.coords, &sample.features).unwrap();
    }
    std::thread::sleep(std::time::Duration::from_millis(200));
    let before = live_threads();

    let idle: Vec<std::net::TcpStream> = (0..256)
        .map(|i| {
            std::net::TcpStream::connect("127.0.0.1:17195")
                .unwrap_or_else(|e| panic!("idle connection {i} refused: {e}"))
        })
        .collect();
    // several poll ticks with all 256 held open
    std::thread::sleep(std::time::Duration::from_millis(400));
    let after = live_threads();
    assert!(
        after <= before + 16,
        "256 idle connections grew the thread population: {before} -> {after}"
    );

    // still serving while holding them all
    let mut c = bsa::server::Client::connect("127.0.0.1:17195").unwrap();
    let pred = c.predict(&sample.coords, &sample.features).unwrap();
    assert_eq!(pred.shape(), &[160, 1]);

    drop(idle);
    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    srv.join().unwrap().unwrap();
    let st = Arc::try_unwrap(router).ok().unwrap().shutdown();
    assert_eq!(st.served, 2);
}

#[test]
fn native_tcp_zero_width_dims_rejected_with_typed_error() {
    // Conformance for the d == 0 / f == 0 header holes: zero-width
    // coords/features used to flow into preprocessing and panic a
    // worker; now each draws a typed status-1 error frame naming the
    // offending field, before any body byte is read.
    let sc = ServeConfig { workers: 1, flush_us: 100, ..Default::default() };
    let (router, stop, srv) = spawn_native_server(25, sc, "127.0.0.1:17197", None);

    for (n, d, f, needle) in
        [(16u32, 0u32, 8u32, "coordinate dims"), (16, 3, 0, "feature dims")]
    {
        let mut s = std::net::TcpStream::connect("127.0.0.1:17197").unwrap();
        s.set_read_timeout(Some(std::time::Duration::from_secs(5))).unwrap();
        std::io::Write::write_all(&mut s, &raw_request_header(n, d, f)).unwrap();
        let msg = read_error_frame(&mut s);
        assert!(msg.contains(needle), "n={n} d={d} f={f}: unhelpful error: {msg}");
    }

    // the server survived both protocol errors
    let gen = generator_for("syn", 25).unwrap();
    let sample = gen.generate(0, 170);
    let mut c = bsa::server::Client::connect("127.0.0.1:17197").unwrap();
    assert_eq!(c.predict(&sample.coords, &sample.features).unwrap().shape(), &[170, 1]);

    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    srv.join().unwrap().unwrap();
    drop(router);
}

#[test]
fn native_tcp_header_bomb_answered_without_allocation() {
    // The allocation-bomb regression: a 16-byte header declaring a
    // ~1 GiB body (n=2^22, f=64) used to be preallocated before any
    // payload arrived. Now the bound is enforced at header time: the
    // error frame must come back immediately — no body was sent, so a
    // server that tries to read (or allocate) the declared payload
    // would hang past the read timeout instead.
    let sc = ServeConfig { workers: 1, flush_us: 100, ..Default::default() };
    let (router, stop, srv) = spawn_native_server(26, sc, "127.0.0.1:17199", None);

    let mut s = std::net::TcpStream::connect("127.0.0.1:17199").unwrap();
    s.set_read_timeout(Some(std::time::Duration::from_secs(5))).unwrap();
    std::io::Write::write_all(&mut s, &raw_request_header(1 << 22, 3, 64)).unwrap();
    let t0 = std::time::Instant::now();
    let msg = read_error_frame(&mut s);
    assert!(msg.contains("max_payload_bytes"), "error must name the bound: {msg}");
    assert!(
        t0.elapsed() < std::time::Duration::from_secs(2),
        "rejection must not wait for (or buffer) the declared body"
    );

    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    srv.join().unwrap().unwrap();
    drop(router);
}

#[test]
fn native_tcp_bad_magic_answered_with_error_frame() {
    // A client speaking the wrong protocol used to get a silent socket
    // drop (anyhow::bail! with no frame) and hang until TCP teardown.
    // Now it gets a status-1 error frame naming the magic, then close.
    let sc = ServeConfig { workers: 1, flush_us: 100, ..Default::default() };
    let (router, stop, srv) = spawn_native_server(27, sc, "127.0.0.1:17201", None);

    let mut s = std::net::TcpStream::connect("127.0.0.1:17201").unwrap();
    s.set_read_timeout(Some(std::time::Duration::from_secs(5))).unwrap();
    std::io::Write::write_all(&mut s, b"GET / HTTP/1.1\r\n").unwrap();
    let msg = read_error_frame(&mut s);
    assert!(msg.contains("magic"), "error must explain the framing problem: {msg}");
    // then a clean close, not a hang
    let mut rest = Vec::new();
    let n = std::io::Read::read_to_end(&mut s, &mut rest).unwrap();
    assert_eq!(n, 0, "connection must close after the error frame");

    stop.store(true, std::sync::atomic::Ordering::SeqCst);
    srv.join().unwrap().unwrap();
    drop(router);
}

#[test]
fn client_rejects_implausible_response_shape() {
    // Client-side hardening twin: a malicious/corrupt server reporting
    // rn=ro=u32::MAX must draw a typed error, not a ~64 EiB allocation
    // attempt. A fake server answers one request with the bogus header.
    let listener = std::net::TcpListener::bind("127.0.0.1:17203").unwrap();
    let fake = std::thread::spawn(move || {
        let (mut s, _) = listener.accept().unwrap();
        // consume the request header so the client's write can't block
        let mut hdr = [0u8; 16];
        std::io::Read::read_exact(&mut s, &mut hdr).unwrap();
        let mut resp = Vec::new();
        resp.extend_from_slice(b"BSRS");
        resp.extend_from_slice(&0u32.to_le_bytes());
        resp.extend_from_slice(&u32::MAX.to_le_bytes());
        resp.extend_from_slice(&u32::MAX.to_le_bytes());
        std::io::Write::write_all(&mut s, &resp).unwrap();
        // hold the socket open: a client that trusted the header would
        // now try to read ~64 EiB from us
        std::thread::sleep(std::time::Duration::from_millis(500));
    });

    let mut client = bsa::server::Client::connect("127.0.0.1:17203").unwrap();
    let coords = Tensor::zeros(vec![4, 3]);
    let feats = Tensor::zeros(vec![4, 6]);
    let e = client.predict(&coords, &feats).unwrap_err();
    assert!(
        e.to_string().contains("implausible response shape"),
        "expected the shape bound to fire, got: {e}"
    );
    fake.join().unwrap();
}

#[test]
fn router_stats_latency_count_is_consistent_with_served() {
    // Regression for a torn read in RouterStats: `served` and the
    // latency histogram used to live behind separate synchronisation
    // (an AtomicU64 and a Mutex), so a stats() call racing a completion
    // could observe served == k with only k-1 latency samples. Both now
    // commit under one lock; every snapshot must satisfy the invariant
    // latency_samples == served, no matter when it is taken.
    let backend = Arc::new(tiny_native_backend(9));
    let sc = ServeConfig { workers: 2, flush_us: 100, ..Default::default() };
    let router = Arc::new(Router::start(backend, sc).unwrap());

    let gen = generator_for("syn", 13).unwrap();
    let requests_per_thread = 6usize;
    let threads = 3usize;
    let done = Arc::new(std::sync::atomic::AtomicBool::new(false));

    std::thread::scope(|s| {
        for t in 0..threads {
            let router = router.clone();
            let sample = gen.generate(t as u64, 170 + 10 * t);
            s.spawn(move || {
                for _ in 0..requests_per_thread {
                    let pred = router
                        .infer(sample.coords.clone(), sample.features.clone())
                        .unwrap();
                    assert!(pred.all_finite());
                }
            });
        }
        // poll snapshots while completions land: the invariant must hold
        // on every one, not just the final quiescent read
        let poller = {
            let router = router.clone();
            let done = done.clone();
            s.spawn(move || {
                let mut snapshots = 0u32;
                while !done.load(std::sync::atomic::Ordering::Relaxed) {
                    let st = router.stats();
                    assert_eq!(
                        st.latency_samples, st.served,
                        "torn stats snapshot: served={} latency_samples={}",
                        st.served, st.latency_samples
                    );
                    snapshots += 1;
                    std::thread::yield_now();
                }
                snapshots
            })
        };
        // release the poller only once every request has completed, so
        // it samples snapshots throughout the contended window
        let target = (threads * requests_per_thread) as u64;
        while router.stats().served < target {
            std::thread::sleep(std::time::Duration::from_millis(5));
        }
        done.store(true, std::sync::atomic::Ordering::Relaxed);
        let polls = poller.join().expect("poller");
        assert!(polls > 0, "poller never sampled");
    });

    let st = Arc::try_unwrap(router).ok().unwrap().shutdown();
    assert_eq!(st.served, (threads * requests_per_thread) as u64);
    assert_eq!(st.latency_samples, st.served);
}

#[test]
fn native_backend_loads_param_file() {
    // Param-file round trip through the backend constructor: weights
    // saved to a .bsackpt file serve identically to the in-memory ones.
    let be = tiny_native_backend(3);
    let path = std::env::temp_dir().join("bsa_it_native_params.bsackpt");
    be.params().save(&path).unwrap();
    let loaded = NativeBackend::load(
        &path,
        AttnHyper::from_model(&tiny_native_config()),
        256,
        1,
    )
    .unwrap();
    let gen = generator_for("syn", 7).unwrap();
    let s = gen.generate(0, 256);
    let x = Tensor::new(vec![1, 256, 6], s.features.data().to_vec());
    assert_eq!(
        be.forward(&x).unwrap(),
        loaded.forward(&x).unwrap(),
        "param file round trip must preserve the function"
    );
    std::fs::remove_file(path).ok();
}

#[test]
fn native_backend_matches_pjrt_forward() {
    // Semantic parity gate: the compiled fwd graph and the native rust
    // forward pass, fed identical weights (from the init graph, matched
    // by manifest input names) and an identical fixture, must agree to
    // 1e-3 max-abs. Skips (like every pjrt test) when artifacts are
    // missing.
    let Some(engine) = engine() else { return };
    let init = engine.load(&format!("init_{TINY}")).unwrap();
    let fwd = engine.load(&format!("fwd_{TINY}")).unwrap();
    // One init execution feeds BOTH backends: the literals go to the
    // pjrt forward, their tensor conversions to the native one, so the
    // two can never see different weights.
    let param_lits = init.run(&[scalar_i32(0)]).unwrap();
    let params: Vec<Tensor> = param_lits
        .iter()
        .map(|l| literal_to_tensor(l).unwrap())
        .collect();
    let names: Vec<String> = fwd
        .info
        .inputs
        .iter()
        .take(fwd.info.nparams)
        .map(|s| s.name.clone())
        .collect();
    let native = NativeBackend::from_flat(
        params,
        &names,
        AttnHyper::from_graph(&fwd.info),
        fwd.info.n,
        fwd.info.batch,
    )
    .unwrap();

    let gen = generator_for("syn", 11).unwrap();
    let n = fwd.info.n;
    let x = Tensor::new(
        vec![fwd.info.batch, n, fwd.info.in_features],
        gen.generate(0, n).features.data().to_vec(),
    );
    let pjrt_out =
        literal_to_tensor(&fwd.run_with_tensors(&param_lits, &[&x]).unwrap()[0]).unwrap();
    let native_out = native.forward(&x).unwrap();
    assert_eq!(pjrt_out.shape(), native_out.shape());
    let max_abs = pjrt_out
        .data()
        .iter()
        .zip(native_out.data())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(
        max_abs < 1e-3,
        "pjrt and native forward disagree: max |diff| = {max_abs}"
    );
}

// ---------------------------------------------------------------------------
// Native training: the artifact-free train → checkpoint → resume → serve
// loop (bsa train --backend native; see docs/TRAINING.md)
// ---------------------------------------------------------------------------

/// Tiny native training fixture: one block, n=64 — a full step is a few
/// milliseconds, so the loop tests stay cheap on any host.
fn tiny_train_model() -> ModelConfig {
    ModelConfig {
        dim: 16,
        num_heads: 2,
        num_blocks: 1,
        ball_size: 32,
        cmp_block: 8,
        sel_block: 8,
        top_k: 2,
        group_size: 8,
        seq_len: 64,
        ..Default::default()
    }
}

fn tiny_native_train_config() -> TrainConfig {
    TrainConfig {
        task: "syn".into(),
        steps: 12,
        batch: 1,
        lr: 3e-3,
        warmup: 1,
        train_samples: 4,
        test_samples: 2,
        log_every: 1,
        ..Default::default()
    }
}

#[test]
fn native_trainer_reduces_loss() {
    let mut trainer =
        bsa::coordinator::NativeTrainer::new(&tiny_train_model(), tiny_native_train_config(), 2)
            .unwrap();
    let mut losses = Vec::new();
    for _ in 0..12 {
        losses.push(trainer.step_once().unwrap());
    }
    assert!(losses.iter().all(|l| l.is_finite()));
    let min_late = losses[4..].iter().cloned().fold(f32::INFINITY, f32::min);
    assert!(
        min_late < losses[0],
        "loss did not decrease: first {} vs best-after-warmup {min_late} ({losses:?})",
        losses[0]
    );
}

#[test]
fn native_trainer_v3_checkpoint_roundtrips_exactly() {
    // save → load → save must reproduce the file byte for byte: the v3
    // layout (model arrays + m.*/v.* moments + step) carries the whole
    // trainer state, and load_checkpoint restores all of it.
    let mc = tiny_train_model();
    let mut trainer =
        bsa::coordinator::NativeTrainer::new(&mc, tiny_native_train_config(), 1).unwrap();
    for _ in 0..3 {
        trainer.step_once().unwrap();
    }
    let p1 = std::env::temp_dir().join("bsa_it_native_v3_a.bsackpt");
    let p2 = std::env::temp_dir().join("bsa_it_native_v3_b.bsackpt");
    trainer.save_checkpoint(&p1).unwrap();

    // the file is format version 3
    let bytes = std::fs::read(&p1).unwrap();
    assert_eq!(&bytes[..4], b"BSAC");
    assert_eq!(u32::from_le_bytes(bytes[4..8].try_into().unwrap()), 3);

    let mut restored =
        bsa::coordinator::NativeTrainer::new(&mc, tiny_native_train_config(), 1).unwrap();
    restored.load_checkpoint(&p1).unwrap();
    assert_eq!(restored.step, trainer.step);
    restored.save_checkpoint(&p2).unwrap();
    assert_eq!(
        std::fs::read(&p1).unwrap(),
        std::fs::read(&p2).unwrap(),
        "v3 save → load → save must be byte-identical (params, moments, step)"
    );

    // the restored trainer evaluates identically (same params, same
    // deterministic dataset streams)
    let a = trainer.evaluate().unwrap();
    let b = restored.evaluate().unwrap();
    assert_eq!(a.to_bits(), b.to_bits(), "eval after resume: {a} vs {b}");
    std::fs::remove_file(p1).ok();
    std::fs::remove_file(p2).ok();
}

#[test]
fn native_trainer_checkpoint_serves_inference() {
    // train → checkpoint → serve with no Python/XLA anywhere: the v3
    // file (moments included) loads straight into the serving backend,
    // and the served forward matches the trainer's own eval forward.
    let mc = tiny_train_model();
    let mut trainer =
        bsa::coordinator::NativeTrainer::new(&mc, tiny_native_train_config(), 1).unwrap();
    for _ in 0..2 {
        trainer.step_once().unwrap();
    }
    let path = std::env::temp_dir().join("bsa_it_native_train_serve.bsackpt");
    trainer.save_checkpoint(&path).unwrap();
    let backend =
        NativeBackend::load(&path, AttnHyper::from_model(&mc), mc.seq_len, 1).unwrap();
    let gen = generator_for("syn", 7).unwrap();
    let s = gen.generate(0, mc.seq_len);
    let x = Tensor::new(vec![1, mc.seq_len, 6], s.features.data().to_vec());
    let served = backend.forward(&x).unwrap();
    let tape = bsa::backend::grad::tape::forward(
        trainer.params(),
        &AttnHyper::from_model(&mc),
        x.data(),
        1,
        mc.seq_len,
        1,
    );
    assert_eq!(served.data(), &tape.pred[..], "served forward != trained forward");
    std::fs::remove_file(path).ok();
}

#[test]
fn native_trainer_resumes_params_only_file_with_zeroed_moments() {
    // A params-only .bsackpt (what aot.py emits, and what v1/v2 files
    // up-convert to) resumes training: moments zeroed, step taken from
    // the file, loop still runs.
    let mc = tiny_train_model();
    let params = bsa::backend::NativeParams::init(9, 6, 1, mc.dim, mc.num_heads, mc.num_blocks, 4);
    let path = std::env::temp_dir().join("bsa_it_native_params_only.bsackpt");
    params.save(&path).unwrap();
    let mut trainer =
        bsa::coordinator::NativeTrainer::new(&mc, tiny_native_train_config(), 1).unwrap();
    trainer.load_checkpoint(&path).unwrap();
    assert_eq!(trainer.step, 0, "params-only file carries step 0");
    let loss = trainer.step_once().unwrap();
    assert!(loss.is_finite());
    std::fs::remove_file(path).ok();
}

#[test]
fn native_trainer_rejects_architecture_drift() {
    // A checkpoint from a different architecture must fail loudly, not
    // silently reshape.
    let mc = tiny_train_model();
    let other = bsa::backend::NativeParams::init(9, 6, 1, 32, 2, 1, 4); // dim 32 != 16
    let path = std::env::temp_dir().join("bsa_it_native_drift.bsackpt");
    other.save(&path).unwrap();
    let mut trainer =
        bsa::coordinator::NativeTrainer::new(&mc, tiny_native_train_config(), 1).unwrap();
    let err = trainer.load_checkpoint(&path).unwrap_err().to_string();
    assert!(err.contains("shape"), "error names the shape drift: {err}");
    std::fs::remove_file(path).ok();
}
