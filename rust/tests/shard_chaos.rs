//! Chaos tests for the shard tier: front-door routing under injected
//! faults (worker kills mid-pipeline, starved health probes, shed
//! storms) plus the restart-detection and thread-hygiene contracts.
//!
//! Every test runs real in-process workers — a native-backend
//! [`Router`] behind the BSRQ/BSRS poll core — attached to a
//! [`Fleet`] with a [`FaultPlan`], so the failure paths exercised here
//! are the production code paths, not mocks. The invariant under test
//! throughout: **no request is ever silently dropped** — every frame
//! written to the front door is answered with a prediction or a typed
//! status-3 shed, in order, regardless of what the fleet is doing.
//!
//! Ports: 17205–17226 (integration.rs owns 17177–17203, check.sh
//! smokes own 1789x).

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use bsa::balltree::content_hash;
use bsa::backend::NativeBackend;
use bsa::config::{ModelConfig, ServeConfig, ShardConfig};
use bsa::coordinator::Router;
use bsa::data::generator_for;
use bsa::server::{Client, ServeLimits, ShedError};
use bsa::shard::{affine_worker, worker::run_prober, Candidate, FaultPlan, Fleet, FrontDoor};
use bsa::trace::{parse_json, Json};

/// Native twin of the tiny core artifact (same dims as integration.rs).
fn tiny_native_config() -> ModelConfig {
    ModelConfig {
        dim: 32,
        num_heads: 2,
        num_blocks: 2,
        ball_size: 64,
        seq_len: 256,
        ..Default::default()
    }
}

fn tiny_native_backend(seed: u64) -> NativeBackend {
    NativeBackend::init(seed, &tiny_native_config(), 6, 1, 1).unwrap()
}

/// Start a native-backend router + poll-core server on `addr` — one
/// shard worker, exactly as `bsa serve` would run it.
fn spawn_worker(
    seed: u64,
    addr: &'static str,
    limits: Option<ServeLimits>,
) -> (Arc<Router>, Arc<AtomicBool>, JoinHandle<anyhow::Result<()>>) {
    let backend = Arc::new(tiny_native_backend(seed));
    let sc = ServeConfig { workers: 1, flush_us: 100, ..Default::default() };
    let router = Arc::new(Router::start(backend, sc).unwrap());
    let stop = Arc::new(AtomicBool::new(false));
    let srv = {
        let router = router.clone();
        let stop = stop.clone();
        let limits = limits.unwrap_or_default();
        std::thread::spawn(move || bsa::server::serve_with(addr, router, stop, limits))
    };
    std::thread::sleep(Duration::from_millis(100));
    (router, stop, srv)
}

/// Shard config tuned for tests: fast probes when `probe_interval_ms`
/// is small, effectively-disabled probing when it is huge (so an
/// injected mark-down stays sticky for attached workers).
fn shard_cfg(addr: &str, workers: usize, probe_interval_ms: u64) -> ShardConfig {
    ShardConfig {
        addr: addr.into(),
        workers,
        probe_interval_ms,
        probe_timeout_ms: 200,
        probe_misses: 2,
        backoff_ms: 50,
        max_backoff_ms: 200,
        respawn_max: 5,
        spill_inflight: 64,
        retry_after_ms: 25,
        drain_ms: 500,
        ..Default::default()
    }
}

fn two_live_candidates() -> Vec<Candidate> {
    (0..2).map(|id| Candidate { id, live: true, inflight: 0 }).collect()
}

fn wait_until(what: &str, timeout: Duration, mut cond: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !cond() {
        assert!(t0.elapsed() < timeout, "timed out waiting for: {what}");
        std::thread::sleep(Duration::from_millis(10));
    }
}

/// Live thread count of this process (linux: /proc/self/status
/// `Threads:`; elsewhere 0, which makes churn assertions vacuous
/// rather than flaky).
fn live_threads() -> usize {
    if let Ok(status) = std::fs::read_to_string("/proc/self/status") {
        for line in status.lines() {
            if let Some(rest) = line.strip_prefix("Threads:") {
                if let Ok(n) = rest.trim().parse() {
                    return n;
                }
            }
        }
    }
    0
}

#[test]
fn shard_two_worker_affinity_and_mid_run_kill() {
    // The PR's acceptance gate. Phase 1: 100 requests over 8 repeating
    // geometries through a 2-worker fleet — rendezvous affinity must
    // pin each geometry to one worker, so the fleet pays exactly one
    // cold ball-tree build per geometry (>= 90% aggregate cache hits).
    // Phase 2: a worker is killed mid-run; every remaining request must
    // still complete or draw a typed shed. Zero silent drops.
    let (ra, stop_a, srv_a) = spawn_worker(31, "127.0.0.1:17205", None);
    let (rb, stop_b, srv_b) = spawn_worker(32, "127.0.0.1:17206", None);
    let addrs = vec!["127.0.0.1:17205".to_string(), "127.0.0.1:17206".to_string()];
    let faults = Arc::new(FaultPlan::default());
    // probe interval >> test length: injected mark-downs stay sticky
    let fleet = Fleet::attach(shard_cfg("127.0.0.1:17207", 2, 60_000), &addrs, faults.clone());
    let fd = FrontDoor::start(fleet.clone()).unwrap();

    let gen = generator_for("syn", 40).unwrap();
    let n = 160usize;
    let samples: Vec<_> = (0..8u64).map(|g| gen.generate(g, n)).collect();

    // Expected placement is deterministic: compute it from the same
    // rendezvous primitive the front door uses, so the per-worker
    // cold-miss counts can be asserted exactly, not just bounded.
    let cands = two_live_candidates();
    let mut expected_misses = [0u64; 2];
    for s in &samples {
        let w = affine_worker(content_hash(&s.coords), &cands).unwrap();
        expected_misses[w] += 1;
    }

    let mut client = Client::connect("127.0.0.1:17207").unwrap();
    for i in 0..100usize {
        let s = &samples[i % 8];
        let pred = client
            .predict(&s.coords, &s.features)
            .unwrap_or_else(|e| panic!("phase-1 request {i} failed: {e}"));
        assert_eq!(pred.shape(), &[n, 1]);
        assert!(pred.all_finite());
    }

    let (sa, sb) = (ra.stats(), rb.stats());
    let hits = sa.tree_hits + sb.tree_hits;
    let misses = sa.tree_misses + sb.tree_misses;
    assert_eq!(hits + misses, 100, "every request consulted a tree cache");
    assert_eq!(misses, 8, "exactly one cold build per geometry — affinity held");
    assert!(hits >= 90, "acceptance: >= 90% tree-cache hits on repeat traffic ({hits}/100)");
    assert_eq!(
        (sa.tree_misses, sb.tree_misses),
        (expected_misses[0], expected_misses[1]),
        "placement matched the rendezvous prediction"
    );

    // Phase 2: kill the worker that owns geometry 0 after 20 more
    // forwards, mid-run. (Attached worker: the kill marks it down and
    // severs its pooled connections; its keys re-place on the survivor.)
    let victim = affine_worker(content_hash(&samples[0].coords), &cands).unwrap();
    faults.kill_worker_after(victim, fleet.forwarded() + 20);

    let (mut ok, mut shed) = (0usize, 0usize);
    for i in 0..40usize {
        let s = &samples[i % 8];
        match client.predict(&s.coords, &s.features) {
            Ok(pred) => {
                assert_eq!(pred.shape(), &[n, 1]);
                ok += 1;
            }
            Err(e) => {
                let se = e
                    .downcast_ref::<ShedError>()
                    .unwrap_or_else(|| panic!("request {i}: untyped failure: {e}"));
                assert!(se.retry_after_ms > 0);
                shed += 1;
            }
        }
    }
    assert_eq!(ok + shed, 40, "zero silent drops across the kill");
    assert!(ok >= 20, "requests re-placed on the survivor must complete (ok={ok})");
    assert!(!fleet.slots()[victim].is_up(), "kill engaged and stayed sticky");

    drop(client);
    fd.shutdown();
    for (stop, srv) in [(stop_a, srv_a), (stop_b, srv_b)] {
        stop.store(true, Ordering::SeqCst);
        srv.join().unwrap().unwrap();
    }
}

#[test]
fn shard_pipelined_replies_survive_worker_death_in_order() {
    // Four BSRQ frames written back-to-back before any reply is read,
    // each with a distinct point count (the reply's row count is the
    // request's fingerprint). Frame 3 is constructed to be affine to a
    // worker whose real server is already dead — the fleet doesn't know
    // yet (probing disabled), so the forward hits a refused connect,
    // marks the worker down, and retries on the survivor. All four
    // replies must come back strictly in request order.
    let (_ra, stop_a, srv_a) = spawn_worker(33, "127.0.0.1:17209", None);
    let (_rb, stop_b, srv_b) = spawn_worker(34, "127.0.0.1:17210", None);
    let addrs = vec!["127.0.0.1:17209".to_string(), "127.0.0.1:17210".to_string()];
    let faults = Arc::new(FaultPlan::default());
    let fleet = Fleet::attach(shard_cfg("127.0.0.1:17211", 2, 60_000), &addrs, faults);
    let fd = FrontDoor::start(fleet.clone()).unwrap();

    let gen = generator_for("syn", 41).unwrap();
    let cands = two_live_candidates();
    let sizes = [128usize, 144, 160, 176];
    let wants = [0usize, 0, 1, 0]; // frame 3 targets the doomed worker
    let samples: Vec<_> = sizes
        .iter()
        .zip(wants)
        .map(|(&nn, want)| {
            (0..64u64)
                .map(|g| gen.generate(1000 + g, nn))
                .find(|s| affine_worker(content_hash(&s.coords), &cands) == Some(want))
                .expect("a geometry affine to the wanted worker exists within 64 draws")
        })
        .collect();

    // Worker 1 dies for real; the fleet still believes it is up.
    stop_b.store(true, Ordering::SeqCst);
    srv_b.join().unwrap().unwrap();
    assert!(fleet.slots()[1].is_up(), "fleet is unaware of the death");

    let mut client = Client::connect("127.0.0.1:17211").unwrap();
    for s in &samples {
        client.send(&s.coords, &s.features).unwrap();
    }
    for (i, &nn) in sizes.iter().enumerate() {
        let pred = client
            .recv_predict()
            .unwrap_or_else(|e| panic!("reply {i} lost across worker death: {e}"));
        assert_eq!(pred.shape(), &[nn, 1], "reply {i} out of order");
        assert!(pred.all_finite());
    }
    assert!(
        !fleet.slots()[1].is_up(),
        "the failed forward marked the dead worker down"
    );

    drop(client);
    fd.shutdown();
    stop_a.store(true, Ordering::SeqCst);
    srv_a.join().unwrap().unwrap();
}

#[test]
fn shard_probe_delay_defers_down_detection() {
    // FaultPlan::delay_probes_ms stalls the prober past the miss
    // deadline: a worker death during the stall goes undetected (the
    // slot stays optimistically up), and detection resumes promptly
    // once the stall is lifted. This pins the failure mode the probe
    // cadence exists to bound — and that the chaos hook really starves
    // it.
    let (_r, stop_w, srv) = spawn_worker(35, "127.0.0.1:17213", None);
    let addrs = vec!["127.0.0.1:17213".to_string()];
    let faults = Arc::new(FaultPlan::default());
    let mut cfg = shard_cfg("127.0.0.1:17214", 1, 40);
    cfg.probe_timeout_ms = 150;
    let fleet = Fleet::attach(cfg, &addrs, faults.clone());
    let stop = Arc::new(AtomicBool::new(false));
    let prober = run_prober(fleet.clone(), stop.clone());

    wait_until("first successful probe", Duration::from_secs(2), || {
        fleet.slots()[0].epoch() > 0
    });
    assert!(fleet.slots()[0].is_up());

    // Stall probes, then kill the real server. probe_misses=2 at a
    // 40ms cadence would detect this within ~100ms — the stall must
    // starve that deadline (at most one in-flight probe can miss).
    faults.delay_probes_ms(60_000);
    std::thread::sleep(Duration::from_millis(120));
    stop_w.store(true, Ordering::SeqCst);
    srv.join().unwrap().unwrap();
    std::thread::sleep(Duration::from_millis(400));
    assert!(
        fleet.slots()[0].is_up(),
        "probes starved past the deadline: the death must be undetected"
    );

    // Lift the stall: two consecutive misses mark it down quickly.
    faults.delay_probes_ms(0);
    wait_until("down detection after stall lifted", Duration::from_secs(3), || {
        !fleet.slots()[0].is_up()
    });

    stop.store(true, Ordering::SeqCst);
    prober.join().unwrap();
}

#[test]
fn shard_worker_shed_hint_propagates_end_to_end() {
    // A worker drowning in admitted bytes sheds with its own
    // retry-after hint; the front door must relay that status-3 frame
    // verbatim — hint included — and keep the client connection open.
    let limits =
        ServeLimits { max_inflight_bytes: 1, retry_after_ms: 777, ..Default::default() };
    let (_r, stop_w, srv) = spawn_worker(36, "127.0.0.1:17216", Some(limits));
    let addrs = vec!["127.0.0.1:17216".to_string()];
    let faults = Arc::new(FaultPlan::default());
    let fleet = Fleet::attach(shard_cfg("127.0.0.1:17217", 1, 60_000), &addrs, faults);
    let fd = FrontDoor::start(fleet).unwrap();

    let gen = generator_for("syn", 42).unwrap();
    let s = gen.generate(0, 160);
    let mut client = Client::connect("127.0.0.1:17217").unwrap();
    for round in 0..2 {
        let err = client.predict(&s.coords, &s.features).unwrap_err();
        let se = err
            .downcast_ref::<ShedError>()
            .unwrap_or_else(|| panic!("round {round}: untyped failure: {err}"));
        assert_eq!(
            se.retry_after_ms, 777,
            "worker's retry hint must be relayed verbatim, not rewritten"
        );
        // round 2 reuses the same connection: a relayed shed keeps it open
    }

    drop(client);
    fd.shutdown();
    stop_w.store(true, Ordering::SeqCst);
    srv.join().unwrap().unwrap();
}

#[test]
fn shard_frontdoor_shed_storm_keeps_connection_usable() {
    // FaultPlan::shed_storm makes the front door shed the next N
    // requests at admission (before any forward). Each shed carries the
    // *front door's* retry hint, the connection survives all of them,
    // and the first post-storm request is served normally.
    let (_r, stop_w, srv) = spawn_worker(37, "127.0.0.1:17219", None);
    let addrs = vec!["127.0.0.1:17219".to_string()];
    let faults = Arc::new(FaultPlan::default());
    let fleet = Fleet::attach(shard_cfg("127.0.0.1:17220", 1, 60_000), &addrs, faults.clone());
    let fd = FrontDoor::start(fleet).unwrap();

    let gen = generator_for("syn", 43).unwrap();
    let s = gen.generate(0, 160);
    let mut client = Client::connect("127.0.0.1:17220").unwrap();
    let pred = client.predict(&s.coords, &s.features).unwrap();
    assert_eq!(pred.shape(), &[160, 1]);

    faults.shed_storm(3);
    for i in 0..3 {
        let err = client.predict(&s.coords, &s.features).unwrap_err();
        let se = err
            .downcast_ref::<ShedError>()
            .unwrap_or_else(|| panic!("storm shed {i}: untyped failure: {err}"));
        assert_eq!(se.retry_after_ms, 25, "front-door-originated hint (cfg.retry_after_ms)");
    }
    // storm exhausted: same connection, request served
    let pred = client.predict(&s.coords, &s.features).unwrap();
    assert_eq!(pred.shape(), &[160, 1]);

    drop(client);
    fd.shutdown();
    stop_w.store(true, Ordering::SeqCst);
    srv.join().unwrap().unwrap();
}

#[test]
fn shard_connection_churn_with_kills_keeps_threads_flat() {
    // 200 short-lived client connections through the front door while a
    // FaultPlan kills the (sole) worker every 20 cycles and a fast
    // prober revives it. Discipline of
    // `native_tcp_connection_churn_reaps_handlers`: every request is
    // answered (prediction or typed shed — never a dropped socket), and
    // the process thread count ends flat, proving handler threads are
    // reaped and kill/revive churn leaks nothing.
    let (_r, stop_w, srv) = spawn_worker(38, "127.0.0.1:17221", None);
    let addrs = vec!["127.0.0.1:17221".to_string()];
    let faults = Arc::new(FaultPlan::default());
    let fleet = Fleet::attach(shard_cfg("127.0.0.1:17222", 1, 25), &addrs, faults.clone());
    let fd = FrontDoor::start(fleet.clone()).unwrap();

    let gen = generator_for("syn", 44).unwrap();
    let s = gen.generate(0, 160);

    // warm all lazily-created machinery before measuring
    {
        let mut c = Client::connect("127.0.0.1:17222").unwrap();
        c.predict(&s.coords, &s.features).unwrap();
    }
    std::thread::sleep(Duration::from_millis(300));
    let before = live_threads();

    let (mut ok, mut shed) = (0usize, 0usize);
    for cycle in 0..200usize {
        if cycle % 20 == 10 {
            faults.kill_worker_after(0, fleet.forwarded() + 1);
        }
        let mut c = Client::connect("127.0.0.1:17222").unwrap();
        match c.predict(&s.coords, &s.features) {
            Ok(pred) => {
                assert_eq!(pred.shape(), &[160, 1]);
                ok += 1;
            }
            Err(e) => {
                e.downcast_ref::<ShedError>()
                    .unwrap_or_else(|| panic!("cycle {cycle}: untyped failure: {e}"));
                shed += 1;
            }
        }
    }
    assert_eq!(ok + shed, 200, "every churn request answered across kill/revive churn");
    assert!(ok > 0, "the prober revived the worker between kills");

    std::thread::sleep(Duration::from_millis(500));
    let after = live_threads();
    assert!(
        after <= before + 3,
        "thread population must stay flat over churn: {before} -> {after}"
    );

    fd.shutdown();
    stop_w.store(true, Ordering::SeqCst);
    srv.join().unwrap().unwrap();
}

#[test]
fn shard_probe_detects_worker_restart_via_epoch() {
    // Satellite 4's contract end-to-end: a worker that dies and comes
    // back on the same address is *not* the same worker — its BSST
    // epoch changed — and the fleet must count the restart and sever
    // any pooled state. The front door's own BSST frame surfaces the
    // per-worker epoch and restart count for operators.
    let (_r1, stop1, srv1) = spawn_worker(39, "127.0.0.1:17224", None);
    let addrs = vec!["127.0.0.1:17224".to_string()];
    let faults = Arc::new(FaultPlan::default());
    let fleet = Fleet::attach(shard_cfg("127.0.0.1:17225", 1, 30), &addrs, faults);
    let fd = FrontDoor::start(fleet.clone()).unwrap();

    wait_until("first successful probe", Duration::from_secs(2), || {
        fleet.slots()[0].epoch() > 0
    });
    let first_epoch = fleet.slots()[0].epoch();
    assert_eq!(fleet.slots()[0].restarts(), 0);

    // Clean restart on the same port: stop, join, rebind.
    stop1.store(true, Ordering::SeqCst);
    srv1.join().unwrap().unwrap();
    let (_r2, stop2, srv2) = spawn_worker(40, "127.0.0.1:17224", None);

    wait_until("restart detected via epoch change", Duration::from_secs(5), || {
        fleet.slots()[0].restarts() >= 1 && fleet.slots()[0].is_up()
    });
    assert_ne!(
        fleet.slots()[0].epoch(),
        first_epoch,
        "the replacement worker's epoch is visible through the probe"
    );

    // The operator-facing view: front-door BSST reports the restart.
    let mut c = Client::connect("127.0.0.1:17225").unwrap();
    let stats = c.stats().unwrap();
    let doc = parse_json(&stats).unwrap();
    assert_eq!(doc.get("role").and_then(|j| j.as_str()), Some("frontdoor"));
    let workers = match doc.get("workers") {
        Some(Json::Arr(v)) => v,
        other => panic!("missing workers array in front-door stats: {other:?}"),
    };
    assert_eq!(workers.len(), 1);
    assert_eq!(workers[0].get("restarts").and_then(|j| j.as_f64()), Some(1.0));
    assert!(matches!(workers[0].get("up"), Some(Json::Bool(true))));

    drop(c);
    fd.shutdown();
    stop2.store(true, Ordering::SeqCst);
    srv2.join().unwrap().unwrap();
}
