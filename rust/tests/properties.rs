//! Property-based tests on the coordinator substrates: ball-tree routing
//! invariants, batching/state round-trips, config parsing, metrics math.
//! (proptest is not vendored offline; bsa::proptest_lite is the in-tree
//! equivalent — deterministic cases, replayable by seed.)

use bsa::backend::{kernels, linalg, Backend, NativeBackend};
use bsa::balltree::BallTree;
use bsa::config::{Document, ModelConfig};
use bsa::data::{generator_for, NormStats, Sample};
use bsa::metrics::{Accumulator, ErrorStats};
use bsa::prng::Rng;
use bsa::proptest_lite::forall;
use bsa::tensor::Tensor;

fn cloud(g: &mut bsa::proptest_lite::Gen, n: usize, d: usize) -> Tensor {
    Tensor::new(vec![n, d], g.normals(n * d))
}

// ---------------------------------------------------------------------------
// ball tree invariants (the routing substrate every request goes through)
// ---------------------------------------------------------------------------

#[test]
fn prop_balltree_perm_covers_every_point_exactly_once() {
    forall(40, |g| {
        let target = g.pow2_in(32, 512);
        let n = g.usize_in(target / 2 + 1..target + 1);
        let d = g.usize_in(2..4);
        let pts = cloud(g, n, d);
        let tree = BallTree::build(&pts, target, g.case);
        let mut count = vec![0usize; n];
        for (&p, &r) in tree.perm.iter().zip(&tree.real) {
            assert!(p < n);
            if r {
                count[p] += 1;
            }
        }
        assert!(count.iter().all(|&c| c == 1), "each real point exactly once");
        assert_eq!(tree.perm.len(), target);
    });
}

#[test]
fn prop_balltree_permute_unpermute_roundtrip() {
    forall(30, |g| {
        let target = g.pow2_in(64, 256);
        let n = g.usize_in(target * 3 / 4..target + 1);
        let f = g.usize_in(1..8);
        let pts = cloud(g, n, 3);
        let feats = cloud(g, n, f);
        let tree = BallTree::build(&pts, target, g.case ^ 0x9);
        let back = tree.unpermute_predictions(&tree.permute_features(&feats));
        assert_eq!(back, feats);
    });
}

#[test]
fn prop_balltree_balls_tighter_than_global() {
    // Every ball's radius is at most the whole cloud's radius; the mean
    // ball radius shrinks monotonically with finer granularity.
    forall(20, |g| {
        let n = 512;
        let pts = cloud(g, n, 3);
        let tree = BallTree::build(&pts, n, g.case);
        let r_whole = tree.mean_radius(n);
        let r_64 = tree.mean_radius(64);
        let r_16 = tree.mean_radius(16);
        assert!(r_64 <= r_whole + 1e-5);
        assert!(r_16 <= r_64 + 1e-5, "finer balls are tighter: {r_16} vs {r_64}");
    });
}

#[test]
fn prop_balltree_deterministic() {
    forall(10, |g| {
        let pts = cloud(g, 200, 3);
        let a = BallTree::build(&pts, 256, 42);
        let b = BallTree::build(&pts, 256, 42);
        assert_eq!(a.perm, b.perm);
        assert_eq!(a.real, b.real);
    });
}

// ---------------------------------------------------------------------------
// tensor gather invariants (the serving batch assembler's zero-copy path)
// ---------------------------------------------------------------------------

#[test]
fn prop_permute_rows_into_roundtrips_and_matches_allocating() {
    forall(40, |g| {
        let rows = g.usize_in(1..40);
        let cols = g.usize_in(1..8);
        let t = cloud(g, rows, cols);
        let mut perm: Vec<usize> = (0..rows).collect();
        let mut rng = Rng::new(g.case ^ 0x5a5a);
        rng.shuffle(&mut perm);

        // `_into` agrees with the allocating permute_rows
        let mut out = vec![f32::NAN; rows * cols];
        t.permute_rows_into(&perm, &mut out);
        assert_eq!(out.as_slice(), t.permute_rows(&perm).data());

        // inverse permutation restores the original exactly
        let mut inv = vec![0usize; rows];
        for (i, &p) in perm.iter().enumerate() {
            inv[p] = i;
        }
        let permuted = Tensor::new(vec![rows, cols], out);
        let mut back = vec![f32::NAN; rows * cols];
        permuted.permute_rows_into(&inv, &mut back);
        assert_eq!(back.as_slice(), t.data());

        // gather semantics: arbitrary index lists (repeats, subsets) —
        // exactly what ball-tree padding produces — copy the right rows
        let glen = g.usize_in(1..2 * rows + 1);
        let gather: Vec<usize> = (0..glen).map(|_| rng.below(rows)).collect();
        let mut gout = vec![f32::NAN; glen * cols];
        t.permute_rows_into(&gather, &mut gout);
        for (i, &p) in gather.iter().enumerate() {
            assert_eq!(&gout[i * cols..(i + 1) * cols], t.row(p));
        }
    });
}

#[test]
fn prop_balltree_cache_transparent_for_preprocessing() {
    // A cache hit must be indistinguishable from a fresh build: same
    // permutation, and bit-identical permuted features via the `_into`
    // gather used by the serving batch assembler.
    use bsa::balltree::{content_hash, BallTreeCache};
    let cache = BallTreeCache::new(8);
    forall(15, |g| {
        let target = g.pow2_in(64, 256);
        let n = g.usize_in(target / 2 + 1..target + 1);
        let f = g.usize_in(1..6);
        let pts = cloud(g, n, 3);
        let feats = cloud(g, n, f);
        let first = cache.get_or_build(&pts, target);
        let second = cache.get_or_build(&pts, target);
        let fresh = BallTree::build(&pts, target, content_hash(&pts));
        assert_eq!(first.perm, fresh.perm);
        assert_eq!(second.perm, fresh.perm);
        let mut a = vec![0.0f32; target * f];
        let mut b = vec![0.0f32; target * f];
        second.permute_features_into(&feats, &mut a);
        fresh.permute_features_into(&feats, &mut b);
        assert_eq!(a, b);
    });
    assert!(cache.hits() >= 15, "every second lookup must hit");
}

// ---------------------------------------------------------------------------
// native backend kernels (the pure-Rust BSA forward pass)
// ---------------------------------------------------------------------------

#[test]
fn prop_softmax_rows_sum_to_one_under_large_logits() {
    // Numerical stability of the native softmax: rows must sum to 1 and
    // stay finite even when logits span huge magnitudes (the own-ball
    // mask injects -1e30 into score rows on every request).
    forall(40, |g| {
        let rows = g.usize_in(1..12);
        let cols = g.usize_in(1..24);
        let mag = g.f32_in(1.0..3e4);
        let mut x: Vec<f32> = g.normals(rows * cols).iter().map(|v| v * mag).collect();
        if g.bool() {
            // mix mask values in like the selection branch does
            let i = g.usize_in(0..x.len());
            x[i] = kernels::NEG_INF;
        }
        linalg::softmax_rows(&mut x, rows, cols, g.usize_in(1..5));
        for row in x.chunks_exact(cols) {
            assert!(row.iter().all(|v| v.is_finite() && *v >= 0.0));
            let s: f32 = row.iter().sum();
            assert!((s - 1.0).abs() < 1e-5, "row sums to {s}");
        }
    });
}

#[test]
fn prop_ball_attention_invariant_to_within_ball_relabeling() {
    // Ball attention treats tokens inside a ball as a set: permuting the
    // q/k/v rows *within* each ball must permute the outputs identically
    // (tolerance-level: summation order inside the softmax changes).
    // Runs the parallel production kernel at a random thread count —
    // the invariant must hold regardless of chunking.
    forall(25, |g| {
        let d = g.usize_in(2..6);
        let ball = g.pow2_in(4, 16);
        let n = ball * g.usize_in(1..5);
        let threads = g.usize_in(1..5);
        let q = g.normals(n * d);
        let k = g.normals(n * d);
        let v = g.normals(n * d);

        // per-ball permutation of token indices
        let mut rng = Rng::new(g.case ^ 0xba11);
        let mut perm: Vec<usize> = (0..n).collect();
        for b in 0..n / ball {
            rng.shuffle(&mut perm[b * ball..(b + 1) * ball]);
        }
        let permute = |x: &[f32]| -> Vec<f32> {
            let mut out = vec![0.0f32; n * d];
            for (i, &p) in perm.iter().enumerate() {
                out[i * d..(i + 1) * d].copy_from_slice(&x[p * d..(p + 1) * d]);
            }
            out
        };

        let mut out = vec![0.0f32; n * d];
        kernels::ball_attention(&q, &k, &v, n, d, ball, threads, &mut out);
        let mut out_p = vec![0.0f32; n * d];
        kernels::ball_attention(
            &permute(&q),
            &permute(&k),
            &permute(&v),
            n,
            d,
            ball,
            threads,
            &mut out_p,
        );
        let expected = permute(&out);
        for (a, b) in out_p.iter().zip(&expected) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    });
}

#[test]
fn prop_native_forward_deterministic_for_fixed_seed() {
    // Two backends built from the same seed are the same function, and
    // repeated evaluation of one backend is bit-stable — the property
    // that makes the native path usable as a parity oracle.
    forall(6, |g| {
        let mc = ModelConfig {
            dim: 16,
            num_heads: 2,
            num_blocks: 1,
            ball_size: 32,
            cmp_block: 8,
            sel_block: 8,
            top_k: 2,
            group_size: 8,
            seq_len: 64,
            ..Default::default()
        };
        let seed = g.case ^ 0xf00d;
        let a = NativeBackend::init(seed, &mc, 3, 1, 1).unwrap();
        let b = NativeBackend::init(seed, &mc, 3, 1, 1).unwrap();
        let x = Tensor::new(vec![1, 64, 3], g.normals(64 * 3));
        let ya = a.forward(&x).unwrap();
        assert_eq!(ya, a.forward(&x).unwrap(), "repeat eval must be bit-stable");
        assert_eq!(ya, b.forward(&x).unwrap(), "same seed, same function");
        assert!(ya.all_finite());
    });
}

// ---------------------------------------------------------------------------
// dataset / normalization invariants (training-state correctness)
// ---------------------------------------------------------------------------

#[test]
fn prop_norm_roundtrip_exact() {
    forall(50, |g| {
        let mean = g.f32_in(-5.0..5.0);
        let std = g.f32_in(0.1..4.0);
        let stats = NormStats { mean, std };
        let t = Tensor::new(vec![32], g.normals(32));
        let rt = stats.denormalize(&stats.normalize(&t));
        for (a, b) in rt.data().iter().zip(t.data()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    });
}

#[test]
fn prop_generators_emit_requested_shapes() {
    forall(12, |g| {
        let task = *g.choose(&["air", "ela", "syn"]);
        let n = g.usize_in(64..300);
        let gen = generator_for(task, g.case).unwrap();
        let s: Sample = gen.generate(g.case, n);
        assert_eq!(s.coords.rows(), n);
        assert_eq!(s.coords.cols(), gen.coord_dim());
        assert_eq!(s.features.shape(), &[n, gen.feature_dim()]);
        assert_eq!(s.target.shape(), &[n, 1]);
        assert!(s.target.all_finite());
        assert!(s.features.all_finite());
    });
}

// ---------------------------------------------------------------------------
// metrics invariants
// ---------------------------------------------------------------------------

#[test]
fn prop_accumulator_matches_direct_computation() {
    forall(40, |g| {
        let xs = g.vec_f32(1..100, -100.0..100.0);
        let mut acc = Accumulator::new();
        for &x in &xs {
            acc.push(x as f64);
        }
        let mean: f64 = xs.iter().map(|&x| x as f64).sum::<f64>() / xs.len() as f64;
        assert!((acc.mean() - mean).abs() < 1e-6 * (1.0 + mean.abs()));
        let mn = xs.iter().cloned().fold(f32::INFINITY, f32::min) as f64;
        assert!((acc.min() - mn).abs() < 1e-6);
    });
}

#[test]
fn prop_mse_nonnegative_and_zero_iff_equal() {
    forall(40, |g| {
        let xs = g.vec_f32(1..50, -10.0..10.0);
        let mut e = ErrorStats::default();
        e.push_slices(&xs, &xs);
        assert_eq!(e.mse(), 0.0);
        let mut e2 = ErrorStats::default();
        let shifted: Vec<f32> = xs.iter().map(|x| x + 1.0).collect();
        e2.push_slices(&xs, &shifted);
        assert!((e2.mse() - 1.0).abs() < 1e-5);
    });
}

// ---------------------------------------------------------------------------
// config parser robustness (fuzz-ish: parse never panics, errors are typed)
// ---------------------------------------------------------------------------

#[test]
fn prop_config_parser_total() {
    let tokens = [
        "[", "]", "=", "\"", "#", "x", "1", "1.5", "true", "[model]", "k = 1",
        "a = \"s\"", "\n", " ", "arr = [1,2]",
    ];
    forall(200, |g| {
        let mut text = String::new();
        for _ in 0..g.usize_in(0..12) {
            text.push_str(*g.choose(&tokens[..]));
            if g.bool() {
                text.push('\n');
            }
        }
        // must never panic — Result either way is fine
        let _ = Document::parse(&text);
    });
}

#[test]
fn prop_config_roundtrip_ints_floats() {
    forall(60, |g| {
        let i = g.usize_in(0..1_000_000) as i64;
        let f = g.f32_in(-1e3..1e3);
        let text = format!("[s]\ni = {i}\nf = {f}\nb = true\n");
        let doc = Document::parse(&text).unwrap();
        assert_eq!(doc.int_or("s", "i", -1), i);
        let back = doc.float_or("s", "f", f64::NAN) as f32;
        assert!((back - f).abs() <= 1e-3 * f.abs().max(1.0), "{f} vs {back}");
        assert!(doc.bool_or("s", "b", false));
    });
}

// ---------------------------------------------------------------------------
// prng statistical sanity under arbitrary streams
// ---------------------------------------------------------------------------

#[test]
fn prop_prng_streams_do_not_collide() {
    forall(30, |g| {
        let base = Rng::new(g.case);
        let mut a = base.fold(1);
        let mut b = base.fold(2);
        let va: Vec<u64> = (0..4).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..4).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    });
}
