//! `BSA_NATIVE_SIMD=off` gate: with SIMD disabled, every fast kernel —
//! and the whole forward pass — must be **bitwise** equal to the scalar
//! `*_reference` composition, at every thread count.
//!
//! This file deliberately contains exactly ONE `#[test]` function: the
//! SIMD dispatch level is resolved process-wide from the environment on
//! first use, and integration-test binaries run their tests on
//! concurrent threads, so a second test could race the `set_var` below
//! against the first resolution. One test per binary makes the env
//! sequencing deterministic (conformance.rs covers the SIMD-on levels;
//! this binary pins the escape hatch).

use bsa::backend::{kernels, linalg, simd, Backend, NativeBackend};
use bsa::config::ModelConfig;
use bsa::tensor::Tensor;

#[test]
fn simd_off_is_bitwise_equal_to_scalar_references() {
    // Must run before anything in this process touches a kernel: the
    // env resolution is cached once.
    std::env::set_var(simd::SIMD_ENV, "off");
    assert_eq!(simd::active(), simd::Level::Scalar, "env escape hatch ignored");
    assert!(!simd::on());

    // kernel-by-kernel: fast == reference, bit for bit, across threads
    let (m, k, n) = (9usize, 23, 17);
    let a = bsa::prng::Rng::new(1).normals(m * k);
    let b = bsa::prng::Rng::new(2).normals(k * n);
    let bt = bsa::prng::Rng::new(3).normals(n * k);
    for threads in [1usize, 2, 3, 8] {
        let mut fast = vec![0.0f32; m * n];
        linalg::matmul(&a, &b, m, k, n, threads, &mut fast);
        let mut refr = vec![0.0f32; m * n];
        linalg::matmul_reference(&a, &b, m, k, n, &mut refr);
        assert_eq!(fast, refr, "matmul (threads {threads})");

        let mut fast = vec![0.0f32; m * n];
        linalg::matmul_nt(&a, &bt, m, k, n, threads, &mut fast);
        let mut refr = vec![0.0f32; m * n];
        linalg::matmul_nt_reference(&a, &bt, m, k, n, &mut refr);
        assert_eq!(fast, refr, "matmul_nt (threads {threads})");

        let mut sm_fast = bsa::prng::Rng::new(4).normals(m * n);
        let mut sm_ref = sm_fast.clone();
        linalg::softmax_rows(&mut sm_fast, m, n, threads);
        linalg::softmax_rows_reference(&mut sm_ref, m, n);
        assert_eq!(sm_fast, sm_ref, "softmax_rows (threads {threads})");

        let x = bsa::prng::Rng::new(5).normals(m * n);
        let scale = bsa::prng::Rng::new(6).normals(n);
        let mut rn_fast = vec![0.0f32; m * n];
        linalg::rms_norm(&x, &scale, m, n, threads, &mut rn_fast);
        let mut rn_ref = vec![0.0f32; m * n];
        linalg::rms_norm_reference(&x, &scale, m, n, &mut rn_ref);
        assert_eq!(rn_fast, rn_ref, "rms_norm (threads {threads})");
    }

    // attention family at an awkward (lane-tail) head dim
    let (bn, bd, ball) = (30usize, 7usize, 5usize);
    let q = bsa::prng::Rng::new(7).normals(bn * bd);
    let kk = bsa::prng::Rng::new(8).normals(bn * bd);
    let v = bsa::prng::Rng::new(9).normals(bn * bd);

    // streaming attention: with SIMD off the fast path runs the exact
    // scalar loops of attend_streaming_reference tile-for-tile, so the
    // match is bitwise — including a nk that straddles the tile boundary
    let snk = kernels::STREAM_TILE + 5;
    let sq = bsa::prng::Rng::new(17).normals(4 * bd);
    let sk = bsa::prng::Rng::new(18).normals(snk * bd);
    let sv = bsa::prng::Rng::new(19).normals(snk * bd);
    let mut stream_ref = vec![0.0f32; 4 * bd];
    let mut sref_scratch = Vec::new();
    kernels::attend_streaming_reference(
        &sq, &sk, &sv, 4, snk, bd, 0.4, &mut stream_ref, &mut sref_scratch,
    );
    for threads in [1usize, 4] {
        let mut fast = vec![0.0f32; 4 * bd];
        let mut s = Vec::new();
        kernels::attend(&sq, &sk, &sv, 4, snk, bd, 0.4, threads, &mut fast, &mut s);
        assert_eq!(fast, stream_ref, "attend streaming (threads {threads})");
        let mut fast2 = vec![0.0f32; 4 * bd];
        let mut s2 = Vec::new();
        kernels::attend_streaming(&sq, &sk, &sv, 4, snk, bd, 0.4, threads, &mut fast2, &mut s2);
        assert_eq!(fast2, stream_ref, "attend_streaming (threads {threads})");
    }

    for threads in [1usize, 4] {
        let mut fast = vec![0.0f32; bn * bd];
        kernels::ball_attention(&q, &kk, &v, bn, bd, ball, threads, &mut fast);
        let mut refr = vec![0.0f32; bn * bd];
        let mut sc = Vec::new();
        kernels::ball_attention_reference(&q, &kk, &v, bn, bd, ball, &mut refr, &mut sc);
        assert_eq!(fast, refr, "ball_attention (threads {threads})");

        let block = 6usize;
        let mut cm_fast = vec![0.0f32; (bn / block) * bd];
        kernels::compress_mean(&q, bn, bd, block, threads, &mut cm_fast);
        let mut cm_ref = vec![0.0f32; (bn / block) * bd];
        kernels::compress_mean_reference(&q, bn, bd, block, &mut cm_ref);
        assert_eq!(cm_fast, cm_ref, "compress_mean (threads {threads})");

        let (group, top_k, nb) = (5usize, 2usize, bn / ball);
        let groups = bn / group;
        let idx: Vec<usize> = (0..groups).flat_map(|g| [g % nb, (g + 1) % nb]).collect();
        let mut sorted = idx.clone();
        for pair in sorted.chunks_exact_mut(top_k) {
            pair.sort_unstable();
        }
        let mut sel_fast = vec![0.0f32; bn * bd];
        kernels::select_attention(
            &q, &kk, &v, &sorted, bn, bd, ball, group, top_k, threads, &mut sel_fast,
        );
        let mut sel_ref = vec![0.0f32; bn * bd];
        let (mut ks, mut vs, mut scr) = (Vec::new(), Vec::new(), Vec::new());
        kernels::select_attention_reference(
            &q, &kk, &v, &sorted, bn, bd, ball, group, top_k, &mut sel_ref, &mut ks, &mut vs,
            &mut scr,
        );
        assert_eq!(sel_fast, sel_ref, "select_attention (threads {threads})");
    }

    // whole forward: scalar mode is still bitwise across thread counts
    let mc = ModelConfig {
        dim: 32,
        num_heads: 2,
        num_blocks: 2,
        ball_size: 64,
        seq_len: 256,
        ..Default::default()
    };
    let x = {
        let mut rng = bsa::prng::Rng::new(12);
        Tensor::new(vec![1, 256, 6], rng.normals(256 * 6))
    };
    let base = NativeBackend::init(5, &mc, 6, 1, 1)
        .unwrap()
        .with_threads(1)
        .forward(&x)
        .unwrap();
    for t in [2usize, 4, 8] {
        let out = NativeBackend::init(5, &mc, 6, 1, 1)
            .unwrap()
            .with_threads(t)
            .forward(&x)
            .unwrap();
        assert_eq!(base, out, "scalar-mode forward diverged at threads={t}");
    }
}
