//! Differential conformance harness for the native parallel kernels.
//!
//! Every fast kernel in `bsa::backend::{linalg, kernels}` has a
//! `*_reference` scalar twin (see the "Kernel conformance" section of
//! the `backend` module docs). This file is the gate that keeps the
//! pairs equivalent: randomized shape sweeps — uneven ball sizes,
//! degenerate single-point balls, panel-boundary-crossing GEMMs,
//! tie-heavy top-k rows, SIMD lane-tail lengths (N%8 in 1..=7),
//! streaming tile tails (nk % STREAM_TILE in 1..=7), single-key units,
//! all-masked rows, single-row panels, subnormal/huge logits — across
//! randomized thread counts, asserting fast == reference within 1e-5.
//! The streaming attention path (`attend_streaming`, the production
//! `attend` since the online-softmax rewrite) is additionally held to
//! the same bound against `attend_reference` — the *materialized*
//! scalar oracle — so the tile-by-tile rescale numerics can never
//! drift from the full-softmax math; and its tile-sized scratch
//! contract (capacity never exceeds STREAM_TILE, i.e. no nq×nk score
//! buffer exists) is asserted directly. That tolerance is
//! the contract since the `backend::simd` microkernel layer landed:
//! SIMD horizontal reductions reorder accumulation, so the fast kernels
//! genuinely differ from their scalar twins in the last bits when SIMD
//! is active (they stay bitwise across *thread counts*, and
//! `rust/tests/simd_off.rs` pins the `BSA_NATIVE_SIMD=off`
//! bitwise-equals-scalar guarantee). On top of the kernel sweeps:
//! whole-forward equivalence across thread counts, concurrent
//! bit-determinism on a shared `Arc<dyn Backend>`, typed errors for
//! shapes the kernels cannot serve (N not divisible by ball size),
//! `params.rs` error paths (truncated / corrupt / mis-shaped `.bsackpt`
//! files), and — when compiled artifacts exist — the native-vs-pjrt
//! fixture gate.
//!
//! The parallel dispatches run on `backend::pool`'s **persistent worker
//! pool**, so this file also gates the pool's lifecycle contract:
//! bitwise-identical kernel output across 100+ reused dispatches at
//! mixed thread counts, a flat global worker population under repeated
//! backend construct/drop churn, and explicit `WorkerPool` drop joining
//! every worker (live gauge reads zero the moment drop returns). The
//! whole-forward sweeps additionally exercise the head-parallel
//! attention path, including nested dispatches (threads > batch*heads).
//!
//! Failures print the `proptest_lite` case id so a shape can be
//! replayed; run just this file with `cargo test --test conformance`
//! (what `scripts/check.sh --quick` does, in release mode so the
//! optimizer-on behaviour of the parallel kernels is what's tested).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use bsa::backend::native::AttnHyper;
use bsa::backend::{kernels, linalg, pool, simd, Backend, NativeBackend, NativeParams};
use bsa::config::ModelConfig;
use bsa::proptest_lite::{forall, Gen};
use bsa::tensor::Tensor;

/// Conformance tolerance: the acceptance contract for fast-vs-reference
/// at any SIMD level. (Across *thread counts* the kernels are bitwise
/// equal, which `conf_forward_bitwise_across_threads` checks end to
/// end; with SIMD off they are bitwise twins, see
/// `rust/tests/simd_off.rs`.)
const TOL: f32 = 1e-5;

fn assert_close(fast: &[f32], reference: &[f32], what: &str) {
    assert_eq!(fast.len(), reference.len(), "{what}: length mismatch");
    for (i, (a, b)) in fast.iter().zip(reference).enumerate() {
        assert!(
            (a - b).abs() <= TOL,
            "{what}[{i}]: fast {a} vs reference {b}"
        );
    }
}

/// Thread counts worth sweeping: serial, even/odd splits, and more
/// threads than most sweep shapes have rows (exercises the clamp).
fn pick_threads(g: &mut Gen) -> usize {
    *g.choose(&[1usize, 2, 3, 4, 8])
}

// ---------------------------------------------------------------------------
// linalg: GEMM family
// ---------------------------------------------------------------------------

#[test]
fn conf_matmul_matches_reference() {
    forall(40, |g| {
        let m = g.usize_in(1..33);
        let k = g.usize_in(1..48);
        let n = g.usize_in(1..40);
        let threads = pick_threads(g);
        let a = g.normals(m * k);
        let b = g.normals(k * n);
        let mut fast = vec![0.0f32; m * n];
        linalg::matmul(&a, &b, m, k, n, threads, &mut fast);
        let mut refr = vec![0.0f32; m * n];
        linalg::matmul_reference(&a, &b, m, k, n, &mut refr);
        assert_close(&fast, &refr, "matmul");
    });
}

#[test]
fn conf_matmul_large_crosses_panels() {
    // KC = 256 and NC = 128 internally: k > 256, n > 128 forces the
    // packed-panel loops to wrap, the case a small sweep never reaches.
    for (m, k, n) in [(3usize, 300usize, 150usize), (9, 513, 257), (1, 1024, 1)] {
        let a = bsa::prng::Rng::new(k as u64).normals(m * k);
        let b = bsa::prng::Rng::new(n as u64).normals(k * n);
        for threads in [1usize, 2, 5] {
            let mut fast = vec![0.0f32; m * n];
            linalg::matmul(&a, &b, m, k, n, threads, &mut fast);
            let mut refr = vec![0.0f32; m * n];
            linalg::matmul_reference(&a, &b, m, k, n, &mut refr);
            assert_close(&fast, &refr, "matmul panel");
        }
    }
}

#[test]
fn conf_matmul_nt_matches_reference() {
    forall(40, |g| {
        let m = g.usize_in(1..33);
        let k = g.usize_in(1..40);
        let n = g.usize_in(1..48);
        let threads = pick_threads(g);
        let a = g.normals(m * k);
        let b = g.normals(n * k);
        let mut fast = vec![0.0f32; m * n];
        linalg::matmul_nt(&a, &b, m, k, n, threads, &mut fast);
        let mut refr = vec![0.0f32; m * n];
        linalg::matmul_nt_reference(&a, &b, m, k, n, &mut refr);
        assert_close(&fast, &refr, "matmul_nt");
    });
}

// ---------------------------------------------------------------------------
// linalg: rowwise ops
// ---------------------------------------------------------------------------

#[test]
fn conf_softmax_rows_matches_reference() {
    forall(40, |g| {
        let rows = g.usize_in(1..24);
        let cols = g.usize_in(1..24);
        let threads = pick_threads(g);
        let mag = g.f32_in(0.5..3e4);
        let mut fast: Vec<f32> = g.normals(rows * cols).iter().map(|v| v * mag).collect();
        if g.bool() {
            // mask values like the selection branch injects
            let i = g.usize_in(0..fast.len());
            fast[i] = kernels::NEG_INF;
        }
        let mut refr = fast.clone();
        linalg::softmax_rows(&mut fast, rows, cols, threads);
        linalg::softmax_rows_reference(&mut refr, rows, cols);
        assert_close(&fast, &refr, "softmax_rows");
    });
}

#[test]
fn conf_rms_norm_matches_reference() {
    forall(40, |g| {
        let rows = g.usize_in(1..24);
        let cols = g.usize_in(1..32);
        let threads = pick_threads(g);
        let x = g.normals(rows * cols);
        let scale = g.normals(cols);
        let mut fast = vec![0.0f32; rows * cols];
        linalg::rms_norm(&x, &scale, rows, cols, threads, &mut fast);
        let mut refr = vec![0.0f32; rows * cols];
        linalg::rms_norm_reference(&x, &scale, rows, cols, &mut refr);
        assert_close(&fast, &refr, "rms_norm");
    });
}

// ---------------------------------------------------------------------------
// kernels: attention family
// ---------------------------------------------------------------------------

#[test]
fn conf_attend_matches_reference() {
    forall(30, |g| {
        let nq = g.usize_in(1..32);
        let nk = g.usize_in(1..32);
        let d = g.usize_in(1..12);
        let threads = pick_threads(g);
        let scale = 1.0 / (d as f32).sqrt();
        let q = g.normals(nq * d);
        let k = g.normals(nk * d);
        let v = g.normals(nk * d);
        let mut fast = vec![0.0f32; nq * d];
        let mut s1 = Vec::new();
        kernels::attend(&q, &k, &v, nq, nk, d, scale, threads, &mut fast, &mut s1);
        let mut refr = vec![0.0f32; nq * d];
        let mut s2 = Vec::new();
        kernels::attend_reference(&q, &k, &v, nq, nk, d, scale, &mut refr, &mut s2);
        assert_close(&fast, &refr, "attend");
    });
}

#[test]
fn conf_attend_streaming_matches_both_references() {
    // The streaming kernel against BOTH twins: its own scalar streaming
    // reference (the usual pair contract) and the materialized scalar
    // oracle (so online-softmax rescaling can never drift from the
    // full-softmax math). nk is built as whole tiles plus a residue so
    // every tail width 0..=7 around the STREAM_TILE boundary sweeps
    // through, including the multi-tile rescale chains.
    forall(30, |g| {
        let tiles = g.usize_in(0..4);
        let tail = g.usize_in(0..8);
        let nk = (tiles * kernels::STREAM_TILE + tail).max(1);
        let nq = g.usize_in(1..12);
        let d = g.usize_in(1..12);
        let threads = pick_threads(g);
        let scale = 1.0 / (d as f32).sqrt();
        let q = g.normals(nq * d);
        let k = g.normals(nk * d);
        let v = g.normals(nk * d);
        let mut fast = vec![0.0f32; nq * d];
        let mut s1 = Vec::new();
        kernels::attend_streaming(&q, &k, &v, nq, nk, d, scale, threads, &mut fast, &mut s1);
        let mut tw = vec![0.0f32; nq * d];
        let mut s2 = Vec::new();
        kernels::attend_streaming_reference(&q, &k, &v, nq, nk, d, scale, &mut tw, &mut s2);
        assert_close(&fast, &tw, "attend_streaming vs scalar streaming twin");
        let mut oracle = vec![0.0f32; nq * d];
        let mut s3 = Vec::new();
        kernels::attend_reference(&q, &k, &v, nq, nk, d, scale, &mut oracle, &mut s3);
        assert_close(&fast, &oracle, "attend_streaming vs materialized oracle");
        // the no-nq×nk-buffer contract, on every swept shape
        assert!(
            s1.capacity() <= kernels::STREAM_TILE,
            "streaming scratch grew to {} (> STREAM_TILE)",
            s1.capacity()
        );
    });
}

#[test]
fn conf_attend_streaming_single_key_is_value_passthrough() {
    // nk = 1: one tile, one key, softmax weight exactly 1.0 — out == v
    // row-for-row, the degenerate unit a tiled kernel mishandles first.
    forall(12, |g| {
        let nq = g.usize_in(1..9);
        let d = g.usize_in(1..10);
        let threads = pick_threads(g);
        let q = g.normals(nq * d);
        let k = g.normals(d);
        let v = g.normals(d);
        let mut out = vec![0.0f32; nq * d];
        let mut s = Vec::new();
        kernels::attend_streaming(&q, &k, &v, nq, 1, d, 0.7, threads, &mut out, &mut s);
        for (i, row) in out.chunks_exact(d).enumerate() {
            assert_close(row, &v, &format!("single-key row {i}"));
        }
    });
}

#[test]
fn conf_attend_streaming_huge_and_subnormal_logits() {
    // Logit magnitudes that stress the online rescale: huge positives
    // (later tiles force alpha ~ exp(-big) underflow of earlier mass),
    // huge negatives, subnormal-scale values, and NEG_INF-masked keys
    // mixed in. Must stay finite and within the oracle bound.
    let d = 4usize;
    let nk = kernels::STREAM_TILE * 2 + 3;
    let mut rng = bsa::prng::Rng::new(42);
    let q: Vec<f32> = rng.normals(3 * d).iter().map(|x| x * 40.0).collect();
    let mut k: Vec<f32> = rng.normals(nk * d);
    let v = rng.normals(nk * d);
    // plant extremes: one huge-logit key in a late tile, one subnormal
    // key, one row of NEG_INF-style mask magnitude
    for j in 0..d {
        k[(nk - 1) * d + j] = 30.0; // with |q| ~ 40 this drives ~1e3 logits
        k[d + j] = 1.0e-39;
        k[2 * d + j] = -35.0;
    }
    for threads in [1usize, 3, 8] {
        let mut fast = vec![0.0f32; 3 * d];
        let mut s1 = Vec::new();
        kernels::attend_streaming(&q, &k, &v, 3, nk, d, 1.0, threads, &mut fast, &mut s1);
        assert!(fast.iter().all(|x| x.is_finite()), "non-finite streaming output");
        let mut oracle = vec![0.0f32; 3 * d];
        let mut s2 = Vec::new();
        kernels::attend_reference(&q, &k, &v, 3, nk, d, 1.0, &mut oracle, &mut s2);
        assert_close(&fast, &oracle, "huge/subnormal logits");
    }
}

#[test]
fn conf_attend_all_masked_rows_are_uniform_not_nan() {
    // Regression (PR 6): a query whose every key is masked (all logits
    // NEG_INF — or even true -inf) must produce the documented uniform
    // average of the values, not NaN, through the streaming tile sweep.
    let (nq, d) = (2usize, 3usize);
    let nk = kernels::STREAM_TILE + 9; // tile boundary + tail, all masked
    let mut rng = bsa::prng::Rng::new(77);
    let q = rng.normals(nq * d);
    let v = rng.normals(nk * d);
    let mut mean = vec![0.0f32; d];
    for row in v.chunks_exact(d) {
        for (m, &x) in mean.iter_mut().zip(row) {
            *m += x / nk as f32;
        }
    }
    for kval in [kernels::NEG_INF, f32::NEG_INFINITY] {
        // drive every logit to exactly kval: q rows are [kval, 0, ...],
        // k rows are [1, 0, ...], so q·k == kval for every pair
        let mut q_masked = q.clone();
        for row in q_masked.chunks_exact_mut(d) {
            row.fill(0.0);
            row[0] = kval;
        }
        let mut k_masked = vec![0.0f32; nk * d];
        for row in k_masked.chunks_exact_mut(d) {
            row[0] = 1.0;
        }
        for threads in [1usize, 4] {
            let mut out = vec![0.0f32; nq * d];
            let mut s = Vec::new();
            kernels::attend_streaming(
                &q_masked, &k_masked, &v, nq, nk, d, 1.0, threads, &mut out, &mut s,
            );
            assert!(out.iter().all(|x| x.is_finite()), "masked rows produced non-finite");
            for (i, row) in out.chunks_exact(d).enumerate() {
                for (j, (&a, &b)) in row.iter().zip(&mean).enumerate() {
                    assert!(
                        (a - b).abs() <= 1e-4,
                        "masked row {i}[{j}]: {a} vs uniform mean {b} (kval={kval})"
                    );
                }
            }
        }
    }
}

#[test]
fn conf_ball_attention_matches_reference() {
    // Uneven (non-power-of-two) ball sizes, the degenerate single-point
    // ball, and ball == n all sweep through here.
    forall(30, |g| {
        let ball = g.usize_in(1..17); // 1 = degenerate single-point balls
        let nballs = g.usize_in(1..9);
        let n = ball * nballs;
        let d = g.usize_in(1..10);
        let threads = pick_threads(g);
        let q = g.normals(n * d);
        let k = g.normals(n * d);
        let v = g.normals(n * d);
        let mut fast = vec![0.0f32; n * d];
        kernels::ball_attention(&q, &k, &v, n, d, ball, threads, &mut fast);
        let mut refr = vec![0.0f32; n * d];
        let mut scores = Vec::new();
        kernels::ball_attention_reference(&q, &k, &v, n, d, ball, &mut refr, &mut scores);
        assert_close(&fast, &refr, "ball_attention");
    });
}

#[test]
fn conf_single_point_balls_are_value_passthrough() {
    // ball_size 1: softmax over one key is 1.0, so out == v exactly —
    // the degenerate edge a chunked implementation is most likely to
    // get wrong.
    let (n, d) = (7usize, 3usize);
    let q = bsa::prng::Rng::new(1).normals(n * d);
    let k = bsa::prng::Rng::new(2).normals(n * d);
    let v = bsa::prng::Rng::new(3).normals(n * d);
    for threads in [1usize, 2, 8] {
        let mut out = vec![0.0f32; n * d];
        kernels::ball_attention(&q, &k, &v, n, d, 1, threads, &mut out);
        assert_close(&out, &v, "single-point ball passthrough");
    }
}

#[test]
fn conf_compress_mean_matches_reference() {
    forall(30, |g| {
        let block = g.usize_in(1..13);
        let nb = g.usize_in(1..17);
        let n = block * nb;
        let d = g.usize_in(1..10);
        let threads = pick_threads(g);
        let x = g.normals(n * d);
        let mut fast = vec![0.0f32; nb * d];
        kernels::compress_mean(&x, n, d, block, threads, &mut fast);
        let mut refr = vec![0.0f32; nb * d];
        kernels::compress_mean_reference(&x, n, d, block, &mut refr);
        assert_close(&fast, &refr, "compress_mean");
    });
}

#[test]
fn conf_group_scores_matches_reference() {
    forall(30, |g| {
        let group = g.usize_in(1..9);
        let groups = g.usize_in(1..9);
        let n = group * groups;
        let d = g.usize_in(1..10);
        let nb = g.usize_in(1..12);
        let threads = pick_threads(g);
        let q = g.normals(n * d);
        let kc = g.normals(nb * d);
        let mut qg1 = Vec::new();
        let mut fast = vec![0.0f32; groups * nb];
        kernels::group_scores(&q, &kc, n, d, group, nb, threads, &mut qg1, &mut fast);
        let mut qg2 = Vec::new();
        let mut refr = vec![0.0f32; groups * nb];
        kernels::group_scores_reference(&q, &kc, n, d, group, nb, &mut qg2, &mut refr);
        assert_close(&fast, &refr, "group_scores");
    });
}

#[test]
fn conf_topk_matches_reference_with_ties() {
    forall(40, |g| {
        let groups = g.usize_in(1..12);
        let nb = g.usize_in(1..20);
        let k = g.usize_in(1..nb + 1);
        let threads = pick_threads(g);
        // quantize so duplicate scores (ties) are common — tie-breaking
        // must stay "first occurrence wins" under parallel chunking
        let scores: Vec<f32> = g
            .normals(groups * nb)
            .iter()
            .map(|v| (v * 2.0).round() / 2.0)
            .collect();
        let mut fast = Vec::new();
        kernels::topk_indices(&scores, groups, nb, k, threads, &mut fast);
        let mut refr = Vec::new();
        kernels::topk_indices_reference(&scores, groups, nb, k, &mut refr);
        assert_eq!(fast, refr, "topk indices diverge (ties?)");
        // structural sanity: ascending within each group, in range
        for grp in fast.chunks_exact(k) {
            for w in grp.windows(2) {
                assert!(w[0] < w[1], "not strictly ascending: {grp:?}");
            }
            assert!(grp.iter().all(|&i| i < nb));
        }
    });
}

#[test]
fn conf_select_attention_matches_reference() {
    forall(25, |g| {
        let sel_block = g.usize_in(1..7);
        let nblocks = g.usize_in(1..7);
        let group = g.usize_in(1..7);
        // n must be divisible by both the selection block and the group
        let n = sel_block * group * nblocks.max(1);
        let nb = n / sel_block;
        let d = g.usize_in(1..8);
        let top_k = g.usize_in(1..nb + 1);
        let groups = n / group;
        let threads = pick_threads(g);
        let q = g.normals(n * d);
        let k = g.normals(n * d);
        let v = g.normals(n * d);
        // random (sorted, in-range) selections per group, like topk emits
        let mut idx = Vec::with_capacity(groups * top_k);
        for _ in 0..groups {
            let mut picks: Vec<usize> = (0..top_k).map(|_| g.usize_in(0..nb)).collect();
            picks.sort_unstable();
            idx.extend(picks);
        }
        let mut fast = vec![0.0f32; n * d];
        kernels::select_attention(&q, &k, &v, &idx, n, d, sel_block, group, top_k, threads, &mut fast);
        let mut refr = vec![0.0f32; n * d];
        let (mut ks, mut vs, mut sc) = (Vec::new(), Vec::new(), Vec::new());
        kernels::select_attention_reference(
            &q, &k, &v, &idx, n, d, sel_block, group, top_k, &mut refr, &mut ks, &mut vs, &mut sc,
        );
        assert_close(&fast, &refr, "select_attention");
    });
}

// ---------------------------------------------------------------------------
// SIMD twins: lane tails, single-row panels, subnormal/huge logits
// (these run at whatever level the host resolved — on a machine with
// AVX2/NEON they exercise the specializations, elsewhere the portable
// lane panels; the scalar level is pinned by rust/tests/simd_off.rs)
// ---------------------------------------------------------------------------

/// Lengths covering every lane-tail residue N % 8 in 1..=7 plus exact
/// multiples and the single-element edge.
const LANE_TAILS: [usize; 12] = [1, 2, 3, 4, 5, 6, 7, 8, 9, 15, 16, 17];

#[test]
fn conf_simd_kernels_at_lane_tail_widths() {
    // The reduction dimension (k for matmul_nt, cols for softmax /
    // rms_norm) is where lane tails live: sweep every residue at random
    // thread counts against the scalar twins.
    forall(24, |g| {
        let k = *g.choose(&LANE_TAILS);
        let m = g.usize_in(1..10);
        let n = *g.choose(&LANE_TAILS);
        let threads = pick_threads(g);
        let a = g.normals(m * k);
        let b = g.normals(n * k);
        let mut fast = vec![0.0f32; m * n];
        linalg::matmul_nt(&a, &b, m, k, n, threads, &mut fast);
        let mut refr = vec![0.0f32; m * n];
        linalg::matmul_nt_reference(&a, &b, m, k, n, &mut refr);
        assert_close(&fast, &refr, "matmul_nt lane tail");

        let rows = g.usize_in(1..6);
        let cols = *g.choose(&LANE_TAILS);
        let mut sm_fast = g.normals(rows * cols);
        let mut sm_ref = sm_fast.clone();
        linalg::softmax_rows(&mut sm_fast, rows, cols, threads);
        linalg::softmax_rows_reference(&mut sm_ref, rows, cols);
        assert_close(&sm_fast, &sm_ref, "softmax lane tail");

        let x = g.normals(rows * cols);
        let scale = g.normals(cols);
        let mut rn_fast = vec![0.0f32; rows * cols];
        linalg::rms_norm(&x, &scale, rows, cols, threads, &mut rn_fast);
        let mut rn_ref = vec![0.0f32; rows * cols];
        linalg::rms_norm_reference(&x, &scale, rows, cols, &mut rn_ref);
        assert_close(&rn_fast, &rn_ref, "rms_norm lane tail");
    });
}

#[test]
fn conf_simd_attention_at_lane_tail_head_dims() {
    // Head dims with every tail residue through the ball / selection
    // unit kernels (the per-unit dot/axpy panels see `d`-length rows).
    forall(16, |g| {
        let d = *g.choose(&LANE_TAILS);
        let ball = g.usize_in(1..9);
        let nballs = g.usize_in(1..5);
        let n = ball * nballs;
        let threads = pick_threads(g);
        let q = g.normals(n * d);
        let k = g.normals(n * d);
        let v = g.normals(n * d);
        let mut fast = vec![0.0f32; n * d];
        kernels::ball_attention(&q, &k, &v, n, d, ball, threads, &mut fast);
        let mut refr = vec![0.0f32; n * d];
        let mut scores = Vec::new();
        kernels::ball_attention_reference(&q, &k, &v, n, d, ball, &mut refr, &mut scores);
        assert_close(&fast, &refr, "ball_attention lane-tail d");

        // selection with the same d: group == sel_block == ball keeps
        // the divisibility contract while d sweeps the tails
        let top_k = g.usize_in(1..nballs + 1);
        let groups = n / ball;
        let mut idx = Vec::with_capacity(groups * top_k);
        for _ in 0..groups {
            let mut picks: Vec<usize> = (0..top_k).map(|_| g.usize_in(0..nballs)).collect();
            picks.sort_unstable();
            idx.extend(picks);
        }
        let mut sel_fast = vec![0.0f32; n * d];
        kernels::select_attention(&q, &k, &v, &idx, n, d, ball, ball, top_k, threads, &mut sel_fast);
        let mut sel_ref = vec![0.0f32; n * d];
        let (mut ks, mut vs, mut sc) = (Vec::new(), Vec::new(), Vec::new());
        kernels::select_attention_reference(
            &q, &k, &v, &idx, n, d, ball, ball, top_k, &mut sel_ref, &mut ks, &mut vs, &mut sc,
        );
        assert_close(&sel_fast, &sel_ref, "select_attention lane-tail d");
    });
}

#[test]
fn conf_simd_single_row_panels() {
    // rows = 1 (one chunk no matter the thread count) at lane-tail
    // widths: the degenerate panel shape a chunked SIMD kernel is most
    // likely to get wrong.
    for &cols in &LANE_TAILS {
        for threads in [1usize, 3, 8] {
            let mut sm_fast = bsa::prng::Rng::new(cols as u64 + 1).normals(cols);
            let mut sm_ref = sm_fast.clone();
            linalg::softmax_rows(&mut sm_fast, 1, cols, threads);
            linalg::softmax_rows_reference(&mut sm_ref, 1, cols);
            assert_close(&sm_fast, &sm_ref, "single-row softmax");

            let a = bsa::prng::Rng::new(cols as u64 + 2).normals(cols);
            let b = bsa::prng::Rng::new(cols as u64 + 3).normals(3 * cols);
            let mut nt_fast = vec![0.0f32; 3];
            linalg::matmul_nt(&a, &b, 1, cols, 3, threads, &mut nt_fast);
            let mut nt_ref = vec![0.0f32; 3];
            linalg::matmul_nt_reference(&a, &b, 1, cols, 3, &mut nt_ref);
            assert_close(&nt_fast, &nt_ref, "single-row matmul_nt");
        }
    }
}

#[test]
fn conf_simd_subnormal_and_huge_logits() {
    // Softmax rows mixing huge logits (3e4: exp underflows for the
    // rest), NEG_INF mask values, exact zeros, and subnormals; plus
    // rms_norm on an all-subnormal row (mean-square underflows to ~0,
    // the eps term must keep the output finite). The fast kernels must
    // stay finite and within the twin bound everywhere.
    let rows: Vec<Vec<f32>> = vec![
        vec![3e4, -3e4, 0.0, 1.0e-40, kernels::NEG_INF],
        vec![kernels::NEG_INF; 7],
        vec![1.0e-40, -1.0e-40, 1.0e-38, 0.0, -0.0, 2.0e-41, 8.5e-39, 1.0e-44],
        vec![700.0, 699.5, -700.0],
        vec![0.0],
    ];
    for (ri, row) in rows.iter().enumerate() {
        let cols = row.len();
        for threads in [1usize, 4] {
            let mut fast = row.clone();
            let mut refr = row.clone();
            linalg::softmax_rows(&mut fast, 1, cols, threads);
            linalg::softmax_rows_reference(&mut refr, 1, cols);
            assert!(fast.iter().all(|v| v.is_finite()), "row {ri}: non-finite softmax");
            assert_close(&fast, &refr, "subnormal/huge softmax");
        }
    }
    let sub = vec![1.0e-40f32, 2.0e-41, -3.0e-39, 1.0e-44, 0.0, -1.0e-40, 5.0e-42, 9.0e-39, 1.0e-41];
    let scale = vec![1.0f32; sub.len()];
    let mut fast = vec![0.0f32; sub.len()];
    linalg::rms_norm(&sub, &scale, 1, sub.len(), 2, &mut fast);
    let mut refr = vec![0.0f32; sub.len()];
    linalg::rms_norm_reference(&sub, &scale, 1, sub.len(), &mut refr);
    assert!(fast.iter().all(|v| v.is_finite()), "subnormal rms_norm non-finite");
    assert_close(&fast, &refr, "subnormal rms_norm");
}

#[test]
fn conf_simd_microkernels_match_scalar_twins() {
    // The microkernel layer itself, at every lane-tail length: the
    // reductions within a reassociation-sized bound of their scalar
    // twins, `row_max` exactly, and the element-parallel panels
    // bitwise (the property linalg::matmul's bitwise twin status
    // rests on). The resolved level must also be stable for the whole
    // process — that is what "bitwise across thread counts" stands on.
    let lvl = simd::active();
    for &n in &LANE_TAILS {
        let x = bsa::prng::Rng::new(n as u64 + 31).normals(n);
        let y = bsa::prng::Rng::new(n as u64 + 77).normals(n);
        let l1: f32 = x.iter().zip(&y).map(|(a, b)| (a * b).abs()).sum();
        let tol = 8.0 * n as f32 * f32::EPSILON * (l1 + 1.0);
        assert!(
            (simd::dot(&x, &y) - simd::dot_scalar(&x, &y)).abs() <= tol,
            "dot n={n}"
        );
        assert!(
            (simd::sum_sq(&x) - simd::sum_sq_scalar(&x)).abs() <= tol,
            "sum_sq n={n}"
        );
        assert_eq!(simd::row_max(&x), simd::row_max_scalar(&x), "row_max n={n}");

        let mut ef = x.clone();
        let mut er = x.clone();
        let max = simd::row_max_scalar(&x);
        let sf = simd::exp_sum(&mut ef, max);
        let sr = simd::exp_sum_scalar(&mut er, max);
        for (a, b) in ef.iter().zip(&er) {
            assert!((a - b).abs() <= TOL, "exp_sum n={n}: {a} vs {b}");
        }
        assert!((sf - sr).abs() <= 1e-4 * (1.0 + sr.abs()), "exp_sum total n={n}");

        let mut af = y.clone();
        simd::axpy(0.5, &x, &mut af);
        let mut ar = y.clone();
        for (o, &v) in ar.iter_mut().zip(&x) {
            *o += 0.5 * v;
        }
        assert_eq!(af, ar, "axpy must be a bitwise panel (n={n})");
    }
    assert_eq!(simd::active(), lvl, "dispatch level changed mid-run");
}

// ---------------------------------------------------------------------------
// whole-forward equivalence + determinism
// ---------------------------------------------------------------------------

fn tiny_config() -> ModelConfig {
    ModelConfig {
        dim: 32,
        num_heads: 2,
        num_blocks: 2,
        ball_size: 64,
        seq_len: 256,
        ..Default::default()
    }
}

fn fixture_input(n: usize, f: usize, seed: u64) -> Tensor {
    let mut rng = bsa::prng::Rng::new(seed);
    Tensor::new(vec![1, n, f], rng.normals(n * f))
}

#[test]
fn conf_forward_bitwise_across_threads() {
    // Stronger than the 1e-5 kernel contract: the full forward pass is
    // bit-identical for every thread budget, because every parallel
    // kernel preserves per-element accumulation order.
    let x = fixture_input(256, 6, 21);
    let base = NativeBackend::init(9, &tiny_config(), 6, 1, 1)
        .unwrap()
        .with_threads(1)
        .forward(&x)
        .unwrap();
    for t in [2usize, 3, 4, 8] {
        let out = NativeBackend::init(9, &tiny_config(), 6, 1, 1)
            .unwrap()
            .with_threads(t)
            .forward(&x)
            .unwrap();
        assert_eq!(base, out, "threads={t} changed the forward output");
    }
}

#[test]
fn conf_f16_forward_holds_the_tolerance_tier() {
    // The f16 storage tier from the backend docs: on unit-scale
    // activations the half-storage forward stays within
    // 5e-2 * (1 + |a|) of the f32 forward, and remains bitwise
    // deterministic across thread counts (encode/decode are
    // deterministic per element).
    use bsa::backend::native::Precision;
    let x = fixture_input(256, 6, 71);
    let full = NativeBackend::init(5, &tiny_config(), 6, 1, 1)
        .unwrap()
        .with_threads(2)
        .forward(&x)
        .unwrap();
    let half = NativeBackend::init(5, &tiny_config(), 6, 1, 1)
        .unwrap()
        .with_threads(2)
        .with_precision(Precision::F16)
        .forward(&x)
        .unwrap();
    assert_eq!(full.shape(), half.shape());
    for (i, (a, b)) in full.data().iter().zip(half.data()).enumerate() {
        assert!(b.is_finite(), "f16 forward[{i}] non-finite");
        assert!(
            (a - b).abs() <= 5e-2 * (1.0 + a.abs()),
            "f16 tier violated at [{i}]: f32 {a} vs f16 {b}"
        );
    }
    for t in [1usize, 3, 8] {
        let again = NativeBackend::init(5, &tiny_config(), 6, 1, 1)
            .unwrap()
            .with_threads(t)
            .with_precision(Precision::F16)
            .forward(&x)
            .unwrap();
        assert_eq!(again, half, "f16 forward not bitwise at threads={t}");
    }
}

#[test]
fn conf_forward_randomized_shapes_match_serial() {
    // Randomized small architectures: parallel forward == serial forward
    // within tolerance (bitwise, in fact) across shape combinations the
    // fixed tiny config never visits.
    forall(6, |g| {
        let dim = *g.choose(&[16usize, 32]);
        let heads = *g.choose(&[1usize, 2]);
        let ball = *g.choose(&[16usize, 32]);
        let mc = ModelConfig {
            dim,
            num_heads: heads,
            num_blocks: g.usize_in(1..3),
            ball_size: ball,
            cmp_block: 8,
            sel_block: 8,
            top_k: 2,
            group_size: 8,
            seq_len: ball * g.usize_in(1..5),
            ..Default::default()
        };
        let x = fixture_input(mc.seq_len, 3, g.case ^ 0xc0);
        let serial = NativeBackend::init(g.case, &mc, 3, 1, 1)
            .unwrap()
            .with_threads(1)
            .forward(&x)
            .unwrap();
        let parallel = NativeBackend::init(g.case, &mc, 3, 1, 1)
            .unwrap()
            .with_threads(pick_threads(g))
            .forward(&x)
            .unwrap();
        assert_close(parallel.data(), serial.data(), "forward");
    });
}

#[test]
fn conf_concurrent_forwards_bitwise_identical() {
    // Interleaving-freedom check: 8 threads drive the *same*
    // `Arc<dyn Backend>` concurrently (the router's worker-pool shape).
    // Any shared-scratch aliasing between concurrent forwards would
    // corrupt at least one output; all eight must be bit-identical.
    let backend: Arc<dyn Backend> =
        Arc::new(NativeBackend::init(3, &tiny_config(), 6, 1, 1).unwrap().with_threads(2));
    let x = fixture_input(256, 6, 33);
    let expected = backend.forward(&x).unwrap();
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..8)
            .map(|_| {
                let backend = backend.clone();
                let x = &x;
                s.spawn(move || backend.forward(x).unwrap())
            })
            .collect();
        for (i, h) in handles.into_iter().enumerate() {
            let out = h.join().expect("concurrent forward panicked");
            assert_eq!(out, expected, "concurrent forward {i} diverged");
        }
    });
}

#[test]
fn conf_rejects_n_not_divisible_by_ball() {
    // The kernels require uniform balls; shapes that break that must be
    // a typed construction error, never a wrong answer or a panic.
    let params = NativeParams::init(0, 6, 1, 32, 2, 1, 4);
    let hyper = AttnHyper { ball_size: 48, cmp_block: 8, group_size: 8, top_k: 2 };
    let err = NativeBackend::new(params, hyper, 100, 1).unwrap_err().to_string();
    assert!(err.contains("ball"), "error names the ball constraint: {err}");
}

// ---------------------------------------------------------------------------
// persistent worker pool: reuse determinism + lifecycle
// ---------------------------------------------------------------------------

#[test]
fn conf_pool_reuse_bitwise_across_dispatches() {
    // 120 dispatches through the same process-wide pool, cycling thread
    // counts and kernels: queue reuse, worker identity, and dispatch
    // order must never change a bit vs the fast kernels' own threads=1
    // output computed once up front (which itself must sit within the
    // 1e-5 twin bound of the scalar references — matmul is a bitwise
    // twin, ball attention a 1e-5 twin when SIMD reductions are active).
    let (m, k, n) = (13usize, 24, 17);
    let a = bsa::prng::Rng::new(5).normals(m * k);
    let b = bsa::prng::Rng::new(6).normals(k * n);
    let mut mm_ref = vec![0.0f32; m * n];
    linalg::matmul_reference(&a, &b, m, k, n, &mut mm_ref);
    let mut mm_expect = vec![0.0f32; m * n];
    linalg::matmul(&a, &b, m, k, n, 1, &mut mm_expect);
    assert_eq!(mm_expect, mm_ref, "matmul is an element-parallel bitwise twin");

    let (bn, bd, ball) = (24usize, 6usize, 4usize);
    let q = bsa::prng::Rng::new(7).normals(bn * bd);
    let kk = bsa::prng::Rng::new(8).normals(bn * bd);
    let v = bsa::prng::Rng::new(9).normals(bn * bd);
    let mut ball_ref = vec![0.0f32; bn * bd];
    let mut sc = Vec::new();
    kernels::ball_attention_reference(&q, &kk, &v, bn, bd, ball, &mut ball_ref, &mut sc);
    let mut ball_expect = vec![0.0f32; bn * bd];
    kernels::ball_attention(&q, &kk, &v, bn, bd, ball, 1, &mut ball_expect);
    assert_close(&ball_expect, &ball_ref, "ball vs scalar twin");

    let mut at_expect = vec![0.0f32; bn * bd];
    let mut at_scratch = Vec::new();
    kernels::attend(&q, &kk, &v, bn, bn, bd, 0.5, 1, &mut at_expect, &mut at_scratch);

    for i in 0..120 {
        let threads = [1usize, 2, 3, 4, 8][i % 5];
        let mut mm = vec![0.0f32; m * n];
        linalg::matmul(&a, &b, m, k, n, threads, &mut mm);
        assert_eq!(mm, mm_expect, "matmul dispatch {i} (threads {threads}) diverged");
        let mut bo = vec![0.0f32; bn * bd];
        kernels::ball_attention(&q, &kk, &v, bn, bd, ball, threads, &mut bo);
        assert_eq!(bo, ball_expect, "ball dispatch {i} (threads {threads}) diverged");
        // the scores scratch is reused across every dispatch; streaming
        // attend must keep it tile-sized forever (no nq×nk growth, and
        // an inherited bigger allocation is shrunk, never kept)
        let mut ao = vec![0.0f32; bn * bd];
        kernels::attend(&q, &kk, &v, bn, bn, bd, 0.5, threads, &mut ao, &mut at_scratch);
        assert_eq!(ao, at_expect, "attend dispatch {i} (threads {threads}) diverged");
        assert!(
            at_scratch.capacity() <= kernels::STREAM_TILE,
            "dispatch {i}: streaming scratch grew to {} (> STREAM_TILE)",
            at_scratch.capacity()
        );
    }
}

#[test]
fn conf_pool_matches_scoped_spawn_bitwise() {
    // The retained scoped-spawn dispatcher is the differential oracle
    // for the pool dispatcher: same chunking, same results, bit for bit.
    let src = bsa::prng::Rng::new(12).normals(64 * 8);
    let work = |row0: usize, chunk: &mut [f32]| {
        for (i, row) in chunk.chunks_exact_mut(8).enumerate() {
            let s = &src[(row0 + i) * 8..(row0 + i + 1) * 8];
            let mut acc = 0.0f32;
            for &x in s {
                acc += x * x;
            }
            for (j, out) in row.iter_mut().enumerate() {
                *out = acc + j as f32;
            }
        }
    };
    for threads in [1usize, 2, 3, 5, 8] {
        let mut pooled = vec![0.0f32; 64 * 8];
        let mut scoped = vec![0.0f32; 64 * 8];
        pool::par_rows(&mut pooled, 8, threads, work);
        pool::par_rows_scoped(&mut scoped, 8, threads, work);
        assert_eq!(pooled, scoped, "pool vs scoped diverged at threads={threads}");
    }
}

#[test]
fn conf_worker_pool_drop_joins_workers() {
    // Explicit pools must not leak threads: every construct/dispatch/
    // drop round ends with the live-worker gauge back at zero the moment
    // drop returns (Drop joins all workers).
    use std::sync::atomic::Ordering;
    for round in 0..6 {
        let p = pool::WorkerPool::new(4);
        let gauge = p.live_gauge();
        assert_eq!(p.worker_count(), 4, "round {round}");
        let mut out = vec![0.0f32; 64 * 8];
        p.par_rows(&mut out, 8, 4, |row0, chunk| {
            for (i, row) in chunk.chunks_exact_mut(8).enumerate() {
                row.fill((row0 + i) as f32);
            }
        });
        for (i, row) in out.chunks_exact(8).enumerate() {
            assert!(row.iter().all(|&v| v == i as f32), "round {round} row {i}");
        }
        assert_eq!(gauge.load(Ordering::SeqCst), 4, "round {round}: workers alive");
        drop(p);
        assert_eq!(
            gauge.load(Ordering::SeqCst),
            0,
            "round {round}: drop must join every worker"
        );
    }
}

#[test]
fn conf_backend_churn_keeps_global_pool_healthy() {
    // NativeBackend shares the lazily-grown global pool: backend
    // construct/forward/drop churn must leave the pool healthy. The
    // race-free invariants (other tests dispatch on the same pool
    // concurrently, so exact worker counts are not assertable here;
    // the deterministic join-on-drop property is covered by
    // conf_worker_pool_drop_joins_workers on explicit pools):
    //   1. forwards stay correct across the whole churn;
    //   2. the pool never exceeds its MAX_THREADS cap, no matter how
    //      many backends came and went (aggregate demand is capped);
    //   3. no global worker ever exits — live_workers >= worker_count
    //      read-after (a dead/leaked-then-reaped worker would show
    //      live < spawned, since only pool drop retires workers and
    //      the global pool is never dropped).
    let x = fixture_input(256, 6, 51);
    let expected = NativeBackend::init(0, &tiny_config(), 6, 1, 1)
        .unwrap()
        .with_threads(4)
        .forward(&x)
        .unwrap();
    for round in 0..8 {
        let be = NativeBackend::init(0, &tiny_config(), 6, 1, 1)
            .unwrap()
            .with_threads(4);
        let out = be.forward(&x).unwrap();
        assert_eq!(out, expected, "round {round}: churn changed the forward output");
        drop(be);
        let spawned = pool::global_pool().worker_count();
        let live = pool::global_pool().live_workers();
        assert!(spawned <= pool::MAX_THREADS, "round {round}: pool exceeded MAX_THREADS");
        assert!(
            live >= spawned,
            "round {round}: {} of {spawned} global workers exited",
            spawned - live
        );
    }
}

// ---------------------------------------------------------------------------
// params.rs error paths: corrupt / truncated / mis-shaped .bsackpt files
// ---------------------------------------------------------------------------

fn tmp(name: &str) -> PathBuf {
    std::env::temp_dir().join(name)
}

#[test]
fn conf_params_truncated_file_is_typed_error() {
    let p = NativeParams::init(0, 6, 1, 32, 2, 1, 4);
    let path = tmp("bsa_conf_truncated.bsackpt");
    p.save(&path).unwrap();
    let bytes = std::fs::read(&path).unwrap();
    // cut mid-array: the loader must return Err, not panic or hand back
    // a silently short parameter set
    for cut in [bytes.len() / 2, bytes.len() - 10, 17] {
        std::fs::write(&path, &bytes[..cut]).unwrap();
        assert!(
            NativeParams::load(&path).is_err(),
            "truncation at {cut} bytes must fail"
        );
    }
    std::fs::remove_file(path).ok();
}

#[test]
fn conf_params_wrong_magic_is_typed_error() {
    let path = tmp("bsa_conf_magic.bsackpt");
    let mut bytes = Vec::new();
    bytes.extend_from_slice(b"NOPE");
    bytes.extend_from_slice(&[0u8; 64]);
    std::fs::write(&path, &bytes).unwrap();
    let err = NativeParams::load(&path).unwrap_err().to_string();
    assert!(err.contains("bsackpt"), "error names the format: {err}");
    std::fs::remove_file(path).ok();
}

#[test]
fn conf_params_shape_mismatch_is_typed_error() {
    // A param file whose arrays disagree with the architecture's shape
    // contract (wq must be (C, C)) fails validation with the array name.
    let p = NativeParams::init(0, 6, 1, 32, 2, 1, 4);
    let mut arrays: Vec<(String, Tensor)> = p
        .named_arrays()
        .into_iter()
        .map(|(n, t)| (n, t.clone()))
        .collect();
    for (name, t) in arrays.iter_mut() {
        if name == "blocks.0.attn.wq" {
            *t = Tensor::zeros(vec![32, 16]); // wrong: must be (32, 32)
        }
    }
    let err = NativeParams::from_named(arrays).unwrap_err().to_string();
    assert!(err.contains("wq"), "error names the offending array: {err}");

    // and the same through a round-tripped file
    let path = tmp("bsa_conf_shape.bsackpt");
    let mut bad = p.clone();
    bad.blocks[0].attn.wq = Tensor::zeros(vec![32, 16]);
    // save() itself doesn't validate (it's a dumb container); load must
    let arrays: Vec<(String, Tensor)> = bad
        .named_arrays()
        .into_iter()
        .map(|(n, t)| (n, t.clone()))
        .collect();
    bsa::coordinator::checkpoint::Checkpoint { step: 0, arrays }
        .save(&path)
        .unwrap();
    assert!(NativeParams::load(&path).is_err());
    std::fs::remove_file(path).ok();
}

#[test]
fn conf_backend_spec_mismatch_is_typed_error() {
    // Valid params + a serving shape the params cannot serve: top_k
    // exceeding the block count at the requested N must error at
    // construction, before any request can hit it.
    let params = NativeParams::init(0, 6, 1, 32, 2, 1, 4);
    let hyper = AttnHyper { ball_size: 16, cmp_block: 8, group_size: 8, top_k: 64 };
    let err = NativeBackend::new(params, hyper, 16, 1).unwrap_err().to_string();
    assert!(err.contains("top_k"), "error names top_k: {err}");
}

// ---------------------------------------------------------------------------
// native == pjrt on the fixture (skips without artifacts, like every
// pjrt-dependent test)
// ---------------------------------------------------------------------------

#[test]
fn conf_native_matches_pjrt_fixture() {
    use bsa::runtime::{literal_to_tensor, scalar_i32, Engine};
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping conf_native_matches_pjrt_fixture: no artifacts (run `make artifacts`)");
        return;
    }
    let engine = Arc::new(Engine::new(&dir).expect("engine"));
    let init = engine.load("init_bsa_syn_n256_b1").unwrap();
    let fwd = engine.load("fwd_bsa_syn_n256_b1").unwrap();
    let param_lits = init.run(&[scalar_i32(0)]).unwrap();
    let params: Vec<Tensor> = param_lits
        .iter()
        .map(|l| literal_to_tensor(l).unwrap())
        .collect();
    let names: Vec<String> = fwd
        .info
        .inputs
        .iter()
        .take(fwd.info.nparams)
        .map(|s| s.name.clone())
        .collect();
    let native = NativeBackend::from_flat(
        params,
        &names,
        AttnHyper::from_graph(&fwd.info),
        fwd.info.n,
        fwd.info.batch,
    )
    .unwrap()
    .with_threads(pool::resolve_threads(0));

    let x = {
        let mut rng = bsa::prng::Rng::new(11);
        Tensor::new(
            vec![fwd.info.batch, fwd.info.n, fwd.info.in_features],
            rng.normals(fwd.info.batch * fwd.info.n * fwd.info.in_features),
        )
    };
    let pjrt_out =
        literal_to_tensor(&fwd.run_with_tensors(&param_lits, &[&x]).unwrap()[0]).unwrap();
    let native_out = native.forward(&x).unwrap();
    assert_eq!(pjrt_out.shape(), native_out.shape());
    let max_abs = pjrt_out
        .data()
        .iter()
        .zip(native_out.data())
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    assert!(
        max_abs < 1e-3,
        "pjrt and native forward disagree: max |diff| = {max_abs}"
    );
}
