//! Sharded serving tier: a front-door router over N poll-core workers.
//!
//! One [`FrontDoor`] process owns the public TCP endpoint and speaks the
//! exact BSRQ/BSRS wire protocol of [`crate::server`]; behind it sits a
//! [`Fleet`] of workers, each a full `bsa serve` instance (batching
//! [`Router`](crate::coordinator::serve::Router) + poll core) with its
//! own [`NativeBackend`](crate::backend::native::NativeBackend) replica
//! and [`BallTreeCache`](crate::balltree::BallTreeCache). Requests are
//! routed by **geometry affinity**: the shard key is the ball-tree
//! content hash of the request's coordinate bytes, placed by rendezvous
//! hashing ([`placement`]), so repeat geometries keep landing on the
//! worker whose tree cache is already warm.
//!
//! Layer map:
//!
//! * [`placement`] — pure routing math (rendezvous scores, spill,
//!   saturation); no I/O, property-tested.
//! * [`worker`] — fleet state: per-worker slots, connection pools, the
//!   health prober (periodic BSST probes, epoch-based restart
//!   detection), respawn with bounded exponential backoff.
//! * [`frontdoor`] — the router process: accept loop, frame forwarding,
//!   shed propagation, graceful drain (docs/FORMATS.md §3).
//! * [`loadgen`] — open-loop load generator + BENCH_serve.json `shard`
//!   section writer.
//!
//! Everything here is std-only, matching the rest of the crate: raw
//! `TcpStream`s, `Arc` + atomics, no async runtime.

pub mod frontdoor;
pub mod loadgen;
pub mod placement;
pub mod worker;

pub use frontdoor::FrontDoor;
pub use loadgen::{arrival_schedule, Arrival, LoadgenOpts, LoadgenReport};
pub use placement::{affine_worker, place, rendezvous_score, Candidate, Placement};
pub use worker::{Fleet, ProbeReport, WorkerSlot};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Test-only fault-injection hook, threaded through the fleet and front
/// door. In production every field stays in its default (inert) state;
/// the chaos suite (`tests/shard_chaos.rs`) arms it to schedule worker
/// kills mid-pipeline, stall health probes past their deadline, and
/// force shed storms — all without reaching into the router's internals,
/// so the code under test is exactly the code that ships.
#[derive(Default)]
pub struct FaultPlan {
    /// `(worker_id, after_forwarded)`: hard-kill `worker_id` once the
    /// front door has forwarded `after_forwarded` frames. Fires once.
    kill_after: Mutex<Option<(usize, u64)>>,
    /// Extra sleep injected into every prober cycle, in ms. Used to
    /// push a probe past `probe_timeout_ms` and prove the miss counter
    /// marks the worker down.
    probe_delay_ms: AtomicU64,
    /// Number of upcoming requests the front door must shed (status 3)
    /// without forwarding — a synthetic shed storm.
    shed_next: AtomicU64,
}

impl FaultPlan {
    /// Arm a one-shot kill of `worker` after `after` forwarded frames.
    pub fn kill_worker_after(&self, worker: usize, after: u64) {
        *self.kill_after.lock().unwrap() = Some((worker, after));
    }

    /// Consume the kill order if `forwarded_total` has reached it.
    pub(crate) fn kill_due(&self, forwarded_total: u64) -> Option<usize> {
        let mut slot = self.kill_after.lock().unwrap();
        match *slot {
            Some((worker, after)) if forwarded_total >= after => {
                *slot = None;
                Some(worker)
            }
            _ => None,
        }
    }

    /// Stall every subsequent prober cycle by `ms` milliseconds.
    pub fn delay_probes_ms(&self, ms: u64) {
        self.probe_delay_ms.store(ms, Ordering::Relaxed);
    }

    pub(crate) fn probe_delay(&self) -> u64 {
        self.probe_delay_ms.load(Ordering::Relaxed)
    }

    /// Shed the next `n` requests at the front door without forwarding.
    pub fn shed_storm(&self, n: u64) {
        self.shed_next.store(n, Ordering::Relaxed);
    }

    /// True when this request is claimed by an armed shed storm.
    pub(crate) fn take_shed(&self) -> bool {
        self.shed_next
            .fetch_update(Ordering::Relaxed, Ordering::Relaxed, |n| n.checked_sub(1))
            .is_ok()
    }
}
