//! Geometry-affinity placement: rendezvous (highest-random-weight)
//! hashing over the ball-tree content hash.
//!
//! The shard key is [`content_hash`](crate::balltree::content_hash) of a
//! request's coordinates — the same value the per-worker
//! [`BallTreeCache`](crate::balltree::BallTreeCache) keys on — so the
//! worker a geometry rendezvous-hashes to is exactly the worker whose
//! cache already holds its tree. Rendezvous hashing gives the two
//! properties the fleet needs with no coordination state at all:
//!
//! * **determinism** — placement is a pure function of (key, live set),
//!   so every front-door restart or concurrent decision agrees;
//! * **minimal disruption** — when a worker dies, only the keys whose
//!   argmax *was* that worker move (~1/N of them); everyone else keeps
//!   their warm cache.
//!
//! Saturation is handled one layer up: when the affine worker's
//! in-flight count is at the spill threshold, the request spills to the
//! least-loaded live worker ([`place`] returns the spill target) rather
//! than queueing unboundedly behind a hot shard. All three properties
//! are pinned by proptest-style checks at the bottom of this file.

/// One worker as the placement function sees it: identity plus the load
/// signals routing needs. Built per-decision by the front door from the
/// fleet's atomics (cheap: a couple of relaxed loads per worker).
#[derive(Debug, Clone, Copy)]
pub struct Candidate {
    /// Stable worker index (slot position in the fleet, not a
    /// generation counter — a respawned worker keeps its id so its keys
    /// come home after recovery).
    pub id: usize,
    /// Healthy and accepting traffic (up, not draining).
    pub live: bool,
    /// Requests currently forwarded to this worker and not yet
    /// answered.
    pub inflight: usize,
}

/// Where a key goes, and why.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Placement {
    /// The rendezvous-affine worker is live and has capacity.
    Affine(usize),
    /// The affine worker is saturated; the request spills to the
    /// least-loaded live worker (`chosen != affine`).
    Spill { affine: usize, chosen: usize },
    /// Every live worker is at or over the spill threshold — the caller
    /// should shed (status 3) rather than queue unboundedly.
    Saturated { affine: usize },
    /// No live worker at all.
    NoWorker,
}

impl Placement {
    /// The worker the request should be forwarded to, if any.
    pub fn target(&self) -> Option<usize> {
        match *self {
            Placement::Affine(id) => Some(id),
            Placement::Spill { chosen, .. } => Some(chosen),
            Placement::Saturated { .. } | Placement::NoWorker => None,
        }
    }

    /// True when the chosen target is the key's rendezvous-affine
    /// worker (the tree-cache-warm path).
    pub fn is_affine(&self) -> bool {
        matches!(self, Placement::Affine(_))
    }
}

/// Rendezvous weight of `worker` for `key`: a splitmix64-style mix of
/// the two, so each (key, worker) pair draws an independent-looking
/// 64-bit weight and the per-key argmax is uniform over workers.
pub fn rendezvous_score(key: u64, worker: u64) -> u64 {
    // Distinct odd multipliers keep (key, worker) and (worker, key)
    // from colliding; the finisher is the same splitmix64 mix the
    // content hash uses.
    let mut h = key
        .wrapping_mul(0x9e3779b97f4a7c15)
        .wrapping_add(worker.wrapping_mul(0xd1b54a32d192ed03))
        ^ 0x2545f4914f6cdd1d;
    h ^= h >> 30;
    h = h.wrapping_mul(0xbf58476d1ce4e5b9);
    h ^= h >> 27;
    h = h.wrapping_mul(0x94d049bb133111eb);
    h ^ (h >> 31)
}

/// The rendezvous-affine worker for `key` among the live candidates:
/// argmax of [`rendezvous_score`], ties broken toward the lower id
/// (ties are a 2^-64 event; the break just keeps the function total).
pub fn affine_worker(key: u64, candidates: &[Candidate]) -> Option<usize> {
    candidates
        .iter()
        .filter(|c| c.live)
        .max_by_key(|c| (rendezvous_score(key, c.id as u64), std::cmp::Reverse(c.id)))
        .map(|c| c.id)
}

/// Full placement decision for one request: affine worker if it has
/// capacity, spill to the least-loaded live worker when it is at or
/// over `spill_inflight`, shed when every live worker is saturated.
pub fn place(key: u64, candidates: &[Candidate], spill_inflight: usize) -> Placement {
    let Some(affine) = affine_worker(key, candidates) else {
        return Placement::NoWorker;
    };
    let spill_at = spill_inflight.max(1);
    let affine_load =
        candidates.iter().find(|c| c.id == affine).map(|c| c.inflight).unwrap_or(0);
    if affine_load < spill_at {
        return Placement::Affine(affine);
    }
    // Saturated affine worker: least-loaded live alternative (lowest id
    // on ties, for determinism). The affine worker itself stays in the
    // running — if it is still the least loaded there is nowhere better
    // to spill, and the key at least lands on its warm cache.
    let chosen = candidates
        .iter()
        .filter(|c| c.live)
        .min_by_key(|c| (c.inflight, c.id))
        .map(|c| (c.id, c.inflight))
        .expect("affine_worker returned Some, so a live candidate exists");
    if chosen.1 >= spill_at {
        Placement::Saturated { affine }
    } else if chosen.0 == affine {
        Placement::Affine(affine)
    } else {
        Placement::Spill { affine, chosen: chosen.0 }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::proptest_lite::forall;

    fn live(n: usize) -> Vec<Candidate> {
        (0..n).map(|id| Candidate { id, live: true, inflight: 0 }).collect()
    }

    // -- proptest-style placement properties (ISSUE 9 satellite) --------

    #[test]
    fn prop_rendezvous_is_deterministic() {
        forall(200, |g| {
            let n = g.usize_in(1..9);
            let key = g.u64();
            let c = live(n);
            let a = affine_worker(key, &c);
            let b = affine_worker(key, &c);
            assert_eq!(a, b, "same (key, live set) must place identically");
            // order of the candidate slice must not matter
            let mut rev = c.clone();
            rev.reverse();
            assert_eq!(a, affine_worker(key, &rev), "candidate order must not matter");
        });
    }

    #[test]
    fn prop_balanced_within_20pct_over_10k_keys() {
        // 10k random content hashes over N workers: every worker's share
        // stays within ±20% of 10k/N. Run for several fleet sizes.
        for n in [2usize, 3, 5, 8] {
            let mut counts = vec![0usize; n];
            let mut rng = crate::prng::Rng::new(0xB5A_5EED ^ n as u64);
            let c = live(n);
            for _ in 0..10_000 {
                let id = affine_worker(rng.next_u64(), &c).unwrap();
                counts[id] += 1;
            }
            let expect = 10_000.0 / n as f64;
            for (id, &got) in counts.iter().enumerate() {
                let dev = (got as f64 - expect).abs() / expect;
                assert!(
                    dev <= 0.20,
                    "worker {id}/{n} got {got} keys, expected ~{expect:.0} (dev {:.1}%)",
                    dev * 100.0
                );
            }
        }
    }

    #[test]
    fn prop_removal_remaps_about_one_nth() {
        // Removing one of N workers must move only the keys that were on
        // it (~1/N), and every surviving key must stay put.
        forall(8, |g| {
            let n = g.usize_in(2..7);
            let victim = g.usize_in(0..n);
            let full = live(n);
            let mut reduced = full.clone();
            reduced[victim].live = false;
            let keys = 4_000usize;
            let mut moved = 0usize;
            for _ in 0..keys {
                let key = g.u64();
                let before = affine_worker(key, &full).unwrap();
                let after = affine_worker(key, &reduced).unwrap();
                if before == victim {
                    moved += 1;
                    assert_ne!(after, victim, "keys must leave the dead worker");
                } else {
                    assert_eq!(before, after, "survivor keys must not move");
                }
            }
            // The moved fraction is binomial(keys, 1/n): allow a wide
            // ±50% relative band so the property, not the noise, fails.
            let expect = keys as f64 / n as f64;
            let dev = (moved as f64 - expect).abs() / expect;
            assert!(
                dev <= 0.5,
                "removing 1 of {n} moved {moved} of {keys} keys (expected ~{expect:.0})"
            );
        });
    }

    // -- spill behaviour -------------------------------------------------

    #[test]
    fn spills_to_least_loaded_when_affine_saturated() {
        let key = 42u64;
        let mut c = live(3);
        let affine = affine_worker(key, &c).unwrap();
        assert_eq!(place(key, &c, 4), Placement::Affine(affine));
        // saturate the affine worker; the others are idle
        c[affine].inflight = 4;
        match place(key, &c, 4) {
            Placement::Spill { affine: a, chosen } => {
                assert_eq!(a, affine);
                assert_ne!(chosen, affine);
                assert_eq!(chosen, c.iter().filter(|x| x.id != affine).map(|x| x.id).min().unwrap());
            }
            other => panic!("expected spill, got {other:?}"),
        }
        // everyone saturated -> shed signal
        for w in c.iter_mut() {
            w.inflight = 9;
        }
        assert_eq!(place(key, &c, 4), Placement::Saturated { affine });
        // no live worker at all
        for w in c.iter_mut() {
            w.live = false;
        }
        assert_eq!(place(key, &c, 4), Placement::NoWorker);
    }

    #[test]
    fn saturated_affine_that_is_still_least_loaded_keeps_the_key() {
        let key = 7u64;
        let mut c = live(2);
        let affine = affine_worker(key, &c).unwrap();
        let other = 1 - affine;
        // both over the threshold, affine less loaded: Saturated (shed),
        // never a spill onto a *more* loaded worker
        c[affine].inflight = 5;
        c[other].inflight = 8;
        assert_eq!(place(key, &c, 4), Placement::Saturated { affine });
        // affine at threshold but other below it: spill
        c[other].inflight = 1;
        assert_eq!(place(key, &c, 4), Placement::Spill { affine, chosen: other });
    }

    #[test]
    fn dead_affine_falls_through_to_survivors() {
        forall(100, |g| {
            let key = g.u64();
            let mut c = live(4);
            let first = affine_worker(key, &c).unwrap();
            c[first].live = false;
            let second = affine_worker(key, &c).unwrap();
            assert_ne!(first, second);
            assert!(place(key, &c, 8).target() == Some(second));
        });
    }
}
