//! Open-loop load generator for the serving tier, plus the
//! BENCH_serve.json `shard` section writer.
//!
//! **Open loop**: request arrival times are fixed up front from the
//! offered rate and never adjust to observed latency — if the server
//! falls behind, lateness shows up as latency instead of silently
//! throttling the offered load (the classic closed-loop coordinated-
//! omission trap). Latency is therefore measured from each request's
//! *scheduled* arrival, not from when the socket write happened.
//!
//! Traffic shape: `geoms` distinct geometries drawn Zipf-style
//! (weight of geometry `i` is `1/(i+1)^s`), so a few geometries are hot
//! — exactly the regime where the front door's tree-cache affinity
//! routing pays off — with a long cold tail. The whole schedule is a
//! pure function of the seed: same seed, same arrivals, same geometry
//! sequence (pinned by a unit test below).

use std::io::Write as _;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use anyhow::Context;

use crate::data::generator_for;
use crate::prng::Rng;
use crate::server::{Client, ShedError};
use crate::tensor::Tensor;
use crate::trace;

/// One scheduled request: when (µs after start) and which geometry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Arrival {
    pub offset_us: u64,
    pub geom: usize,
}

/// The full open-loop schedule: `floor(rate · duration)` arrivals at
/// fixed `1/rate` spacing, geometries drawn Zipf-style with exponent
/// `zipf_s`. Deterministic in `seed`.
pub fn arrival_schedule(
    seed: u64,
    rate_per_s: f64,
    duration_ms: u64,
    geoms: usize,
    zipf_s: f64,
) -> Vec<Arrival> {
    if rate_per_s <= 0.0 || duration_ms == 0 || geoms == 0 {
        return Vec::new();
    }
    let count = (rate_per_s * duration_ms as f64 / 1000.0).floor() as usize;
    let gap_us = 1e6 / rate_per_s;
    // Zipf-ish weights 1/(i+1)^s, sampled by inverse CDF.
    let weights: Vec<f64> = (0..geoms).map(|i| 1.0 / ((i + 1) as f64).powf(zipf_s)).collect();
    let total: f64 = weights.iter().sum();
    let mut rng = Rng::new(seed);
    (0..count)
        .map(|i| {
            let mut u = rng.uniform() as f64 * total;
            let mut geom = geoms - 1;
            for (g, w) in weights.iter().enumerate() {
                if u < *w {
                    geom = g;
                    break;
                }
                u -= w;
            }
            Arrival { offset_us: (i as f64 * gap_us) as u64, geom }
        })
        .collect()
}

/// Knobs for one loadgen run (CLI flags map 1:1 onto these).
#[derive(Debug, Clone)]
pub struct LoadgenOpts {
    /// Front door (or single server) address.
    pub addr: String,
    pub rate_per_s: f64,
    pub duration_ms: u64,
    /// Distinct geometries in the traffic mix.
    pub geoms: usize,
    /// Client connections; arrivals are dealt round-robin across them.
    pub conns: usize,
    /// Zipf exponent for the geometry mix (0 = uniform).
    pub zipf_s: f64,
    /// Dataset task for geometry synthesis ("syn", "air", "ela").
    pub task: String,
    /// Points per geometry.
    pub points: usize,
    pub seed: u64,
}

impl Default for LoadgenOpts {
    fn default() -> Self {
        LoadgenOpts {
            addr: "127.0.0.1:7070".into(),
            rate_per_s: 50.0,
            duration_ms: 10_000,
            geoms: 8,
            conns: 4,
            zipf_s: 1.0,
            task: "syn".into(),
            points: 256,
            seed: 0,
        }
    }
}

/// Per-worker cache view scraped from the front door's BSST reply after
/// the run (or from a single server's flat counters).
#[derive(Debug, Clone)]
pub struct WorkerCache {
    pub id: u64,
    pub tree_hits: u64,
    pub tree_misses: u64,
    pub hit_ratio: f64,
}

/// Everything one loadgen run measured.
#[derive(Debug, Clone)]
pub struct LoadgenReport {
    pub offered_per_s: f64,
    pub achieved_per_s: f64,
    pub requests: usize,
    pub geometries: usize,
    pub ok: u64,
    pub shed: u64,
    pub errors: u64,
    pub shed_rate: f64,
    pub p50_us: u64,
    pub p95_us: u64,
    pub p99_us: u64,
    pub workers: Vec<WorkerCache>,
}

/// Run the open-loop generator against `opts.addr`. Every scheduled
/// arrival is accounted for exactly once — ok, shed, or error — so a
/// dropped request is a visible number, never silence.
pub fn run(opts: &LoadgenOpts) -> anyhow::Result<LoadgenReport> {
    let schedule = arrival_schedule(
        opts.seed,
        opts.rate_per_s,
        opts.duration_ms,
        opts.geoms,
        opts.zipf_s,
    );
    anyhow::ensure!(!schedule.is_empty(), "empty schedule (rate/duration/geoms all > 0?)");
    anyhow::ensure!(opts.conns > 0, "need at least one connection");
    let gen = generator_for(&opts.task, opts.seed)?;
    let samples: Vec<(Tensor, Tensor)> = (0..opts.geoms)
        .map(|g| {
            let s = gen.generate(g as u64, opts.points);
            (s.coords, s.features)
        })
        .collect();
    let samples = Arc::new(samples);

    let ok = Arc::new(AtomicU64::new(0));
    let shed = Arc::new(AtomicU64::new(0));
    let errors = Arc::new(AtomicU64::new(0));
    // Start a beat in the future so every sender thread is up before
    // the first scheduled arrival.
    let t0 = Instant::now() + Duration::from_millis(50);
    let mut lanes: Vec<Vec<Arrival>> = vec![Vec::new(); opts.conns];
    for (i, a) in schedule.iter().enumerate() {
        lanes[i % opts.conns].push(*a);
    }
    let started = Instant::now();
    let mut threads = Vec::new();
    for lane in lanes {
        let addr = opts.addr.clone();
        let samples = Arc::clone(&samples);
        let (ok, shed, errors) = (Arc::clone(&ok), Arc::clone(&shed), Arc::clone(&errors));
        threads.push(std::thread::spawn(move || {
            let mut lat_us: Vec<u64> = Vec::with_capacity(lane.len());
            let mut client: Option<Client> = Client::connect(&addr).ok();
            for a in lane {
                let due = t0 + Duration::from_micros(a.offset_us);
                let now = Instant::now();
                if due > now {
                    std::thread::sleep(due - now);
                }
                if client.is_none() {
                    client = Client::connect(&addr).ok();
                }
                let Some(c) = client.as_mut() else {
                    errors.fetch_add(1, Ordering::Relaxed);
                    continue;
                };
                let (coords, feats) = &samples[a.geom];
                match c.predict(coords, feats) {
                    Ok(_) => {
                        ok.fetch_add(1, Ordering::Relaxed);
                        // Open loop: latency from the *scheduled* time.
                        lat_us.push(due.elapsed().as_micros() as u64);
                    }
                    Err(e) if e.downcast_ref::<ShedError>().is_some() => {
                        shed.fetch_add(1, Ordering::Relaxed);
                    }
                    Err(_) => {
                        errors.fetch_add(1, Ordering::Relaxed);
                        // Transport fault: reconnect before the next
                        // arrival (worker churn must not wedge a lane).
                        client = None;
                    }
                }
            }
            lat_us
        }));
    }
    let mut lat_us: Vec<u64> = Vec::with_capacity(schedule.len());
    for t in threads {
        lat_us.extend(t.join().expect("loadgen sender thread panicked"));
    }
    let wall_s = started.elapsed().as_secs_f64().max(1e-9);
    lat_us.sort_unstable();
    let pct = |q: f64| -> u64 {
        if lat_us.is_empty() {
            return 0;
        }
        lat_us[((lat_us.len() - 1) as f64 * q).round() as usize]
    };
    let (ok, shed, errors) = (
        ok.load(Ordering::Relaxed),
        shed.load(Ordering::Relaxed),
        errors.load(Ordering::Relaxed),
    );
    let total = schedule.len() as u64;
    debug_assert_eq!(ok + shed + errors, total, "every arrival must be accounted for");
    Ok(LoadgenReport {
        offered_per_s: opts.rate_per_s,
        achieved_per_s: ok as f64 / wall_s,
        requests: schedule.len(),
        geometries: opts.geoms,
        ok,
        shed,
        errors,
        shed_rate: shed as f64 / total.max(1) as f64,
        p50_us: pct(0.50),
        p95_us: pct(0.95),
        p99_us: pct(0.99),
        workers: scrape_workers(&opts.addr),
    })
}

/// Post-run BSST scrape: per-worker cache stats from a front door's
/// `workers` array (docs/FORMATS.md §3.3), or the flat counters of a
/// single server as a one-element fleet.
fn scrape_workers(addr: &str) -> Vec<WorkerCache> {
    let Ok(mut c) = Client::connect(addr) else { return Vec::new() };
    let Ok(text) = c.stats() else { return Vec::new() };
    let Ok(json) = trace::parse_json(&text) else { return Vec::new() };
    let num = |j: &trace::Json, key: &str| j.get(key).and_then(|v| v.as_f64()).unwrap_or(0.0);
    let cache = |id: u64, hits: f64, misses: f64| WorkerCache {
        id,
        tree_hits: hits as u64,
        tree_misses: misses as u64,
        hit_ratio: hits / (hits + misses).max(1.0),
    };
    match json.get("workers") {
        Some(trace::Json::Arr(ws)) => ws
            .iter()
            .map(|w| cache(num(w, "id") as u64, num(w, "tree_hits"), num(w, "tree_misses")))
            .collect(),
        _ => vec![cache(0, num(&json, "tree_hits"), num(&json, "tree_misses"))],
    }
}

impl LoadgenReport {
    /// Compact JSON object for the `shard` section of BENCH_serve.json.
    /// `requests`/`geometries` are run descriptors (benchdiff skip
    /// keys); metric keys carry their direction in the suffix
    /// (`_us`/`_per_s`/`shed_rate`/`hit_ratio`).
    pub fn to_json(&self) -> String {
        use std::fmt::Write as _;
        let mut workers = String::new();
        for (i, w) in self.workers.iter().enumerate() {
            if i > 0 {
                workers.push_str(", ");
            }
            write!(
                workers,
                "\"w{}\": {{\"tree_hits\": {}, \"tree_misses\": {}, \"hit_ratio\": {:.4}}}",
                w.id, w.tree_hits, w.tree_misses, w.hit_ratio
            )
            .expect("writing to String cannot fail");
        }
        format!(
            "{{\"requests\": {}, \"geometries\": {}, \"offered_per_s\": {:.2}, \
             \"achieved_per_s\": {:.2}, \"ok\": {}, \"shed\": {}, \"errors\": {}, \
             \"shed_rate\": {:.4}, \"p50_us\": {}, \"p95_us\": {}, \"p99_us\": {}, \
             \"workers\": {{{}}}}}",
            self.requests,
            self.geometries,
            self.offered_per_s,
            self.achieved_per_s,
            self.ok,
            self.shed,
            self.errors,
            self.shed_rate,
            self.p50_us,
            self.p95_us,
            self.p99_us,
            workers,
        )
    }

    /// Human-readable summary for the terminal.
    pub fn print(&self) {
        println!(
            "loadgen: offered {:.1}/s achieved {:.1}/s over {} requests ({} geometries)",
            self.offered_per_s, self.achieved_per_s, self.requests, self.geometries
        );
        println!(
            "  ok {}  shed {} ({:.1}%)  errors {}",
            self.ok,
            self.shed,
            self.shed_rate * 100.0,
            self.errors
        );
        println!(
            "  latency from scheduled arrival: p50 {} us  p95 {} us  p99 {} us",
            self.p50_us, self.p95_us, self.p99_us
        );
        for w in &self.workers {
            println!(
                "  worker {}: tree_hits {} tree_misses {} (hit ratio {:.1}%)",
                w.id,
                w.tree_hits,
                w.tree_misses,
                w.hit_ratio * 100.0
            );
        }
    }
}

// ---------------------------------------------------------------------------
// BENCH_serve.json section splicing
// ---------------------------------------------------------------------------

/// Byte span of the JSON value starting at `start` in `doc`: either the
/// literal `null` or a brace-balanced object (string-aware). `None` if
/// neither parses.
fn value_span(doc: &str, start: usize) -> Option<std::ops::Range<usize>> {
    let bytes = doc.as_bytes();
    if doc[start..].starts_with("null") {
        return Some(start..start + 4);
    }
    if bytes.get(start) != Some(&b'{') {
        return None;
    }
    let mut depth = 0usize;
    let mut in_str = false;
    let mut escaped = false;
    for (i, &b) in bytes.iter().enumerate().skip(start) {
        if in_str {
            if escaped {
                escaped = false;
            } else if b == b'\\' {
                escaped = true;
            } else if b == b'"' {
                in_str = false;
            }
            continue;
        }
        match b {
            b'"' => in_str = true,
            b'{' => depth += 1,
            b'}' => {
                depth -= 1;
                if depth == 0 {
                    return Some(start..i + 1);
                }
            }
            _ => {}
        }
    }
    None
}

/// Locate the top-level `"key": <value>` span in `doc` (the value's
/// byte range), if present.
fn section_span(doc: &str, key: &str) -> Option<std::ops::Range<usize>> {
    let needle = format!("\"{key}\":");
    let at = doc.find(&needle)?;
    let mut start = at + needle.len();
    let bytes = doc.as_bytes();
    while start < bytes.len() && (bytes[start] as char).is_whitespace() {
        start += 1;
    }
    value_span(doc, start)
}

/// The raw text of the top-level `"key"` section of a bench doc.
pub fn extract_section(doc: &str, key: &str) -> Option<String> {
    section_span(doc, key).map(|r| doc[r].to_string())
}

/// Splice `fragment` in as the top-level `"key"` section: replaces an
/// existing value (object or `null` placeholder), else inserts before
/// the document's final `}`. Pure text surgery so the rest of the doc —
/// whoever wrote it — is preserved byte-for-byte.
pub fn merge_section(doc: &str, key: &str, fragment: &str) -> String {
    if let Some(span) = section_span(doc, key) {
        let mut out = String::with_capacity(doc.len() + fragment.len());
        out.push_str(&doc[..span.start]);
        out.push_str(fragment);
        out.push_str(&doc[span.end..]);
        return out;
    }
    match doc.rfind('}') {
        Some(close) => {
            let mut out = String::with_capacity(doc.len() + fragment.len() + key.len() + 8);
            out.push_str(doc[..close].trim_end());
            out.push_str(&format!(",\n  \"{key}\": {fragment}\n"));
            out.push_str(&doc[close..]);
            out
        }
        None => format!("{{\n  \"{key}\": {fragment}\n}}\n"),
    }
}

/// Merge the report into BENCH_serve.json next to ROADMAP.md (the same
/// auto-detection the bench runner uses: repo root or `rust/`). Returns
/// the path written, or `None` when no repo root was found (the report
/// is print-only then).
pub fn write_bench_section(report: &LoadgenReport) -> anyhow::Result<Option<String>> {
    let path = if std::path::Path::new("ROADMAP.md").exists() {
        "BENCH_serve.json"
    } else if std::path::Path::new("../ROADMAP.md").exists() {
        "../BENCH_serve.json"
    } else {
        return Ok(None);
    };
    let existing = std::fs::read_to_string(path).unwrap_or_else(|_| "{\n}\n".to_string());
    let merged = merge_section(&existing, "shard", &report.to_json());
    let mut f = std::fs::File::create(path).with_context(|| format!("writing {path}"))?;
    f.write_all(merged.as_bytes())?;
    Ok(Some(path.to_string()))
}

#[cfg(test)]
mod tests {
    use super::*;

    // -- schedule determinism (ISSUE 9 satellite) -----------------------

    #[test]
    fn same_seed_same_schedule() {
        let a = arrival_schedule(7, 200.0, 2_000, 8, 1.0);
        let b = arrival_schedule(7, 200.0, 2_000, 8, 1.0);
        assert_eq!(a, b, "schedule must be a pure function of the seed");
        assert_eq!(a.len(), 400);
        let c = arrival_schedule(8, 200.0, 2_000, 8, 1.0);
        assert_ne!(
            a.iter().map(|x| x.geom).collect::<Vec<_>>(),
            c.iter().map(|x| x.geom).collect::<Vec<_>>(),
            "a different seed must draw a different geometry sequence"
        );
    }

    #[test]
    fn schedule_is_open_loop_fixed_spacing() {
        let s = arrival_schedule(0, 1000.0, 100, 4, 1.0);
        assert_eq!(s.len(), 100);
        for w in s.windows(2) {
            assert_eq!(w[1].offset_us - w[0].offset_us, 1000, "1 kHz = 1000 us spacing");
        }
        assert!(s.iter().all(|a| a.geom < 4));
    }

    #[test]
    fn zipf_mix_skews_hot() {
        let s = arrival_schedule(3, 500.0, 4_000, 8, 1.0);
        let mut counts = [0usize; 8];
        for a in &s {
            counts[a.geom] += 1;
        }
        assert!(
            counts[0] > counts[7] * 2,
            "geometry 0 must be much hotter than the tail: {counts:?}"
        );
        assert!(counts.iter().all(|&c| c > 0), "every geometry appears: {counts:?}");
    }

    #[test]
    fn degenerate_schedules_are_empty() {
        assert!(arrival_schedule(0, 0.0, 1000, 4, 1.0).is_empty());
        assert!(arrival_schedule(0, 100.0, 0, 4, 1.0).is_empty());
        assert!(arrival_schedule(0, 100.0, 1000, 0, 1.0).is_empty());
    }

    // -- section splicing -----------------------------------------------

    const DOC: &str = "{\n  \"bench\": \"serve_hot_path\",\n  \"reps\": 3,\n  \
                       \"e2e\": {\"p50_us\": 10, \"tag\": \"a}b\"}\n}\n";

    #[test]
    fn merge_inserts_when_absent() {
        let out = merge_section(DOC, "shard", "{\"shed_rate\": 0.1}");
        assert_eq!(extract_section(&out, "shard").unwrap(), "{\"shed_rate\": 0.1}");
        // the rest of the doc is untouched
        assert_eq!(extract_section(&out, "e2e"), extract_section(DOC, "e2e"));
        assert!(out.contains("\"bench\": \"serve_hot_path\""));
    }

    #[test]
    fn merge_replaces_existing_and_null() {
        let with_null = merge_section(DOC, "shard", "null");
        assert_eq!(extract_section(&with_null, "shard").unwrap(), "null");
        let filled = merge_section(&with_null, "shard", "{\"p99_us\": 42}");
        assert_eq!(extract_section(&filled, "shard").unwrap(), "{\"p99_us\": 42}");
        let refilled = merge_section(&filled, "shard", "{\"p99_us\": 43}");
        assert_eq!(extract_section(&refilled, "shard").unwrap(), "{\"p99_us\": 43}");
        assert_eq!(refilled.matches("\"shard\"").count(), 1, "no duplicate sections");
    }

    #[test]
    fn brace_matching_ignores_braces_inside_strings() {
        // `e2e` contains a string with a `}` in it; the span must still
        // cover the whole object.
        assert_eq!(
            extract_section(DOC, "e2e").unwrap(),
            "{\"p50_us\": 10, \"tag\": \"a}b\"}"
        );
    }

    #[test]
    fn report_json_is_parseable_and_merge_roundtrips() {
        let report = LoadgenReport {
            offered_per_s: 100.0,
            achieved_per_s: 98.5,
            requests: 200,
            geometries: 8,
            ok: 190,
            shed: 8,
            errors: 2,
            shed_rate: 0.04,
            p50_us: 900,
            p95_us: 2100,
            p99_us: 4000,
            workers: vec![
                WorkerCache { id: 0, tree_hits: 90, tree_misses: 4, hit_ratio: 90.0 / 94.0 },
                WorkerCache { id: 1, tree_hits: 88, tree_misses: 4, hit_ratio: 88.0 / 92.0 },
            ],
        };
        let json = report.to_json();
        let parsed = trace::parse_json(&json).expect("report JSON must parse");
        assert_eq!(parsed.get("ok").and_then(|v| v.as_f64()), Some(190.0));
        assert!(parsed.get("workers").and_then(|w| w.get("w1")).is_some());
        let merged = merge_section(DOC, "shard", &json);
        let back = extract_section(&merged, "shard").unwrap();
        assert_eq!(back, json, "splice must preserve the fragment byte-for-byte");
        let reparsed = trace::parse_json(&merged).expect("merged doc must still be JSON");
        assert_eq!(
            reparsed.get("shard").and_then(|s| s.get("shed_rate")).and_then(|v| v.as_f64()),
            Some(0.04)
        );
    }
}
