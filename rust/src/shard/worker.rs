//! Fleet state: per-worker slots, connection pools, the health prober,
//! and respawn with bounded exponential backoff.
//!
//! A [`Fleet`] owns N [`WorkerSlot`]s. Each slot tracks one worker —
//! either *attached* (an externally managed server, e.g. an in-process
//! poll core in the chaos tests) or *spawned* (a child `bsa serve`
//! process this fleet started and must also reap). All hot-path state is
//! atomics so the front door's placement snapshot is a handful of
//! relaxed loads; the only locks are the per-worker idle-connection pool
//! and the spawn recipe, neither of which is touched per-request once a
//! pooled connection exists.
//!
//! Health model (docs/FORMATS.md §3.2): the prober thread sends a BSST
//! stats probe to every worker each `probe_interval_ms`. A worker that
//! fails `probe_misses` consecutive probes is marked down, its pooled
//! connections are severed, and — if spawned — it is respawned with
//! exponential backoff (`backoff_ms` doubling up to `max_backoff_ms`,
//! at most `respawn_max` attempts per outage). Restarts are detected
//! from the probe payload itself: the router `epoch` changing, or
//! `uptime_ms` moving backwards (a fresh process restarts both).

use std::io::{Read, Write};
use std::net::{TcpStream, ToSocketAddrs};
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Context};

use crate::config::ShardConfig;
use crate::server::{read_u32, RESP_MAGIC, STATS_MAGIC, STATUS_STATS};
use crate::shard::placement::Candidate;
use crate::shard::FaultPlan;
use crate::trace;

/// Idle connections kept per worker; more are opened on demand and the
/// excess is dropped at check-in.
const POOL_CAP: usize = 8;

/// What one successful BSST probe told us about a worker.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ProbeReport {
    /// Router incarnation: an entropy-seeded per-process counter in the
    /// worker, so a fresh process (almost surely) never repeats its
    /// predecessor's epochs and any change means a restart.
    pub epoch: u64,
    /// Milliseconds since the worker's router started. A respawned
    /// process reports a smaller value than before — the backup restart
    /// signal for the astronomically unlikely cross-process epoch
    /// collision.
    pub uptime_ms: u64,
    /// Requests the worker has served.
    pub served: u64,
    /// Ball-tree cache hits / misses — the affinity signal the loadgen
    /// report aggregates per worker.
    pub tree_hits: u64,
    pub tree_misses: u64,
}

/// How the fleet controls a worker's lifecycle.
enum Kind {
    /// Externally managed (tests attach in-process servers; ops can
    /// attach already-running `bsa serve` instances). The fleet probes
    /// and routes but never spawns or signals it.
    Attached,
    /// A child process this fleet spawned and respawns on death.
    Spawned { argv: Vec<String>, child: Option<Child> },
}

/// One worker as the fleet tracks it. All counters are relaxed atomics:
/// they are health/routing signals, not synchronization.
pub struct WorkerSlot {
    /// Stable slot index — survives respawn, so rendezvous placement
    /// brings a recovered worker's keys back home.
    pub id: usize,
    pub addr: String,
    kind: Mutex<Kind>,
    up: AtomicBool,
    inflight: AtomicUsize,
    /// Consecutive failed probes (reset on any success).
    misses: AtomicUsize,
    /// Revival attempts since the worker went down (reset on recovery).
    retries: AtomicUsize,
    backoff_ms: AtomicU64,
    /// Earliest next revival attempt, in ms since fleet start.
    next_attempt_ms: AtomicU64,
    /// Last seen router epoch (0 = never probed).
    epoch: AtomicU64,
    uptime_ms: AtomicU64,
    /// Restarts detected via epoch change or uptime regression.
    restarts: AtomicU64,
    served: AtomicU64,
    tree_hits: AtomicU64,
    tree_misses: AtomicU64,
    pool: Mutex<Vec<TcpStream>>,
}

impl WorkerSlot {
    fn new(id: usize, addr: String, kind: Kind, cfg: &ShardConfig) -> WorkerSlot {
        WorkerSlot {
            id,
            addr,
            kind: Mutex::new(kind),
            // Optimistic: the first probe (or first forward failure)
            // corrects this within one probe interval.
            up: AtomicBool::new(true),
            inflight: AtomicUsize::new(0),
            misses: AtomicUsize::new(0),
            retries: AtomicUsize::new(0),
            backoff_ms: AtomicU64::new(cfg.backoff_ms),
            next_attempt_ms: AtomicU64::new(0),
            epoch: AtomicU64::new(0),
            uptime_ms: AtomicU64::new(0),
            restarts: AtomicU64::new(0),
            served: AtomicU64::new(0),
            tree_hits: AtomicU64::new(0),
            tree_misses: AtomicU64::new(0),
            pool: Mutex::new(Vec::new()),
        }
    }

    pub fn is_up(&self) -> bool {
        self.up.load(Ordering::Relaxed)
    }

    pub fn inflight(&self) -> usize {
        self.inflight.load(Ordering::Relaxed)
    }

    pub fn restarts(&self) -> u64 {
        self.restarts.load(Ordering::Relaxed)
    }

    pub fn epoch(&self) -> u64 {
        self.epoch.load(Ordering::Relaxed)
    }

    pub fn tree_stats(&self) -> (u64, u64) {
        (self.tree_hits.load(Ordering::Relaxed), self.tree_misses.load(Ordering::Relaxed))
    }
}

/// RAII in-flight marker: placement load signals stay correct on every
/// exit path of the forward loop (success, worker error, client error).
pub(crate) struct InflightGuard {
    slot: Arc<WorkerSlot>,
}

impl InflightGuard {
    pub(crate) fn enter(slot: Arc<WorkerSlot>) -> InflightGuard {
        slot.inflight.fetch_add(1, Ordering::Relaxed);
        InflightGuard { slot }
    }
}

impl Drop for InflightGuard {
    fn drop(&mut self) {
        self.slot.inflight.fetch_sub(1, Ordering::Relaxed);
    }
}

/// The worker fleet: slots plus the shard config and fault hook shared
/// with the front door.
pub struct Fleet {
    pub(crate) slots: Vec<Arc<WorkerSlot>>,
    pub(crate) cfg: ShardConfig,
    pub(crate) faults: Arc<FaultPlan>,
    t0: Instant,
    forwarded: AtomicU64,
}

impl Fleet {
    /// Attach to externally managed workers at `addrs` (no spawning, no
    /// signalling — just probing and routing).
    pub fn attach(cfg: ShardConfig, addrs: &[String], faults: Arc<FaultPlan>) -> Arc<Fleet> {
        let slots = addrs
            .iter()
            .enumerate()
            .map(|(id, addr)| Arc::new(WorkerSlot::new(id, addr.clone(), Kind::Attached, &cfg)))
            .collect();
        Fleet::finish(slots, cfg, faults)
    }

    /// Spawn `cfg.workers` child `bsa serve` processes on consecutive
    /// ports from `cfg.worker_base_port`, each launched as
    /// `<current_exe> serve --addr 127.0.0.1:<port> <extra_args...>`.
    pub fn spawn(
        cfg: ShardConfig,
        extra_args: &[String],
        faults: Arc<FaultPlan>,
    ) -> anyhow::Result<Arc<Fleet>> {
        let exe = std::env::current_exe().context("resolving worker executable")?;
        let exe = exe.to_str().context("non-utf8 executable path")?.to_string();
        let mut slots = Vec::with_capacity(cfg.workers);
        for id in 0..cfg.workers {
            let port = cfg
                .worker_base_port
                .checked_add(id as u16)
                .context("worker_base_port + workers overflows u16")?;
            let addr = format!("127.0.0.1:{port}");
            let mut argv = vec![exe.clone(), "serve".into(), "--addr".into(), addr.clone()];
            argv.extend(extra_args.iter().cloned());
            let child = launch(&argv).with_context(|| format!("spawning worker {id} on {addr}"))?;
            slots.push(Arc::new(WorkerSlot::new(
                id,
                addr,
                Kind::Spawned { argv, child: Some(child) },
                &cfg,
            )));
        }
        Ok(Fleet::finish(slots, cfg, faults))
    }

    fn finish(slots: Vec<Arc<WorkerSlot>>, cfg: ShardConfig, faults: Arc<FaultPlan>) -> Arc<Fleet> {
        let fleet =
            Arc::new(Fleet { slots, cfg, faults, t0: Instant::now(), forwarded: AtomicU64::new(0) });
        for slot in &fleet.slots {
            let s = Arc::clone(slot);
            trace::register_gauge_owned(
                format!("shard.worker{}.inflight", slot.id),
                Box::new(move || s.inflight() as f64),
            );
            let s = Arc::clone(slot);
            trace::register_gauge_owned(
                format!("shard.worker{}.up", slot.id),
                Box::new(move || if s.is_up() { 1.0 } else { 0.0 }),
            );
        }
        let all = fleet.slots.clone();
        trace::register_gauge_owned(
            "shard.workers_up".to_string(),
            Box::new(move || all.iter().filter(|s| s.is_up()).count() as f64),
        );
        fleet
    }

    pub fn slots(&self) -> &[Arc<WorkerSlot>] {
        &self.slots
    }

    fn since_start_ms(&self) -> u64 {
        self.t0.elapsed().as_millis() as u64
    }

    /// Placement snapshot for one routing decision.
    pub fn candidates(&self) -> Vec<Candidate> {
        self.slots
            .iter()
            .map(|s| Candidate { id: s.id, live: s.is_up(), inflight: s.inflight() })
            .collect()
    }

    /// Count a forwarded frame; returns the new total (feeds the
    /// fault plan's kill-after trigger).
    pub(crate) fn note_forwarded(&self) -> u64 {
        trace::incr("shard.forwarded");
        self.forwarded.fetch_add(1, Ordering::Relaxed) + 1
    }

    pub fn forwarded(&self) -> u64 {
        self.forwarded.load(Ordering::Relaxed)
    }

    /// An idle pooled connection to worker `id`, if any. Pooled streams
    /// can be stale (the worker restarted between probes), so the
    /// forward path treats a failure on one as "try a fresh connection"
    /// rather than "worker is down".
    pub(crate) fn pooled(&self, id: usize) -> Option<TcpStream> {
        self.slots[id].pool.lock().unwrap().pop()
    }

    /// A fresh connection to worker `id`; failure here is real evidence
    /// the worker is unreachable.
    pub(crate) fn connect_fresh(&self, id: usize) -> anyhow::Result<TcpStream> {
        let timeout = Duration::from_millis(self.cfg.probe_timeout_ms.max(100));
        let stream = connect_timeout(&self.slots[id].addr, timeout)?;
        stream.set_nodelay(true).ok();
        Ok(stream)
    }

    /// Return a healthy connection to the pool (dropped if full).
    pub(crate) fn checkin(&self, id: usize, stream: TcpStream) {
        let mut pool = self.slots[id].pool.lock().unwrap();
        if pool.len() < POOL_CAP {
            pool.push(stream);
        }
    }

    /// Drop every pooled connection to worker `id` (its process died or
    /// restarted; the old streams are poison).
    pub(crate) fn sever(&self, id: usize) {
        self.slots[id].pool.lock().unwrap().clear();
    }

    /// Transition worker `id` to down: sever its pool and arm the
    /// revival schedule. Idempotent — only the up→down edge counts.
    pub(crate) fn mark_down(&self, id: usize) {
        let slot = &self.slots[id];
        self.sever(id);
        if slot.up.swap(false, Ordering::Relaxed) {
            trace::incr("shard.worker_down");
            slot.retries.store(0, Ordering::Relaxed);
            slot.backoff_ms.store(self.cfg.backoff_ms, Ordering::Relaxed);
            slot.next_attempt_ms
                .store(self.since_start_ms() + self.cfg.backoff_ms, Ordering::Relaxed);
        }
    }

    /// Fault injection: hard-kill worker `id` (SIGKILL for spawned
    /// children; attached workers are killed by the test itself) and
    /// mark it down.
    pub(crate) fn inject_kill(&self, id: usize) {
        trace::incr("shard.faults_injected");
        if let Kind::Spawned { child: Some(c), .. } = &mut *self.slots[id].kind.lock().unwrap() {
            c.kill().ok();
        }
        self.mark_down(id);
    }

    /// Fold a successful probe into the slot: restart detection, cache
    /// stats, and the down→up transition.
    fn apply_probe(&self, id: usize, r: ProbeReport) {
        let slot = &self.slots[id];
        let prev_epoch = slot.epoch.swap(r.epoch, Ordering::Relaxed);
        let prev_uptime = slot.uptime_ms.swap(r.uptime_ms, Ordering::Relaxed);
        // Restart = epoch changed (same-process router churn) or uptime
        // went backwards (a fresh process restarts both counters).
        let restarted = (prev_epoch != 0 && prev_epoch != r.epoch)
            || (prev_epoch != 0 && r.uptime_ms < prev_uptime);
        if restarted {
            slot.restarts.fetch_add(1, Ordering::Relaxed);
            trace::incr("shard.worker_restarts");
            // Old pooled streams may predate the restart; sever so the
            // forward path never talks to a ghost.
            self.sever(id);
        }
        slot.served.store(r.served, Ordering::Relaxed);
        slot.tree_hits.store(r.tree_hits, Ordering::Relaxed);
        slot.tree_misses.store(r.tree_misses, Ordering::Relaxed);
        slot.misses.store(0, Ordering::Relaxed);
        if !slot.up.swap(true, Ordering::Relaxed) {
            trace::incr("shard.worker_recovered");
            slot.retries.store(0, Ordering::Relaxed);
            slot.backoff_ms.store(self.cfg.backoff_ms, Ordering::Relaxed);
        }
    }

    /// One prober pass over the fleet: probe up workers (miss counting),
    /// revive down ones whose backoff has elapsed.
    fn probe_pass(&self) {
        let timeout = Duration::from_millis(self.cfg.probe_timeout_ms.max(1));
        for slot in &self.slots {
            if slot.is_up() {
                match probe_addr(&slot.addr, timeout) {
                    Ok(r) => self.apply_probe(slot.id, r),
                    Err(_) => {
                        trace::incr("shard.probe_misses");
                        let misses = slot.misses.fetch_add(1, Ordering::Relaxed) + 1;
                        if misses >= self.cfg.probe_misses {
                            self.mark_down(slot.id);
                        }
                    }
                }
            } else {
                self.try_revive(slot);
            }
        }
    }

    /// Revival attempt for a down worker, rate-limited by the backoff
    /// schedule. Spawned workers are additionally capped at
    /// `respawn_max` attempts per outage; attached workers have no
    /// process to respawn — a "revival" is just a probe — so they keep
    /// being probed at the `max_backoff_ms` cadence forever (a
    /// transient stall must never permanently route around a worker the
    /// fleet cannot restart).
    fn try_revive(&self, slot: &Arc<WorkerSlot>) {
        let now = self.since_start_ms();
        if now < slot.next_attempt_ms.load(Ordering::Relaxed) {
            return;
        }
        let spawned = matches!(&*slot.kind.lock().unwrap(), Kind::Spawned { .. });
        if spawned && slot.retries.load(Ordering::Relaxed) >= self.cfg.respawn_max {
            return;
        }
        // Spawned workers whose process is gone get a fresh process;
        // attached workers (and still-running children that are merely
        // unresponsive) are just re-probed.
        if let Kind::Spawned { argv, child } = &mut *slot.kind.lock().unwrap() {
            let dead = match child {
                Some(c) => c.try_wait().map(|st| st.is_some()).unwrap_or(true),
                None => true,
            };
            if dead {
                trace::incr("shard.worker_respawns");
                *child = launch(argv).ok();
            }
        }
        let timeout = Duration::from_millis(self.cfg.probe_timeout_ms.max(1));
        match probe_addr(&slot.addr, timeout) {
            Ok(r) => self.apply_probe(slot.id, r),
            Err(_) => {
                slot.retries.fetch_add(1, Ordering::Relaxed);
                let backoff = slot.backoff_ms.load(Ordering::Relaxed);
                let next =
                    backoff.saturating_mul(2).min(self.cfg.max_backoff_ms.max(self.cfg.backoff_ms));
                slot.backoff_ms.store(next, Ordering::Relaxed);
                slot.next_attempt_ms.store(self.since_start_ms() + backoff, Ordering::Relaxed);
            }
        }
    }

    /// Graceful fleet shutdown: SIGTERM every spawned child (each drains
    /// its own connections within its `drain_ms`, per docs/FORMATS.md
    /// §2.4), wait boundedly, then SIGKILL stragglers. Attached workers
    /// are untouched — whoever started them owns them.
    pub fn shutdown(&self) {
        for slot in &self.slots {
            if let Kind::Spawned { child: Some(c), .. } = &*slot.kind.lock().unwrap() {
                terminate(c);
            }
        }
        let deadline = Instant::now() + Duration::from_millis(self.cfg.drain_ms + 1000);
        for slot in &self.slots {
            let mut kind = slot.kind.lock().unwrap();
            if let Kind::Spawned { child: Some(c), .. } = &mut *kind {
                while c.try_wait().map(|st| st.is_none()).unwrap_or(false) {
                    if Instant::now() >= deadline {
                        c.kill().ok();
                        break;
                    }
                    std::thread::sleep(Duration::from_millis(20));
                }
                c.wait().ok();
            }
            if let Kind::Spawned { child, .. } = &mut *kind {
                *child = None;
            }
        }
    }
}

impl Drop for Fleet {
    fn drop(&mut self) {
        // Last-resort reaping so a panicking front door never leaks
        // worker processes; the graceful path is `shutdown()`.
        for slot in &self.slots {
            if let Kind::Spawned { child: Some(c), .. } = &mut *slot.kind.lock().unwrap() {
                c.kill().ok();
                c.wait().ok();
            }
        }
    }
}

fn launch(argv: &[String]) -> anyhow::Result<Child> {
    let child = Command::new(&argv[0])
        .args(&argv[1..])
        .stdin(Stdio::null())
        .stdout(Stdio::null())
        .spawn()?;
    Ok(child)
}

/// Ask a child to drain gracefully (SIGTERM → its own serve loop stops
/// accepting and drains within `drain_ms`, docs/FORMATS.md §2.4).
fn terminate(child: &Child) {
    unsafe {
        libc::kill(child.id() as libc::pid_t, libc::SIGTERM);
    }
}

pub(crate) fn connect_timeout(addr: &str, timeout: Duration) -> anyhow::Result<TcpStream> {
    let sa = addr
        .to_socket_addrs()?
        .next()
        .ok_or_else(|| anyhow!("worker address {addr} did not resolve"))?;
    Ok(TcpStream::connect_timeout(&sa, timeout)?)
}

/// One BSST probe: connect, request stats, parse the health fields out
/// of the status-2 JSON payload (docs/FORMATS.md §2.3 / §3.2). Any
/// failure — connect, timeout, bad frame, missing key — is one miss.
pub fn probe_addr(addr: &str, timeout: Duration) -> anyhow::Result<ProbeReport> {
    let mut stream = connect_timeout(addr, timeout)?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    stream.write_all(STATS_MAGIC)?;
    let mut magic = [0u8; 4];
    stream.read_exact(&mut magic)?;
    if &magic != RESP_MAGIC {
        bail!("bad stats response magic {magic:?}");
    }
    let status = read_u32(&mut stream)?;
    if status != STATUS_STATS {
        bail!("expected status-2 stats frame, got status {status}");
    }
    let len = read_u32(&mut stream)? as usize;
    if len >= 65536 {
        bail!("stats payload {len} B over bound");
    }
    let mut payload = vec![0u8; len];
    stream.read_exact(&mut payload)?;
    let text = String::from_utf8(payload).context("stats payload not utf-8")?;
    let json = trace::parse_json(&text).map_err(|e| anyhow!("stats payload not JSON: {e}"))?;
    let field = |key: &str| -> anyhow::Result<u64> {
        json.get(key)
            .and_then(|v| v.as_f64())
            .map(|v| v as u64)
            .ok_or_else(|| anyhow!("stats payload missing numeric {key:?}"))
    };
    Ok(ProbeReport {
        epoch: field("epoch")?,
        uptime_ms: field("uptime_ms")?,
        served: field("served")?,
        tree_hits: field("tree_hits")?,
        tree_misses: field("tree_misses")?,
    })
}

/// Run the health prober until `stop`: one [`Fleet::probe_pass`] per
/// `probe_interval_ms`, sleeping in short ticks so shutdown is prompt.
/// The fault plan's probe delay (chaos tests) stalls the *cycle*, which
/// is how a test starves probes past the miss deadline.
pub fn run_prober(
    fleet: Arc<Fleet>,
    stop: Arc<std::sync::atomic::AtomicBool>,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name("shard-prober".into())
        .spawn(move || {
            while !stop.load(Ordering::Relaxed) {
                let delay = fleet.faults.probe_delay();
                if delay > 0 {
                    // Injected stall: up to `delay` ms, re-checked every
                    // tick so a test can clear it and resume promptly.
                    let until = Instant::now() + Duration::from_millis(delay);
                    while !stop.load(Ordering::Relaxed)
                        && Instant::now() < until
                        && fleet.faults.probe_delay() > 0
                    {
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    continue;
                }
                fleet.probe_pass();
                sleep_ticks(fleet.cfg.probe_interval_ms.max(1), &stop);
            }
        })
        .expect("spawning shard prober thread")
}

fn sleep_ticks(ms: u64, stop: &std::sync::atomic::AtomicBool) {
    let deadline = Instant::now() + Duration::from_millis(ms);
    while Instant::now() < deadline && !stop.load(Ordering::Relaxed) {
        std::thread::sleep(Duration::from_millis(10.min(ms.max(1))));
    }
}
