//! The front-door router: public TCP endpoint, frame forwarding with
//! geometry-affinity placement, shed propagation, graceful drain.
//!
//! The front door speaks the exact wire protocol of [`crate::server`]
//! (docs/FORMATS.md §2) on both sides: clients talk to it as if it were
//! a single server, and it talks to each worker as an ordinary client.
//! Per accepted connection one handler thread reads BSRQ frames,
//! computes the shard key with
//! [`content_hash_le_bytes`](crate::balltree::content_hash_le_bytes)
//! directly over the coordinate wire bytes (bit-identical to the hash
//! the worker's tree cache keys on — no float decode on the routing
//! path), places it via [`place`](crate::shard::placement::place), and
//! relays the worker's reply. Replies leave in request order because a
//! handler forwards one frame at a time; pipelined frames queue in the
//! client socket.
//!
//! Failure contract (docs/FORMATS.md §3.3): a worker transport failure
//! is retried on the surviving workers (the whole response is buffered
//! before any reply byte reaches the client, so a mid-reply worker
//! death is retried cleanly); when no live worker remains the client
//! gets a typed status-3 shed, never silence. Worker status-3 sheds are
//! relayed verbatim — the worker's own `retry_after_ms` propagates to
//! the client. Worker status-1 errors are relayed and the connection is
//! closed, mirroring the single-server contract.

use std::fmt::Write as _;
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{bail, Context};

use crate::balltree::content_hash_le_bytes;
use crate::server::{
    accept_error_backoff, bounded_stats_json, encode_err, encode_shed, MAX_COORD_DIMS,
    MAX_FEAT_DIMS, MAX_POINTS, REQ_MAGIC, RESP_MAGIC, STATS_MAGIC, STATUS_ERR, STATUS_OK,
    STATUS_SHED, STATUS_STATS,
};
use crate::shard::placement::{place, Placement};
use crate::shard::worker::{run_prober, Fleet, InflightGuard};
use crate::trace;

/// Hard ceiling on one forwarded exchange (a worker that neither
/// replies nor errors within this is treated as dead and retried).
const FORWARD_TIMEOUT_MS: u64 = 30_000;
/// Once a client has started a frame, the rest must arrive within this.
const CLIENT_FRAME_TIMEOUT_MS: u64 = 10_000;
/// Reply plausibility bounds, mirroring the client's own
/// (docs/FORMATS.md §2.1): relayed `rn`/`ro` and total reply bytes.
const RELAY_MAX_OUT_FEATURES: u32 = 1 << 16;
const RELAY_MAX_RESP_BYTES: u64 = 1 << 30;
/// Poll tick for all timeout-tolerant socket reads.
const READ_TICK: Duration = Duration::from_millis(100);

/// A running front door: accept loop + health prober over a [`Fleet`].
pub struct FrontDoor {
    addr: String,
    stop: Arc<AtomicBool>,
    accept: Option<JoinHandle<()>>,
    prober: Option<JoinHandle<()>>,
    fleet: Arc<Fleet>,
}

impl FrontDoor {
    /// Bind `fleet.cfg.addr` and start routing. The prober starts with
    /// the accept loop, so worker health converges within one probe
    /// interval of startup.
    pub fn start(fleet: Arc<Fleet>) -> anyhow::Result<FrontDoor> {
        let listener = TcpListener::bind(&fleet.cfg.addr)
            .with_context(|| format!("binding front door to {}", fleet.cfg.addr))?;
        let addr = listener.local_addr()?.to_string();
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let prober = run_prober(Arc::clone(&fleet), Arc::clone(&stop));
        let accept = {
            let fleet = Arc::clone(&fleet);
            let stop = Arc::clone(&stop);
            std::thread::Builder::new()
                .name("shard-accept".into())
                .spawn(move || accept_loop(listener, fleet, stop))
                .expect("spawning shard accept thread")
        };
        Ok(FrontDoor { addr, stop, accept: Some(accept), prober: Some(prober), fleet })
    }

    /// The actually-bound address (resolves a `:0` port request).
    pub fn local_addr(&self) -> &str {
        &self.addr
    }

    /// Shared stop flag — signal handlers set this to begin the drain.
    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }

    pub fn fleet(&self) -> &Arc<Fleet> {
        &self.fleet
    }

    /// Graceful shutdown, in drain order (docs/FORMATS.md §3.4): stop
    /// accepting, let handlers finish their in-flight frame (bounded by
    /// `drain_ms`), join the prober, then SIGTERM-drain spawned workers.
    pub fn shutdown(mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept.take() {
            h.join().ok();
        }
        if let Some(h) = self.prober.take() {
            h.join().ok();
        }
        self.fleet.shutdown();
    }

    /// Block until `stop` is set (CLI path: a SIGINT/SIGTERM handler
    /// owns the flag), then drain.
    pub fn run_until_stopped(self) {
        let stop = self.stop_flag();
        while !stop.load(Ordering::Relaxed) {
            std::thread::sleep(Duration::from_millis(50));
        }
        self.shutdown();
    }
}

impl Drop for FrontDoor {
    fn drop(&mut self) {
        // Belt-and-braces for the non-`shutdown` path (panics, tests):
        // stop the threads so the process can exit.
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.accept.take() {
            h.join().ok();
        }
        if let Some(h) = self.prober.take() {
            h.join().ok();
        }
    }
}

fn accept_loop(listener: TcpListener, fleet: Arc<Fleet>, stop: Arc<AtomicBool>) {
    let mut handlers: Vec<JoinHandle<()>> = Vec::new();
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                trace::incr("shard.conns");
                let fleet = Arc::clone(&fleet);
                let stop = Arc::clone(&stop);
                let h = std::thread::Builder::new()
                    .name("shard-handler".into())
                    .spawn(move || handle_conn(stream, fleet, stop))
                    .expect("spawning shard handler thread");
                handlers.push(h);
            }
            Err(e) => match accept_error_backoff(&e) {
                None => std::thread::sleep(Duration::from_millis(5)),
                Some(backoff) => std::thread::sleep(backoff),
            },
        }
        // Reap finished handlers each pass so the thread count stays
        // flat under connection churn (same discipline as the worker's
        // own poll core).
        handlers.retain(|h| !h.is_finished());
    }
    // Drain: the listener drops here (no new connections); handlers
    // observe `stop` at their next read tick, finish their in-flight
    // frame (bounded inside the forward path), and exit.
    drop(listener);
    for h in handlers {
        h.join().ok();
    }
}

/// One client connection: BSRQ/BSST frames in, relayed replies out.
fn handle_conn(mut client: TcpStream, fleet: Arc<Fleet>, stop: Arc<AtomicBool>) {
    client.set_nodelay(true).ok();
    if client.set_read_timeout(Some(READ_TICK)).is_err() {
        return;
    }
    let mut req: Vec<u8> = Vec::new();
    let mut resp: Vec<u8> = Vec::new();
    loop {
        let mut magic = [0u8; 4];
        match read_client(&mut client, &mut magic, &stop, fleet.cfg.drain_ms) {
            Ok(true) => {}
            // Clean close, or drain while idle between frames.
            Ok(false) | Err(_) => return,
        }
        if &magic == STATS_MAGIC {
            let frame = fleet_stats_frame(&fleet);
            if client.write_all(&frame).is_err() {
                return;
            }
            continue;
        }
        if &magic != REQ_MAGIC {
            let _ = client.write_all(&encode_err("bad frame magic (expected BSRQ or BSST)"));
            return;
        }
        let mut hdr = [0u8; 12];
        if read_started(&mut client, &mut hdr, &stop, fleet.cfg.drain_ms).is_err() {
            return;
        }
        let n = u32::from_le_bytes(hdr[0..4].try_into().unwrap());
        let d = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
        let f = u32::from_le_bytes(hdr[8..12].try_into().unwrap());
        // Same admission bounds as the worker (docs/FORMATS.md §2.1):
        // status-1 then close, because the declared body length of a
        // malformed header cannot be trusted.
        if n == 0
            || n > MAX_POINTS
            || d == 0
            || d > MAX_COORD_DIMS
            || f == 0
            || f > MAX_FEAT_DIMS
        {
            let _ = client.write_all(&encode_err(&format!("bad request header n={n} d={d} f={f}")));
            return;
        }
        let coord_bytes = 4 * n as usize * d as usize;
        let body_bytes = coord_bytes + 4 * n as usize * f as usize;
        req.clear();
        req.extend_from_slice(REQ_MAGIC);
        req.extend_from_slice(&hdr);
        let body_at = req.len();
        req.resize(body_at + body_bytes, 0);
        if read_started(&mut client, &mut req[body_at..], &stop, fleet.cfg.drain_ms).is_err() {
            return;
        }
        if fleet.faults.take_shed() {
            trace::incr("shard.sheds_origin");
            let frame =
                encode_shed(fleet.cfg.retry_after_ms as u32, "shard front door: injected shed");
            if client.write_all(&frame).is_err() {
                return;
            }
            continue;
        }
        let key = content_hash_le_bytes(&req[body_at..body_at + coord_bytes]);
        match forward(&fleet, key, &req, &mut resp, &mut client, &stop) {
            Ok(true) => continue,
            Ok(false) | Err(_) => return,
        }
    }
}

/// Route one frame: place, forward, relay; retry on surviving workers
/// when the chosen one fails at the transport level. Returns
/// `Ok(keep_connection)`.
fn forward(
    fleet: &Arc<Fleet>,
    key: u64,
    req: &[u8],
    resp: &mut Vec<u8>,
    client: &mut TcpStream,
    stop: &AtomicBool,
) -> anyhow::Result<bool> {
    let mut tried: Vec<usize> = Vec::new();
    for attempt in 0..=fleet.slots().len() {
        let mut cands = fleet.candidates();
        for c in cands.iter_mut() {
            if tried.contains(&c.id) {
                c.live = false;
            }
        }
        let decision = place(key, &cands, fleet.cfg.spill_inflight);
        let Some(target) = decision.target() else {
            // Saturated everywhere or no live worker: typed shed, the
            // connection stays usable (status-3 contract).
            trace::incr("shard.sheds_origin");
            let why = match decision {
                Placement::Saturated { .. } => "all workers saturated",
                _ => "no live worker available",
            };
            let frame = encode_shed(fleet.cfg.retry_after_ms as u32, why);
            return Ok(client.write_all(&frame).is_ok());
        };
        let guard = InflightGuard::enter(Arc::clone(&fleet.slots()[target]));
        let outcome = forward_once(fleet, target, req, resp, stop);
        drop(guard);
        match outcome {
            Ok(reply) => {
                let total = fleet.note_forwarded();
                if let Some(victim) = fleet.faults.kill_due(total) {
                    fleet.inject_kill(victim);
                }
                match (attempt, &decision) {
                    (0, Placement::Affine(_)) => trace::incr("shard.affinity_hits"),
                    (_, Placement::Spill { .. }) => trace::incr("shard.spills"),
                    _ => {}
                }
                if matches!(reply, Reply::Shed) {
                    trace::incr("shard.sheds_forwarded");
                }
                if client.write_all(resp).is_err() {
                    return Ok(false);
                }
                // Status-1 closes the connection on both hops.
                return Ok(!matches!(reply, Reply::ErrClose));
            }
            Err(_) => {
                // Transport failure: mark the worker down immediately
                // (the prober will confirm / revive it) and re-place the
                // key among the survivors.
                trace::incr("shard.retries");
                fleet.mark_down(target);
                tried.push(target);
            }
        }
    }
    trace::incr("shard.sheds_origin");
    let frame = encode_shed(fleet.cfg.retry_after_ms as u32, "no live worker available");
    Ok(client.write_all(&frame).is_ok())
}

/// What kind of frame the worker answered with (already buffered in
/// `resp`, verbatim, ready to relay).
enum Reply {
    Ok,
    Shed,
    ErrClose,
}

/// One complete exchange with worker `id`: send the frame, buffer the
/// entire validated reply into `resp`. Any error means the reply never
/// reached us whole, so the caller may retry on another worker — the
/// client has seen zero bytes of it. A failure on a *pooled* stream
/// (which may simply be stale) gets one fresh-connection retry before
/// the error counts against the worker; requests are pure inference, so
/// the occasional duplicated send is harmless.
fn forward_once(
    fleet: &Arc<Fleet>,
    id: usize,
    req: &[u8],
    resp: &mut Vec<u8>,
    stop: &AtomicBool,
) -> anyhow::Result<Reply> {
    if let Some(w) = fleet.pooled(id) {
        if let Ok(reply) = exchange(fleet, id, w, req, resp, stop) {
            return Ok(reply);
        }
        trace::incr("shard.stale_pool_conns");
    }
    let w = fleet.connect_fresh(id)?;
    exchange(fleet, id, w, req, resp, stop)
}

/// The actual wire exchange on an owned worker stream.
fn exchange(
    fleet: &Arc<Fleet>,
    id: usize,
    mut w: TcpStream,
    req: &[u8],
    resp: &mut Vec<u8>,
    stop: &AtomicBool,
) -> anyhow::Result<Reply> {
    w.set_write_timeout(Some(Duration::from_millis(FORWARD_TIMEOUT_MS)))?;
    w.set_read_timeout(Some(READ_TICK))?;
    w.write_all(req)?;
    let mut hdr = [0u8; 8];
    read_deadline(&mut w, &mut hdr, FORWARD_TIMEOUT_MS, stop, fleet.cfg.drain_ms)?;
    if &hdr[0..4] != RESP_MAGIC {
        bail!("bad reply magic from worker {id}");
    }
    let status = u32::from_le_bytes(hdr[4..8].try_into().unwrap());
    resp.clear();
    resp.extend_from_slice(&hdr);
    match status {
        STATUS_OK => {
            let mut dims = [0u8; 8];
            read_deadline(&mut w, &mut dims, FORWARD_TIMEOUT_MS, stop, fleet.cfg.drain_ms)?;
            let rn = u32::from_le_bytes(dims[0..4].try_into().unwrap());
            let ro = u32::from_le_bytes(dims[4..8].try_into().unwrap());
            let bytes = 4 * rn as u64 * ro as u64;
            if rn > MAX_POINTS || ro > RELAY_MAX_OUT_FEATURES || bytes > RELAY_MAX_RESP_BYTES {
                bail!("implausible reply dims rn={rn} ro={ro} from worker {id}");
            }
            resp.extend_from_slice(&dims);
            let at = resp.len();
            resp.resize(at + bytes as usize, 0);
            read_deadline(&mut w, &mut resp[at..], FORWARD_TIMEOUT_MS, stop, fleet.cfg.drain_ms)?;
            fleet.checkin(id, w);
            Ok(Reply::Ok)
        }
        STATUS_SHED => {
            // retry_after_ms + message, relayed verbatim so the
            // worker's own backpressure hint reaches the client.
            let mut retry = [0u8; 4];
            read_deadline(&mut w, &mut retry, FORWARD_TIMEOUT_MS, stop, fleet.cfg.drain_ms)?;
            resp.extend_from_slice(&retry);
            relay_message(&mut w, resp, fleet, stop)?;
            fleet.checkin(id, w);
            Ok(Reply::Shed)
        }
        STATUS_ERR => {
            relay_message(&mut w, resp, fleet, stop)?;
            // The worker closes after status-1; its stream is spent.
            Ok(Reply::ErrClose)
        }
        other => bail!("unexpected reply status {other} from worker {id}"),
    }
}

/// Buffer a bounded `mlen | msg` tail (status-1 and status-3 frames).
fn relay_message(
    w: &mut TcpStream,
    resp: &mut Vec<u8>,
    fleet: &Arc<Fleet>,
    stop: &AtomicBool,
) -> anyhow::Result<()> {
    let mut mlen = [0u8; 4];
    read_deadline(w, &mut mlen, FORWARD_TIMEOUT_MS, stop, fleet.cfg.drain_ms)?;
    let len = u32::from_le_bytes(mlen) as usize;
    if len >= 65536 {
        bail!("worker message length {len} over bound");
    }
    resp.extend_from_slice(&mlen);
    let at = resp.len();
    resp.resize(at + len, 0);
    read_deadline(w, &mut resp[at..], FORWARD_TIMEOUT_MS, stop, fleet.cfg.drain_ms)?;
    Ok(())
}

/// Fleet-aggregate BSST reply (docs/FORMATS.md §3.3): front-door role
/// marker, per-worker health/affinity snapshot, plus the process's
/// tracing sections — all under the same 64 KiB status-2 bound.
fn fleet_stats_frame(fleet: &Arc<Fleet>) -> Vec<u8> {
    let mut workers = String::new();
    for (i, s) in fleet.slots().iter().enumerate() {
        if i > 0 {
            workers.push_str(", ");
        }
        let (hits, misses) = s.tree_stats();
        write!(
            workers,
            "{{\"id\": {}, \"addr\": \"{}\", \"up\": {}, \"epoch\": {}, \"restarts\": {}, \
             \"inflight\": {}, \"tree_hits\": {}, \"tree_misses\": {}}}",
            s.id,
            trace::json_escape(&s.addr),
            s.is_up(),
            s.epoch(),
            s.restarts(),
            s.inflight(),
            hits,
            misses,
        )
        .expect("writing to String cannot fail");
    }
    let up = fleet.slots().iter().filter(|s| s.is_up()).count();
    let core = format!(
        "\"role\": \"frontdoor\", \"workers_up\": {}, \"forwarded\": {}, \"workers\": [{}]",
        up,
        fleet.forwarded(),
        workers,
    );
    let json = bounded_stats_json(&core, &trace::stats_sections_json());
    let mut buf = Vec::with_capacity(12 + json.len());
    buf.extend_from_slice(RESP_MAGIC);
    buf.extend_from_slice(&STATUS_STATS.to_le_bytes());
    buf.extend_from_slice(&(json.len() as u32).to_le_bytes());
    buf.extend_from_slice(json.as_bytes());
    buf
}

/// Read exactly `buf.len()` bytes from an idle client position.
/// `Ok(false)` = no frame started and the connection closed cleanly (or
/// the drain began) — the handler should exit without an error. Once
/// the first byte arrives, the frame must complete within
/// [`CLIENT_FRAME_TIMEOUT_MS`] — or within `drain_ms` of the drain
/// beginning, whichever is sooner, so a client stalling mid-frame can
/// never hold shutdown past the documented drain bound
/// (docs/FORMATS.md §3.4).
fn read_client(
    stream: &mut TcpStream,
    buf: &mut [u8],
    stop: &AtomicBool,
    drain_ms: u64,
) -> anyhow::Result<bool> {
    let mut pos = 0;
    let mut deadline: Option<Instant> = None;
    let mut drain_deadline: Option<Instant> = None;
    while pos < buf.len() {
        match stream.read(&mut buf[pos..]) {
            Ok(0) => {
                if pos == 0 {
                    return Ok(false);
                }
                bail!("client closed mid-frame");
            }
            Ok(m) => {
                pos += m;
                deadline
                    .get_or_insert(Instant::now() + Duration::from_millis(CLIENT_FRAME_TIMEOUT_MS));
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                let now = Instant::now();
                if stop.load(Ordering::Relaxed) {
                    if pos == 0 {
                        return Ok(false);
                    }
                    let d = *drain_deadline
                        .get_or_insert(now + Duration::from_millis(drain_ms.max(1)));
                    if now >= d {
                        bail!("drain deadline reached mid-frame");
                    }
                }
                if let Some(d) = deadline {
                    if now >= d {
                        bail!("client frame stalled");
                    }
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(true)
}

/// [`read_client`] for a frame already in progress: completion is
/// mandatory, bounded by [`CLIENT_FRAME_TIMEOUT_MS`] — and, once the
/// drain begins, additionally by `drain_ms` (same contract as the
/// mid-frame path of [`read_client`]).
fn read_started(
    stream: &mut TcpStream,
    buf: &mut [u8],
    stop: &AtomicBool,
    drain_ms: u64,
) -> anyhow::Result<()> {
    let deadline = Instant::now() + Duration::from_millis(CLIENT_FRAME_TIMEOUT_MS);
    let mut drain_deadline: Option<Instant> = None;
    let mut pos = 0;
    while pos < buf.len() {
        match stream.read(&mut buf[pos..]) {
            Ok(0) => bail!("client closed mid-frame"),
            Ok(m) => pos += m,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                let now = Instant::now();
                if now >= deadline {
                    bail!("client frame stalled");
                }
                if stop.load(Ordering::Relaxed) {
                    let d = *drain_deadline
                        .get_or_insert(now + Duration::from_millis(drain_ms.max(1)));
                    if now >= d {
                        bail!("drain deadline reached mid-frame");
                    }
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}

/// Timeout-tolerant exact read from a worker stream (whose read timeout
/// is [`READ_TICK`]): bounded by `timeout_ms` overall, and — once the
/// drain begins — additionally by `drain_ms`, so shutdown never waits
/// the full forward timeout on a wedged worker.
fn read_deadline(
    stream: &mut TcpStream,
    buf: &mut [u8],
    timeout_ms: u64,
    stop: &AtomicBool,
    drain_ms: u64,
) -> anyhow::Result<()> {
    let hard = Instant::now() + Duration::from_millis(timeout_ms);
    let mut drain_deadline: Option<Instant> = None;
    let mut pos = 0;
    while pos < buf.len() {
        match stream.read(&mut buf[pos..]) {
            Ok(0) => bail!("worker closed mid-reply"),
            Ok(m) => pos += m,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                let now = Instant::now();
                if now >= hard {
                    bail!("worker reply timed out");
                }
                if stop.load(Ordering::Relaxed) {
                    let d = *drain_deadline
                        .get_or_insert(now + Duration::from_millis(drain_ms.max(1)));
                    if now >= d {
                        bail!("drain deadline reached mid-reply");
                    }
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
    Ok(())
}
