//! Training orchestrators: the pjrt [`Trainer`] drives the fused
//! `train_<tag>` HLO graph; the artifact-free [`NativeTrainer`] runs the
//! same loop on the pure-Rust backward pass
//! ([`crate::backend::grad`]) — select with `bsa train --backend native`.
//!
//! The compiled step is `(params…, m…, v…, step, lr, x, y) -> (params…,
//! m…, v…, loss)` (AdamW fused in by aot.py); the native step is
//! [`grad::loss_and_grads`](crate::backend::grad::loss_and_grads)
//! followed by a host-side [`Adam`](crate::backend::grad::Adam) update
//! with the same rule. Shared host responsibilities:
//!
//! * materialize the synthetic dataset and build one **ball tree per
//!   sample** (cached) — the geometric regularization BSA requires;
//! * assemble shuffled mini-batches of permuted features/targets;
//! * compute the cosine-with-warmup LR schedule (paper Appendix A) and
//!   feed it as a scalar, keeping the compiled graph schedule-free;
//! * run eval over the held-out split (the `fwd_<tag>` graph, or the
//!   tape forward for native);
//! * persist checkpoints — both write the same `.bsackpt` layout
//!   (model arrays + `m.*`/`v.*` moments + step; `docs/TRAINING.md`),
//!   so either trainer's checkpoint serves on either backend.
//!
//! Both trainers draw batches from the same seeded streams
//! (`tc.seed ^ i` per-sample trees, `tc.seed ^ 0x7221` batch sampling),
//! so the data order is identical across backends for a given config.

use std::path::Path;
use std::sync::Arc;
use std::time::Instant;

use crate::balltree::BallTree;
use crate::config::TrainConfig;
use crate::data::{Dataset, SplitSpec};
use crate::metrics::{Accumulator, ErrorStats};
use crate::prng::Rng;
use crate::runtime::{
    literal_scalar_f32, literal_to_tensor, scalar_f32, tensor_to_literal, Engine, Executable,
    GraphKind,
};
use crate::tensor::Tensor;

use super::checkpoint::Checkpoint;

/// One logged training event.
#[derive(Debug, Clone)]
pub struct LogEntry {
    pub step: usize,
    pub loss: f32,
    pub lr: f64,
    pub ms_per_step: f64,
}

/// Training driver bound to one artifact tag (model × task × N × B).
pub struct Trainer {
    engine: Arc<Engine>,
    train_exe: Arc<Executable>,
    fwd_exe: Arc<Executable>,
    tc: TrainConfig,
    /// params ++ m ++ v as literals, in manifest flatten order.
    state: Vec<xla::Literal>,
    pub step: usize,
    dataset: Dataset,
    split: SplitSpec,
    trees: Vec<BallTree>,
    rng: Rng,
    pub history: Vec<LogEntry>,
    n: usize,
    batch: usize,
    feat_dim: usize,
}

impl Trainer {
    /// Build a trainer for artifact `tag`, generating `train_samples +
    /// test_samples` synthetic samples and initializing parameters via the
    /// `init_<tag>` graph with `tc.seed`.
    pub fn new(engine: Arc<Engine>, tag: &str, tc: TrainConfig) -> anyhow::Result<Trainer> {
        let train_exe = engine.load(&format!("train_{tag}"))?;
        let fwd_exe = engine.load(&format!("fwd_{tag}"))?;
        let init_exe = engine.load(&format!("init_{tag}"))?;
        anyhow::ensure!(train_exe.info.kind == GraphKind::Train, "not a train graph");

        let info = &train_exe.info;
        let n = info.n;
        let batch = info.batch;
        let feat_dim = info.in_features;

        // dataset + ball trees
        let gen = crate::data::generator_for(&tc.task, tc.seed)?;
        anyhow::ensure!(
            gen.feature_dim() == feat_dim,
            "task {} has {} features but artifact {tag} expects {feat_dim}",
            tc.task,
            gen.feature_dim()
        );
        let total = tc.train_samples + tc.test_samples;
        let split = SplitSpec { train: tc.train_samples, test: tc.test_samples };
        // generate ~7/8 of N points per sample so the ball-tree pad path
        // (duplicate points up to the static graph length) is exercised,
        // like ShapeNet's 3586 -> 4096
        let n_points = n - n / 8;
        let dataset = Dataset::materialize(gen.as_ref(), total, n_points, split);
        let trees: Vec<BallTree> = dataset
            .samples
            .iter()
            .enumerate()
            .map(|(i, s)| BallTree::build(&s.coords, n, tc.seed ^ i as u64))
            .collect();

        // init params; zeros for optimizer moments
        let nparams = info.nparams;
        let out = init_exe.run(&[crate::runtime::scalar_i32(tc.seed as i32)])?;
        anyhow::ensure!(out.len() == nparams, "init returned {} arrays", out.len());
        let mut state = out;
        for i in 0..2 * nparams {
            let spec = &train_exe.info.inputs[nparams + i];
            state.push(tensor_to_literal(&Tensor::zeros(spec.dims.clone()))?);
        }

        let rng = Rng::new(tc.seed ^ 0x7221);
        Ok(Trainer {
            engine,
            train_exe,
            fwd_exe,
            tc,
            state,
            step: 0,
            dataset,
            split,
            trees,
            rng,
            history: vec![],
            n,
            batch,
            feat_dim,
        })
    }

    pub fn dataset(&self) -> &Dataset {
        &self.dataset
    }

    pub fn engine(&self) -> &Arc<Engine> {
        &self.engine
    }

    /// Assemble a batch (x, y) from sample indices (ball-order permuted,
    /// targets normalized by the train-split stats).
    fn assemble(&self, idxs: &[usize]) -> anyhow::Result<(Tensor, Tensor)> {
        let b = idxs.len();
        let mut x = Vec::with_capacity(b * self.n * self.feat_dim);
        let mut y = Vec::with_capacity(b * self.n);
        for &i in idxs {
            let s = &self.dataset.samples[i];
            let t = &self.trees[i];
            let feats = t.permute_features(&s.features);
            let targ = t.permute_features(&self.dataset.norm.normalize(&s.target));
            x.extend_from_slice(feats.data());
            y.extend_from_slice(targ.data());
        }
        Ok((
            Tensor::new(vec![b, self.n, self.feat_dim], x),
            Tensor::new(vec![b, self.n, 1], y),
        ))
    }

    /// Run one optimization step on a random train batch; returns the loss.
    pub fn step_once(&mut self) -> anyhow::Result<f32> {
        let idxs: Vec<usize> = (0..self.batch)
            .map(|_| self.rng.below(self.split.train))
            .collect();
        let (x, y) = self.assemble(&idxs)?;
        let started = Instant::now();

        let lr = self.tc.lr_at(self.step) as f32;
        let nparams = self.train_exe.info.nparams;
        let mut inputs = std::mem::take(&mut self.state);
        inputs.push(scalar_f32((self.step + 1) as f32));
        inputs.push(scalar_f32(lr));
        inputs.push(tensor_to_literal(&x)?);
        inputs.push(tensor_to_literal(&y)?);

        let mut out = self.train_exe.run(&inputs)?;
        let loss = literal_scalar_f32(&out[3 * nparams])?;
        out.truncate(3 * nparams);
        self.state = out;
        self.step += 1;

        let ms = started.elapsed().as_secs_f64() * 1e3;
        if self.step % self.tc.log_every == 0 || self.step == 1 {
            self.history.push(LogEntry { step: self.step, loss, lr: lr as f64, ms_per_step: ms });
        }
        anyhow::ensure!(loss.is_finite(), "loss diverged at step {}: {loss}", self.step);
        Ok(loss)
    }

    /// Train for `tc.steps` steps with periodic logging/eval callbacks.
    pub fn run<F: FnMut(&LogEntry)>(&mut self, mut on_log: F) -> anyhow::Result<f32> {
        let mut last = f32::NAN;
        for _ in self.step..self.tc.steps {
            last = self.step_once()?;
            if let Some(entry) = self.history.last() {
                if entry.step == self.step {
                    on_log(entry);
                }
            }
        }
        Ok(last)
    }

    /// Mean test MSE (normalized target units) over the held-out split.
    pub fn evaluate(&self) -> anyhow::Result<f64> {
        let nparams = self.fwd_exe.info.nparams;
        let fwd_batch = self.fwd_exe.info.batch;
        let mut err = ErrorStats::default();
        let test_range: Vec<usize> =
            (self.split.train..self.split.train + self.split.test).collect();
        for chunk in test_range.chunks(fwd_batch) {
            // pad the final chunk by repeating its last sample
            let mut idxs = chunk.to_vec();
            while idxs.len() < fwd_batch {
                idxs.push(*chunk.last().unwrap());
            }
            let (x, y) = self.assemble(&idxs)?;
            let params = &self.state[..nparams];
            let out = self.fwd_exe.run_with_tensors(params, &[&x])?;
            let pred = literal_to_tensor(&out[0])?;
            // only score the non-padded chunk entries and real points
            for (bi, &si) in chunk.iter().enumerate() {
                let tree = &self.trees[si];
                let stride = self.n;
                for p in 0..self.n {
                    if tree.real[p] {
                        let off = bi * stride + p;
                        err.push_pair(pred.data()[off], y.data()[off]);
                    }
                }
            }
        }
        Ok(err.mse())
    }

    /// Per-step wall-clock statistics from the log history.
    pub fn step_time_stats(&self) -> Accumulator {
        let mut acc = Accumulator::new();
        for e in &self.history {
            acc.push(e.ms_per_step);
        }
        acc
    }

    /// Save params (+ optimizer state + step) to a checkpoint file.
    pub fn save_checkpoint(&self, path: &Path) -> anyhow::Result<()> {
        let names: Vec<&str> = self
            .train_exe
            .info
            .inputs
            .iter()
            .take(3 * self.train_exe.info.nparams)
            .map(|s| s.name.as_str())
            .collect();
        let mut arrays = Vec::with_capacity(self.state.len());
        for (lit, name) in self.state.iter().zip(names) {
            arrays.push((name.to_string(), literal_to_tensor(lit)?));
        }
        Checkpoint { step: self.step as u64, arrays }.save(path)
    }

    /// Restore params/optimizer state/step from a checkpoint.
    pub fn load_checkpoint(&mut self, path: &Path) -> anyhow::Result<()> {
        let ck = Checkpoint::load(path)?;
        let expect = 3 * self.train_exe.info.nparams;
        anyhow::ensure!(
            ck.arrays.len() == expect,
            "checkpoint has {} arrays, graph needs {expect}",
            ck.arrays.len()
        );
        let mut state = Vec::with_capacity(expect);
        for ((name, t), spec) in ck.arrays.iter().zip(&self.train_exe.info.inputs) {
            anyhow::ensure!(
                t.shape() == spec.dims.as_slice(),
                "checkpoint array {name} shape {:?} != graph {:?}",
                t.shape(),
                spec.dims
            );
            state.push(tensor_to_literal(t)?);
        }
        self.state = state;
        self.step = ck.step as usize;
        Ok(())
    }

    /// Borrow the current parameter literals (first `nparams` of state).
    pub fn params(&self) -> &[xla::Literal] {
        &self.state[..self.train_exe.info.nparams]
    }
}

/// Artifact-free training driver: the same loop as [`Trainer`], but the
/// step runs [`grad::loss_and_grads`](crate::backend::grad::loss_and_grads)
/// (pure-Rust tape forward + reverse sweep) and a host-side
/// [`Adam`](crate::backend::grad::Adam) update — no HLO artifacts, no
/// PJRT, no Python toolchain. The top-k branch selection trains
/// straight-through (indices replayed from the forward, no score
/// gradient), matching the jax reference's `stop_gradient` (see
/// `docs/TRAINING.md`).
///
/// Checkpoints are `.bsackpt` v3: model arrays plus `m.<name>` /
/// `v.<name>` optimizer moments and the completed-step count, so
/// train → save → resume round-trips exactly and the same file loads
/// for inference (readers skip `m.*`/`v.*`). Loading a v1/v2 or
/// params-only file resumes with zeroed moments.
pub struct NativeTrainer {
    tc: TrainConfig,
    hyper: crate::backend::native::AttnHyper,
    params: crate::backend::NativeParams,
    opt: crate::backend::grad::Adam,
    pub step: usize,
    dataset: Dataset,
    split: SplitSpec,
    trees: Vec<BallTree>,
    rng: Rng,
    pub history: Vec<LogEntry>,
    n: usize,
    batch: usize,
    feat_dim: usize,
    threads: usize,
}

impl NativeTrainer {
    /// Build a native trainer from the typed configs: synthesizes the
    /// dataset (same seeded streams as the pjrt [`Trainer`]), builds one
    /// ball tree per sample, and initializes parameters with
    /// [`NativeParams::init`](crate::backend::NativeParams::init) from
    /// `tc.seed`. `threads` is the per-step kernel thread budget
    /// (0 = auto, like serving; a pure latency knob — the trajectory is
    /// bitwise identical at any setting).
    pub fn new(
        mc: &crate::config::ModelConfig,
        tc: TrainConfig,
        threads: usize,
    ) -> anyhow::Result<NativeTrainer> {
        anyhow::ensure!(
            mc.variant == "bsa",
            "native training implements the paper's bsa variant (got {:?})",
            mc.variant
        );
        let mut mc = mc.clone();
        mc.ball_size = mc.ball_size.min(mc.seq_len);
        mc.validate()?;
        let n = mc.seq_len;
        let batch = tc.batch.max(1);

        // dataset + ball trees (same streams as Trainer::new so the
        // data order matches the pjrt path for a given config)
        let gen = crate::data::generator_for(&tc.task, tc.seed)?;
        let feat_dim = gen.feature_dim();
        let total = tc.train_samples + tc.test_samples;
        let split = SplitSpec { train: tc.train_samples, test: tc.test_samples };
        let n_points = n - n / 8;
        let dataset = Dataset::materialize(gen.as_ref(), total, n_points, split);
        let trees: Vec<BallTree> = dataset
            .samples
            .iter()
            .enumerate()
            .map(|(i, s)| BallTree::build(&s.coords, n, tc.seed ^ i as u64))
            .collect();

        let params = crate::backend::NativeParams::init(
            tc.seed,
            feat_dim,
            1, // scalar pressure/deformation target, like the artifacts
            mc.dim,
            mc.num_heads,
            mc.num_blocks,
            4, // mlp_ratio, fixed across the repo (aot.py, NativeBackend)
        );
        let opt = crate::backend::grad::Adam::new(&params, tc.weight_decay as f32);
        let rng = Rng::new(tc.seed ^ 0x7221);
        let hyper = crate::backend::native::AttnHyper::from_model(&mc);
        Ok(NativeTrainer {
            tc,
            hyper,
            params,
            opt,
            step: 0,
            dataset,
            split,
            trees,
            rng,
            history: vec![],
            n,
            batch,
            feat_dim,
            threads,
        })
    }

    pub fn dataset(&self) -> &Dataset {
        &self.dataset
    }

    /// Borrow the current model parameters.
    pub fn params(&self) -> &crate::backend::NativeParams {
        &self.params
    }

    /// Assemble a batch (x, y) from sample indices (ball-order permuted,
    /// targets normalized by the train-split stats).
    fn assemble(&self, idxs: &[usize]) -> (Tensor, Tensor) {
        let b = idxs.len();
        let mut x = Vec::with_capacity(b * self.n * self.feat_dim);
        let mut y = Vec::with_capacity(b * self.n);
        for &i in idxs {
            let s = &self.dataset.samples[i];
            let t = &self.trees[i];
            let feats = t.permute_features(&s.features);
            let targ = t.permute_features(&self.dataset.norm.normalize(&s.target));
            x.extend_from_slice(feats.data());
            y.extend_from_slice(targ.data());
        }
        (
            Tensor::new(vec![b, self.n, self.feat_dim], x),
            Tensor::new(vec![b, self.n, 1], y),
        )
    }

    /// Run one optimization step on a random train batch; returns the loss.
    pub fn step_once(&mut self) -> anyhow::Result<f32> {
        let idxs: Vec<usize> = (0..self.batch)
            .map(|_| self.rng.below(self.split.train))
            .collect();
        let (x, y) = self.assemble(&idxs);
        let started = Instant::now();

        let lr = self.tc.lr_at(self.step) as f32;
        let (loss, _tape, grads) = crate::backend::grad::loss_and_grads(
            &self.params,
            &self.hyper,
            x.data(),
            y.data(),
            self.batch,
            self.n,
            self.threads,
        );
        self.opt.step(lr, &mut self.params, &grads);
        self.step += 1;

        let ms = started.elapsed().as_secs_f64() * 1e3;
        if self.step % self.tc.log_every == 0 || self.step == 1 {
            self.history.push(LogEntry { step: self.step, loss, lr: lr as f64, ms_per_step: ms });
        }
        anyhow::ensure!(loss.is_finite(), "loss diverged at step {}: {loss}", self.step);
        Ok(loss)
    }

    /// Train for `tc.steps` steps with periodic logging callbacks.
    pub fn run<F: FnMut(&LogEntry)>(&mut self, mut on_log: F) -> anyhow::Result<f32> {
        let mut last = f32::NAN;
        for _ in self.step..self.tc.steps {
            last = self.step_once()?;
            if let Some(entry) = self.history.last() {
                if entry.step == self.step {
                    on_log(entry);
                }
            }
        }
        Ok(last)
    }

    /// Mean test MSE (normalized target units) over the held-out split,
    /// using the tape forward (numerically identical to the serving
    /// forward).
    pub fn evaluate(&self) -> anyhow::Result<f64> {
        let mut err = ErrorStats::default();
        let test_range: Vec<usize> =
            (self.split.train..self.split.train + self.split.test).collect();
        for chunk in test_range.chunks(self.batch) {
            // pad the final chunk by repeating its last sample
            let mut idxs = chunk.to_vec();
            while idxs.len() < self.batch {
                idxs.push(*chunk.last().unwrap());
            }
            let (x, y) = self.assemble(&idxs);
            let tape = crate::backend::grad::tape::forward(
                &self.params,
                &self.hyper,
                x.data(),
                self.batch,
                self.n,
                self.threads,
            );
            // only score the non-padded chunk entries and real points
            for (bi, &si) in chunk.iter().enumerate() {
                let tree = &self.trees[si];
                let stride = self.n;
                for p in 0..self.n {
                    if tree.real[p] {
                        let off = bi * stride + p;
                        err.push_pair(tape.pred[off], y.data()[off]);
                    }
                }
            }
        }
        Ok(err.mse())
    }

    /// Per-step wall-clock statistics from the log history.
    pub fn step_time_stats(&self) -> Accumulator {
        let mut acc = Accumulator::new();
        for e in &self.history {
            acc.push(e.ms_per_step);
        }
        acc
    }

    /// Save a full training checkpoint (`.bsackpt` v3): model arrays,
    /// `m.<name>`/`v.<name>` optimizer moments, completed-step count.
    /// The file doubles as an inference param file — loaders skip the
    /// moment arrays.
    pub fn save_checkpoint(&self, path: &Path) -> anyhow::Result<()> {
        let mut arrays: Vec<(String, Tensor)> = self
            .params
            .named_arrays()
            .into_iter()
            .map(|(n, t)| (n, t.clone()))
            .collect();
        for (n, t) in self.opt.m.named_arrays() {
            arrays.push((format!("m.{n}"), t.clone()));
        }
        for (n, t) in self.opt.v.named_arrays() {
            arrays.push((format!("v.{n}"), t.clone()));
        }
        Checkpoint { step: self.step as u64, arrays }.save(path)
    }

    /// Restore params/optimizer state/step from a checkpoint. A v3 file
    /// written by [`save_checkpoint`](Self::save_checkpoint) round-trips
    /// exactly; a v1/v2 or params-only file (no `m.*`/`v.*` arrays)
    /// resumes with freshly zeroed moments — the documented
    /// up-conversion (`docs/TRAINING.md`). Shape or architecture drift
    /// is a hard error.
    pub fn load_checkpoint(&mut self, path: &Path) -> anyhow::Result<()> {
        let ck = Checkpoint::load(path)?;
        let params = crate::backend::NativeParams::from_named(ck.arrays.clone())
            .map_err(|e| anyhow::anyhow!("resuming from {}: {e}", path.display()))?;
        for ((name, old), (_, new)) in
            self.params.named_arrays().iter().zip(params.named_arrays())
        {
            anyhow::ensure!(
                old.shape() == new.shape(),
                "checkpoint array {name} shape {:?} != model {:?}",
                new.shape(),
                old.shape()
            );
        }
        let mut moments: std::collections::BTreeMap<String, Tensor> = ck
            .arrays
            .into_iter()
            .filter(|(n, _)| n.starts_with("m.") || n.starts_with("v."))
            .collect();
        let mut opt = crate::backend::grad::Adam::new(&params, self.tc.weight_decay as f32);
        if !moments.is_empty() {
            // full v3 checkpoint: every moment must be present and shaped
            // like its parameter (partial state would silently corrupt
            // the bias correction)
            for (prefix, tree) in [("m", &mut opt.m), ("v", &mut opt.v)] {
                for (name, t) in tree.named_arrays_mut() {
                    let key = format!("{prefix}.{name}");
                    let src = moments.remove(&key).ok_or_else(|| {
                        anyhow::anyhow!("checkpoint missing optimizer array {key:?}")
                    })?;
                    anyhow::ensure!(
                        src.shape() == t.shape(),
                        "optimizer array {key} shape {:?} != param {:?}",
                        src.shape(),
                        t.shape()
                    );
                    *t = src;
                }
            }
            anyhow::ensure!(
                moments.is_empty(),
                "checkpoint has unexpected optimizer arrays: {:?}",
                moments.keys().take(6).collect::<Vec<_>>()
            );
        }
        opt.t = ck.step;
        self.params = params;
        self.opt = opt;
        self.step = ck.step as usize;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    // Trainer integration tests live in rust/tests/integration.rs — the
    // pjrt ones need compiled artifacts; the NativeTrainer end-to-end
    // loop (loss decreases, v3 checkpoint round-trip) lives there too.
    // Unit-testable pieces (schedule, batching math) are covered in
    // config::tests and data::tests.
}
