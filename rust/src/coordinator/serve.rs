//! Serving router: bounded queue → deadline batcher → worker pool.
//!
//! The router is backend-agnostic: workers hold an
//! `Arc<dyn Backend>` ([`crate::backend::Backend`]) and never see PJRT
//! types, so the same hot path serves compiled HLO artifacts
//! ([`PjrtBackend`](crate::backend::PjrtBackend)) or the pure-Rust BSA
//! forward pass ([`NativeBackend`](crate::backend::NativeBackend)) on
//! artifact-free hosts.
//!
//! Requests carry an arbitrary-size point cloud; a worker
//!   1. looks up (or builds) the ball tree for the geometry (pads to the
//!      backend's N),
//!   2. permutes features into ball order,
//!   3. runs the backend's forward pass,
//!   4. inverse-permutes predictions back to the caller's point order.
//!
//! The dynamic batcher groups up to `spec.batch` requests (the backend's
//! batch dimension) and flushes early after `flush_us` so tail latency is
//! bounded — vLLM-style continuous batching collapsed to the static-shape
//! setting of AOT-compiled graphs.
//!
//! # Serving hot path
//!
//! The host-side coordinator is engineered so a request touches the
//! allocator as little as possible between dequeue and reply:
//!
//! * **Ball-tree cache** — ball orderings depend only on the geometry,
//!   not the features, so the dominant CFD pattern (one mesh, many
//!   feature fields) hits a content-addressed LRU
//!   [`BallTreeCache`](crate::balltree::BallTreeCache) (capacity
//!   `ServeConfig::tree_cache`, 0 disables) and skips `BallTree::build`
//!   entirely. Keys use the chunked 8-bytes-at-a-time
//!   [`content_hash`](crate::balltree::content_hash), which doubles as
//!   the deterministic pad seed: cached and freshly built trees are
//!   bit-identical, so caching is semantically invisible.
//! * **Zero-copy batch assembly** — each worker owns one preallocated
//!   `(B, N, F)` input tensor, reused across batches. Per-request
//!   permuted features are gathered straight into the request's slot via
//!   `BallTree::permute_features_into` (no per-request `Tensor` +
//!   `extend_from_slice`), and predictions are inverse-permuted from a
//!   borrowed window (`Tensor::slice_rows_view` +
//!   `unpermute_predictions_view`) instead of a `slice_rows` copy. The
//!   only allocation per request on the happy path is the reply tensor
//!   itself.
//! * **Concurrent preprocessing** — validation and cache hits run
//!   inline (a hit is a hash + gather, cheaper than a thread spawn);
//!   cache-missing requests — the only expensive step — are deduplicated
//!   by geometry (a same-mesh burst builds its tree once) and built in
//!   parallel under `std::thread::scope`, overlapping with the previous
//!   batch's forward pass (which, on the PJRT backend, holds the
//!   process-wide `EXECUTE_LOCK`). Steady-state repeated-geometry
//!   traffic never spawns a thread.
//!
//! Measured numbers for cold-tree vs cached-tree p50/p95 latency and
//! throughput are produced by `cargo bench -- serve_hot_path`, which
//! writes the machine-readable `BENCH_serve.json` perf artifact;
//! `scripts/check.sh` runs it in smoke mode so every change refreshes
//! the trajectory.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender, TrySendError};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::backend::{Backend, BackendSpec, PjrtBackend};
use crate::balltree::{BallTree, BallTreeCache};
use crate::config::ServeConfig;
use crate::metrics::LatencyHistogram;
use crate::runtime::Engine;
use crate::tensor::Tensor;

/// An inference request: a point cloud + per-point features.
pub struct ServeRequest {
    pub id: u64,
    pub coords: Tensor,   // (N0, D)
    pub features: Tensor, // (N0, F)
    pub reply: SyncSender<ServeResponse>,
    pub enqueued: Instant,
}

/// The prediction for one request.
#[derive(Debug)]
pub struct ServeResponse {
    pub id: u64,
    pub result: anyhow::Result<Tensor>, // (N0, out_features)
    pub latency: Duration,
}

/// Router statistics snapshot.
#[derive(Debug, Clone, Default)]
pub struct RouterStats {
    pub served: u64,
    pub rejected: u64,
    pub batches: u64,
    pub mean_batch: f64,
    /// Ball-tree cache hits (geometry already resident).
    pub tree_hits: u64,
    /// Ball-tree cache misses (tree built from scratch).
    pub tree_misses: u64,
    pub latency_summary: String,
    /// Number of samples inside `latency_summary`. Taken under the same
    /// lock as `served`, so `latency_samples == served` in every snapshot
    /// (the regression test for the old torn read, where `served` could
    /// run ahead of its latency sample).
    pub latency_samples: u64,
    /// Milliseconds since this router instance started. A value that
    /// *decreased* between two probes of the same address means the
    /// process (or in-process router) restarted in between.
    pub uptime_ms: u64,
    /// Monotonic router incarnation: every [`Router::start`] draws the
    /// next value from a per-process entropy-seeded counter (see
    /// [`next_epoch`]), so a respawned worker — same process or a fresh
    /// one — is distinguishable from a healthy one even when both
    /// probes land in the same low-uptime window. Without it, the shard
    /// front door's affinity bookkeeping would keep crediting a
    /// restarted worker with a tree cache it no longer holds.
    pub epoch: u64,
}

/// Why a submit was refused without reaching a worker. The TCP
/// front-end maps `QueueFull` to the typed status-3 shed frame
/// (docs/FORMATS.md §2.2) instead of a generic status-1 error, so
/// clients can distinguish "back off and retry" from "your request is
/// wrong".
#[derive(Debug, thiserror::Error)]
pub enum SubmitError {
    #[error("router queue full")]
    QueueFull,
    #[error("router is shutting down")]
    ShuttingDown,
}

/// Completion state: the served counter and the latency histogram move
/// together under one lock, so `stats()` can never observe a request
/// counted as served before (or after) its latency sample landed.
#[derive(Default)]
struct Done {
    served: u64,
    latency: LatencyHistogram,
}

struct Shared {
    /// The model engine — compiled-artifact or native (workers never see
    /// which; parameter-literal caching and the execute lock live inside
    /// the PJRT implementation).
    backend: Arc<dyn Backend>,
    /// Content-addressed LRU of built ball trees (see module docs).
    tree_cache: BallTreeCache,
    done: Mutex<Done>,
    rejected: AtomicU64,
    batches: AtomicU64,
    batch_sum: AtomicU64,
    stop: AtomicBool,
    /// Router start time; `stats()` reports it as `uptime_ms`.
    started: Instant,
    /// This router's incarnation number (see [`RouterStats::epoch`]).
    epoch: u64,
}

/// Source of [`RouterStats::epoch`]: strictly increasing across every
/// [`Router::start`] in the process. Seeded lazily (0 = unseeded) from
/// per-process entropy rather than starting at a fixed 1: the shard
/// front door detects worker restarts by the epoch *changing* between
/// probes, and a counter that restarts at the same value in every
/// process would make a respawned child invisible whenever the backup
/// uptime-regression check also misses (previous process died younger
/// than the new process's first-probe uptime).
static ROUTER_EPOCH: AtomicU64 = AtomicU64::new(0);

/// Draw the next router epoch, seeding [`ROUTER_EPOCH`] on first use
/// with a splitmix64 mix of the PID and the wall clock. The seed is
/// masked to 48 bits (epochs stay readable in stats output, with
/// headroom for per-process increments) and forced nonzero — the shard
/// prober uses epoch 0 as its "never probed" sentinel.
fn next_epoch() -> u64 {
    if ROUTER_EPOCH.load(Ordering::Relaxed) == 0 {
        let pid = std::process::id() as u64;
        let nanos = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_nanos() as u64)
            .unwrap_or(0);
        let mut h = pid ^ nanos.rotate_left(17);
        h ^= h >> 30;
        h = h.wrapping_mul(0xbf58476d1ce4e5b9);
        h ^= h >> 27;
        h = h.wrapping_mul(0x94d049bb133111eb);
        h ^= h >> 31;
        let seed = (h & 0xffff_ffff_ffff).max(1);
        // A concurrent seeder winning the race is fine — both values
        // are valid nonzero seeds and fetch_add keeps monotonicity.
        let _ = ROUTER_EPOCH.compare_exchange(0, seed, Ordering::Relaxed, Ordering::Relaxed);
    }
    ROUTER_EPOCH.fetch_add(1, Ordering::Relaxed)
}

/// The serving front: spawn with [`Router::start`], submit with
/// [`Router::submit`], stop with [`Router::shutdown`].
pub struct Router {
    /// `Some` while the router accepts requests; [`Router::shutdown`]
    /// takes it, dropping the only sender so workers observe a
    /// disconnected channel (no phantom replacement channel involved).
    tx: Option<SyncSender<ServeRequest>>,
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    next_id: AtomicU64,
}

impl Router {
    /// Start the router over any [`Backend`] (the native backend makes
    /// the whole serving stack artifact-free; see
    /// [`Router::start_pjrt`] for the compiled-artifact convenience).
    pub fn start(backend: Arc<dyn Backend>, cfg: ServeConfig) -> anyhow::Result<Router> {
        let (tx, rx) = sync_channel::<ServeRequest>(cfg.queue_cap);
        let rx = Arc::new(Mutex::new(rx));
        let shared = Arc::new(Shared {
            backend,
            tree_cache: BallTreeCache::new(cfg.tree_cache),
            done: Mutex::new(Done::default()),
            rejected: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batch_sum: AtomicU64::new(0),
            stop: AtomicBool::new(false),
            started: Instant::now(),
            epoch: next_epoch(),
        });

        let mut workers = Vec::with_capacity(cfg.workers.max(1));
        for w in 0..cfg.workers.max(1) {
            let rx = rx.clone();
            let shared = shared.clone();
            let cfg = cfg.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("bsa-worker-{w}"))
                    .spawn(move || worker_loop(rx, shared, cfg))
                    .expect("spawn worker"),
            );
        }
        Ok(Router { tx: Some(tx), shared, workers, next_id: AtomicU64::new(1) })
    }

    /// Convenience: start over a compiled forward graph and its parameter
    /// tensors (host tensors from a checkpoint or an init graph, matching
    /// the graph's leading inputs).
    pub fn start_pjrt(
        engine: Arc<Engine>,
        graph: &str,
        params: Vec<Tensor>,
        cfg: ServeConfig,
    ) -> anyhow::Result<Router> {
        let backend = PjrtBackend::new(&engine, graph, params)?;
        Self::start(Arc::new(backend), cfg)
    }

    /// Submit a request; returns the receiver for its response, or a
    /// typed [`SubmitError`] immediately if the queue is full
    /// (backpressure) or the router is shutting down. Both refusals
    /// count toward the `rejected` stat.
    pub fn try_submit(
        &self,
        coords: Tensor,
        features: Tensor,
    ) -> Result<Receiver<ServeResponse>, SubmitError> {
        let (reply, rx) = sync_channel(1);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = ServeRequest { id, coords, features, reply, enqueued: Instant::now() };
        let tx = self.tx.as_ref().expect("router accepts requests until shutdown");
        match tx.try_send(req) {
            Ok(()) => Ok(rx),
            Err(e) => {
                self.shared.rejected.fetch_add(1, Ordering::Relaxed);
                match e {
                    TrySendError::Full(_) => Err(SubmitError::QueueFull),
                    TrySendError::Disconnected(_) => Err(SubmitError::ShuttingDown),
                }
            }
        }
    }

    /// Submit a request; anyhow-typed convenience over
    /// [`Router::try_submit`] for callers that don't branch on the
    /// refusal kind.
    pub fn submit(
        &self,
        coords: Tensor,
        features: Tensor,
    ) -> anyhow::Result<Receiver<ServeResponse>> {
        self.try_submit(coords, features).map_err(|e| anyhow::anyhow!("{e}"))
    }

    /// Count a request refused *upstream* of the queue — the TCP
    /// front-end's admission control (connection cap, inflight-bytes
    /// budget) — so the BSST `rejected` stat covers every refused
    /// request no matter where it was refused.
    pub fn note_rejected(&self) {
        self.shared.rejected.fetch_add(1, Ordering::Relaxed);
    }

    /// Convenience: submit and block for the response.
    pub fn infer(&self, coords: Tensor, features: Tensor) -> anyhow::Result<Tensor> {
        let rx = self.submit(coords, features)?;
        let resp = rx
            .recv()
            .map_err(|_| anyhow::anyhow!("worker dropped the request"))?;
        resp.result
    }

    pub fn stats(&self) -> RouterStats {
        let batches = self.shared.batches.load(Ordering::Relaxed);
        // One lock acquisition covers served + latency: both were updated
        // together, so the snapshot is internally consistent.
        let (served, latency_summary, latency_samples) = {
            let done = self.shared.done.lock().unwrap();
            (done.served, done.latency.summary(), done.latency.count() as u64)
        };
        RouterStats {
            served,
            rejected: self.shared.rejected.load(Ordering::Relaxed),
            batches,
            mean_batch: if batches == 0 {
                0.0
            } else {
                self.shared.batch_sum.load(Ordering::Relaxed) as f64 / batches as f64
            },
            tree_hits: self.shared.tree_cache.hits(),
            tree_misses: self.shared.tree_cache.misses(),
            latency_summary,
            latency_samples,
            uptime_ms: self.shared.started.elapsed().as_millis() as u64,
            epoch: self.shared.epoch,
        }
    }

    /// p50/p95 request latency in microseconds.
    pub fn latency_us(&self, pct: f64) -> f64 {
        self.shared.done.lock().unwrap().latency.percentile_us(pct)
    }

    /// Stop workers and wait for them.
    pub fn shutdown(mut self) -> RouterStats {
        self.shared.stop.store(true, Ordering::SeqCst);
        // Dropping the only sender disconnects the channel, waking workers
        // blocked in recv.
        drop(self.tx.take());
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.stats()
    }
}

fn worker_loop(rx: Arc<Mutex<Receiver<ServeRequest>>>, shared: Arc<Shared>, cfg: ServeConfig) {
    let spec = shared.backend.spec().clone();
    let graph_batch = spec.batch;
    // One reusable (B, N, F) input buffer per worker: batch assembly
    // writes into it in place, so steady-state serving performs no
    // per-request feature-tensor allocation.
    let mut scratch = Tensor::zeros(vec![spec.batch, spec.n, spec.in_features]);
    loop {
        if shared.stop.load(Ordering::Relaxed) {
            return;
        }
        // Collect a batch: first request blocks (with timeout so shutdown
        // is honoured), then fill until graph_batch or the flush deadline.
        let mut batch: Vec<ServeRequest> = Vec::with_capacity(graph_batch);
        {
            let rx = rx.lock().unwrap();
            match rx.recv_timeout(Duration::from_millis(50)) {
                Ok(req) => batch.push(req),
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => return,
            }
            let deadline = Instant::now() + Duration::from_micros(cfg.flush_us);
            while batch.len() < graph_batch {
                let left = deadline.saturating_duration_since(Instant::now());
                if left.is_zero() {
                    break;
                }
                match rx.recv_timeout(left) {
                    Ok(req) => batch.push(req),
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
        } // release the lock before compute

        shared.batches.fetch_add(1, Ordering::Relaxed);
        shared.batch_sum.fetch_add(batch.len() as u64, Ordering::Relaxed);
        crate::trace::incr("router.batches");
        crate::trace::incr_by("router.batch_requests", batch.len() as u64);
        process_batch(&shared, batch, &mut scratch);
    }
}

/// Reject a request the backend cannot serve before any tree or buffer
/// work happens (also guards `BallTree::build`'s preconditions).
fn validate_request(spec: &BackendSpec, req: &ServeRequest) -> anyhow::Result<()> {
    anyhow::ensure!(
        req.coords.rows() > 0,
        "request {} has an empty point cloud",
        req.id
    );
    anyhow::ensure!(
        req.features.cols() == spec.in_features && req.features.rows() == req.coords.rows(),
        "request {} features {:?} incompatible with backend ({} per-point features)",
        req.id,
        req.features.shape(),
        spec.in_features
    );
    anyhow::ensure!(
        req.coords.rows() <= spec.n,
        "request {} has {} points > backend N {}",
        req.id,
        req.coords.rows(),
        spec.n
    );
    Ok(())
}

/// Complete one cache-miss *group*: requests in a batch with identical
/// geometry (same content hash + dims — e.g. a same-mesh burst hitting a
/// cold cache) share one `BallTree::build`, and each member's permuted
/// features are gathered into its slot. The internal panic guard turns a
/// pathological group into per-request errors instead of a dead worker.
fn build_gather_group(
    shared: &Shared,
    batch: &[ServeRequest],
    hash: u64,
    members: Vec<(usize, &mut [f32])>,
) -> Vec<(usize, anyhow::Result<Arc<BallTree>>)> {
    let indices: Vec<usize> = members.iter().map(|(bi, _)| *bi).collect();
    std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        let _s = crate::trace::span("tree_build");
        let first = members[0].0;
        let tree = shared
            .tree_cache
            .build_insert(&batch[first].coords, shared.backend.spec().n, hash);
        members
            .into_iter()
            .map(|(bi, slot)| {
                tree.permute_features_into(&batch[bi].features, slot);
                (bi, Ok(tree.clone()))
            })
            .collect::<Vec<_>>()
    }))
    .unwrap_or_else(|_| {
        indices
            .into_iter()
            .map(|bi| (bi, Err(anyhow::anyhow!("preprocessing panicked"))))
            .collect()
    })
}

/// Run one (possibly partial) batch through the backend. `xt` is the
/// worker's reusable `(B, N, F)` input tensor.
fn process_batch(shared: &Shared, batch: Vec<ServeRequest>, xt: &mut Tensor) {
    let spec = shared.backend.spec();
    let n = spec.n;
    let f = spec.in_features;
    let graph_batch = spec.batch;
    debug_assert!(batch.len() <= graph_batch);
    debug_assert_eq!(xt.len(), graph_batch * n * f);

    // Queue wait = submit -> batch pickup, measured from the request's
    // enqueue timestamp (a guard can't straddle the channel hop).
    if crate::trace::spans_enabled() {
        for req in &batch {
            crate::trace::record_us(
                "router.queue_wait",
                req.enqueued.elapsed().as_secs_f64() * 1e6,
            );
        }
    }

    // Preprocess into disjoint slots of the shared buffer. Stage 1 runs
    // inline: validation and cache *hits* — a hit is a hash + gather,
    // cheaper than a thread spawn. Stage 2 dedupes the cache *misses* by
    // geometry and runs the remaining `BallTree::build`s (the only
    // expensive step) on scoped threads when several distinct geometries
    // miss at once. Steady-state repeated-geometry traffic never spawns.
    let mut preps: Vec<Option<anyhow::Result<Arc<BallTree>>>> =
        (0..batch.len()).map(|_| None).collect();
    {
        let _preprocess = crate::trace::span("router.preprocess");
        let (used, pad) = xt.data_mut().split_at_mut(batch.len() * n * f);
        let mut pending: Vec<(usize, u64, &mut [f32])> = Vec::new();
        for (bi, (req, slot)) in batch.iter().zip(used.chunks_mut(n * f)).enumerate() {
            if let Err(e) = validate_request(spec, req) {
                // reused buffer: don't leak a previous batch's features
                slot.fill(0.0);
                preps[bi] = Some(Err(e));
                continue;
            }
            let cache_span = crate::trace::span("tree_cache");
            match shared.tree_cache.try_get(&req.coords, n) {
                Ok(tree) => {
                    tree.permute_features_into(&req.features, slot);
                    preps[bi] = Some(Ok(tree));
                }
                Err(hash) => pending.push((bi, hash, slot)),
            }
            drop(cache_span);
        }
        // Group the misses by geometry: identical clouds in one batch
        // (same-mesh burst on a cold cache) build their tree exactly once.
        let breq: &[ServeRequest] = &batch;
        let mut groups: Vec<((u64, usize, usize), Vec<(usize, &mut [f32])>)> = Vec::new();
        for (bi, hash, slot) in pending {
            let key = (hash, breq[bi].coords.rows(), breq[bi].coords.cols());
            match groups.iter_mut().find(|(k, _)| *k == key) {
                Some((_, members)) => members.push((bi, slot)),
                None => groups.push((key, vec![(bi, slot)])),
            }
        }
        // One expensive build per group: inline for a single group, scoped
        // threads when several distinct geometries miss at once (overlaps
        // with another worker's execution under EXECUTE_LOCK).
        if groups.len() == 1 {
            let ((hash, _, _), members) = groups.pop().unwrap();
            for (bi, r) in build_gather_group(shared, breq, hash, members) {
                preps[bi] = Some(r);
            }
        } else if !groups.is_empty() {
            // Scoped build threads start with an empty span stack; adopt
            // the worker's path so `tree_build` nests under
            // `router.preprocess` like the inline branch does.
            let parent = if crate::trace::spans_enabled() {
                crate::trace::current_path()
            } else {
                None
            };
            std::thread::scope(|s| {
                let handles: Vec<_> = groups
                    .into_iter()
                    .map(|((hash, _, _), members)| {
                        let idxs: Vec<usize> = members.iter().map(|(bi, _)| *bi).collect();
                        let job_parent = parent.clone();
                        (
                            idxs,
                            s.spawn(move || {
                                let _adopt = job_parent.map(crate::trace::adopt_parent);
                                build_gather_group(shared, breq, hash, members)
                            }),
                        )
                    })
                    .collect();
                for (idxs, h) in handles {
                    match h.join() {
                        Ok(results) => {
                            for (bi, r) in results {
                                preps[bi] = Some(r);
                            }
                        }
                        // unreachable (build_gather_group guards panics
                        // internally), but never leave a request unanswered
                        Err(_) => {
                            for bi in idxs {
                                preps[bi] =
                                    Some(Err(anyhow::anyhow!("preprocessing panicked")));
                            }
                        }
                    }
                }
            });
        }
        // Zero pad slots beyond the batch (the buffer is reused, so they
        // may hold a previous batch's features).
        pad.fill(0.0);
    }

    let run = shared.backend.forward(&*xt);

    match run {
        Ok(pred) => {
            let of = spec.out_features;
            if pred.cols() != of || pred.rows() != graph_batch * n {
                // The spec promised (B, N, out_features); anything else
                // would scatter garbage back to callers.
                let msg = format!(
                    "prediction shape {:?} does not match backend ({graph_batch}, {n}, {of})",
                    pred.shape()
                );
                fail_batch(batch, &msg);
                return;
            }
            for (bi, (req, prep)) in batch.into_iter().zip(preps).enumerate() {
                let latency = req.enqueued.elapsed();
                let prep = prep.expect("every request was preprocessed in stage 1 or 2");
                let result = prep.map(|tree| {
                    // Borrow the request's window of the batched output;
                    // the reply tensor is the only allocation here.
                    tree.unpermute_predictions_view(pred.slice_rows_view(bi * n, n), of)
                });
                {
                    // One lock: served and its latency sample land
                    // atomically with respect to `stats()` (the old
                    // separate AtomicU64 + Mutex pair could tear).
                    let mut done = shared.done.lock().unwrap();
                    done.latency.record(latency);
                    done.served += 1;
                }
                let _ = req.reply.try_send(ServeResponse { id: req.id, result, latency });
            }
        }
        Err(e) => fail_batch(batch, &format!("batch execution failed: {e}")),
    }
}

/// Reply to every request of a failed batch with the same error.
fn fail_batch(batch: Vec<ServeRequest>, msg: &str) {
    for req in batch {
        let latency = req.enqueued.elapsed();
        let _ = req.reply.try_send(ServeResponse {
            id: req.id,
            result: Err(anyhow::anyhow!("{msg}")),
            latency,
        });
    }
}

#[cfg(test)]
mod tests {
    // Router integration tests live in rust/tests/integration.rs — both
    // over a real compiled graph (PjrtBackend, needs artifacts) and over
    // the artifact-free NativeBackend, which also covers queue /
    // backpressure behaviour on hosts without a PJRT toolchain. Ball-tree
    // cache hit/miss, LRU eviction, and cached-vs-fresh determinism are
    // unit-tested next to BallTreeCache in src/balltree.rs.
}
