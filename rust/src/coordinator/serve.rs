//! Serving router: bounded queue → deadline batcher → worker pool.
//!
//! Requests carry an arbitrary-size point cloud; a worker
//!   1. builds the ball tree (pads to the compiled graph's N),
//!   2. permutes features into ball order,
//!   3. executes the `fwd_<tag>` graph,
//!   4. inverse-permutes predictions back to the caller's point order.
//!
//! The dynamic batcher groups up to `graph.batch` requests (the compiled
//! batch dimension) and flushes early after `flush_us` so tail latency is
//! bounded — vLLM-style continuous batching collapsed to the static-shape
//! setting of AOT-compiled graphs.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use crate::balltree::BallTree;
use crate::config::ServeConfig;
use crate::metrics::LatencyHistogram;
use crate::runtime::{literal_to_tensor, Engine, Executable};
use crate::tensor::Tensor;

/// An inference request: a point cloud + per-point features.
pub struct ServeRequest {
    pub id: u64,
    pub coords: Tensor,   // (N0, D)
    pub features: Tensor, // (N0, F)
    pub reply: SyncSender<ServeResponse>,
    pub enqueued: Instant,
}

/// The prediction for one request.
#[derive(Debug)]
pub struct ServeResponse {
    pub id: u64,
    pub result: anyhow::Result<Tensor>, // (N0, out_features)
    pub latency: Duration,
}

/// Router statistics snapshot.
#[derive(Debug, Clone, Default)]
pub struct RouterStats {
    pub served: u64,
    pub rejected: u64,
    pub batches: u64,
    pub mean_batch: f64,
    pub latency_summary: String,
}

/// Immutable parameter literals shared across workers.
///
/// SAFETY: `xla::Literal` wraps a heap buffer that is never mutated after
/// construction here; workers only pass borrowed pointers into `execute`,
/// which reads them. The raw pointer inside is the only reason Send/Sync
/// cannot be derived.
struct ParamLiterals(Vec<xla::Literal>);
unsafe impl Send for ParamLiterals {}
unsafe impl Sync for ParamLiterals {}

struct Shared {
    exe: Arc<Executable>,
    /// Parameters pre-converted to literals once at startup (perf: the
    /// first implementation rebuilt ~5 MB of literals per batch — see
    /// EXPERIMENTS.md §Perf L3).
    params: ParamLiterals,
    served: AtomicU64,
    rejected: AtomicU64,
    batches: AtomicU64,
    batch_sum: AtomicU64,
    latency: Mutex<LatencyHistogram>,
    stop: AtomicBool,
}

/// The serving front: spawn with [`Router::start`], submit with
/// [`Router::submit`], stop with [`Router::shutdown`].
pub struct Router {
    tx: SyncSender<ServeRequest>,
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    next_id: AtomicU64,
}

impl Router {
    /// Start the router over a forward graph and its parameter tensors.
    ///
    /// `params` are host tensors (e.g. from a checkpoint or an init graph)
    /// matching the graph's leading inputs.
    pub fn start(
        engine: Arc<Engine>,
        graph: &str,
        params: Vec<Tensor>,
        cfg: ServeConfig,
    ) -> anyhow::Result<Router> {
        let exe = engine.load(graph)?;
        anyhow::ensure!(
            params.len() == exe.info.nparams,
            "graph {graph} needs {} params, got {}",
            exe.info.nparams,
            params.len()
        );
        let param_lits: Vec<xla::Literal> = params
            .iter()
            .map(crate::runtime::tensor_to_literal)
            .collect::<Result<_, _>>()?;
        let (tx, rx) = sync_channel::<ServeRequest>(cfg.queue_cap);
        let rx = Arc::new(Mutex::new(rx));
        let shared = Arc::new(Shared {
            exe,
            params: ParamLiterals(param_lits),
            served: AtomicU64::new(0),
            rejected: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            batch_sum: AtomicU64::new(0),
            latency: Mutex::new(LatencyHistogram::new()),
            stop: AtomicBool::new(false),
        });

        let mut workers = Vec::with_capacity(cfg.workers.max(1));
        for w in 0..cfg.workers.max(1) {
            let rx = rx.clone();
            let shared = shared.clone();
            let cfg = cfg.clone();
            workers.push(
                std::thread::Builder::new()
                    .name(format!("bsa-worker-{w}"))
                    .spawn(move || worker_loop(rx, shared, cfg))
                    .expect("spawn worker"),
            );
        }
        Ok(Router { tx, shared, workers, next_id: AtomicU64::new(1) })
    }

    /// Submit a request; returns the receiver for its response, or an
    /// error immediately if the queue is full (backpressure).
    pub fn submit(
        &self,
        coords: Tensor,
        features: Tensor,
    ) -> anyhow::Result<Receiver<ServeResponse>> {
        let (reply, rx) = sync_channel(1);
        let id = self.next_id.fetch_add(1, Ordering::Relaxed);
        let req = ServeRequest { id, coords, features, reply, enqueued: Instant::now() };
        self.tx.try_send(req).map_err(|e| {
            self.shared.rejected.fetch_add(1, Ordering::Relaxed);
            anyhow::anyhow!("queue full: {e}")
        })?;
        Ok(rx)
    }

    /// Convenience: submit and block for the response.
    pub fn infer(&self, coords: Tensor, features: Tensor) -> anyhow::Result<Tensor> {
        let rx = self.submit(coords, features)?;
        let resp = rx
            .recv()
            .map_err(|_| anyhow::anyhow!("worker dropped the request"))?;
        resp.result
    }

    pub fn stats(&self) -> RouterStats {
        let batches = self.shared.batches.load(Ordering::Relaxed);
        RouterStats {
            served: self.shared.served.load(Ordering::Relaxed),
            rejected: self.shared.rejected.load(Ordering::Relaxed),
            batches,
            mean_batch: if batches == 0 {
                0.0
            } else {
                self.shared.batch_sum.load(Ordering::Relaxed) as f64 / batches as f64
            },
            latency_summary: self.shared.latency.lock().unwrap().summary(),
        }
    }

    /// p50/p95 request latency in microseconds.
    pub fn latency_us(&self, pct: f64) -> f64 {
        self.shared.latency.lock().unwrap().percentile_us(pct)
    }

    /// Stop workers and wait for them.
    pub fn shutdown(mut self) -> RouterStats {
        self.shared.stop.store(true, Ordering::SeqCst);
        // wake workers blocked on recv by dropping the sender
        drop(std::mem::replace(&mut self.tx, sync_channel(1).0));
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
        self.stats()
    }
}

fn worker_loop(rx: Arc<Mutex<Receiver<ServeRequest>>>, shared: Arc<Shared>, cfg: ServeConfig) {
    let graph_batch = shared.exe.info.batch;
    loop {
        if shared.stop.load(Ordering::Relaxed) {
            return;
        }
        // Collect a batch: first request blocks (with timeout so shutdown
        // is honoured), then fill until graph_batch or the flush deadline.
        let mut batch: Vec<ServeRequest> = Vec::with_capacity(graph_batch);
        {
            let rx = rx.lock().unwrap();
            match rx.recv_timeout(Duration::from_millis(50)) {
                Ok(req) => batch.push(req),
                Err(RecvTimeoutError::Timeout) => continue,
                Err(RecvTimeoutError::Disconnected) => return,
            }
            let deadline = Instant::now() + Duration::from_micros(cfg.flush_us);
            while batch.len() < graph_batch {
                let left = deadline.saturating_duration_since(Instant::now());
                if left.is_zero() {
                    break;
                }
                match rx.recv_timeout(left) {
                    Ok(req) => batch.push(req),
                    Err(RecvTimeoutError::Timeout) => break,
                    Err(RecvTimeoutError::Disconnected) => break,
                }
            }
        } // release the lock before compute

        shared.batches.fetch_add(1, Ordering::Relaxed);
        shared.batch_sum.fetch_add(batch.len() as u64, Ordering::Relaxed);
        process_batch(&shared, batch);
    }
}

/// Run one (possibly partial) batch through the compiled graph.
fn process_batch(shared: &Shared, batch: Vec<ServeRequest>) {
    let info = &shared.exe.info;
    let n = info.n;
    let f = info.in_features;
    let graph_batch = info.batch;

    // preprocess: ball tree + permutation per request
    let mut trees = Vec::with_capacity(batch.len());
    let mut x = Vec::with_capacity(graph_batch * n * f);
    let mut failed: Vec<(usize, String)> = vec![];
    for (bi, req) in batch.iter().enumerate() {
        if req.features.cols() != f || req.features.rows() != req.coords.rows() {
            failed.push((bi, format!(
                "request {} features {:?} incompatible with graph ({} per-point features)",
                req.id,
                req.features.shape(),
                f
            )));
            trees.push(None);
            x.extend(std::iter::repeat(0.0).take(n * f));
            continue;
        }
        if req.coords.rows() > n {
            failed.push((bi, format!("request {} has {} points > graph N {n}", req.id, req.coords.rows())));
            trees.push(None);
            x.extend(std::iter::repeat(0.0).take(n * f));
            continue;
        }
        // Seed the tree (pad-point choice) from the *content*, not the
        // request id: identical inputs must produce identical predictions.
        let tree = BallTree::build(&req.coords, n, content_hash(&req.coords));
        let feats = tree.permute_features(&req.features);
        x.extend_from_slice(feats.data());
        trees.push(Some(tree));
    }
    // pad the batch dimension with zeros
    while x.len() < graph_batch * n * f {
        x.push(0.0);
    }

    let xt = Tensor::new(vec![graph_batch, n, f], x);
    let run = (|| -> anyhow::Result<Tensor> {
        let out = shared.exe.run_with_tensors(&shared.params.0, &[&xt])?;
        literal_to_tensor(&out[0])
    })();

    match run {
        Ok(pred) => {
            let of = info.out_features;
            for (bi, req) in batch.into_iter().enumerate() {
                let latency = req.enqueued.elapsed();
                let result = if let Some((_, msg)) = failed.iter().find(|(i, _)| *i == bi) {
                    Err(anyhow::anyhow!("{msg}"))
                } else {
                    let tree = trees[bi].as_ref().unwrap();
                    let sample = pred.slice_rows(bi * info.n, info.n);
                    let _ = of;
                    Ok(tree.unpermute_predictions(&sample))
                };
                shared.latency.lock().unwrap().record(latency);
                shared.served.fetch_add(1, Ordering::Relaxed);
                let _ = req.reply.try_send(ServeResponse { id: req.id, result, latency });
            }
        }
        Err(e) => {
            let msg = format!("batch execution failed: {e}");
            for req in batch {
                let latency = req.enqueued.elapsed();
                let _ = req.reply.try_send(ServeResponse {
                    id: req.id,
                    result: Err(anyhow::anyhow!("{msg}")),
                    latency,
                });
            }
        }
    }
}

/// FNV-1a over the raw coordinate bytes (deterministic serving seed).
fn content_hash(t: &Tensor) -> u64 {
    let mut h: u64 = 0xcbf29ce484222325;
    for x in t.data() {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

#[cfg(test)]
mod tests {
    // Router integration tests (with a real compiled graph) live in
    // rust/tests/integration.rs. Queue/backpressure unit behaviour is
    // covered there too since Router requires an Engine.
    use super::*;

    #[test]
    fn content_hash_is_stable_and_sensitive() {
        let a = Tensor::new(vec![4], vec![1., 2., 3., 4.]);
        let b = Tensor::new(vec![4], vec![1., 2., 3., 4.]);
        let c = Tensor::new(vec![4], vec![1., 2., 3., 5.]);
        assert_eq!(content_hash(&a), content_hash(&b));
        assert_ne!(content_hash(&a), content_hash(&c));
    }
}
