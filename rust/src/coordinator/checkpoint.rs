//! Checkpoint format (`.bsackpt`): named f32 arrays + training step.
//!
//! Layout (little-endian):
//!   magic "BSAC" | version u32 | step u64 | count u32
//!   per array: name_len u32 | name bytes | ndims u32 | dims u32... | f32 data

use std::io::{Read, Write};
use std::path::Path;

use crate::tensor::Tensor;

const MAGIC: &[u8; 4] = b"BSAC";
const VERSION: u32 = 1;

/// A named tensor collection with a step counter.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    pub arrays: Vec<(String, Tensor)>,
}

impl Checkpoint {
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&self.step.to_le_bytes())?;
        w.write_all(&(self.arrays.len() as u32).to_le_bytes())?;
        for (name, t) in &self.arrays {
            let nb = name.as_bytes();
            w.write_all(&(nb.len() as u32).to_le_bytes())?;
            w.write_all(nb)?;
            w.write_all(&(t.shape().len() as u32).to_le_bytes())?;
            for &d in t.shape() {
                w.write_all(&(d as u32).to_le_bytes())?;
            }
            let mut buf = Vec::with_capacity(t.len() * 4);
            for x in t.data() {
                buf.extend_from_slice(&x.to_le_bytes());
            }
            w.write_all(&buf)?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<Checkpoint> {
        let mut r = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "not a .bsackpt file: {}", path.display());
        let version = read_u32(&mut r)?;
        anyhow::ensure!(version == VERSION, "unsupported checkpoint version {version}");
        let mut step_b = [0u8; 8];
        r.read_exact(&mut step_b)?;
        let step = u64::from_le_bytes(step_b);
        let count = read_u32(&mut r)? as usize;
        anyhow::ensure!(count < 100_000, "corrupt checkpoint: {count} arrays");
        let mut arrays = Vec::with_capacity(count);
        for _ in 0..count {
            let nlen = read_u32(&mut r)? as usize;
            anyhow::ensure!(nlen < 4096, "corrupt name length");
            let mut nb = vec![0u8; nlen];
            r.read_exact(&mut nb)?;
            let name = String::from_utf8(nb)?;
            let ndims = read_u32(&mut r)? as usize;
            anyhow::ensure!(ndims <= 8, "corrupt rank {ndims}");
            let mut dims = Vec::with_capacity(ndims);
            for _ in 0..ndims {
                dims.push(read_u32(&mut r)? as usize);
            }
            let n: usize = dims.iter().product();
            anyhow::ensure!(n < (1 << 28), "corrupt dims {dims:?}");
            let mut buf = vec![0u8; n * 4];
            r.read_exact(&mut buf)?;
            let data = buf
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            arrays.push((name, Tensor::new(dims, data)));
        }
        Ok(Checkpoint { step, arrays })
    }
}

fn read_u32<R: Read>(r: &mut R) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let ck = Checkpoint {
            step: 1234,
            arrays: vec![
                ("blocks.0.attn.wq".into(), Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.])),
                ("scalar".into(), Tensor::new(vec![], vec![7.0])),
            ],
        };
        let path = std::env::temp_dir().join("bsa_ckpt_test.bsackpt");
        ck.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded, ck);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join("bsa_ckpt_bad.bsackpt");
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn creates_parent_dirs() {
        let dir = std::env::temp_dir().join("bsa_ckpt_nested/x/y");
        let path = dir.join("c.bsackpt");
        let ck = Checkpoint { step: 0, arrays: vec![] };
        ck.save(&path).unwrap();
        assert!(path.exists());
        std::fs::remove_dir_all(std::env::temp_dir().join("bsa_ckpt_nested")).ok();
    }
}
