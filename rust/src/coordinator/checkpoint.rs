//! Checkpoint format (`.bsackpt`): named arrays + training step, with a
//! per-array storage dtype since version 2.
//!
//! Layout (little-endian):
//!   magic "BSAC" | version u32 | step u64 | count u32
//!   per array: name_len u32 | name bytes | ndims u32 | dims u32...
//!              | dtype u8 (v2+) | data
//!
//! The dtype byte selects the on-disk element encoding: `0` = f32
//! (4-byte LE), `1` = IEEE binary16 (2-byte LE, see [`crate::half`]).
//! In-memory tensors are always f32 — f16 arrays are up-converted on
//! load (exact) and rounded to nearest-even on save. **Version 1 files
//! have no dtype byte** (every array is f32); the loader still accepts
//! them, so checkpoints written before the dtype axis keep loading
//! forever. See `docs/FORMATS.md` §1 for the normative spec.
//!
//! **Version 3** changes no field layout — it marks the *content*
//! convention the native trainer writes: the model arrays followed by
//! Adam first/second moments as `m.<name>` / `v.<name>` pairs, with
//! `step` counting completed optimizer steps (see `docs/TRAINING.md`
//! §4). Readers that only want the model (`NativeParams::from_named`,
//! `bsa serve`) skip the `m.*`/`v.*` arrays, so every v3 training
//! checkpoint doubles as an inference param file; v1/v2 files (no
//! moments) resume training with freshly zeroed moments. The loader
//! accepts versions 1..=3 and rejects anything newer.

use std::io::{Read, Write};
use std::path::Path;

use crate::half;
use crate::tensor::Tensor;

const MAGIC: &[u8; 4] = b"BSAC";
const VERSION: u32 = 3;

/// On-disk element encoding of one checkpoint array (the v2 dtype byte).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Dtype {
    /// 4-byte little-endian IEEE single precision (dtype byte 0).
    #[default]
    F32,
    /// 2-byte little-endian IEEE binary16 (dtype byte 1); up-converted
    /// to f32 on load, rounded to nearest-even on save.
    F16,
}

impl Dtype {
    fn byte(self) -> u8 {
        match self {
            Dtype::F32 => 0,
            Dtype::F16 => 1,
        }
    }

    fn from_byte(b: u8) -> anyhow::Result<Dtype> {
        match b {
            0 => Ok(Dtype::F32),
            1 => Ok(Dtype::F16),
            _ => anyhow::bail!("corrupt checkpoint: unknown dtype byte {b}"),
        }
    }
}

/// A named tensor collection with a step counter.
#[derive(Debug, Clone, PartialEq)]
pub struct Checkpoint {
    pub step: u64,
    pub arrays: Vec<(String, Tensor)>,
}

impl Checkpoint {
    /// Save with f32 storage for every array (the default dtype).
    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        self.save_with_dtype(path, Dtype::F32)
    }

    /// Save every array with the given storage dtype. [`Dtype::F16`]
    /// halves the file and rounds each element to the nearest binary16
    /// value (relative error <= 2^-11 in the normal range; the load is
    /// then exact).
    pub fn save_with_dtype(&self, path: &Path, dtype: Dtype) -> anyhow::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(MAGIC)?;
        w.write_all(&VERSION.to_le_bytes())?;
        w.write_all(&self.step.to_le_bytes())?;
        w.write_all(&(self.arrays.len() as u32).to_le_bytes())?;
        for (name, t) in &self.arrays {
            let nb = name.as_bytes();
            w.write_all(&(nb.len() as u32).to_le_bytes())?;
            w.write_all(nb)?;
            w.write_all(&(t.shape().len() as u32).to_le_bytes())?;
            for &d in t.shape() {
                w.write_all(&(d as u32).to_le_bytes())?;
            }
            w.write_all(&[dtype.byte()])?;
            match dtype {
                Dtype::F32 => {
                    let mut buf = Vec::with_capacity(t.len() * 4);
                    for x in t.data() {
                        buf.extend_from_slice(&x.to_le_bytes());
                    }
                    w.write_all(&buf)?;
                }
                Dtype::F16 => {
                    let mut buf = Vec::with_capacity(t.len() * 2);
                    for &x in t.data() {
                        buf.extend_from_slice(&half::f32_to_f16_bits(x).to_le_bytes());
                    }
                    w.write_all(&buf)?;
                }
            }
        }
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<Checkpoint> {
        let mut r = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == MAGIC, "not a .bsackpt file: {}", path.display());
        let version = read_u32(&mut r)?;
        anyhow::ensure!(
            (1..=VERSION).contains(&version),
            "unsupported checkpoint version {version}"
        );
        let mut step_b = [0u8; 8];
        r.read_exact(&mut step_b)?;
        let step = u64::from_le_bytes(step_b);
        let count = read_u32(&mut r)? as usize;
        anyhow::ensure!(count < 100_000, "corrupt checkpoint: {count} arrays");
        let mut arrays = Vec::with_capacity(count);
        for _ in 0..count {
            let nlen = read_u32(&mut r)? as usize;
            anyhow::ensure!(nlen < 4096, "corrupt name length");
            let mut nb = vec![0u8; nlen];
            r.read_exact(&mut nb)?;
            let name = String::from_utf8(nb)?;
            let ndims = read_u32(&mut r)? as usize;
            anyhow::ensure!(ndims <= 8, "corrupt rank {ndims}");
            let mut dims = Vec::with_capacity(ndims);
            for _ in 0..ndims {
                dims.push(read_u32(&mut r)? as usize);
            }
            let n: usize = dims.iter().product();
            anyhow::ensure!(n < (1 << 28), "corrupt dims {dims:?}");
            // v1 records carry no dtype byte: legacy files are all-f32.
            let dtype = if version == 1 {
                Dtype::F32
            } else {
                let mut b = [0u8; 1];
                r.read_exact(&mut b)?;
                Dtype::from_byte(b[0])?
            };
            let data: Vec<f32> = match dtype {
                Dtype::F32 => {
                    let mut buf = vec![0u8; n * 4];
                    r.read_exact(&mut buf)?;
                    buf.chunks_exact(4)
                        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                        .collect()
                }
                Dtype::F16 => {
                    let mut buf = vec![0u8; n * 2];
                    r.read_exact(&mut buf)?;
                    buf.chunks_exact(2)
                        .map(|c| half::f16_bits_to_f32(u16::from_le_bytes([c[0], c[1]])))
                        .collect()
                }
            };
            arrays.push((name, Tensor::new(dims, data)));
        }
        Ok(Checkpoint { step, arrays })
    }
}

fn read_u32<R: Read>(r: &mut R) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip() {
        let ck = Checkpoint {
            step: 1234,
            arrays: vec![
                ("blocks.0.attn.wq".into(), Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.])),
                ("scalar".into(), Tensor::new(vec![], vec![7.0])),
            ],
        };
        let path = std::env::temp_dir().join("bsa_ckpt_test.bsackpt");
        ck.save(&path).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded, ck);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn f16_roundtrip_quantizes_to_half_grid() {
        // Values exactly representable in f16 survive bit-for-bit; a
        // value off the grid comes back as its nearest-even rounding.
        let ck = Checkpoint {
            step: 9,
            arrays: vec![(
                "w".into(),
                Tensor::new(vec![4], vec![0.5, -1.25, 1.0 + 0.000_488_281_25, 3.0e-5]),
            )],
        };
        let path = std::env::temp_dir().join("bsa_ckpt_f16_test.bsackpt");
        ck.save_with_dtype(&path, Dtype::F16).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.step, 9);
        let got = loaded.arrays[0].1.data();
        let want: Vec<f32> = ck.arrays[0]
            .1
            .data()
            .iter()
            .map(|&x| half::f16_bits_to_f32(half::f32_to_f16_bits(x)))
            .collect();
        assert_eq!(got, &want[..]);
        // and the f16 file is smaller than its f32 twin
        let f16_len = std::fs::metadata(&path).unwrap().len();
        ck.save(&path).unwrap();
        let f32_len = std::fs::metadata(&path).unwrap().len();
        assert!(f16_len < f32_len, "{f16_len} vs {f32_len}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn loads_legacy_v1_files_without_dtype_byte() {
        // Hand-write a v1 file: no per-array dtype byte, f32 data.
        let path = std::env::temp_dir().join("bsa_ckpt_v1_test.bsackpt");
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(b"BSAC");
        buf.extend_from_slice(&1u32.to_le_bytes()); // version 1
        buf.extend_from_slice(&77u64.to_le_bytes()); // step
        buf.extend_from_slice(&1u32.to_le_bytes()); // count
        buf.extend_from_slice(&1u32.to_le_bytes()); // name_len
        buf.push(b'w');
        buf.extend_from_slice(&1u32.to_le_bytes()); // ndims
        buf.extend_from_slice(&2u32.to_le_bytes()); // dims = [2]
        buf.extend_from_slice(&1.5f32.to_le_bytes());
        buf.extend_from_slice(&(-2.5f32).to_le_bytes());
        std::fs::write(&path, &buf).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.step, 77);
        assert_eq!(loaded.arrays[0].0, "w");
        assert_eq!(loaded.arrays[0].1.data(), &[1.5, -2.5]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn loads_legacy_v2_files_with_dtype_byte() {
        // Hand-write a v2 file (dtype byte present, no optimizer
        // arrays) — pre-v3 checkpoints must keep loading forever.
        let path = std::env::temp_dir().join("bsa_ckpt_v2_test.bsackpt");
        let mut buf: Vec<u8> = Vec::new();
        buf.extend_from_slice(b"BSAC");
        buf.extend_from_slice(&2u32.to_le_bytes()); // version 2
        buf.extend_from_slice(&55u64.to_le_bytes()); // step
        buf.extend_from_slice(&1u32.to_le_bytes()); // count
        buf.extend_from_slice(&1u32.to_le_bytes()); // name_len
        buf.push(b'w');
        buf.extend_from_slice(&1u32.to_le_bytes()); // ndims
        buf.extend_from_slice(&2u32.to_le_bytes()); // dims = [2]
        buf.push(0); // dtype byte: f32
        buf.extend_from_slice(&0.25f32.to_le_bytes());
        buf.extend_from_slice(&(-4.0f32).to_le_bytes());
        std::fs::write(&path, &buf).unwrap();
        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.step, 55);
        assert_eq!(loaded.arrays[0].0, "w");
        assert_eq!(loaded.arrays[0].1.data(), &[0.25, -4.0]);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_future_version() {
        let ck = Checkpoint {
            step: 0,
            arrays: vec![("w".into(), Tensor::new(vec![1], vec![1.0]))],
        };
        let path = std::env::temp_dir().join("bsa_ckpt_future.bsackpt");
        ck.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        bytes[4..8].copy_from_slice(&(VERSION + 1).to_le_bytes());
        std::fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err().to_string();
        assert!(err.contains("version"), "unexpected error: {err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_unknown_dtype_byte() {
        let ck = Checkpoint {
            step: 0,
            arrays: vec![("w".into(), Tensor::new(vec![1], vec![1.0]))],
        };
        let path = std::env::temp_dir().join("bsa_ckpt_baddtype.bsackpt");
        ck.save(&path).unwrap();
        let mut bytes = std::fs::read(&path).unwrap();
        // dtype byte sits right before the final 4 data bytes
        let pos = bytes.len() - 5;
        bytes[pos] = 7;
        std::fs::write(&path, &bytes).unwrap();
        let err = Checkpoint::load(&path).unwrap_err().to_string();
        assert!(err.contains("dtype"), "unexpected error: {err}");
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn rejects_garbage() {
        let path = std::env::temp_dir().join("bsa_ckpt_bad.bsackpt");
        std::fs::write(&path, b"definitely not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn creates_parent_dirs() {
        let dir = std::env::temp_dir().join("bsa_ckpt_nested/x/y");
        let path = dir.join("c.bsackpt");
        let ck = Checkpoint { step: 0, arrays: vec![] };
        ck.save(&path).unwrap();
        assert!(path.exists());
        std::fs::remove_dir_all(std::env::temp_dir().join("bsa_ckpt_nested")).ok();
    }
}
