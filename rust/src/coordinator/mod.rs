//! L3 coordinator: the runtime systems around the compiled BSA model.
//!
//! * [`train`] — training orchestrator: data loading, ball-tree
//!   permutation, cosine LR schedule (host-side), fused train-step
//!   execution, eval, checkpointing.
//! * [`serve`] — serving router: bounded request queue, deadline-based
//!   dynamic batcher, worker pool over compiled forward graphs.
//! * [`checkpoint`] — parameter/optimizer-state persistence (`.bsackpt`).
//!
//! The BSA paper's contribution is the attention mechanism (L1/L2);
//! this layer is the production harness a deployment needs, plus the
//! glue that makes the geometry regular (ball-tree permutation) before
//! the static-shape compiled graphs see it.

pub mod checkpoint;
pub mod serve;
pub mod train;

pub use serve::{Router, ServeRequest, ServeResponse, SubmitError};
pub use train::{NativeTrainer, Trainer};
