//! Minimal property-testing harness (proptest is not vendored offline).
//!
//! Provides deterministic random case generation on top of
//! [`Rng`](crate::prng::Rng) plus a `forall` runner that reports the
//! failing case's seed so it can be replayed:
//!
//! ```no_run
//! // (no_run: doctest binaries don't inherit the xla_extension rpath)
//! use bsa::proptest_lite::{forall, Gen};
//! forall(100, |g| {
//!     let xs = g.vec_f32(1..50, -10.0..10.0);
//!     let sum: f32 = xs.iter().sum();
//!     assert!(sum.is_finite());
//! });
//! ```

use crate::prng::Rng;
use std::ops::Range;

/// Per-case generator handed to property bodies.
pub struct Gen {
    rng: Rng,
    pub case: u64,
}

impl Gen {
    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        assert!(r.start < r.end, "empty range");
        r.start + self.rng.below(r.end - r.start)
    }

    pub fn f32_in(&mut self, r: Range<f32>) -> f32 {
        self.rng.range(r.start, r.end)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.uniform() < 0.5
    }

    /// Uniform random `u64` (hash keys, shard keys).
    pub fn u64(&mut self) -> u64 {
        self.rng.next_u64()
    }

    /// Power of two in [lo, hi] (inclusive), both powers of two.
    pub fn pow2_in(&mut self, lo: usize, hi: usize) -> usize {
        assert!(lo.is_power_of_two() && hi.is_power_of_two() && lo <= hi);
        let lo_exp = lo.trailing_zeros();
        let hi_exp = hi.trailing_zeros();
        1 << (lo_exp + self.rng.below((hi_exp - lo_exp + 1) as usize) as u32)
    }

    pub fn vec_f32(&mut self, len: Range<usize>, vals: Range<f32>) -> Vec<f32> {
        let n = self.usize_in(len);
        (0..n).map(|_| self.f32_in(vals.clone())).collect()
    }

    pub fn normals(&mut self, n: usize) -> Vec<f32> {
        self.rng.normals(n)
    }

    /// Choose one element of a slice.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len())]
    }
}

/// Run `cases` random cases; panics with the failing case id on error.
pub fn forall<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(cases: u64, body: F) {
    forall_seeded(0xB5A_5EED, cases, body)
}

/// `forall` with an explicit base seed (use to replay a failure).
pub fn forall_seeded<F: Fn(&mut Gen) + std::panic::RefUnwindSafe>(
    seed: u64,
    cases: u64,
    body: F,
) {
    for case in 0..cases {
        let mut g = Gen { rng: Rng::new(seed).fold(case), case };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| body(&mut g)));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed at case {case} (seed {seed:#x}): {msg}");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let counter = std::sync::atomic::AtomicU64::new(0);
        forall(50, |_| {
            counter.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
        });
        assert_eq!(counter.load(std::sync::atomic::Ordering::Relaxed), 50);
    }

    #[test]
    fn failing_property_reports_case() {
        let result = std::panic::catch_unwind(|| {
            forall(100, |g| {
                let x = g.usize_in(0..100);
                assert!(x != 42 || g.case < 3, "boom");
            });
        });
        // may or may not hit 42 in 100 cases; just ensure no false panic fmt
        let _ = result;
    }

    #[test]
    fn pow2_bounds() {
        forall(100, |g| {
            let p = g.pow2_in(4, 64);
            assert!(p.is_power_of_two());
            assert!((4..=64).contains(&p));
        });
    }

    #[test]
    fn gen_is_deterministic_per_case() {
        let mut a = Gen { rng: Rng::new(1).fold(5), case: 5 };
        let mut b = Gen { rng: Rng::new(1).fold(5), case: 5 };
        assert_eq!(a.usize_in(0..1000), b.usize_in(0..1000));
    }
}
