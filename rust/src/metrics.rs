//! Metrics: latency histograms, throughput counters, error accumulators,
//! and markdown table rendering for the benchmark harness.

use std::time::Duration;

/// Streaming scalar accumulator (count/mean/min/max + sum of squares).
#[derive(Debug, Clone, Default)]
pub struct Accumulator {
    n: u64,
    sum: f64,
    sumsq: f64,
    min: f64,
    max: f64,
}

impl Accumulator {
    pub fn new() -> Self {
        Accumulator { n: 0, sum: 0.0, sumsq: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        self.sum += x;
        self.sumsq += x * x;
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.sum / self.n as f64 }
    }

    pub fn var(&self) -> f64 {
        if self.n == 0 {
            return 0.0;
        }
        let m = self.mean();
        (self.sumsq / self.n as f64 - m * m).max(0.0)
    }

    pub fn std(&self) -> f64 {
        self.var().sqrt()
    }

    pub fn min(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.min }
    }

    pub fn max(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.max }
    }
}

/// MSE / RMSE accumulator over prediction-target pairs.
#[derive(Debug, Clone, Default)]
pub struct ErrorStats {
    n: u64,
    sq_sum: f64,
    abs_sum: f64,
}

impl ErrorStats {
    pub fn push_pair(&mut self, pred: f32, target: f32) {
        let d = (pred - target) as f64;
        self.n += 1;
        self.sq_sum += d * d;
        self.abs_sum += d.abs();
    }

    pub fn push_slices(&mut self, pred: &[f32], target: &[f32]) {
        assert_eq!(pred.len(), target.len());
        for (p, t) in pred.iter().zip(target) {
            self.push_pair(*p, *t);
        }
    }

    pub fn mse(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.sq_sum / self.n as f64 }
    }

    pub fn rmse(&self) -> f64 {
        self.mse().sqrt()
    }

    pub fn mae(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.abs_sum / self.n as f64 }
    }

    pub fn count(&self) -> u64 {
        self.n
    }
}

/// Fixed-bucket log-scale latency histogram with exact percentile support
/// for moderate sample counts (stores raw samples up to a cap, then falls
/// back to bucket interpolation).
#[derive(Debug, Clone)]
pub struct LatencyHistogram {
    samples: Vec<f64>, // microseconds
    cap: usize,
    overflow: Accumulator,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        LatencyHistogram { samples: Vec::new(), cap: 1 << 20, overflow: Accumulator::new() }
    }

    pub fn record(&mut self, d: Duration) {
        self.record_us(d.as_secs_f64() * 1e6);
    }

    pub fn record_us(&mut self, us: f64) {
        if self.samples.len() < self.cap {
            self.samples.push(us);
        } else {
            self.overflow.push(us);
        }
    }

    pub fn count(&self) -> usize {
        self.samples.len() + self.overflow.count() as usize
    }

    /// Recorded samples, sorted ascending. One clone+sort serves every
    /// percentile in a batch query (the trace registry renders dozens of
    /// histograms per BSST snapshot — per-percentile sorting was O(k·n log n)).
    fn sorted(&self) -> Vec<f64> {
        let mut xs = self.samples.clone();
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        xs
    }

    fn rank(xs: &[f64], p: f64) -> f64 {
        if xs.is_empty() {
            return 0.0;
        }
        let rank = (p / 100.0 * (xs.len() - 1) as f64).round() as usize;
        xs[rank.min(xs.len() - 1)]
    }

    /// Exact percentile over recorded samples (0.0..=100.0).
    pub fn percentile_us(&self, p: f64) -> f64 {
        Self::rank(&self.sorted(), p)
    }

    /// Exact percentiles for several `p` values over ONE sort of the
    /// samples. Returns one value per requested percentile, in order.
    pub fn percentiles_us(&self, ps: &[f64]) -> Vec<f64> {
        let xs = self.sorted();
        ps.iter().map(|p| Self::rank(&xs, *p)).collect()
    }

    pub fn mean_us(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn summary(&self) -> String {
        let p = self.percentiles_us(&[50.0, 95.0, 99.0, 100.0]);
        format!(
            "n={} mean={:.1}us p50={:.1}us p95={:.1}us p99={:.1}us max={:.1}us",
            self.count(),
            self.mean_us(),
            p[0],
            p[1],
            p[2],
            p[3],
        )
    }

    /// Machine-readable JSON object of the same summary — the unit the
    /// `BENCH_*.json` perf-trajectory artifacts are built from, so
    /// successive PRs can regress against recorded numbers.
    pub fn json(&self) -> String {
        let p = self.percentiles_us(&[50.0, 95.0, 99.0, 100.0]);
        format!(
            "{{\"n\": {}, \"mean_us\": {:.2}, \"p50_us\": {:.2}, \"p95_us\": {:.2}, \"p99_us\": {:.2}, \"max_us\": {:.2}}}",
            self.count(),
            self.mean_us(),
            p[0],
            p[1],
            p[2],
            p[3],
        )
    }
}

/// Simple wall-clock throughput meter.
#[derive(Debug)]
pub struct Throughput {
    start: std::time::Instant,
    items: u64,
}

impl Default for Throughput {
    fn default() -> Self {
        Self::new()
    }
}

impl Throughput {
    pub fn new() -> Self {
        Throughput { start: std::time::Instant::now(), items: 0 }
    }

    pub fn add(&mut self, n: u64) {
        self.items += n;
    }

    pub fn per_sec(&self) -> f64 {
        let dt = self.start.elapsed().as_secs_f64();
        if dt <= 0.0 { 0.0 } else { self.items as f64 / dt }
    }

    pub fn items(&self) -> u64 {
        self.items
    }
}

/// Markdown table builder used by the bench harness to print paper tables.
#[derive(Debug, Default, Clone)]
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(header: &[&str]) -> Self {
        Table { header: header.iter().map(|s| s.to_string()).collect(), rows: vec![] }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "column count");
        self.rows.push(cells.to_vec());
    }

    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], width: &[usize]| {
            let mut line = String::from("|");
            for (c, w) in cells.iter().zip(width) {
                line.push_str(&format!(" {c:<w$} |"));
            }
            line.push('\n');
            line
        };
        out.push_str(&fmt_row(&self.header, &width));
        let mut sep = String::from("|");
        for w in &width {
            sep.push_str(&format!("{}|", "-".repeat(w + 2)));
        }
        sep.push('\n');
        out.push_str(&sep);
        for r in &self.rows {
            out.push_str(&fmt_row(r, &width));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accumulator_stats() {
        let mut a = Accumulator::new();
        for x in [1.0, 2.0, 3.0, 4.0] {
            a.push(x);
        }
        assert_eq!(a.count(), 4);
        assert!((a.mean() - 2.5).abs() < 1e-12);
        assert!((a.var() - 1.25).abs() < 1e-9);
        assert_eq!(a.min(), 1.0);
        assert_eq!(a.max(), 4.0);
    }

    #[test]
    fn error_stats_mse_rmse() {
        let mut e = ErrorStats::default();
        e.push_slices(&[1.0, 2.0], &[0.0, 0.0]);
        assert!((e.mse() - 2.5).abs() < 1e-9);
        assert!((e.rmse() - 2.5f64.sqrt()).abs() < 1e-9);
        assert!((e.mae() - 1.5).abs() < 1e-9);
    }

    #[test]
    fn histogram_percentiles() {
        let mut h = LatencyHistogram::new();
        for i in 1..=100 {
            h.record_us(i as f64);
        }
        assert_eq!(h.count(), 100);
        assert!((h.percentile_us(50.0) - 50.0).abs() <= 1.0);
        assert!((h.percentile_us(99.0) - 99.0).abs() <= 1.0);
        let batch = h.percentiles_us(&[50.0, 99.0, 100.0]);
        assert_eq!(batch[0], h.percentile_us(50.0));
        assert_eq!(batch[1], h.percentile_us(99.0));
        assert_eq!(batch[2], 100.0);
        assert!((h.mean_us() - 50.5).abs() < 1e-9);
        assert!(h.summary().contains("n=100"));
    }

    #[test]
    fn histogram_json_is_well_formed() {
        let mut h = LatencyHistogram::new();
        for i in 1..=10 {
            h.record_us(i as f64 * 100.0);
        }
        let j = h.json();
        assert!(j.starts_with('{') && j.ends_with('}'));
        for key in ["\"n\"", "\"mean_us\"", "\"p50_us\"", "\"p95_us\"", "\"p99_us\"", "\"max_us\""] {
            assert!(j.contains(key), "missing {key} in {j}");
        }
        assert!(j.contains("\"n\": 10"));
    }

    #[test]
    fn table_renders_aligned_markdown() {
        let mut t = Table::new(&["Model", "MSE"]);
        t.row(&["BSA".into(), "14.31".into()]);
        t.row(&["Full Attention".into(), "13.29".into()]);
        let s = t.render();
        assert!(s.contains("| Model"));
        assert!(s.contains("| BSA"));
        assert!(s.lines().count() == 4);
    }

    #[test]
    #[should_panic]
    fn table_rejects_wrong_arity() {
        let mut t = Table::new(&["a", "b"]);
        t.row(&["only-one".into()]);
    }
}
