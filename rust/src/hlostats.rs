//! HLO-text statistics: parse lowered artifacts and count operations.
//!
//! Cross-checks the closed-form FLOPs model (`flops.rs`) against what XLA
//! actually emitted: dot-product FLOPs are summed from the `dot` /
//! `convolution` instruction shapes in the artifact text, and instruction
//! histograms make regressions in lowering (e.g. an unexpected
//! `while`-loop explosion from interpret mode) visible in tests and in
//! `bsa info --hlo <graph>`.
//!
//! The parser is intentionally shallow: it reads instruction lines of the
//! form `%name = type[dims]{layout} opcode(...)` without building a graph
//! — enough for op counts and GEMM cost, robust to dialect details.

use std::collections::BTreeMap;
use std::path::Path;

/// Summary of one HLO module's instruction mix.
#[derive(Debug, Clone, Default)]
pub struct HloStats {
    /// opcode -> count
    pub ops: BTreeMap<String, usize>,
    /// total f32 elements across all instruction output shapes
    pub output_elements: u64,
    /// 2*M*N*K summed over dot instructions (best-effort from shapes)
    pub dot_flops: f64,
    pub instructions: usize,
    pub computations: usize,
}

impl HloStats {
    pub fn count(&self, op: &str) -> usize {
        self.ops.get(op).copied().unwrap_or(0)
    }

    /// Render a short human-readable table of the top opcodes.
    pub fn summary(&self, top: usize) -> String {
        let mut pairs: Vec<(&String, &usize)> = self.ops.iter().collect();
        pairs.sort_by(|a, b| b.1.cmp(a.1));
        let mut out = format!(
            "{} instructions in {} computations, dot FLOPs {:.3} G\n",
            self.instructions,
            self.computations,
            self.dot_flops / 1e9
        );
        for (op, n) in pairs.into_iter().take(top) {
            out.push_str(&format!("  {op:<24} {n}\n"));
        }
        out
    }
}

/// Parse HLO text (as written by aot.py) into statistics.
pub fn parse_hlo_text(text: &str) -> HloStats {
    let mut stats = HloStats::default();
    // instruction name -> output dims (for dot operand lookup; HLO defines
    // operands before use, and names are module-unique in practice)
    let mut dims_of: BTreeMap<String, Vec<u64>> = BTreeMap::new();
    for raw in text.lines() {
        let line = raw.trim();
        if line.starts_with("HloModule") {
            continue;
        }
        // computation headers look like `%fused_computation (param: f32[]) -> f32[] {`
        // or `ENTRY %main ... {`
        if line.ends_with('{') && (line.starts_with('%') || line.starts_with("ENTRY")) {
            stats.computations += 1;
            continue;
        }
        // instruction lines: `[%]name = type[shape] opcode(operands), attrs`
        let Some(eq) = line.find(" = ") else { continue };
        let name = line[..eq].trim_start_matches("ROOT ").trim_start_matches('%');
        let rhs = &line[eq + 3..];
        let Some((shape_part, rest)) = split_shape(rhs) else { continue };
        let Some(op) = rest.split(['(', ' ']).next() else { continue };
        if op.is_empty() {
            continue;
        }
        stats.instructions += 1;
        *stats.ops.entry(op.to_string()).or_default() += 1;
        let out_dims = shape_dims(shape_part);
        let out_elems: u64 = out_dims.iter().product::<u64>().max(1);
        stats.output_elements += out_elems;
        dims_of.insert(name.to_string(), out_dims);

        if op == "dot" {
            // cost = 2 * output_elems * K; K from the lhs contracting dim.
            if let Some(k) = contracting_k(rest, &dims_of) {
                stats.dot_flops += 2.0 * out_elems as f64 * k as f64;
            }
        }
    }
    stats
}

/// Load + parse an artifact file.
pub fn load(path: &Path) -> anyhow::Result<HloStats> {
    let text = std::fs::read_to_string(path)?;
    Ok(parse_hlo_text(&text))
}

/// Split "f32[2,3]{1,0} rest..." -> ("f32[2,3]", "rest...").
/// Also handles tuple types by taking the flat text up to the space.
fn split_shape(s: &str) -> Option<(&str, &str)> {
    // the shape token ends at the first space that is not inside brackets
    let mut depth = 0i32;
    for (i, c) in s.char_indices() {
        match c {
            '[' | '(' | '{' => depth += 1,
            ']' | ')' | '}' => depth -= 1,
            ' ' if depth == 0 => return Some((&s[..i], s[i + 1..].trim_start())),
            _ => {}
        }
    }
    None
}

/// Extract the dims of the first `[...]` group: "f32[2,3]{1,0}" -> [2, 3].
fn shape_dims(shape: &str) -> Vec<u64> {
    let Some(open) = shape.find('[') else { return vec![] };
    let Some(close) = shape[open..].find(']') else { return vec![] };
    shape[open + 1..open + close]
        .split(',')
        .filter_map(|d| d.trim().parse().ok())
        .collect()
}

/// For a dot instruction body, recover K from `lhs_contracting_dims={d}`
/// and the lhs operand's shape — either inlined (`dot(f32[a,k] %x, ...)`)
/// or looked up by operand name in the shapes seen so far.
fn contracting_k(rest: &str, dims_of: &BTreeMap<String, Vec<u64>>) -> Option<u64> {
    let dims_pos = rest.find("lhs_contracting_dims={")?;
    let after = &rest[dims_pos + "lhs_contracting_dims={".len()..];
    let idx: usize = after.split('}').next()?.split(',').next()?.trim().parse().ok()?;
    let open = rest.find('(')?;
    let operands = &rest[open + 1..];
    // first operand ends at the first ',' or ')' at bracket depth 0
    let mut depth = 0i32;
    let mut end = operands.len();
    for (i, c) in operands.char_indices() {
        match c {
            '[' | '{' | '(' => depth += 1,
            ']' | '}' => depth -= 1,
            ',' | ')' if depth == 0 => {
                end = i;
                break;
            }
            _ => {}
        }
    }
    let first = operands[..end].trim();
    let dims = if first.contains('[') {
        shape_dims(first)
    } else {
        dims_of.get(first.trim_start_matches('%'))?.clone()
    };
    dims.get(idx).copied()
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
HloModule jit_f, entry_computation_layout={(f32[4,8]{1,0})->f32[4,4]{1,0}}

ENTRY %main.5 (x.1: f32[4,8]) -> f32[4,4] {
  %x.1 = f32[4,8]{1,0} parameter(0)
  %t.2 = f32[8,4]{1,0} transpose(%x.1), dimensions={1,0}
  %d.3 = f32[4,4]{1,0} dot(f32[4,8]{1,0} %x.1, f32[8,4]{1,0} %t.2), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %a.4 = f32[4,4]{1,0} add(%d.3, %d.3)
}
"#;

    #[test]
    fn parses_op_histogram() {
        let s = parse_hlo_text(SAMPLE);
        assert_eq!(s.count("parameter"), 1);
        assert_eq!(s.count("dot"), 1);
        assert_eq!(s.count("add"), 1);
        assert_eq!(s.count("transpose"), 1);
        assert_eq!(s.computations, 1);
    }

    #[test]
    fn dot_flops_from_shapes() {
        let s = parse_hlo_text(SAMPLE);
        // 2 * (4*4) * 8 = 256
        assert_eq!(s.dot_flops, 256.0);
    }

    #[test]
    fn shape_dims_parse() {
        assert_eq!(shape_dims("f32[2,3]{1,0}"), vec![2, 3]);
        assert_eq!(shape_dims("f32[]"), Vec::<u64>::new());
        assert_eq!(shape_dims("pred[7]"), vec![7]);
    }

    #[test]
    fn summary_renders() {
        let s = parse_hlo_text(SAMPLE);
        let out = s.summary(3);
        assert!(out.contains("instructions"));
        assert!(out.contains("dot"));
    }

    #[test]
    fn real_artifacts_if_present() {
        // Cross-check against the real lowered artifacts when built:
        // the analytic FLOPs model and the actual dot count must agree on
        // magnitude for the dense baseline (tolerant: fusion changes dots).
        let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        let p = dir.join("fwd_full_air_n4096_b1_ref.hlo.txt");
        if p.exists() {
            let s = load(&p).unwrap();
            assert!(s.count("dot") > 0, "no dots in dense fwd?");
            let analytic = crate::flops::model_flops(
                "full",
                &crate::config::ModelConfig { seq_len: 4096, ..Default::default() },
            )
            .unwrap();
            // dot_flops should be within 3x of the matmul part (fusions,
            // softmax excluded from dots)
            let ratio = s.dot_flops / (analytic.projections + analytic.attention + analytic.mlp);
            assert!(
                (0.3..3.0).contains(&ratio),
                "artifact dot flops {:.2}G vs analytic {:.2}G (ratio {ratio})",
                s.dot_flops / 1e9,
                analytic.total() / 1e9,
            );
        }
    }
}
