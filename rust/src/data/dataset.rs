//! Dataset plumbing: samples, splits, normalization, and the `.bsad`
//! binary shard format (no serde offline — a small explicit codec).

use std::io::{Read, Write};
use std::path::Path;

use crate::tensor::Tensor;

/// One geometry sample: coordinates, per-point input features, target field.
#[derive(Debug, Clone, PartialEq)]
pub struct Sample {
    pub coords: Tensor,   // (N, D)
    pub features: Tensor, // (N, F)
    pub target: Tensor,   // (N, 1)
}

/// Train/test split sizes (deterministic: sample index ranges).
#[derive(Debug, Clone, Copy)]
pub struct SplitSpec {
    pub train: usize,
    pub test: usize,
}

impl SplitSpec {
    /// Paper's ShapeNet split ratio (700/189) scaled to `total`.
    pub fn paper_ratio(total: usize) -> SplitSpec {
        let train = total * 700 / 889;
        SplitSpec { train, test: total - train }
    }
}

/// Target normalization statistics computed on the training split.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NormStats {
    pub mean: f32,
    pub std: f32,
}

impl NormStats {
    pub fn from_targets(samples: &[Sample]) -> NormStats {
        let mut sum = 0.0f64;
        let mut n = 0usize;
        for s in samples {
            sum += s.target.data().iter().map(|&x| x as f64).sum::<f64>();
            n += s.target.len();
        }
        let mean = (sum / n.max(1) as f64) as f32;
        let mut var = 0.0f64;
        for s in samples {
            var += s
                .target
                .data()
                .iter()
                .map(|&x| ((x - mean) as f64).powi(2))
                .sum::<f64>();
        }
        let std = ((var / n.max(1) as f64) as f32).sqrt().max(1e-6);
        NormStats { mean, std }
    }

    pub fn normalize(&self, t: &Tensor) -> Tensor {
        let data = t.data().iter().map(|&x| (x - self.mean) / self.std).collect();
        Tensor::new(t.shape().to_vec(), data)
    }

    pub fn denormalize(&self, t: &Tensor) -> Tensor {
        let data = t.data().iter().map(|&x| x * self.std + self.mean).collect();
        Tensor::new(t.shape().to_vec(), data)
    }
}

/// An in-memory dataset (materialized from a generator or a shard file).
#[derive(Debug, Clone)]
pub struct Dataset {
    pub task: String,
    pub samples: Vec<Sample>,
    pub norm: NormStats,
}

impl Dataset {
    /// Materialize `count` samples with `n_points` each from a generator,
    /// computing normalization on the first `split.train` samples.
    pub fn materialize(
        gen: &dyn super::Generator,
        count: usize,
        n_points: usize,
        split: SplitSpec,
    ) -> Dataset {
        let samples: Vec<Sample> =
            (0..count as u64).map(|i| gen.generate(i, n_points)).collect();
        let norm = NormStats::from_targets(&samples[..split.train.min(samples.len())]);
        Dataset { task: gen.task().to_string(), samples, norm }
    }

    pub fn train_test(&self, split: SplitSpec) -> (&[Sample], &[Sample]) {
        let t = split.train.min(self.samples.len());
        let e = (t + split.test).min(self.samples.len());
        (&self.samples[..t], &self.samples[t..e])
    }

    // ---------------------------------------------------------------
    // .bsad shard format:
    //   magic "BSAD" | version u32 | task len u32 + bytes | count u32
    //   | norm mean f32, std f32
    //   per sample: n u32, d u32, f u32 | coords | features | target (f32 LE)
    // ---------------------------------------------------------------

    const MAGIC: &'static [u8; 4] = b"BSAD";
    const VERSION: u32 = 1;

    pub fn save(&self, path: &Path) -> anyhow::Result<()> {
        let mut w = std::io::BufWriter::new(std::fs::File::create(path)?);
        w.write_all(Self::MAGIC)?;
        w.write_all(&Self::VERSION.to_le_bytes())?;
        let task = self.task.as_bytes();
        w.write_all(&(task.len() as u32).to_le_bytes())?;
        w.write_all(task)?;
        w.write_all(&(self.samples.len() as u32).to_le_bytes())?;
        w.write_all(&self.norm.mean.to_le_bytes())?;
        w.write_all(&self.norm.std.to_le_bytes())?;
        for s in &self.samples {
            let n = s.coords.rows() as u32;
            let d = s.coords.cols() as u32;
            let f = s.features.cols() as u32;
            w.write_all(&n.to_le_bytes())?;
            w.write_all(&d.to_le_bytes())?;
            w.write_all(&f.to_le_bytes())?;
            write_f32s(&mut w, s.coords.data())?;
            write_f32s(&mut w, s.features.data())?;
            write_f32s(&mut w, s.target.data())?;
        }
        Ok(())
    }

    pub fn load(path: &Path) -> anyhow::Result<Dataset> {
        let mut r = std::io::BufReader::new(std::fs::File::open(path)?);
        let mut magic = [0u8; 4];
        r.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == Self::MAGIC, "bad magic in {}", path.display());
        let version = read_u32(&mut r)?;
        anyhow::ensure!(version == Self::VERSION, "unsupported version {version}");
        let tlen = read_u32(&mut r)? as usize;
        anyhow::ensure!(tlen < 256, "task name too long");
        let mut tbuf = vec![0u8; tlen];
        r.read_exact(&mut tbuf)?;
        let task = String::from_utf8(tbuf)?;
        let count = read_u32(&mut r)? as usize;
        let mean = read_f32(&mut r)?;
        let std = read_f32(&mut r)?;
        let mut samples = Vec::with_capacity(count);
        for _ in 0..count {
            let n = read_u32(&mut r)? as usize;
            let d = read_u32(&mut r)? as usize;
            let f = read_u32(&mut r)? as usize;
            anyhow::ensure!(n > 0 && n < (1 << 24) && d <= 16 && f <= 64, "corrupt header");
            let coords = Tensor::new(vec![n, d], read_f32s(&mut r, n * d)?);
            let features = Tensor::new(vec![n, f], read_f32s(&mut r, n * f)?);
            let target = Tensor::new(vec![n, 1], read_f32s(&mut r, n)?);
            samples.push(Sample { coords, features, target });
        }
        Ok(Dataset { task, samples, norm: NormStats { mean, std } })
    }
}

fn write_f32s<W: Write>(w: &mut W, xs: &[f32]) -> std::io::Result<()> {
    // bulk little-endian write
    let mut buf = Vec::with_capacity(xs.len() * 4);
    for x in xs {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    w.write_all(&buf)
}

fn read_u32<R: Read>(r: &mut R) -> std::io::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_f32<R: Read>(r: &mut R) -> std::io::Result<f32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(f32::from_le_bytes(b))
}

fn read_f32s<R: Read>(r: &mut R, n: usize) -> std::io::Result<Vec<f32>> {
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SyntheticGenerator;

    #[test]
    fn norm_stats_standardize() {
        let gen = SyntheticGenerator::new(0);
        let ds = Dataset::materialize(&gen, 8, 64, SplitSpec { train: 6, test: 2 });
        let n = ds.norm;
        // normalizing the training targets yields ~0 mean, ~1 std
        let mut all = Vec::new();
        for s in &ds.samples[..6] {
            all.extend_from_slice(n.normalize(&s.target).data());
        }
        let mean: f32 = all.iter().sum::<f32>() / all.len() as f32;
        let var: f32 = all.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / all.len() as f32;
        assert!(mean.abs() < 1e-3, "mean {mean}");
        assert!((var - 1.0).abs() < 1e-2, "var {var}");
    }

    #[test]
    fn normalize_roundtrip() {
        let n = NormStats { mean: 3.0, std: 2.0 };
        let t = Tensor::new(vec![4], vec![1., 3., 5., 7.]);
        let back = n.denormalize(&n.normalize(&t));
        for (a, b) in back.data().iter().zip(t.data()) {
            assert!((a - b).abs() < 1e-6);
        }
    }

    #[test]
    fn shard_roundtrip() {
        let gen = SyntheticGenerator::new(1);
        let ds = Dataset::materialize(&gen, 4, 32, SplitSpec { train: 3, test: 1 });
        let dir = std::env::temp_dir().join("bsa_test_shard");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("t.bsad");
        ds.save(&path).unwrap();
        let loaded = Dataset::load(&path).unwrap();
        assert_eq!(loaded.task, "syn");
        assert_eq!(loaded.samples.len(), 4);
        assert_eq!(loaded.samples[2], ds.samples[2]);
        assert_eq!(loaded.norm, ds.norm);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn corrupt_file_rejected() {
        let dir = std::env::temp_dir().join("bsa_test_shard");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bad.bsad");
        std::fs::write(&path, b"NOPE----").unwrap();
        assert!(Dataset::load(&path).is_err());
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn paper_ratio_split() {
        let s = SplitSpec::paper_ratio(889);
        assert_eq!(s.train, 700);
        assert_eq!(s.test, 189);
    }

    #[test]
    fn train_test_slices() {
        let gen = SyntheticGenerator::new(2);
        let ds = Dataset::materialize(&gen, 10, 16, SplitSpec { train: 7, test: 3 });
        let (tr, te) = ds.train_test(SplitSpec { train: 7, test: 3 });
        assert_eq!(tr.len(), 7);
        assert_eq!(te.len(), 3);
    }
}
