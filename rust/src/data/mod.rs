//! Synthetic dataset substrates.
//!
//! The paper evaluates on ShapeNet-Car (Umetani & Bickel 2018: 889 cars ×
//! 3586 surface points with RANS pressure at Re=5e6) and the Elasticity
//! benchmark (Li et al. 2021: 972-node hyperelastic unit cells). Neither
//! dataset ships with this repo (proprietary / external), so per the
//! substitution rule both are replaced by *procedural generators* that
//! preserve the learning problem's structure — smooth scalar fields on
//! irregular geometry whose value depends on both local shape and global
//! context. See DESIGN.md §Substitutions.

pub mod airflow;
pub mod dataset;
pub mod elasticity;

pub use dataset::{Dataset, NormStats, Sample, SplitSpec};

use crate::tensor::Tensor;

/// A procedural sample generator: seed -> one geometry + target field.
pub trait Generator: Send + Sync {
    /// Human-readable task id ("air", "ela", ...), matches aot.py tasks.
    fn task(&self) -> &'static str;
    /// Per-point input feature count (must match the lowered artifacts).
    fn feature_dim(&self) -> usize;
    /// Spatial dimensionality of the coordinates.
    fn coord_dim(&self) -> usize;
    /// Generate sample `index` with `n_points` points.
    fn generate(&self, index: u64, n_points: usize) -> Sample;
}

/// Look up a generator by task name.
pub fn generator_for(task: &str, seed: u64) -> anyhow::Result<Box<dyn Generator>> {
    match task {
        "air" => Ok(Box::new(airflow::AirflowGenerator::new(seed))),
        "ela" => Ok(Box::new(elasticity::ElasticityGenerator::new(seed))),
        "syn" => Ok(Box::new(SyntheticGenerator::new(seed))),
        other => Err(anyhow::anyhow!("unknown task {other:?}")),
    }
}

/// Trivial random-field generator for fast tests ("syn" task).
pub struct SyntheticGenerator {
    seed: u64,
}

impl SyntheticGenerator {
    pub fn new(seed: u64) -> Self {
        SyntheticGenerator { seed }
    }
}

impl Generator for SyntheticGenerator {
    fn task(&self) -> &'static str {
        "syn"
    }

    fn feature_dim(&self) -> usize {
        6
    }

    fn coord_dim(&self) -> usize {
        3
    }

    fn generate(&self, index: u64, n_points: usize) -> Sample {
        let mut rng = crate::prng::Rng::new(self.seed).fold(index);
        let coords = Tensor::new(vec![n_points, 3], rng.normals(n_points * 3));
        let mut feats = Vec::with_capacity(n_points * 6);
        let mut target = Vec::with_capacity(n_points);
        for i in 0..n_points {
            let c = coords.row(i);
            feats.extend_from_slice(c);
            feats.extend_from_slice(&[c[0] * c[1], c[1] * c[2], c[0] * c[2]]);
            // smooth nonlocal-ish target
            target.push((c[0].sin() + c[1] * c[2]).tanh());
        }
        Sample {
            coords,
            features: Tensor::new(vec![n_points, 6], feats),
            target: Tensor::new(vec![n_points, 1], target),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generator_lookup() {
        assert_eq!(generator_for("air", 0).unwrap().task(), "air");
        assert_eq!(generator_for("ela", 0).unwrap().task(), "ela");
        assert_eq!(generator_for("syn", 0).unwrap().task(), "syn");
        assert!(generator_for("nope", 0).is_err());
    }

    #[test]
    fn synthetic_shapes_and_determinism() {
        let g = SyntheticGenerator::new(7);
        let a = g.generate(3, 128);
        let b = g.generate(3, 128);
        assert_eq!(a.coords.shape(), &[128, 3]);
        assert_eq!(a.features.shape(), &[128, 6]);
        assert_eq!(a.target.shape(), &[128, 1]);
        assert_eq!(a.coords, b.coords);
        let c = g.generate(4, 128);
        assert_ne!(a.coords, c.coords);
    }
}
