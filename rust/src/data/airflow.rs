//! Procedural ShapeNet-Car surrogate: car-like surfaces + airflow pressure.
//!
//! Replaces the paper's ShapeNet-Car dataset (889 car bodies, 3586 surface
//! points, RANS pressure at Re = 5e6). Each sample is:
//!
//! * **Geometry** — a closed car-like surface assembled from a
//!   superellipsoid body, a cabin superellipsoid, and four wheel arches;
//!   proportions, exponents and cabin placement vary per seed, giving a
//!   family of shapes with the diversity role of the 889 cars.
//! * **Pressure** — a potential-flow-inspired surrogate of the surface
//!   pressure coefficient for freestream flow along +x:
//!     - stagnation term `cp ≈ s²` on windward surfaces (s = n̂·v̂ < 0),
//!     - sphere-like suction `cp ≈ 1 − 2.25·(1−s²)` on the sides,
//!     - a *wake plateau* behind the widest section whose level depends on
//!       the car's global slenderness — a genuinely **nonlocal** term: the
//!       pressure at a rear point depends on geometry metres upstream,
//!       which is exactly the long-range dependence BSA's global branches
//!       are supposed to capture (and ball-local attention alone cannot),
//!     - cabin interference suction and smooth per-seed harmonic noise.
//!
//! Absolute values are not RANS; the *learning problem shape* (smooth
//! field, local + global geometry dependence, stagnation/wake asymmetry)
//! is preserved. See DESIGN.md §Substitutions.

use crate::prng::Rng;
use crate::tensor::Tensor;

use super::dataset::Sample;
use super::Generator;

/// Car-shape parameters drawn per sample.
#[derive(Debug, Clone)]
pub struct CarShape {
    /// Body half-extents (length, width, height).
    pub half: [f32; 3],
    /// Superellipsoid exponent (2 = ellipsoid, larger = boxier).
    pub power: f32,
    /// Cabin half-extents and x/z offset.
    pub cabin_half: [f32; 3],
    pub cabin_off: [f32; 2],
    /// Harmonic noise phases/amps for the pressure field.
    pub phases: [f32; 6],
}

impl CarShape {
    fn sample(rng: &mut Rng) -> CarShape {
        CarShape {
            half: [
                rng.range(1.6, 2.4),  // length
                rng.range(0.7, 1.0),  // width
                rng.range(0.45, 0.65), // height
            ],
            power: rng.range(2.2, 3.5),
            cabin_half: [rng.range(0.6, 1.0), rng.range(0.5, 0.75), rng.range(0.25, 0.4)],
            cabin_off: [rng.range(-0.5, 0.2), 0.0],
            phases: [
                rng.range(0.0, std::f32::consts::TAU),
                rng.range(0.0, std::f32::consts::TAU),
                rng.range(0.0, std::f32::consts::TAU),
                rng.range(1.0, 3.0),
                rng.range(1.0, 3.0),
                rng.range(0.02, 0.08), // noise amplitude
            ],
        }
    }
}

/// Airflow pressure dataset generator ("air" task; 6 features/point).
pub struct AirflowGenerator {
    seed: u64,
}

impl AirflowGenerator {
    pub fn new(seed: u64) -> Self {
        AirflowGenerator { seed }
    }
}

/// Superellipsoid surface point + outward normal for direction (u, v).
fn superellipsoid_point(half: &[f32; 3], p: f32, theta: f32, phi: f32) -> ([f32; 3], [f32; 3]) {
    // |x/a|^p + |y/b|^p + |z/c|^p = 1, parametrised by spherical angles.
    let sgn_pow = |x: f32, e: f32| x.signum() * x.abs().powf(e);
    let e = 2.0 / p;
    let (st, ct) = theta.sin_cos();
    let (sp, cp) = phi.sin_cos();
    let x = half[0] * sgn_pow(ct * cp, e);
    let y = half[1] * sgn_pow(ct * sp, e);
    let z = half[2] * sgn_pow(st, e);
    // gradient of the implicit function gives the normal direction
    let g = [
        p / half[0] * sgn_pow(x / half[0], p - 1.0),
        p / half[1] * sgn_pow(y / half[1], p - 1.0),
        p / half[2] * sgn_pow(z / half[2], p - 1.0),
    ];
    let norm = (g[0] * g[0] + g[1] * g[1] + g[2] * g[2]).sqrt().max(1e-6);
    ([x, y, z], [g[0] / norm, g[1] / norm, g[2] / norm])
}

/// Surrogate pressure coefficient at a surface point.
fn pressure_cp(shape: &CarShape, pos: &[f32; 3], normal: &[f32; 3], on_cabin: bool) -> f32 {
    // freestream along +x; windward normals face -x
    let s = -normal[0]; // n̂ · (−v̂): 1 at stagnation, -1 at base
    let lateral = 1.0 - normal[0] * normal[0];

    let mut cp = if s > 0.0 {
        // windward: stagnation rise minus side suction
        s * s - 1.25 * lateral * (1.0 - s)
    } else {
        // leeward base
        -0.2 + 0.3 * s
    };

    // wake plateau: points behind the widest section sit in separated flow;
    // plateau level depends on *global* slenderness (len/width ratio)
    let slender = shape.half[0] / shape.half[1];
    if pos[0] > 0.3 * shape.half[0] && normal[0] > -0.3 {
        let wake = -0.35 - 0.1 * (slender - 2.0);
        cp = 0.5 * cp + 0.5 * wake;
    }

    // cabin interference: extra suction over the cabin (accelerated flow)
    if on_cabin {
        cp -= 0.25;
    }

    // smooth harmonic "turbulence" noise, deterministic per seed
    let ph = &shape.phases;
    cp += ph[5]
        * ((ph[3] * pos[0] + ph[0]).sin()
            + (ph[4] * pos[1] + ph[1]).sin() * (ph[3] * pos[2] + ph[2]).cos());
    cp
}

impl Generator for AirflowGenerator {
    fn task(&self) -> &'static str {
        "air"
    }

    fn feature_dim(&self) -> usize {
        6 // coords (3) + surface normal (3)
    }

    fn coord_dim(&self) -> usize {
        3
    }

    fn generate(&self, index: u64, n_points: usize) -> Sample {
        let mut rng = Rng::new(self.seed).fold(index);
        let shape = CarShape::sample(&mut rng);

        // ~82% of points on the body, rest on the cabin
        let n_cabin = n_points / 6;
        let n_body = n_points - n_cabin;

        let mut coords = Vec::with_capacity(n_points * 3);
        let mut feats = Vec::with_capacity(n_points * 6);
        let mut target = Vec::with_capacity(n_points);

        let mut push = |pos: [f32; 3], normal: [f32; 3], on_cabin: bool, shape: &CarShape| {
            let cp = pressure_cp(shape, &pos, &normal, on_cabin);
            coords.extend_from_slice(&pos);
            feats.extend_from_slice(&pos);
            feats.extend_from_slice(&normal);
            target.push(cp);
        };

        for _ in 0..n_body {
            // stratified-ish angles: uniform on the sphere then mapped
            let theta = (rng.range(-1.0, 1.0) as f32).asin();
            let phi = rng.range(0.0, std::f32::consts::TAU);
            let (pos, normal) = superellipsoid_point(&shape.half, shape.power, theta, phi);
            push(pos, normal, false, &shape);
        }
        for _ in 0..n_cabin {
            let theta = rng.range(0.05, 1.45); // upper hemisphere only
            let phi = rng.range(0.0, std::f32::consts::TAU);
            let (mut pos, normal) =
                superellipsoid_point(&shape.cabin_half, 2.4, theta, phi);
            pos[0] += shape.cabin_off[0];
            pos[2] += shape.half[2] + 0.6 * shape.cabin_half[2];
            push(pos, normal, true, &shape);
        }

        Sample {
            coords: Tensor::new(vec![n_points, 3], coords),
            features: Tensor::new(vec![n_points, 6], feats),
            target: Tensor::new(vec![n_points, 1], target),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn shapes_and_determinism() {
        let g = AirflowGenerator::new(0);
        let a = g.generate(0, 512);
        assert_eq!(a.coords.shape(), &[512, 3]);
        assert_eq!(a.features.shape(), &[512, 6]);
        assert_eq!(a.target.shape(), &[512, 1]);
        assert_eq!(a.coords, g.generate(0, 512).coords);
        assert_ne!(a.coords, g.generate(1, 512).coords);
        assert!(a.target.all_finite());
    }

    #[test]
    fn normals_are_unit() {
        let g = AirflowGenerator::new(1);
        let s = g.generate(0, 256);
        for i in 0..256 {
            let f = s.features.row(i);
            let n2 = f[3] * f[3] + f[4] * f[4] + f[5] * f[5];
            assert!((n2 - 1.0).abs() < 1e-3, "normal norm² {n2}");
        }
    }

    #[test]
    fn stagnation_pressure_higher_than_wake() {
        // The front (windward, n_x < -0.8) must carry higher cp than the
        // rear points on average — the basic physics of the surrogate.
        let g = AirflowGenerator::new(2);
        let s = g.generate(0, 2048);
        let (mut front, mut nf, mut rear, mut nr) = (0.0, 0, 0.0, 0);
        for i in 0..2048 {
            let f = s.features.row(i);
            let cp = s.target.row(i)[0];
            if f[3] < -0.8 {
                front += cp;
                nf += 1;
            } else if f[3] > 0.8 {
                rear += cp;
                nr += 1;
            }
        }
        assert!(nf > 10 && nr > 10);
        assert!(front / nf as f32 > rear / nr as f32 + 0.3);
    }

    #[test]
    fn wake_depends_on_global_slenderness() {
        // Two shapes differing only in length must differ in rear-side cp:
        // the nonlocal term the dataset exists to provide.
        let mut shape = CarShape {
            half: [1.6, 0.9, 0.5],
            power: 2.5,
            cabin_half: [0.8, 0.6, 0.3],
            cabin_off: [0.0, 0.0],
            phases: [0.0; 6],
        };
        let pos = [1.0, 0.6, 0.0];
        let normal = [0.1, 0.99, 0.0];
        let cp_short = pressure_cp(&shape, &pos, &normal, false);
        shape.half[0] = 2.4; // longer car, same local geometry at the point
        let pos_long = [1.0, 0.6, 0.0];
        let cp_long = pressure_cp(&shape, &pos_long, &normal, false);
        assert!((cp_short - cp_long).abs() > 0.01, "{cp_short} vs {cp_long}");
    }

    #[test]
    fn pressure_range_is_physical() {
        let g = AirflowGenerator::new(3);
        let s = g.generate(0, 1024);
        // cp in a sane bluff-body range
        assert!(s.target.min() > -4.0);
        assert!(s.target.max() < 1.6);
        assert!(s.target.std() > 0.1); // non-trivial field
    }
}
