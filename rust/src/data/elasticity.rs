//! Procedural Elasticity surrogate: plate-with-hole stress fields.
//!
//! Replaces the Elasticity benchmark (Li et al. 2021: hyperelastic unit
//! cells with a void, 972 nodes each). Each sample is a unit plate with a
//! randomly placed/sized circular hole under uniaxial tension along x; the
//! target is the von Mises stress from the **Kirsch solution** — the
//! classical analytic stress-concentration field around a circular hole:
//!
//!   σ_rr = σ/2 (1 − a²/r²) + σ/2 (1 − 4a²/r² + 3a⁴/r⁴) cos 2θ
//!   σ_θθ = σ/2 (1 + a²/r²) − σ/2 (1 + 3a⁴/r⁴) cos 2θ
//!   σ_rθ = −σ/2 (1 + 2a²/r² − 3a⁴/r⁴) sin 2θ
//!
//! Same structure as the paper's task: a scalar field with a local
//! singularity (stress concentration, factor 3 at the hole equator) plus
//! smooth far-field behaviour; sequence length 972 in the paper, padded to
//! 1024 by the ball tree here.

use crate::prng::Rng;
use crate::tensor::Tensor;

use super::dataset::Sample;
use super::Generator;

/// Elasticity dataset generator ("ela" task; 4 features/point).
pub struct ElasticityGenerator {
    seed: u64,
}

impl ElasticityGenerator {
    pub fn new(seed: u64) -> Self {
        ElasticityGenerator { seed }
    }
}

/// Kirsch-solution stress components at polar (r, theta) for hole radius a
/// under unit uniaxial far-field tension along x.
pub fn kirsch_stress(a: f32, r: f32, theta: f32) -> (f32, f32, f32) {
    let q = (a / r).powi(2);
    let q2 = q * q; // a^4 / r^4
    let c2 = (2.0 * theta).cos();
    let s2 = (2.0 * theta).sin();
    let srr = 0.5 * (1.0 - q) + 0.5 * (1.0 - 4.0 * q + 3.0 * q2) * c2;
    let stt = 0.5 * (1.0 + q) - 0.5 * (1.0 + 3.0 * q2) * c2;
    let srt = -0.5 * (1.0 + 2.0 * q - 3.0 * q2) * s2;
    (srr, stt, srt)
}

/// Plane-stress von Mises magnitude from polar components.
pub fn von_mises(srr: f32, stt: f32, srt: f32) -> f32 {
    (srr * srr - srr * stt + stt * stt + 3.0 * srt * srt).max(0.0).sqrt()
}

impl Generator for ElasticityGenerator {
    fn task(&self) -> &'static str {
        "ela"
    }

    fn feature_dim(&self) -> usize {
        4 // coords (2) + distance-to-hole (1) + hole radius (1)
    }

    fn coord_dim(&self) -> usize {
        2
    }

    fn generate(&self, index: u64, n_points: usize) -> Sample {
        let mut rng = Rng::new(self.seed ^ 0xE1A5).fold(index);
        // hole well inside the unit cell [-1, 1]^2
        let a = rng.range(0.15, 0.35);
        let cx = rng.range(-0.3, 0.3);
        let cy = rng.range(-0.3, 0.3);

        let mut coords = Vec::with_capacity(n_points * 2);
        let mut feats = Vec::with_capacity(n_points * 4);
        let mut target = Vec::with_capacity(n_points);

        let mut placed = 0;
        while placed < n_points {
            let x = rng.range(-1.0, 1.0);
            let y = rng.range(-1.0, 1.0);
            let dx = x - cx;
            let dy = y - cy;
            let r = (dx * dx + dy * dy).sqrt();
            if r < a {
                continue; // inside the void
            }
            let theta = dy.atan2(dx);
            let (srr, stt, srt) = kirsch_stress(a, r, theta);
            let vm = von_mises(srr, stt, srt);
            coords.extend_from_slice(&[x, y]);
            feats.extend_from_slice(&[x, y, r - a, a]);
            target.push(vm);
            placed += 1;
        }

        Sample {
            coords: Tensor::new(vec![n_points, 2], coords),
            features: Tensor::new(vec![n_points, 4], feats),
            target: Tensor::new(vec![n_points, 1], target),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kirsch_far_field_is_uniaxial() {
        // r >> a: stress tends to the uniaxial far field (vm -> 1).
        let (srr, stt, srt) = kirsch_stress(0.2, 50.0, 0.7);
        let vm = von_mises(srr, stt, srt);
        assert!((vm - 1.0).abs() < 0.01, "vm {vm}");
    }

    #[test]
    fn kirsch_concentration_factor_three() {
        // At the hole boundary, theta = pi/2: sigma_tt = 3 (classical SCF).
        let (srr, stt, _) = kirsch_stress(0.2, 0.2, std::f32::consts::FRAC_PI_2);
        assert!(srr.abs() < 1e-5, "srr {srr}");
        assert!((stt - 3.0).abs() < 1e-4, "stt {stt}");
        // At theta = 0 the boundary is compressive: sigma_tt = -1.
        let (_, stt0, _) = kirsch_stress(0.2, 0.2, 0.0);
        assert!((stt0 + 1.0).abs() < 1e-4, "stt0 {stt0}");
    }

    #[test]
    fn samples_avoid_the_hole() {
        let g = ElasticityGenerator::new(0);
        let s = g.generate(0, 972);
        // hole parameters are embedded in the features: dist > 0 everywhere
        for i in 0..972 {
            assert!(s.features.row(i)[2] >= 0.0);
        }
    }

    #[test]
    fn shapes_and_determinism() {
        let g = ElasticityGenerator::new(1);
        let a = g.generate(5, 972);
        assert_eq!(a.coords.shape(), &[972, 2]);
        assert_eq!(a.features.shape(), &[972, 4]);
        assert_eq!(a.target.shape(), &[972, 1]);
        assert_eq!(a.target, g.generate(5, 972).target);
        assert!(a.target.all_finite());
    }

    #[test]
    fn stress_field_has_concentration() {
        let g = ElasticityGenerator::new(2);
        let s = g.generate(0, 2048);
        // max stress should exceed the far field substantially
        assert!(s.target.max() > 1.8, "max {}", s.target.max());
        assert!(s.target.min() >= 0.0);
    }
}
