//! Configuration system: a TOML-subset parser and the typed configs.
//!
//! No serde/toml crates are vendored offline, so this module implements
//! the subset of TOML the project needs — `[section]` headers, `key =
//! value` with string / integer / float / boolean / homogeneous-array
//! values, `#` comments — plus typed views (`ModelConfig`, `TrainConfig`,
//! `ServeConfig`, `BenchConfig`) whose defaults reproduce the paper's
//! Table 4 and Appendix A.

use std::collections::BTreeMap;
use std::fmt;
use std::path::Path;

/// A parsed TOML-subset value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Str(String),
    Int(i64),
    Float(f64),
    Bool(bool),
    Array(Vec<Value>),
}

impl fmt::Display for Value {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Value::Str(s) => write!(f, "\"{s}\""),
            Value::Int(i) => write!(f, "{i}"),
            Value::Float(x) => write!(f, "{x}"),
            Value::Bool(b) => write!(f, "{b}"),
            Value::Array(xs) => {
                write!(f, "[")?;
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        write!(f, ", ")?;
                    }
                    write!(f, "{x}")?;
                }
                write!(f, "]")
            }
        }
    }
}

/// Parse error with line context.
#[derive(Debug, thiserror::Error)]
#[error("config parse error at line {line}: {msg}")]
pub struct ParseError {
    pub line: usize,
    pub msg: String,
}

/// Flat section -> key -> value document.
#[derive(Debug, Clone, Default)]
pub struct Document {
    sections: BTreeMap<String, BTreeMap<String, Value>>,
}

impl Document {
    /// Parse a TOML-subset string.
    pub fn parse(text: &str) -> Result<Document, ParseError> {
        let mut doc = Document::default();
        let mut section = String::new();
        doc.sections.entry(section.clone()).or_default();

        for (lineno, raw) in text.lines().enumerate() {
            let line = lineno + 1;
            let s = strip_comment(raw).trim();
            if s.is_empty() {
                continue;
            }
            if let Some(name) = s.strip_prefix('[') {
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| ParseError { line, msg: "unterminated [section]".into() })?
                    .trim();
                if name.is_empty() {
                    return Err(ParseError { line, msg: "empty section name".into() });
                }
                section = name.to_string();
                doc.sections.entry(section.clone()).or_default();
                continue;
            }
            let eq = s
                .find('=')
                .ok_or_else(|| ParseError { line, msg: format!("expected key = value, got {s:?}") })?;
            let key = s[..eq].trim();
            if key.is_empty() {
                return Err(ParseError { line, msg: "empty key".into() });
            }
            let val = parse_value(s[eq + 1..].trim(), line)?;
            doc.sections
                .get_mut(&section)
                .expect("section exists")
                .insert(key.to_string(), val);
        }
        Ok(doc)
    }

    /// Load from a file path.
    pub fn load(path: &Path) -> anyhow::Result<Document> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e}", path.display()))?;
        Ok(Self::parse(&text)?)
    }

    pub fn get(&self, section: &str, key: &str) -> Option<&Value> {
        self.sections.get(section).and_then(|s| s.get(key))
    }

    pub fn sections(&self) -> impl Iterator<Item = &str> {
        self.sections.keys().map(|s| s.as_str())
    }

    pub fn keys(&self, section: &str) -> Vec<&str> {
        self.sections
            .get(section)
            .map(|s| s.keys().map(|k| k.as_str()).collect())
            .unwrap_or_default()
    }

    // typed getters with defaults ------------------------------------

    pub fn str_or(&self, section: &str, key: &str, default: &str) -> String {
        match self.get(section, key) {
            Some(Value::Str(s)) => s.clone(),
            _ => default.to_string(),
        }
    }

    pub fn int_or(&self, section: &str, key: &str, default: i64) -> i64 {
        match self.get(section, key) {
            Some(Value::Int(i)) => *i,
            Some(Value::Float(x)) => *x as i64,
            _ => default,
        }
    }

    pub fn float_or(&self, section: &str, key: &str, default: f64) -> f64 {
        match self.get(section, key) {
            Some(Value::Float(x)) => *x,
            Some(Value::Int(i)) => *i as f64,
            _ => default,
        }
    }

    pub fn bool_or(&self, section: &str, key: &str, default: bool) -> bool {
        match self.get(section, key) {
            Some(Value::Bool(b)) => *b,
            _ => default,
        }
    }
}

fn strip_comment(s: &str) -> &str {
    // '#' starts a comment unless inside a quoted string
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '#' if !in_str => return &s[..i],
            _ => {}
        }
    }
    s
}

fn parse_value(s: &str, line: usize) -> Result<Value, ParseError> {
    if s.is_empty() {
        return Err(ParseError { line, msg: "empty value".into() });
    }
    if let Some(inner) = s.strip_prefix('"') {
        let inner = inner
            .strip_suffix('"')
            .ok_or_else(|| ParseError { line, msg: "unterminated string".into() })?;
        return Ok(Value::Str(inner.to_string()));
    }
    if let Some(inner) = s.strip_prefix('[') {
        let inner = inner
            .strip_suffix(']')
            .ok_or_else(|| ParseError { line, msg: "unterminated array".into() })?
            .trim();
        if inner.is_empty() {
            return Ok(Value::Array(vec![]));
        }
        let mut items = Vec::new();
        for part in split_top_level(inner) {
            items.push(parse_value(part.trim(), line)?);
        }
        return Ok(Value::Array(items));
    }
    match s {
        "true" => return Ok(Value::Bool(true)),
        "false" => return Ok(Value::Bool(false)),
        _ => {}
    }
    if let Ok(i) = s.parse::<i64>() {
        return Ok(Value::Int(i));
    }
    if let Ok(x) = s.parse::<f64>() {
        return Ok(Value::Float(x));
    }
    Err(ParseError { line, msg: format!("cannot parse value {s:?}") })
}

/// Split a flat array body on commas (no nested arrays-of-arrays needed).
fn split_top_level(s: &str) -> Vec<&str> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    let mut in_str = false;
    for (i, c) in s.char_indices() {
        match c {
            '"' => in_str = !in_str,
            '[' if !in_str => depth += 1,
            ']' if !in_str => depth = depth.saturating_sub(1),
            ',' if !in_str && depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    out.push(&s[start..]);
    out
}

// ---------------------------------------------------------------------------
// typed configs (defaults = paper Table 4 / Appendix A)
// ---------------------------------------------------------------------------

/// Model architecture + sparse-attention parameters (paper Table 4).
#[derive(Debug, Clone, PartialEq)]
pub struct ModelConfig {
    pub variant: String, // bsa | bsa_nogs | bsa_gc | full | erwin | pointnet
    pub dim: usize,
    pub num_heads: usize,
    pub num_blocks: usize,
    pub ball_size: usize,
    pub cmp_block: usize,
    pub sel_block: usize,
    pub top_k: usize,
    pub group_size: usize,
    pub seq_len: usize,
}

impl Default for ModelConfig {
    fn default() -> Self {
        ModelConfig {
            variant: "bsa".into(),
            dim: 64,
            num_heads: 4,
            num_blocks: 6,
            ball_size: 256, // paper Table 4
            cmp_block: 8,
            sel_block: 8,
            top_k: 4,
            group_size: 8,
            seq_len: 1024,
        }
    }
}

/// Paper-scale configuration (18 blocks, N=4096): Appendix A.
impl ModelConfig {
    pub fn paper_scale() -> Self {
        ModelConfig { num_blocks: 18, seq_len: 4096, ..Default::default() }
    }

    pub fn from_doc(doc: &Document) -> Self {
        let d = ModelConfig::default();
        ModelConfig {
            variant: doc.str_or("model", "variant", &d.variant),
            dim: doc.int_or("model", "dim", d.dim as i64) as usize,
            num_heads: doc.int_or("model", "num_heads", d.num_heads as i64) as usize,
            num_blocks: doc.int_or("model", "num_blocks", d.num_blocks as i64) as usize,
            ball_size: doc.int_or("model", "ball_size", d.ball_size as i64) as usize,
            cmp_block: doc.int_or("model", "cmp_block", d.cmp_block as i64) as usize,
            sel_block: doc.int_or("model", "sel_block", d.sel_block as i64) as usize,
            top_k: doc.int_or("model", "top_k", d.top_k as i64) as usize,
            group_size: doc.int_or("model", "group_size", d.group_size as i64) as usize,
            seq_len: doc.int_or("model", "seq_len", d.seq_len as i64) as usize,
        }
    }

    /// The divisibility contract shared with python/compile/params.py.
    pub fn validate(&self) -> anyhow::Result<()> {
        let err = |m: String| Err(anyhow::anyhow!(m));
        if self.dim % self.num_heads != 0 {
            return err(format!("dim {} % heads {} != 0", self.dim, self.num_heads));
        }
        if self.seq_len % self.ball_size != 0 {
            return err(format!("seq_len {} % ball {} != 0", self.seq_len, self.ball_size));
        }
        if self.ball_size % self.cmp_block != 0 || self.ball_size % self.group_size != 0 {
            return err("ball size must be divisible by cmp block and group".into());
        }
        if self.top_k > self.seq_len / self.cmp_block {
            return err(format!("top_k {} exceeds block count", self.top_k));
        }
        Ok(())
    }
}

/// Training hyperparameters (paper Appendix A).
#[derive(Debug, Clone, PartialEq)]
pub struct TrainConfig {
    pub task: String, // air | ela | syn
    pub steps: usize,
    pub batch: usize,
    pub lr: f64,
    pub weight_decay: f64,
    pub warmup: usize,
    pub seed: u64,
    pub train_samples: usize,
    pub test_samples: usize,
    pub log_every: usize,
    pub eval_every: usize,
    pub checkpoint_dir: String,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            task: "air".into(),
            steps: 400,
            batch: 2,
            lr: 1e-3,          // paper
            weight_decay: 0.01, // paper
            warmup: 20,
            seed: 0,
            train_samples: 96,
            test_samples: 24,
            log_every: 10,
            eval_every: 100,
            checkpoint_dir: "checkpoints".into(),
        }
    }
}

impl TrainConfig {
    pub fn from_doc(doc: &Document) -> Self {
        let d = TrainConfig::default();
        TrainConfig {
            task: doc.str_or("train", "task", &d.task),
            steps: doc.int_or("train", "steps", d.steps as i64) as usize,
            batch: doc.int_or("train", "batch", d.batch as i64) as usize,
            lr: doc.float_or("train", "lr", d.lr),
            weight_decay: doc.float_or("train", "weight_decay", d.weight_decay),
            warmup: doc.int_or("train", "warmup", d.warmup as i64) as usize,
            seed: doc.int_or("train", "seed", d.seed as i64) as u64,
            train_samples: doc.int_or("train", "train_samples", d.train_samples as i64) as usize,
            test_samples: doc.int_or("train", "test_samples", d.test_samples as i64) as usize,
            log_every: doc.int_or("train", "log_every", d.log_every as i64) as usize,
            eval_every: doc.int_or("train", "eval_every", d.eval_every as i64) as usize,
            checkpoint_dir: doc.str_or("train", "checkpoint_dir", &d.checkpoint_dir),
        }
    }

    /// Cosine schedule with linear warmup (paper: cosine, lr 1e-3).
    pub fn lr_at(&self, step: usize) -> f64 {
        if step < self.warmup {
            return self.lr * (step as f64 + 1.0) / self.warmup as f64;
        }
        let t = (step - self.warmup) as f64 / (self.steps - self.warmup).max(1) as f64;
        let t = t.min(1.0);
        0.5 * self.lr * (1.0 + (std::f64::consts::PI * t).cos())
    }
}

/// Serving configuration for the router/batcher.
#[derive(Debug, Clone, PartialEq)]
pub struct ServeConfig {
    pub addr: String,
    pub workers: usize,
    pub max_batch: usize,
    /// Maximum time a request may wait for batchmates.
    pub flush_us: u64,
    pub queue_cap: usize,
    pub seq_len: usize,
    /// Capacity of the content-addressed ball-tree cache (trees held;
    /// 0 disables). Repeated geometries — one mesh, many feature fields —
    /// skip `BallTree::build` entirely on a hit.
    pub tree_cache: usize,
    /// Kernel threads for the native backend's forward pass (0 = auto:
    /// the `BSA_NATIVE_THREADS` env var if set, else the machine's
    /// available parallelism — see `backend::pool::resolve_threads`).
    /// This is also the demand one forward pass registers with the
    /// persistent worker pool: the pool is shared process-wide, grows
    /// lazily to the *aggregate* demand of concurrent forwards (capped
    /// at `backend::pool::MAX_THREADS`), and never spawns per request.
    /// Purely a latency knob: native outputs are bitwise identical for
    /// every setting.
    pub native_threads: usize,
    /// SIMD microkernel mode for the native backend: `"auto"` (default
    /// — `BSA_NATIVE_SIMD` env var, else runtime AVX2/NEON detection),
    /// `"on"` (best detected level, ignoring the env var), or `"off"`
    /// (scalar loops, bitwise-equal to the `*_reference` twins). See
    /// `backend::simd` for the 1e-5 twin rule SIMD levels operate
    /// under.
    pub native_simd: String,
    /// Storage precision of the native backend's attention staging
    /// buffers and (via load-time quantization) parameters: `"f32"`
    /// (default) or `"f16"` (IEEE binary16 storage, f32 accumulation —
    /// halves staging memory at a documented accuracy cost; see
    /// `backend::native::Precision`).
    pub precision: String,
    /// Trace level for the observability subsystem: `"off"`,
    /// `"counters"`, or `"spans"` (`"on"` is accepted as an alias for
    /// `"spans"`). Empty (the default) defers to the `BSA_TRACE`
    /// environment variable; the `--trace` CLI flag overrides both. See
    /// `trace` (module docs) for the cost model at each level.
    pub trace: String,
    /// Admission control — open-connection cap for the poll core
    /// (`server::ServeLimits`): connections past it are answered with a
    /// status-3 shed frame at accept time and closed.
    pub max_conns: usize,
    /// Admission control — largest declared request body (coords +
    /// feats bytes) accepted. Enforced at header time: bigger requests
    /// get a status-1 error frame before a single payload byte is
    /// buffered.
    pub max_payload_bytes: u64,
    /// Admission control — global budget over admitted-but-unanswered
    /// request bytes; past it, new requests are shed with status 3 and
    /// the connection stays usable.
    pub max_inflight_bytes: u64,
    /// Admission control — per-connection in-flight frame cap, applied
    /// as read backpressure (no shed frame; TCP flow control pushes
    /// back on the client).
    pub conn_quota: usize,
    /// Retry-after hint (milliseconds) carried by status-3 shed frames.
    pub retry_after_ms: u64,
    /// Drain budget after SIGINT/SIGTERM: in-flight requests get this
    /// many milliseconds to complete and flush before the server closes
    /// their connections.
    pub drain_ms: u64,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            addr: "127.0.0.1:7077".into(),
            workers: 2,
            max_batch: 1, // fwd artifacts are lowered per (B, N); core suite has B=1
            flush_us: 2000,
            queue_cap: 1024,
            seq_len: 4096,
            tree_cache: 64,
            native_threads: 0,
            native_simd: "auto".into(),
            precision: "f32".into(),
            trace: String::new(),
            max_conns: 4096,
            max_payload_bytes: 64 << 20,
            max_inflight_bytes: 256 << 20,
            conn_quota: 32,
            retry_after_ms: 50,
            drain_ms: 2000,
        }
    }
}

impl ServeConfig {
    pub fn from_doc(doc: &Document) -> Self {
        let d = ServeConfig::default();
        ServeConfig {
            addr: doc.str_or("serve", "addr", &d.addr),
            workers: doc.int_or("serve", "workers", d.workers as i64) as usize,
            max_batch: doc.int_or("serve", "max_batch", d.max_batch as i64) as usize,
            flush_us: doc.int_or("serve", "flush_us", d.flush_us as i64) as u64,
            queue_cap: doc.int_or("serve", "queue_cap", d.queue_cap as i64) as usize,
            seq_len: doc.int_or("serve", "seq_len", d.seq_len as i64) as usize,
            tree_cache: doc.int_or("serve", "tree_cache", d.tree_cache as i64) as usize,
            native_threads: doc.int_or("serve", "native_threads", d.native_threads as i64)
                as usize,
            native_simd: doc.str_or("serve", "native_simd", &d.native_simd),
            precision: doc.str_or("serve", "precision", &d.precision),
            trace: doc.str_or("serve", "trace", &d.trace),
            max_conns: doc.int_or("serve", "max_conns", d.max_conns as i64) as usize,
            max_payload_bytes: doc.int_or("serve", "max_payload_bytes", d.max_payload_bytes as i64)
                as u64,
            max_inflight_bytes: doc
                .int_or("serve", "max_inflight_bytes", d.max_inflight_bytes as i64)
                as u64,
            conn_quota: doc.int_or("serve", "conn_quota", d.conn_quota as i64) as usize,
            retry_after_ms: doc.int_or("serve", "retry_after_ms", d.retry_after_ms as i64) as u64,
            drain_ms: doc.int_or("serve", "drain_ms", d.drain_ms as i64) as u64,
        }
    }
}

/// Shard-tier configuration: the front-door router process plus its
/// worker fleet (`bsa shard`, `crate::shard`). Settable in a `[shard]`
/// TOML section; the front door forwards frames over the same BSRQ/BSRS
/// protocol the single-process server speaks, so per-worker admission
/// limits stay in `[serve]`.
#[derive(Debug, Clone, PartialEq)]
pub struct ShardConfig {
    /// Front-door bind address (what clients connect to).
    pub addr: String,
    /// Number of workers to spawn or attach.
    pub workers: usize,
    /// First worker port when the front door spawns its own fleet
    /// (worker `i` binds `base_port + i` on 127.0.0.1).
    pub worker_base_port: u16,
    /// Health-probe cadence: the front door sends each worker a BSST
    /// stats probe this often (docs/FORMATS.md §3.2).
    pub probe_interval_ms: u64,
    /// Probe deadline: a probe that hasn't answered within this budget
    /// counts as a miss.
    pub probe_timeout_ms: u64,
    /// Consecutive probe misses before a worker is marked down and its
    /// shard range re-placed.
    pub probe_misses: usize,
    /// Base respawn/reattach backoff after a worker death; doubles per
    /// consecutive failure.
    pub backoff_ms: u64,
    /// Backoff ceiling (the doubling stops here).
    pub max_backoff_ms: u64,
    /// Bounded respawn budget per worker death: after this many failed
    /// respawn/reattach attempts the worker stays down until an operator
    /// intervenes (its keys remain re-placed on the survivors).
    pub respawn_max: usize,
    /// Per-worker in-flight request cap past which the rendezvous-affine
    /// worker counts as saturated and the request spills to the
    /// least-loaded live worker instead.
    pub spill_inflight: usize,
    /// Retry-after hint (ms) on front-door-originated shed frames (no
    /// live worker, fleet saturated). Worker-originated sheds forward
    /// the worker's own hint unchanged.
    pub retry_after_ms: u64,
    /// Drain budget on SIGINT/SIGTERM: stop accepting, then give
    /// in-flight forwards this long to complete (same contract as the
    /// single-process server's `[serve] drain_ms`).
    pub drain_ms: u64,
}

impl Default for ShardConfig {
    fn default() -> Self {
        ShardConfig {
            addr: "127.0.0.1:7070".into(),
            workers: 2,
            worker_base_port: 7100,
            probe_interval_ms: 500,
            probe_timeout_ms: 1000,
            probe_misses: 2,
            backoff_ms: 200,
            max_backoff_ms: 5000,
            respawn_max: 5,
            spill_inflight: 32,
            retry_after_ms: 50,
            drain_ms: 2000,
        }
    }
}

impl ShardConfig {
    pub fn from_doc(doc: &Document) -> Self {
        let d = ShardConfig::default();
        ShardConfig {
            addr: doc.str_or("shard", "addr", &d.addr),
            workers: doc.int_or("shard", "workers", d.workers as i64) as usize,
            worker_base_port: doc.int_or("shard", "worker_base_port", d.worker_base_port as i64)
                as u16,
            probe_interval_ms: doc.int_or("shard", "probe_interval_ms", d.probe_interval_ms as i64)
                as u64,
            probe_timeout_ms: doc.int_or("shard", "probe_timeout_ms", d.probe_timeout_ms as i64)
                as u64,
            probe_misses: doc.int_or("shard", "probe_misses", d.probe_misses as i64) as usize,
            backoff_ms: doc.int_or("shard", "backoff_ms", d.backoff_ms as i64) as u64,
            max_backoff_ms: doc.int_or("shard", "max_backoff_ms", d.max_backoff_ms as i64) as u64,
            respawn_max: doc.int_or("shard", "respawn_max", d.respawn_max as i64) as usize,
            spill_inflight: doc.int_or("shard", "spill_inflight", d.spill_inflight as i64) as usize,
            retry_after_ms: doc.int_or("shard", "retry_after_ms", d.retry_after_ms as i64) as u64,
            drain_ms: doc.int_or("shard", "drain_ms", d.drain_ms as i64) as u64,
        }
    }
}

/// Benchmark harness configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct BenchConfig {
    pub reps: usize,
    pub warmup: usize,
    pub max_n: usize,
    pub artifacts: String,
}

impl Default for BenchConfig {
    fn default() -> Self {
        BenchConfig { reps: 5, warmup: 2, max_n: 16384, artifacts: "artifacts".into() }
    }
}

/// Render the paper's Table 4 from a ModelConfig (used by `bsa config`).
pub fn table4(cfg: &ModelConfig) -> String {
    format!(
        "Table 4. Sparse attention parameters\n\
         | Parameter                        | Value |\n\
         |----------------------------------|-------|\n\
         | Ball size                        | {} |\n\
         | Compression block size           | {} |\n\
         | Compression block sliding stride | {} |\n\
         | Selection block size             | {} |\n\
         | Number of blocks selected        | {} |\n",
        cfg.ball_size, cfg.cmp_block, cfg.cmp_block, cfg.sel_block, cfg.top_k
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# bsa config
[model]
variant = "bsa"   # the paper's model
dim = 128
num_blocks = 18
ball_size = 256

[train]
lr = 0.001
steps = 1000
task = "air"

[serve]
addr = "0.0.0.0:9000"
flush_us = 500

[misc]
flag = true
xs = [1, 2, 3]
names = ["a", "b"]
empty = []
"#;

    #[test]
    fn parse_sections_and_values() {
        let doc = Document::parse(SAMPLE).unwrap();
        assert_eq!(doc.get("model", "dim"), Some(&Value::Int(128)));
        assert_eq!(doc.get("train", "lr"), Some(&Value::Float(0.001)));
        assert_eq!(doc.get("misc", "flag"), Some(&Value::Bool(true)));
        assert_eq!(
            doc.get("misc", "xs"),
            Some(&Value::Array(vec![Value::Int(1), Value::Int(2), Value::Int(3)]))
        );
        assert_eq!(doc.str_or("serve", "addr", ""), "0.0.0.0:9000");
    }

    #[test]
    fn comments_and_strings() {
        let doc = Document::parse("a = \"x # not a comment\" # comment\n").unwrap();
        assert_eq!(doc.get("", "a"), Some(&Value::Str("x # not a comment".into())));
    }

    #[test]
    fn errors_have_line_numbers() {
        let err = Document::parse("ok = 1\nbroken\n").unwrap_err();
        assert_eq!(err.line, 2);
        let err = Document::parse("[unterminated\n").unwrap_err();
        assert_eq!(err.line, 1);
        let err = Document::parse("x = \"open\n").unwrap_err();
        assert_eq!(err.line, 1);
    }

    #[test]
    fn typed_model_config() {
        let doc = Document::parse(SAMPLE).unwrap();
        let mc = ModelConfig::from_doc(&doc);
        assert_eq!(mc.dim, 128);
        assert_eq!(mc.num_blocks, 18);
        assert_eq!(mc.ball_size, 256); // explicit
        assert_eq!(mc.top_k, 4); // default
    }

    #[test]
    fn defaults_match_paper_table4() {
        let d = ModelConfig::default();
        assert_eq!(d.ball_size, 256);
        assert_eq!(d.cmp_block, 8);
        assert_eq!(d.sel_block, 8);
        assert_eq!(d.top_k, 4);
        let t = table4(&d);
        assert!(t.contains("| 256 |"));
    }

    #[test]
    fn validation_rejects_bad_configs() {
        let mut c = ModelConfig::default();
        c.validate().unwrap();
        c.dim = 65;
        assert!(c.validate().is_err());
        let mut c = ModelConfig { seq_len: 1000, ..Default::default() };
        assert!(c.validate().is_err());
        c = ModelConfig { top_k: 10_000, ..Default::default() };
        assert!(c.validate().is_err());
    }

    #[test]
    fn serve_config_tree_cache_knob() {
        assert_eq!(ServeConfig::default().tree_cache, 64);
        let doc = Document::parse("[serve]\ntree_cache = 8\n").unwrap();
        let sc = ServeConfig::from_doc(&doc);
        assert_eq!(sc.tree_cache, 8);
        let off = Document::parse("[serve]\ntree_cache = 0\n").unwrap();
        assert_eq!(ServeConfig::from_doc(&off).tree_cache, 0);
    }

    #[test]
    fn serve_config_native_threads_knob() {
        assert_eq!(ServeConfig::default().native_threads, 0, "default = auto");
        let doc = Document::parse("[serve]\nnative_threads = 4\n").unwrap();
        assert_eq!(ServeConfig::from_doc(&doc).native_threads, 4);
    }

    #[test]
    fn serve_config_native_simd_knob() {
        assert_eq!(ServeConfig::default().native_simd, "auto", "default = auto");
        let doc = Document::parse("[serve]\nnative_simd = \"off\"\n").unwrap();
        assert_eq!(ServeConfig::from_doc(&doc).native_simd, "off");
    }

    #[test]
    fn serve_config_precision_knob() {
        assert_eq!(ServeConfig::default().precision, "f32", "default = f32");
        let doc = Document::parse("[serve]\nprecision = \"f16\"\n").unwrap();
        assert_eq!(ServeConfig::from_doc(&doc).precision, "f16");
    }

    #[test]
    fn serve_config_admission_knobs() {
        let d = ServeConfig::default();
        assert_eq!(d.max_conns, 4096);
        assert_eq!(d.max_payload_bytes, 64 << 20);
        assert_eq!(d.max_inflight_bytes, 256 << 20);
        assert_eq!(d.conn_quota, 32);
        assert_eq!(d.retry_after_ms, 50);
        assert_eq!(d.drain_ms, 2000);
        let doc = Document::parse(
            "[serve]\nmax_conns = 128\nmax_payload_bytes = 1048576\n\
             max_inflight_bytes = 4194304\nconn_quota = 4\nretry_after_ms = 75\ndrain_ms = 500\n",
        )
        .unwrap();
        let sc = ServeConfig::from_doc(&doc);
        assert_eq!(sc.max_conns, 128);
        assert_eq!(sc.max_payload_bytes, 1 << 20);
        assert_eq!(sc.max_inflight_bytes, 4 << 20);
        assert_eq!(sc.conn_quota, 4);
        assert_eq!(sc.retry_after_ms, 75);
        assert_eq!(sc.drain_ms, 500);
    }

    #[test]
    fn shard_config_knobs() {
        let d = ShardConfig::default();
        assert_eq!(d.workers, 2);
        assert_eq!(d.worker_base_port, 7100);
        assert_eq!(d.probe_interval_ms, 500);
        assert_eq!(d.probe_timeout_ms, 1000);
        assert_eq!(d.probe_misses, 2);
        assert_eq!(d.backoff_ms, 200);
        assert_eq!(d.max_backoff_ms, 5000);
        assert_eq!(d.respawn_max, 5);
        assert_eq!(d.spill_inflight, 32);
        assert_eq!(d.retry_after_ms, 50);
        assert_eq!(d.drain_ms, 2000);
        let doc = Document::parse(
            "[shard]\naddr = \"127.0.0.1:9100\"\nworkers = 4\nworker_base_port = 9200\n\
             probe_interval_ms = 100\nprobe_timeout_ms = 250\nprobe_misses = 3\n\
             backoff_ms = 50\nmax_backoff_ms = 400\nrespawn_max = 2\nspill_inflight = 8\n\
             retry_after_ms = 20\ndrain_ms = 750\n",
        )
        .unwrap();
        let sc = ShardConfig::from_doc(&doc);
        assert_eq!(sc.addr, "127.0.0.1:9100");
        assert_eq!(sc.workers, 4);
        assert_eq!(sc.worker_base_port, 9200);
        assert_eq!(sc.probe_interval_ms, 100);
        assert_eq!(sc.probe_timeout_ms, 250);
        assert_eq!(sc.probe_misses, 3);
        assert_eq!(sc.backoff_ms, 50);
        assert_eq!(sc.max_backoff_ms, 400);
        assert_eq!(sc.respawn_max, 2);
        assert_eq!(sc.spill_inflight, 8);
        assert_eq!(sc.retry_after_ms, 20);
        assert_eq!(sc.drain_ms, 750);
    }

    #[test]
    fn cosine_schedule_shape() {
        let tc = TrainConfig { steps: 100, warmup: 10, lr: 1.0, ..Default::default() };
        assert!(tc.lr_at(0) < 0.2); // warmup starts low
        assert!((tc.lr_at(9) - 1.0).abs() < 0.11); // end of warmup ~ peak
        assert!(tc.lr_at(50) < tc.lr_at(10)); // decays
        assert!(tc.lr_at(99) < 0.01); // ~0 at the end
    }

    #[test]
    fn value_display_roundtrips_through_parse() {
        let vals = vec![
            Value::Int(42),
            Value::Float(2.5),
            Value::Bool(false),
            Value::Str("hi".into()),
            Value::Array(vec![Value::Int(1), Value::Int(2)]),
        ];
        for v in vals {
            let text = format!("k = {v}\n");
            let doc = Document::parse(&text).unwrap();
            assert_eq!(doc.get("", "k"), Some(&v));
        }
    }
}
