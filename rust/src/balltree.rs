//! Ball tree construction — the geometric substrate of BSA.
//!
//! The Erwin transformer (Zhdanov et al. 2025) imposes regularity on an
//! unordered point set by organising it into a balanced binary *ball
//! tree*: points are recursively split at the median along the axis of
//! largest spread. Reading the leaves in tree order yields a permutation
//! under which **every contiguous chunk of 2^k positions is a ball** — a
//! spatially compact neighbourhood. BSA inherits this: the rust
//! coordinator permutes each input cloud with this module before invoking
//! the compiled HLO, so the kernels see ball-local chunks (ball
//! attention), block-local chunks (compression/selection), and groups, all
//! as plain contiguous slices.
//!
//! Points are padded *by duplicating real points* up to the model's
//! sequence length (a power-of-two multiple of the ball size); the `real`
//! mask lets metrics ignore pad positions. Duplicated points are harmless
//! for attention semantics (they attend like their originals) and keep the
//! compiled graph shape static.

use crate::prng::Rng;
use crate::tensor::Tensor;

/// A built ball tree over a (possibly padded) point cloud.
#[derive(Debug, Clone)]
pub struct BallTree {
    /// Permutation: position `i` in ball order holds original point
    /// `perm[i]` (an index into the *original, unpadded* cloud).
    pub perm: Vec<usize>,
    /// `real[i]` is false for pad duplicates.
    pub real: Vec<bool>,
    /// Number of original points.
    pub n_points: usize,
    /// Padded length (== perm.len()), a power-of-two multiple of 1.
    pub n_padded: usize,
    /// Dimensionality of the points.
    pub dim: usize,
    /// Permuted coordinates, shape (n_padded, dim).
    pub coords: Tensor,
}

/// Geometric summary of one ball at a given granularity.
#[derive(Debug, Clone, PartialEq)]
pub struct Ball {
    pub center: Vec<f32>,
    pub radius: f32,
    /// Range [start, start+size) in ball order.
    pub start: usize,
    pub size: usize,
}

impl BallTree {
    /// Build a ball tree over `points` (N, D), padding to `target_len`.
    ///
    /// `target_len` must be >= N and a power of two (the compiled model's
    /// sequence length). Pads duplicate points chosen deterministically
    /// from `seed` so padded balls stay spatially coherent.
    pub fn build(points: &Tensor, target_len: usize, seed: u64) -> BallTree {
        let n = points.rows();
        let d = points.cols();
        assert!(n > 0, "empty point cloud");
        assert!(target_len >= n, "target_len {target_len} < n {n}");
        assert!(target_len.is_power_of_two(), "target_len must be 2^k");

        // Pad by sampling random existing points; duplicates sit next to
        // their originals after the median splits, keeping balls compact.
        let mut rng = Rng::new(seed);
        let mut idx: Vec<usize> = (0..n).collect();
        let mut is_real = vec![true; n];
        while idx.len() < target_len {
            idx.push(rng.below(n));
            is_real.push(false);
        }

        // Recursive median split over (index, realness) pairs.
        let mut pairs: Vec<(usize, bool)> = idx.into_iter().zip(is_real).collect();
        split_recursive(points, &mut pairs);

        let perm: Vec<usize> = pairs.iter().map(|p| p.0).collect();
        let real: Vec<bool> = pairs.iter().map(|p| p.1).collect();
        let mut coords = Vec::with_capacity(target_len * d);
        for &p in &perm {
            coords.extend_from_slice(points.row(p));
        }
        BallTree {
            perm,
            real,
            n_points: n,
            n_padded: target_len,
            dim: d,
            coords: Tensor::new(vec![target_len, d], coords),
        }
    }

    /// Permute per-point features (N, F) into ball order (n_padded, F).
    /// Pad rows replicate their source point's features.
    pub fn permute_features(&self, features: &Tensor) -> Tensor {
        assert_eq!(features.rows(), self.n_points, "feature rows");
        let f = features.cols();
        let mut out = Vec::with_capacity(self.n_padded * f);
        for &p in &self.perm {
            out.extend_from_slice(features.row(p));
        }
        Tensor::new(vec![self.n_padded, f], out)
    }

    /// Scatter per-position predictions (n_padded, F) back to original
    /// point order (n_points, F). Pad positions are dropped; if a point
    /// was duplicated, the *real* occurrence wins.
    pub fn unpermute_predictions(&self, preds: &Tensor) -> Tensor {
        assert_eq!(preds.rows(), self.n_padded, "pred rows");
        let f = preds.cols();
        let mut out = vec![0.0f32; self.n_points * f];
        let mut seen = vec![false; self.n_points];
        for (i, (&p, &r)) in self.perm.iter().zip(&self.real).enumerate() {
            if r {
                out[p * f..(p + 1) * f].copy_from_slice(preds.row(i));
                seen[p] = true;
            }
        }
        // Defensive: every real point appears exactly once by construction.
        debug_assert!(seen.iter().all(|&s| s));
        Tensor::new(vec![self.n_points, f], out)
    }

    /// Number of balls at granularity `ball_size` (must divide n_padded).
    pub fn num_balls(&self, ball_size: usize) -> usize {
        assert_eq!(self.n_padded % ball_size, 0, "ball size must divide N");
        self.n_padded / ball_size
    }

    /// Ball id of a position at a granularity.
    pub fn ball_of(&self, pos: usize, ball_size: usize) -> usize {
        pos / ball_size
    }

    /// Geometric center/radius of each ball at `ball_size` granularity.
    pub fn balls(&self, ball_size: usize) -> Vec<Ball> {
        let nb = self.num_balls(ball_size);
        let d = self.dim;
        let mut out = Vec::with_capacity(nb);
        for b in 0..nb {
            let start = b * ball_size;
            let mut center = vec![0.0f32; d];
            for i in start..start + ball_size {
                for (c, &x) in center.iter_mut().zip(self.coords.row(i)) {
                    *c += x;
                }
            }
            for c in center.iter_mut() {
                *c /= ball_size as f32;
            }
            let mut radius: f32 = 0.0;
            for i in start..start + ball_size {
                let dist: f32 = self
                    .coords
                    .row(i)
                    .iter()
                    .zip(&center)
                    .map(|(x, c)| (x - c).powi(2))
                    .sum::<f32>()
                    .sqrt();
                radius = radius.max(dist);
            }
            out.push(Ball { center, radius, start, size: ball_size });
        }
        out
    }

    /// Mean ball radius at a granularity — a compactness diagnostic used
    /// by tests and the receptive-field example.
    pub fn mean_radius(&self, ball_size: usize) -> f32 {
        let balls = self.balls(ball_size);
        balls.iter().map(|b| b.radius).sum::<f32>() / balls.len() as f32
    }
}

/// Recursive in-place median split: after the call, every aligned
/// power-of-two segment of `pairs` is a subtree (ball).
fn split_recursive(points: &Tensor, pairs: &mut [(usize, bool)]) {
    if pairs.len() <= 1 {
        return;
    }
    let d = points.cols();

    // Axis of largest spread across the segment.
    let mut lo = vec![f32::INFINITY; d];
    let mut hi = vec![f32::NEG_INFINITY; d];
    for &(p, _) in pairs.iter() {
        for (a, &x) in points.row(p).iter().enumerate() {
            lo[a] = lo[a].min(x);
            hi[a] = hi[a].max(x);
        }
    }
    let axis = (0..d)
        .max_by(|&i, &j| {
            (hi[i] - lo[i])
                .partial_cmp(&(hi[j] - lo[j]))
                .unwrap_or(std::cmp::Ordering::Equal)
        })
        .unwrap_or(0);

    let mid = pairs.len() / 2;
    pairs.select_nth_unstable_by(mid, |a, b| {
        points.row(a.0)[axis]
            .partial_cmp(&points.row(b.0)[axis])
            .unwrap_or(std::cmp::Ordering::Equal)
    });
    let (left, right) = pairs.split_at_mut(mid);
    split_recursive(points, left);
    split_recursive(points, right);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn cloud(n: usize, d: usize, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        Tensor::new(vec![n, d], rng.normals(n * d))
    }

    #[test]
    fn perm_is_valid_permutation_when_unpadded() {
        let pts = cloud(256, 3, 0);
        let t = BallTree::build(&pts, 256, 0);
        let mut seen = vec![false; 256];
        for &p in &t.perm {
            assert!(!seen[p], "duplicate without padding");
            seen[p] = true;
        }
        assert!(seen.iter().all(|&s| s));
        assert!(t.real.iter().all(|&r| r));
    }

    #[test]
    fn padding_duplicates_and_masks() {
        let pts = cloud(100, 3, 1);
        let t = BallTree::build(&pts, 128, 1);
        assert_eq!(t.n_padded, 128);
        assert_eq!(t.real.iter().filter(|&&r| r).count(), 100);
        // every real point appears exactly once among real slots
        let mut count = vec![0usize; 100];
        for (&p, &r) in t.perm.iter().zip(&t.real) {
            if r {
                count[p] += 1;
            }
        }
        assert!(count.iter().all(|&c| c == 1));
    }

    #[test]
    fn balls_are_spatially_compact() {
        // Ball-ordered chunks must be far tighter than random chunks.
        let pts = cloud(1024, 3, 2);
        let t = BallTree::build(&pts, 1024, 2);
        let tree_r = t.mean_radius(64);

        // random ordering baseline
        let mut rng = Rng::new(3);
        let mut perm: Vec<usize> = (0..1024).collect();
        rng.shuffle(&mut perm);
        let shuffled = pts.permute_rows(&perm);
        let t_rand = BallTree {
            perm: (0..1024).collect(),
            real: vec![true; 1024],
            n_points: 1024,
            n_padded: 1024,
            dim: 3,
            coords: shuffled,
        };
        let rand_r = t_rand.mean_radius(64);
        assert!(
            tree_r < 0.7 * rand_r,
            "tree radius {tree_r} not much tighter than random {rand_r}"
        );
    }

    #[test]
    fn hierarchy_nested() {
        // Each ball at size 2m is the union of two adjacent balls at m —
        // so its radius must be >= either child's distance structure.
        let pts = cloud(512, 3, 4);
        let t = BallTree::build(&pts, 512, 4);
        let fine = t.balls(32);
        let coarse = t.balls(64);
        for (b, cb) in coarse.iter().enumerate() {
            let l = &fine[2 * b];
            let r = &fine[2 * b + 1];
            assert_eq!(cb.start, l.start);
            assert_eq!(cb.start + cb.size, r.start + r.size);
        }
    }

    #[test]
    fn feature_roundtrip() {
        let pts = cloud(200, 3, 5);
        let feats = cloud(200, 6, 6);
        let t = BallTree::build(&pts, 256, 5);
        let pf = t.permute_features(&feats);
        assert_eq!(pf.shape(), &[256, 6]);
        // unpermute identity: treat features as "predictions"
        let back = t.unpermute_predictions(&pf);
        assert_eq!(back, feats);
    }

    #[test]
    fn split_axis_separates_space() {
        // Two well-separated clusters must land in different halves.
        let mut data = Vec::new();
        for i in 0..64 {
            let off = if i < 32 { -10.0 } else { 10.0 };
            data.extend_from_slice(&[off + (i % 7) as f32 * 0.01, 0.0, 0.0]);
        }
        let pts = Tensor::new(vec![64, 3], data);
        let t = BallTree::build(&pts, 64, 0);
        let first_half: Vec<f32> = (0..32).map(|i| t.coords.row(i)[0]).collect();
        let second_half: Vec<f32> = (32..64).map(|i| t.coords.row(i)[0]).collect();
        assert!(first_half.iter().all(|&x| x < 0.0) != first_half.iter().all(|&x| x > 0.0) || true);
        // halves are homogeneous in sign
        assert!(
            first_half.iter().all(|&x| x < 0.0) && second_half.iter().all(|&x| x > 0.0)
                || first_half.iter().all(|&x| x > 0.0) && second_half.iter().all(|&x| x < 0.0)
        );
    }

    #[test]
    #[should_panic]
    fn non_power_of_two_target_panics() {
        let pts = cloud(10, 3, 0);
        BallTree::build(&pts, 24, 0);
    }

    #[test]
    fn ball_of_granularity() {
        let pts = cloud(128, 3, 9);
        let t = BallTree::build(&pts, 128, 9);
        assert_eq!(t.ball_of(0, 32), 0);
        assert_eq!(t.ball_of(127, 32), 3);
        assert_eq!(t.num_balls(32), 4);
    }
}
