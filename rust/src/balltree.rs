//! Ball tree construction — the geometric substrate of BSA.
//!
//! The Erwin transformer (Zhdanov et al. 2025) imposes regularity on an
//! unordered point set by organising it into a balanced binary *ball
//! tree*: points are recursively split at the median along the axis of
//! largest spread. Reading the leaves in tree order yields a permutation
//! under which **every contiguous chunk of 2^k positions is a ball** — a
//! spatially compact neighbourhood. BSA inherits this: the rust
//! coordinator permutes each input cloud with this module before invoking
//! the compiled HLO, so the kernels see ball-local chunks (ball
//! attention), block-local chunks (compression/selection), and groups, all
//! as plain contiguous slices.
//!
//! Points are padded *by duplicating real points* up to the model's
//! sequence length (a power-of-two multiple of the ball size); the `real`
//! mask lets metrics ignore pad positions. Duplicated points are harmless
//! for attention semantics (they attend like their originals) and keep the
//! compiled graph shape static.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use crate::prng::Rng;
use crate::tensor::Tensor;

/// A built ball tree over a (possibly padded) point cloud.
#[derive(Debug, Clone)]
pub struct BallTree {
    /// Permutation: position `i` in ball order holds original point
    /// `perm[i]` (an index into the *original, unpadded* cloud).
    pub perm: Vec<usize>,
    /// `real[i]` is false for pad duplicates.
    pub real: Vec<bool>,
    /// Number of original points.
    pub n_points: usize,
    /// Padded length (== perm.len()), a power-of-two multiple of 1.
    pub n_padded: usize,
    /// Dimensionality of the points.
    pub dim: usize,
    /// Permuted coordinates, shape (n_padded, dim).
    pub coords: Tensor,
}

/// Geometric summary of one ball at a given granularity.
#[derive(Debug, Clone, PartialEq)]
pub struct Ball {
    pub center: Vec<f32>,
    pub radius: f32,
    /// Range [start, start+size) in ball order.
    pub start: usize,
    pub size: usize,
}

impl BallTree {
    /// Build a ball tree over `points` (N, D), padding to `target_len`.
    ///
    /// `target_len` must be >= N and a power of two (the compiled model's
    /// sequence length). Pads duplicate points chosen deterministically
    /// from `seed` so padded balls stay spatially coherent.
    pub fn build(points: &Tensor, target_len: usize, seed: u64) -> BallTree {
        let n = points.rows();
        let d = points.cols();
        assert!(n > 0, "empty point cloud");
        assert!(target_len >= n, "target_len {target_len} < n {n}");
        assert!(target_len.is_power_of_two(), "target_len must be 2^k");

        // Pad by sampling random existing points; duplicates sit next to
        // their originals after the median splits, keeping balls compact.
        let mut rng = Rng::new(seed);
        let mut idx: Vec<usize> = (0..n).collect();
        let mut is_real = vec![true; n];
        while idx.len() < target_len {
            idx.push(rng.below(n));
            is_real.push(false);
        }

        // Median split over (index, realness) pairs.
        let mut pairs: Vec<(usize, bool)> = idx.into_iter().zip(is_real).collect();
        split_balanced(points, &mut pairs);

        let perm: Vec<usize> = pairs.iter().map(|p| p.0).collect();
        let real: Vec<bool> = pairs.iter().map(|p| p.1).collect();
        let mut coords = vec![0.0f32; target_len * d];
        points.permute_rows_into(&perm, &mut coords);
        BallTree {
            perm,
            real,
            n_points: n,
            n_padded: target_len,
            dim: d,
            coords: Tensor::new(vec![target_len, d], coords),
        }
    }

    /// Permute per-point features (N, F) into ball order (n_padded, F).
    /// Pad rows replicate their source point's features.
    pub fn permute_features(&self, features: &Tensor) -> Tensor {
        let f = features.cols();
        let mut out = vec![0.0f32; self.n_padded * f];
        self.permute_features_into(features, &mut out);
        Tensor::new(vec![self.n_padded, f], out)
    }

    /// Allocation-free variant of [`permute_features`](Self::permute_features):
    /// gathers rows directly into `out` (length `n_padded * F`). The
    /// serving batch assembler uses this to write each request's permuted
    /// features straight into its slot of the shared `(B, N, F)` buffer.
    pub fn permute_features_into(&self, features: &Tensor, out: &mut [f32]) {
        assert_eq!(features.rows(), self.n_points, "feature rows");
        features.permute_rows_into(&self.perm, out);
    }

    /// Scatter per-position predictions (n_padded, F) back to original
    /// point order (n_points, F). Pad positions are dropped; if a point
    /// was duplicated, the *real* occurrence wins.
    pub fn unpermute_predictions(&self, preds: &Tensor) -> Tensor {
        assert_eq!(preds.rows(), self.n_padded, "pred rows");
        self.unpermute_predictions_view(preds.data(), preds.cols())
    }

    /// Borrowed-slice variant of
    /// [`unpermute_predictions`](Self::unpermute_predictions): reads a flat
    /// `(n_padded * f)` row-major view, so a per-request window of a
    /// batched prediction tensor can be un-permuted without an
    /// intermediate `slice_rows` copy.
    pub fn unpermute_predictions_view(&self, preds: &[f32], f: usize) -> Tensor {
        assert_eq!(preds.len(), self.n_padded * f, "pred view len");
        let mut out = vec![0.0f32; self.n_points * f];
        let mut seen = vec![false; self.n_points];
        for (i, (&p, &r)) in self.perm.iter().zip(&self.real).enumerate() {
            if r {
                out[p * f..(p + 1) * f].copy_from_slice(&preds[i * f..(i + 1) * f]);
                seen[p] = true;
            }
        }
        // Defensive: every real point appears exactly once by construction.
        debug_assert!(seen.iter().all(|&s| s));
        Tensor::new(vec![self.n_points, f], out)
    }

    /// Number of balls at granularity `ball_size` (must divide n_padded).
    pub fn num_balls(&self, ball_size: usize) -> usize {
        assert_eq!(self.n_padded % ball_size, 0, "ball size must divide N");
        self.n_padded / ball_size
    }

    /// Ball id of a position at a granularity.
    pub fn ball_of(&self, pos: usize, ball_size: usize) -> usize {
        pos / ball_size
    }

    /// Geometric center/radius of each ball at `ball_size` granularity.
    pub fn balls(&self, ball_size: usize) -> Vec<Ball> {
        let nb = self.num_balls(ball_size);
        let d = self.dim;
        let mut out = Vec::with_capacity(nb);
        for b in 0..nb {
            let start = b * ball_size;
            let mut center = vec![0.0f32; d];
            for i in start..start + ball_size {
                for (c, &x) in center.iter_mut().zip(self.coords.row(i)) {
                    *c += x;
                }
            }
            for c in center.iter_mut() {
                *c /= ball_size as f32;
            }
            let mut radius: f32 = 0.0;
            for i in start..start + ball_size {
                let dist: f32 = self
                    .coords
                    .row(i)
                    .iter()
                    .zip(&center)
                    .map(|(x, c)| (x - c).powi(2))
                    .sum::<f32>()
                    .sqrt();
                radius = radius.max(dist);
            }
            out.push(Ball { center, radius, start, size: ball_size });
        }
        out
    }

    /// Mean ball radius at a granularity — a compactness diagnostic used
    /// by tests and the receptive-field example.
    pub fn mean_radius(&self, ball_size: usize) -> f32 {
        let balls = self.balls(ball_size);
        balls.iter().map(|b| b.radius).sum::<f32>() / balls.len() as f32
    }
}

/// In-place median split: after the call, every aligned power-of-two
/// segment of `pairs` is a subtree (ball).
///
/// Implemented as an explicit work-stack rather than recursion so the
/// per-segment `lo`/`hi` spread buffers are allocated once and reused —
/// the recursive version allocated two `Vec<f32>` per tree node, which
/// dominated small-D construction profiles. The tree shape is identical:
/// segment order of the splits does not affect the result.
fn split_balanced(points: &Tensor, pairs: &mut [(usize, bool)]) {
    if pairs.len() <= 1 {
        return;
    }
    let d = points.cols();
    let mut lo = vec![0.0f32; d];
    let mut hi = vec![0.0f32; d];
    // Each stack entry is a [start, end) segment still to be split. A
    // balanced binary split of L leaves pushes at most ceil(log2 L) + 1
    // live entries, but Vec growth is cheap either way.
    let mut stack: Vec<(usize, usize)> = vec![(0, pairs.len())];
    while let Some((start, end)) = stack.pop() {
        if end - start <= 1 {
            continue;
        }
        let seg = &pairs[start..end];

        // Axis of largest spread across the segment (scratch reused).
        lo.fill(f32::INFINITY);
        hi.fill(f32::NEG_INFINITY);
        for &(p, _) in seg {
            for (a, &x) in points.row(p).iter().enumerate() {
                lo[a] = lo[a].min(x);
                hi[a] = hi[a].max(x);
            }
        }
        let axis = (0..d)
            .max_by(|&i, &j| {
                (hi[i] - lo[i])
                    .partial_cmp(&(hi[j] - lo[j]))
                    .unwrap_or(std::cmp::Ordering::Equal)
            })
            .unwrap_or(0);

        let mid = (end - start) / 2;
        pairs[start..end].select_nth_unstable_by(mid, |a, b| {
            points.row(a.0)[axis]
                .partial_cmp(&points.row(b.0)[axis])
                .unwrap_or(std::cmp::Ordering::Equal)
        });
        stack.push((start, start + mid));
        stack.push((start + mid, end));
    }
}

// ---------------------------------------------------------------------------
// content hashing + ball-tree cache (the serving hot path's fast lane)
// ---------------------------------------------------------------------------

/// Content hash of a tensor's raw f32 payload, 8 bytes at a time.
///
/// FNV-1a-style multiply-xor over 64-bit words (two f32 bit patterns per
/// step) with a splitmix64 finalizer for avalanche — ~8x fewer hash steps
/// than the original byte-at-a-time FNV on the same data. Used both as
/// the deterministic pad-point seed (identical clouds must pad
/// identically) and as the [`BallTreeCache`] key.
pub fn content_hash(t: &Tensor) -> u64 {
    const PRIME: u64 = 0x100000001b3;
    // Seed with the length so clouds that differ only by trailing zeros
    // (or by an element landing in the odd remainder) still separate.
    let mut h: u64 = 0xcbf29ce484222325 ^ (t.len() as u64).wrapping_mul(0x9e3779b97f4a7c15);
    let mut chunks = t.data().chunks_exact(2);
    for pair in &mut chunks {
        let word = (pair[0].to_bits() as u64) | ((pair[1].to_bits() as u64) << 32);
        h = (h ^ word).wrapping_mul(PRIME);
    }
    if let [last] = chunks.remainder() {
        h = (h ^ last.to_bits() as u64).wrapping_mul(PRIME);
    }
    finalize_hash(h)
}

/// [`content_hash`] over the raw little-endian wire encoding of an f32
/// array — the coordinate payload of a `BSRQ` frame, exactly as it sits
/// in the shard front door's relay buffer. Bit-identical to hashing the
/// decoded `Tensor` (pinned by `content_hash_bytes_matches_tensor`), so
/// the front door can derive the shard key without materializing a
/// tensor per forwarded request. `bytes.len()` must be a multiple of 4.
pub fn content_hash_le_bytes(bytes: &[u8]) -> u64 {
    const PRIME: u64 = 0x100000001b3;
    debug_assert_eq!(bytes.len() % 4, 0, "f32 wire payload is 4-byte aligned");
    let len = bytes.len() / 4;
    let mut h: u64 = 0xcbf29ce484222325 ^ (len as u64).wrapping_mul(0x9e3779b97f4a7c15);
    let mut chunks = bytes.chunks_exact(8);
    for pair in &mut chunks {
        // Two LE f32 bit patterns packed low-then-high — the same word
        // `content_hash` builds from `f32::to_bits` pairs.
        let word = u64::from_le_bytes([
            pair[0], pair[1], pair[2], pair[3], pair[4], pair[5], pair[6], pair[7],
        ]);
        h = (h ^ word).wrapping_mul(PRIME);
    }
    let rem = chunks.remainder();
    if rem.len() == 4 {
        let last = u32::from_le_bytes([rem[0], rem[1], rem[2], rem[3]]);
        h = (h ^ last as u64).wrapping_mul(PRIME);
    }
    finalize_hash(h)
}

/// splitmix64 finalizer shared by the two `content_hash` flavours.
fn finalize_hash(mut h: u64) -> u64 {
    h ^= h >> 30;
    h = h.wrapping_mul(0xbf58476d1ce4e5b9);
    h ^= h >> 27;
    h = h.wrapping_mul(0x94d049bb133111eb);
    h ^ (h >> 31)
}

/// Cache key: content hash plus the cheap-to-check dimensions, so a
/// 64-bit collision additionally has to match shape and padded length.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
struct CacheKey {
    hash: u64,
    rows: usize,
    cols: usize,
    target: usize,
}

struct CacheSlot {
    tree: Arc<BallTree>,
    /// Logical timestamp of the last hit (LRU ordering).
    tick: u64,
}

/// Content-addressed LRU cache of built ball trees.
///
/// Erwin-style ball orderings depend only on the *geometry* — not on the
/// feature fields — so the dominant CFD serving pattern (one mesh, many
/// feature fields) pays `BallTree::build` once and then hits here. Keys
/// are [`content_hash`] of the coordinates plus (rows, cols, target_len);
/// trees are shared out as `Arc` so hits are a hash + clone.
///
/// A capacity of 0 disables caching (every lookup builds and is counted
/// as a miss). Eviction is least-recently-used.
pub struct BallTreeCache {
    inner: Mutex<CacheInner>,
    hits: AtomicU64,
    misses: AtomicU64,
}

struct CacheInner {
    cap: usize,
    tick: u64,
    map: HashMap<CacheKey, CacheSlot>,
}

impl BallTreeCache {
    /// New cache holding up to `cap` trees (0 disables caching).
    pub fn new(cap: usize) -> BallTreeCache {
        BallTreeCache {
            inner: Mutex::new(CacheInner { cap, tick: 0, map: HashMap::new() }),
            hits: AtomicU64::new(0),
            misses: AtomicU64::new(0),
        }
    }

    /// Try a pure lookup: `Ok(tree)` on a hit (LRU position refreshed),
    /// `Err(content_hash)` on a miss so the caller can decide *where* to
    /// build — the serving router satisfies hits inline and only sends
    /// misses (the expensive step) to worker threads, then completes them
    /// with [`build_insert`](Self::build_insert).
    pub fn try_get(&self, coords: &Tensor, target_len: usize) -> Result<Arc<BallTree>, u64> {
        let hash = content_hash(coords);
        let key = CacheKey {
            hash,
            rows: coords.rows(),
            cols: coords.cols(),
            target: target_len,
        };
        {
            let mut inner = self.inner.lock().unwrap();
            if inner.cap > 0 {
                inner.tick += 1;
                let tick = inner.tick;
                if let Some(slot) = inner.map.get_mut(&key) {
                    slot.tick = tick;
                    let tree = slot.tree.clone();
                    self.hits.fetch_add(1, Ordering::Relaxed);
                    return Ok(tree);
                }
            }
        }
        self.misses.fetch_add(1, Ordering::Relaxed);
        Err(hash)
    }

    /// Build the tree for a miss reported by [`try_get`](Self::try_get)
    /// and insert it (evicting the LRU entry at capacity). `hash` must be
    /// the value `try_get` returned for these coords: it seeds the pad
    /// points, keeping cached and rebuilt trees bit-identical. The build
    /// runs outside the cache lock so concurrent misses on different
    /// geometries don't serialize.
    pub fn build_insert(&self, coords: &Tensor, target_len: usize, hash: u64) -> Arc<BallTree> {
        let key = CacheKey {
            hash,
            rows: coords.rows(),
            cols: coords.cols(),
            target: target_len,
        };
        let tree = Arc::new(BallTree::build(coords, target_len, hash));
        let mut inner = self.inner.lock().unwrap();
        if inner.cap > 0 {
            if inner.map.len() >= inner.cap && !inner.map.contains_key(&key) {
                if let Some(oldest) = inner
                    .map
                    .iter()
                    .min_by_key(|(_, slot)| slot.tick)
                    .map(|(k, _)| *k)
                {
                    inner.map.remove(&oldest);
                }
            }
            inner.tick += 1;
            let tick = inner.tick;
            inner.map.insert(key, CacheSlot { tree: tree.clone(), tick });
        }
        tree
    }

    /// Look up the tree for `coords` padded to `target_len`, building (and
    /// inserting) it on a miss.
    pub fn get_or_build(&self, coords: &Tensor, target_len: usize) -> Arc<BallTree> {
        match self.try_get(coords, target_len) {
            Ok(tree) => tree,
            Err(hash) => self.build_insert(coords, target_len, hash),
        }
    }

    /// Number of cache hits so far.
    pub fn hits(&self) -> u64 {
        self.hits.load(Ordering::Relaxed)
    }

    /// Number of cache misses (i.e. tree builds) so far.
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }

    /// Number of trees currently resident.
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().map.len()
    }

    /// True when no trees are resident.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn cloud(n: usize, d: usize, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        Tensor::new(vec![n, d], rng.normals(n * d))
    }

    #[test]
    fn content_hash_bytes_matches_tensor() {
        // The shard front door hashes the raw BSRQ coordinate bytes; the
        // router hashes the decoded tensor. Both must produce the same
        // shard key or affinity placement silently degrades to random.
        for (n, d, seed) in [(1, 1, 0u64), (5, 3, 1), (64, 3, 2), (101, 7, 3)] {
            let t = cloud(n, d, seed);
            let mut wire = Vec::with_capacity(t.len() * 4);
            for x in t.data() {
                wire.extend_from_slice(&x.to_le_bytes());
            }
            assert_eq!(content_hash_le_bytes(&wire), content_hash(&t), "n={n} d={d}");
        }
    }

    #[test]
    fn perm_is_valid_permutation_when_unpadded() {
        let pts = cloud(256, 3, 0);
        let t = BallTree::build(&pts, 256, 0);
        let mut seen = vec![false; 256];
        for &p in &t.perm {
            assert!(!seen[p], "duplicate without padding");
            seen[p] = true;
        }
        assert!(seen.iter().all(|&s| s));
        assert!(t.real.iter().all(|&r| r));
    }

    #[test]
    fn padding_duplicates_and_masks() {
        let pts = cloud(100, 3, 1);
        let t = BallTree::build(&pts, 128, 1);
        assert_eq!(t.n_padded, 128);
        assert_eq!(t.real.iter().filter(|&&r| r).count(), 100);
        // every real point appears exactly once among real slots
        let mut count = vec![0usize; 100];
        for (&p, &r) in t.perm.iter().zip(&t.real) {
            if r {
                count[p] += 1;
            }
        }
        assert!(count.iter().all(|&c| c == 1));
    }

    #[test]
    fn balls_are_spatially_compact() {
        // Ball-ordered chunks must be far tighter than random chunks.
        let pts = cloud(1024, 3, 2);
        let t = BallTree::build(&pts, 1024, 2);
        let tree_r = t.mean_radius(64);

        // random ordering baseline
        let mut rng = Rng::new(3);
        let mut perm: Vec<usize> = (0..1024).collect();
        rng.shuffle(&mut perm);
        let shuffled = pts.permute_rows(&perm);
        let t_rand = BallTree {
            perm: (0..1024).collect(),
            real: vec![true; 1024],
            n_points: 1024,
            n_padded: 1024,
            dim: 3,
            coords: shuffled,
        };
        let rand_r = t_rand.mean_radius(64);
        assert!(
            tree_r < 0.7 * rand_r,
            "tree radius {tree_r} not much tighter than random {rand_r}"
        );
    }

    #[test]
    fn hierarchy_nested() {
        // Each ball at size 2m is the union of two adjacent balls at m —
        // so its radius must be >= either child's distance structure.
        let pts = cloud(512, 3, 4);
        let t = BallTree::build(&pts, 512, 4);
        let fine = t.balls(32);
        let coarse = t.balls(64);
        for (b, cb) in coarse.iter().enumerate() {
            let l = &fine[2 * b];
            let r = &fine[2 * b + 1];
            assert_eq!(cb.start, l.start);
            assert_eq!(cb.start + cb.size, r.start + r.size);
        }
    }

    #[test]
    fn feature_roundtrip() {
        let pts = cloud(200, 3, 5);
        let feats = cloud(200, 6, 6);
        let t = BallTree::build(&pts, 256, 5);
        let pf = t.permute_features(&feats);
        assert_eq!(pf.shape(), &[256, 6]);
        // unpermute identity: treat features as "predictions"
        let back = t.unpermute_predictions(&pf);
        assert_eq!(back, feats);
    }

    #[test]
    fn split_axis_separates_space() {
        // Two well-separated clusters must land in different halves.
        let mut data = Vec::new();
        for i in 0..64 {
            let off = if i < 32 { -10.0 } else { 10.0 };
            data.extend_from_slice(&[off + (i % 7) as f32 * 0.01, 0.0, 0.0]);
        }
        let pts = Tensor::new(vec![64, 3], data);
        let t = BallTree::build(&pts, 64, 0);
        let first_half: Vec<f32> = (0..32).map(|i| t.coords.row(i)[0]).collect();
        let second_half: Vec<f32> = (32..64).map(|i| t.coords.row(i)[0]).collect();
        assert!(first_half.iter().all(|&x| x < 0.0) != first_half.iter().all(|&x| x > 0.0) || true);
        // halves are homogeneous in sign
        assert!(
            first_half.iter().all(|&x| x < 0.0) && second_half.iter().all(|&x| x > 0.0)
                || first_half.iter().all(|&x| x > 0.0) && second_half.iter().all(|&x| x < 0.0)
        );
    }

    #[test]
    #[should_panic]
    fn non_power_of_two_target_panics() {
        let pts = cloud(10, 3, 0);
        BallTree::build(&pts, 24, 0);
    }

    #[test]
    fn ball_of_granularity() {
        let pts = cloud(128, 3, 9);
        let t = BallTree::build(&pts, 128, 9);
        assert_eq!(t.ball_of(0, 32), 0);
        assert_eq!(t.ball_of(127, 32), 3);
        assert_eq!(t.num_balls(32), 4);
    }

    #[test]
    fn permute_features_into_matches_allocating() {
        let pts = cloud(100, 3, 12);
        let feats = cloud(100, 5, 13);
        let t = BallTree::build(&pts, 128, 12);
        let alloc = t.permute_features(&feats);
        let mut buf = vec![f32::NAN; 128 * 5];
        t.permute_features_into(&feats, &mut buf);
        assert_eq!(buf.as_slice(), alloc.data());
    }

    #[test]
    fn unpermute_view_matches_tensor_path() {
        let pts = cloud(90, 3, 14);
        let feats = cloud(90, 4, 15);
        let t = BallTree::build(&pts, 128, 14);
        let permuted = t.permute_features(&feats);
        let via_tensor = t.unpermute_predictions(&permuted);
        let via_view = t.unpermute_predictions_view(permuted.data(), 4);
        assert_eq!(via_tensor, via_view);
        assert_eq!(via_view, feats);
    }

    #[test]
    fn content_hash_is_stable_and_sensitive() {
        let a = Tensor::new(vec![4], vec![1., 2., 3., 4.]);
        let b = Tensor::new(vec![4], vec![1., 2., 3., 4.]);
        let c = Tensor::new(vec![4], vec![1., 2., 3., 5.]);
        assert_eq!(content_hash(&a), content_hash(&b));
        assert_ne!(content_hash(&a), content_hash(&c));
        // odd lengths exercise the chunk remainder
        let d = Tensor::new(vec![3], vec![1., 2., 3.]);
        let e = Tensor::new(vec![3], vec![1., 2., 4.]);
        assert_ne!(content_hash(&d), content_hash(&e));
        // trailing zeros vs shorter payload must differ (length is mixed in)
        let f = Tensor::new(vec![2], vec![1., 0.]);
        let g = Tensor::new(vec![1], vec![1.]);
        assert_ne!(content_hash(&f), content_hash(&g));
    }

    #[test]
    fn cache_counts_hits_and_misses() {
        let cache = BallTreeCache::new(8);
        let a = cloud(64, 3, 20);
        let b = cloud(64, 3, 21);
        let t1 = cache.get_or_build(&a, 64);
        let t2 = cache.get_or_build(&b, 64);
        assert_eq!((cache.hits(), cache.misses()), (0, 2));
        let t1_again = cache.get_or_build(&a, 64);
        assert_eq!((cache.hits(), cache.misses()), (1, 2));
        assert!(Arc::ptr_eq(&t1, &t1_again));
        assert!(!Arc::ptr_eq(&t1, &t2));
        // same coords at a different padded length is a distinct entry
        cache.get_or_build(&a, 128);
        assert_eq!(cache.misses(), 3);
        assert_eq!(cache.len(), 3);
    }

    #[test]
    fn cache_evicts_least_recently_used() {
        let cache = BallTreeCache::new(2);
        let a = cloud(32, 3, 30);
        let b = cloud(32, 3, 31);
        let c = cloud(32, 3, 32);
        cache.get_or_build(&a, 32);
        cache.get_or_build(&b, 32);
        cache.get_or_build(&a, 32); // touch a: b becomes LRU
        cache.get_or_build(&c, 32); // evicts b
        assert_eq!(cache.len(), 2);
        let misses_before = cache.misses();
        cache.get_or_build(&a, 32); // still resident
        assert_eq!(cache.misses(), misses_before);
        cache.get_or_build(&b, 32); // was evicted: rebuild
        assert_eq!(cache.misses(), misses_before + 1);
    }

    #[test]
    fn try_get_then_build_insert_roundtrip() {
        let cache = BallTreeCache::new(2);
        let a = cloud(48, 3, 50);
        let hash = match cache.try_get(&a, 64) {
            Err(h) => h,
            Ok(_) => panic!("hit on an empty cache"),
        };
        assert_eq!((cache.hits(), cache.misses()), (0, 1));
        assert_eq!(hash, content_hash(&a));
        let built = cache.build_insert(&a, 64, hash);
        let hit = cache.try_get(&a, 64).expect("resident after insert");
        assert!(Arc::ptr_eq(&built, &hit));
        assert_eq!((cache.hits(), cache.misses()), (1, 1));
        // the hash seeds padding, so the cached tree matches a fresh build
        let fresh = BallTree::build(&a, 64, content_hash(&a));
        assert_eq!(hit.perm, fresh.perm);
    }

    #[test]
    fn cache_capacity_zero_disables() {
        let cache = BallTreeCache::new(0);
        let a = cloud(32, 3, 33);
        cache.get_or_build(&a, 32);
        cache.get_or_build(&a, 32);
        assert_eq!((cache.hits(), cache.misses()), (0, 2));
        assert!(cache.is_empty());
    }

    #[test]
    fn cached_tree_is_bit_identical_to_fresh_build() {
        // The cache must be semantically invisible: a hit returns a tree
        // whose permutation, padding, and feature routing are bit-identical
        // to building from scratch with the content-hash seed.
        let pts = cloud(120, 3, 40);
        let feats = cloud(120, 6, 41);
        let cache = BallTreeCache::new(4);
        cache.get_or_build(&pts, 128); // prime
        let cached = cache.get_or_build(&pts, 128);
        assert!(cache.hits() >= 1);
        let fresh = BallTree::build(&pts, 128, content_hash(&pts));
        assert_eq!(cached.perm, fresh.perm);
        assert_eq!(cached.real, fresh.real);
        assert_eq!(cached.coords, fresh.coords);
        let a = cached.unpermute_predictions(&cached.permute_features(&feats));
        let b = fresh.unpermute_predictions(&fresh.permute_features(&feats));
        assert_eq!(a, b);
    }
}
