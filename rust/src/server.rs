//! TCP serving front-end: a length-prefixed binary frame protocol over
//! the [`Router`](crate::coordinator::Router) (no HTTP/JSON stack is
//! vendored offline; the protocol is documented here and implemented for
//! both server and client).
//!
//! The server is backend-agnostic: the router it fronts may execute
//! compiled HLO artifacts or the pure-Rust
//! [`NativeBackend`](crate::backend::NativeBackend) (`bsa serve
//! --backend native`, optionally with `--precision f16` half-storage
//! forwards) — the wire protocol (always f32 on the wire) and stats
//! surface are identical either way.
//!
//! Frame layout (little-endian):
//!   request:  magic "BSRQ" | n u32 | d u32 | f u32 | coords n*d f32 | feats n*f f32
//!   response: magic "BSRS" | status u32 (0 = ok) | n u32 | o u32 | preds n*o f32
//!             on error: status 1 | msg_len u32 | msg bytes
//!   stats:    magic "BSST" (no body) → "BSRS" | status 2 | len u32 | json bytes
//!             (router counters incl. ball-tree cache hits/misses — the
//!             serving hot path's observability surface)
//!
//! The normative protocol specification — field bounds, status codes,
//! the BSST stats-frame JSON schema, and pipelining/shutdown semantics —
//! is `docs/FORMATS.md` at the repo root; keep this module and that
//! document in sync.

use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::coordinator::Router;
use crate::tensor::Tensor;

const REQ_MAGIC: &[u8; 4] = b"BSRQ";
const RESP_MAGIC: &[u8; 4] = b"BSRS";
const STATS_MAGIC: &[u8; 4] = b"BSST";
/// Hard cap on points per request (sanity bound for the wire format).
const MAX_POINTS: u32 = 1 << 22;

/// Serve loop: accept connections and answer prediction requests until
/// `stop` is set. Each connection may pipeline many requests. Finished
/// connection handlers are reaped (joined and dropped) on every accept
/// iteration, so a long-lived server holds one `JoinHandle` per *live*
/// connection rather than one per connection ever accepted; only the
/// still-live handlers are joined at shutdown.
pub fn serve(addr: &str, router: Arc<Router>, stop: Arc<AtomicBool>) -> anyhow::Result<()> {
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    log::info!("bsa server listening on {addr}");
    let mut conns: Vec<std::thread::JoinHandle<()>> = vec![];
    while !stop.load(Ordering::Relaxed) {
        reap_finished(&mut conns);
        match listener.accept() {
            Ok((stream, peer)) => {
                log::debug!("connection from {peer}");
                let router = router.clone();
                let stop = stop.clone();
                conns.push(std::thread::spawn(move || {
                    if let Err(e) = handle_conn(stream, &router, &stop) {
                        log::debug!("connection ended: {e}");
                    }
                }));
            }
            Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(5));
            }
            Err(e) => return Err(e.into()),
        }
    }
    for c in conns {
        let _ = c.join();
    }
    Ok(())
}

/// Join and drop every connection handler that has already exited
/// (`is_finished` is a cheap atomic read; join on a finished thread
/// returns immediately). Order is irrelevant, so `swap_remove` keeps
/// the reap O(live).
fn reap_finished(conns: &mut Vec<std::thread::JoinHandle<()>>) {
    let mut i = 0;
    while i < conns.len() {
        if conns[i].is_finished() {
            let _ = conns.swap_remove(i).join();
        } else {
            i += 1;
        }
    }
}

fn handle_conn(mut stream: TcpStream, router: &Router, stop: &AtomicBool) -> anyhow::Result<()> {
    stream.set_nodelay(true)?;
    // Frame headers are read with a timeout so idle connections observe
    // `stop` (otherwise a blocked read would wedge server shutdown while a
    // client keeps the socket open). Once a frame has started, the rest is
    // read blocking — frames are short and written atomically.
    stream.set_read_timeout(Some(std::time::Duration::from_millis(100)))?;
    loop {
        // wait for the 4-byte magic, polling stop on timeout
        let mut magic = [0u8; 4];
        loop {
            if stop.load(Ordering::Relaxed) {
                return Ok(());
            }
            match stream.read(&mut magic[..1]) {
                Ok(0) => return Ok(()), // clean close
                Ok(_) => break,
                Err(e)
                    if e.kind() == std::io::ErrorKind::WouldBlock
                        || e.kind() == std::io::ErrorKind::TimedOut =>
                {
                    continue;
                }
                Err(e) => return Err(e.into()),
            }
        }
        stream.set_read_timeout(None)?;
        stream.read_exact(&mut magic[1..])?;
        if &magic == STATS_MAGIC {
            write_stats(&mut stream, router)?;
            stream.set_read_timeout(Some(std::time::Duration::from_millis(100)))?;
            continue;
        }
        if &magic != REQ_MAGIC {
            crate::trace::incr("server.error_frames");
            anyhow::bail!("bad request magic {magic:?}");
        }
        crate::trace::incr("server.requests");
        let result = {
            let _s = crate::trace::span("serve.decode");
            read_request_body(&mut stream)
        };
        stream.set_read_timeout(Some(std::time::Duration::from_millis(100)))?;
        let (coords, feats) = match result {
            Ok(x) => x,
            Err(e)
                if e.downcast_ref::<std::io::Error>()
                    .map(|io| io.kind() == std::io::ErrorKind::UnexpectedEof)
                    == Some(true) =>
            {
                return Ok(()); // clean close mid-frame
            }
            Err(e) => {
                crate::trace::incr("server.error_frames");
                return Err(e);
            }
        };
        match router.infer(coords, feats) {
            Ok(pred) => {
                let _s = crate::trace::span("serve.encode");
                write_ok(&mut stream, &pred)?
            }
            Err(e) => {
                crate::trace::incr("server.error_frames");
                write_err(&mut stream, &e.to_string())?
            }
        }
    }
}

/// Read the request after its magic has been consumed.
fn read_request_body(stream: &mut TcpStream) -> anyhow::Result<(Tensor, Tensor)> {
    let n = read_u32(stream)?;
    let d = read_u32(stream)?;
    let f = read_u32(stream)?;
    anyhow::ensure!(n > 0 && n <= MAX_POINTS, "bad point count {n}");
    anyhow::ensure!(d <= 16 && f <= 64, "bad dims d={d} f={f}");
    let coords = read_f32s(stream, (n * d) as usize)?;
    let feats = read_f32s(stream, (n * f) as usize)?;
    Ok((
        Tensor::new(vec![n as usize, d as usize], coords),
        Tensor::new(vec![n as usize, f as usize], feats),
    ))
}

fn write_ok(stream: &mut TcpStream, pred: &Tensor) -> anyhow::Result<()> {
    let mut buf = Vec::with_capacity(16 + pred.len() * 4);
    buf.extend_from_slice(RESP_MAGIC);
    buf.extend_from_slice(&0u32.to_le_bytes());
    buf.extend_from_slice(&(pred.rows() as u32).to_le_bytes());
    buf.extend_from_slice(&(pred.cols() as u32).to_le_bytes());
    for x in pred.data() {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    stream.write_all(&buf)?;
    Ok(())
}

fn write_stats(stream: &mut TcpStream, router: &Router) -> anyhow::Result<()> {
    let st = router.stats();
    // Keys are append-only (docs/FORMATS.md §2.3): the tracing sections
    // (`trace_version`/`trace_level`/`spans`/`counters`/`gauges`, schema
    // §2.3.1) ride after the original router counters. Span aggregation
    // is per stage path (not per layer index), so the payload stays far
    // below the client's 64KiB stats bound at any model depth.
    let json = format!(
        "{{\"served\": {}, \"rejected\": {}, \"batches\": {}, \"mean_batch\": {:.3}, \
         \"tree_hits\": {}, \"tree_misses\": {}, \"latency\": \"{}\", \"latency_n\": {}, {}}}",
        st.served,
        st.rejected,
        st.batches,
        st.mean_batch,
        st.tree_hits,
        st.tree_misses,
        st.latency_summary,
        st.latency_samples,
        crate::trace::stats_sections_json(),
    );
    let mut buf = Vec::with_capacity(12 + json.len());
    buf.extend_from_slice(RESP_MAGIC);
    buf.extend_from_slice(&2u32.to_le_bytes());
    buf.extend_from_slice(&(json.len() as u32).to_le_bytes());
    buf.extend_from_slice(json.as_bytes());
    stream.write_all(&buf)?;
    Ok(())
}

fn write_err(stream: &mut TcpStream, msg: &str) -> anyhow::Result<()> {
    let mut buf = Vec::with_capacity(12 + msg.len());
    buf.extend_from_slice(RESP_MAGIC);
    buf.extend_from_slice(&1u32.to_le_bytes());
    buf.extend_from_slice(&(msg.len() as u32).to_le_bytes());
    buf.extend_from_slice(msg.as_bytes());
    stream.write_all(&buf)?;
    Ok(())
}

// ---------------------------------------------------------------------------
// client
// ---------------------------------------------------------------------------

/// Blocking client for the frame protocol.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> anyhow::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Client { stream })
    }

    /// Send one point cloud, receive predictions (N, out_features).
    pub fn predict(&mut self, coords: &Tensor, feats: &Tensor) -> anyhow::Result<Tensor> {
        let n = coords.rows();
        let mut buf = Vec::with_capacity(16 + (coords.len() + feats.len()) * 4);
        buf.extend_from_slice(REQ_MAGIC);
        buf.extend_from_slice(&(n as u32).to_le_bytes());
        buf.extend_from_slice(&(coords.cols() as u32).to_le_bytes());
        buf.extend_from_slice(&(feats.cols() as u32).to_le_bytes());
        for x in coords.data() {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        for x in feats.data() {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        self.stream.write_all(&buf)?;

        let mut magic = [0u8; 4];
        self.stream.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == RESP_MAGIC, "bad response magic");
        let status = read_u32(&mut self.stream)?;
        if status != 0 {
            let mlen = read_u32(&mut self.stream)? as usize;
            anyhow::ensure!(mlen < 65536, "oversized error message");
            let mut m = vec![0u8; mlen];
            self.stream.read_exact(&mut m)?;
            anyhow::bail!("server error: {}", String::from_utf8_lossy(&m));
        }
        let rn = read_u32(&mut self.stream)? as usize;
        let ro = read_u32(&mut self.stream)? as usize;
        let data = read_f32s(&mut self.stream, rn * ro)?;
        Ok(Tensor::new(vec![rn, ro], data))
    }

    /// Query router statistics (JSON string; see the frame docs above).
    pub fn stats(&mut self) -> anyhow::Result<String> {
        self.stream.write_all(STATS_MAGIC)?;
        let mut magic = [0u8; 4];
        self.stream.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == RESP_MAGIC, "bad response magic");
        let status = read_u32(&mut self.stream)?;
        anyhow::ensure!(status == 2, "expected stats frame, got status {status}");
        let len = read_u32(&mut self.stream)? as usize;
        anyhow::ensure!(len < 65536, "oversized stats payload");
        let mut buf = vec![0u8; len];
        self.stream.read_exact(&mut buf)?;
        Ok(String::from_utf8(buf)?)
    }
}

fn read_u32<R: Read>(r: &mut R) -> anyhow::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_f32s<R: Read>(r: &mut R, n: usize) -> anyhow::Result<Vec<f32>> {
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    // Wire-format framing is covered end-to-end by rust/tests/integration.rs
    // (server + client over a compiled graph). The handle-reaping logic is
    // unit-tested here because the leak it prevents (a Vec<JoinHandle>
    // growing per connection ever accepted) is invisible from outside the
    // process: exited-but-unjoined threads leave the OS thread count on
    // their own, so only inspecting the vec itself can catch a regression.
    use super::reap_finished;

    #[test]
    fn reap_finished_drops_only_exited_handlers() {
        let (tx, rx) = std::sync::mpsc::channel::<()>();
        let mut conns = Vec::new();
        for _ in 0..8 {
            conns.push(std::thread::spawn(|| {}));
        }
        // one still-live handler, blocked like an idle connection
        conns.push(std::thread::spawn(move || {
            rx.recv().ok();
        }));

        // wait (bounded) for the 8 trivial handlers to exit
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while conns.iter().take(8).any(|h| !h.is_finished()) {
            assert!(std::time::Instant::now() < deadline, "handlers never exited");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }

        reap_finished(&mut conns);
        assert_eq!(conns.len(), 1, "reap must drop every exited handler, keep the live one");

        // release the live handler; a second reap empties the vec
        tx.send(()).unwrap();
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        while !conns[0].is_finished() {
            assert!(std::time::Instant::now() < deadline, "live handler never exited");
            std::thread::sleep(std::time::Duration::from_millis(2));
        }
        reap_finished(&mut conns);
        assert!(conns.is_empty(), "second reap must join the released handler");
    }
}
