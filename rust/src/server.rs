//! TCP serving front-end: a length-prefixed binary frame protocol over
//! the [`Router`](crate::coordinator::Router) (no HTTP/JSON stack is
//! vendored offline; the protocol is documented here and implemented for
//! both server and client).
//!
//! The server is a **single-threaded nonblocking poll core**: one thread
//! owns the listener and every connection, multiplexed with
//! `libc::poll` over `TcpStream::set_nonblocking` sockets. Each
//! connection is a small state machine (magic → header → body →
//! respond) decoding frames incrementally into a reusable per-connection
//! buffer; completed requests are handed to the router and their
//! replies flow back through a per-connection FIFO, so many BSRQ frames
//! can be in flight on one connection (true pipelining) while responses
//! stay in request order. Idle connections cost one `pollfd` entry and
//! no thread, so the core holds thousands of open sockets.
//!
//! Admission control ([`ServeLimits`]) bounds what the core accepts:
//!
//! * `max_conns` — connections past the cap are answered with a shed
//!   frame at accept time and closed;
//! * `max_payload_bytes` — enforced at *header* time: an oversized
//!   declared body is answered with a status-1 error frame before a
//!   single payload byte is buffered (no attacker-controlled
//!   allocation);
//! * `max_inflight_bytes` — a global budget over admitted-but-unanswered
//!   request bytes; past it, requests are *shed*: the body is drained in
//!   a fixed scratch buffer and a typed status-3 frame with a
//!   retry-after hint is returned, the connection stays usable;
//! * `conn_quota` — per-connection in-flight frame cap, applied as
//!   backpressure (the core simply stops reading that socket until
//!   responses drain; TCP flow control pushes back on the client).
//!
//! Router queue-full is also surfaced as a status-3 shed frame (instead
//! of a generic error), and every shed increments the router's
//! `rejected` counter so BSST stats account for refused work wherever
//! it was refused. On `stop` (SIGINT) the core drains: it stops
//! accepting, finishes frames already past their magic, flushes every
//! pending response, and exits within `drain_ms`.
//!
//! Frame layout (little-endian):
//!   request:  magic "BSRQ" | n u32 | d u32 | f u32 | coords n*d f32 | feats n*f f32
//!   response: magic "BSRS" | status u32 (0 = ok) | n u32 | o u32 | preds n*o f32
//!             on error: status 1 | msg_len u32 | msg bytes
//!             on shed:  status 3 | retry_after_ms u32 | msg_len u32 | msg bytes
//!   stats:    magic "BSST" (no body) → "BSRS" | status 2 | len u32 | json bytes
//!             (router counters incl. ball-tree cache hits/misses — the
//!             serving hot path's observability surface)
//!
//! The normative protocol specification — field bounds, status codes,
//! the BSST stats-frame JSON schema, and pipelining/shutdown semantics —
//! is `docs/FORMATS.md` at the repo root; keep this module and that
//! document in sync.

use std::collections::VecDeque;
use std::io::{ErrorKind, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::os::unix::io::AsRawFd;
use std::sync::atomic::{AtomicBool, AtomicI64, AtomicU64, Ordering};
use std::sync::mpsc::{Receiver, TryRecvError};
use std::sync::{Arc, Once};
use std::time::{Duration, Instant};

use crate::config::ServeConfig;
use crate::coordinator::{Router, ServeResponse, SubmitError};
use crate::tensor::Tensor;

// Frame constants are `pub(crate)`: the shard front door (crate::shard)
// speaks the same wire protocol when relaying frames between clients
// and workers, and must agree on these bytes exactly.
pub(crate) const REQ_MAGIC: &[u8; 4] = b"BSRQ";
pub(crate) const RESP_MAGIC: &[u8; 4] = b"BSRS";
pub(crate) const STATS_MAGIC: &[u8; 4] = b"BSST";
/// Hard cap on points per request (sanity bound for the wire format).
pub(crate) const MAX_POINTS: u32 = 1 << 22;
/// Hard cap on coordinate dims per point.
pub(crate) const MAX_COORD_DIMS: u32 = 16;
/// Hard cap on feature dims per point.
pub(crate) const MAX_FEAT_DIMS: u32 = 64;
/// Largest error/shed message the server writes; the reference client
/// rejects status-1/2/3 payloads >= 64 KiB, so the server truncates to
/// stay decodable (docs/FORMATS.md §2.2).
const MAX_MSG_BYTES: usize = 65535;
/// Largest stats (status-2) payload; same client bound as above.
const MAX_STATS_BYTES: usize = 65535;
/// Client-side plausibility bound on `o` in an ok frame.
const MAX_OUT_FEATURES: u32 = 1 << 16;
/// Client-side bound on a whole ok-frame payload (matches the protocol's
/// ~1 GiB theoretical request ceiling).
const MAX_RESP_BYTES: u64 = 1 << 30;
/// Body bytes are read in steps of at most this, so a connection's read
/// buffer grows with data actually received, never with the declared
/// frame size.
const READ_CHUNK: usize = 256 * 1024;
/// Scratch size used to drain (discard) the body of a shed request.
const DISCARD_CHUNK: usize = 64 * 1024;
/// Backoff after a transient `accept()` error (EMFILE, ECONNABORTED, …)
/// before the listener is polled again.
const ACCEPT_BACKOFF: Duration = Duration::from_millis(10);

pub(crate) const STATUS_OK: u32 = 0;
pub(crate) const STATUS_ERR: u32 = 1;
pub(crate) const STATUS_STATS: u32 = 2;
pub(crate) const STATUS_SHED: u32 = 3;

// ---------------------------------------------------------------------------
// admission limits
// ---------------------------------------------------------------------------

/// Admission-control knobs for the poll core. Mirrors the `[serve]`
/// limits in [`ServeConfig`]; [`serve`] uses the defaults, `bsa serve`
/// builds one from its config/flags and calls [`serve_with`].
#[derive(Debug, Clone)]
pub struct ServeLimits {
    /// Open-connection cap; connections past it get a shed frame at
    /// accept time and are closed.
    pub max_conns: usize,
    /// Largest declared request body (coords + feats bytes) accepted;
    /// bigger headers are answered with a status-1 error frame and the
    /// connection is closed (the body is never buffered).
    pub max_payload_bytes: u64,
    /// Global budget over admitted-but-unanswered request bytes; past
    /// it, new requests are shed (status 3) but the connection lives.
    pub max_inflight_bytes: u64,
    /// Per-connection in-flight frame cap (backpressure: the core stops
    /// reading the socket, no shed frame).
    pub conn_quota: usize,
    /// Retry-after hint carried by status-3 shed frames, milliseconds.
    pub retry_after_ms: u32,
    /// Drain budget after `stop` is set: in-flight requests get this
    /// long to complete and flush before connections are closed.
    pub drain_ms: u64,
}

impl Default for ServeLimits {
    fn default() -> Self {
        ServeLimits {
            max_conns: 4096,
            max_payload_bytes: 64 << 20,
            max_inflight_bytes: 256 << 20,
            conn_quota: 32,
            retry_after_ms: 50,
            drain_ms: 2000,
        }
    }
}

impl From<&ServeConfig> for ServeLimits {
    fn from(sc: &ServeConfig) -> Self {
        ServeLimits {
            max_conns: sc.max_conns,
            max_payload_bytes: sc.max_payload_bytes,
            max_inflight_bytes: sc.max_inflight_bytes,
            conn_quota: sc.conn_quota,
            retry_after_ms: sc.retry_after_ms as u32,
            drain_ms: sc.drain_ms,
        }
    }
}

impl ServeLimits {
    /// Clamp degenerate values that would wedge the core (a zero
    /// connection or frame quota can never make progress).
    fn sanitized(mut self) -> Self {
        self.max_conns = self.max_conns.max(1);
        self.conn_quota = self.conn_quota.max(1);
        self
    }
}

// ---------------------------------------------------------------------------
// gauges (process-global; aggregated across servers in one process)
// ---------------------------------------------------------------------------

struct ServerGauges {
    open_conns: AtomicI64,
    inflight_frames: AtomicI64,
    inflight_bytes: AtomicI64,
    shed_total: AtomicU64,
}

static GAUGES: ServerGauges = ServerGauges {
    open_conns: AtomicI64::new(0),
    inflight_frames: AtomicI64::new(0),
    inflight_bytes: AtomicI64::new(0),
    shed_total: AtomicU64::new(0),
};
static GAUGE_REG: Once = Once::new();

/// The server's live gauges, registered with the trace registry on
/// first use so BSST frames report them (`server.*` in the `gauges`
/// section). Like `pool.*`, they are process-global: several in-process
/// servers (the test suite) aggregate into one set, and the
/// inflight-bytes admission budget is shared accordingly.
fn gauges() -> &'static ServerGauges {
    GAUGE_REG.call_once(|| {
        crate::trace::register_gauge(
            "server.open_conns",
            Box::new(|| GAUGES.open_conns.load(Ordering::Relaxed) as f64),
        );
        crate::trace::register_gauge(
            "server.inflight_frames",
            Box::new(|| GAUGES.inflight_frames.load(Ordering::Relaxed) as f64),
        );
        crate::trace::register_gauge(
            "server.inflight_bytes",
            Box::new(|| GAUGES.inflight_bytes.load(Ordering::Relaxed) as f64),
        );
        crate::trace::register_gauge(
            "server.shed_total",
            Box::new(|| GAUGES.shed_total.load(Ordering::Relaxed) as f64),
        );
    });
    &GAUGES
}

// ---------------------------------------------------------------------------
// frame encoding
// ---------------------------------------------------------------------------

/// Truncate a message to the client's 64 KiB payload cap on a UTF-8
/// character boundary (a longer message would make the client fail with
/// "oversized error message" instead of surfacing the real one).
fn truncate_msg(msg: &str) -> &str {
    if msg.len() <= MAX_MSG_BYTES {
        return msg;
    }
    let mut end = MAX_MSG_BYTES;
    while !msg.is_char_boundary(end) {
        end -= 1;
    }
    &msg[..end]
}

fn encode_ok(pred: &Tensor) -> Vec<u8> {
    let mut buf = Vec::with_capacity(16 + pred.len() * 4);
    buf.extend_from_slice(RESP_MAGIC);
    buf.extend_from_slice(&STATUS_OK.to_le_bytes());
    buf.extend_from_slice(&(pred.rows() as u32).to_le_bytes());
    buf.extend_from_slice(&(pred.cols() as u32).to_le_bytes());
    for x in pred.data() {
        buf.extend_from_slice(&x.to_le_bytes());
    }
    buf
}

pub(crate) fn encode_err(msg: &str) -> Vec<u8> {
    let msg = truncate_msg(msg);
    let mut buf = Vec::with_capacity(12 + msg.len());
    buf.extend_from_slice(RESP_MAGIC);
    buf.extend_from_slice(&STATUS_ERR.to_le_bytes());
    buf.extend_from_slice(&(msg.len() as u32).to_le_bytes());
    buf.extend_from_slice(msg.as_bytes());
    buf
}

pub(crate) fn encode_shed(retry_after_ms: u32, msg: &str) -> Vec<u8> {
    let msg = truncate_msg(msg);
    let mut buf = Vec::with_capacity(16 + msg.len());
    buf.extend_from_slice(RESP_MAGIC);
    buf.extend_from_slice(&STATUS_SHED.to_le_bytes());
    buf.extend_from_slice(&retry_after_ms.to_le_bytes());
    buf.extend_from_slice(&(msg.len() as u32).to_le_bytes());
    buf.extend_from_slice(msg.as_bytes());
    buf
}

/// Assemble the stats JSON under the client's 64 KiB status-2 bound.
/// `core` and `sections` are brace-less `"k": v, ...` fragments. Span
/// aggregation keeps the payload far below the bound in practice; if the
/// tracing sections ever blow it, they are dropped (flagged with
/// `"trace_truncated": true`) rather than shipping a frame the client
/// must reject.
pub(crate) fn bounded_stats_json(core: &str, sections: &str) -> String {
    let full = format!("{{{core}, {sections}}}");
    if full.len() <= MAX_STATS_BYTES {
        return full;
    }
    format!("{{{core}, \"trace_truncated\": true}}")
}

/// Brace-less router-counter fragment of the stats payload
/// (docs/FORMATS.md §2.3). Keys are append-only: `uptime_ms` and
/// `epoch` (router incarnation) ride after the original counters so the
/// shard front door can tell a respawned worker from a healthy one
/// (docs/FORMATS.md §3.2).
fn core_stats_json(router: &Router) -> String {
    let st = router.stats();
    format!(
        "\"served\": {}, \"rejected\": {}, \"batches\": {}, \"mean_batch\": {:.3}, \
         \"tree_hits\": {}, \"tree_misses\": {}, \"latency\": \"{}\", \"latency_n\": {}, \
         \"uptime_ms\": {}, \"epoch\": {}",
        st.served,
        st.rejected,
        st.batches,
        st.mean_batch,
        st.tree_hits,
        st.tree_misses,
        st.latency_summary,
        st.latency_samples,
        st.uptime_ms,
        st.epoch,
    )
}

fn stats_frame(router: &Router) -> Vec<u8> {
    // Keys are append-only (docs/FORMATS.md §2.3): the tracing sections
    // (`trace_version`/`trace_level`/`spans`/`counters`/`gauges`, schema
    // §2.3.1) ride after the original router counters.
    let json = bounded_stats_json(&core_stats_json(router), &crate::trace::stats_sections_json());
    let mut buf = Vec::with_capacity(12 + json.len());
    buf.extend_from_slice(RESP_MAGIC);
    buf.extend_from_slice(&STATUS_STATS.to_le_bytes());
    buf.extend_from_slice(&(json.len() as u32).to_le_bytes());
    buf.extend_from_slice(json.as_bytes());
    buf
}

// ---------------------------------------------------------------------------
// header admission
// ---------------------------------------------------------------------------

enum Admission {
    /// Header accepted; `bytes` is the declared body size to read.
    Admit { bytes: u64 },
    /// Protocol violation: status-1 error frame, then close (the
    /// declared body length can't be trusted, so the stream is dead).
    Reject(String),
    /// Over the inflight budget: drain `bytes` of body, answer with a
    /// status-3 shed frame, keep the connection.
    Shed { bytes: u64, why: &'static str },
}

/// Decide what to do with a decoded BSRQ header, *before* any body byte
/// is read or buffered. `inflight` is the current global
/// admitted-but-unanswered byte count.
fn admit_header(n: u32, d: u32, f: u32, inflight: u64, limits: &ServeLimits) -> Admission {
    if n == 0 || n > MAX_POINTS {
        return Admission::Reject(format!("bad point count n={n} (expected 1..={MAX_POINTS})"));
    }
    if d == 0 || d > MAX_COORD_DIMS {
        return Admission::Reject(format!(
            "bad coordinate dims d={d} (expected 1..={MAX_COORD_DIMS})"
        ));
    }
    if f == 0 || f > MAX_FEAT_DIMS {
        return Admission::Reject(format!(
            "bad feature dims f={f} (expected 1..={MAX_FEAT_DIMS})"
        ));
    }
    let bytes = 4 * (n as u64) * (d as u64 + f as u64);
    if bytes > limits.max_payload_bytes {
        return Admission::Reject(format!(
            "request body {bytes} B exceeds max_payload_bytes {} (n={n} d={d} f={f})",
            limits.max_payload_bytes
        ));
    }
    if inflight.saturating_add(bytes) > limits.max_inflight_bytes {
        return Admission::Shed { bytes, why: "server over its inflight-bytes budget" };
    }
    Admission::Admit { bytes }
}

/// Classify an `accept()` error: `None` means "no pending connection,
/// just poll again" (WouldBlock); `Some(backoff)` means a transient
/// fault (EMFILE fd exhaustion, ECONNABORTED races, …) — log, back off
/// briefly, keep serving. No accept error is ever fatal: the old serve
/// loop returned `Err` here and one fd-exhaustion blip killed the
/// listener for every connected client.
pub(crate) fn accept_error_backoff(e: &std::io::Error) -> Option<Duration> {
    if e.kind() == ErrorKind::WouldBlock {
        None
    } else {
        Some(ACCEPT_BACKOFF)
    }
}

// ---------------------------------------------------------------------------
// per-connection state machine
// ---------------------------------------------------------------------------

enum ReadState {
    /// Waiting for a 4-byte frame magic.
    Magic,
    /// BSRQ magic seen; waiting for the 12-byte n/d/f header.
    Header,
    /// Header admitted; reading `bytes` body bytes into `rbuf`.
    Body { n: usize, d: usize, f: usize, bytes: u64 },
    /// Shed: discarding `remaining` body bytes through a shared scratch
    /// buffer, then queueing the prepared `reply` frame.
    Discard { remaining: u64, reply: Vec<u8> },
}

/// A response slot in a connection's FIFO: either an already-encoded
/// frame or a router receiver still owed its result. Responses leave in
/// FIFO order, which is what keeps pipelining in request order.
enum Pending {
    Ready(Vec<u8>),
    Waiting { rx: Receiver<ServeResponse>, bytes: u64 },
}

enum ReadProgress {
    Complete,
    Blocked,
    Eof,
}

/// Read toward `need` total bytes in `buf`, growing it in bounded
/// `READ_CHUNK` steps (so buffer growth tracks bytes actually received,
/// never the declared frame size).
fn read_into(stream: &mut TcpStream, buf: &mut Vec<u8>, need: usize) -> std::io::Result<ReadProgress> {
    while buf.len() < need {
        let target = need.min(buf.len() + READ_CHUNK);
        let start = buf.len();
        buf.resize(target, 0);
        match stream.read(&mut buf[start..]) {
            Ok(0) => {
                buf.truncate(start);
                return Ok(ReadProgress::Eof);
            }
            Ok(k) => buf.truncate(start + k),
            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                buf.truncate(start);
                return Ok(ReadProgress::Blocked);
            }
            Err(e) if e.kind() == ErrorKind::Interrupted => buf.truncate(start),
            Err(e) => {
                buf.truncate(start);
                return Err(e);
            }
        }
    }
    Ok(ReadProgress::Complete)
}

struct Conn {
    stream: TcpStream,
    rbuf: Vec<u8>,
    rstate: ReadState,
    wbuf: Vec<u8>,
    wpos: usize,
    pending: VecDeque<Pending>,
    /// Set on EOF or a fatal protocol error: stop reading, flush every
    /// queued response, then close.
    close_when_drained: bool,
}

impl Conn {
    fn new(stream: TcpStream) -> std::io::Result<Conn> {
        stream.set_nonblocking(true)?;
        stream.set_nodelay(true)?;
        gauges().open_conns.fetch_add(1, Ordering::Relaxed);
        Ok(Conn {
            stream,
            rbuf: Vec::new(),
            rstate: ReadState::Magic,
            wbuf: Vec::new(),
            wpos: 0,
            pending: VecDeque::new(),
            close_when_drained: false,
        })
    }

    fn mid_frame(&self) -> bool {
        !matches!(self.rstate, ReadState::Magic)
    }

    /// Should the poll set include POLLIN for this socket? False under
    /// per-connection quota backpressure (TCP flow control then pushes
    /// back on the client) and, while draining, for anything but
    /// finishing a frame already past its magic.
    fn wants_read(&self, draining: bool, quota: usize) -> bool {
        if self.close_when_drained || self.pending.len() >= quota {
            return false;
        }
        if draining {
            return self.mid_frame();
        }
        true
    }

    fn wants_write(&self) -> bool {
        self.wpos < self.wbuf.len()
    }

    fn queue_frame(&mut self, frame: Vec<u8>) {
        self.pending.push_back(Pending::Ready(frame));
    }

    /// One scheduling pass: read/parse what's available, move completed
    /// responses into the write buffer, flush. Returns `false` when the
    /// connection should be dropped.
    fn drive(
        &mut self,
        router: &Router,
        limits: &ServeLimits,
        draining: bool,
        can_read: bool,
        scratch: &mut [u8],
    ) -> bool {
        if can_read && !self.pump_reads(router, limits, draining, scratch) {
            return false;
        }
        self.pump_responses();
        if !self.flush() {
            return false;
        }
        let idle = self.pending.is_empty() && !self.wants_write();
        if self.close_when_drained && idle {
            return false;
        }
        // Draining: a connection with nothing owed and no frame underway
        // is closed; mid-frame connections get to finish (bounded by the
        // caller's drain deadline).
        if draining && idle && !self.mid_frame() {
            return false;
        }
        true
    }

    /// Decode as many frames as the socket has bytes for, respecting
    /// quota backpressure. Returns `false` on a socket error (drop the
    /// connection without ceremony).
    fn pump_reads(
        &mut self,
        router: &Router,
        limits: &ServeLimits,
        draining: bool,
        scratch: &mut [u8],
    ) -> bool {
        loop {
            if !self.wants_read(draining, limits.conn_quota) {
                return true;
            }
            match std::mem::replace(&mut self.rstate, ReadState::Magic) {
                ReadState::Magic => match read_into(&mut self.stream, &mut self.rbuf, 4) {
                    Err(_) => return false,
                    Ok(ReadProgress::Blocked) => return true,
                    Ok(ReadProgress::Eof) => {
                        // Clean close at (or inside) a frame boundary.
                        self.close_when_drained = true;
                        return true;
                    }
                    Ok(ReadProgress::Complete) => {
                        let magic = [self.rbuf[0], self.rbuf[1], self.rbuf[2], self.rbuf[3]];
                        self.rbuf.clear();
                        if &magic == STATS_MAGIC {
                            self.queue_frame(stats_frame(router));
                        } else if &magic == REQ_MAGIC {
                            self.rstate = ReadState::Header;
                        } else {
                            // Answer before closing: the old server
                            // bailed without a frame and clients hung
                            // until TCP teardown.
                            crate::trace::incr("server.error_frames");
                            self.queue_frame(encode_err(&format!(
                                "bad request magic {magic:?} (expected BSRQ or BSST)"
                            )));
                            self.close_when_drained = true;
                            return true;
                        }
                    }
                },
                ReadState::Header => match read_into(&mut self.stream, &mut self.rbuf, 12) {
                    Err(_) => return false,
                    Ok(ReadProgress::Blocked) => {
                        self.rstate = ReadState::Header;
                        return true;
                    }
                    Ok(ReadProgress::Eof) => {
                        self.close_when_drained = true;
                        return true;
                    }
                    Ok(ReadProgress::Complete) => {
                        let n = u32::from_le_bytes(self.rbuf[0..4].try_into().unwrap());
                        let d = u32::from_le_bytes(self.rbuf[4..8].try_into().unwrap());
                        let f = u32::from_le_bytes(self.rbuf[8..12].try_into().unwrap());
                        self.rbuf.clear();
                        let g = gauges();
                        let inflight = g.inflight_bytes.load(Ordering::Relaxed).max(0) as u64;
                        match admit_header(n, d, f, inflight, limits) {
                            Admission::Admit { bytes } => {
                                g.inflight_bytes.fetch_add(bytes as i64, Ordering::Relaxed);
                                self.rstate = ReadState::Body {
                                    n: n as usize,
                                    d: d as usize,
                                    f: f as usize,
                                    bytes,
                                };
                            }
                            Admission::Reject(msg) => {
                                crate::trace::incr("server.error_frames");
                                self.queue_frame(encode_err(&msg));
                                self.close_when_drained = true;
                                return true;
                            }
                            Admission::Shed { bytes, why } => {
                                router.note_rejected();
                                g.shed_total.fetch_add(1, Ordering::Relaxed);
                                crate::trace::incr("server.shed");
                                self.rstate = ReadState::Discard {
                                    remaining: bytes,
                                    reply: encode_shed(limits.retry_after_ms, why),
                                };
                            }
                        }
                    }
                },
                ReadState::Body { n, d, f, bytes } => {
                    match read_into(&mut self.stream, &mut self.rbuf, bytes as usize) {
                        Err(_) => {
                            gauges().inflight_bytes.fetch_sub(bytes as i64, Ordering::Relaxed);
                            return false;
                        }
                        Ok(ReadProgress::Blocked) => {
                            self.rstate = ReadState::Body { n, d, f, bytes };
                            return true;
                        }
                        Ok(ReadProgress::Eof) => {
                            gauges().inflight_bytes.fetch_sub(bytes as i64, Ordering::Relaxed);
                            self.close_when_drained = true;
                            return true;
                        }
                        Ok(ReadProgress::Complete) => self.submit_request(router, limits, n, d, f, bytes),
                    }
                }
                ReadState::Discard { mut remaining, reply } => {
                    while remaining > 0 {
                        let want = (remaining as usize).min(scratch.len());
                        match self.stream.read(&mut scratch[..want]) {
                            Ok(0) => {
                                self.close_when_drained = true;
                                return true;
                            }
                            Ok(k) => remaining -= k as u64,
                            Err(e) if e.kind() == ErrorKind::WouldBlock => {
                                self.rstate = ReadState::Discard { remaining, reply };
                                return true;
                            }
                            Err(e) if e.kind() == ErrorKind::Interrupted => {}
                            Err(_) => return false,
                        }
                    }
                    self.queue_frame(reply);
                }
            }
        }
    }

    /// A fully buffered body: decode, hand to the router, remember the
    /// reply receiver in FIFO order.
    fn submit_request(
        &mut self,
        router: &Router,
        limits: &ServeLimits,
        n: usize,
        d: usize,
        f: usize,
        bytes: u64,
    ) {
        let g = gauges();
        let (coords, feats) = {
            let _s = crate::trace::span("serve.decode");
            let nd = n * d * 4;
            let coords: Vec<f32> = self.rbuf[..nd]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            let feats: Vec<f32> = self.rbuf[nd..]
                .chunks_exact(4)
                .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
                .collect();
            (Tensor::new(vec![n, d], coords), Tensor::new(vec![n, f], feats))
        };
        self.rbuf.clear();
        crate::trace::incr("server.requests");
        match router.try_submit(coords, feats) {
            Ok(rx) => {
                g.inflight_frames.fetch_add(1, Ordering::Relaxed);
                self.pending.push_back(Pending::Waiting { rx, bytes });
            }
            Err(SubmitError::QueueFull) => {
                g.inflight_bytes.fetch_sub(bytes as i64, Ordering::Relaxed);
                g.shed_total.fetch_add(1, Ordering::Relaxed);
                crate::trace::incr("server.shed");
                self.queue_frame(encode_shed(
                    limits.retry_after_ms,
                    "router queue full; retry shortly",
                ));
            }
            Err(SubmitError::ShuttingDown) => {
                g.inflight_bytes.fetch_sub(bytes as i64, Ordering::Relaxed);
                crate::trace::incr("server.error_frames");
                self.queue_frame(encode_err("router is shutting down"));
                self.close_when_drained = true;
            }
        }
    }

    /// Encode completed router replies into the write buffer, strictly
    /// FIFO: a Waiting head whose result isn't in yet blocks everything
    /// behind it, which is exactly the in-order pipelining contract.
    fn pump_responses(&mut self) {
        while let Some(front) = self.pending.front_mut() {
            let frame = match front {
                Pending::Ready(_) => match self.pending.pop_front() {
                    Some(Pending::Ready(f)) => f,
                    _ => unreachable!("front was Ready"),
                },
                Pending::Waiting { rx, bytes } => {
                    let bytes = *bytes;
                    let frame = match rx.try_recv() {
                        Ok(resp) => {
                            let _s = crate::trace::span("serve.encode");
                            match resp.result {
                                Ok(pred) => encode_ok(&pred),
                                Err(e) => {
                                    crate::trace::incr("server.error_frames");
                                    encode_err(&e.to_string())
                                }
                            }
                        }
                        Err(TryRecvError::Empty) => break,
                        Err(TryRecvError::Disconnected) => {
                            crate::trace::incr("server.error_frames");
                            encode_err("worker dropped the request")
                        }
                    };
                    let g = gauges();
                    g.inflight_bytes.fetch_sub(bytes as i64, Ordering::Relaxed);
                    g.inflight_frames.fetch_sub(1, Ordering::Relaxed);
                    self.pending.pop_front();
                    frame
                }
            };
            if self.wbuf.is_empty() {
                self.wpos = 0;
            }
            self.wbuf.extend_from_slice(&frame);
        }
    }

    /// Write as much of the buffered output as the socket accepts.
    /// Returns `false` on a socket error.
    fn flush(&mut self) -> bool {
        while self.wpos < self.wbuf.len() {
            match self.stream.write(&self.wbuf[self.wpos..]) {
                Ok(0) => return false,
                Ok(k) => self.wpos += k,
                Err(e) if e.kind() == ErrorKind::WouldBlock => break,
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(_) => return false,
            }
        }
        if self.wpos == self.wbuf.len() {
            self.wbuf.clear();
            self.wpos = 0;
        }
        true
    }
}

impl Drop for Conn {
    /// Gauge/budget bookkeeping survives any exit path: bytes still
    /// admitted (mid-body or awaiting a router reply) are refunded here.
    fn drop(&mut self) {
        let g = gauges();
        if let ReadState::Body { bytes, .. } = self.rstate {
            g.inflight_bytes.fetch_sub(bytes as i64, Ordering::Relaxed);
        }
        for p in &self.pending {
            if let Pending::Waiting { bytes, .. } = p {
                g.inflight_bytes.fetch_sub(*bytes as i64, Ordering::Relaxed);
                g.inflight_frames.fetch_sub(1, Ordering::Relaxed);
            }
        }
        g.open_conns.fetch_sub(1, Ordering::Relaxed);
    }
}

// ---------------------------------------------------------------------------
// poll loop
// ---------------------------------------------------------------------------

/// Readiness flags for the connections that existed when `poll` ran.
/// Connections accepted afterwards default to ready (their first drive
/// pass costs one cheap WouldBlock read at worst).
fn poll_readiness(
    listener: &TcpListener,
    conns: &[Conn],
    accepting: bool,
    draining: bool,
    quota: usize,
    timeout_ms: i32,
) -> Vec<bool> {
    let mut fds: Vec<libc::pollfd> = Vec::with_capacity(conns.len() + 1);
    let mut idx: Vec<usize> = Vec::with_capacity(conns.len());
    if accepting {
        fds.push(libc::pollfd { fd: listener.as_raw_fd(), events: libc::POLLIN, revents: 0 });
    }
    for (i, c) in conns.iter().enumerate() {
        let mut ev: libc::c_short = 0;
        if c.wants_read(draining, quota) {
            ev |= libc::POLLIN;
        }
        if c.wants_write() {
            ev |= libc::POLLOUT;
        }
        if ev != 0 {
            idx.push(i);
            fds.push(libc::pollfd { fd: c.stream.as_raw_fd(), events: ev, revents: 0 });
        }
    }
    let mut ready = vec![false; conns.len()];
    if fds.is_empty() {
        // Nothing pollable (e.g. every connection is quota-backpressured
        // or waiting on the router): just sleep the tick.
        std::thread::sleep(Duration::from_millis(timeout_ms.max(0) as u64));
        return ready;
    }
    let rc = unsafe { libc::poll(fds.as_mut_ptr(), fds.len() as libc::nfds_t, timeout_ms) };
    if rc <= 0 {
        return ready; // timeout or EINTR: nothing newly ready
    }
    let base = usize::from(accepting);
    for (k, fd) in fds.iter().enumerate().skip(base) {
        // POLLHUP/POLLERR count as ready too: the next read surfaces the
        // close/error and the connection is dropped.
        if fd.revents != 0 {
            ready[idx[k - base]] = true;
        }
    }
    ready
}

/// Accept everything pending. Past `max_conns`, the new socket gets a
/// best-effort shed frame and is closed (typed refusal, not a silent
/// RST). Returns a backoff deadline after a transient accept error.
fn accept_pending(
    listener: &TcpListener,
    conns: &mut Vec<Conn>,
    router: &Router,
    limits: &ServeLimits,
) -> Option<Instant> {
    loop {
        match listener.accept() {
            Ok((stream, peer)) => {
                if conns.len() >= limits.max_conns {
                    log::warn!("connection cap {} hit; shedding {peer}", limits.max_conns);
                    router.note_rejected();
                    gauges().shed_total.fetch_add(1, Ordering::Relaxed);
                    crate::trace::incr("server.shed");
                    let mut stream = stream;
                    let _ = stream
                        .write(&encode_shed(limits.retry_after_ms, "server at connection cap"));
                    continue; // stream drops → close
                }
                log::debug!("connection from {peer}");
                match Conn::new(stream) {
                    Ok(c) => conns.push(c),
                    Err(e) => log::warn!("failed to set up connection from {peer}: {e}"),
                }
            }
            Err(e) => {
                return accept_error_backoff(&e).map(|backoff| {
                    crate::trace::incr("server.accept_errors");
                    log::warn!("transient accept error ({e}); backing off {backoff:?}");
                    Instant::now() + backoff
                });
            }
        }
    }
}

/// Serve with default [`ServeLimits`]: accept connections and answer
/// prediction requests until `stop` is set. Each connection may
/// pipeline many requests; responses are returned in request order.
pub fn serve(addr: &str, router: Arc<Router>, stop: Arc<AtomicBool>) -> anyhow::Result<()> {
    serve_with(addr, router, stop, ServeLimits::default())
}

/// The poll core (see module docs). One thread drives the listener and
/// every connection; no per-connection threads exist. On `stop`, drains
/// in-flight work for up to `limits.drain_ms` before returning.
pub fn serve_with(
    addr: &str,
    router: Arc<Router>,
    stop: Arc<AtomicBool>,
    limits: ServeLimits,
) -> anyhow::Result<()> {
    let limits = limits.sanitized();
    let listener = TcpListener::bind(addr)?;
    listener.set_nonblocking(true)?;
    log::info!(
        "bsa server listening on {addr} (poll core: max_conns={}, max_payload={} B, \
         max_inflight={} B, conn_quota={})",
        limits.max_conns,
        limits.max_payload_bytes,
        limits.max_inflight_bytes,
        limits.conn_quota
    );
    gauges();
    let mut conns: Vec<Conn> = Vec::new();
    let mut scratch = vec![0u8; DISCARD_CHUNK];
    let mut accept_backoff: Option<Instant> = None;
    let mut drain_started: Option<Instant> = None;

    loop {
        let draining = stop.load(Ordering::Relaxed);
        if draining {
            let t0 = *drain_started.get_or_insert_with(|| {
                log::info!("stop requested; draining {} connection(s)", conns.len());
                Instant::now()
            });
            if conns.is_empty() {
                break;
            }
            if t0.elapsed() >= Duration::from_millis(limits.drain_ms) {
                log::warn!(
                    "drain deadline ({} ms) reached with {} connection(s) still busy; closing",
                    limits.drain_ms,
                    conns.len()
                );
                break;
            }
        }

        let accepting =
            !draining && accept_backoff.is_none_or(|until| Instant::now() >= until);
        // Busy (responses owed or buffered output) → short tick so router
        // replies are picked up promptly; idle → longer tick bounded only
        // by stop-observation latency.
        let busy = conns.iter().any(|c| !c.pending.is_empty() || c.wants_write());
        let timeout_ms = if busy { 1 } else { 25 };
        let ready = poll_readiness(&listener, &conns, accepting, draining, limits.conn_quota, timeout_ms);

        if accepting {
            accept_backoff = accept_pending(&listener, &mut conns, &router, &limits);
        }

        let mut kept: Vec<Conn> = Vec::with_capacity(conns.len());
        for (i, mut c) in conns.drain(..).enumerate() {
            let can_read = ready.get(i).copied().unwrap_or(true);
            if c.drive(&router, &limits, draining, can_read, &mut scratch) {
                kept.push(c);
            }
            // dropped connections refund their admission budget in Drop
        }
        conns = kept;
    }
    Ok(())
}

// ---------------------------------------------------------------------------
// client
// ---------------------------------------------------------------------------

/// Typed status-3 refusal: the server shed the request under overload
/// and suggests retrying after `retry_after_ms`. Downcast from the
/// anyhow error chain ([`Client::predict`] / [`Client::recv_predict`]).
#[derive(Debug, thiserror::Error)]
#[error("server shed the request (retry after {retry_after_ms} ms): {msg}")]
pub struct ShedError {
    pub retry_after_ms: u32,
    pub msg: String,
}

/// Blocking client for the frame protocol.
pub struct Client {
    stream: TcpStream,
}

impl Client {
    pub fn connect(addr: &str) -> anyhow::Result<Client> {
        let stream = TcpStream::connect(addr)?;
        stream.set_nodelay(true)?;
        Ok(Client { stream })
    }

    /// Send one point cloud, receive predictions (N, out_features).
    pub fn predict(&mut self, coords: &Tensor, feats: &Tensor) -> anyhow::Result<Tensor> {
        self.send(coords, feats)?;
        self.recv_predict()
    }

    /// Send one request frame without waiting for its response. Pair
    /// with [`Client::recv_predict`]; the server answers pipelined
    /// frames in request order.
    pub fn send(&mut self, coords: &Tensor, feats: &Tensor) -> anyhow::Result<()> {
        let n = coords.rows();
        let mut buf = Vec::with_capacity(16 + (coords.len() + feats.len()) * 4);
        buf.extend_from_slice(REQ_MAGIC);
        buf.extend_from_slice(&(n as u32).to_le_bytes());
        buf.extend_from_slice(&(coords.cols() as u32).to_le_bytes());
        buf.extend_from_slice(&(feats.cols() as u32).to_le_bytes());
        for x in coords.data() {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        for x in feats.data() {
            buf.extend_from_slice(&x.to_le_bytes());
        }
        self.stream.write_all(&buf)?;
        Ok(())
    }

    /// Receive the next prediction response (in request order).
    pub fn recv_predict(&mut self) -> anyhow::Result<Tensor> {
        let mut magic = [0u8; 4];
        self.stream.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == RESP_MAGIC, "bad response magic");
        match read_u32(&mut self.stream)? {
            STATUS_OK => {
                let rn = read_u32(&mut self.stream)?;
                let ro = read_u32(&mut self.stream)?;
                // Bound server-reported dims before allocating: a
                // malicious or corrupt peer must not drive the client
                // into a huge allocation (the old client multiplied the
                // raw u32s straight into vec![0u8; ..]).
                let bytes = (rn as u64) * (ro as u64) * 4;
                anyhow::ensure!(
                    rn <= MAX_POINTS && ro <= MAX_OUT_FEATURES && bytes <= MAX_RESP_BYTES,
                    "implausible response shape {rn}x{ro} ({bytes} B)"
                );
                let data = read_f32s(&mut self.stream, rn as usize * ro as usize)?;
                Ok(Tensor::new(vec![rn as usize, ro as usize], data))
            }
            STATUS_SHED => {
                let retry_after_ms = read_u32(&mut self.stream)?;
                let msg = self.read_short_payload()?;
                Err(ShedError { retry_after_ms, msg }.into())
            }
            STATUS_ERR => {
                let msg = self.read_short_payload()?;
                anyhow::bail!("server error: {msg}");
            }
            s => anyhow::bail!("unexpected response status {s}"),
        }
    }

    fn read_short_payload(&mut self) -> anyhow::Result<String> {
        let mlen = read_u32(&mut self.stream)? as usize;
        anyhow::ensure!(mlen < 65536, "oversized error message");
        let mut m = vec![0u8; mlen];
        self.stream.read_exact(&mut m)?;
        Ok(String::from_utf8_lossy(&m).into_owned())
    }

    /// Query router statistics (JSON string; see the frame docs above).
    pub fn stats(&mut self) -> anyhow::Result<String> {
        self.stream.write_all(STATS_MAGIC)?;
        let mut magic = [0u8; 4];
        self.stream.read_exact(&mut magic)?;
        anyhow::ensure!(&magic == RESP_MAGIC, "bad response magic");
        let status = read_u32(&mut self.stream)?;
        anyhow::ensure!(status == STATUS_STATS, "expected stats frame, got status {status}");
        let len = read_u32(&mut self.stream)? as usize;
        anyhow::ensure!(len < 65536, "oversized stats payload");
        let mut buf = vec![0u8; len];
        self.stream.read_exact(&mut buf)?;
        Ok(String::from_utf8(buf)?)
    }
}

pub(crate) fn read_u32<R: Read>(r: &mut R) -> anyhow::Result<u32> {
    let mut b = [0u8; 4];
    r.read_exact(&mut b)?;
    Ok(u32::from_le_bytes(b))
}

fn read_f32s<R: Read>(r: &mut R, n: usize) -> anyhow::Result<Vec<f32>> {
    let mut buf = vec![0u8; n * 4];
    r.read_exact(&mut buf)?;
    Ok(buf
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    // End-to-end framing (pipelining, shed under load, drain, the
    // idle-connection scaling contract) lives in
    // rust/tests/integration.rs over a real NativeBackend router. The
    // pure decision functions — header admission, accept-error
    // classification, message truncation, the stats bound — are pinned
    // here because their failure modes (1 GiB preallocation from a
    // 16-byte header, a listener killed by EMFILE, a client rejecting
    // the error meant to explain the problem) are exactly the bug
    // classes this module exists to keep out.
    use super::*;

    fn limits() -> ServeLimits {
        ServeLimits::default()
    }

    #[test]
    fn accept_errors_are_never_fatal() {
        // The old serve loop returned Err on any non-WouldBlock accept
        // error: one EMFILE blip tore down the listener. Every such
        // error must now map to a finite backoff, never a teardown.
        for code in [libc::EMFILE, libc::ENFILE, libc::ECONNABORTED, libc::EINTR] {
            let e = std::io::Error::from_raw_os_error(code);
            assert!(
                accept_error_backoff(&e).is_some(),
                "os error {code} must back off, not kill the listener"
            );
        }
        let wb = std::io::Error::from(ErrorKind::WouldBlock);
        assert!(accept_error_backoff(&wb).is_none(), "WouldBlock is not an error");
    }

    #[test]
    fn header_bomb_is_rejected_before_any_allocation() {
        // n=2^22, f=64 is the header that used to preallocate ~1 GiB.
        let a = admit_header(1 << 22, 3, 64, 0, &limits());
        match a {
            Admission::Reject(msg) => {
                assert!(msg.contains("max_payload_bytes"), "must name the bound: {msg}")
            }
            _ => panic!("oversized declared body must be rejected at header time"),
        }
    }

    #[test]
    fn zero_width_dims_are_rejected() {
        for (n, d, f) in [(16u32, 0u32, 8u32), (16, 3, 0), (0, 3, 8)] {
            match admit_header(n, d, f, 0, &limits()) {
                Admission::Reject(msg) => {
                    assert!(msg.starts_with("bad "), "typed message, got: {msg}")
                }
                _ => panic!("n={n} d={d} f={f} must be rejected"),
            }
        }
    }

    #[test]
    fn inflight_budget_sheds_not_rejects() {
        let mut l = limits();
        l.max_inflight_bytes = 1024;
        match admit_header(16, 3, 8, 1000, &l) {
            Admission::Shed { bytes, .. } => assert_eq!(bytes, 4 * 16 * (3 + 8)),
            _ => panic!("over-budget admission must shed, keeping the connection"),
        }
        // under budget: admitted with the exact byte count
        match admit_header(16, 3, 8, 0, &l) {
            Admission::Admit { bytes } => assert_eq!(bytes, 4 * 16 * (3 + 8)),
            _ => panic!("in-budget request must be admitted"),
        }
    }

    #[test]
    fn error_messages_truncate_to_client_cap_on_char_boundary() {
        // 'é' is 2 bytes; an odd cap would split it without the boundary
        // walk-back. The client rejects payloads >= 64 KiB, so the frame
        // must declare < 65536 bytes.
        let long: String = "é".repeat(60_000); // 120_000 bytes
        let frame = encode_err(&long);
        let mlen = u32::from_le_bytes(frame[8..12].try_into().unwrap()) as usize;
        assert!(mlen < 65536, "declared msg_len {mlen} still oversized");
        assert_eq!(frame.len(), 12 + mlen);
        assert!(std::str::from_utf8(&frame[12..]).is_ok(), "truncation split a UTF-8 char");
        // short messages pass through untouched
        let short = encode_err("nope");
        assert_eq!(&short[12..], b"nope");
    }

    #[test]
    fn shed_frame_layout_roundtrips() {
        let frame = encode_shed(75, "busy");
        assert_eq!(&frame[0..4], RESP_MAGIC);
        assert_eq!(u32::from_le_bytes(frame[4..8].try_into().unwrap()), STATUS_SHED);
        assert_eq!(u32::from_le_bytes(frame[8..12].try_into().unwrap()), 75);
        assert_eq!(u32::from_le_bytes(frame[12..16].try_into().unwrap()), 4);
        assert_eq!(&frame[16..], b"busy");
    }

    #[test]
    fn stats_json_is_bounded_and_stays_valid() {
        let core = "\"served\": 1, \"rejected\": 0";
        let small = bounded_stats_json(core, "\"x\": 1");
        assert_eq!(small, "{\"served\": 1, \"rejected\": 0, \"x\": 1}");
        // A pathological sections blob (e.g. unbounded span paths) must
        // not produce a frame the client rejects: drop sections, flag it.
        let huge = format!("\"blob\": \"{}\"", "y".repeat(80_000));
        let bounded = bounded_stats_json(core, &huge);
        assert!(bounded.len() <= MAX_STATS_BYTES);
        assert!(bounded.contains("\"trace_truncated\": true"));
        assert!(bounded.starts_with('{') && bounded.ends_with('}'));
    }
}
