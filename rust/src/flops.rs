//! Analytic FLOPs model — regenerates Table 3's GFLOPS column.
//!
//! The paper measures FLOPs with the DeepSpeed profiler; for the
//! matmul-dominated graphs here, profiler counts equal the closed-form
//! matmul counts (2·M·N·K per GEMM) plus small softmax/norm terms, so we
//! compute them directly. Counting the paper's architecture (dim 64,
//! 18 blocks, Table 4 sparse parameters) at N=4096 reproduces the paper's
//! ordering and magnitudes:
//!
//!   Full ≈ 87 GFLOPs, BSA ≈ 26-28, BSA w/o group selection slightly
//!   higher, BSA w/ group compression lower, Erwin lowest.

use crate::config::ModelConfig;

/// FLOPs breakdown for one forward pass of a full model.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct Flops {
    pub projections: f64,
    pub attention: f64,
    pub mlp: f64,
    pub other: f64,
}

impl Flops {
    pub fn total(&self) -> f64 {
        self.projections + self.attention + self.mlp + self.other
    }

    pub fn gflops(&self) -> f64 {
        self.total() / 1e9
    }
}

/// Softmax cost per score element (exp, sub, div, max/sum shares).
const SOFTMAX_COST: f64 = 5.0;

/// QKV+output projections and gate for one block.
fn proj_flops(n: f64, c: f64, heads: f64, gated: bool) -> f64 {
    let base = 4.0 * 2.0 * n * c * c; // wq, wk, wv, wo
    if gated {
        base + 2.0 * n * c * 3.0 * heads
    } else {
        base
    }
}

/// SwiGLU MLP for one block (3 GEMMs at expansion `ratio`).
fn mlp_flops(n: f64, c: f64, ratio: f64) -> f64 {
    3.0 * 2.0 * n * c * (ratio * c)
}

/// Dense attention core on Nq queries and Nk keys at width c.
fn attn_core(nq: f64, nk: f64, c: f64) -> f64 {
    // QK^T + PV GEMMs + softmax over the score matrix
    2.0 * nq * nk * c * 2.0 + SOFTMAX_COST * nq * nk
}

/// Attention core of one BSA layer (the three branches), per block.
fn bsa_attention_core(cfg: &ModelConfig, variant: &str) -> f64 {
    let n = cfg.seq_len as f64;
    let c = cfg.dim as f64;
    let m = cfg.ball_size.min(cfg.seq_len) as f64;
    let l = cfg.cmp_block as f64;
    let k = cfg.top_k as f64;
    let g = match variant {
        "bsa_nogs" => 1.0,
        _ => cfg.group_size as f64,
    };
    let nb = n / l; // number of compressed blocks

    // ball branch: per-ball dense attention
    let ball = attn_core(n, m, c);

    // compression pooling (mean): one add per element; MLP variant adds GEMMs
    let pool = if variant == "bsa_gc" {
        // MLP phi on K, V and Q (per head, hidden = 2*dh)
        let dh = c / cfg.num_heads as f64;
        let hidden = 2.0 * dh;
        let per_tensor = 2.0 * nb * (l * dh) * hidden + 2.0 * nb * hidden * dh;
        3.0 * cfg.num_heads as f64 * per_tensor
    } else {
        2.0 * n * c // mean pooling of K and V
    };

    // compressed attention
    let cmp = if variant == "bsa_gc" {
        attn_core(nb, nb, c) // pooled queries
    } else {
        attn_core(n, nb, c)
    };

    // selection: importance scores on pooled queries + top-k + gather attn
    let scores = 2.0 * (n / g) * nb * c;
    let slc = attn_core(n, k * l, c);

    ball + pool + cmp + scores + slc
}

/// Forward FLOPs of a whole model variant at the given config.
///
/// Unknown variant names are a typed error (they reach here straight
/// from CLI/config strings, so a bad value must report, not abort).
pub fn model_flops(variant: &str, cfg: &ModelConfig) -> anyhow::Result<Flops> {
    let n = cfg.seq_len as f64;
    let c = cfg.dim as f64;
    let blocks = cfg.num_blocks as f64;
    let heads = cfg.num_heads as f64;
    let ratio = 4.0;

    Ok(match variant {
        "full" => Flops {
            projections: blocks * proj_flops(n, c, heads, false),
            attention: blocks * attn_core(n, n, c),
            mlp: blocks * mlp_flops(n, c, ratio),
            other: 2.0 * n * c * 8.0, // embed + head + norms (small)
        },
        "erwin" => {
            // BTA U-Net: 2 encoder levels (pool 4), bottleneck, 2 decoders.
            let m = 128.0_f64.min(n);
            let mut attn = 0.0;
            let mut proj = 0.0;
            let mut mlp = 0.0;
            let mut nl = n;
            for _ in 0..2 {
                attn += attn_core(nl, m.min(nl), c);
                proj += proj_flops(nl, c, heads, false);
                mlp += mlp_flops(nl, c, ratio);
                nl /= 4.0;
            }
            attn += attn_core(nl, m.min(nl), c);
            proj += proj_flops(nl, c, heads, false);
            mlp += mlp_flops(nl, c, ratio);
            for _ in 0..2 {
                nl *= 4.0;
                attn += attn_core(nl, m.min(nl), c);
                proj += proj_flops(nl, c, heads, false);
                mlp += mlp_flops(nl, c, ratio);
            }
            Flops { projections: proj, attention: attn, mlp, other: 2.0 * n * c * 8.0 }
        }
        "pointnet" => {
            // per-point MLPs only
            let widths = [6.0, 64.0, 128.0, 2.0 * c, 2.0 * c * 2.0, c, 1.0];
            let mut f = 0.0;
            for w in widths.windows(2) {
                f += 2.0 * n * w[0] * w[1];
            }
            Flops { projections: 0.0, attention: 0.0, mlp: f, other: 0.0 }
        }
        v @ ("bsa" | "bsa_nogs" | "bsa_gc") => Flops {
            projections: blocks * proj_flops(n, c, heads, true),
            attention: blocks * bsa_attention_core(cfg, v),
            mlp: blocks * mlp_flops(n, c, ratio),
            other: 2.0 * n * c * 8.0,
        },
        other => anyhow::bail!(
            "unknown model variant {other:?} \
             (expected erwin|full|bsa|bsa_nogs|bsa_gc|pointnet)"
        ),
    })
}

/// Single-attention-layer FLOPs (used by the F3/F4 scaling benches).
pub fn attn_layer_flops(kind: &str, n: usize, cfg: &ModelConfig) -> f64 {
    let mut c = cfg.clone();
    c.seq_len = n;
    c.ball_size = cfg.ball_size.min(n);
    let nf = n as f64;
    let cf = cfg.dim as f64;
    let proj = proj_flops(nf, cf, cfg.num_heads as f64, kind.starts_with("bsa"));
    let core = match kind {
        "full" => attn_core(nf, nf, cf),
        "bta" => attn_core(nf, c.ball_size as f64, cf),
        k => bsa_attention_core(&c, k),
    };
    proj + core
}

#[cfg(test)]
mod tests {
    use super::*;

    fn paper_cfg() -> ModelConfig {
        ModelConfig { num_blocks: 18, seq_len: 4096, ..Default::default() }
    }

    #[test]
    fn full_attention_matches_paper_magnitude() {
        // Paper Table 3: Full Attention = 87.08 GFLOPs at N=4096.
        let f = model_flops("full", &paper_cfg()).unwrap();
        let g = f.gflops();
        assert!((80.0..95.0).contains(&g), "full = {g} GFLOPs");
    }

    #[test]
    fn unknown_variant_is_typed_error() {
        // Bad CLI/config strings must report, not abort the process.
        let err = model_flops("bsa_typo", &paper_cfg()).unwrap_err().to_string();
        assert!(err.contains("bsa_typo"), "error names the bad variant: {err}");
        assert!(err.contains("expected"), "error lists the valid set: {err}");
    }

    #[test]
    fn bsa_matches_paper_magnitude() {
        // Paper Table 3: BSA = 27.91 GFLOPs.
        let g = model_flops("bsa", &paper_cfg()).unwrap().gflops();
        assert!((20.0..35.0).contains(&g), "bsa = {g} GFLOPs");
    }

    #[test]
    fn paper_ordering_holds() {
        // Erwin < BSA+gc < BSA <= BSA-nogs << Full (Table 3 shape).
        let cfg = paper_cfg();
        let erwin = model_flops("erwin", &cfg).unwrap().gflops();
        let gc = model_flops("bsa_gc", &cfg).unwrap().gflops();
        let bsa = model_flops("bsa", &cfg).unwrap().gflops();
        let nogs = model_flops("bsa_nogs", &cfg).unwrap().gflops();
        let full = model_flops("full", &cfg).unwrap().gflops();
        assert!(erwin < gc, "erwin {erwin} < gc {gc}");
        assert!(gc < bsa, "gc {gc} < bsa {bsa}");
        assert!(bsa <= nogs, "bsa {bsa} <= nogs {nogs}");
        assert!(nogs < full, "nogs {nogs} < full {full}");
    }

    #[test]
    fn bsa_grows_slower_than_full() {
        // Quadrupling N ~16x's full attention. BSA keeps one quadratic
        // term (the compressed branch, N^2/l) but its ball/selection
        // branches are linear, so its growth ratio must be visibly lower
        // and its absolute count ~l-fold smaller at scale.
        let mut small = paper_cfg();
        small.seq_len = 4096;
        let mut large = paper_cfg();
        large.seq_len = 16384;
        let r_full =
            model_flops("full", &large).unwrap().attention / model_flops("full", &small).unwrap().attention;
        let r_bsa =
            model_flops("bsa", &large).unwrap().attention / model_flops("bsa", &small).unwrap().attention;
        assert!(r_full > 14.0, "full ratio {r_full}");
        assert!(r_bsa < 13.0, "bsa ratio {r_bsa}");
        let abs_ratio = model_flops("full", &large).unwrap().attention
            / model_flops("bsa", &large).unwrap().attention;
        assert!(abs_ratio > 5.0, "full/bsa at 16384 = {abs_ratio}");
    }

    #[test]
    fn attn_layer_scaling_crossover() {
        // Per-layer: full is cheaper at tiny N, BSA wins at large N (Fig. 3).
        let cfg = ModelConfig::default();
        let f256 = attn_layer_flops("full", 256, &cfg);
        let b256 = attn_layer_flops("bsa", 256, &cfg);
        let f64k = attn_layer_flops("full", 65536, &cfg);
        let b64k = attn_layer_flops("bsa", 65536, &cfg);
        assert!(f256 < b256, "full cheaper at 256: {f256} vs {b256}");
        assert!(b64k * 4.0 < f64k, "bsa >4x cheaper at 65536: {b64k} vs {f64k}");
    }

    #[test]
    fn pointnet_is_linear() {
        let cfg = ModelConfig::default();
        let mut a = cfg.clone();
        a.seq_len = 1024;
        let mut b = cfg.clone();
        b.seq_len = 4096;
        let ra = model_flops("pointnet", &a).unwrap().total();
        let rb = model_flops("pointnet", &b).unwrap().total();
        assert!((rb / ra - 4.0).abs() < 0.01);
    }
}
