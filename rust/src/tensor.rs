//! Minimal host tensor: dense row-major f32 arrays with shape metadata.
//!
//! This is the host-side data currency between the substrates (ball tree,
//! dataset generators) and the PJRT runtime; it deliberately supports only
//! what the coordinator needs — construction, indexed access, permutation
//! along the point axis, slicing, statistics — and converts to/from
//! `xla::Literal` in `runtime::literal`.

use std::fmt;

/// Dense row-major f32 tensor.
#[derive(Clone, PartialEq)]
pub struct Tensor {
    shape: Vec<usize>,
    data: Vec<f32>,
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[{} elems]", self.shape, self.data.len())
    }
}

impl Tensor {
    /// New tensor from shape and data; panics on element-count mismatch.
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, data.len(), "shape {shape:?} != data len {}", data.len());
        Tensor { shape, data }
    }

    /// All-zeros tensor.
    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    /// Filled with a constant.
    pub fn full(shape: Vec<usize>, value: f32) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![value; n] }
    }

    /// Scalar tensor.
    pub fn scalar(value: f32) -> Self {
        Tensor { shape: vec![], data: vec![value] }
    }

    pub fn shape(&self) -> &[usize] {
        &self.shape
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_data(self) -> Vec<f32> {
        self.data
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Number of rows when viewed as (rows, cols) with `cols` trailing.
    pub fn rows(&self) -> usize {
        assert!(!self.shape.is_empty());
        self.shape[..self.shape.len() - 1].iter().product()
    }

    /// Trailing dimension.
    pub fn cols(&self) -> usize {
        *self.shape.last().expect("rank >= 1")
    }

    /// Row view for rank >= 1 tensors interpreted as (rows, cols).
    pub fn row(&self, i: usize) -> &[f32] {
        let c = self.cols();
        &self.data[i * c..(i + 1) * c]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let c = self.cols();
        &mut self.data[i * c..(i + 1) * c]
    }

    /// Reshape in place (same element count).
    pub fn reshape(mut self, shape: Vec<usize>) -> Self {
        let n: usize = shape.iter().product();
        assert_eq!(n, self.data.len(), "reshape {shape:?}");
        self.shape = shape;
        self
    }

    /// Permute rows (axis 0 of the (rows, cols) view): out[i] = self[perm[i]].
    pub fn permute_rows(&self, perm: &[usize]) -> Tensor {
        assert_eq!(perm.len(), self.rows(), "perm len");
        let mut out = vec![0.0f32; self.data.len()];
        self.permute_rows_into(perm, &mut out);
        Tensor { shape: self.shape.clone(), data: out }
    }

    /// Gather rows into a caller-owned buffer: out row i = self[perm[i]].
    ///
    /// Unlike [`permute_rows`](Self::permute_rows), `perm` need not be a
    /// permutation — indices may repeat or cover a subset (this is what
    /// ball-tree padding produces) — and no allocation is performed, which
    /// is why the serving batch assembler uses it. `out` must hold exactly
    /// `perm.len() * cols` elements.
    pub fn permute_rows_into(&self, perm: &[usize], out: &mut [f32]) {
        let c = self.cols();
        assert_eq!(out.len(), perm.len() * c, "permute_rows_into out len");
        for (dst, &p) in out.chunks_exact_mut(c).zip(perm) {
            self.copy_row_into(p, dst);
        }
    }

    /// Copy one row into a caller-owned buffer of length `cols`.
    pub fn copy_row_into(&self, i: usize, out: &mut [f32]) {
        out.copy_from_slice(self.row(i));
    }

    /// Mean of all elements.
    pub fn mean(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        self.data.iter().sum::<f32>() / self.data.len() as f32
    }

    /// Population standard deviation.
    pub fn std(&self) -> f32 {
        if self.data.is_empty() {
            return 0.0;
        }
        let m = self.mean();
        (self.data.iter().map(|x| (x - m).powi(2)).sum::<f32>() / self.data.len() as f32)
            .sqrt()
    }

    pub fn min(&self) -> f32 {
        self.data.iter().copied().fold(f32::INFINITY, f32::min)
    }

    pub fn max(&self) -> f32 {
        self.data.iter().copied().fold(f32::NEG_INFINITY, f32::max)
    }

    /// Mean squared error against another tensor of the same shape.
    pub fn mse(&self, other: &Tensor) -> f32 {
        assert_eq!(self.shape, other.shape, "mse shape");
        if self.data.is_empty() {
            return 0.0;
        }
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).powi(2))
            .sum::<f32>()
            / self.data.len() as f32
    }

    /// True if all elements are finite.
    pub fn all_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }

    /// Concatenate along axis 0; all shapes must agree on trailing dims.
    pub fn concat0(parts: &[&Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let tail = &parts[0].shape[1..];
        let mut rows = 0;
        let mut data = Vec::new();
        for p in parts {
            assert_eq!(&p.shape[1..], tail, "concat0 trailing dims");
            rows += p.shape[0];
            data.extend_from_slice(&p.data);
        }
        let mut shape = vec![rows];
        shape.extend_from_slice(tail);
        Tensor { shape, data }
    }

    /// Slice rows [start, start+len) of the (rows, cols) view. The result
    /// collapses leading dims: shape (len, cols).
    pub fn slice_rows(&self, start: usize, len: usize) -> Tensor {
        let c = self.cols();
        Tensor { shape: vec![len, c], data: self.slice_rows_view(start, len).to_vec() }
    }

    /// Borrowed view of rows [start, start+len) as a flat `(len * cols)`
    /// slice — the zero-copy counterpart of [`slice_rows`](Self::slice_rows)
    /// for consumers that only read (e.g. per-request prediction
    /// un-permutation in the serving hot path).
    pub fn slice_rows_view(&self, start: usize, len: usize) -> &[f32] {
        let c = self.cols();
        &self.data[start * c..(start + len) * c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_stats() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.rows(), 2);
        assert_eq!(t.cols(), 3);
        assert!((t.mean() - 3.5).abs() < 1e-6);
        assert_eq!(t.min(), 1.0);
        assert_eq!(t.max(), 6.0);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![1.0]);
    }

    #[test]
    fn permute_rows_roundtrip() {
        let t = Tensor::new(vec![3, 2], vec![0., 0., 1., 1., 2., 2.]);
        let perm = vec![2, 0, 1];
        let p = t.permute_rows(&perm);
        assert_eq!(p.row(0), &[2., 2.]);
        assert_eq!(p.row(1), &[0., 0.]);
        // inverse permutation restores the original
        let mut inv = vec![0; 3];
        for (i, &j) in perm.iter().enumerate() {
            inv[j] = i;
        }
        assert_eq!(p.permute_rows(&inv), t);
    }

    #[test]
    fn permute_rows_into_matches_allocating_and_gathers() {
        let t = Tensor::new(vec![3, 2], vec![0., 0., 1., 1., 2., 2.]);
        let perm = vec![2, 0, 1];
        let mut out = vec![0.0f32; 6];
        t.permute_rows_into(&perm, &mut out);
        assert_eq!(out.as_slice(), t.permute_rows(&perm).data());
        // gather semantics: repeats and subsets are allowed
        let gather = vec![1, 1, 2, 0, 1];
        let mut g = vec![0.0f32; 10];
        t.permute_rows_into(&gather, &mut g);
        assert_eq!(&g[0..2], &[1., 1.]);
        assert_eq!(&g[8..10], &[1., 1.]);
    }

    #[test]
    #[should_panic]
    fn permute_rows_into_rejects_wrong_out_len() {
        let t = Tensor::new(vec![2, 2], vec![1., 2., 3., 4.]);
        let mut out = vec![0.0f32; 3];
        t.permute_rows_into(&[0, 1], &mut out);
    }

    #[test]
    fn slice_rows_view_borrows_same_data() {
        let t = Tensor::new(vec![4, 2], (0..8).map(|x| x as f32).collect());
        assert_eq!(t.slice_rows_view(1, 2), t.slice_rows(1, 2).data());
        assert_eq!(t.slice_rows_view(0, 4), t.data());
    }

    #[test]
    fn copy_row_into_extracts() {
        let t = Tensor::new(vec![2, 3], (0..6).map(|x| x as f32).collect());
        let mut row = [0.0f32; 3];
        t.copy_row_into(1, &mut row);
        assert_eq!(row, [3., 4., 5.]);
    }

    #[test]
    fn mse_of_identical_is_zero() {
        let t = Tensor::new(vec![4], vec![1., 2., 3., 4.]);
        assert_eq!(t.mse(&t), 0.0);
    }

    #[test]
    fn concat0_stacks_rows() {
        let a = Tensor::new(vec![1, 2], vec![1., 2.]);
        let b = Tensor::new(vec![2, 2], vec![3., 4., 5., 6.]);
        let c = Tensor::concat0(&[&a, &b]);
        assert_eq!(c.shape(), &[3, 2]);
        assert_eq!(c.row(2), &[5., 6.]);
    }

    #[test]
    fn slice_rows_extracts() {
        let t = Tensor::new(vec![4, 2], (0..8).map(|x| x as f32).collect());
        let s = t.slice_rows(1, 2);
        assert_eq!(s.shape(), &[2, 2]);
        assert_eq!(s.row(0), &[2., 3.]);
    }

    #[test]
    fn reshape_preserves_data() {
        let t = Tensor::new(vec![2, 3], (0..6).map(|x| x as f32).collect());
        let r = t.clone().reshape(vec![3, 2]);
        assert_eq!(r.shape(), &[3, 2]);
        assert_eq!(r.data(), t.data());
    }
}
