//! `bsa` CLI — the leader entrypoint of the BSA stack.
//!
//! Subcommands:
//!   train     train a model variant on a synthetic task
//!             (`--backend pjrt` runs the fused compiled train graph;
//!              `--backend native` runs the pure-Rust backward pass +
//!              AdamW — no artifacts or Python toolchain; checkpoints
//!              are `.bsackpt` v3 with optimizer moments, resumable and
//!              directly servable — see docs/TRAINING.md)
//!   eval      evaluate a checkpoint on the held-out split
//!             (same `--backend` switch as train)
//!   serve     start the TCP inference server
//!             (`--backend pjrt` runs compiled HLO artifacts;
//!              `--backend native` runs the pure-Rust BSA forward pass —
//!              no artifacts or Python toolchain needed; weights come
//!              from `--params <file>.bsackpt` or a seeded random init)
//!   gen-data  materialize a dataset shard (.bsad)
//!   balltree  inspect ball-tree statistics for a sample
//!   flops     print the analytic FLOPs table (Table 3 GFLOPS column)
//!   config    show the resolved configuration (Table 4)
//!   info      list artifacts and platform info
//!   stats     query a live server's BSST stats frame and pretty-print
//!             the router counters, per-stage span histograms, and
//!             worker-pool gauges (see `bsa::trace`; `--probe` sends one
//!             synthetic prediction first so span histograms are warm)
//!   shard     start the sharded serving tier: one front-door router over
//!             N workers with geometry-affinity placement, health probes,
//!             and respawn (see `bsa::shard`; docs/FORMATS.md §3)
//!   loadgen   open-loop load generator against a server or front door;
//!             records p50/p95/p99 vs offered rate, shed rate, and
//!             per-worker cache hit ratios into BENCH_serve.json
//!
//! Logging goes to stderr through a minimal built-in logger; filter with
//! `BSA_LOG=error|warn|info|debug` (default `info`). Tracing is separate
//! (`--trace` / `BSA_TRACE`, see `bsa::trace`): `bsa serve --trace spans
//! --trace-out trace.json` additionally writes a Chrome trace-event file
//! loadable in `chrome://tracing` / Perfetto on exit (Ctrl-C is caught so
//! the file is flushed).

use std::path::{Path, PathBuf};
use std::sync::Arc;

use bsa::cli::{render_help, Args, FlagSpec};
use bsa::config::{table4, Document, ModelConfig, ServeConfig, TrainConfig};
use bsa::coordinator::Trainer;
use bsa::data::{Dataset, SplitSpec};
use bsa::flops::model_flops;
use bsa::metrics::Table;
use bsa::runtime::Engine;

fn flag_specs() -> Vec<FlagSpec> {
    vec![
        FlagSpec { name: "config", help: "TOML config file", takes_value: true, default: None },
        FlagSpec { name: "artifacts", help: "artifacts directory", takes_value: true, default: Some("artifacts") },
        FlagSpec { name: "backend", help: "execution backend for serve/train/eval: pjrt (compiled HLO artifacts) | native (pure-Rust BSA forward + backward; needs no artifacts or Python toolchain)", takes_value: true, default: Some("pjrt") },
        FlagSpec { name: "params", help: "native-backend weights: a .bsackpt param file (flat binary of named f32 arrays — params_<tag>.bsackpt from aot.py or any training checkpoint); random init if omitted", takes_value: true, default: None },
        FlagSpec { name: "variant", help: "model variant for `bsa flops`: erwin|full|bsa|bsa_nogs|bsa_gc|pointnet (all when omitted)", takes_value: true, default: None },
        FlagSpec { name: "tag", help: "artifact tag (model_task_nN_bB)", takes_value: true, default: Some("bsa_air_n1024_b2") },
        FlagSpec { name: "task", help: "dataset task: air|ela|syn", takes_value: true, default: Some("air") },
        FlagSpec { name: "steps", help: "training steps", takes_value: true, default: None },
        FlagSpec { name: "seed", help: "rng seed", takes_value: true, default: Some("0") },
        FlagSpec { name: "checkpoint", help: "checkpoint path", takes_value: true, default: None },
        FlagSpec { name: "addr", help: "server bind address", takes_value: true, default: Some("127.0.0.1:7077") },
        FlagSpec { name: "workers", help: "serving workers", takes_value: true, default: Some("2") },
        // no baked-in default: absent flag falls back to the config
        // file's [serve] native_threads (a Some() default would clobber it)
        FlagSpec { name: "threads", help: "native-backend kernel threads per forward pass, i.e. the demand each forward registers with the shared persistent worker pool (0 = auto: BSA_NATIVE_THREADS env var, else hardware parallelism; default: [serve] native_threads or 0); outputs are bitwise identical for every setting", takes_value: true, default: None },
        // no baked-in default: absent flag falls back to [serve] native_simd
        FlagSpec { name: "simd", help: "native-backend SIMD microkernels: auto (BSA_NATIVE_SIMD env var, else runtime AVX2/NEON detection) | on (best detected level) | off (scalar loops, bitwise *_reference numerics); default: [serve] native_simd or auto", takes_value: true, default: None },
        // no baked-in default: absent flag falls back to [serve] precision
        FlagSpec { name: "precision", help: "native-backend storage precision: f32 | f16 (half-precision parameters + attention staging buffers, f32 accumulation everywhere; outputs within the documented f16 tolerance tier); default: [serve] precision or f32", takes_value: true, default: None },
        // no baked-in default: absent flag falls back to [serve] trace,
        // then the BSA_TRACE env var, then off
        FlagSpec { name: "trace", help: "observability level: off | counters | spans (on = spans); spans record per-stage latency histograms served over BSST and `bsa stats` (default: [serve] trace, else BSA_TRACE, else off)", takes_value: true, default: None },
        FlagSpec { name: "trace-out", help: "write a Chrome trace-event JSON (chrome://tracing / Perfetto) to this path on exit; implies --trace spans", takes_value: true, default: None },
        FlagSpec { name: "max-conns", help: "admission: open-connection cap; excess connections get a status-3 shed frame and are closed (default: [serve] max_conns or 4096)", takes_value: true, default: None },
        FlagSpec { name: "max-payload-bytes", help: "admission: largest declared request body accepted; bigger headers are answered with a status-1 error frame before any payload is buffered (default: [serve] max_payload_bytes or 67108864)", takes_value: true, default: None },
        FlagSpec { name: "max-inflight-bytes", help: "admission: global budget over admitted-but-unanswered request bytes; past it requests are shed with status 3 + retry-after (default: [serve] max_inflight_bytes or 268435456)", takes_value: true, default: None },
        FlagSpec { name: "conn-quota", help: "admission: per-connection in-flight frame cap, applied as read backpressure (default: [serve] conn_quota or 32)", takes_value: true, default: None },
        FlagSpec { name: "drain-ms", help: "drain budget on SIGINT/SIGTERM: in-flight requests get this long to complete and flush before connections close (default: [serve] drain_ms or 2000)", takes_value: true, default: None },
        FlagSpec { name: "probe", help: "for `bsa stats`: send one synthetic prediction first so span histograms are populated", takes_value: false, default: None },
        FlagSpec { name: "worker-addrs", help: "for `bsa shard`: comma-separated addresses of already-running workers to attach (skips spawning; the fleet probes and routes but does not own their lifecycle)", takes_value: true, default: None },
        FlagSpec { name: "worker-base-port", help: "for `bsa shard`: spawned worker i binds 127.0.0.1:(base+i) (default: [shard] worker_base_port or 7100)", takes_value: true, default: None },
        FlagSpec { name: "spill-inflight", help: "for `bsa shard`: in-flight requests per worker before a key spills off its affine worker (default: [shard] spill_inflight or 32)", takes_value: true, default: None },
        FlagSpec { name: "rate", help: "for `bsa loadgen`: offered arrival rate, requests/s (open loop: the schedule never slows down for a lagging server)", takes_value: true, default: Some("50") },
        FlagSpec { name: "duration-ms", help: "for `bsa loadgen`: run length in ms", takes_value: true, default: Some("10000") },
        FlagSpec { name: "geoms", help: "for `bsa loadgen`: distinct geometries in the Zipf traffic mix", takes_value: true, default: Some("8") },
        FlagSpec { name: "conns", help: "for `bsa loadgen`: client connections (arrivals dealt round-robin)", takes_value: true, default: Some("4") },
        FlagSpec { name: "zipf", help: "for `bsa loadgen`: Zipf exponent of the geometry mix (0 = uniform)", takes_value: true, default: Some("1.0") },
        FlagSpec { name: "quick", help: "for `bsa loadgen`: 2 s smoke preset (25 req/s, 2 conns), for CI", takes_value: false, default: None },
        FlagSpec { name: "samples", help: "samples for gen-data", takes_value: true, default: Some("32") },
        FlagSpec { name: "points", help: "points per sample", takes_value: true, default: Some("896") },
        FlagSpec { name: "out", help: "output path", takes_value: true, default: None },
        FlagSpec { name: "n", help: "sequence length", takes_value: true, default: Some("4096") },
        FlagSpec { name: "paper", help: "use the paper-scale config", takes_value: false, default: None },
        FlagSpec { name: "show", help: "print resolved config", takes_value: false, default: None },
        FlagSpec { name: "help", help: "show help", takes_value: false, default: None },
    ]
}

fn main() {
    // Unix CLI convention: die quietly on SIGPIPE (`bsa info | head`)
    // instead of panicking on a broken-pipe write.
    unsafe {
        libc::signal(libc::SIGPIPE, libc::SIG_DFL);
    }
    init_logger();
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let specs = flag_specs();
    let args = match Args::parse(&argv, &specs) {
        Ok(a) => a,
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(2);
        }
    };
    if args.command.is_empty() || args.has("help") {
        print_usage(&specs);
        return;
    }
    let result = match args.command.as_str() {
        "train" => cmd_train(&args),
        "eval" => cmd_eval(&args),
        "serve" => cmd_serve(&args),
        "gen-data" => cmd_gen_data(&args),
        "balltree" => cmd_balltree(&args),
        "flops" => cmd_flops(&args),
        "config" => cmd_config(&args),
        "info" => cmd_info(&args),
        "stats" => cmd_stats(&args),
        "shard" => cmd_shard(&args),
        "loadgen" => cmd_loadgen(&args),
        other => {
            eprintln!("unknown command {other:?}\n");
            print_usage(&specs);
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

/// Minimal stderr logger behind the `log` facade (nothing called
/// `log::set_logger` before this — every `log::info!` in the crate was a
/// silent no-op). Timestamped UTC lines, filtered by the `BSA_LOG` env
/// var (`error|warn|info|debug|trace|off`, default `info`).
struct StderrLogger {
    max: log::LevelFilter,
}

impl log::Log for StderrLogger {
    fn enabled(&self, metadata: &log::Metadata) -> bool {
        metadata.level() <= self.max
    }

    fn log(&self, record: &log::Record) {
        if !self.enabled(record.metadata()) {
            return;
        }
        eprintln!(
            "{} {:<5} [{}] {}",
            bsa::trace::format_utc(std::time::SystemTime::now()),
            record.level(),
            record.target(),
            record.args()
        );
    }

    fn flush(&self) {}
}

fn init_logger() {
    let max = match std::env::var("BSA_LOG")
        .map(|v| v.trim().to_ascii_lowercase())
        .as_deref()
    {
        Ok("off") | Ok("none") => log::LevelFilter::Off,
        Ok("error") => log::LevelFilter::Error,
        Ok("warn") => log::LevelFilter::Warn,
        Ok("debug") => log::LevelFilter::Debug,
        Ok("trace") => log::LevelFilter::Trace,
        Ok("info") | Err(_) => log::LevelFilter::Info,
        Ok(other) => {
            eprintln!("warning: unknown BSA_LOG level {other:?}; using info");
            log::LevelFilter::Info
        }
    };
    // Leak one small allocation for the process lifetime; set_logger
    // wants a &'static. A second init (impossible here) is a no-op.
    let logger: &'static StderrLogger = Box::leak(Box::new(StderrLogger { max }));
    if log::set_logger(logger).is_ok() {
        log::set_max_level(max);
    }
}

fn print_usage(specs: &[FlagSpec]) {
    println!(
        "bsa {} — Ball Sparse Attention runtime\n\n\
         usage: bsa <command> [flags]\n\n\
         commands:\n  \
         train     train a model variant on a synthetic task\n  \
         eval      evaluate a checkpoint on the held-out split\n  \
         serve     start the TCP inference server (--backend native|pjrt)\n  \
         gen-data  materialize a dataset shard (.bsad)\n  \
         balltree  inspect ball-tree statistics\n  \
         flops     print the analytic FLOPs table\n  \
         config    show the resolved configuration (Table 4)\n  \
         info      list artifacts and platform\n  \
         stats     query a live server's stats/trace breakdown (bsa stats <addr>)\n  \
         shard     start the sharded serving tier: a front-door router over N\n            \
         workers with geometry-affinity placement (spawns native workers,\n            \
         or attaches to running ones via --worker-addrs)\n  \
         loadgen   open-loop load generator (bsa loadgen <addr> --rate R);\n            \
         writes the `shard` section of BENCH_serve.json\n",
        bsa::VERSION
    );
    println!("{}", render_help("<command>", "shared flags", specs));
}

fn load_doc(args: &Args) -> anyhow::Result<Document> {
    match args.flag("config") {
        Some(path) => Document::load(Path::new(path)),
        None => Ok(Document::default()),
    }
}

fn train_config(args: &Args, doc: &Document) -> anyhow::Result<TrainConfig> {
    let mut tc = TrainConfig::from_doc(doc);
    tc.task = args.str_flag("task", &tc.task);
    if let Some(s) = args.flag("steps") {
        tc.steps = s.parse()?;
    }
    tc.seed = args.u64_flag("seed", tc.seed)?;
    Ok(tc)
}

/// Build the artifact-free trainer: architecture from `[model]` config
/// (+ `--n` sequence-length override), gradients and AdamW from
/// `bsa::backend::grad` — no HLO artifacts or Python toolchain needed.
fn native_trainer(args: &Args, doc: &Document) -> anyhow::Result<bsa::coordinator::NativeTrainer> {
    let tc = train_config(args, doc)?;
    let mut mc = ModelConfig::from_doc(doc);
    mc.seq_len = args.usize_flag("n", mc.seq_len)?;
    let threads = args.usize_flag("threads", 0)?;
    println!(
        "native bsa: dim {} x {} blocks, {} heads, n {}, task {}",
        mc.dim, mc.num_blocks, mc.num_heads, mc.seq_len, tc.task
    );
    bsa::coordinator::NativeTrainer::new(&mc, tc, threads)
}

fn cmd_train(args: &Args) -> anyhow::Result<()> {
    use bsa::backend::BackendKind;
    let doc = load_doc(args)?;
    if args.str_flag("backend", "pjrt").parse::<BackendKind>()? == BackendKind::Native {
        let ckpt: Option<PathBuf> = args.flag("checkpoint").map(PathBuf::from);
        let mut trainer = native_trainer(args, &doc)?;
        if let Some(p) = &ckpt {
            if p.exists() {
                trainer.load_checkpoint(p)?;
                println!("resumed from {} at step {}", p.display(), trainer.step);
            }
        }
        trainer.run(|e| {
            println!(
                "step {:>6}  loss {:.6}  lr {:.2e}  {:.1} ms/step",
                e.step, e.loss, e.lr, e.ms_per_step
            );
        })?;
        let mse = trainer.evaluate()?;
        println!("test MSE (normalized): {mse:.6}  (x100 = {:.3})", mse * 100.0);
        if let Some(p) = &ckpt {
            trainer.save_checkpoint(p)?;
            println!("checkpoint saved to {}", p.display());
        }
        return Ok(());
    }
    let tc = train_config(args, &doc)?;
    let tag = args.str_flag("tag", "");
    let engine = Arc::new(Engine::new(Path::new(&args.str_flag("artifacts", "artifacts")))?);
    println!("platform: {}", engine.platform());
    println!("training {tag} on task {} for {} steps", tc.task, tc.steps);

    let ckpt: Option<PathBuf> = args.flag("checkpoint").map(PathBuf::from);
    let mut trainer = Trainer::new(engine, &tag, tc.clone())?;
    if let Some(p) = &ckpt {
        if p.exists() {
            trainer.load_checkpoint(p)?;
            println!("resumed from {} at step {}", p.display(), trainer.step);
        }
    }
    trainer.run(|e| {
        println!(
            "step {:>6}  loss {:.6}  lr {:.2e}  {:.1} ms/step",
            e.step, e.loss, e.lr, e.ms_per_step
        );
    })?;
    let mse = trainer.evaluate()?;
    println!("test MSE (normalized): {mse:.6}  (x100 = {:.3})", mse * 100.0);
    if let Some(p) = &ckpt {
        trainer.save_checkpoint(p)?;
        println!("checkpoint saved to {}", p.display());
    }
    Ok(())
}

fn cmd_eval(args: &Args) -> anyhow::Result<()> {
    use bsa::backend::BackendKind;
    let doc = load_doc(args)?;
    if args.str_flag("backend", "pjrt").parse::<BackendKind>()? == BackendKind::Native {
        let mut trainer = native_trainer(args, &doc)?;
        if let Some(p) = args.flag("checkpoint") {
            trainer.load_checkpoint(Path::new(p))?;
        }
        let mse = trainer.evaluate()?;
        println!("test MSE (normalized): {mse:.6}  (x100 = {:.3})", mse * 100.0);
        return Ok(());
    }
    let mut tc = train_config(args, &doc)?;
    tc.steps = 0;
    let tag = args.str_flag("tag", "");
    let engine = Arc::new(Engine::new(Path::new(&args.str_flag("artifacts", "artifacts")))?);
    let mut trainer = Trainer::new(engine, &tag, tc)?;
    if let Some(p) = args.flag("checkpoint") {
        trainer.load_checkpoint(Path::new(p))?;
    }
    let mse = trainer.evaluate()?;
    println!("test MSE (normalized): {mse:.6}  (x100 = {:.3})", mse * 100.0);
    Ok(())
}

fn cmd_serve(args: &Args) -> anyhow::Result<()> {
    use bsa::backend::{Backend as _, BackendKind};
    let doc = load_doc(args)?;
    let mut sc = ServeConfig::from_doc(&doc);
    sc.addr = args.str_flag("addr", &sc.addr);
    sc.workers = args.usize_flag("workers", sc.workers)?;
    sc.native_threads = args.usize_flag("threads", sc.native_threads)?;
    sc.native_simd = args.str_flag("simd", &sc.native_simd);
    sc.precision = args.str_flag("precision", &sc.precision);
    sc.trace = args.str_flag("trace", &sc.trace);
    sc.max_conns = args.usize_flag("max-conns", sc.max_conns)?;
    sc.max_payload_bytes = args.u64_flag("max-payload-bytes", sc.max_payload_bytes)?;
    sc.max_inflight_bytes = args.u64_flag("max-inflight-bytes", sc.max_inflight_bytes)?;
    sc.conn_quota = args.usize_flag("conn-quota", sc.conn_quota)?;
    sc.drain_ms = args.u64_flag("drain-ms", sc.drain_ms)?;
    // Trace level: --trace flag > [serve] trace > BSA_TRACE env (the
    // lazy default inside bsa::trace::level()). --trace-out needs span
    // events, so it upgrades the level if necessary.
    let mut trace_level = if sc.trace.is_empty() {
        bsa::trace::level()
    } else {
        sc.trace.parse()?
    };
    let trace_out = args.flag("trace-out").map(PathBuf::from);
    if trace_out.is_some() && trace_level != bsa::trace::TraceLevel::Spans {
        log::info!("--trace-out implies --trace spans (was {trace_level})");
        trace_level = bsa::trace::TraceLevel::Spans;
    }
    bsa::trace::set_level(trace_level);
    if trace_out.is_some() {
        bsa::trace::enable_chrome();
    }
    // Resolve the process-wide SIMD dispatch level before any kernel
    // runs (`--simd` / [serve] native_simd; "auto" defers to the
    // BSA_NATIVE_SIMD env var and hardware detection).
    bsa::backend::simd::set_force(sc.native_simd.parse()?);
    let kind: BackendKind = args.str_flag("backend", "pjrt").parse()?;

    let router = match kind {
        BackendKind::Pjrt => {
            let tag = args.str_flag("tag", "bsa_air_n4096_b1");
            let engine =
                Arc::new(Engine::new(Path::new(&args.str_flag("artifacts", "artifacts")))?);
            // parameters: checkpoint if given, else init graph of a
            // train-capable tag
            let params = load_or_init_params(&engine, &tag, args)?;
            println!("serving fwd_{tag} (pjrt) on {} with {} workers", sc.addr, sc.workers);
            Arc::new(bsa::coordinator::Router::start_pjrt(
                engine,
                &format!("fwd_{tag}"),
                params,
                sc.clone(),
            )?)
        }
        BackendKind::Native => {
            let backend = native_backend(args, &doc, &sc)?;
            println!(
                "serving {} (native, artifact-free) on {} with {} workers, {} kernel threads, simd {}, precision {}",
                backend.spec().name,
                sc.addr,
                sc.workers,
                backend.threads(),
                bsa::backend::simd::active().name(),
                backend.precision()
            );
            Arc::new(bsa::coordinator::Router::start(Arc::new(backend), sc.clone())?)
        }
    };
    let stop = Arc::new(std::sync::atomic::AtomicBool::new(false));
    install_stop_handler(stop.clone());
    if trace_level != bsa::trace::TraceLevel::Off {
        log::info!("tracing {trace_level} (query with `bsa stats {}`)", sc.addr);
    }
    println!(
        "admission: max_conns {}, max_payload {} B, max_inflight {} B, conn_quota {}",
        sc.max_conns, sc.max_payload_bytes, sc.max_inflight_bytes, sc.conn_quota
    );
    let limits = bsa::server::ServeLimits::from(&sc);
    let served = bsa::server::serve_with(&sc.addr, router, stop, limits);
    if let Some(path) = &trace_out {
        bsa::trace::write_chrome_trace(path)?;
        log::info!(
            "wrote Chrome trace to {} (open in chrome://tracing or Perfetto)",
            path.display()
        );
    }
    served
}

/// The serve-loop stop flag, reachable from the signal handler.
static SERVE_STOP: std::sync::OnceLock<Arc<std::sync::atomic::AtomicBool>> =
    std::sync::OnceLock::new();

/// Async-signal-safe stop: one relaxed atomic store (OnceLock::get is a
/// lock-free read). The poll core observes the flag on its next tick
/// (<= 25 ms) and begins draining.
extern "C" fn handle_stop_signal(_sig: libc::c_int) {
    if let Some(stop) = SERVE_STOP.get() {
        stop.store(true, std::sync::atomic::Ordering::Relaxed);
    }
}

/// Catch SIGINT/SIGTERM so `bsa serve` shuts down cleanly — the poll
/// core drains in-flight requests (bounded by `--drain-ms`) and
/// `--trace-out` gets written — instead of the process dying mid-frame.
fn install_stop_handler(stop: Arc<std::sync::atomic::AtomicBool>) {
    let _ = SERVE_STOP.set(stop);
    unsafe {
        libc::signal(
            libc::SIGINT,
            handle_stop_signal as extern "C" fn(libc::c_int) as libc::sighandler_t,
        );
        libc::signal(
            libc::SIGTERM,
            handle_stop_signal as extern "C" fn(libc::c_int) as libc::sighandler_t,
        );
    }
}

/// Build the pure-Rust backend: architecture from `[model]` config (+
/// `--n` sequence-length override), features from the task's generator,
/// weights from `--params`/`--checkpoint` (.bsackpt) or a seeded init.
fn native_backend(
    args: &Args,
    doc: &Document,
    sc: &ServeConfig,
) -> anyhow::Result<bsa::backend::NativeBackend> {
    use bsa::backend::{native::AttnHyper, NativeBackend};
    let mut mc = ModelConfig::from_doc(doc);
    mc.seq_len = args.usize_flag("n", sc.seq_len)?;
    anyhow::ensure!(
        mc.variant == "bsa",
        "native backend implements the paper's bsa variant (got {:?})",
        mc.variant
    );
    mc.ball_size = mc.ball_size.min(mc.seq_len);
    mc.validate()?;
    let task = args.str_flag("task", "air");
    let gen = bsa::data::generator_for(&task, 0)?;
    let batch = sc.max_batch.max(1);
    let param_file = args.flag("params").or_else(|| args.flag("checkpoint"));
    let backend = match param_file {
        Some(p) => NativeBackend::load(
            Path::new(p),
            AttnHyper::from_model(&mc),
            mc.seq_len,
            batch,
        ),
        None => {
            let seed = args.u64_flag("seed", 0)?;
            NativeBackend::init(seed, &mc, gen.feature_dim(), 1, batch)
        }
    }?;
    // `--threads` / [serve] native_threads; 0 defers to the
    // BSA_NATIVE_THREADS env override, then hardware parallelism.
    // `--precision f16` quantizes the weights once and switches the
    // attention staging buffers to half-precision storage.
    Ok(backend
        .with_threads(sc.native_threads)
        .with_precision(sc.precision.parse()?))
}

/// Load params from --checkpoint, or run an init graph for random weights.
fn load_or_init_params(
    engine: &Arc<Engine>,
    tag: &str,
    args: &Args,
) -> anyhow::Result<Vec<bsa::tensor::Tensor>> {
    use bsa::runtime::literal_to_tensor;
    if let Some(p) = args.flag("checkpoint") {
        let ck = bsa::coordinator::checkpoint::Checkpoint::load(Path::new(p))?;
        let fwd = engine.load(&format!("fwd_{tag}"))?;
        let n = fwd.info.nparams;
        anyhow::ensure!(ck.arrays.len() >= n, "checkpoint too small for {tag}");
        return Ok(ck.arrays.into_iter().take(n).map(|(_, t)| t).collect());
    }
    // fall back: init graph with seed (serving random weights is still
    // useful for smoke tests and latency benches)
    let seed = args.u64_flag("seed", 0)? as i32;
    let init = engine.load(&format!("init_{tag}")).or_else(|_| {
        // fwd-only tags (e.g. n4096) borrow weights from the train-scale
        // init of the same variant when shapes match
        engine.load(&format!("init_{}", tag.replace("n4096_b1", "n1024_b2")))
    })?;
    let out = init.run(&[bsa::runtime::scalar_i32(seed)])?;
    out.iter().map(literal_to_tensor).collect()
}

fn cmd_gen_data(args: &Args) -> anyhow::Result<()> {
    let task = args.str_flag("task", "air");
    let samples = args.usize_flag("samples", 32)?;
    let points = args.usize_flag("points", 896)?;
    let seed = args.u64_flag("seed", 0)?;
    let out = args
        .flag("out")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from(format!("{task}_{samples}x{points}.bsad")));
    let gen = bsa::data::generator_for(&task, seed)?;
    let split = SplitSpec::paper_ratio(samples);
    let ds = Dataset::materialize(gen.as_ref(), samples, points, split);
    ds.save(&out)?;
    println!(
        "wrote {} samples x {} points ({}) norm mean={:.4} std={:.4}",
        samples,
        points,
        out.display(),
        ds.norm.mean,
        ds.norm.std
    );
    Ok(())
}

fn cmd_balltree(args: &Args) -> anyhow::Result<()> {
    let task = args.str_flag("task", "air");
    let points = args.usize_flag("points", 3584)?;
    let n = args.usize_flag("n", 4096)?;
    let seed = args.u64_flag("seed", 0)?;
    let gen = bsa::data::generator_for(&task, seed)?;
    let sample = gen.generate(0, points);
    let tree = bsa::balltree::BallTree::build(&sample.coords, n, seed);
    let mut t = Table::new(&["ball size", "#balls", "mean radius", "max radius"]);
    for m in [32, 64, 128, 256] {
        if n % m != 0 {
            continue;
        }
        let balls = tree.balls(m);
        let mean = balls.iter().map(|b| b.radius).sum::<f32>() / balls.len() as f32;
        let max = balls.iter().map(|b| b.radius).fold(0.0f32, f32::max);
        t.row(&[m.to_string(), balls.len().to_string(), format!("{mean:.4}"), format!("{max:.4}")]);
    }
    println!("{}", t.render());
    Ok(())
}

fn cmd_flops(args: &Args) -> anyhow::Result<()> {
    let n = args.usize_flag("n", 4096)?;
    let mut cfg = if args.has("paper") {
        ModelConfig::paper_scale()
    } else {
        ModelConfig::default()
    };
    cfg.seq_len = n;
    // --variant restricts the table to one row; an unknown name is a
    // clean CLI error (model_flops returns Err rather than panicking).
    let variants: Vec<String> = match args.flag("variant") {
        Some(v) => vec![v.to_string()],
        None => ["erwin", "full", "bsa", "bsa_nogs", "bsa_gc", "pointnet"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    };
    let mut t = Table::new(&["Attention type", "GFLOPS"]);
    for v in &variants {
        let f = model_flops(v, &cfg)?;
        t.row(&[v.clone(), format!("{:.2}", f.gflops())]);
    }
    println!("analytic FLOPs at N={n}, dim={}, blocks={}:", cfg.dim, cfg.num_blocks);
    println!("{}", t.render());
    Ok(())
}

fn cmd_config(args: &Args) -> anyhow::Result<()> {
    let doc = load_doc(args)?;
    let mc = if args.has("paper") { ModelConfig::paper_scale() } else { ModelConfig::from_doc(&doc) };
    mc.validate()?;
    println!("{}", table4(&mc));
    if args.has("show") {
        println!("{mc:#?}");
        println!("{:#?}", TrainConfig::from_doc(&doc));
        println!("{:#?}", ServeConfig::from_doc(&doc));
    }
    Ok(())
}

fn cmd_info(args: &Args) -> anyhow::Result<()> {
    let dir = args.str_flag("artifacts", "artifacts");
    let engine = Engine::new(Path::new(&dir))?;
    println!("platform: {}", engine.platform());

    // `bsa info <graph>`: HLO instruction statistics for one artifact
    if let Some(graph) = args.positional.first() {
        let g = engine.manifest.get(graph)?;
        let stats = bsa::hlostats::load(&Path::new(&dir).join(&g.file))?;
        println!("{graph} ({}):", g.file);
        println!("{}", stats.summary(12));
        return Ok(());
    }

    println!("artifacts in {dir}:");
    for name in engine.manifest.names() {
        let g = engine.manifest.get(name)?;
        println!(
            "  {name:<34} kind={:?} N={} B={} params={}",
            g.kind, g.n, g.batch, g.nparams
        );
    }
    Ok(())
}

/// `bsa shard`: run the sharded serving tier (bsa::shard). Workers are
/// either spawned as child `bsa serve --backend native` processes on
/// consecutive ports, or attached with `--worker-addrs` (in which case
/// their lifecycle stays external — the fleet probes and routes only).
fn cmd_shard(args: &Args) -> anyhow::Result<()> {
    use bsa::shard::{FaultPlan, Fleet, FrontDoor};
    let doc = load_doc(args)?;
    let mut cfg = bsa::config::ShardConfig::from_doc(&doc);
    cfg.addr = args.str_flag("addr", &cfg.addr);
    cfg.workers = args.usize_flag("workers", cfg.workers)?;
    cfg.worker_base_port =
        args.usize_flag("worker-base-port", cfg.worker_base_port as usize)? as u16;
    cfg.spill_inflight = args.usize_flag("spill-inflight", cfg.spill_inflight)?;
    cfg.drain_ms = args.u64_flag("drain-ms", cfg.drain_ms)?;
    let faults = Arc::new(FaultPlan::default());
    let fleet = match args.list_flag("worker-addrs") {
        Some(addrs) => {
            anyhow::ensure!(!addrs.is_empty(), "--worker-addrs has no addresses");
            println!(
                "shard front door on {} attaching {} workers: {}",
                cfg.addr,
                addrs.len(),
                addrs.join(", ")
            );
            Fleet::attach(cfg.clone(), &addrs, faults)
        }
        None => {
            // Spawned workers inherit the serve-shaping flags so the
            // whole fleet runs one consistent model/backend config.
            let mut extra = vec!["--backend".to_string(), "native".to_string()];
            for f in ["task", "n", "seed", "threads", "simd", "precision", "params", "config"] {
                if let Some(v) = args.flag(f) {
                    extra.push(format!("--{f}"));
                    extra.push(v.to_string());
                }
            }
            println!(
                "shard front door on {} spawning {} native workers from port {}",
                cfg.addr, cfg.workers, cfg.worker_base_port
            );
            Fleet::spawn(cfg.clone(), &extra, faults)?
        }
    };
    let fd = FrontDoor::start(fleet)?;
    println!(
        "shard tier up on {} (probe every {} ms, spill at {} in-flight, drain {} ms)",
        fd.local_addr(),
        cfg.probe_interval_ms,
        cfg.spill_inflight,
        cfg.drain_ms
    );
    install_stop_handler(fd.stop_flag());
    fd.run_until_stopped();
    Ok(())
}

/// `bsa loadgen <addr>`: open-loop load generator (bsa::shard::loadgen).
fn cmd_loadgen(args: &Args) -> anyhow::Result<()> {
    use bsa::shard::loadgen;
    let mut opts = loadgen::LoadgenOpts::default();
    opts.addr = match args.positional.first() {
        Some(a) => a.clone(),
        None => args.str_flag("addr", &opts.addr),
    };
    opts.rate_per_s = args.f64_flag("rate", opts.rate_per_s)?;
    opts.duration_ms = args.u64_flag("duration-ms", opts.duration_ms)?;
    opts.geoms = args.usize_flag("geoms", opts.geoms)?;
    opts.conns = args.usize_flag("conns", opts.conns)?;
    opts.zipf_s = args.f64_flag("zipf", opts.zipf_s)?;
    opts.task = args.str_flag("task", &opts.task);
    opts.points = args.usize_flag("points", opts.points)?;
    opts.seed = args.u64_flag("seed", opts.seed)?;
    if args.has("quick") {
        opts.rate_per_s = 25.0;
        opts.duration_ms = 2_000;
        opts.conns = 2;
    }
    println!(
        "loadgen -> {}: {:.0} req/s for {} ms, {} geometries (zipf {}), {} conns, {} points",
        opts.addr, opts.rate_per_s, opts.duration_ms, opts.geoms, opts.zipf_s, opts.conns,
        opts.points
    );
    let report = loadgen::run(&opts)?;
    report.print();
    // Machine-readable line: the same JSON object that lands in the
    // `shard` section of BENCH_serve.json, always on stdout — scripts
    // (e.g. the check.sh shard smoke) parse this even when no
    // ROADMAP.md is nearby and the artifact itself is not written.
    println!("report {}", report.to_json());
    match loadgen::write_bench_section(&report)? {
        Some(path) => println!("merged `shard` section into {path}"),
        None => println!("(no ROADMAP.md nearby; BENCH_serve.json not written)"),
    }
    Ok(())
}

/// `bsa stats <addr>`: query a live server's BSST frame and pretty-print
/// the router counters, per-stage span histograms, trace counters, and
/// worker-pool gauges. `--probe` first sends one synthetic prediction
/// (`--task`/`--points` shape it) so span histograms are populated even
/// against a freshly started server.
fn cmd_stats(args: &Args) -> anyhow::Result<()> {
    use bsa::trace::Json;
    let addr = match args.positional.first() {
        Some(a) => a.clone(),
        None => args.str_flag("addr", "127.0.0.1:7077"),
    };
    let mut client = bsa::server::Client::connect(&addr)?;
    if args.has("probe") {
        let task = args.str_flag("task", "air");
        let points = args.usize_flag("points", 896)?;
        let seed = args.u64_flag("seed", 0)?;
        let gen = bsa::data::generator_for(&task, seed)?;
        let sample = gen.generate(0, points);
        client.predict(&sample.coords, &sample.features)?;
    }
    let raw = client.stats()?;
    let doc = bsa::trace::parse_json(&raw)
        .map_err(|e| anyhow::anyhow!("stats frame is not valid JSON: {e}"))?;

    println!("server {addr}");
    println!("-- router");
    for key in [
        "served",
        "rejected",
        "batches",
        "mean_batch",
        "tree_hits",
        "tree_misses",
        "latency",
        "latency_n",
    ] {
        if let Some(v) = doc.get(key) {
            match v {
                Json::Str(s) => println!("  {key:<14} {s}"),
                Json::Num(x) if x.fract() == 0.0 => println!("  {key:<14} {x:.0}"),
                Json::Num(x) => println!("  {key:<14} {x:.3}"),
                other => println!("  {key:<14} {other:?}"),
            }
        }
    }

    let level = doc
        .get("trace_level")
        .and_then(Json::as_str)
        .unwrap_or("off");
    println!("-- trace (level {level})");
    if let Some(spans) = doc.get("spans").and_then(Json::entries) {
        if spans.is_empty() {
            println!("  no spans recorded (run with --trace spans and serve traffic)");
        } else {
            let mut t = Table::new(&["span", "n", "mean us", "p50 us", "p95 us", "p99 us", "max us"]);
            for (path, hist) in spans {
                let g = |k: &str| {
                    hist.get(k)
                        .and_then(Json::as_f64)
                        .map(|v| format!("{v:.1}"))
                        .unwrap_or_else(|| "-".into())
                };
                let n = hist
                    .get("n")
                    .and_then(Json::as_f64)
                    .map(|v| format!("{v:.0}"))
                    .unwrap_or_else(|| "-".into());
                t.row(&[
                    path.clone(),
                    n,
                    g("mean_us"),
                    g("p50_us"),
                    g("p95_us"),
                    g("p99_us"),
                    g("max_us"),
                ]);
            }
            print!("{}", t.render());
        }
    }
    if let Some(counters) = doc.get("counters").and_then(Json::entries) {
        if !counters.is_empty() {
            println!("-- counters");
            for (name, v) in counters {
                if let Some(x) = v.as_f64() {
                    println!("  {name:<24} {x:.0}");
                }
            }
        }
    }
    if let Some(gauges) = doc.get("gauges").and_then(Json::entries) {
        if !gauges.is_empty() {
            println!("-- gauges");
            for (name, v) in gauges {
                match v {
                    Json::Num(x) => println!("  {name:<24} {x:.3}"),
                    _ => println!("  {name:<24} null"),
                }
            }
        }
    }
    Ok(())
}
