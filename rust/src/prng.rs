//! Deterministic PRNG (SplitMix64 + xoshiro256**) used across the crate.
//!
//! No external `rand` crate is vendored offline, and determinism across
//! the dataset generators, the serving load generator, and the property
//! tests matters more than cryptographic quality — so this is a small,
//! fully specified generator with stable streams per seed.

/// xoshiro256** by Blackman & Vigna; seeded through SplitMix64.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator with a deterministic state derived from `seed`.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 to expand the seed into four non-zero words.
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = move || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (e.g. per-sample, per-worker).
    pub fn fold(&self, stream: u64) -> Self {
        let mut base = Rng::new(stream ^ 0xA076_1D64_78BD_642F);
        base.s[0] ^= self.s[0];
        base.s[1] ^= self.s[1];
        base.s[2] ^= self.s[2];
        base.s[3] ^= self.s[3];
        // avoid the all-zero state
        if base.s == [0, 0, 0, 0] {
            base.s[0] = 1;
        }
        base
    }

    /// Next raw 64-bit value.
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform f32 in [0, 1).
    pub fn uniform(&mut self) -> f32 {
        // 24 high bits -> [0, 1) with full float precision
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }

    /// Uniform f32 in [lo, hi).
    pub fn range(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.uniform()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.uniform().max(1e-9);
        let u2 = self.uniform();
        let r = (-2.0 * u1.ln()).sqrt();
        r * (2.0 * std::f32::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Vector of standard normals.
    pub fn normals(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let a: Vec<u64> = (0..8).map({ let mut r = Rng::new(42); move |_| r.next_u64() }).collect();
        let b: Vec<u64> = (0..8).map({ let mut r = Rng::new(42); move |_| r.next_u64() }).collect();
        assert_eq!(a, b);
        let c: Vec<u64> = (0..8).map({ let mut r = Rng::new(43); move |_| r.next_u64() }).collect();
        assert_ne!(a, c);
    }

    #[test]
    fn uniform_in_unit_interval() {
        let mut r = Rng::new(7);
        for _ in 0..10_000 {
            let u = r.uniform();
            assert!((0.0..1.0).contains(&u));
        }
    }

    #[test]
    fn normal_moments_sane() {
        let mut r = Rng::new(3);
        let xs = r.normals(20_000);
        let mean = xs.iter().sum::<f32>() / xs.len() as f32;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f32>() / xs.len() as f32;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn fold_streams_are_independent() {
        let base = Rng::new(1);
        let mut a = base.fold(0);
        let mut b = base.fold(1);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn below_bounds() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
    }
}
