//! Hand-rolled CLI parser (clap is not vendored offline).
//!
//! Supports `bsa <subcommand> [--flag value] [--switch] [positional...]`
//! with typed accessors, defaults, and generated help text.

use std::collections::BTreeMap;

/// Declarative flag spec for help text + validation.
#[derive(Debug, Clone)]
pub struct FlagSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub takes_value: bool,
    pub default: Option<&'static str>,
}

/// A parsed command line.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    flags: BTreeMap<String, String>,
    switches: Vec<String>,
    pub positional: Vec<String>,
}

#[derive(Debug, thiserror::Error)]
pub enum CliError {
    #[error("unknown flag --{0}")]
    UnknownFlag(String),
    #[error("flag --{0} requires a value")]
    MissingValue(String),
    #[error("invalid value for --{0}: {1}")]
    BadValue(String, String),
}

impl Args {
    /// Parse argv (excluding program name) against a flag spec table.
    pub fn parse(argv: &[String], specs: &[FlagSpec]) -> Result<Args, CliError> {
        let mut out = Args::default();
        let mut it = argv.iter().peekable();
        if let Some(first) = it.peek() {
            if !first.starts_with('-') {
                out.command = it.next().unwrap().clone();
            }
        }
        while let Some(arg) = it.next() {
            if let Some(name) = arg.strip_prefix("--") {
                // --flag=value form
                if let Some((k, v)) = name.split_once('=') {
                    let spec = find(specs, k).ok_or_else(|| CliError::UnknownFlag(k.into()))?;
                    if !spec.takes_value {
                        return Err(CliError::BadValue(k.into(), v.into()));
                    }
                    out.flags.insert(k.to_string(), v.to_string());
                    continue;
                }
                let spec =
                    find(specs, name).ok_or_else(|| CliError::UnknownFlag(name.into()))?;
                if spec.takes_value {
                    let v = it
                        .next()
                        .ok_or_else(|| CliError::MissingValue(name.into()))?;
                    out.flags.insert(name.to_string(), v.clone());
                } else {
                    out.switches.push(name.to_string());
                }
            } else {
                out.positional.push(arg.clone());
            }
        }
        // fill defaults
        for s in specs {
            if s.takes_value && !out.flags.contains_key(s.name) {
                if let Some(d) = s.default {
                    out.flags.insert(s.name.to_string(), d.to_string());
                }
            }
        }
        Ok(out)
    }

    pub fn flag(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }

    pub fn str_flag(&self, name: &str, default: &str) -> String {
        self.flag(name).unwrap_or(default).to_string()
    }

    pub fn usize_flag(&self, name: &str, default: usize) -> Result<usize, CliError> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::BadValue(name.into(), v.into())),
        }
    }

    pub fn f64_flag(&self, name: &str, default: f64) -> Result<f64, CliError> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::BadValue(name.into(), v.into())),
        }
    }

    pub fn u64_flag(&self, name: &str, default: u64) -> Result<u64, CliError> {
        match self.flag(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| CliError::BadValue(name.into(), v.into())),
        }
    }

    /// Comma-separated list flag (`--worker-addrs a:1,b:2`): trimmed,
    /// empty items dropped. `None` when the flag is absent; `Some`
    /// never contains an empty vec unless the value was all commas.
    pub fn list_flag(&self, name: &str) -> Option<Vec<String>> {
        self.flag(name).map(|v| {
            v.split(',')
                .map(|s| s.trim().to_string())
                .filter(|s| !s.is_empty())
                .collect()
        })
    }
}

fn find<'a>(specs: &'a [FlagSpec], name: &str) -> Option<&'a FlagSpec> {
    specs.iter().find(|s| s.name == name)
}

/// Render help text for a subcommand.
pub fn render_help(command: &str, about: &str, specs: &[FlagSpec]) -> String {
    let mut out = format!("bsa {command} — {about}\n\nflags:\n");
    for s in specs {
        let v = if s.takes_value { " <value>" } else { "" };
        let d = s
            .default
            .map(|d| format!(" (default: {d})"))
            .unwrap_or_default();
        out.push_str(&format!("  --{}{v}\n      {}{d}\n", s.name, s.help));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<FlagSpec> {
        vec![
            FlagSpec { name: "steps", help: "train steps", takes_value: true, default: Some("100") },
            FlagSpec { name: "task", help: "dataset", takes_value: true, default: Some("air") },
            FlagSpec { name: "verbose", help: "log more", takes_value: false, default: None },
        ]
    }

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_command_flags_positional() {
        let a = Args::parse(&sv(&["train", "--steps", "500", "--verbose", "extra"]), &specs())
            .unwrap();
        assert_eq!(a.command, "train");
        assert_eq!(a.usize_flag("steps", 0).unwrap(), 500);
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["extra"]);
    }

    #[test]
    fn equals_form() {
        let a = Args::parse(&sv(&["x", "--steps=7"]), &specs()).unwrap();
        assert_eq!(a.usize_flag("steps", 0).unwrap(), 7);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&sv(&["t"]), &specs()).unwrap();
        assert_eq!(a.str_flag("task", ""), "air");
        assert_eq!(a.usize_flag("steps", 0).unwrap(), 100);
    }

    #[test]
    fn unknown_flag_errors() {
        assert!(matches!(
            Args::parse(&sv(&["t", "--nope"]), &specs()),
            Err(CliError::UnknownFlag(_))
        ));
    }

    #[test]
    fn missing_value_errors() {
        assert!(matches!(
            Args::parse(&sv(&["t", "--steps"]), &specs()),
            Err(CliError::MissingValue(_))
        ));
    }

    #[test]
    fn bad_value_errors() {
        let a = Args::parse(&sv(&["t", "--steps", "abc"]), &specs()).unwrap();
        assert!(matches!(a.usize_flag("steps", 0), Err(CliError::BadValue(..))));
    }

    #[test]
    fn list_flag_splits_and_trims() {
        let a = Args::parse(&sv(&["t", "--task", "a:1, b:2 ,,c:3"]), &specs()).unwrap();
        assert_eq!(a.list_flag("task").unwrap(), vec!["a:1", "b:2", "c:3"]);
        assert_eq!(a.list_flag("steps").unwrap(), vec!["100"], "defaults flow through");
        let b = Args::parse(&sv(&["t"]), &specs()).unwrap();
        assert!(b.list_flag("verbose").is_none(), "absent flag is None");
    }

    #[test]
    fn help_mentions_flags() {
        let h = render_help("train", "train a model", &specs());
        assert!(h.contains("--steps"));
        assert!(h.contains("default: 100"));
    }
}
