//! Zero-dependency hierarchical span tracing + metrics registry.
//!
//! This is the observability substrate for the serve path and the native
//! backend: thread-local span stacks with RAII guards record wall-time into
//! a global lock-sharded registry of [`metrics::LatencyHistogram`]s keyed by
//! the dotted span path (`forward.layer.ball_attention`), plus named
//! counters and callback gauges. Everything is std-only — no serde, no
//! tracing crate — matching the repo's zero-dependency discipline.
//!
//! # Levels
//!
//! The subsystem has three levels, settable via `--trace off|counters|spans`
//! on `bsa serve` / the benches, or the `BSA_TRACE` environment variable
//! (`on` is accepted as an alias for `spans`):
//!
//! * `off` — nothing is recorded. Every instrumentation site costs one
//!   relaxed atomic load and a branch; there is no allocation, no lock, no
//!   clock read. This is the default.
//! * `counters` — named counters ([`incr`]) are recorded; spans stay inert.
//! * `spans` — counters plus full span timing: every [`span`] guard reads
//!   the monotonic clock twice and records the duration under its
//!   hierarchical path.
//!
//! # Span paths
//!
//! Span names are static strings; the recorded key is the dot-joined chain
//! of the active thread-local stack, e.g. a `span("ball_attention")` inside
//! `span("layer")` inside `span("forward")` records under
//! `forward.layer.ball_attention`. Spans cross [`WorkerPool`] job
//! boundaries via parent adoption: the dispatcher captures
//! [`current_path`] and each queued job installs it with [`adopt_parent`],
//! which swaps the worker's entire stack in and restores it on drop — so a
//! help-while-waiting thread running another dispatch's job cannot leak its
//! own path into the adopted one.
//!
//! [`WorkerPool`]: crate::backend::pool::WorkerPool
//!
//! # Chrome trace export
//!
//! When the chrome sink is enabled ([`enable_chrome`], wired to
//! `--trace-out <file>`), every closed span additionally appends a complete
//! ("ph":"X") trace event with a per-thread tid, and
//! [`write_chrome_trace`] serializes the buffer in Chrome trace-event
//! format — loadable directly in `chrome://tracing` or Perfetto. See
//! docs/FORMATS.md §2.3.1 for the BSST JSON schema these stats ride on.

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::fmt::Write as _;
use std::sync::atomic::{AtomicBool, AtomicU8, AtomicU64, Ordering};
use std::sync::{Mutex, OnceLock};
use std::time::{Duration, Instant, SystemTime};

use crate::metrics::LatencyHistogram;

/// Environment variable consulted for the initial trace level.
pub const TRACE_ENV: &str = "BSA_TRACE";

/// Schema version of the BSST `spans`/`gauges`/`counters` sections
/// (docs/FORMATS.md §2.3.1). Bump only on incompatible shape changes;
/// key additions are append-only and do not bump it.
pub const TRACE_SCHEMA_VERSION: u32 = 1;

// ---------------------------------------------------------------------------
// Level
// ---------------------------------------------------------------------------

/// How much the trace subsystem records. Ordered: each level includes the
/// previous one's recording.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum TraceLevel {
    /// Record nothing (default). One relaxed load per instrumentation site.
    Off = 0,
    /// Record named counters only.
    Counters = 1,
    /// Record counters and span timings.
    Spans = 2,
}

impl TraceLevel {
    /// Parse a user-facing level string. `"on"` is an alias for `"spans"`.
    pub fn parse(s: &str) -> Option<TraceLevel> {
        match s.trim().to_ascii_lowercase().as_str() {
            "off" | "0" | "none" | "" => Some(TraceLevel::Off),
            "counters" | "1" => Some(TraceLevel::Counters),
            "spans" | "on" | "2" => Some(TraceLevel::Spans),
            _ => None,
        }
    }

    /// The canonical name (`off` / `counters` / `spans`).
    pub fn as_str(self) -> &'static str {
        match self {
            TraceLevel::Off => "off",
            TraceLevel::Counters => "counters",
            TraceLevel::Spans => "spans",
        }
    }
}

impl std::fmt::Display for TraceLevel {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.as_str())
    }
}

impl std::str::FromStr for TraceLevel {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> anyhow::Result<TraceLevel> {
        TraceLevel::parse(s).ok_or_else(|| {
            anyhow::anyhow!("unknown trace level {s:?} (expected off|counters|spans)")
        })
    }
}

/// Global level. 255 = uninitialized sentinel: the first read resolves
/// `BSA_TRACE` lazily so library users get env control without any init
/// call, while `bsa serve --trace ...` overrides it explicitly.
static LEVEL: AtomicU8 = AtomicU8::new(255);

#[cold]
fn init_level_from_env() -> u8 {
    let lvl = std::env::var(TRACE_ENV)
        .ok()
        .and_then(|v| TraceLevel::parse(&v))
        .unwrap_or(TraceLevel::Off) as u8;
    // Racing initializers agree (env is stable), so a plain store is fine.
    LEVEL.store(lvl, Ordering::Relaxed);
    lvl
}

/// The active trace level.
#[inline]
pub fn level() -> TraceLevel {
    let raw = LEVEL.load(Ordering::Relaxed);
    let raw = if raw == 255 { init_level_from_env() } else { raw };
    match raw {
        1 => TraceLevel::Counters,
        2 => TraceLevel::Spans,
        _ => TraceLevel::Off,
    }
}

/// Override the trace level for the whole process (flag > config > env).
pub fn set_level(lvl: TraceLevel) {
    LEVEL.store(lvl as u8, Ordering::Relaxed);
}

/// True when counters (or more) are being recorded.
#[inline]
pub fn counters_enabled() -> bool {
    level() >= TraceLevel::Counters
}

/// True when span timings are being recorded.
#[inline]
pub fn spans_enabled() -> bool {
    level() == TraceLevel::Spans
}

// ---------------------------------------------------------------------------
// Thread-local span stack
// ---------------------------------------------------------------------------

#[derive(Default)]
struct SpanStack {
    /// Adopted prefix installed by [`adopt_parent`] (dispatcher's path).
    parent: Option<String>,
    /// Names of the spans currently open on this thread, outermost first.
    names: Vec<&'static str>,
}

impl SpanStack {
    fn path(&self) -> Option<String> {
        if self.parent.is_none() && self.names.is_empty() {
            return None;
        }
        let mut out = String::with_capacity(48);
        if let Some(p) = &self.parent {
            out.push_str(p);
        }
        for name in &self.names {
            if !out.is_empty() {
                out.push('.');
            }
            out.push_str(name);
        }
        Some(out)
    }
}

thread_local! {
    static STACK: RefCell<SpanStack> = RefCell::new(SpanStack::default());
}

/// The dotted path of the innermost open span on this thread (including any
/// adopted parent prefix), or `None` when no span is open. Dispatchers
/// capture this to hand to [`adopt_parent`] inside pool jobs.
pub fn current_path() -> Option<String> {
    STACK.with(|s| s.borrow().path())
}

/// RAII guard returned by [`span`]. On drop it records the elapsed wall
/// time under the full dotted path, then pops itself from the stack.
pub struct SpanGuard {
    start: Option<Instant>,
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        let Some(start) = self.start else { return };
        let elapsed = start.elapsed();
        let path = STACK.with(|s| {
            let mut st = s.borrow_mut();
            let path = st.path();
            st.names.pop();
            path
        });
        if let Some(path) = path {
            record_span(&path, elapsed, start);
        }
    }
}

/// Open a span named `name` on this thread. Inert (no clock read, no stack
/// push) unless the level is `spans`. Use via the [`span!`] macro or
/// directly; the guard closes the span when dropped.
///
/// [`span!`]: crate::span
#[inline]
pub fn span(name: &'static str) -> SpanGuard {
    if !spans_enabled() {
        return SpanGuard { start: None };
    }
    STACK.with(|s| s.borrow_mut().names.push(name));
    SpanGuard {
        start: Some(Instant::now()),
    }
}

/// Guard installed by [`adopt_parent`]: holds the worker thread's previous
/// span stack and restores it on drop.
pub struct ParentGuard {
    saved: SpanStack,
}

impl Drop for ParentGuard {
    fn drop(&mut self) {
        STACK.with(|s| {
            *s.borrow_mut() = std::mem::take(&mut self.saved);
        });
    }
}

/// Install `parent` as this thread's span prefix for the duration of the
/// returned guard. The *entire* current stack is swapped out (not just a
/// prefix): a help-while-waiting caller thread may execute another
/// dispatch's job with its own spans still open, and those must not leak
/// into the adopted path. Restored exactly on drop.
pub fn adopt_parent(parent: String) -> ParentGuard {
    let saved = STACK.with(|s| {
        std::mem::replace(
            &mut *s.borrow_mut(),
            SpanStack {
                parent: Some(parent),
                names: Vec::new(),
            },
        )
    });
    ParentGuard { saved }
}

// ---------------------------------------------------------------------------
// Sharded registry
// ---------------------------------------------------------------------------

const SHARDS: usize = 8;

#[derive(Default)]
struct Shard {
    spans: Mutex<BTreeMap<String, LatencyHistogram>>,
    counters: Mutex<BTreeMap<&'static str, u64>>,
}

struct Registry {
    shards: [Shard; SHARDS],
    gauges: Mutex<BTreeMap<&'static str, Box<dyn Fn() -> f64 + Send + Sync>>>,
}

static REGISTRY: OnceLock<Registry> = OnceLock::new();

fn registry() -> &'static Registry {
    REGISTRY.get_or_init(|| Registry {
        shards: Default::default(),
        gauges: Mutex::new(BTreeMap::new()),
    })
}

/// FNV-1a over the key bytes, folded to a shard index. Deterministic and
/// dependency-free; collisions only cost lock contention, never data.
fn shard_index(key: &str) -> usize {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in key.as_bytes() {
        h ^= u64::from(*b);
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    (h as usize) % SHARDS
}

fn record_span(path: &str, elapsed: Duration, start: Instant) {
    let reg = registry();
    {
        let shard = &reg.shards[shard_index(path)];
        let mut spans = shard.spans.lock().unwrap();
        match spans.get_mut(path) {
            Some(h) => h.record(elapsed),
            None => {
                let mut h = LatencyHistogram::new();
                h.record(elapsed);
                spans.insert(path.to_string(), h);
            }
        }
    }
    chrome_push(path, start, elapsed);
}

/// Record a pre-measured duration (in microseconds) under `path`, for call
/// sites that can't hold a guard across the measured region (e.g. router
/// queue wait measured from an enqueue timestamp). No-op unless spans are
/// enabled.
pub fn record_us(path: &'static str, us: f64) {
    if !spans_enabled() {
        return;
    }
    let reg = registry();
    let shard = &reg.shards[shard_index(path)];
    let mut spans = shard.spans.lock().unwrap();
    match spans.get_mut(path) {
        Some(h) => h.record_us(us),
        None => {
            let mut h = LatencyHistogram::new();
            h.record_us(us);
            spans.insert(path.to_string(), h);
        }
    }
}

/// Increment counter `name` by 1. No-op below the `counters` level.
#[inline]
pub fn incr(name: &'static str) {
    incr_by(name, 1);
}

/// Increment counter `name` by `n`. No-op below the `counters` level.
pub fn incr_by(name: &'static str, n: u64) {
    if !counters_enabled() {
        return;
    }
    let reg = registry();
    let shard = &reg.shards[shard_index(name)];
    let mut counters = shard.counters.lock().unwrap();
    *counters.entry(name).or_insert(0) += n;
}

/// Register a named gauge: `f` is called at snapshot time (BSST stats /
/// `bsa stats`). Re-registering a name replaces the previous callback, so
/// idempotent init paths (e.g. `global_pool`) are safe.
pub fn register_gauge(name: &'static str, f: Box<dyn Fn() -> f64 + Send + Sync>) {
    registry().gauges.lock().unwrap().insert(name, f);
}

/// [`register_gauge`] for runtime-formatted names (the shard tier's
/// per-worker gauges, `shard.worker<i>.*`). The registry keys on
/// `&'static str`, so the name is interned once in a process-wide table
/// and reused on re-registration — repeated fleet construction (tests,
/// respawn churn) re-registers gauges without growing the intern table
/// beyond the set of distinct names.
pub fn register_gauge_owned(name: String, f: Box<dyn Fn() -> f64 + Send + Sync>) {
    static INTERNED: Mutex<Vec<&'static str>> = Mutex::new(Vec::new());
    let mut interned = INTERNED.lock().unwrap();
    let key = match interned.iter().find(|s| **s == name) {
        Some(s) => *s,
        None => {
            let leaked: &'static str = Box::leak(name.into_boxed_str());
            interned.push(leaked);
            leaked
        }
    };
    drop(interned);
    register_gauge(key, f);
}

/// Snapshot of every span histogram, keyed by dotted path.
pub fn spans_snapshot() -> BTreeMap<String, LatencyHistogram> {
    let mut out = BTreeMap::new();
    for shard in &registry().shards {
        for (k, v) in shard.spans.lock().unwrap().iter() {
            out.insert(k.clone(), v.clone());
        }
    }
    out
}

/// Current value of one counter (0 if never incremented). One shard
/// lock instead of the full [`counters_snapshot`] walk — for tests and
/// the serve bench, which assert on individual `server.*` counts
/// without parsing a stats frame.
pub fn counter_value(name: &str) -> u64 {
    let reg = registry();
    let shard = &reg.shards[shard_index(name)];
    let counters = shard.counters.lock().unwrap();
    counters.get(name).copied().unwrap_or(0)
}

/// Snapshot of every counter.
pub fn counters_snapshot() -> BTreeMap<&'static str, u64> {
    let mut out = BTreeMap::new();
    for shard in &registry().shards {
        for (k, v) in shard.counters.lock().unwrap().iter() {
            out.insert(*k, *v);
        }
    }
    out
}

/// Evaluate every registered gauge.
pub fn gauges_snapshot() -> BTreeMap<&'static str, f64> {
    let gauges = registry().gauges.lock().unwrap();
    gauges.iter().map(|(k, f)| (*k, f())).collect()
}

/// Clear all recorded spans and counters (gauges keep their callbacks).
/// Test hook; also useful before an A/B overhead measurement.
pub fn reset() {
    for shard in &registry().shards {
        shard.spans.lock().unwrap().clear();
        shard.counters.lock().unwrap().clear();
    }
    let sink = chrome_sink();
    sink.events.lock().unwrap().clear();
}

/// The tracing sections of the BSST stats JSON, as `"key": value` pairs
/// without the enclosing braces (spliced into `server::write_stats`'s
/// hand-built object). Shape documented in docs/FORMATS.md §2.3.1.
pub fn stats_sections_json() -> String {
    let mut out = String::with_capacity(1024);
    let _ = write!(
        out,
        "\"trace_version\": {TRACE_SCHEMA_VERSION}, \"trace_level\": \"{}\"",
        level()
    );
    out.push_str(", \"spans\": {");
    let mut first = true;
    for (path, hist) in spans_snapshot() {
        if !first {
            out.push_str(", ");
        }
        first = false;
        let _ = write!(out, "\"{path}\": {}", hist.json());
    }
    out.push_str("}, \"counters\": {");
    let mut first = true;
    for (name, v) in counters_snapshot() {
        if !first {
            out.push_str(", ");
        }
        first = false;
        let _ = write!(out, "\"{name}\": {v}");
    }
    out.push_str("}, \"gauges\": {");
    let mut first = true;
    for (name, v) in gauges_snapshot() {
        if !first {
            out.push_str(", ");
        }
        first = false;
        let _ = write!(out, "\"{name}\": {}", fmt_f64(v));
    }
    out.push('}');
    out
}

/// JSON-safe float formatting: finite values print as-is, non-finite as
/// null (hand-rolled JSON has no Infinity/NaN literals).
fn fmt_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.3}")
    } else {
        "null".to_string()
    }
}

// ---------------------------------------------------------------------------
// Chrome trace-event sink
// ---------------------------------------------------------------------------

struct ChromeEvent {
    path: String,
    ts_us: f64,
    dur_us: f64,
    tid: u64,
}

struct ChromeSink {
    enabled: AtomicBool,
    events: Mutex<Vec<ChromeEvent>>,
    epoch: OnceLock<Instant>,
}

static CHROME: OnceLock<ChromeSink> = OnceLock::new();

/// Cap on buffered chrome events: a runaway spans-on serve run must not
/// grow without bound. ~1M events is ~100MB of JSON — past any useful
/// Perfetto load anyway.
const CHROME_EVENT_CAP: usize = 1 << 20;

fn chrome_sink() -> &'static ChromeSink {
    CHROME.get_or_init(|| ChromeSink {
        enabled: AtomicBool::new(false),
        events: Mutex::new(Vec::new()),
        epoch: OnceLock::new(),
    })
}

static NEXT_TID: AtomicU64 = AtomicU64::new(1);

thread_local! {
    static TID: u64 = NEXT_TID.fetch_add(1, Ordering::Relaxed);
}

/// Start buffering chrome trace events (wired to `--trace-out`). The epoch
/// for timestamps is fixed at the first enable.
pub fn enable_chrome() {
    let sink = chrome_sink();
    sink.epoch.get_or_init(Instant::now);
    sink.enabled.store(true, Ordering::Relaxed);
}

/// True when the chrome sink is buffering events.
pub fn chrome_enabled() -> bool {
    chrome_sink().enabled.load(Ordering::Relaxed)
}

fn chrome_push(path: &str, start: Instant, dur: Duration) {
    let sink = chrome_sink();
    if !sink.enabled.load(Ordering::Relaxed) {
        return;
    }
    let Some(epoch) = sink.epoch.get() else { return };
    // Saturating: a span that started before the epoch clamps to ts=0.
    let ts_us = start.duration_since(*epoch).as_secs_f64() * 1e6;
    let tid = TID.with(|t| *t);
    let mut events = sink.events.lock().unwrap();
    if events.len() >= CHROME_EVENT_CAP {
        return;
    }
    events.push(ChromeEvent {
        path: path.to_string(),
        ts_us,
        dur_us: dur.as_secs_f64() * 1e6,
        tid,
    });
}

/// Serialize the buffered events as Chrome trace-event-format JSON
/// (complete "X" events, pid=1, tid = per-thread counter). Loadable in
/// `chrome://tracing` and Perfetto.
pub fn chrome_trace_json() -> String {
    let sink = chrome_sink();
    let events = sink.events.lock().unwrap();
    let mut out = String::with_capacity(events.len() * 96 + 64);
    out.push_str("{\"traceEvents\": [");
    for (i, ev) in events.iter().enumerate() {
        if i > 0 {
            out.push_str(", ");
        }
        let _ = write!(
            out,
            "{{\"name\": \"{}\", \"cat\": \"bsa\", \"ph\": \"X\", \"ts\": {:.3}, \
             \"dur\": {:.3}, \"pid\": 1, \"tid\": {}}}",
            ev.path, ev.ts_us, ev.dur_us, ev.tid
        );
    }
    out.push_str("], \"displayTimeUnit\": \"ms\"}");
    out
}

/// Write [`chrome_trace_json`] to `path`.
pub fn write_chrome_trace(path: &std::path::Path) -> std::io::Result<()> {
    std::fs::write(path, chrome_trace_json())
}

/// Escape a string for embedding between quotes in hand-written JSON:
/// backslash, double quote, and control characters. Every emitter that
/// interpolates externally supplied text (worker addresses from config,
/// error messages) must pass it through here, or a single `"` in the
/// input produces a payload [`parse_json`] — and every other JSON
/// parser — rejects.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len());
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Mini JSON parser (for `bsa stats` — the client must read back the BSST
// payload the server hand-writes; still zero-dependency)
// ---------------------------------------------------------------------------

/// A parsed JSON value. Objects preserve insertion order via `Vec` so
/// `bsa stats` prints sections in server order.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    /// Look up a key in an object.
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(v) => Some(*v),
            _ => None,
        }
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Object entries, if this is an object.
    pub fn entries(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(pairs) => Some(pairs),
            _ => None,
        }
    }
}

/// Parse a JSON document. Recursive descent with a depth limit; supports
/// the subset this codebase emits (no unicode escapes beyond `\uXXXX`,
/// which are decoded for the BMP and replaced with U+FFFD outside it).
pub fn parse_json(text: &str) -> Result<Json, String> {
    let bytes = text.as_bytes();
    let mut p = Parser { b: bytes, i: 0 };
    p.skip_ws();
    let v = p.value(0)?;
    p.skip_ws();
    if p.i != bytes.len() {
        return Err(format!("trailing data at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

const MAX_DEPTH: usize = 64;

impl Parser<'_> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected {:?} at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self, depth: usize) -> Result<Json, String> {
        if depth > MAX_DEPTH {
            return Err("nesting too deep".into());
        }
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(depth),
            Some(b'[') => self.array(depth),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'n') => self.literal("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(c) => Err(format!("unexpected {:?} at byte {}", c as char, self.i)),
            None => Err("unexpected end of input".into()),
        }
    }

    fn literal(&mut self, lit: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(lit.as_bytes()) {
            self.i += lit.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).map_err(|e| e.to_string())?;
        s.parse::<f64>()
            .map(Json::Num)
            .map_err(|e| format!("bad number {s:?}: {e}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("truncated \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|e| e.to_string())?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|e| e.to_string())?;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 scalar (input is a &str, so valid).
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|e| e.to_string())?;
                    let ch = rest.chars().next().ok_or("unexpected end in string")?;
                    out.push(ch);
                    self.i += ch.len_utf8();
                }
                None => return Err("unterminated string".into()),
            }
        }
    }

    fn array(&mut self, depth: usize) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value(depth + 1)?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self, depth: usize) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value(depth + 1)?;
            out.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }
}

// ---------------------------------------------------------------------------
// UTC timestamp formatting (for the stderr logger — still zero-dependency)
// ---------------------------------------------------------------------------

/// Format a [`SystemTime`] as `YYYY-MM-DDTHH:MM:SS.mmmZ` using Howard
/// Hinnant's `civil_from_days` algorithm — no chrono, no libc localtime.
pub fn format_utc(t: SystemTime) -> String {
    let dur = t
        .duration_since(SystemTime::UNIX_EPOCH)
        .unwrap_or(Duration::ZERO);
    let secs = dur.as_secs();
    let millis = dur.subsec_millis();
    let days = (secs / 86_400) as i64;
    let rem = secs % 86_400;
    let (h, m, s) = (rem / 3600, (rem % 3600) / 60, rem % 60);

    // civil_from_days (Hinnant): days since 1970-01-01 -> (y, m, d).
    let z = days + 719_468;
    let era = z.div_euclid(146_097);
    let doe = z.rem_euclid(146_097);
    let yoe = (doe - doe / 1460 + doe / 36_524 - doe / 146_096) / 365;
    let y = yoe + era * 400;
    let doy = doe - (365 * yoe + yoe / 4 - yoe / 100);
    let mp = (5 * doy + 2) / 153;
    let d = doy - (153 * mp + 2) / 5 + 1;
    let mo = if mp < 10 { mp + 3 } else { mp - 9 };
    let y = if mo <= 2 { y + 1 } else { y };

    format!("{y:04}-{mo:02}-{d:02}T{h:02}:{m:02}:{s:02}.{millis:03}Z")
}

// ---------------------------------------------------------------------------
// Tests
// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;

    /// The trace level is process-global and lib tests run concurrently in
    /// one binary — every test that mutates the level serializes here.
    static TEST_LOCK: Mutex<()> = Mutex::new(());

    fn lock() -> std::sync::MutexGuard<'static, ()> {
        TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner())
    }

    #[test]
    fn json_escape_round_trips_through_parse_json() {
        assert_eq!(json_escape("127.0.0.1:9000"), "127.0.0.1:9000");
        for hostile in ["a\"b", "back\\slash", "nl\nline", "tab\there", "bell\u{7}"] {
            let doc = format!("{{\"addr\": \"{}\"}}", json_escape(hostile));
            let json = parse_json(&doc).expect("escaped string must parse");
            assert_eq!(json.get("addr").and_then(|v| v.as_str()), Some(hostile));
        }
    }

    #[test]
    fn level_parsing() {
        assert_eq!(TraceLevel::parse("off"), Some(TraceLevel::Off));
        assert_eq!(TraceLevel::parse("counters"), Some(TraceLevel::Counters));
        assert_eq!(TraceLevel::parse("spans"), Some(TraceLevel::Spans));
        assert_eq!(TraceLevel::parse("on"), Some(TraceLevel::Spans));
        assert_eq!(TraceLevel::parse("SPANS"), Some(TraceLevel::Spans));
        assert_eq!(TraceLevel::parse(""), Some(TraceLevel::Off));
        assert_eq!(TraceLevel::parse("bogus"), None);
        assert_eq!(TraceLevel::Spans.as_str(), "spans");
    }

    #[test]
    fn spans_nest_into_dotted_paths() {
        let _g = lock();
        let prev = level();
        set_level(TraceLevel::Spans);
        {
            let _a = span("t_nest_outer");
            {
                let _b = span("t_nest_inner");
                assert_eq!(
                    current_path().as_deref(),
                    Some("t_nest_outer.t_nest_inner")
                );
            }
            assert_eq!(current_path().as_deref(), Some("t_nest_outer"));
        }
        set_level(prev);
        let snap = spans_snapshot();
        assert!(snap.contains_key("t_nest_outer"));
        assert!(snap.contains_key("t_nest_outer.t_nest_inner"));
        assert_eq!(snap["t_nest_outer.t_nest_inner"].count(), 1);
    }

    #[test]
    fn disabled_mode_records_nothing() {
        let _g = lock();
        let prev = level();
        set_level(TraceLevel::Off);
        {
            let _a = span("t_disabled_span");
            incr("t_disabled_counter");
        }
        set_level(prev);
        assert!(!spans_snapshot().contains_key("t_disabled_span"));
        assert!(!counters_snapshot().contains_key("t_disabled_counter"));
    }

    #[test]
    fn counters_level_counts_but_does_not_time() {
        let _g = lock();
        let prev = level();
        set_level(TraceLevel::Counters);
        {
            let _a = span("t_counters_span");
            incr("t_counters_counter");
            incr_by("t_counters_counter", 4);
        }
        set_level(prev);
        assert_eq!(counters_snapshot().get("t_counters_counter"), Some(&5));
        assert!(!spans_snapshot().contains_key("t_counters_span"));
    }

    #[test]
    fn counter_value_reads_one_counter() {
        let _g = lock();
        let prev = level();
        set_level(TraceLevel::Counters);
        assert_eq!(counter_value("t_counter_value_probe"), 0, "unknown counter reads 0");
        incr_by("t_counter_value_probe", 3);
        set_level(prev);
        assert_eq!(counter_value("t_counter_value_probe"), 3);
        assert_eq!(
            counters_snapshot().get("t_counter_value_probe"),
            Some(&counter_value("t_counter_value_probe")),
            "point read agrees with the full snapshot"
        );
    }

    #[test]
    fn adopt_parent_swaps_and_restores_whole_stack() {
        let _g = lock();
        let prev = level();
        set_level(TraceLevel::Spans);
        {
            let _mine = span("t_adopt_mine");
            assert_eq!(current_path().as_deref(), Some("t_adopt_mine"));
            {
                let _p = adopt_parent("t_adopt_parent.dispatch".to_string());
                // The caller's own open span must NOT leak into the
                // adopted path (help-while-waiting correctness).
                assert_eq!(
                    current_path().as_deref(),
                    Some("t_adopt_parent.dispatch")
                );
                let _child = span("t_adopt_child");
                assert_eq!(
                    current_path().as_deref(),
                    Some("t_adopt_parent.dispatch.t_adopt_child")
                );
                drop(_child);
            }
            assert_eq!(current_path().as_deref(), Some("t_adopt_mine"));
        }
        set_level(prev);
        assert!(spans_snapshot().contains_key("t_adopt_parent.dispatch.t_adopt_child"));
    }

    #[test]
    fn spans_cross_pool_job_boundaries() {
        let _g = lock();
        let prev = level();
        set_level(TraceLevel::Spans);
        {
            let _outer = span("t_pool_outer");
            assert_eq!(current_path().as_deref(), Some("t_pool_outer"));
            let mut data = vec![0u64; 64];
            // Adoption is built into par_rows: queued jobs inherit the
            // dispatcher's path with no per-call plumbing.
            crate::backend::pool::par_rows(&mut data, 1, 8, |row0, chunk| {
                let _s = span("t_pool_job");
                for (i, v) in chunk.iter_mut().enumerate() {
                    *v = (row0 + i) as u64;
                }
            });
            // Caller's own stack intact after helping with jobs.
            assert_eq!(current_path().as_deref(), Some("t_pool_outer"));
            for (i, v) in data.iter().enumerate() {
                assert_eq!(*v, i as u64);
            }
        }
        set_level(prev);
        let snap = spans_snapshot();
        assert!(snap.contains_key("t_pool_outer.t_pool_job"));
        assert!(snap["t_pool_outer.t_pool_job"].count() >= 1);
    }

    #[test]
    fn gauges_evaluate_at_snapshot_time() {
        let _g = lock();
        register_gauge("t_gauge", Box::new(|| 42.5));
        let snap = gauges_snapshot();
        assert_eq!(snap.get("t_gauge"), Some(&42.5));
        // Re-registering replaces.
        register_gauge("t_gauge", Box::new(|| 7.0));
        assert_eq!(gauges_snapshot().get("t_gauge"), Some(&7.0));
    }

    #[test]
    fn chrome_trace_is_valid_json_with_matched_events() {
        let _g = lock();
        let prev = level();
        set_level(TraceLevel::Spans);
        enable_chrome();
        {
            let _a = span("t_chrome_outer");
            let _b = span("t_chrome_inner");
        }
        set_level(prev);
        let text = chrome_trace_json();
        let doc = parse_json(&text).expect("chrome trace must be valid JSON");
        let events = doc
            .get("traceEvents")
            .and_then(|v| match v {
                Json::Arr(a) => Some(a),
                _ => None,
            })
            .expect("traceEvents array");
        let mut seen_outer = false;
        let mut seen_inner = false;
        for ev in events {
            assert_eq!(ev.get("ph").and_then(Json::as_str), Some("X"));
            assert!(ev.get("ts").and_then(Json::as_f64).is_some());
            assert!(ev.get("dur").and_then(Json::as_f64).is_some());
            assert!(ev.get("tid").and_then(Json::as_f64).is_some());
            match ev.get("name").and_then(Json::as_str) {
                Some("t_chrome_outer") => seen_outer = true,
                Some("t_chrome_outer.t_chrome_inner") => seen_inner = true,
                _ => {}
            }
        }
        assert!(seen_outer && seen_inner, "both spans present as X events");
    }

    #[test]
    fn stats_sections_shape() {
        let _g = lock();
        let prev = level();
        set_level(TraceLevel::Spans);
        {
            let _a = span("t_stats_span");
            incr("t_stats_counter");
        }
        set_level(prev);
        let wrapped = format!("{{{}}}", stats_sections_json());
        let doc = parse_json(&wrapped).expect("stats sections must parse");
        assert_eq!(
            doc.get("trace_version").and_then(Json::as_f64),
            Some(f64::from(TRACE_SCHEMA_VERSION))
        );
        assert!(doc.get("trace_level").and_then(Json::as_str).is_some());
        let spans = doc.get("spans").expect("spans object");
        let hist = spans.get("t_stats_span").expect("recorded span present");
        for key in ["n", "mean_us", "p50_us", "p95_us", "p99_us", "max_us"] {
            assert!(hist.get(key).is_some(), "span histogram missing {key}");
        }
        assert_eq!(
            doc.get("counters")
                .and_then(|c| c.get("t_stats_counter"))
                .and_then(Json::as_f64),
            Some(1.0)
        );
        assert!(doc.get("gauges").is_some());
    }

    #[test]
    fn record_us_aggregates_without_guard() {
        let _g = lock();
        let prev = level();
        set_level(TraceLevel::Spans);
        record_us("t_record_us", 100.0);
        record_us("t_record_us", 300.0);
        set_level(prev);
        let snap = spans_snapshot();
        let h = &snap["t_record_us"];
        assert_eq!(h.count(), 2);
        assert!((h.mean_us() - 200.0).abs() < 1e-9);
    }

    #[test]
    fn json_parser_round_trips() {
        let doc = parse_json(
            r#"{"a": 1.5, "b": [true, false, null], "c": {"nested": "str\n\"q\""}, "d": -2e3}"#,
        )
        .unwrap();
        assert_eq!(doc.get("a").and_then(Json::as_f64), Some(1.5));
        assert_eq!(
            doc.get("b"),
            Some(&Json::Arr(vec![
                Json::Bool(true),
                Json::Bool(false),
                Json::Null
            ]))
        );
        assert_eq!(
            doc.get("c").and_then(|c| c.get("nested")).and_then(Json::as_str),
            Some("str\n\"q\"")
        );
        assert_eq!(doc.get("d").and_then(Json::as_f64), Some(-2000.0));
        assert!(parse_json("{\"unterminated\": ").is_err());
        assert!(parse_json("[1, 2,]").is_err());
        assert!(parse_json("{} trailing").is_err());
    }

    #[test]
    fn format_utc_known_dates() {
        assert_eq!(
            format_utc(SystemTime::UNIX_EPOCH),
            "1970-01-01T00:00:00.000Z"
        );
        // 2000-02-29T12:34:56.789Z == 951827696.789 (leap day crossing).
        let t = SystemTime::UNIX_EPOCH + Duration::from_millis(951_827_696_789);
        assert_eq!(format_utc(t), "2000-02-29T12:34:56.789Z");
        // 2026-08-08T00:00:00Z == 1786147200.
        let t = SystemTime::UNIX_EPOCH + Duration::from_secs(1_786_147_200);
        assert_eq!(format_utc(t), "2026-08-08T00:00:00.000Z");
    }
}
