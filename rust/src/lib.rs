//! # BSA — Ball Sparse Attention for Large-scale Geometries
//!
//! Rust coordinator (Layer 3) of the three-layer BSA stack:
//!
//! * **L1** — Pallas kernels (`python/compile/kernels/`): ball attention,
//!   flash attention, block compression, grouped selection attention.
//! * **L2** — JAX model zoo (`python/compile/model.py`): the paper's
//!   BSA transformer plus Full-Attention / Erwin-style / PointNet
//!   baselines, AOT-lowered to HLO text artifacts.
//! * **L3** — this crate: ball-tree geometry substrate, synthetic dataset
//!   generators, inference backends, PJRT runtime, training orchestrator,
//!   serving router with dynamic batching, metrics, analytic FLOPs model,
//!   CLI.
//!
//! Inference is multi-backend behind the [`backend::Backend`] trait:
//!
//! * [`backend::PjrtBackend`] executes AOT-compiled HLO through the PJRT
//!   C API (`xla` crate) — Python never runs on the request path;
//!   `make artifacts` lowers the model once.
//! * [`backend::NativeBackend`] runs the full BSA forward pass in pure
//!   Rust (ball attention, block compression, grouped selection, gated
//!   merge), so serving, benches, and integration tests work on hosts
//!   with no artifacts and no Python/XLA toolchain at all — and double
//!   as a semantic parity oracle for the compiled graphs.
//!
//! See `DESIGN.md` for the paper-to-module map and `EXPERIMENTS.md` for
//! reproduction results.

pub mod backend;
pub mod balltree;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod flops;
pub mod half;
pub mod hlostats;
pub mod metrics;
pub mod prng;
pub mod proptest_lite;
pub mod rfield;
pub mod runtime;
pub mod server;
pub mod shard;
pub mod tensor;
pub mod trace;
pub mod viz;

/// Crate version string (mirrors Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");

/// Open a trace span on the current thread: `let _s = span!("forward");`.
/// Sugar for [`trace::span`]; inert unless `--trace spans` / `BSA_TRACE=spans`
/// is active (one relaxed atomic load when disabled).
#[macro_export]
macro_rules! span {
    ($name:expr) => {
        $crate::trace::span($name)
    };
}
