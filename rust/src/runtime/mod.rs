//! PJRT runtime: loads AOT-compiled HLO-text artifacts and executes them.
//!
//! Wraps the `xla` crate (PJRT C API): one CPU client per process, an
//! executable cache keyed by graph name, and typed conversions between the
//! host [`Tensor`](crate::tensor::Tensor) type and `xla::Literal`s.
//!
//! HLO **text** is the interchange format: jax >= 0.5 emits HloModuleProto
//! with 64-bit instruction ids which xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see aot.py docstring and /opt/xla-example).

pub mod manifest;

pub use manifest::{DType, GraphInfo, GraphKind, IoSpec, Manifest};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::{Arc, Condvar, Mutex};

use crate::tensor::Tensor;

/// A loaded, compiled graph plus its manifest entry.
pub struct Executable {
    pub info: GraphInfo,
    exe: xla::PjRtLoadedExecutable,
}

/// Process-wide execute lock.
///
/// The TFRT CPU PJRT client shares one intra-op thread pool sized by the
/// host's core count; on small hosts (this testbed has a single core)
/// two concurrent `Execute` calls deadlock — one call's completion waits
/// on pool progress that the other call is blocking. All executions are
/// therefore serialized here; serving workers still overlap their
/// pre/post-processing (ball-tree build, permutation, framing) with the
/// running computation.
static EXECUTE_LOCK: Mutex<()> = Mutex::new(());

impl Executable {
    /// Execute with literal inputs; returns the flattened output literals
    /// (the lowered graphs always return a tuple — it is decomposed here).
    pub fn run(&self, inputs: &[xla::Literal]) -> anyhow::Result<Vec<xla::Literal>> {
        let refs: Vec<&xla::Literal> = inputs.iter().collect();
        self.run_borrowed(&refs)
    }

    /// Execute with borrowed literal inputs (no copies; the hot path).
    pub fn run_borrowed(&self, inputs: &[&xla::Literal]) -> anyhow::Result<Vec<xla::Literal>> {
        anyhow::ensure!(
            inputs.len() == self.info.inputs.len(),
            "graph {} expects {} inputs, got {}",
            self.info.name,
            self.info.inputs.len(),
            inputs.len()
        );
        let result = {
            let _guard = EXECUTE_LOCK.lock().unwrap();
            self.exe
                .execute::<&xla::Literal>(inputs)
                .map_err(|e| anyhow::anyhow!("execute {}: {e}", self.info.name))?
        };
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("download {}: {e}", self.info.name))?;
        let outs = tuple
            .to_tuple()
            .map_err(|e| anyhow::anyhow!("untuple {}: {e}", self.info.name))?;
        anyhow::ensure!(
            outs.len() == self.info.outputs.len(),
            "graph {} returned {} outputs, manifest says {}",
            self.info.name,
            outs.len(),
            self.info.outputs.len()
        );
        Ok(outs)
    }

    /// Execute with host tensors for the trailing inputs and borrowed
    /// literal state for the leading ones (fwd graphs: params + x).
    /// State literals are NOT copied (perf: the first implementation
    /// deep-cloned ~5 MB of parameters per call — EXPERIMENTS.md §Perf).
    pub fn run_with_tensors(
        &self,
        state: &[xla::Literal],
        tensors: &[&Tensor],
    ) -> anyhow::Result<Vec<xla::Literal>> {
        let extra: Vec<xla::Literal> = tensors
            .iter()
            .map(|t| tensor_to_literal(t))
            .collect::<Result<_, _>>()?;
        let inputs: Vec<&xla::Literal> = state.iter().chain(extra.iter()).collect();
        self.run_borrowed(&inputs)
    }
}

// SAFETY: PJRT clients and loaded executables are documented as
// thread-safe in the PJRT C API (executions may be issued from multiple
// threads; the runtime synchronizes internally). The wrapper types hold
// raw pointers, which is the only reason the compiler cannot derive
// Send/Sync. The serving worker pool relies on this.
unsafe impl Send for Executable {}
unsafe impl Sync for Executable {}

/// Executable-cache entry: compiled, or claimed by an in-flight compile.
///
/// The `Building` marker is what makes [`Engine::load`] single-flight:
/// a thread that finds it waits on the condvar instead of compiling the
/// same graph a second time (the original double-checked cache let two
/// threads that both missed race into duplicate compiles).
enum CacheSlot {
    Ready(Arc<Executable>),
    Building,
}

/// Process-wide engine: PJRT client + manifest + executable cache.
pub struct Engine {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    dir: PathBuf,
    cache: Mutex<HashMap<String, CacheSlot>>,
    /// Signalled when an in-flight compile finishes (or fails).
    cache_done: Condvar,
}

// SAFETY: see the note on `Executable`; the client pointer is thread-safe
// and the cache is mutex-guarded.
unsafe impl Send for Engine {}
unsafe impl Sync for Engine {}

impl Engine {
    /// Create an engine over an artifacts directory (`artifacts/` by
    /// default; must contain `manifest.txt` from `make artifacts`).
    pub fn new(artifacts_dir: &Path) -> anyhow::Result<Engine> {
        let manifest = Manifest::load(&artifacts_dir.join("manifest.txt"))?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("creating PJRT CPU client: {e}"))?;
        Ok(Engine {
            client,
            manifest,
            dir: artifacts_dir.to_path_buf(),
            cache: Mutex::new(HashMap::new()),
            cache_done: Condvar::new(),
        })
    }

    /// Resolve the default artifacts directory (env override, then ./artifacts).
    pub fn default_dir() -> PathBuf {
        std::env::var("BSA_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("artifacts"))
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load (or fetch from cache) a compiled graph by manifest name.
    ///
    /// Single-flight: the first thread to miss claims the entry
    /// (`CacheSlot::Building`) and compiles outside the cache lock;
    /// concurrent callers for the same graph block on the condvar and
    /// receive the shared executable, so each graph compiles exactly
    /// once per engine. A failed compile clears the claim (and wakes
    /// waiters to retry or fail themselves) rather than caching the
    /// error.
    pub fn load(&self, name: &str) -> anyhow::Result<Arc<Executable>> {
        {
            let mut cache = self.cache.lock().unwrap();
            loop {
                let in_flight = match cache.get(name) {
                    Some(CacheSlot::Ready(e)) => return Ok(e.clone()),
                    Some(CacheSlot::Building) => true,
                    None => false,
                };
                if in_flight {
                    cache = self.cache_done.wait(cache).unwrap();
                } else {
                    cache.insert(name.to_string(), CacheSlot::Building);
                    break;
                }
            }
        }
        // Panic-safe claim: if the compile below unwinds (poisoned
        // EXECUTE_LOCK, FFI abort surfaced as a panic), the guard clears
        // the `Building` marker and wakes waiters so they retry or fail
        // themselves — a panic must degrade to "someone else compiles",
        // never to a permanent hang of every loader of this graph.
        struct Claim<'a> {
            engine: &'a Engine,
            name: &'a str,
            done: bool,
        }
        impl Drop for Claim<'_> {
            fn drop(&mut self) {
                if !self.done {
                    // recover a poisoned lock: panicking inside Drop
                    // during unwind would abort the process
                    let mut cache = self
                        .engine
                        .cache
                        .lock()
                        .unwrap_or_else(std::sync::PoisonError::into_inner);
                    cache.remove(self.name);
                    drop(cache);
                    self.engine.cache_done.notify_all();
                }
            }
        }
        let mut claim = Claim { engine: self, name, done: false };

        let built = (|| -> anyhow::Result<Arc<Executable>> {
            let info = self.manifest.get(name)?.clone();
            let path = self.dir.join(&info.file);
            let proto = xla::HloModuleProto::from_text_file(&path)
                .map_err(|e| anyhow::anyhow!("parsing {}: {e}", path.display()))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = {
                // serialize with executions (see EXECUTE_LOCK)
                let _guard = EXECUTE_LOCK.lock().unwrap();
                self.client
                    .compile(&comp)
                    .map_err(|e| anyhow::anyhow!("compiling {name}: {e}"))?
            };
            Ok(Arc::new(Executable { info, exe }))
        })();
        let mut cache = self.cache.lock().unwrap();
        match &built {
            Ok(entry) => {
                cache.insert(name.to_string(), CacheSlot::Ready(entry.clone()));
            }
            Err(_) => {
                // release the claim so a later caller can retry
                cache.remove(name);
            }
        }
        claim.done = true;
        drop(cache);
        self.cache_done.notify_all();
        built
    }

    /// Number of compiled graphs currently cached (in-flight compiles
    /// are not counted).
    pub fn cached(&self) -> usize {
        self.cache
            .lock()
            .unwrap()
            .values()
            .filter(|s| matches!(s, CacheSlot::Ready(_)))
            .count()
    }
}

// ---------------------------------------------------------------------------
// literal <-> tensor conversions
// ---------------------------------------------------------------------------

/// Host tensor -> rank-N f32 literal.
pub fn tensor_to_literal(t: &Tensor) -> anyhow::Result<xla::Literal> {
    let flat = xla::Literal::vec1(t.data());
    if t.shape().is_empty() {
        return Ok(flat.reshape(&[]).map_err(|e| anyhow::anyhow!("reshape scalar: {e}"))?);
    }
    let dims: Vec<i64> = t.shape().iter().map(|&d| d as i64).collect();
    flat.reshape(&dims).map_err(|e| anyhow::anyhow!("reshape {dims:?}: {e}"))
}

/// Literal -> host tensor (f32; converts ints if needed).
pub fn literal_to_tensor(l: &xla::Literal) -> anyhow::Result<Tensor> {
    let shape = l
        .array_shape()
        .map_err(|e| anyhow::anyhow!("literal shape: {e}"))?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = l
        .to_vec::<f32>()
        .map_err(|e| anyhow::anyhow!("literal to f32 vec: {e}"))?;
    Ok(Tensor::new(dims, data))
}

/// f32 scalar literal.
pub fn scalar_f32(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// i32 scalar literal.
pub fn scalar_i32(x: i32) -> xla::Literal {
    xla::Literal::scalar(x)
}

/// Deep-copy a literal (the xla crate exposes no Clone; round-trip bytes).
pub fn clone_literal(l: &xla::Literal) -> anyhow::Result<xla::Literal> {
    let shape = l
        .array_shape()
        .map_err(|e| anyhow::anyhow!("clone shape: {e}"))?;
    let dims: Vec<i64> = shape.dims().to_vec();
    match l.ty().map_err(|e| anyhow::anyhow!("clone ty: {e}"))? {
        xla::ElementType::F32 => {
            let v = l.to_vec::<f32>().map_err(|e| anyhow::anyhow!("{e}"))?;
            Ok(xla::Literal::vec1(&v)
                .reshape(&dims)
                .map_err(|e| anyhow::anyhow!("{e}"))?)
        }
        xla::ElementType::S32 => {
            let v = l.to_vec::<i32>().map_err(|e| anyhow::anyhow!("{e}"))?;
            Ok(xla::Literal::vec1(&v)
                .reshape(&dims)
                .map_err(|e| anyhow::anyhow!("{e}"))?)
        }
        other => Err(anyhow::anyhow!("clone: unsupported element type {other:?}")),
    }
}

/// Extract the f32 scalar value of a literal.
pub fn literal_scalar_f32(l: &xla::Literal) -> anyhow::Result<f32> {
    l.get_first_element::<f32>()
        .map_err(|e| anyhow::anyhow!("scalar extract: {e}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_literal_roundtrip() {
        let t = Tensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let l = tensor_to_literal(&t).unwrap();
        let back = literal_to_tensor(&l).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn scalar_literals() {
        let l = scalar_f32(2.5);
        assert_eq!(literal_scalar_f32(&l).unwrap(), 2.5);
        let i = scalar_i32(7);
        assert_eq!(i.get_first_element::<i32>().unwrap(), 7);
    }

    #[test]
    fn clone_literal_independent() {
        let t = Tensor::new(vec![4], vec![1., 2., 3., 4.]);
        let l = tensor_to_literal(&t).unwrap();
        let c = clone_literal(&l).unwrap();
        assert_eq!(literal_to_tensor(&c).unwrap(), t);
    }
}
