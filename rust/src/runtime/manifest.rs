//! Parser for `artifacts/manifest.txt`, the contract between aot.py and
//! the rust runtime: every lowered graph's file, role, sparse-attention
//! parameters, and exact input/output shapes in flattening order.

use std::collections::BTreeMap;
use std::path::Path;

/// Element type of a graph I/O slot.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
    U32,
}

impl DType {
    fn parse(s: &str) -> anyhow::Result<DType> {
        match s {
            "f32" => Ok(DType::F32),
            "i32" => Ok(DType::I32),
            "u32" => Ok(DType::U32),
            other => Err(anyhow::anyhow!("unknown dtype {other}")),
        }
    }
}

/// One input or output slot.
#[derive(Debug, Clone, PartialEq)]
pub struct IoSpec {
    pub index: usize,
    pub dtype: DType,
    /// Empty for scalars.
    pub dims: Vec<usize>,
    pub name: String,
}

impl IoSpec {
    pub fn elements(&self) -> usize {
        self.dims.iter().product()
    }
}

/// Role of a graph in the pipeline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GraphKind {
    Init,
    Fwd,
    Train,
    Attn,
}

impl GraphKind {
    fn parse(s: &str) -> anyhow::Result<GraphKind> {
        match s {
            "init" => Ok(GraphKind::Init),
            "fwd" => Ok(GraphKind::Fwd),
            "train" => Ok(GraphKind::Train),
            "attn" => Ok(GraphKind::Attn),
            other => Err(anyhow::anyhow!("unknown graph kind {other}")),
        }
    }
}

/// Everything the runtime needs to know about one lowered graph.
#[derive(Debug, Clone)]
pub struct GraphInfo {
    pub name: String,
    pub file: String,
    pub kind: GraphKind,
    pub tag: String,
    pub n: usize,
    pub batch: usize,
    pub nparams: usize,
    pub ball_size: usize,
    pub cmp_block: usize,
    pub group_size: usize,
    pub top_k: usize,
    pub in_features: usize,
    pub out_features: usize,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

/// The parsed manifest: graph name -> info.
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    graphs: BTreeMap<String, GraphInfo>,
}

impl Manifest {
    pub fn load(path: &Path) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e} (run `make artifacts`)", path.display()))?;
        Self::parse(&text)
    }

    pub fn parse(text: &str) -> anyhow::Result<Manifest> {
        let mut graphs = BTreeMap::new();
        let mut cur: Option<GraphInfo> = None;

        for (lineno, raw) in text.lines().enumerate() {
            let line = raw.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            if let Some(name) = line.strip_prefix("[graph ") {
                if let Some(g) = cur.take() {
                    graphs.insert(g.name.clone(), g);
                }
                let name = name
                    .strip_suffix(']')
                    .ok_or_else(|| anyhow::anyhow!("line {}: bad graph header", lineno + 1))?;
                cur = Some(GraphInfo {
                    name: name.to_string(),
                    file: String::new(),
                    kind: GraphKind::Fwd,
                    tag: String::new(),
                    n: 0,
                    batch: 0,
                    nparams: 0,
                    ball_size: 0,
                    cmp_block: 0,
                    group_size: 0,
                    top_k: 0,
                    in_features: 0,
                    out_features: 0,
                    inputs: vec![],
                    outputs: vec![],
                });
                continue;
            }
            let g = cur
                .as_mut()
                .ok_or_else(|| anyhow::anyhow!("line {}: key outside [graph]", lineno + 1))?;
            let mut parts = line.splitn(2, ' ');
            let key = parts.next().unwrap_or_default();
            let rest = parts.next().unwrap_or_default().trim();
            match key {
                "file" => g.file = rest.to_string(),
                "kind" => g.kind = GraphKind::parse(rest)?,
                "tag" => g.tag = rest.to_string(),
                "n" => g.n = rest.parse()?,
                "batch" => g.batch = rest.parse()?,
                "nparams" => g.nparams = rest.parse()?,
                "ball_size" => g.ball_size = rest.parse()?,
                "cmp_block" => g.cmp_block = rest.parse()?,
                "group_size" => g.group_size = rest.parse()?,
                "top_k" => g.top_k = rest.parse()?,
                "in_features" => g.in_features = rest.parse()?,
                "out_features" => g.out_features = rest.parse()?,
                "input" | "output" => {
                    let spec = parse_io(rest)
                        .map_err(|e| anyhow::anyhow!("line {}: {e}", lineno + 1))?;
                    if key == "input" {
                        g.inputs.push(spec);
                    } else {
                        g.outputs.push(spec);
                    }
                }
                other => anyhow::bail!("line {}: unknown manifest key {other:?}", lineno + 1),
            }
        }
        if let Some(g) = cur.take() {
            graphs.insert(g.name.clone(), g);
        }
        Ok(Manifest { graphs })
    }

    pub fn get(&self, name: &str) -> anyhow::Result<&GraphInfo> {
        self.graphs.get(name).ok_or_else(|| {
            anyhow::anyhow!(
                "graph {name:?} not in manifest (have: {:?}); re-run `make artifacts` \
                 with the right suite",
                self.graphs.keys().take(8).collect::<Vec<_>>()
            )
        })
    }

    pub fn names(&self) -> impl Iterator<Item = &str> {
        self.graphs.keys().map(|s| s.as_str())
    }

    pub fn len(&self) -> usize {
        self.graphs.len()
    }

    pub fn is_empty(&self) -> bool {
        self.graphs.is_empty()
    }
}

/// Parse `"<idx> <dtype> <dims|scalar> <name>"`.
fn parse_io(s: &str) -> anyhow::Result<IoSpec> {
    let mut it = s.split_whitespace();
    let index: usize = it
        .next()
        .ok_or_else(|| anyhow::anyhow!("missing index"))?
        .parse()?;
    let dtype = DType::parse(it.next().ok_or_else(|| anyhow::anyhow!("missing dtype"))?)?;
    let dims_s = it.next().ok_or_else(|| anyhow::anyhow!("missing dims"))?;
    let dims = if dims_s == "scalar" {
        vec![]
    } else {
        dims_s
            .split(',')
            .map(|d| d.parse::<usize>())
            .collect::<Result<Vec<_>, _>>()?
    };
    let name = it.next().unwrap_or("unnamed").to_string();
    Ok(IoSpec { index, dtype, dims, name })
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"
# bsa artifact manifest v1
[graph fwd_tiny]
file fwd_tiny.hlo.txt
kind fwd
tag tiny
n 256
batch 1
nparams 2
ball_size 64
cmp_block 8
group_size 8
top_k 4
in_features 6
out_features 1
input 0 f32 6,32 embed_w
input 1 f32 32 embed_b
input 2 f32 1,256,6 x
output 0 f32 1,256,1 pred

[graph init_tiny]
file init_tiny.hlo.txt
kind init
tag tiny
n 256
batch 1
nparams 2
ball_size 64
cmp_block 8
group_size 8
top_k 4
in_features 6
out_features 1
input 0 i32 scalar seed
output 0 f32 6,32 embed_w
output 1 f32 32 embed_b
"#;

    #[test]
    fn parses_graphs() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.len(), 2);
        let g = m.get("fwd_tiny").unwrap();
        assert_eq!(g.kind, GraphKind::Fwd);
        assert_eq!(g.n, 256);
        assert_eq!(g.inputs.len(), 3);
        assert_eq!(g.inputs[2].dims, vec![1, 256, 6]);
        assert_eq!(g.inputs[2].name, "x");
        assert_eq!(g.outputs[0].elements(), 256);
        let init = m.get("init_tiny").unwrap();
        assert_eq!(init.kind, GraphKind::Init);
        assert_eq!(init.inputs[0].dtype, DType::I32);
        assert!(init.inputs[0].dims.is_empty());
    }

    #[test]
    fn missing_graph_error_is_actionable() {
        let m = Manifest::parse(SAMPLE).unwrap();
        let err = m.get("nope").unwrap_err().to_string();
        assert!(err.contains("make artifacts"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Manifest::parse("input 0 f32 1 x\n").is_err()); // outside graph
        assert!(Manifest::parse("[graph g]\nkind whatever\n").is_err());
        assert!(Manifest::parse("[graph g]\nwat 3\n").is_err());
    }

    #[test]
    fn real_manifest_if_present() {
        // Integration: parse the checked-out artifacts manifest when built.
        let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/manifest.txt");
        if path.exists() {
            let m = Manifest::load(&path).unwrap();
            assert!(!m.is_empty());
            for name in m.names() {
                let g = m.get(name).unwrap();
                assert!(!g.file.is_empty());
                assert!(!g.inputs.is_empty());
                assert!(!g.outputs.is_empty());
            }
        }
    }
}
