//! Receptive-field analysis (paper Figure 2).
//!
//! Computes, for a query position in ball order, the set of input
//! positions each BSA branch can reach. Selection scores use the same
//! semantics as the compiled model — group-mean query · block-mean key
//! with the own-ball mask — over a deterministic random projection of the
//! point features (the *structure* of the receptive field, which is what
//! Figure 2 visualizes, does not depend on trained weights).

use crate::prng::Rng;
use crate::tensor::Tensor;

/// Sparse-attention geometry parameters for the analysis.
#[derive(Debug, Clone, Copy)]
pub struct RFieldParams {
    pub ball_size: usize,
    pub cmp_block: usize,
    pub group_size: usize,
    pub top_k: usize,
    pub proj_dim: usize,
    pub mask_own_ball: bool,
}

impl Default for RFieldParams {
    fn default() -> Self {
        RFieldParams {
            ball_size: 256,
            cmp_block: 8,
            group_size: 8,
            top_k: 4,
            proj_dim: 16,
            mask_own_ball: true,
        }
    }
}

/// Per-branch reach masks for one query position.
#[derive(Debug, Clone)]
pub struct RField {
    pub query_pos: usize,
    pub query_ball: usize,
    /// Ball branch: own ball only.
    pub ball: Vec<bool>,
    /// Ball + selection branches.
    pub select: Vec<bool>,
    /// Ball + selection + compression (global, coarse).
    pub compress: Vec<bool>,
    /// The selected block indices.
    pub selected_blocks: Vec<usize>,
}

impl RField {
    pub fn counts(&self) -> (usize, usize, usize) {
        let c = |v: &[bool]| v.iter().filter(|&&x| x).count();
        (c(&self.ball), c(&self.select), c(&self.compress))
    }
}

/// Compute receptive fields for `query_pos` over ball-ordered `feats`.
pub fn receptive_field(feats: &Tensor, query_pos: usize, p: RFieldParams, seed: u64) -> RField {
    let n = feats.rows();
    let f = feats.cols();
    let d = p.proj_dim;
    assert_eq!(n % p.ball_size, 0);
    assert_eq!(n % p.cmp_block, 0);
    let query_ball = query_pos / p.ball_size;
    let query_group = query_pos / p.group_size;

    // deterministic random projections (structure surrogate)
    let mut rng = Rng::new(seed ^ 0xF1E1D);
    let wq: Vec<f32> = rng.normals(f * d);
    let wk: Vec<f32> = rng.normals(f * d);
    let proj = |row: &[f32], w: &[f32]| -> Vec<f32> {
        (0..d)
            .map(|j| row.iter().enumerate().map(|(i, &x)| x * w[i * d + j]).sum())
            .collect()
    };

    // ball branch
    let mut ball = vec![false; n];
    for i in query_ball * p.ball_size..(query_ball + 1) * p.ball_size {
        ball[i] = true;
    }

    // selection scores: group-mean q · block-mean k
    let mut qg = vec![0.0f32; d];
    for pos in query_group * p.group_size..(query_group + 1) * p.group_size {
        for (j, v) in proj(feats.row(pos), &wq).iter().enumerate() {
            qg[j] += v / p.group_size as f32;
        }
    }
    let n_blocks = n / p.cmp_block;
    let mut scores = vec![f32::NEG_INFINITY; n_blocks];
    for b in 0..n_blocks {
        if p.mask_own_ball && (b * p.cmp_block) / p.ball_size == query_ball {
            continue;
        }
        let mut kc = vec![0.0f32; d];
        for pos in b * p.cmp_block..(b + 1) * p.cmp_block {
            for (j, v) in proj(feats.row(pos), &wk).iter().enumerate() {
                kc[j] += v / p.cmp_block as f32;
            }
        }
        scores[b] = qg.iter().zip(&kc).map(|(a, b)| a * b).sum();
    }
    let mut order: Vec<usize> = (0..n_blocks).collect();
    order.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
    let selected_blocks: Vec<usize> = order.into_iter().take(p.top_k).collect();

    let mut select = ball.clone();
    for &b in &selected_blocks {
        for i in b * p.cmp_block..(b + 1) * p.cmp_block {
            select[i] = true;
        }
    }

    RField {
        query_pos,
        query_ball,
        ball,
        select,
        compress: vec![true; n],
        selected_blocks,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn feats(n: usize) -> Tensor {
        let mut rng = Rng::new(3);
        Tensor::new(vec![n, 6], rng.normals(n * 6))
    }

    #[test]
    fn field_grows_monotonically() {
        // Figure 2's claim: ball < +selection < +compression.
        let p = RFieldParams { ball_size: 64, ..Default::default() };
        let rf = receptive_field(&feats(512), 100, p, 0);
        let (b, s, c) = rf.counts();
        assert_eq!(b, 64);
        assert_eq!(s, 64 + p.top_k * p.cmp_block);
        assert_eq!(c, 512);
        assert!(b < s && s < c);
    }

    #[test]
    fn mask_keeps_selection_outside_own_ball() {
        let p = RFieldParams { ball_size: 64, ..Default::default() };
        let rf = receptive_field(&feats(512), 100, p, 1);
        for &b in &rf.selected_blocks {
            assert_ne!((b * p.cmp_block) / p.ball_size, rf.query_ball);
        }
    }

    #[test]
    fn unmasked_selection_may_stay_local() {
        let p = RFieldParams { ball_size: 64, mask_own_ball: false, ..Default::default() };
        let rf = receptive_field(&feats(512), 100, p, 1);
        // no constraint violated; just confirm we get k blocks
        assert_eq!(rf.selected_blocks.len(), p.top_k);
    }

    #[test]
    fn deterministic_per_seed() {
        let p = RFieldParams { ball_size: 64, ..Default::default() };
        let a = receptive_field(&feats(256), 10, p, 7);
        let b = receptive_field(&feats(256), 10, p, 7);
        assert_eq!(a.selected_blocks, b.selected_blocks);
    }
}
