//! Pure-Rust BSA inference: the paper's forward pass with no PJRT, no
//! artifacts, no Python anywhere.
//!
//! The model is the trunk of `python/compile/model.py::bsa_forward` for
//! the paper-default variant (mean-pooling compression, group selection,
//! own-ball mask): `num_blocks` blocks of RMSNorm -> three-branch BSA
//! attention -> RMSNorm -> SwiGLU, between a linear embed and a linear
//! head. Batch and head dimensions are folded exactly like the jax side
//! (`_split_heads`), so every kernel in [`super::kernels`] sees the same
//! `(N, dh)` head-major operands the Pallas/ref kernels see — which is
//! what makes this backend a usable parity oracle for the compiled HLO.
//!
//! Compute is thread-parallel via [`super::pool`]'s persistent worker
//! pool on two axes. The projections and MLP GEMMs split output rows
//! across threads. Attention is **head-parallel**: the per-(batch, head)
//! units of the attention step are independent — each reads its own
//! column slice of the Q/K/V projections and writes its own `(N, dh)`
//! block of a head-major staging buffer — so the units are dispatched
//! as pool jobs with per-thread `HeadScratch` buffers, and
//! a pure reordering pass folds the head-major blocks back into
//! token-major `(B*N, C)` rows before the output projection. When the
//! thread budget exceeds the unit count, the leftover budget goes to the
//! kernels *inside* each unit (nested dispatches are deadlock-free: the
//! pool's waiters run queued jobs instead of blocking).
//!
//! The thread count comes from [`NativeBackend::with_threads`] /
//! `ServeConfig::native_threads`, with the `BSA_NATIVE_THREADS` env var
//! as the zero-config override (see [`pool::resolve_threads`]). The
//! kernels' inner loops run on the [`super::simd`] microkernel layer
//! (AVX2/NEON via runtime detection, `BSA_NATIVE_SIMD=off` to force the
//! scalar loops — see `simd`'s docs for the 1e-5 twin rule). Every
//! kernel computes a given output row identically regardless of which
//! chunk or worker it lands in, and the gated head merge is a
//! fixed-order per-element expression, so the forward pass is
//! **bitwise deterministic across thread counts** at any fixed SIMD
//! level — asserted by `rust/tests/conformance.rs`; with SIMD off it is
//! additionally bitwise equal to the scalar `*_reference` composition
//! (`rust/tests/simd_off.rs`).
//!
//! Scratch buffers are allocated once per `forward` call and reused
//! across blocks (plus one `HeadScratch` per pool chunk inside the
//! head-parallel dispatch); per-call cost is a handful of `Vec`s, far
//! below the matmul work itself.

use crate::config::ModelConfig;
use crate::tensor::Tensor;

use super::kernels;
use super::linalg;
use super::params::{BlockParams, NativeParams};
use super::pool;
use super::{Backend, BackendSpec};

/// Sparse-attention hyperparameters the forward pass needs at run time
/// (the *architecture* dims — width, heads, depth — live in the params).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AttnHyper {
    /// Ball size m (clamped to N at construction, like aot.py).
    pub ball_size: usize,
    /// Compression block l (= selection block and stride, Table 4).
    pub cmp_block: usize,
    /// Selection group size g.
    pub group_size: usize,
    /// Number of selected blocks k*.
    pub top_k: usize,
}

impl AttnHyper {
    /// From the shared typed config (paper Table 4 defaults).
    pub fn from_model(mc: &ModelConfig) -> AttnHyper {
        AttnHyper {
            ball_size: mc.ball_size,
            cmp_block: mc.cmp_block,
            group_size: mc.group_size,
            top_k: mc.top_k,
        }
    }

    /// From a compiled graph's manifest entry (parity testing).
    pub fn from_graph(info: &crate::runtime::GraphInfo) -> AttnHyper {
        AttnHyper {
            ball_size: info.ball_size,
            cmp_block: info.cmp_block,
            group_size: info.group_size,
            top_k: info.top_k,
        }
    }
}

/// The native CPU backend: BSA parameters + sparse hyperparameters +
/// the static `(batch, n)` serving shape + kernel thread budget.
pub struct NativeBackend {
    params: NativeParams,
    hyper: AttnHyper,
    spec: BackendSpec,
    /// Resolved kernel thread count (>= 1); see [`Self::with_threads`].
    threads: usize,
}

impl NativeBackend {
    /// Build from explicit parameters. `n` is the serving sequence
    /// length (requests are ball-tree padded to it), `batch` the batch
    /// size a single `forward` consumes. The ball size is clamped to
    /// `n` exactly like aot.py clamps it at lowering. Kernel threads
    /// default to the `BSA_NATIVE_THREADS` env var or the machine's
    /// available parallelism; override with [`Self::with_threads`].
    pub fn new(
        params: NativeParams,
        mut hyper: AttnHyper,
        n: usize,
        batch: usize,
    ) -> anyhow::Result<NativeBackend> {
        params.validate()?;
        hyper.ball_size = hyper.ball_size.min(n);
        anyhow::ensure!(batch > 0 && n > 0, "batch and n must be positive");
        anyhow::ensure!(n % hyper.ball_size == 0, "N {n} % ball {} != 0", hyper.ball_size);
        anyhow::ensure!(
            hyper.ball_size % hyper.cmp_block == 0 && hyper.ball_size % hyper.group_size == 0,
            "ball size {} must be divisible by cmp block {} and group {}",
            hyper.ball_size,
            hyper.cmp_block,
            hyper.group_size
        );
        anyhow::ensure!(
            hyper.top_k <= n / hyper.cmp_block,
            "top_k {} exceeds block count {}",
            hyper.top_k,
            n / hyper.cmp_block
        );
        let spec = BackendSpec {
            name: format!("native:bsa_n{n}_b{batch}"),
            n,
            batch,
            in_features: params.in_features(),
            out_features: params.out_features(),
        };
        Ok(NativeBackend { params, hyper, spec, threads: pool::resolve_threads(0) })
    }

    /// Set the kernel thread budget: `threads > 0` pins the count, `0`
    /// re-resolves from `BSA_NATIVE_THREADS` / hardware parallelism.
    /// Outputs are bitwise identical for every setting (the parallel
    /// kernels are order-preserving); this only trades latency for CPU.
    pub fn with_threads(mut self, threads: usize) -> NativeBackend {
        self.threads = pool::resolve_threads(threads);
        self
    }

    /// The resolved kernel thread count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Deterministic random-weight backend (smoke tests, latency benches,
    /// artifact-free serving — mirrors serving a `init_<tag>` graph).
    pub fn init(
        seed: u64,
        mc: &ModelConfig,
        in_features: usize,
        out_features: usize,
        batch: usize,
    ) -> anyhow::Result<NativeBackend> {
        let params = NativeParams::init(
            seed,
            in_features,
            out_features,
            mc.dim,
            mc.num_heads,
            mc.num_blocks,
            4, // SwiGLU expansion (model.py mlp_ratio default)
        );
        Self::new(params, AttnHyper::from_model(mc), mc.seq_len, batch)
    }

    /// Load weights from a `.bsackpt` param file or training checkpoint
    /// (see the module docs in [`super`] for the format).
    pub fn load(
        path: &std::path::Path,
        hyper: AttnHyper,
        n: usize,
        batch: usize,
    ) -> anyhow::Result<NativeBackend> {
        Self::new(NativeParams::load(path)?, hyper, n, batch)
    }

    /// Build from the flat parameter list + manifest input names of a
    /// compiled graph (the parity-oracle path: identical weights on both
    /// backends).
    pub fn from_flat(
        params: Vec<Tensor>,
        names: &[String],
        hyper: AttnHyper,
        n: usize,
        batch: usize,
    ) -> anyhow::Result<NativeBackend> {
        anyhow::ensure!(
            params.len() == names.len(),
            "{} params but {} names",
            params.len(),
            names.len()
        );
        let named = names.iter().cloned().zip(params).collect();
        Self::new(NativeParams::from_named(named)?, hyper, n, batch)
    }

    /// The loaded parameters (read-only).
    pub fn params(&self) -> &NativeParams {
        &self.params
    }

    /// Sparse hyperparameters in effect (ball size already clamped).
    pub fn hyper(&self) -> &AttnHyper {
        &self.hyper
    }

    /// Three-branch BSA attention for one block (paper Sec. 2.2),
    /// **head-parallel**. `a` is the RMS-normed input `(B*N, C)` flat.
    ///
    /// The `B * H` (batch, head) units are independent: each gathers its
    /// own `(N, dh)` column slice of the Q/K/V projections, runs the
    /// three branches, and writes its gated merge (eq. 9) into its own
    /// `(N, dh)` block of the head-major staging buffer `merged_hm`
    /// (layout `(B, H, N, dh)`). The units are dispatched over the
    /// worker pool with one `HeadScratch` per chunk; a reordering pass
    /// then folds `merged_hm` back to token-major `(B*N, C)` `merged`
    /// rows, which `wo` projects into `out`.
    ///
    /// Bitwise determinism: unit outputs land in disjoint buffers, the
    /// fold is a pure copy, and the kernels inside a unit are themselves
    /// bitwise thread-count-invariant — so this function's output is
    /// identical for every thread budget (at whatever SIMD level the
    /// process resolved; see [`super::simd`]). When `threads > units`,
    /// the surplus is handed to the kernels inside each unit (`inner`
    /// below); the pool's help-while-waiting latch makes that nesting
    /// safe.
    fn attention(&self, blk: &BlockParams, a: &[f32], out: &mut [f32], s: &mut Scratch) {
        let (b, n) = (self.spec.batch, self.spec.n);
        let c = self.params.dim();
        let h_cnt = self.params.num_heads();
        let dh = c / h_cnt;
        let m = self.hyper.ball_size;
        let l = self.hyper.cmp_block;
        let g = self.hyper.group_size;
        let top_k = self.hyper.top_k;
        let nb = n / l;
        let groups = n / g;
        let rows = b * n;
        let scale = 1.0 / (dh as f32).sqrt();
        let th = self.threads;

        linalg::matmul(a, blk.attn.wq.data(), rows, c, c, th, &mut s.q);
        linalg::matmul(a, blk.attn.wk.data(), rows, c, c, th, &mut s.k);
        linalg::matmul(a, blk.attn.wv.data(), rows, c, c, th, &mut s.v);
        linalg::matmul(a, blk.attn.wg.data(), rows, c, 3 * h_cnt, th, &mut s.gates);

        let units = b * h_cnt;
        // Surplus thread budget (th > units) flows to the kernels inside
        // the units: the first `th % units` units get one extra nested
        // thread, so summed concurrency equals the budget exactly —
        // neither idle threads (floor) nor oversubscription (ceil).
        // Which unit gets the surplus is fixed by unit index, and thread
        // counts never affect numerics, so this is bitwise-neutral.
        let inner_base = th / units;
        let inner_extra = th % units;
        let Scratch { q, k, v, gates, merged, merged_hm, head_scratch } = s;
        let (q, k, v, gates) = (&q[..], &k[..], &v[..], &gates[..]);

        // Free-list of HeadScratch instances shared by the chunks and
        // reused across blocks (and the whole forward): each chunk pops
        // one (allocating only on first use), works through its units,
        // and returns it — two uncontended lock ops per chunk instead of
        // hundreds of KB of fresh zeroed Vecs per chunk per block.
        let scratch_pool = std::sync::Mutex::new(std::mem::take(head_scratch));
        pool::par_rows(&mut merged_hm[..], n * dh, th, |u0, hchunk| {
            let mut hs = scratch_pool
                .lock()
                .unwrap()
                .pop()
                .unwrap_or_else(|| HeadScratch::new(n, dh, nb, groups));
            for (ui, ublock) in hchunk.chunks_exact_mut(n * dh).enumerate() {
                let u = u0 + ui;
                let (bi, hd) = (u / h_cnt, u % h_cnt);
                let inner = (inner_base + usize::from(u < inner_extra)).max(1);
                // split heads: column slice hd*dh.. of this batch item
                let col0 = hd * dh;
                for t in 0..n {
                    let src = (bi * n + t) * c + col0;
                    hs.qs[t * dh..(t + 1) * dh].copy_from_slice(&q[src..src + dh]);
                    hs.ks[t * dh..(t + 1) * dh].copy_from_slice(&k[src..src + dh]);
                    hs.vs[t * dh..(t + 1) * dh].copy_from_slice(&v[src..src + dh]);
                }

                // ball branch (eq. 3)
                kernels::ball_attention(&hs.qs, &hs.ks, &hs.vs, n, dh, m, inner, &mut hs.o_ball);

                // compression branch (eq. 5): mean phi + dense attention
                kernels::compress_mean(&hs.ks, n, dh, l, inner, &mut hs.kc);
                kernels::compress_mean(&hs.vs, n, dh, l, inner, &mut hs.vc);
                kernels::attend(
                    &hs.qs, &hs.kc, &hs.vc, n, nb, dh, scale, inner, &mut hs.o_cmp,
                    &mut hs.scores,
                );

                // selection branch (eqs. 6-8, 10-12): grouped top-k over
                // compressed keys, own-ball blocks masked out
                kernels::group_scores(
                    &hs.qs, &hs.kc, n, dh, g, nb, inner, &mut hs.qg, &mut hs.gscores,
                );
                kernels::mask_own_ball(&mut hs.gscores, groups, nb, g, l, m);
                kernels::topk_indices(&hs.gscores, groups, nb, top_k, inner, &mut hs.idx);
                kernels::select_attention(
                    &hs.qs, &hs.ks, &hs.vs, &hs.idx, n, dh, l, g, top_k, inner, &mut hs.o_slc,
                );

                // gated fusion (eq. 9): per-token per-head sigmoid gates,
                // written into this unit's own (N, dh) block
                for t in 0..n {
                    let grow = (bi * n + t) * 3 * h_cnt;
                    let gb = linalg::sigmoid(gates[grow + hd]);
                    let gc = linalg::sigmoid(gates[grow + h_cnt + hd]);
                    let gs = linalg::sigmoid(gates[grow + 2 * h_cnt + hd]);
                    let dst = t * dh;
                    for d0 in 0..dh {
                        ublock[dst + d0] = gb * hs.o_ball[dst + d0]
                            + gc * hs.o_cmp[dst + d0]
                            + gs * hs.o_slc[dst + d0];
                    }
                }
            }
            scratch_pool.lock().unwrap().push(hs);
        });
        *head_scratch = scratch_pool.into_inner().unwrap();

        // fold heads: (B, H, N, dh) head-major -> (B*N, C) token-major
        // (pure copy, so bitwise-neutral; row-parallel over tokens)
        let merged_hm = &merged_hm[..];
        pool::par_rows(&mut merged[..], c, th, |row0, ochunk| {
            for (ri, orow) in ochunk.chunks_exact_mut(c).enumerate() {
                let r = row0 + ri;
                let (bi, t) = (r / n, r % n);
                for hd in 0..h_cnt {
                    let src = ((bi * h_cnt + hd) * n + t) * dh;
                    orow[hd * dh..(hd + 1) * dh].copy_from_slice(&merged_hm[src..src + dh]);
                }
            }
        });
        linalg::matmul(&merged[..], blk.attn.wo.data(), rows, c, c, th, out);
    }
}

/// Per-forward scratch buffers (sized once, reused across blocks; the
/// per-(batch, head) attention scratch lives in `HeadScratch`, one per
/// pool chunk).
struct Scratch {
    // (B*N, C) projections
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    gates: Vec<f32>,
    /// Token-major (B*N, C) gated merge, input to the `wo` projection.
    merged: Vec<f32>,
    /// Head-major (B, H, N, dh) staging buffer the parallel units write
    /// into (disjoint (N, dh) blocks, one per unit).
    merged_hm: Vec<f32>,
    /// Free-list of per-chunk attention scratch, grown lazily to the
    /// peak concurrent chunk count and reused across blocks.
    head_scratch: Vec<HeadScratch>,
}

impl Scratch {
    fn new(rows: usize, c: usize, h_cnt: usize) -> Scratch {
        Scratch {
            q: vec![0.0; rows * c],
            k: vec![0.0; rows * c],
            v: vec![0.0; rows * c],
            gates: vec![0.0; rows * 3 * h_cnt],
            merged: vec![0.0; rows * c],
            merged_hm: vec![0.0; rows * c],
            head_scratch: Vec::new(),
        }
    }
}

/// Scratch for one (batch, head) attention unit: the `(N, dh)` operand
/// gathers, the three branch outputs, and the compression/selection
/// intermediates. One instance per pool chunk ("per-thread head
/// scratch"), reused across the units in that chunk.
struct HeadScratch {
    qs: Vec<f32>,
    ks: Vec<f32>,
    vs: Vec<f32>,
    o_ball: Vec<f32>,
    o_cmp: Vec<f32>,
    o_slc: Vec<f32>,
    kc: Vec<f32>,
    vc: Vec<f32>,
    qg: Vec<f32>,
    gscores: Vec<f32>,
    idx: Vec<usize>,
    scores: Vec<f32>,
}

impl HeadScratch {
    fn new(n: usize, dh: usize, nb: usize, groups: usize) -> HeadScratch {
        HeadScratch {
            qs: vec![0.0; n * dh],
            ks: vec![0.0; n * dh],
            vs: vec![0.0; n * dh],
            o_ball: vec![0.0; n * dh],
            o_cmp: vec![0.0; n * dh],
            o_slc: vec![0.0; n * dh],
            kc: vec![0.0; nb * dh],
            vc: vec![0.0; nb * dh],
            qg: Vec::new(),
            gscores: vec![0.0; groups * nb],
            idx: Vec::new(),
            scores: Vec::new(),
        }
    }
}

impl Backend for NativeBackend {
    fn spec(&self) -> &BackendSpec {
        &self.spec
    }

    fn forward(&self, x: &Tensor) -> anyhow::Result<Tensor> {
        let spec = &self.spec;
        anyhow::ensure!(
            x.shape() == [spec.batch, spec.n, spec.in_features],
            "input shape {:?} != backend ({}, {}, {})",
            x.shape(),
            spec.batch,
            spec.n,
            spec.in_features
        );
        let (b, n) = (spec.batch, spec.n);
        let c = self.params.dim();
        let h_cnt = self.params.num_heads();
        let rows = b * n;
        let th = self.threads;
        let mut s = Scratch::new(rows, c, h_cnt);

        // embed
        let mut h = vec![0.0f32; rows * c];
        linalg::matmul(x.data(), self.params.embed_w.data(), rows, spec.in_features, c, th, &mut h);
        linalg::add_bias(&mut h, self.params.embed_b.data(), rows, c);

        // trunk
        let hid = self.params.blocks[0].mlp.w1.cols();
        let mut norm = vec![0.0f32; rows * c];
        let mut branch = vec![0.0f32; rows * c];
        let mut h1 = vec![0.0f32; rows * hid];
        let mut h3 = vec![0.0f32; rows * hid];
        for blk in &self.params.blocks {
            // x = x + attn(rms_norm(x))
            linalg::rms_norm(&h, blk.norm1.data(), rows, c, th, &mut norm);
            self.attention(blk, &norm, &mut branch, &mut s);
            for (hv, &av) in h.iter_mut().zip(&branch) {
                *hv += av;
            }
            // x = x + swiglu(rms_norm(x))
            linalg::rms_norm(&h, blk.norm2.data(), rows, c, th, &mut norm);
            linalg::matmul(&norm, blk.mlp.w1.data(), rows, c, hid, th, &mut h1);
            linalg::matmul(&norm, blk.mlp.w3.data(), rows, c, hid, th, &mut h3);
            for (a, &g) in h1.iter_mut().zip(&h3) {
                *a = linalg::silu(*a) * g;
            }
            linalg::matmul(&h1, blk.mlp.w2.data(), rows, hid, c, th, &mut branch);
            for (hv, &mv) in h.iter_mut().zip(&branch) {
                *hv += mv;
            }
        }

        // head
        linalg::rms_norm(&h, self.params.norm_out.data(), rows, c, th, &mut norm);
        let of = spec.out_features;
        let mut out = vec![0.0f32; rows * of];
        linalg::matmul(&norm, self.params.head_w.data(), rows, c, of, th, &mut out);
        linalg::add_bias(&mut out, self.params.head_b.data(), rows, of);
        Ok(Tensor::new(vec![b, n, of], out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn tiny_backend(seed: u64) -> NativeBackend {
        let mc = ModelConfig {
            dim: 32,
            num_heads: 2,
            num_blocks: 2,
            ball_size: 64,
            seq_len: 256,
            ..Default::default()
        };
        NativeBackend::init(seed, &mc, 6, 1, 1).unwrap()
    }

    fn input(n: usize, f: usize, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        Tensor::new(vec![1, n, f], rng.normals(n * f))
    }

    #[test]
    fn forward_shape_and_finiteness() {
        let be = tiny_backend(0);
        assert_eq!(be.spec().n, 256);
        assert_eq!(be.spec().in_features, 6);
        let out = be.forward(&input(256, 6, 1)).unwrap();
        assert_eq!(out.shape(), &[1, 256, 1]);
        assert!(out.all_finite());
        assert!(out.std() > 0.0, "degenerate constant output");
    }

    #[test]
    fn forward_rejects_wrong_shape() {
        let be = tiny_backend(0);
        assert!(be.forward(&Tensor::zeros(vec![1, 128, 6])).is_err());
        assert!(be.forward(&Tensor::zeros(vec![1, 256, 5])).is_err());
        assert!(be.forward(&Tensor::zeros(vec![2, 256, 6])).is_err());
    }

    #[test]
    fn forward_deterministic_and_seed_sensitive() {
        let x = input(256, 6, 2);
        let a = tiny_backend(7).forward(&x).unwrap();
        let b = tiny_backend(7).forward(&x).unwrap();
        assert_eq!(a, b, "same seed, same input => bit-identical output");
        let c = tiny_backend(8).forward(&x).unwrap();
        assert_ne!(a, c, "different seed must change the function");
    }

    #[test]
    fn forward_bitwise_stable_across_thread_counts() {
        // The load-bearing property of the parallel kernels: the thread
        // budget is a pure latency knob, never a numerics knob.
        let x = input(256, 6, 4);
        let base = tiny_backend(5).with_threads(1).forward(&x).unwrap();
        for t in [2usize, 3, 8] {
            let out = tiny_backend(5).with_threads(t).forward(&x).unwrap();
            assert_eq!(base, out, "threads={t} changed the output");
        }
    }

    #[test]
    fn with_threads_resolves_and_caps() {
        let be = tiny_backend(0).with_threads(3);
        assert_eq!(be.threads(), 3);
        let be = be.with_threads(100_000);
        assert_eq!(be.threads(), pool::MAX_THREADS);
        assert!(tiny_backend(0).threads() >= 1, "auto-resolve is positive");
    }

    #[test]
    fn ball_size_clamped_to_n() {
        // paper config at small N: ball 256 > N 64 clamps like aot.py
        let mc = ModelConfig { seq_len: 64, num_blocks: 1, ..Default::default() };
        let be = NativeBackend::init(0, &mc, 6, 1, 1).unwrap();
        assert_eq!(be.hyper().ball_size, 64);
        let out = be.forward(&input(64, 6, 3)).unwrap();
        assert!(out.all_finite());
    }

    #[test]
    fn rejects_invalid_hyper() {
        let params = NativeParams::init(0, 6, 1, 32, 2, 1, 4);
        // group 12 does not divide ball 64
        let hyper = AttnHyper { ball_size: 64, cmp_block: 8, group_size: 12, top_k: 4 };
        assert!(NativeBackend::new(params, hyper, 256, 1).is_err());
    }
}
