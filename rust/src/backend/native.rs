//! Pure-Rust BSA inference: the paper's forward pass with no PJRT, no
//! artifacts, no Python anywhere.
//!
//! The model is the trunk of `python/compile/model.py::bsa_forward` for
//! the paper-default variant (mean-pooling compression, group selection,
//! own-ball mask): `num_blocks` blocks of RMSNorm -> three-branch BSA
//! attention -> RMSNorm -> SwiGLU, between a linear embed and a linear
//! head. Batch and head dimensions are folded exactly like the jax side
//! (`_split_heads`), so every kernel in [`super::kernels`] sees the same
//! `(N, dh)` head-major operands the Pallas/ref kernels see — which is
//! what makes this backend a usable parity oracle for the compiled HLO.
//!
//! Compute is thread-parallel via [`super::pool`]: the projections and
//! MLP GEMMs split output rows across threads, ball attention splits
//! balls, compression splits blocks, selection/top-k split groups. The
//! thread count comes from [`NativeBackend::with_threads`] /
//! `ServeConfig::native_threads`, with the `BSA_NATIVE_THREADS` env var
//! as the zero-config override (see [`pool::resolve_threads`]). All
//! parallel kernels are bitwise equal to their `*_reference` twins, so
//! the forward pass is deterministic across thread counts — asserted by
//! `rust/tests/conformance.rs`.
//!
//! Scratch buffers are allocated once per `forward` call and reused
//! across blocks and heads (plus small per-thread gather buffers inside
//! the parallel kernels); per-call cost is a handful of `Vec`s, far
//! below the matmul work itself.

use crate::config::ModelConfig;
use crate::tensor::Tensor;

use super::kernels;
use super::linalg;
use super::params::{BlockParams, NativeParams};
use super::pool;
use super::{Backend, BackendSpec};

/// Sparse-attention hyperparameters the forward pass needs at run time
/// (the *architecture* dims — width, heads, depth — live in the params).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AttnHyper {
    /// Ball size m (clamped to N at construction, like aot.py).
    pub ball_size: usize,
    /// Compression block l (= selection block and stride, Table 4).
    pub cmp_block: usize,
    /// Selection group size g.
    pub group_size: usize,
    /// Number of selected blocks k*.
    pub top_k: usize,
}

impl AttnHyper {
    /// From the shared typed config (paper Table 4 defaults).
    pub fn from_model(mc: &ModelConfig) -> AttnHyper {
        AttnHyper {
            ball_size: mc.ball_size,
            cmp_block: mc.cmp_block,
            group_size: mc.group_size,
            top_k: mc.top_k,
        }
    }

    /// From a compiled graph's manifest entry (parity testing).
    pub fn from_graph(info: &crate::runtime::GraphInfo) -> AttnHyper {
        AttnHyper {
            ball_size: info.ball_size,
            cmp_block: info.cmp_block,
            group_size: info.group_size,
            top_k: info.top_k,
        }
    }
}

/// The native CPU backend: BSA parameters + sparse hyperparameters +
/// the static `(batch, n)` serving shape + kernel thread budget.
pub struct NativeBackend {
    params: NativeParams,
    hyper: AttnHyper,
    spec: BackendSpec,
    /// Resolved kernel thread count (>= 1); see [`Self::with_threads`].
    threads: usize,
}

impl NativeBackend {
    /// Build from explicit parameters. `n` is the serving sequence
    /// length (requests are ball-tree padded to it), `batch` the batch
    /// size a single `forward` consumes. The ball size is clamped to
    /// `n` exactly like aot.py clamps it at lowering. Kernel threads
    /// default to the `BSA_NATIVE_THREADS` env var or the machine's
    /// available parallelism; override with [`Self::with_threads`].
    pub fn new(
        params: NativeParams,
        mut hyper: AttnHyper,
        n: usize,
        batch: usize,
    ) -> anyhow::Result<NativeBackend> {
        params.validate()?;
        hyper.ball_size = hyper.ball_size.min(n);
        anyhow::ensure!(batch > 0 && n > 0, "batch and n must be positive");
        anyhow::ensure!(n % hyper.ball_size == 0, "N {n} % ball {} != 0", hyper.ball_size);
        anyhow::ensure!(
            hyper.ball_size % hyper.cmp_block == 0 && hyper.ball_size % hyper.group_size == 0,
            "ball size {} must be divisible by cmp block {} and group {}",
            hyper.ball_size,
            hyper.cmp_block,
            hyper.group_size
        );
        anyhow::ensure!(
            hyper.top_k <= n / hyper.cmp_block,
            "top_k {} exceeds block count {}",
            hyper.top_k,
            n / hyper.cmp_block
        );
        let spec = BackendSpec {
            name: format!("native:bsa_n{n}_b{batch}"),
            n,
            batch,
            in_features: params.in_features(),
            out_features: params.out_features(),
        };
        Ok(NativeBackend { params, hyper, spec, threads: pool::resolve_threads(0) })
    }

    /// Set the kernel thread budget: `threads > 0` pins the count, `0`
    /// re-resolves from `BSA_NATIVE_THREADS` / hardware parallelism.
    /// Outputs are bitwise identical for every setting (the parallel
    /// kernels are order-preserving); this only trades latency for CPU.
    pub fn with_threads(mut self, threads: usize) -> NativeBackend {
        self.threads = pool::resolve_threads(threads);
        self
    }

    /// The resolved kernel thread count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Deterministic random-weight backend (smoke tests, latency benches,
    /// artifact-free serving — mirrors serving a `init_<tag>` graph).
    pub fn init(
        seed: u64,
        mc: &ModelConfig,
        in_features: usize,
        out_features: usize,
        batch: usize,
    ) -> anyhow::Result<NativeBackend> {
        let params = NativeParams::init(
            seed,
            in_features,
            out_features,
            mc.dim,
            mc.num_heads,
            mc.num_blocks,
            4, // SwiGLU expansion (model.py mlp_ratio default)
        );
        Self::new(params, AttnHyper::from_model(mc), mc.seq_len, batch)
    }

    /// Load weights from a `.bsackpt` param file or training checkpoint
    /// (see the module docs in [`super`] for the format).
    pub fn load(
        path: &std::path::Path,
        hyper: AttnHyper,
        n: usize,
        batch: usize,
    ) -> anyhow::Result<NativeBackend> {
        Self::new(NativeParams::load(path)?, hyper, n, batch)
    }

    /// Build from the flat parameter list + manifest input names of a
    /// compiled graph (the parity-oracle path: identical weights on both
    /// backends).
    pub fn from_flat(
        params: Vec<Tensor>,
        names: &[String],
        hyper: AttnHyper,
        n: usize,
        batch: usize,
    ) -> anyhow::Result<NativeBackend> {
        anyhow::ensure!(
            params.len() == names.len(),
            "{} params but {} names",
            params.len(),
            names.len()
        );
        let named = names.iter().cloned().zip(params).collect();
        Self::new(NativeParams::from_named(named)?, hyper, n, batch)
    }

    /// The loaded parameters (read-only).
    pub fn params(&self) -> &NativeParams {
        &self.params
    }

    /// Sparse hyperparameters in effect (ball size already clamped).
    pub fn hyper(&self) -> &AttnHyper {
        &self.hyper
    }

    /// Three-branch BSA attention for one block (paper Sec. 2.2), heads
    /// folded. `a` is the RMS-normed input `(B*N, C)` flat; the gated
    /// merged result (pre-`wo`) is accumulated per head into `merged`,
    /// then projected into `out`.
    #[allow(clippy::too_many_arguments)]
    fn attention(&self, blk: &BlockParams, a: &[f32], out: &mut [f32], s: &mut Scratch) {
        let (b, n) = (self.spec.batch, self.spec.n);
        let c = self.params.dim();
        let h_cnt = self.params.num_heads();
        let dh = c / h_cnt;
        let m = self.hyper.ball_size;
        let l = self.hyper.cmp_block;
        let g = self.hyper.group_size;
        let top_k = self.hyper.top_k;
        let nb = n / l;
        let groups = n / g;
        let rows = b * n;
        let scale = 1.0 / (dh as f32).sqrt();
        let th = self.threads;

        linalg::matmul(a, blk.attn.wq.data(), rows, c, c, th, &mut s.q);
        linalg::matmul(a, blk.attn.wk.data(), rows, c, c, th, &mut s.k);
        linalg::matmul(a, blk.attn.wv.data(), rows, c, c, th, &mut s.v);
        linalg::matmul(a, blk.attn.wg.data(), rows, c, 3 * h_cnt, th, &mut s.gates);

        for bi in 0..b {
            for hd in 0..h_cnt {
                // split heads: column slice hd*dh.. of this batch item
                let col0 = hd * dh;
                for t in 0..n {
                    let src = (bi * n + t) * c + col0;
                    s.qs[t * dh..(t + 1) * dh].copy_from_slice(&s.q[src..src + dh]);
                    s.ks[t * dh..(t + 1) * dh].copy_from_slice(&s.k[src..src + dh]);
                    s.vs[t * dh..(t + 1) * dh].copy_from_slice(&s.v[src..src + dh]);
                }

                // ball branch (eq. 3): one ball batch per thread chunk
                kernels::ball_attention(&s.qs, &s.ks, &s.vs, n, dh, m, th, &mut s.o_ball);

                // compression branch (eq. 5): mean phi + dense attention
                kernels::compress_mean(&s.ks, n, dh, l, th, &mut s.kc);
                kernels::compress_mean(&s.vs, n, dh, l, th, &mut s.vc);
                kernels::attend(&s.qs, &s.kc, &s.vc, n, nb, dh, scale, th, &mut s.o_cmp, &mut s.scores);

                // selection branch (eqs. 6-8, 10-12): grouped top-k over
                // compressed keys, own-ball blocks masked out
                kernels::group_scores(&s.qs, &s.kc, n, dh, g, nb, th, &mut s.qg, &mut s.gscores);
                kernels::mask_own_ball(&mut s.gscores, groups, nb, g, l, m);
                kernels::topk_indices(&s.gscores, groups, nb, top_k, th, &mut s.idx);
                kernels::select_attention(
                    &s.qs, &s.ks, &s.vs, &s.idx, n, dh, l, g, top_k, th, &mut s.o_slc,
                );

                // gated fusion (eq. 9): per-token per-head sigmoid gates,
                // written into this head's column slice of `merged`
                for t in 0..n {
                    let row = bi * n + t;
                    let grow = row * 3 * h_cnt;
                    let gb = linalg::sigmoid(s.gates[grow + hd]);
                    let gc = linalg::sigmoid(s.gates[grow + h_cnt + hd]);
                    let gs = linalg::sigmoid(s.gates[grow + 2 * h_cnt + hd]);
                    let dst = row * c + col0;
                    for d0 in 0..dh {
                        s.merged[dst + d0] = gb * s.o_ball[t * dh + d0]
                            + gc * s.o_cmp[t * dh + d0]
                            + gs * s.o_slc[t * dh + d0];
                    }
                }
            }
        }
        linalg::matmul(&s.merged, blk.attn.wo.data(), rows, c, c, th, out);
    }
}

/// Per-forward scratch buffers (sized once, reused across blocks/heads;
/// the parallel kernels' per-thread gather buffers live inside the
/// kernels themselves).
struct Scratch {
    // (B*N, C) projections
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    gates: Vec<f32>,
    merged: Vec<f32>,
    // per-head (N, dh) operands and branch outputs
    qs: Vec<f32>,
    ks: Vec<f32>,
    vs: Vec<f32>,
    o_ball: Vec<f32>,
    o_cmp: Vec<f32>,
    o_slc: Vec<f32>,
    // compression / selection intermediates
    kc: Vec<f32>,
    vc: Vec<f32>,
    qg: Vec<f32>,
    gscores: Vec<f32>,
    idx: Vec<usize>,
    scores: Vec<f32>,
}

impl Scratch {
    fn new(rows: usize, c: usize, n: usize, dh: usize, nb: usize, groups: usize, h_cnt: usize) -> Scratch {
        Scratch {
            q: vec![0.0; rows * c],
            k: vec![0.0; rows * c],
            v: vec![0.0; rows * c],
            gates: vec![0.0; rows * 3 * h_cnt],
            merged: vec![0.0; rows * c],
            qs: vec![0.0; n * dh],
            ks: vec![0.0; n * dh],
            vs: vec![0.0; n * dh],
            o_ball: vec![0.0; n * dh],
            o_cmp: vec![0.0; n * dh],
            o_slc: vec![0.0; n * dh],
            kc: vec![0.0; nb * dh],
            vc: vec![0.0; nb * dh],
            qg: Vec::new(),
            gscores: vec![0.0; groups * nb],
            idx: Vec::new(),
            scores: Vec::new(),
        }
    }
}

impl Backend for NativeBackend {
    fn spec(&self) -> &BackendSpec {
        &self.spec
    }

    fn forward(&self, x: &Tensor) -> anyhow::Result<Tensor> {
        let spec = &self.spec;
        anyhow::ensure!(
            x.shape() == [spec.batch, spec.n, spec.in_features],
            "input shape {:?} != backend ({}, {}, {})",
            x.shape(),
            spec.batch,
            spec.n,
            spec.in_features
        );
        let (b, n) = (spec.batch, spec.n);
        let c = self.params.dim();
        let h_cnt = self.params.num_heads();
        let dh = c / h_cnt;
        let rows = b * n;
        let nb = n / self.hyper.cmp_block;
        let groups = n / self.hyper.group_size;
        let th = self.threads;
        let mut s = Scratch::new(rows, c, n, dh, nb, groups, h_cnt);

        // embed
        let mut h = vec![0.0f32; rows * c];
        linalg::matmul(x.data(), self.params.embed_w.data(), rows, spec.in_features, c, th, &mut h);
        linalg::add_bias(&mut h, self.params.embed_b.data(), rows, c);

        // trunk
        let hid = self.params.blocks[0].mlp.w1.cols();
        let mut norm = vec![0.0f32; rows * c];
        let mut branch = vec![0.0f32; rows * c];
        let mut h1 = vec![0.0f32; rows * hid];
        let mut h3 = vec![0.0f32; rows * hid];
        for blk in &self.params.blocks {
            // x = x + attn(rms_norm(x))
            linalg::rms_norm(&h, blk.norm1.data(), rows, c, th, &mut norm);
            self.attention(blk, &norm, &mut branch, &mut s);
            for (hv, &av) in h.iter_mut().zip(&branch) {
                *hv += av;
            }
            // x = x + swiglu(rms_norm(x))
            linalg::rms_norm(&h, blk.norm2.data(), rows, c, th, &mut norm);
            linalg::matmul(&norm, blk.mlp.w1.data(), rows, c, hid, th, &mut h1);
            linalg::matmul(&norm, blk.mlp.w3.data(), rows, c, hid, th, &mut h3);
            for (a, &g) in h1.iter_mut().zip(&h3) {
                *a = linalg::silu(*a) * g;
            }
            linalg::matmul(&h1, blk.mlp.w2.data(), rows, hid, c, th, &mut branch);
            for (hv, &mv) in h.iter_mut().zip(&branch) {
                *hv += mv;
            }
        }

        // head
        linalg::rms_norm(&h, self.params.norm_out.data(), rows, c, th, &mut norm);
        let of = spec.out_features;
        let mut out = vec![0.0f32; rows * of];
        linalg::matmul(&norm, self.params.head_w.data(), rows, c, of, th, &mut out);
        linalg::add_bias(&mut out, self.params.head_b.data(), rows, of);
        Ok(Tensor::new(vec![b, n, of], out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn tiny_backend(seed: u64) -> NativeBackend {
        let mc = ModelConfig {
            dim: 32,
            num_heads: 2,
            num_blocks: 2,
            ball_size: 64,
            seq_len: 256,
            ..Default::default()
        };
        NativeBackend::init(seed, &mc, 6, 1, 1).unwrap()
    }

    fn input(n: usize, f: usize, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        Tensor::new(vec![1, n, f], rng.normals(n * f))
    }

    #[test]
    fn forward_shape_and_finiteness() {
        let be = tiny_backend(0);
        assert_eq!(be.spec().n, 256);
        assert_eq!(be.spec().in_features, 6);
        let out = be.forward(&input(256, 6, 1)).unwrap();
        assert_eq!(out.shape(), &[1, 256, 1]);
        assert!(out.all_finite());
        assert!(out.std() > 0.0, "degenerate constant output");
    }

    #[test]
    fn forward_rejects_wrong_shape() {
        let be = tiny_backend(0);
        assert!(be.forward(&Tensor::zeros(vec![1, 128, 6])).is_err());
        assert!(be.forward(&Tensor::zeros(vec![1, 256, 5])).is_err());
        assert!(be.forward(&Tensor::zeros(vec![2, 256, 6])).is_err());
    }

    #[test]
    fn forward_deterministic_and_seed_sensitive() {
        let x = input(256, 6, 2);
        let a = tiny_backend(7).forward(&x).unwrap();
        let b = tiny_backend(7).forward(&x).unwrap();
        assert_eq!(a, b, "same seed, same input => bit-identical output");
        let c = tiny_backend(8).forward(&x).unwrap();
        assert_ne!(a, c, "different seed must change the function");
    }

    #[test]
    fn forward_bitwise_stable_across_thread_counts() {
        // The load-bearing property of the parallel kernels: the thread
        // budget is a pure latency knob, never a numerics knob.
        let x = input(256, 6, 4);
        let base = tiny_backend(5).with_threads(1).forward(&x).unwrap();
        for t in [2usize, 3, 8] {
            let out = tiny_backend(5).with_threads(t).forward(&x).unwrap();
            assert_eq!(base, out, "threads={t} changed the output");
        }
    }

    #[test]
    fn with_threads_resolves_and_caps() {
        let be = tiny_backend(0).with_threads(3);
        assert_eq!(be.threads(), 3);
        let be = be.with_threads(100_000);
        assert_eq!(be.threads(), pool::MAX_THREADS);
        assert!(tiny_backend(0).threads() >= 1, "auto-resolve is positive");
    }

    #[test]
    fn ball_size_clamped_to_n() {
        // paper config at small N: ball 256 > N 64 clamps like aot.py
        let mc = ModelConfig { seq_len: 64, num_blocks: 1, ..Default::default() };
        let be = NativeBackend::init(0, &mc, 6, 1, 1).unwrap();
        assert_eq!(be.hyper().ball_size, 64);
        let out = be.forward(&input(64, 6, 3)).unwrap();
        assert!(out.all_finite());
    }

    #[test]
    fn rejects_invalid_hyper() {
        let params = NativeParams::init(0, 6, 1, 32, 2, 1, 4);
        // group 12 does not divide ball 64
        let hyper = AttnHyper { ball_size: 64, cmp_block: 8, group_size: 12, top_k: 4 };
        assert!(NativeBackend::new(params, hyper, 256, 1).is_err());
    }
}
