//! Pure-Rust BSA inference: the paper's forward pass with no PJRT, no
//! artifacts, no Python anywhere.
//!
//! The model is the trunk of `python/compile/model.py::bsa_forward` for
//! the paper-default variant (mean-pooling compression, group selection,
//! own-ball mask): `num_blocks` blocks of RMSNorm -> three-branch BSA
//! attention -> RMSNorm -> SwiGLU, between a linear embed and a linear
//! head. Batch and head dimensions are folded exactly like the jax side
//! (`_split_heads`), so every kernel in [`super::kernels`] sees the same
//! `(N, dh)` head-major operands the Pallas/ref kernels see — which is
//! what makes this backend a usable parity oracle for the compiled HLO.
//!
//! Compute is thread-parallel via [`super::pool`]'s persistent worker
//! pool on two axes. The projections and MLP GEMMs split output rows
//! across threads. Attention is **head-parallel**: the per-(batch, head)
//! units of the attention step are independent — each reads its own
//! column slice of the Q/K/V projections and writes its own `(N, dh)`
//! block of a head-major staging buffer — so the units are dispatched
//! as pool jobs with per-thread `HeadScratch` buffers, and
//! a pure reordering pass folds the head-major blocks back into
//! token-major `(B*N, C)` rows before the output projection. When the
//! thread budget exceeds the unit count, the leftover budget goes to the
//! kernels *inside* each unit (nested dispatches are deadlock-free: the
//! pool's waiters run queued jobs instead of blocking).
//!
//! The thread count comes from [`NativeBackend::with_threads`] /
//! `ServeConfig::native_threads`, with the `BSA_NATIVE_THREADS` env var
//! as the zero-config override (see [`pool::resolve_threads`]). The
//! kernels' inner loops run on the [`super::simd`] microkernel layer
//! (AVX2/NEON via runtime detection, `BSA_NATIVE_SIMD=off` to force the
//! scalar loops — see `simd`'s docs for the 1e-5 twin rule). Every
//! kernel computes a given output row identically regardless of which
//! chunk or worker it lands in, and the gated head merge is a
//! fixed-order per-element expression, so the forward pass is
//! **bitwise deterministic across thread counts** at any fixed SIMD
//! level — asserted by `rust/tests/conformance.rs`; with SIMD off it is
//! additionally bitwise equal to the scalar `*_reference` composition
//! (`rust/tests/simd_off.rs`).
//!
//! Scratch buffers are allocated once per `forward` call and reused
//! across blocks (plus one `HeadScratch` per pool chunk inside the
//! head-parallel dispatch); per-call cost is a handful of `Vec`s, far
//! below the matmul work itself.
//!
//! **Precision.** [`NativeBackend::with_precision`] selects the
//! *storage* format of the attention staging buffers ([`Precision::F16`]
//! = IEEE binary16 via [`crate::half`]): the Q/K/V projections and the
//! head-major merge buffer are held as 2-byte half words, decoded to f32
//! at the per-unit gather and re-encoded at the unit's merge write, and
//! the parameters are quantized to the f16 grid once at selection time —
//! the values a true half store would hold. Every kernel still
//! *accumulates* in f32 (the gather decodes into f32 `HeadScratch`
//! buffers), so f16 mode changes rounding at the staging boundaries
//! only; the documented tolerance tier vs the f32 forward is in
//! "Kernel conformance" ([`super`]). Gates and the residual stream stay
//! f32 — they are `O(rows)` small next to the staging buffers, and gate
//! sigmoids are the forward's most error-sensitive scalars.

use crate::config::ModelConfig;
use crate::half;
use crate::tensor::Tensor;

use super::kernels;
use super::linalg;
use super::params::{BlockParams, NativeParams};
use super::pool;
use super::{Backend, BackendSpec};

/// Sparse-attention hyperparameters the forward pass needs at run time
/// (the *architecture* dims — width, heads, depth — live in the params).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct AttnHyper {
    /// Ball size m (clamped to N at construction, like aot.py).
    pub ball_size: usize,
    /// Compression block l (= selection block and stride, Table 4).
    pub cmp_block: usize,
    /// Selection group size g.
    pub group_size: usize,
    /// Number of selected blocks k*.
    pub top_k: usize,
}

impl AttnHyper {
    /// From the shared typed config (paper Table 4 defaults).
    pub fn from_model(mc: &ModelConfig) -> AttnHyper {
        AttnHyper {
            ball_size: mc.ball_size,
            cmp_block: mc.cmp_block,
            group_size: mc.group_size,
            top_k: mc.top_k,
        }
    }

    /// From a compiled graph's manifest entry (parity testing).
    pub fn from_graph(info: &crate::runtime::GraphInfo) -> AttnHyper {
        AttnHyper {
            ball_size: info.ball_size,
            cmp_block: info.cmp_block,
            group_size: info.group_size,
            top_k: info.top_k,
        }
    }
}

/// Storage precision of the forward pass's attention staging buffers
/// (and, via load-time quantization, the parameters). Accumulation is
/// always f32; see the module docs. Parsed from the `--precision`
/// serve flag / `[serve] precision` config key.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Precision {
    /// Full f32 storage everywhere (the default).
    #[default]
    F32,
    /// IEEE binary16 storage for Q/K/V staging, the head-merge buffer,
    /// and the parameters; f32 accumulation in every kernel.
    F16,
}

impl std::str::FromStr for Precision {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Precision> {
        match s.to_ascii_lowercase().as_str() {
            "f32" => Ok(Precision::F32),
            "f16" | "half" => Ok(Precision::F16),
            other => anyhow::bail!("unknown precision {other:?} (expected f32 or f16)"),
        }
    }
}

impl std::fmt::Display for Precision {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(match self {
            Precision::F32 => "f32",
            Precision::F16 => "f16",
        })
    }
}

/// The native CPU backend: BSA parameters + sparse hyperparameters +
/// the static `(batch, n)` serving shape + kernel thread budget.
pub struct NativeBackend {
    params: NativeParams,
    hyper: AttnHyper,
    spec: BackendSpec,
    /// Resolved kernel thread count (>= 1); see [`Self::with_threads`].
    threads: usize,
    /// Staging-buffer storage precision; see [`Self::with_precision`].
    precision: Precision,
}

impl NativeBackend {
    /// Build from explicit parameters. `n` is the serving sequence
    /// length (requests are ball-tree padded to it), `batch` the batch
    /// size a single `forward` consumes. The ball size is clamped to
    /// `n` exactly like aot.py clamps it at lowering. Kernel threads
    /// default to the `BSA_NATIVE_THREADS` env var or the machine's
    /// available parallelism; override with [`Self::with_threads`].
    pub fn new(
        params: NativeParams,
        mut hyper: AttnHyper,
        n: usize,
        batch: usize,
    ) -> anyhow::Result<NativeBackend> {
        params.validate()?;
        hyper.ball_size = hyper.ball_size.min(n);
        anyhow::ensure!(batch > 0 && n > 0, "batch and n must be positive");
        anyhow::ensure!(n % hyper.ball_size == 0, "N {n} % ball {} != 0", hyper.ball_size);
        anyhow::ensure!(
            hyper.ball_size % hyper.cmp_block == 0 && hyper.ball_size % hyper.group_size == 0,
            "ball size {} must be divisible by cmp block {} and group {}",
            hyper.ball_size,
            hyper.cmp_block,
            hyper.group_size
        );
        anyhow::ensure!(
            hyper.top_k <= n / hyper.cmp_block,
            "top_k {} exceeds block count {}",
            hyper.top_k,
            n / hyper.cmp_block
        );
        let spec = BackendSpec {
            name: format!("native:bsa_n{n}_b{batch}"),
            n,
            batch,
            in_features: params.in_features(),
            out_features: params.out_features(),
        };
        Ok(NativeBackend {
            params,
            hyper,
            spec,
            threads: pool::resolve_threads(0),
            precision: Precision::F32,
        })
    }

    /// Set the kernel thread budget: `threads > 0` pins the count, `0`
    /// re-resolves from `BSA_NATIVE_THREADS` / hardware parallelism.
    /// Outputs are bitwise identical for every setting (the parallel
    /// kernels are order-preserving); this only trades latency for CPU.
    pub fn with_threads(mut self, threads: usize) -> NativeBackend {
        self.threads = pool::resolve_threads(threads);
        self
    }

    /// The resolved kernel thread count.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Select the staging-buffer storage precision. Switching to
    /// [`Precision::F16`] also rounds every parameter to the nearest
    /// binary16 value in place (one-way — the dropped bits are gone, as
    /// they would be in a true half store; switching back to
    /// [`Precision::F32`] afterwards keeps the quantized params and only
    /// restores f32 staging). Outputs stay bitwise stable across thread
    /// counts at either setting.
    pub fn with_precision(mut self, precision: Precision) -> NativeBackend {
        if precision == Precision::F16 && self.precision != Precision::F16 {
            quantize_params(&mut self.params);
        }
        self.precision = precision;
        self
    }

    /// The staging-buffer storage precision in effect.
    pub fn precision(&self) -> Precision {
        self.precision
    }

    /// Deterministic random-weight backend (smoke tests, latency benches,
    /// artifact-free serving — mirrors serving a `init_<tag>` graph).
    pub fn init(
        seed: u64,
        mc: &ModelConfig,
        in_features: usize,
        out_features: usize,
        batch: usize,
    ) -> anyhow::Result<NativeBackend> {
        let params = NativeParams::init(
            seed,
            in_features,
            out_features,
            mc.dim,
            mc.num_heads,
            mc.num_blocks,
            4, // SwiGLU expansion (model.py mlp_ratio default)
        );
        Self::new(params, AttnHyper::from_model(mc), mc.seq_len, batch)
    }

    /// Load weights from a `.bsackpt` param file or training checkpoint
    /// (see the module docs in [`super`] for the format).
    pub fn load(
        path: &std::path::Path,
        hyper: AttnHyper,
        n: usize,
        batch: usize,
    ) -> anyhow::Result<NativeBackend> {
        Self::new(NativeParams::load(path)?, hyper, n, batch)
    }

    /// Build from the flat parameter list + manifest input names of a
    /// compiled graph (the parity-oracle path: identical weights on both
    /// backends).
    pub fn from_flat(
        params: Vec<Tensor>,
        names: &[String],
        hyper: AttnHyper,
        n: usize,
        batch: usize,
    ) -> anyhow::Result<NativeBackend> {
        anyhow::ensure!(
            params.len() == names.len(),
            "{} params but {} names",
            params.len(),
            names.len()
        );
        let named = names.iter().cloned().zip(params).collect();
        Self::new(NativeParams::from_named(named)?, hyper, n, batch)
    }

    /// The loaded parameters (read-only).
    pub fn params(&self) -> &NativeParams {
        &self.params
    }

    /// Sparse hyperparameters in effect (ball size already clamped).
    pub fn hyper(&self) -> &AttnHyper {
        &self.hyper
    }

    /// Three-branch BSA attention for one block (paper Sec. 2.2),
    /// **head-parallel**. `a` is the RMS-normed input `(B*N, C)` flat.
    ///
    /// The `B * H` (batch, head) units are independent: each gathers its
    /// own `(N, dh)` column slice of the Q/K/V projections, runs the
    /// three branches, and writes its gated merge (eq. 9) into its own
    /// `(N, dh)` block of the head-major staging buffer `merged_hm`
    /// (layout `(B, H, N, dh)`). The units are dispatched over the
    /// worker pool with one `HeadScratch` per chunk; a reordering pass
    /// then folds `merged_hm` back to token-major `(B*N, C)` `merged`
    /// rows, which `wo` projects into `out`.
    ///
    /// Bitwise determinism: unit outputs land in disjoint buffers, the
    /// fold is a pure copy, and the kernels inside a unit are themselves
    /// bitwise thread-count-invariant — so this function's output is
    /// identical for every thread budget (at whatever SIMD level the
    /// process resolved; see [`super::simd`]). When `threads > units`,
    /// the surplus is handed to the kernels inside each unit (`inner`
    /// below); the pool's help-while-waiting latch makes that nesting
    /// safe.
    fn attention(&self, blk: &BlockParams, a: &[f32], out: &mut [f32], s: &mut Scratch) {
        let (b, n) = (self.spec.batch, self.spec.n);
        let c = self.params.dim();
        let h_cnt = self.params.num_heads();
        let dh = c / h_cnt;
        let m = self.hyper.ball_size;
        let l = self.hyper.cmp_block;
        let g = self.hyper.group_size;
        let top_k = self.hyper.top_k;
        let nb = n / l;
        let groups = n / g;
        let rows = b * n;
        let scale = 1.0 / (dh as f32).sqrt();
        let th = self.threads;

        let Scratch { q, k, v, gates, merged, merged_hm, q16, k16, v16, merged_hm16, head_scratch } =
            s;

        // Q/K/V projections. In f16 mode the f32 `q` vec doubles as the
        // single matmul workspace: each projection is computed in f32
        // and immediately encoded into its half-word staging buffer, so
        // only one f32 (rows, C) buffer exists alongside the three
        // 2-byte ones.
        let qkv_span = crate::trace::span("qkv_proj");
        match self.precision {
            Precision::F32 => {
                linalg::matmul(a, blk.attn.wq.data(), rows, c, c, th, q);
                linalg::matmul(a, blk.attn.wk.data(), rows, c, c, th, k);
                linalg::matmul(a, blk.attn.wv.data(), rows, c, c, th, v);
            }
            Precision::F16 => {
                linalg::matmul(a, blk.attn.wq.data(), rows, c, c, th, q);
                half::encode_slice(q, q16);
                linalg::matmul(a, blk.attn.wk.data(), rows, c, c, th, q);
                half::encode_slice(q, k16);
                linalg::matmul(a, blk.attn.wv.data(), rows, c, c, th, q);
                half::encode_slice(q, v16);
            }
        }
        linalg::matmul(a, blk.attn.wg.data(), rows, c, 3 * h_cnt, th, gates);
        drop(qkv_span);

        let units = b * h_cnt;
        // Surplus thread budget (th > units) flows to the kernels inside
        // the units: the first `th % units` units get one extra nested
        // thread, so summed concurrency equals the budget exactly —
        // neither idle threads (floor) nor oversubscription (ceil).
        // Which unit gets the surplus is fixed by unit index, and thread
        // counts never affect numerics, so this is bitwise-neutral.
        let inner_base = th / units;
        let inner_extra = th % units;
        let gates = &gates[..];
        let staged = match self.precision {
            Precision::F32 => Staged::F32 { q: &q[..], k: &k[..], v: &v[..] },
            Precision::F16 => Staged::F16 { q: &q16[..], k: &k16[..], v: &v16[..] },
        };

        // One (batch, head) unit: gather the head's (N, dh) operand
        // slices (decoding f16 staging when active — kernels always
        // accumulate in f32), run the three branches, and write the
        // gated merge (eq. 9) into `hs.merge`.
        let run_unit = |u: usize, inner: usize, hs: &mut HeadScratch| {
            let (bi, hd) = (u / h_cnt, u % h_cnt);
            // split heads: column slice hd*dh.. of this batch item
            let col0 = hd * dh;
            match staged {
                Staged::F32 { q, k, v } => {
                    for t in 0..n {
                        let src = (bi * n + t) * c + col0;
                        hs.qs[t * dh..(t + 1) * dh].copy_from_slice(&q[src..src + dh]);
                        hs.ks[t * dh..(t + 1) * dh].copy_from_slice(&k[src..src + dh]);
                        hs.vs[t * dh..(t + 1) * dh].copy_from_slice(&v[src..src + dh]);
                    }
                }
                Staged::F16 { q, k, v } => {
                    for t in 0..n {
                        let src = (bi * n + t) * c + col0;
                        for j in 0..dh {
                            hs.qs[t * dh + j] = half::f16_bits_to_f32(q[src + j]);
                            hs.ks[t * dh + j] = half::f16_bits_to_f32(k[src + j]);
                            hs.vs[t * dh + j] = half::f16_bits_to_f32(v[src + j]);
                        }
                    }
                }
            }

            // Stage spans live here (not inside kernels.rs): the timing
            // instrumentation must not perturb the bitwise fast==reference
            // kernel contract, and a unit is the natural per-stage grain.
            // Pool jobs adopt the dispatcher's path, so these record as
            // e.g. `forward.layer.ball_attention`.

            // ball branch (eq. 3)
            {
                let _s = crate::trace::span("ball_attention");
                kernels::ball_attention(&hs.qs, &hs.ks, &hs.vs, n, dh, m, inner, &mut hs.o_ball);
            }

            // compression branch (eq. 5): mean phi + streaming attention
            {
                let _s = crate::trace::span("compression");
                kernels::compress_mean(&hs.ks, n, dh, l, inner, &mut hs.kc);
                kernels::compress_mean(&hs.vs, n, dh, l, inner, &mut hs.vc);
                kernels::attend(
                    &hs.qs, &hs.kc, &hs.vc, n, nb, dh, scale, inner, &mut hs.o_cmp,
                    &mut hs.scores,
                );
            }

            // selection branch (eqs. 6-8, 10-12): grouped top-k over
            // compressed keys, own-ball blocks masked out
            {
                let _s = crate::trace::span("selection");
                kernels::group_scores(
                    &hs.qs, &hs.kc, n, dh, g, nb, inner, &mut hs.qg, &mut hs.gscores,
                );
                kernels::mask_own_ball(&mut hs.gscores, groups, nb, g, l, m);
                kernels::topk_indices(&hs.gscores, groups, nb, top_k, inner, &mut hs.idx);
                kernels::select_attention(
                    &hs.qs, &hs.ks, &hs.vs, &hs.idx, n, dh, l, g, top_k, inner, &mut hs.o_slc,
                );
            }

            // gated fusion (eq. 9): per-token per-head sigmoid gates
            let _s = crate::trace::span("gated_merge");
            for t in 0..n {
                let grow = (bi * n + t) * 3 * h_cnt;
                let gb = linalg::sigmoid(gates[grow + hd]);
                let gc = linalg::sigmoid(gates[grow + h_cnt + hd]);
                let gs = linalg::sigmoid(gates[grow + 2 * h_cnt + hd]);
                let dst = t * dh;
                for d0 in 0..dh {
                    hs.merge[dst + d0] = gb * hs.o_ball[dst + d0]
                        + gc * hs.o_cmp[dst + d0]
                        + gs * hs.o_slc[dst + d0];
                }
            }
        };

        // Free-list of HeadScratch instances shared by the chunks and
        // reused across blocks (and the whole forward): each chunk pops
        // one (allocating only on first use), works through its units,
        // and returns it — two uncontended lock ops per chunk instead of
        // hundreds of KB of fresh zeroed Vecs per chunk per block. The
        // unit's merge lands in its own disjoint (N, dh) block of the
        // head-major staging buffer (half words in f16 mode).
        let scratch_pool = std::sync::Mutex::new(std::mem::take(head_scratch));
        match self.precision {
            Precision::F32 => {
                pool::par_rows(&mut merged_hm[..], n * dh, th, |u0, hchunk| {
                    let mut hs = scratch_pool
                        .lock()
                        .unwrap()
                        .pop()
                        .unwrap_or_else(|| HeadScratch::new(n, dh, nb, groups));
                    for (ui, ublock) in hchunk.chunks_exact_mut(n * dh).enumerate() {
                        let u = u0 + ui;
                        let inner = (inner_base + usize::from(u < inner_extra)).max(1);
                        run_unit(u, inner, &mut hs);
                        ublock.copy_from_slice(&hs.merge);
                    }
                    scratch_pool.lock().unwrap().push(hs);
                });
            }
            Precision::F16 => {
                pool::par_rows(&mut merged_hm16[..], n * dh, th, |u0, hchunk| {
                    let mut hs = scratch_pool
                        .lock()
                        .unwrap()
                        .pop()
                        .unwrap_or_else(|| HeadScratch::new(n, dh, nb, groups));
                    for (ui, ublock) in hchunk.chunks_exact_mut(n * dh).enumerate() {
                        let u = u0 + ui;
                        let inner = (inner_base + usize::from(u < inner_extra)).max(1);
                        run_unit(u, inner, &mut hs);
                        for (o, &x) in ublock.iter_mut().zip(&hs.merge) {
                            *o = half::f32_to_f16_bits(x);
                        }
                    }
                    scratch_pool.lock().unwrap().push(hs);
                });
            }
        }
        *head_scratch = scratch_pool.into_inner().unwrap();

        // fold heads: (B, H, N, dh) head-major -> (B*N, C) token-major
        // (a pure copy — f16 decode is deterministic per element — so
        // bitwise-neutral; row-parallel over tokens)
        let _output_proj = crate::trace::span("output_proj");
        match self.precision {
            Precision::F32 => {
                let merged_hm = &merged_hm[..];
                pool::par_rows(&mut merged[..], c, th, |row0, ochunk| {
                    for (ri, orow) in ochunk.chunks_exact_mut(c).enumerate() {
                        let r = row0 + ri;
                        let (bi, t) = (r / n, r % n);
                        for hd in 0..h_cnt {
                            let src = ((bi * h_cnt + hd) * n + t) * dh;
                            orow[hd * dh..(hd + 1) * dh]
                                .copy_from_slice(&merged_hm[src..src + dh]);
                        }
                    }
                });
            }
            Precision::F16 => {
                let merged_hm16 = &merged_hm16[..];
                pool::par_rows(&mut merged[..], c, th, |row0, ochunk| {
                    for (ri, orow) in ochunk.chunks_exact_mut(c).enumerate() {
                        let r = row0 + ri;
                        let (bi, t) = (r / n, r % n);
                        for hd in 0..h_cnt {
                            let src = ((bi * h_cnt + hd) * n + t) * dh;
                            for j in 0..dh {
                                orow[hd * dh + j] = half::f16_bits_to_f32(merged_hm16[src + j]);
                            }
                        }
                    }
                });
            }
        }
        linalg::matmul(&merged[..], blk.attn.wo.data(), rows, c, c, th, out);
    }
}

/// Round every parameter tensor to the nearest binary16 value in place —
/// the in-memory equivalent of a round-trip through f16 storage (see
/// [`crate::coordinator::checkpoint::Dtype::F16`]).
fn quantize_params(p: &mut NativeParams) {
    let mut tensors: Vec<&mut Tensor> = vec![
        &mut p.embed_w,
        &mut p.embed_b,
        &mut p.norm_out,
        &mut p.head_w,
        &mut p.head_b,
    ];
    for b in &mut p.blocks {
        tensors.extend([
            &mut b.attn.wq,
            &mut b.attn.wk,
            &mut b.attn.wv,
            &mut b.attn.wo,
            &mut b.attn.wg,
            &mut b.mlp.w1,
            &mut b.mlp.w2,
            &mut b.mlp.w3,
            &mut b.norm1,
            &mut b.norm2,
        ]);
    }
    for t in tensors {
        half::quantize_slice(t.data_mut());
    }
}

/// Borrowed view of the staged Q/K/V projections at the active
/// precision, consumed by the per-unit gather.
#[derive(Clone, Copy)]
enum Staged<'a> {
    F32 { q: &'a [f32], k: &'a [f32], v: &'a [f32] },
    F16 { q: &'a [u16], k: &'a [u16], v: &'a [u16] },
}

/// Per-forward scratch buffers (sized once, reused across blocks; the
/// per-(batch, head) attention scratch lives in `HeadScratch`, one per
/// pool chunk).
struct Scratch {
    /// (B*N, C) Q projection in f32 mode; in f16 mode the only f32
    /// projection workspace (Q, then K, then V pass through it before
    /// encoding into the half-word buffers below).
    q: Vec<f32>,
    /// (B*N, C) K/V projections — f32 mode only (empty in f16 mode).
    k: Vec<f32>,
    v: Vec<f32>,
    gates: Vec<f32>,
    /// Token-major (B*N, C) gated merge, input to the `wo` projection.
    merged: Vec<f32>,
    /// Head-major (B, H, N, dh) staging buffer the parallel units write
    /// into (disjoint (N, dh) blocks, one per unit) — f32 mode.
    merged_hm: Vec<f32>,
    /// Half-word staging twins of q/k/v/merged_hm — f16 mode only
    /// (empty in f32 mode). 2 bytes per element, decoded at the unit
    /// gather / head fold, encoded at the projection / merge writes.
    q16: Vec<u16>,
    k16: Vec<u16>,
    v16: Vec<u16>,
    merged_hm16: Vec<u16>,
    /// Free-list of per-chunk attention scratch, grown lazily to the
    /// peak concurrent chunk count and reused across blocks.
    head_scratch: Vec<HeadScratch>,
}

impl Scratch {
    fn new(rows: usize, c: usize, h_cnt: usize, precision: Precision) -> Scratch {
        let f32s = |on: bool| if on { vec![0.0f32; rows * c] } else { Vec::new() };
        let f16s = |on: bool| if on { vec![0u16; rows * c] } else { Vec::new() };
        let full = precision == Precision::F32;
        Scratch {
            q: vec![0.0; rows * c],
            k: f32s(full),
            v: f32s(full),
            gates: vec![0.0; rows * 3 * h_cnt],
            merged: vec![0.0; rows * c],
            merged_hm: f32s(full),
            q16: f16s(!full),
            k16: f16s(!full),
            v16: f16s(!full),
            merged_hm16: f16s(!full),
            head_scratch: Vec::new(),
        }
    }
}

/// Scratch for one (batch, head) attention unit: the `(N, dh)` operand
/// gathers, the three branch outputs, and the compression/selection
/// intermediates. One instance per pool chunk ("per-thread head
/// scratch"), reused across the units in that chunk.
struct HeadScratch {
    qs: Vec<f32>,
    ks: Vec<f32>,
    vs: Vec<f32>,
    o_ball: Vec<f32>,
    o_cmp: Vec<f32>,
    o_slc: Vec<f32>,
    kc: Vec<f32>,
    vc: Vec<f32>,
    qg: Vec<f32>,
    gscores: Vec<f32>,
    idx: Vec<usize>,
    scores: Vec<f32>,
    /// The unit's gated merge, staged in f32 before the (possibly
    /// half-word) write into the shared head-major buffer.
    merge: Vec<f32>,
}

impl HeadScratch {
    fn new(n: usize, dh: usize, nb: usize, groups: usize) -> HeadScratch {
        HeadScratch {
            qs: vec![0.0; n * dh],
            ks: vec![0.0; n * dh],
            vs: vec![0.0; n * dh],
            o_ball: vec![0.0; n * dh],
            o_cmp: vec![0.0; n * dh],
            o_slc: vec![0.0; n * dh],
            kc: vec![0.0; nb * dh],
            vc: vec![0.0; nb * dh],
            qg: Vec::new(),
            gscores: vec![0.0; groups * nb],
            idx: Vec::new(),
            scores: Vec::new(),
            merge: vec![0.0; n * dh],
        }
    }
}

impl Backend for NativeBackend {
    fn spec(&self) -> &BackendSpec {
        &self.spec
    }

    fn forward(&self, x: &Tensor) -> anyhow::Result<Tensor> {
        let spec = &self.spec;
        anyhow::ensure!(
            x.shape() == [spec.batch, spec.n, spec.in_features],
            "input shape {:?} != backend ({}, {}, {})",
            x.shape(),
            spec.batch,
            spec.n,
            spec.in_features
        );
        let (b, n) = (spec.batch, spec.n);
        let c = self.params.dim();
        let h_cnt = self.params.num_heads();
        let rows = b * n;
        let th = self.threads;
        let mut s = Scratch::new(rows, c, h_cnt, self.precision);
        let _fwd = crate::trace::span("forward");

        // embed
        let mut h = vec![0.0f32; rows * c];
        {
            let _s = crate::trace::span("embed");
            linalg::matmul(
                x.data(),
                self.params.embed_w.data(),
                rows,
                spec.in_features,
                c,
                th,
                &mut h,
            );
            linalg::add_bias(&mut h, self.params.embed_b.data(), rows, c);
        }

        // trunk
        let hid = self.params.blocks[0].mlp.w1.cols();
        let mut norm = vec![0.0f32; rows * c];
        let mut branch = vec![0.0f32; rows * c];
        let mut h1 = vec![0.0f32; rows * hid];
        let mut h3 = vec![0.0f32; rows * hid];
        for blk in &self.params.blocks {
            let _layer = crate::trace::span("layer");
            // x = x + attn(rms_norm(x))
            linalg::rms_norm(&h, blk.norm1.data(), rows, c, th, &mut norm);
            self.attention(blk, &norm, &mut branch, &mut s);
            for (hv, &av) in h.iter_mut().zip(&branch) {
                *hv += av;
            }
            // x = x + swiglu(rms_norm(x))
            let _swiglu = crate::trace::span("swiglu");
            linalg::rms_norm(&h, blk.norm2.data(), rows, c, th, &mut norm);
            linalg::matmul(&norm, blk.mlp.w1.data(), rows, c, hid, th, &mut h1);
            linalg::matmul(&norm, blk.mlp.w3.data(), rows, c, hid, th, &mut h3);
            for (a, &g) in h1.iter_mut().zip(&h3) {
                *a = linalg::silu(*a) * g;
            }
            linalg::matmul(&h1, blk.mlp.w2.data(), rows, hid, c, th, &mut branch);
            for (hv, &mv) in h.iter_mut().zip(&branch) {
                *hv += mv;
            }
        }

        // head
        let _head = crate::trace::span("head");
        linalg::rms_norm(&h, self.params.norm_out.data(), rows, c, th, &mut norm);
        let of = spec.out_features;
        let mut out = vec![0.0f32; rows * of];
        linalg::matmul(&norm, self.params.head_w.data(), rows, c, of, th, &mut out);
        linalg::add_bias(&mut out, self.params.head_b.data(), rows, of);
        Ok(Tensor::new(vec![b, n, of], out))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn tiny_backend(seed: u64) -> NativeBackend {
        let mc = ModelConfig {
            dim: 32,
            num_heads: 2,
            num_blocks: 2,
            ball_size: 64,
            seq_len: 256,
            ..Default::default()
        };
        NativeBackend::init(seed, &mc, 6, 1, 1).unwrap()
    }

    fn input(n: usize, f: usize, seed: u64) -> Tensor {
        let mut rng = Rng::new(seed);
        Tensor::new(vec![1, n, f], rng.normals(n * f))
    }

    #[test]
    fn forward_shape_and_finiteness() {
        let be = tiny_backend(0);
        assert_eq!(be.spec().n, 256);
        assert_eq!(be.spec().in_features, 6);
        let out = be.forward(&input(256, 6, 1)).unwrap();
        assert_eq!(out.shape(), &[1, 256, 1]);
        assert!(out.all_finite());
        assert!(out.std() > 0.0, "degenerate constant output");
    }

    #[test]
    fn forward_rejects_wrong_shape() {
        let be = tiny_backend(0);
        assert!(be.forward(&Tensor::zeros(vec![1, 128, 6])).is_err());
        assert!(be.forward(&Tensor::zeros(vec![1, 256, 5])).is_err());
        assert!(be.forward(&Tensor::zeros(vec![2, 256, 6])).is_err());
    }

    #[test]
    fn forward_deterministic_and_seed_sensitive() {
        let x = input(256, 6, 2);
        let a = tiny_backend(7).forward(&x).unwrap();
        let b = tiny_backend(7).forward(&x).unwrap();
        assert_eq!(a, b, "same seed, same input => bit-identical output");
        let c = tiny_backend(8).forward(&x).unwrap();
        assert_ne!(a, c, "different seed must change the function");
    }

    #[test]
    fn forward_bitwise_stable_across_thread_counts() {
        // The load-bearing property of the parallel kernels: the thread
        // budget is a pure latency knob, never a numerics knob.
        let x = input(256, 6, 4);
        let base = tiny_backend(5).with_threads(1).forward(&x).unwrap();
        for t in [2usize, 3, 8] {
            let out = tiny_backend(5).with_threads(t).forward(&x).unwrap();
            assert_eq!(base, out, "threads={t} changed the output");
        }
    }

    #[test]
    fn precision_parses_and_displays() {
        assert_eq!("f32".parse::<Precision>().unwrap(), Precision::F32);
        assert_eq!("f16".parse::<Precision>().unwrap(), Precision::F16);
        assert_eq!("F16".parse::<Precision>().unwrap(), Precision::F16);
        assert_eq!("half".parse::<Precision>().unwrap(), Precision::F16);
        assert!("bf16".parse::<Precision>().is_err());
        assert_eq!(Precision::F16.to_string(), "f16");
    }

    #[test]
    fn f16_forward_holds_the_documented_tolerance_tier() {
        // The f16 tier ("Kernel conformance" in the backend docs): with
        // half storage at the staging boundaries and f16-grid params,
        // forward outputs on unit-scale inputs stay within 5e-2 of the
        // f32 forward — loose next to the per-rounding 2^-11 because
        // errors compound across blocks, tight enough to catch any
        // accumulation done in half by mistake.
        let x = input(256, 6, 11);
        let full = tiny_backend(3).forward(&x).unwrap();
        let be = tiny_backend(3).with_precision(Precision::F16);
        assert_eq!(be.precision(), Precision::F16);
        let half_out = be.forward(&x).unwrap();
        assert!(half_out.all_finite());
        assert_ne!(full, half_out, "f16 storage should perturb the output");
        for (a, b) in full.data().iter().zip(half_out.data()) {
            assert!((a - b).abs() <= 5e-2 * (1.0 + a.abs()), "{a} vs {b}");
        }
    }

    #[test]
    fn f16_forward_bitwise_stable_across_thread_counts() {
        // The thread-count invariant must survive the precision axis:
        // encode/decode are deterministic per element and unit writes
        // stay disjoint.
        let x = input(256, 6, 12);
        let base = tiny_backend(6)
            .with_precision(Precision::F16)
            .with_threads(1)
            .forward(&x)
            .unwrap();
        for t in [2usize, 3, 8] {
            let out = tiny_backend(6)
                .with_precision(Precision::F16)
                .with_threads(t)
                .forward(&x)
                .unwrap();
            assert_eq!(base, out, "threads={t} changed the f16 output");
        }
    }

    #[test]
    fn with_threads_resolves_and_caps() {
        let be = tiny_backend(0).with_threads(3);
        assert_eq!(be.threads(), 3);
        let be = be.with_threads(100_000);
        assert_eq!(be.threads(), pool::MAX_THREADS);
        assert!(tiny_backend(0).threads() >= 1, "auto-resolve is positive");
    }

    #[test]
    fn ball_size_clamped_to_n() {
        // paper config at small N: ball 256 > N 64 clamps like aot.py
        let mc = ModelConfig { seq_len: 64, num_blocks: 1, ..Default::default() };
        let be = NativeBackend::init(0, &mc, 6, 1, 1).unwrap();
        assert_eq!(be.hyper().ball_size, 64);
        let out = be.forward(&input(64, 6, 3)).unwrap();
        assert!(out.all_finite());
    }

    #[test]
    fn rejects_invalid_hyper() {
        let params = NativeParams::init(0, 6, 1, 32, 2, 1, 4);
        // group 12 does not divide ball 64
        let hyper = AttnHyper { ball_size: 64, cmp_block: 8, group_size: 12, top_k: 4 };
        assert!(NativeBackend::new(params, hyper, 256, 1).is_err());
    }
}
