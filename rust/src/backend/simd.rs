//! SIMD microkernel layer: explicit 8-lane f32 panels the native
//! kernels' inner loops are built from.
//!
//! # Lane-width contract
//!
//! Every microkernel is written against a fixed lane width of
//! [`LANES`] = 8 f32 elements (one AVX2 `ymm` register, two NEON
//! `float32x4_t`s). The portable implementations process the input as
//! whole 8-lane panels — fixed-width local arrays with no loop-carried
//! scalar dependence — so stable Rust autovectorizes them on any
//! target, then handle the `len % 8` tail scalar-wise. The portable
//! reductions ([`dot`], [`sum_sq`], [`exp_sum`], [`row_max`]) keep one
//! accumulator per lane and combine them with a fixed pairwise tree
//! (`hsum8`); the AVX2/NEON specializations use their own register
//! blocking and horizontal-add sequences. Every implementation is
//! fully deterministic on its own — same input, same level, same bits;
//! which worker thread runs the panel can never matter — but last-bit
//! results may differ *between* levels (all within the 1e-5 twin
//! bound).
//!
//! # Dispatch levels and the escape hatch
//!
//! [`active`] resolves one process-wide [`Level`]:
//!
//! * [`Level::Scalar`] — the original scalar loops, bit-for-bit the
//!   `*_reference` numerics. Selected by `BSA_NATIVE_SIMD=off` (see
//!   [`SIMD_ENV`]), `[serve] native_simd = "off"`, `bsa serve --simd
//!   off`, or [`set_force`].
//! * [`Level::Portable`] — the autovectorizing lane-array panels
//!   (always available; also `BSA_NATIVE_SIMD=portable`).
//! * [`Level::Avx2`] — `std::arch` x86-64 specializations compiled with
//!   `avx2,fma` (FMA dot/sum-sq; the remaining panels recompiled under
//!   the wider feature set), chosen at runtime via
//!   `is_x86_feature_detected!`.
//! * [`Level::Neon`] — aarch64 `vfmaq_f32` dot/sum-sq via
//!   `is_aarch64_feature_detected!` (the other panels use the portable
//!   code, which the aarch64 baseline already vectorizes).
//!
//! # The amended twin rule (1e-5)
//!
//! The element-parallel panels ([`axpy`], [`add_assign`], [`scale`])
//! perform exactly the scalar op sequence per element — separate mul
//! and add, never a contracted FMA — so their results are **bitwise
//! identical at every level**, and kernels built only from them
//! (`linalg::matmul`, `kernels::compress_mean`) keep their bitwise
//! equality with their scalar twins. The horizontal reductions are
//! where SIMD genuinely reorders floating-point accumulation (lane
//! partial sums + a tree combine instead of one left-to-right chain),
//! and [`exp_sum`] additionally evaluates `exp` with a degree-6
//! polynomial (max relative error ~1.2e-7, validated by
//! `python/tests/test_simd_mirror.py`) instead of libm. Kernels built
//! on them — `matmul_nt`, `softmax_rows`, `rms_norm`, and the
//! attention family — therefore match their `*_reference` twins to the
//! documented **1e-5 differential bound** rather than bitwise (see
//! "Kernel conformance" in [`super`]).
//!
//! The streaming-attention panels follow the same split:
//! [`tile_scores`] is a reduction (per-key [`dot`], so the 1e-5 tier),
//! [`exp_one`] uses the level's `exp` numerics (libm at
//! [`Level::Scalar`], [`exp_sum`]'s polynomial otherwise — the
//! online-softmax rescale factor must round exactly like the tile
//! weights or the running sum drifts from the one-pass softmax it
//! mirrors), and [`rescale`] is element-parallel and **bitwise at
//! every level** like [`scale`]. Two properties survive
//! unconditionally:
//!
//! 1. **bitwise across thread counts** — the level is fixed
//!    process-wide and panels are per-row deterministic, so the thread
//!    budget still never changes a bit;
//! 2. **`BSA_NATIVE_SIMD=off` is bitwise-equal to the scalar twins**
//!    everywhere (asserted by `rust/tests/simd_off.rs`).

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Fixed lane width (f32 elements) every microkernel is blocked by.
pub const LANES: usize = 8;

/// Environment override consulted once per process by [`active`]:
/// `off`/`0`/`false`/`scalar` force [`Level::Scalar`], `portable`
/// forces [`Level::Portable`], anything else (or unset) auto-detects.
pub const SIMD_ENV: &str = "BSA_NATIVE_SIMD";

/// A resolved microkernel implementation level (see module docs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Level {
    /// Original scalar loops — bitwise `*_reference` numerics.
    Scalar,
    /// Autovectorizing 8-lane panels, any target.
    Portable,
    /// x86-64 AVX2+FMA specializations.
    Avx2,
    /// aarch64 NEON specializations.
    Neon,
}

impl Level {
    /// Stable lowercase name for logs and bench JSON.
    pub fn name(self) -> &'static str {
        match self {
            Level::Scalar => "scalar",
            Level::Portable => "portable",
            Level::Avx2 => "avx2",
            Level::Neon => "neon",
        }
    }
}

/// Programmatic override for the dispatch level (CLI `--simd`, config
/// `[serve] native_simd`, bench A/B timing). `Auto` defers to the
/// `BSA_NATIVE_SIMD` env var + hardware detection.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Force {
    /// Env var if set, else hardware detection (the default).
    #[default]
    Auto,
    /// Force the scalar loops (bitwise `*_reference` numerics).
    Off,
    /// Force the best detected SIMD level, ignoring the env var.
    On,
}

impl std::str::FromStr for Force {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<Force> {
        match s.trim().to_ascii_lowercase().as_str() {
            "auto" | "" => Ok(Force::Auto),
            "on" | "true" | "1" => Ok(Force::On),
            "off" | "false" | "0" => Ok(Force::Off),
            other => Err(anyhow::anyhow!(
                "unknown simd mode {other:?} (expected \"auto\", \"on\", or \"off\")"
            )),
        }
    }
}

const FORCE_AUTO: u8 = 0;
const FORCE_OFF: u8 = 1;
const FORCE_ON: u8 = 2;

static FORCE: AtomicU8 = AtomicU8::new(FORCE_AUTO);

/// Set the process-wide dispatch override. Call at startup (or from a
/// single-threaded bench harness): the level is global, so flipping it
/// while forwards are in flight changes which implementation later
/// panels pick — never unsound, but it forfeits the "bitwise across
/// thread counts" guarantee for the forwards that straddle the flip.
pub fn set_force(f: Force) {
    let v = match f {
        Force::Auto => FORCE_AUTO,
        Force::Off => FORCE_OFF,
        Force::On => FORCE_ON,
    };
    FORCE.store(v, Ordering::Relaxed);
}

/// Best level the hardware supports (cached; ignores the env var).
fn hardware_level() -> Level {
    static HW: OnceLock<Level> = OnceLock::new();
    *HW.get_or_init(|| {
        #[cfg(target_arch = "x86_64")]
        {
            if std::arch::is_x86_feature_detected!("avx2")
                && std::arch::is_x86_feature_detected!("fma")
            {
                return Level::Avx2;
            }
        }
        #[cfg(target_arch = "aarch64")]
        {
            if std::arch::is_aarch64_feature_detected!("neon") {
                return Level::Neon;
            }
        }
        Level::Portable
    })
}

/// `BSA_NATIVE_SIMD` resolution (cached once per process).
fn env_level() -> Level {
    static RESOLVED: OnceLock<Level> = OnceLock::new();
    *RESOLVED.get_or_init(|| {
        match std::env::var(SIMD_ENV)
            .ok()
            .map(|s| s.trim().to_ascii_lowercase())
            .as_deref()
        {
            Some("off") | Some("0") | Some("false") | Some("scalar") => Level::Scalar,
            Some("portable") => Level::Portable,
            _ => hardware_level(),
        }
    })
}

/// The level every microkernel dispatches on right now.
#[inline]
pub fn active() -> Level {
    match FORCE.load(Ordering::Relaxed) {
        FORCE_OFF => Level::Scalar,
        FORCE_ON => hardware_level(),
        _ => env_level(),
    }
}

/// `true` when SIMD panels are in use (level != [`Level::Scalar`]).
/// Kernels with a dedicated scalar code path branch on this once per
/// chunk so that `BSA_NATIVE_SIMD=off` runs the original loops verbatim.
#[inline]
pub fn on() -> bool {
    active() != Level::Scalar
}

/// Fixed pairwise combine of the 8 lane accumulators used by the
/// *portable* reductions (the AVX2/NEON `dot`/`sum_sq` specializations
/// have their own blocking and horizontal-add sequences, so last-bit
/// results differ *across* levels; each level is deterministic on its
/// own, which is all the twin contract needs).
#[inline(always)]
fn hsum8(a: &[f32; LANES]) -> f32 {
    ((a[0] + a[1]) + (a[2] + a[3])) + ((a[4] + a[5]) + (a[6] + a[7]))
}

// ---------------------------------------------------------------------------
// exp panel (polynomial, vectorizable)
// ---------------------------------------------------------------------------

// Cephes-style expf: clamp, round-to-even via the 1.5*2^23 magic
// constant, Cody-Waite ln2 split, degree-6 polynomial, exponent-bit
// scale. Max relative error ~1.2e-7 over the clamped range, exp(0) is
// exactly 1.0, and inputs below EXP_LO saturate at the smallest normal
// (~1.18e-38) — negligible against any unmasked softmax term. The
// numpy mirror in python/tests/test_simd_mirror.py re-derives these
// bounds with exact f32 arithmetic.
const EXP_HI: f32 = 88.02;
const EXP_LO: f32 = -87.33654;
const LOG2E: f32 = 1.442_695;
const LN2_HI: f32 = 0.693_359_4;
const LN2_LO: f32 = -2.121_944_4e-4;
const EXP_MAGIC: f32 = 12_582_912.0; // 1.5 * 2^23
const EXP_C0: f32 = 1.987_569_1e-4;
const EXP_C1: f32 = 1.398_199_9e-3;
const EXP_C2: f32 = 8.333_452e-3;
const EXP_C3: f32 = 4.166_579_6e-2;
const EXP_C4: f32 = 1.666_666_6e-1;
const EXP_C5: f32 = 0.5;

/// Polynomial `e^x` for one lane (no branches, no libm — the body
/// autovectorizes inside the lane loops that call it).
#[inline(always)]
fn exp_lane(x: f32) -> f32 {
    let x = x.clamp(EXP_LO, EXP_HI);
    let n = (x * LOG2E + EXP_MAGIC) - EXP_MAGIC;
    let r = x - n * LN2_HI;
    let r = r - n * LN2_LO;
    let mut p = EXP_C0;
    p = p * r + EXP_C1;
    p = p * r + EXP_C2;
    p = p * r + EXP_C3;
    p = p * r + EXP_C4;
    p = p * r + EXP_C5;
    let p = p * (r * r) + (r + 1.0);
    let bits = (((n as i32) + 127) << 23) as u32;
    p * f32::from_bits(bits)
}

// ---------------------------------------------------------------------------
// scalar twins (the pre-SIMD numerics, selected by Level::Scalar)
// ---------------------------------------------------------------------------

/// Scalar dot product — the exact accumulation order of the
/// `*_reference` kernels (left-to-right, single chain).
#[inline]
pub fn dot_scalar(x: &[f32], y: &[f32]) -> f32 {
    x.iter().zip(y).map(|(a, b)| a * b).sum()
}

/// Scalar sum of squares (RMSNorm reference order).
#[inline]
pub fn sum_sq_scalar(x: &[f32]) -> f32 {
    x.iter().map(|v| v * v).sum()
}

/// Scalar row max (softmax reference order).
#[inline]
pub fn row_max_scalar(x: &[f32]) -> f32 {
    x.iter().copied().fold(f32::NEG_INFINITY, f32::max)
}

/// Scalar subtract-max exponentiation in place, returning the running
/// sum — the softmax reference inner loop (libm `exp`, one sum chain).
#[inline]
pub fn exp_sum_scalar(row: &mut [f32], max: f32) -> f32 {
    let mut sum = 0.0f32;
    for v in row.iter_mut() {
        *v = (*v - max).exp();
        sum += *v;
    }
    sum
}

// ---------------------------------------------------------------------------
// portable 8-lane panels (autovectorize on stable Rust)
// ---------------------------------------------------------------------------

/// 8-lane dot product: one accumulator per lane, [`hsum8`] combine,
/// scalar tail. Deterministic for a given length; reassociates the sum
/// vs [`dot_scalar`] (the 1e-5 twin bound's origin).
#[inline]
pub fn dot_portable(x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len(), "dot operand lengths");
    let mut acc = [0.0f32; LANES];
    let mut cx = x.chunks_exact(LANES);
    let mut cy = y.chunks_exact(LANES);
    for (xs, ys) in (&mut cx).zip(&mut cy) {
        for ((a, &xv), &yv) in acc.iter_mut().zip(xs).zip(ys) {
            *a += xv * yv;
        }
    }
    let mut sum = hsum8(&acc);
    for (&xv, &yv) in cx.remainder().iter().zip(cy.remainder()) {
        sum += xv * yv;
    }
    sum
}

/// 8-lane sum of squares (same shape as [`dot_portable`]).
#[inline]
pub fn sum_sq_portable(x: &[f32]) -> f32 {
    let mut acc = [0.0f32; LANES];
    let mut cx = x.chunks_exact(LANES);
    for xs in &mut cx {
        for (a, &xv) in acc.iter_mut().zip(xs) {
            *a += xv * xv;
        }
    }
    let mut sum = hsum8(&acc);
    for &xv in cx.remainder() {
        sum += xv * xv;
    }
    sum
}

/// 8-lane row max. `max` is exact under any reduction order (absent
/// NaN), so this is value-identical to [`row_max_scalar`].
#[inline]
pub fn row_max_portable(x: &[f32]) -> f32 {
    let mut m = [f32::NEG_INFINITY; LANES];
    let mut cx = x.chunks_exact(LANES);
    for xs in &mut cx {
        for (a, &v) in m.iter_mut().zip(xs) {
            *a = (*a).max(v);
        }
    }
    let mut best = f32::NEG_INFINITY;
    for &v in &m {
        best = best.max(v);
    }
    for &v in cx.remainder() {
        best = best.max(v);
    }
    best
}

/// 8-lane subtract-max exponentiation in place (polynomial
/// [`exp_lane`]), returning the sum of the exponentials.
#[inline]
pub fn exp_sum_portable(row: &mut [f32], max: f32) -> f32 {
    let mut acc = [0.0f32; LANES];
    let mut chunks = row.chunks_exact_mut(LANES);
    for xs in &mut chunks {
        for (a, v) in acc.iter_mut().zip(xs.iter_mut()) {
            let e = exp_lane(*v - max);
            *v = e;
            *a += e;
        }
    }
    let mut sum = hsum8(&acc);
    for v in chunks.into_remainder() {
        let e = exp_lane(*v - max);
        *v = e;
        sum += e;
    }
    sum
}

// element-parallel panels: one op sequence per element, no loop-carried
// accumulator — bitwise identical at every level (the autovectorizer
// widens them without reassociating anything, and Rust never contracts
// the separate mul and add into an FMA).

#[inline]
fn axpy_panel(a: f32, x: &[f32], y: &mut [f32]) {
    for (o, &v) in y.iter_mut().zip(x) {
        *o += a * v;
    }
}

#[inline]
fn add_assign_panel(y: &mut [f32], x: &[f32]) {
    for (o, &v) in y.iter_mut().zip(x) {
        *o += v;
    }
}

#[inline]
fn scale_panel(x: &mut [f32], s: f32) {
    for v in x.iter_mut() {
        *v *= s;
    }
}

// ---------------------------------------------------------------------------
// AVX2 / FMA specializations (x86-64, runtime-detected)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
#[allow(clippy::missing_safety_doc)] // module docs state the one contract
mod avx2 {
    //! `target_feature(avx2, fma)` bodies. The reductions are
    //! hand-written with `_mm256_fmadd_ps` (two accumulators for ILP);
    //! the remaining panels reuse the portable code, recompiled under
    //! the wider feature set — same IEEE op sequence, wider registers.
    //!
    //! Safety: every fn here is `unsafe` solely because of
    //! `target_feature`; callers must have verified
    //! `is_x86_feature_detected!("avx2")` && `("fma")` (the dispatchers
    //! in [`super`] do).

    use std::arch::x86_64::*;

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn dot(x: &[f32], y: &[f32]) -> f32 {
        let n = x.len();
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut i = 0;
        while i + 16 <= n {
            acc0 = _mm256_fmadd_ps(
                _mm256_loadu_ps(x.as_ptr().add(i)),
                _mm256_loadu_ps(y.as_ptr().add(i)),
                acc0,
            );
            acc1 = _mm256_fmadd_ps(
                _mm256_loadu_ps(x.as_ptr().add(i + 8)),
                _mm256_loadu_ps(y.as_ptr().add(i + 8)),
                acc1,
            );
            i += 16;
        }
        if i + 8 <= n {
            acc0 = _mm256_fmadd_ps(
                _mm256_loadu_ps(x.as_ptr().add(i)),
                _mm256_loadu_ps(y.as_ptr().add(i)),
                acc0,
            );
            i += 8;
        }
        let acc = _mm256_add_ps(acc0, acc1);
        let s = _mm_add_ps(_mm256_castps256_ps128(acc), _mm256_extractf128_ps::<1>(acc));
        let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
        let s = _mm_add_ss(s, _mm_shuffle_ps::<0b01>(s, s));
        let mut sum = _mm_cvtss_f32(s);
        while i < n {
            sum += x[i] * y[i];
            i += 1;
        }
        sum
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn sum_sq(x: &[f32]) -> f32 {
        dot(x, x)
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn row_max(x: &[f32]) -> f32 {
        super::row_max_portable(x)
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn exp_sum(row: &mut [f32], max: f32) -> f32 {
        super::exp_sum_portable(row, max)
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
        super::axpy_panel(a, x, y)
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn add_assign(y: &mut [f32], x: &[f32]) {
        super::add_assign_panel(y, x)
    }

    #[target_feature(enable = "avx2", enable = "fma")]
    pub unsafe fn scale(x: &mut [f32], s: f32) {
        super::scale_panel(x, s)
    }
}

// ---------------------------------------------------------------------------
// NEON specializations (aarch64, runtime-detected)
// ---------------------------------------------------------------------------

#[cfg(target_arch = "aarch64")]
#[allow(clippy::missing_safety_doc)] // module docs state the one contract
mod neon {
    //! `vfmaq_f32` reductions; everything else already vectorizes at
    //! the aarch64 baseline, so the portable panels are used directly.
    //!
    //! Safety: `unsafe` solely because of `target_feature(neon)`;
    //! callers must have verified `is_aarch64_feature_detected!("neon")`
    //! (the dispatchers in [`super`] do).

    use std::arch::aarch64::*;

    #[target_feature(enable = "neon")]
    pub unsafe fn dot(x: &[f32], y: &[f32]) -> f32 {
        let n = x.len();
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut i = 0;
        while i + 8 <= n {
            acc0 = vfmaq_f32(
                acc0,
                vld1q_f32(x.as_ptr().add(i)),
                vld1q_f32(y.as_ptr().add(i)),
            );
            acc1 = vfmaq_f32(
                acc1,
                vld1q_f32(x.as_ptr().add(i + 4)),
                vld1q_f32(y.as_ptr().add(i + 4)),
            );
            i += 8;
        }
        let mut sum = vaddvq_f32(vaddq_f32(acc0, acc1));
        while i < n {
            sum += x[i] * y[i];
            i += 1;
        }
        sum
    }

    #[target_feature(enable = "neon")]
    pub unsafe fn sum_sq(x: &[f32]) -> f32 {
        dot(x, x)
    }
}

// ---------------------------------------------------------------------------
// dispatchers (the API the kernels call)
//
// Each microkernel comes as a `*_at(level, ...)` form plus a
// convenience form that resolves [`active`] itself. Hot loops resolve
// the level ONCE per kernel invocation and call `*_at` per
// row/element — a branch on a local enum instead of an atomic load +
// OnceLock read per inner-loop iteration (the level is process-wide
// and fixed during a kernel call, so the two forms are equivalent).
// ---------------------------------------------------------------------------

/// Dot product at an explicit level. Reduction-reordering: matches
/// [`dot_scalar`] to the 1e-5 twin bound, exactly at [`Level::Scalar`].
#[inline]
pub fn dot_at(level: Level, x: &[f32], y: &[f32]) -> f32 {
    debug_assert_eq!(x.len(), y.len(), "dot operand lengths");
    match level {
        Level::Scalar => dot_scalar(x, y),
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { avx2::dot(x, y) },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => unsafe { neon::dot(x, y) },
        _ => dot_portable(x, y),
    }
}

/// [`dot_at`] at the [`active`] level.
#[inline]
pub fn dot(x: &[f32], y: &[f32]) -> f32 {
    dot_at(active(), x, y)
}

/// Sum of squares at an explicit level (same contract as [`dot_at`]).
#[inline]
pub fn sum_sq_at(level: Level, x: &[f32]) -> f32 {
    match level {
        Level::Scalar => sum_sq_scalar(x),
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { avx2::sum_sq(x) },
        #[cfg(target_arch = "aarch64")]
        Level::Neon => unsafe { neon::sum_sq(x) },
        _ => sum_sq_portable(x),
    }
}

/// [`sum_sq_at`] at the [`active`] level.
#[inline]
pub fn sum_sq(x: &[f32]) -> f32 {
    sum_sq_at(active(), x)
}

/// Row max at an explicit level — value-identical at every level (max
/// is order-insensitive), dispatched only for codegen.
#[inline]
pub fn row_max_at(level: Level, x: &[f32]) -> f32 {
    match level {
        Level::Scalar => row_max_scalar(x),
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { avx2::row_max(x) },
        _ => row_max_portable(x),
    }
}

/// [`row_max_at`] at the [`active`] level.
#[inline]
pub fn row_max(x: &[f32]) -> f32 {
    row_max_at(active(), x)
}

/// Subtract-max exponentiation + sum at an explicit level. SIMD levels
/// use the polynomial [`exp_lane`] and a lane-tree sum (1e-5 twin
/// bound); [`Level::Scalar`] is the exact libm reference loop.
#[inline]
pub fn exp_sum_at(level: Level, row: &mut [f32], max: f32) -> f32 {
    match level {
        Level::Scalar => exp_sum_scalar(row, max),
        #[cfg(target_arch = "x86_64")]
        Level::Avx2 => unsafe { avx2::exp_sum(row, max) },
        _ => exp_sum_portable(row, max),
    }
}

/// [`exp_sum_at`] at the [`active`] level.
#[inline]
pub fn exp_sum(row: &mut [f32], max: f32) -> f32 {
    exp_sum_at(active(), row, max)
}

/// `y += a * x` at an explicit level, element-parallel — **bitwise
/// identical at every level** (no reassociation, no FMA contraction),
/// so kernels built on it keep exact equality with their scalar twins.
#[inline]
pub fn axpy_at(level: Level, a: f32, x: &[f32], y: &mut [f32]) {
    debug_assert_eq!(x.len(), y.len(), "axpy operand lengths");
    #[cfg(target_arch = "x86_64")]
    {
        if level == Level::Avx2 {
            return unsafe { avx2::axpy(a, x, y) };
        }
    }
    let _ = level;
    axpy_panel(a, x, y)
}

/// [`axpy_at`] at the [`active`] level.
#[inline]
pub fn axpy(a: f32, x: &[f32], y: &mut [f32]) {
    axpy_at(active(), a, x, y)
}

/// `y += x` at an explicit level, element-parallel — bitwise identical
/// at every level.
#[inline]
pub fn add_assign_at(level: Level, y: &mut [f32], x: &[f32]) {
    debug_assert_eq!(x.len(), y.len(), "add_assign operand lengths");
    #[cfg(target_arch = "x86_64")]
    {
        if level == Level::Avx2 {
            return unsafe { avx2::add_assign(y, x) };
        }
    }
    let _ = level;
    add_assign_panel(y, x)
}

/// [`add_assign_at`] at the [`active`] level.
#[inline]
pub fn add_assign(y: &mut [f32], x: &[f32]) {
    add_assign_at(active(), y, x)
}

/// `x *= s` at an explicit level, element-parallel — bitwise identical
/// at every level.
#[inline]
pub fn scale_at(level: Level, x: &mut [f32], s: f32) {
    #[cfg(target_arch = "x86_64")]
    {
        if level == Level::Avx2 {
            return unsafe { avx2::scale(x, s) };
        }
    }
    let _ = level;
    scale_panel(x, s)
}

/// [`scale_at`] at the [`active`] level.
#[inline]
pub fn scale(x: &mut [f32], s: f32) {
    scale_at(active(), x, s)
}

// ---------------------------------------------------------------------------
// streaming-attention panels (tile scores, single exp, accumulator rescale)
//
// The building blocks of kernels::attend_streaming's online softmax:
// per key tile, scores = q·Kᵀ * scale (tile_scores), the tile max and
// exponentials reuse row_max / exp_sum, the running-max correction
// needs one exp with the *same* rounding as the tile weights (exp_one)
// and an element-parallel accumulator rescale (rescale).
// ---------------------------------------------------------------------------

/// Scaled `q · Kᵀ` scores for one key tile at an explicit level:
/// `out[j] = dot(q, keys[j*d..][..d]) * scale`. Built on [`dot_at`], so
/// it inherits the reduction tier — 1e-5 vs the scalar chain, exact at
/// [`Level::Scalar`]. `keys` holds `out.len()` contiguous rows of `d`
/// floats.
#[inline]
pub fn tile_scores_at(
    level: Level,
    q: &[f32],
    keys: &[f32],
    d: usize,
    scale: f32,
    out: &mut [f32],
) {
    debug_assert_eq!(keys.len(), out.len() * d, "tile_scores key tile shape");
    for (j, o) in out.iter_mut().enumerate() {
        *o = dot_at(level, q, &keys[j * d..(j + 1) * d]) * scale;
    }
}

/// [`tile_scores_at`] at the [`active`] level.
#[inline]
pub fn tile_scores(q: &[f32], keys: &[f32], d: usize, scale: f32, out: &mut [f32]) {
    tile_scores_at(active(), q, keys, d, scale, out)
}

/// One exponential with the level's `exp` numerics: libm at
/// [`Level::Scalar`], the polynomial [`exp_lane`] everywhere else. The
/// online-softmax rescale factor `alpha = exp(m_old - m_new)` must
/// round exactly like the tile weights ([`exp_sum_at`]) at the same
/// level, or the streaming running sum drifts from the one-pass
/// softmax it reproduces — hence a dedicated dispatcher instead of
/// `f32::exp` at the call site.
#[inline]
pub fn exp_one_at(level: Level, x: f32) -> f32 {
    match level {
        Level::Scalar => x.exp(),
        _ => exp_lane(x),
    }
}

/// [`exp_one_at`] at the [`active`] level.
#[inline]
pub fn exp_one(x: f32) -> f32 {
    exp_one_at(active(), x)
}

/// Streaming-accumulator rescale `acc *= alpha` — the online softmax's
/// correction step when the running max rises. Element-parallel (the
/// [`scale_at`] panels), so it is **bitwise identical at every level**,
/// which is what keeps the streaming kernel's `BSA_NATIVE_SIMD=off`
/// path bitwise-equal to its scalar twin.
#[inline]
pub fn rescale_at(level: Level, acc: &mut [f32], alpha: f32) {
    scale_at(level, acc, alpha)
}

/// [`rescale_at`] at the [`active`] level.
#[inline]
pub fn rescale(acc: &mut [f32], alpha: f32) {
    rescale_at(active(), acc, alpha)
}

#[cfg(test)]
mod tests {
    // These tests never call set_force: the dispatch level is process
    // global and the lib test binary runs tests concurrently, so
    // flipping it here would race the linalg/kernels/native tests.
    // Level-forcing behaviour is covered by rust/tests/simd_off.rs
    // (a single-test binary where mutating the mode is safe).
    use super::*;
    use crate::prng::Rng;

    /// Reassociation-safe bound for an n-term f32 reduction over the
    /// given operands: n * eps * sum(|terms|), padded 8x.
    fn sum_tol(terms: impl Iterator<Item = f32>, n: usize) -> f32 {
        let l1: f32 = terms.map(f32::abs).sum();
        8.0 * n as f32 * f32::EPSILON * (l1 + 1.0)
    }

    #[test]
    fn dot_portable_matches_scalar_at_every_tail() {
        for n in [0usize, 1, 2, 3, 4, 5, 6, 7, 8, 9, 15, 16, 17, 31, 32, 33, 100] {
            let x = Rng::new(n as u64 + 1).normals(n);
            let y = Rng::new(n as u64 + 1000).normals(n);
            let fast = dot_portable(&x, &y);
            let refr = dot_scalar(&x, &y);
            let tol = sum_tol(x.iter().zip(&y).map(|(a, b)| a * b), n);
            assert!((fast - refr).abs() <= tol, "n={n}: {fast} vs {refr}");
        }
    }

    #[test]
    fn dispatcher_reductions_within_twin_bound() {
        let n = 37;
        let x = Rng::new(2).normals(n);
        let y = Rng::new(3).normals(n);
        let d = dot(&x, &y);
        let tol = sum_tol(x.iter().zip(&y).map(|(a, b)| a * b), n);
        assert!((d - dot_scalar(&x, &y)).abs() <= tol);
        let s = sum_sq(&x);
        let tol = sum_tol(x.iter().map(|v| v * v), n);
        assert!((s - sum_sq_scalar(&x)).abs() <= tol);
    }

    #[test]
    fn row_max_is_exact_at_every_level_and_tail() {
        for n in [1usize, 3, 7, 8, 9, 16, 21, 64] {
            let x = Rng::new(n as u64 + 7).normals(n);
            let expect = row_max_scalar(&x);
            assert_eq!(row_max_portable(&x), expect, "portable n={n}");
            assert_eq!(row_max(&x), expect, "dispatch n={n}");
        }
    }

    #[test]
    fn exp_lane_polynomial_accuracy() {
        // relative error < 1e-6 across the softmax-relevant range, and
        // the exact anchors the twin bound leans on
        for i in 0..=2000 {
            let x = -87.0 + 87.0 * (i as f32 / 2000.0);
            let approx = exp_lane(x);
            let exact = (x as f64).exp();
            let rel = ((approx as f64) - exact).abs() / exact;
            assert!(rel < 1e-6, "x={x}: rel err {rel}");
        }
        assert_eq!(exp_lane(0.0), 1.0, "exp(0) must be exactly 1");
        assert!(exp_lane(-2e30) < 1.3e-38, "deep underflow saturates near zero");
        assert!(exp_lane(-2e30) >= 0.0);
    }

    #[test]
    fn exp_sum_portable_matches_libm_within_bound() {
        for n in [1usize, 5, 8, 13, 64] {
            let mut fast: Vec<f32> = Rng::new(n as u64 + 77).normals(n);
            // include a masked-style entry and a large logit
            if n >= 3 {
                fast[0] = -1e30;
                fast[1] = 3e4;
            }
            let mut refr = fast.clone();
            let max = row_max_scalar(&fast);
            let sf = exp_sum_portable(&mut fast, max);
            let sr = exp_sum_scalar(&mut refr, max);
            for (i, (a, b)) in fast.iter().zip(&refr).enumerate() {
                assert!((a - b).abs() <= 1e-5, "n={n} elem {i}: {a} vs {b}");
            }
            assert!((sf - sr).abs() <= 1e-4 * (1.0 + sr.abs()), "n={n}: {sf} vs {sr}");
        }
    }

    #[test]
    fn elementwise_panels_bitwise_equal_scalar() {
        for n in [0usize, 1, 7, 8, 9, 33] {
            let x = Rng::new(n as u64 + 11).normals(n);
            let base = Rng::new(n as u64 + 12).normals(n);
            let a = 0.37f32;

            let mut fast = base.clone();
            axpy(a, &x, &mut fast);
            let mut refr = base.clone();
            for (o, &v) in refr.iter_mut().zip(&x) {
                *o += a * v;
            }
            assert_eq!(fast, refr, "axpy n={n}");

            let mut fast = base.clone();
            add_assign(&mut fast, &x);
            let mut refr = base.clone();
            for (o, &v) in refr.iter_mut().zip(&x) {
                *o += v;
            }
            assert_eq!(fast, refr, "add_assign n={n}");

            let mut fast = base.clone();
            scale(&mut fast, a);
            let mut refr = base;
            for v in refr.iter_mut() {
                *v *= a;
            }
            assert_eq!(fast, refr, "scale n={n}");
        }
    }

    #[test]
    fn tile_scores_are_scaled_per_key_dots() {
        for nk in [1usize, 2, 5, 8, 11] {
            for d in [1usize, 3, 8, 17] {
                let q = Rng::new((nk * 31 + d) as u64).normals(d);
                let keys = Rng::new((nk * 37 + d) as u64).normals(nk * d);
                let scale = 0.31f32;
                let mut out = vec![0.0f32; nk];
                tile_scores(&q, &keys, d, scale, &mut out);
                for j in 0..nk {
                    let expect = dot_scalar(&q, &keys[j * d..(j + 1) * d]) * scale;
                    let tol = sum_tol(
                        q.iter().zip(&keys[j * d..(j + 1) * d]).map(|(a, b)| a * b),
                        d,
                    );
                    assert!((out[j] - expect).abs() <= tol, "nk={nk} d={d} j={j}");
                }
                // explicit Scalar level is the exact reference chain
                let mut exact = vec![0.0f32; nk];
                tile_scores_at(Level::Scalar, &q, &keys, d, scale, &mut exact);
                for j in 0..nk {
                    assert_eq!(exact[j], dot_scalar(&q, &keys[j * d..(j + 1) * d]) * scale);
                }
            }
        }
    }

    #[test]
    fn exp_one_matches_the_levels_exp_sum_numerics() {
        // the rescale factor and the tile weights must round identically
        // at a fixed level, or the streaming sum drifts
        for &x in &[-0.5f32, -3.0, -20.0, 0.0, -1e30] {
            assert_eq!(exp_one_at(Level::Scalar, x), x.exp(), "scalar twin is libm");
            let mut row = [x];
            let s = exp_sum_at(Level::Portable, &mut row, 0.0);
            assert_eq!(exp_one_at(Level::Portable, x), row[0], "x={x}");
            assert_eq!(s, row[0]);
        }
        assert_eq!(exp_one_at(Level::Portable, 0.0), 1.0);
    }

    #[test]
    fn rescale_is_bitwise_scale_at_every_length() {
        for n in [0usize, 1, 7, 8, 9, 33] {
            let base = Rng::new(n as u64 + 21).normals(n);
            let alpha = 0.731f32;
            let mut fast = base.clone();
            rescale(&mut fast, alpha);
            let mut refr = base;
            for v in refr.iter_mut() {
                *v *= alpha;
            }
            assert_eq!(fast, refr, "rescale n={n}");
        }
    }

    #[test]
    fn force_parses_and_levels_name() {
        assert_eq!("auto".parse::<Force>().unwrap(), Force::Auto);
        assert_eq!("on".parse::<Force>().unwrap(), Force::On);
        assert_eq!("OFF".parse::<Force>().unwrap(), Force::Off);
        assert!("fast".parse::<Force>().is_err());
        // whatever the host resolves to, the name round-trips
        let lvl = active();
        assert!(["scalar", "portable", "avx2", "neon"].contains(&lvl.name()));
    }
}
