//! Native CPU ports of the BSA attention kernels — parallel blocked
//! versions plus `*_reference` scalar twins.
//!
//! Each `*_reference` function mirrors its pure-jnp oracle in
//! `python/compile/kernels/ref.py` — same shapes, same masking
//! constants, same top-k tie-breaking. The un-suffixed functions are the
//! production kernels: they split their output over
//! [`pool::par_rows`](super::pool::par_rows) chunks (balls for ball
//! attention, blocks for compression, groups for selection/top-k) —
//! executed by the persistent worker pool, not per-call threads — and
//! compute each unit on the [`super::simd`] microkernels
//! ([`attend_unit`]'s dot / max / exp-sum / axpy panels, the
//! compression add/scale panels). With SIMD active the attention-family
//! kernels match their twins to the documented **1e-5** differential
//! bound (horizontal reductions reorder accumulation);
//! [`compress_mean`] and [`topk_indices`] stay bitwise, and with
//! `BSA_NATIVE_SIMD=off` every kernel runs the twin's exact scalar
//! loops. In all modes, outputs are **bitwise stable across thread
//! counts** — chunking never changes what a unit computes.
//! `rust/tests/conformance.rs` sweeps all of this across randomized
//! shapes and thread counts (see "Kernel conformance" in [`super`]).
//! The head-parallel attention in [`super::native`] calls these kernels
//! from inside pool jobs; nested dispatches are safe (the pool's waiters
//! help run queued work) and thread-count-neutral by the same invariant.
//!
//! All operands are flat row-major `(N, d)` slices for one attention
//! head; the model layer folds batch and heads before calling in here,
//! exactly like the jax side folds `(B, N, C)` to `(B*H, N, dh)`.
//!
//! Notation follows the paper (Sec. 2): ball size `m`, compression block
//! `l`, selection group `g`, `k*` selected blocks.

use super::linalg::{
    matmul, matmul_nt, matmul_nt_reference, matmul_reference, softmax_row_simd, softmax_rows,
    softmax_rows_reference,
};
use super::{pool, simd};

/// Mask value matching `ref.py::NEG_INF`: large but finite so an
/// all-masked row softmaxes to uniform instead of NaN.
pub const NEG_INF: f32 = -1e30;

/// Dense scaled-dot-product attention: `out = softmax(q k^T * scale) v`,
/// parallel over query rows (the compression branch calls this with
/// `nq = N`). `q` is `(nq, d)`, `k`/`v` are `(nk, d)`, `out` is
/// `(nq, d)`. `scores` is caller-owned scratch, resized to `nq * nk`.
#[allow(clippy::too_many_arguments)]
pub fn attend(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    nq: usize,
    nk: usize,
    d: usize,
    scale: f32,
    threads: usize,
    out: &mut [f32],
    scores: &mut Vec<f32>,
) {
    scores.resize(nq * nk, 0.0);
    matmul_nt(q, k, nq, d, nk, threads, scores);
    simd::scale(scores, scale);
    softmax_rows(scores, nq, nk, threads);
    matmul(scores, v, nq, nk, d, threads, out);
}

/// Scalar twin of [`attend`] (and the building block the parallel ball /
/// selection kernels run per unit on their own thread).
#[allow(clippy::too_many_arguments)]
pub fn attend_reference(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    nq: usize,
    nk: usize,
    d: usize,
    scale: f32,
    out: &mut [f32],
    scores: &mut Vec<f32>,
) {
    scores.resize(nq * nk, 0.0);
    matmul_nt_reference(q, k, nq, d, nk, scores);
    for s in scores.iter_mut() {
        *s *= scale;
    }
    softmax_rows_reference(scores, nq, nk);
    matmul_reference(scores, v, nq, nk, d, out);
}

/// One serial attention unit on the [`super::simd`] microkernels: per
/// query row, `simd::dot` scores against every key, the row softmax
/// panels, and an ascending-key `simd::axpy` accumulation of the
/// values — the same per-element op sequence as the parallel
/// [`attend`] composition, so a ball/selection unit computed here is a
/// 1e-5 twin of [`attend_reference`] when SIMD is active. When SIMD is
/// off this delegates to the scalar twin verbatim, keeping the
/// `BSA_NATIVE_SIMD=off` path bitwise. The ball and selection kernels
/// run this per chunk unit; thread counts never change what a unit
/// computes.
#[allow(clippy::too_many_arguments)]
fn attend_unit(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    nq: usize,
    nk: usize,
    d: usize,
    scale: f32,
    out: &mut [f32],
    scores: &mut Vec<f32>,
) {
    let lvl = simd::active();
    if lvl == simd::Level::Scalar {
        attend_reference(q, k, v, nq, nk, d, scale, out, scores);
        return;
    }
    scores.resize(nq * nk, 0.0);
    for i in 0..nq {
        let qrow = &q[i * d..(i + 1) * d];
        let srow = &mut scores[i * nk..(i + 1) * nk];
        for (j, s) in srow.iter_mut().enumerate() {
            *s = simd::dot_at(lvl, qrow, &k[j * d..(j + 1) * d]) * scale;
        }
        softmax_row_simd(lvl, srow);
        let orow = &mut out[i * d..(i + 1) * d];
        orow.fill(0.0);
        for (j, &w) in srow.iter().enumerate() {
            simd::axpy_at(lvl, w, &v[j * d..(j + 1) * d], orow);
        }
    }
}

/// Ball attention (paper eq. 3): full attention inside disjoint balls of
/// `ball_size` tokens, one ball-batch per thread chunk. `q`/`k`/`v`/`out`
/// are `(n, d)` with `n % ball_size == 0` (the ball tree guarantees this
/// by padding).
#[allow(clippy::too_many_arguments)]
pub fn ball_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    ball_size: usize,
    threads: usize,
    out: &mut [f32],
) {
    assert_eq!(n % ball_size, 0, "n must be divisible by ball size");
    assert_eq!(out.len(), n * d, "ball_attention out len");
    let scale = 1.0 / (d as f32).sqrt();
    let chunk = ball_size * d;
    pool::par_rows(out, chunk, threads, |ball0, ochunk| {
        let mut scores = Vec::new();
        for (bi, oball) in ochunk.chunks_exact_mut(chunk).enumerate() {
            let r = (ball0 + bi) * chunk..(ball0 + bi + 1) * chunk;
            attend_unit(
                &q[r.clone()],
                &k[r.clone()],
                &v[r],
                ball_size,
                ball_size,
                d,
                scale,
                oball,
                &mut scores,
            );
        }
    });
}

/// Scalar twin of [`ball_attention`] (caller-owned `scores` scratch,
/// like the original serial kernel).
#[allow(clippy::too_many_arguments)]
pub fn ball_attention_reference(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    ball_size: usize,
    out: &mut [f32],
    scores: &mut Vec<f32>,
) {
    assert_eq!(n % ball_size, 0, "n must be divisible by ball size");
    let scale = 1.0 / (d as f32).sqrt();
    let chunk = ball_size * d;
    for b in 0..n / ball_size {
        let r = b * chunk..(b + 1) * chunk;
        attend_reference(
            &q[r.clone()],
            &k[r.clone()],
            &v[r.clone()],
            ball_size,
            ball_size,
            d,
            scale,
            &mut out[r],
            scores,
        );
    }
}

/// Compression pooling phi = mean (paper eq. 5): mean-pool
/// non-overlapping blocks of `block` tokens, `(n, d) -> (n/block, d)`,
/// parallel over block chunks. Built only from the element-parallel
/// [`simd::add_assign`] / [`simd::scale`] panels, so it stays
/// **bitwise equal** to [`compress_mean_reference`] at every SIMD
/// level and thread count.
pub fn compress_mean(x: &[f32], n: usize, d: usize, block: usize, threads: usize, out: &mut [f32]) {
    assert_eq!(n % block, 0, "n must be divisible by block");
    let nb = n / block;
    assert_eq!(out.len(), nb * d, "compress out len");
    let inv = 1.0 / block as f32;
    let lvl = simd::active();
    pool::par_rows(out, d, threads, |b0, ochunk| {
        for (bi, orow) in ochunk.chunks_exact_mut(d).enumerate() {
            let b = b0 + bi;
            orow.fill(0.0);
            for t in 0..block {
                simd::add_assign_at(lvl, orow, &x[(b * block + t) * d..(b * block + t + 1) * d]);
            }
            simd::scale_at(lvl, orow, inv);
        }
    });
}

/// Scalar twin of [`compress_mean`].
pub fn compress_mean_reference(x: &[f32], n: usize, d: usize, block: usize, out: &mut [f32]) {
    assert_eq!(n % block, 0, "n must be divisible by block");
    let nb = n / block;
    assert_eq!(out.len(), nb * d, "compress out len");
    let inv = 1.0 / block as f32;
    for b in 0..nb {
        let orow = &mut out[b * d..(b + 1) * d];
        orow.fill(0.0);
        for t in 0..block {
            let xrow = &x[(b * block + t) * d..(b * block + t + 1) * d];
            for (o, &v) in orow.iter_mut().zip(xrow) {
                *o += v;
            }
        }
        for o in orow.iter_mut() {
            *o *= inv;
        }
    }
}

/// Group-averaged importance scores S-bar (paper eq. 12): scores of the
/// group-mean query against each compressed key, **unscaled** (they only
/// rank blocks, matching `ref_group_scores`). `q` is `(n, d)`, `kc` is
/// `(nb, d)`, `out` is `(n/group, nb)`; `qg` is `(n/group) * d` scratch.
#[allow(clippy::too_many_arguments)]
pub fn group_scores(
    q: &[f32],
    kc: &[f32],
    n: usize,
    d: usize,
    group: usize,
    nb: usize,
    threads: usize,
    qg: &mut Vec<f32>,
    out: &mut [f32],
) {
    assert_eq!(n % group, 0, "n must be divisible by group");
    let groups = n / group;
    qg.resize(groups * d, 0.0);
    compress_mean(q, n, d, group, threads, qg);
    matmul_nt(qg, kc, groups, d, nb, threads, out);
}

/// Scalar twin of [`group_scores`].
#[allow(clippy::too_many_arguments)]
pub fn group_scores_reference(
    q: &[f32],
    kc: &[f32],
    n: usize,
    d: usize,
    group: usize,
    nb: usize,
    qg: &mut Vec<f32>,
    out: &mut [f32],
) {
    assert_eq!(n % group, 0, "n must be divisible by group");
    let groups = n / group;
    qg.resize(groups * d, 0.0);
    compress_mean_reference(q, n, d, group, qg);
    matmul_nt_reference(qg, kc, groups, d, nb, out);
}

/// Mask scores of compressed blocks inside the query group's own ball
/// (paper Sec. 3.2): selection should reach *outside* the coverage ball
/// attention already provides. `scores` is `(groups, nb)`. Elementwise
/// and branch-free per cell, so it is its own reference (shared by the
/// parallel and reference forward paths).
pub fn mask_own_ball(scores: &mut [f32], groups: usize, nb: usize, group: usize, cmp_block: usize, ball_size: usize) {
    assert_eq!(scores.len(), groups * nb, "mask scores len");
    for gi in 0..groups {
        let gball = gi * group / ball_size;
        let row = &mut scores[gi * nb..(gi + 1) * nb];
        for (bi, s) in row.iter_mut().enumerate() {
            if bi * cmp_block / ball_size == gball {
                *s = NEG_INF;
            }
        }
    }
}

/// Per-group first-max argmax-and-suppress top-k for one score row
/// (bit-matching `ref_topk_indices`' tie-breaking: strict `>` keeps the
/// first occurrence, like `jnp.argmax`). `row` is clobbered.
fn topk_row(row: &mut [f32], k: usize, out: &mut [usize]) {
    for slot in out.iter_mut().take(k) {
        let mut best = 0usize;
        let mut bv = f32::NEG_INFINITY;
        for (i, &v) in row.iter().enumerate() {
            if v > bv {
                bv = v;
                best = i;
            }
        }
        *slot = best;
        row[best] -= 2e30;
    }
    out[..k].sort_unstable();
}

/// Top-k block indices per score row, ascending-sorted (contiguous
/// gathers downstream), parallel over group-row chunks. `out` is resized
/// to `groups * k`.
pub fn topk_indices(scores: &[f32], groups: usize, nb: usize, k: usize, threads: usize, out: &mut Vec<usize>) {
    assert_eq!(scores.len(), groups * nb, "topk scores len");
    assert!(k <= nb, "top_k {k} exceeds block count {nb}");
    out.clear();
    out.resize(groups * k, 0);
    if k == 0 {
        return;
    }
    pool::par_rows(out.as_mut_slice(), k, threads, |g0, ochunk| {
        let mut row = vec![0.0f32; nb];
        for (gi, oslot) in ochunk.chunks_exact_mut(k).enumerate() {
            row.copy_from_slice(&scores[(g0 + gi) * nb..(g0 + gi + 1) * nb]);
            topk_row(&mut row, k, oslot);
        }
    });
}

/// Scalar twin of [`topk_indices`]: k rounds of argmax-and-suppress per
/// row, single thread (ref.py avoids `lax.top_k` for AOT-toolchain
/// reasons; k* is 4 in the paper, so the loop is tiny either way).
pub fn topk_indices_reference(scores: &[f32], groups: usize, nb: usize, k: usize, out: &mut Vec<usize>) {
    assert_eq!(scores.len(), groups * nb, "topk scores len");
    assert!(k <= nb, "top_k {k} exceeds block count {nb}");
    out.clear();
    out.resize(groups * k, 0);
    if k == 0 {
        return;
    }
    let mut row = vec![0.0f32; nb];
    for gi in 0..groups {
        row.copy_from_slice(&scores[gi * nb..(gi + 1) * nb]);
        topk_row(&mut row, k, &mut out[gi * k..(gi + 1) * k]);
    }
}

/// Grouped selection attention (paper eqs. 6-8, 10-12): every query in
/// group `p` attends the `k*` selected blocks of `sel_block` tokens given
/// by `idx[p]`, parallel over group chunks (gather scratch is
/// per-thread). `q`/`k`/`v`/`out` are `(n, d)`; `idx` is `groups * k`
/// flat.
#[allow(clippy::too_many_arguments)]
pub fn select_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    idx: &[usize],
    n: usize,
    d: usize,
    sel_block: usize,
    group: usize,
    top_k: usize,
    threads: usize,
    out: &mut [f32],
) {
    assert_eq!(n % group, 0, "n must be divisible by group");
    let groups = n / group;
    assert_eq!(idx.len(), groups * top_k, "idx len");
    assert_eq!(out.len(), n * d, "select_attention out len");
    let scale = 1.0 / (d as f32).sqrt();
    let blk = sel_block * d;
    let gd = group * d;
    pool::par_rows(out, gd, threads, |p0, ochunk| {
        let mut ksel = vec![0.0f32; top_k * blk];
        let mut vsel = vec![0.0f32; top_k * blk];
        let mut scores = Vec::new();
        for (pi, ogroup) in ochunk.chunks_exact_mut(gd).enumerate() {
            let p = p0 + pi;
            for (j, &bi) in idx[p * top_k..(p + 1) * top_k].iter().enumerate() {
                debug_assert!((bi + 1) * blk <= k.len(), "block index {bi} out of range");
                ksel[j * blk..(j + 1) * blk].copy_from_slice(&k[bi * blk..(bi + 1) * blk]);
                vsel[j * blk..(j + 1) * blk].copy_from_slice(&v[bi * blk..(bi + 1) * blk]);
            }
            attend_unit(
                &q[p * gd..(p + 1) * gd],
                &ksel,
                &vsel,
                group,
                top_k * sel_block,
                d,
                scale,
                ogroup,
                &mut scores,
            );
        }
    });
}

/// Scalar twin of [`select_attention`] (caller-owned gather scratch,
/// like the original serial kernel).
#[allow(clippy::too_many_arguments)]
pub fn select_attention_reference(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    idx: &[usize],
    n: usize,
    d: usize,
    sel_block: usize,
    group: usize,
    top_k: usize,
    out: &mut [f32],
    ksel: &mut Vec<f32>,
    vsel: &mut Vec<f32>,
    scores: &mut Vec<f32>,
) {
    assert_eq!(n % group, 0, "n must be divisible by group");
    let groups = n / group;
    assert_eq!(idx.len(), groups * top_k, "idx len");
    let scale = 1.0 / (d as f32).sqrt();
    let blk = sel_block * d;
    ksel.resize(top_k * blk, 0.0);
    vsel.resize(top_k * blk, 0.0);
    for p in 0..groups {
        for (j, &bi) in idx[p * top_k..(p + 1) * top_k].iter().enumerate() {
            debug_assert!((bi + 1) * blk <= k.len(), "block index {bi} out of range");
            ksel[j * blk..(j + 1) * blk].copy_from_slice(&k[bi * blk..(bi + 1) * blk]);
            vsel[j * blk..(j + 1) * blk].copy_from_slice(&v[bi * blk..(bi + 1) * blk]);
        }
        let qr = p * group * d..(p + 1) * group * d;
        attend_reference(
            &q[qr.clone()],
            ksel,
            vsel,
            group,
            top_k * sel_block,
            d,
            scale,
            &mut out[qr],
            scores,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn rand(n: usize, seed: u64) -> Vec<f32> {
        Rng::new(seed).normals(n)
    }

    #[test]
    fn attend_uniform_when_keys_identical() {
        // identical keys => uniform weights => output = mean of values
        let d = 4;
        let q = rand(d, 0);
        let k = [vec![1.0f32; d], vec![1.0f32; d]].concat();
        let v = [vec![2.0f32; d], vec![4.0f32; d]].concat();
        let mut out = vec![0.0f32; d];
        let mut s = Vec::new();
        attend(&q, &k, &v, 1, 2, d, 0.5, 2, &mut out, &mut s);
        for &o in &out {
            assert!((o - 3.0).abs() < 1e-6);
        }
        let mut refr = vec![0.0f32; d];
        attend_reference(&q, &k, &v, 1, 2, d, 0.5, &mut refr, &mut s);
        assert_eq!(out, refr);
    }

    #[test]
    fn ball_attention_is_blockwise_dense() {
        // one ball spanning everything == plain dense attention
        let (n, d) = (8, 4);
        let q = rand(n * d, 1);
        let k = rand(n * d, 2);
        let v = rand(n * d, 3);
        let mut whole = vec![0.0f32; n * d];
        let mut dense = vec![0.0f32; n * d];
        let mut s = Vec::new();
        ball_attention(&q, &k, &v, n, d, n, 2, &mut whole);
        attend_reference(&q, &k, &v, n, n, d, 1.0 / (d as f32).sqrt(), &mut dense, &mut s);
        // 1e-5 (not bitwise): with SIMD active the unit's reductions
        // reorder accumulation vs the scalar reference (the twin rule).
        for (a, b) in whole.iter().zip(&dense) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }

        // two balls: each half ignores the other (change the far half's
        // values, near half's output must not move)
        let mut halves = vec![0.0f32; n * d];
        ball_attention(&q, &k, &v, n, d, n / 2, 2, &mut halves);
        let mut v2 = v.clone();
        for x in &mut v2[n / 2 * d..] {
            *x += 100.0;
        }
        let mut halves2 = vec![0.0f32; n * d];
        ball_attention(&q, &k, &v2, n, d, n / 2, 2, &mut halves2);
        assert_eq!(halves[..n / 2 * d], halves2[..n / 2 * d]);
        assert_ne!(halves[n / 2 * d..], halves2[n / 2 * d..]);
    }

    #[test]
    fn compress_mean_pools_blocks() {
        // rows 0..3 constant per row, block 2 => means of row pairs
        let x = [0.0f32, 0.0, 1.0, 1.0, 2.0, 2.0, 4.0, 4.0];
        let mut out = vec![0.0f32; 4];
        compress_mean(&x, 4, 2, 2, 2, &mut out);
        assert_eq!(out, [0.5, 0.5, 3.0, 3.0]);
        let mut refr = vec![0.0f32; 4];
        compress_mean_reference(&x, 4, 2, 2, &mut refr);
        assert_eq!(out, refr);
    }

    #[test]
    fn own_ball_mask_hits_exactly_own_blocks() {
        // n=16, group 4, cmp 2, ball 8: groups 0-1 in ball 0, blocks 0-3
        let groups = 4;
        let nb = 8;
        let mut scores = vec![1.0f32; groups * nb];
        mask_own_ball(&mut scores, groups, nb, 4, 2, 8);
        for gi in 0..groups {
            for bi in 0..nb {
                let masked = scores[gi * nb + bi] == NEG_INF;
                let same_ball = (gi * 4) / 8 == (bi * 2) / 8;
                assert_eq!(masked, same_ball, "gi {gi} bi {bi}");
            }
        }
    }

    #[test]
    fn topk_picks_largest_sorted_and_first_on_ties() {
        let scores = [0.1f32, 5.0, 3.0, 5.0, -1.0, 4.0];
        let mut out = Vec::new();
        topk_indices(&scores, 1, 6, 3, 2, &mut out);
        // picks: 1 (first 5.0), 3 (second 5.0), 5 (4.0) -> sorted
        assert_eq!(out, vec![1, 3, 5]);
        let mut refr = Vec::new();
        topk_indices_reference(&scores, 1, 6, 3, &mut refr);
        assert_eq!(out, refr);
    }

    #[test]
    fn select_attention_equals_dense_when_selection_covers_all() {
        // top_k * sel_block == n and idx = all blocks => dense attention
        // per group of queries over the whole sequence.
        let (n, d, l, g) = (8usize, 4usize, 2usize, 4usize);
        let q = rand(n * d, 7);
        let k = rand(n * d, 8);
        let v = rand(n * d, 9);
        let top_k = n / l;
        let idx: Vec<usize> = (0..n / g).flat_map(|_| 0..top_k).collect();
        let mut sel = vec![0.0f32; n * d];
        select_attention(&q, &k, &v, &idx, n, d, l, g, top_k, 2, &mut sel);
        let mut sc = Vec::new();
        let mut dense = vec![0.0f32; n * d];
        attend_reference(&q, &k, &v, n, n, d, 1.0 / (d as f32).sqrt(), &mut dense, &mut sc);
        for (a, b) in sel.iter().zip(&dense) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }
}
