//! Native CPU ports of the BSA attention kernels — parallel blocked
//! versions plus `*_reference` scalar twins.
//!
//! The attention family is **streaming** (flash-style, the recipe of
//! `python/compile/kernels/flash_attention.py`): keys are consumed in
//! fixed [`STREAM_TILE`]-wide tiles with an online softmax (running
//! max / exp-sum / rescaled output accumulator), so no kernel ever
//! materializes an `nq * nk` score matrix — the only score storage is
//! one stack tile per worker. [`attend_streaming`] has a scalar twin
//! [`attend_streaming_reference`] (the same tiled loop pinned at the
//! scalar SIMD level), and the old materialize-then-softmax composition
//! survives as [`attend_materialized`] / [`attend_reference`] — the
//! latter still mirrors the pure-jnp oracle in
//! `python/compile/kernels/ref.py` bit-for-bit and serves as the
//! *materialized oracle* the streaming kernels are differentially
//! tested against (streaming reorders the softmax reduction, so that
//! comparison carries the documented 1e-5 tier, not bitwise).
//!
//! The remaining `*_reference` twins mirror ref.py's shapes, masking
//! constants, and top-k tie-breaking; the ball/selection references run
//! the scalar streaming loop per unit since the streaming kernel
//! landed. The un-suffixed functions are the production kernels: they
//! split their output over [`pool::par_rows`](super::pool::par_rows)
//! chunks (balls for ball attention, blocks for compression, groups
//! for selection/top-k) — executed by the persistent worker pool, not
//! per-call threads — and compute each unit on the [`super::simd`]
//! microkernels ([`stream_row`]'s tile-score / max / exp-sum / rescale
//! / axpy panels, the compression add/scale panels). With SIMD active
//! the attention-family kernels match their twins to the documented
//! **1e-5** differential bound (horizontal reductions reorder
//! accumulation); [`compress_mean`] and [`topk_indices`] stay bitwise,
//! and with `BSA_NATIVE_SIMD=off` every kernel runs the twin's exact
//! scalar loops. In all modes, outputs are **bitwise stable across
//! thread counts** — chunking never changes what a unit computes.
//! `rust/tests/conformance.rs` sweeps all of this across randomized
//! shapes and thread counts (see "Kernel conformance" in [`super`]).
//! The head-parallel attention in [`super::native`] calls these kernels
//! from inside pool jobs; nested dispatches are safe (the pool's waiters
//! help run queued work) and thread-count-neutral by the same invariant.
//!
//! **No tracing instrumentation lives in this module.** Per-stage spans
//! (`forward.layer.ball_attention` / `compression` / `selection`, see
//! [`crate::trace`]) are recorded at the per-unit call sites in
//! [`super::native`]: kernels are the bitwise-contract surface, and a
//! span guard inside a chunk loop would both perturb the hot loops and
//! record at the wrong grain (per chunk, not per stage). Timing here is
//! observable but never numeric — instrumentation cannot change what a
//! unit computes.
//!
//! All operands are flat row-major `(N, d)` slices for one attention
//! head; the model layer folds batch and heads before calling in here,
//! exactly like the jax side folds `(B, N, C)` to `(B*H, N, dh)`.
//!
//! Notation follows the paper (Sec. 2): ball size `m`, compression block
//! `l`, selection group `g`, `k*` selected blocks.

use super::linalg::{
    matmul, matmul_nt, matmul_nt_reference, matmul_reference, softmax_rows,
    softmax_rows_reference,
};
use super::{pool, simd};

/// Mask value matching `ref.py::NEG_INF`: large but finite so an
/// all-masked row softmaxes to uniform instead of NaN.
pub const NEG_INF: f32 = -1e30;

/// Key-tile width of the streaming attention kernels: per query row,
/// keys are consumed in fixed tiles of this many scores — the *only*
/// score storage the streaming path ever holds (one stack buffer per
/// worker), vs the `nq * nk` matrix the materialized path allocates.
pub const STREAM_TILE: usize = 64;

/// Dense scaled-dot-product attention: `out = softmax(q k^T * scale) v`.
/// `q` is `(nq, d)`, `k`/`v` are `(nk, d)`, `out` is `(nq, d)`.
///
/// Since the fused streaming kernel landed this is an alias for
/// [`attend_streaming`] — one pass over the keys, online softmax, no
/// `nq * nk` score buffer. The caller-owned `scores` scratch is kept
/// for call-compatibility and *shrunk* (see [`attend_streaming`]); the
/// old materialize-then-softmax composition survives as
/// [`attend_materialized`] for benches and differential tests.
#[allow(clippy::too_many_arguments)]
pub fn attend(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    nq: usize,
    nk: usize,
    d: usize,
    scale: f32,
    threads: usize,
    out: &mut [f32],
    scores: &mut Vec<f32>,
) {
    attend_streaming(q, k, v, nq, nk, d, scale, threads, out, scores)
}

/// Fused streaming attention (flash-style, ROADMAP item 3): a single
/// pass over the keys in [`STREAM_TILE`]-wide tiles, each query row
/// maintaining a running max `m`, exp-sum `l`, and output accumulator
/// with the standard online-softmax rescale `acc *= exp(m_old - m_new)`
/// — the recipe of `python/compile/kernels/flash_attention.py`.
/// Parallel over query rows; per-row work runs on the [`super::simd`]
/// streaming panels (`tile_scores` / `row_max` / `exp_sum` / `exp_one`
/// / `rescale` / `axpy`), with all accumulation in f32.
///
/// Memory: no `nq * nk` score matrix is ever allocated — each worker
/// keeps one [`STREAM_TILE`] score tile on its stack. The caller-owned
/// `scores` scratch (signature-compatible with [`attend_materialized`])
/// is cleared and shrunk to at most [`STREAM_TILE`] capacity, so
/// pooled scratch free-lists (e.g. `native::HeadScratch`) stop pinning
/// one large unit's `nq * nk` peak for the process lifetime.
///
/// Numerics (the documented tiers — see "Kernel conformance" in
/// [`super`]): vs the scalar twin [`attend_streaming_reference`] this
/// is a 1e-5 differential twin at SIMD levels and **bitwise** under
/// `BSA_NATIVE_SIMD=off`; vs the materialized oracle
/// [`attend_reference`] the streaming reordering of the softmax
/// reduction also stays within the same 1e-5 sweep bound. A query row
/// whose whole tile sweep has `max == -inf` produces the uniform value
/// mean, mirroring `softmax_rows`' documented uniform-instead-of-NaN
/// behavior for all-masked rows (see [`stream_row`]).
#[allow(clippy::too_many_arguments)]
pub fn attend_streaming(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    nq: usize,
    nk: usize,
    d: usize,
    scale: f32,
    threads: usize,
    out: &mut [f32],
    scores: &mut Vec<f32>,
) {
    debug_assert_eq!(out.len(), nq * d, "attend out len");
    // Streaming-mode scratch is tile-sized by contract: release any
    // nq*nk capacity a previous materialized call left behind.
    scores.clear();
    if scores.capacity() > STREAM_TILE {
        scores.shrink_to(STREAM_TILE);
    }
    let lvl = simd::active();
    pool::par_rows(out, d, threads, |q0, ochunk| {
        let mut tile = [0.0f32; STREAM_TILE];
        for (i, orow) in ochunk.chunks_exact_mut(d).enumerate() {
            let p = q0 + i;
            stream_row(lvl, &q[p * d..(p + 1) * d], k, v, nk, d, scale, orow, &mut tile);
        }
    });
}

/// Scalar twin of [`attend_streaming`]: the same tiled online-softmax
/// loop pinned at [`simd::Level::Scalar`] (libm exp, left-to-right
/// reduction chains), serial. Bitwise-equal to the fast kernel under
/// `BSA_NATIVE_SIMD=off` at every thread count; differs from the
/// materialized [`attend_reference`] only by the streaming reduction
/// order (the 1e-5 tier).
#[allow(clippy::too_many_arguments)]
pub fn attend_streaming_reference(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    nq: usize,
    nk: usize,
    d: usize,
    scale: f32,
    out: &mut [f32],
    scores: &mut Vec<f32>,
) {
    debug_assert_eq!(out.len(), nq * d, "attend out len");
    scores.clear();
    if scores.capacity() > STREAM_TILE {
        scores.shrink_to(STREAM_TILE);
    }
    let mut tile = [0.0f32; STREAM_TILE];
    for i in 0..nq {
        stream_row(
            simd::Level::Scalar,
            &q[i * d..(i + 1) * d],
            k,
            v,
            nk,
            d,
            scale,
            &mut out[i * d..(i + 1) * d],
            &mut tile,
        );
    }
}

/// The pre-streaming composition (materialize `nq * nk` scores, scale,
/// row softmax, dense matmul with the values), kept as the bench A/B
/// comparator and a second differential oracle. `scores` is resized to
/// `nq * nk` — this is the path whose peak memory the streaming kernel
/// exists to avoid.
#[allow(clippy::too_many_arguments)]
pub fn attend_materialized(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    nq: usize,
    nk: usize,
    d: usize,
    scale: f32,
    threads: usize,
    out: &mut [f32],
    scores: &mut Vec<f32>,
) {
    scores.resize(nq * nk, 0.0);
    matmul_nt(q, k, nq, d, nk, threads, scores);
    simd::scale(scores, scale);
    softmax_rows(scores, nq, nk, threads);
    matmul(scores, v, nq, nk, d, threads, out);
}

/// Scalar materialized oracle: mirrors the pure-jnp
/// `ref.py::ref_attend` composition bit-for-bit (full score matrix,
/// reference softmax, reference matmul). The streaming kernels are
/// differentially tested against this at the 1e-5 tier; the scalar
/// *streaming* twin is [`attend_streaming_reference`].
#[allow(clippy::too_many_arguments)]
pub fn attend_reference(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    nq: usize,
    nk: usize,
    d: usize,
    scale: f32,
    out: &mut [f32],
    scores: &mut Vec<f32>,
) {
    scores.resize(nq * nk, 0.0);
    matmul_nt_reference(q, k, nq, d, nk, scores);
    for s in scores.iter_mut() {
        *s *= scale;
    }
    softmax_rows_reference(scores, nq, nk);
    matmul_reference(scores, v, nq, nk, d, out);
}

/// One query row of the streaming kernel at an explicit SIMD level:
/// walk the keys in [`STREAM_TILE`]-wide tiles keeping the running max
/// `m`, exp-sum `l`, and the value accumulator in `orow` (always f32 —
/// reduced-precision *storage* happens a layer up, in
/// `native`'s forward staging). Per tile: scaled scores
/// ([`simd::tile_scores_at`]), the tile max, an
/// `alpha = exp(m - m_new)` rescale of `orow` and `l` when the max
/// rises ([`simd::exp_one_at`] + [`simd::rescale_at`] — same exp
/// rounding as the weights, element-parallel rescale), in-place
/// exponentials summed into `l` ([`simd::exp_sum_at`]), and an
/// ascending-key [`simd::axpy_at`] of the weights into `orow`. The
/// final `1/l` normalization replaces the softmax division.
///
/// All-masked semantics: a tile whose max is `-inf` (true infinities —
/// the finite [`NEG_INF`] never triggers this) contributes nothing and
/// is skipped, because `exp(-inf - -inf)` is NaN. If the *whole* sweep
/// was skipped (`l == 0` at the end) the row degrades to the uniform
/// value mean — the same "uniform instead of NaN" contract
/// `softmax_rows` documents for all-masked rows. Rows masked with the
/// finite [`NEG_INF`] take the ordinary path and land on the same
/// uniform row, exactly like the materialized kernel.
#[allow(clippy::too_many_arguments)]
fn stream_row(
    lvl: simd::Level,
    qrow: &[f32],
    k: &[f32],
    v: &[f32],
    nk: usize,
    d: usize,
    scale: f32,
    orow: &mut [f32],
    tile: &mut [f32; STREAM_TILE],
) {
    let mut m = f32::NEG_INFINITY;
    let mut l = 0.0f32;
    orow.fill(0.0);
    let mut j0 = 0usize;
    while j0 < nk {
        let tl = STREAM_TILE.min(nk - j0);
        let t = &mut tile[..tl];
        simd::tile_scores_at(lvl, qrow, &k[j0 * d..(j0 + tl) * d], d, scale, t);
        let tmax = simd::row_max_at(lvl, t);
        if tmax == f32::NEG_INFINITY {
            j0 += tl;
            continue;
        }
        if tmax > m {
            if l > 0.0 {
                let alpha = simd::exp_one_at(lvl, m - tmax);
                simd::rescale_at(lvl, orow, alpha);
                l *= alpha;
            }
            m = tmax;
        }
        l += simd::exp_sum_at(lvl, t, m);
        for (jj, &w) in t.iter().enumerate() {
            let j = j0 + jj;
            simd::axpy_at(lvl, w, &v[j * d..(j + 1) * d], orow);
        }
        j0 += tl;
    }
    if l > 0.0 {
        simd::scale_at(lvl, orow, 1.0 / l);
    } else {
        // every tile was -inf-masked (or nk == 0): uniform value mean
        let w = 1.0 / nk as f32;
        for j in 0..nk {
            simd::axpy_at(lvl, w, &v[j * d..(j + 1) * d], orow);
        }
    }
}

/// One streaming attention unit on the caller's thread — the per-ball /
/// per-group body of [`ball_attention`] and [`select_attention`]:
/// [`stream_row`] per query at the active SIMD level, one stack tile as
/// the only score storage. Under `BSA_NATIVE_SIMD=off` this runs
/// [`attend_streaming_reference`]'s exact loop, which keeps the ball
/// and selection kernels bitwise twins of their references in scalar
/// mode; thread counts never change what a unit computes.
fn attend_unit(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    nq: usize,
    nk: usize,
    d: usize,
    scale: f32,
    out: &mut [f32],
) {
    let lvl = simd::active();
    let mut tile = [0.0f32; STREAM_TILE];
    for i in 0..nq {
        stream_row(
            lvl,
            &q[i * d..(i + 1) * d],
            k,
            v,
            nk,
            d,
            scale,
            &mut out[i * d..(i + 1) * d],
            &mut tile,
        );
    }
}

/// Ball attention (paper eq. 3): full attention inside disjoint balls of
/// `ball_size` tokens, one ball-batch per thread chunk. `q`/`k`/`v`/`out`
/// are `(n, d)` with `n % ball_size == 0` (the ball tree guarantees this
/// by padding).
#[allow(clippy::too_many_arguments)]
pub fn ball_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    ball_size: usize,
    threads: usize,
    out: &mut [f32],
) {
    assert_eq!(n % ball_size, 0, "n must be divisible by ball size");
    assert_eq!(out.len(), n * d, "ball_attention out len");
    let scale = 1.0 / (d as f32).sqrt();
    let chunk = ball_size * d;
    pool::par_rows(out, chunk, threads, |ball0, ochunk| {
        for (bi, oball) in ochunk.chunks_exact_mut(chunk).enumerate() {
            let r = (ball0 + bi) * chunk..(ball0 + bi + 1) * chunk;
            attend_unit(&q[r.clone()], &k[r.clone()], &v[r], ball_size, ball_size, d, scale, oball);
        }
    });
}

/// Scalar twin of [`ball_attention`]: the scalar streaming loop
/// ([`attend_streaming_reference`]) per ball, serial. The `scores`
/// scratch is kept for call-compatibility with the original serial
/// kernel and stays tile-sized under the streaming contract.
#[allow(clippy::too_many_arguments)]
pub fn ball_attention_reference(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    n: usize,
    d: usize,
    ball_size: usize,
    out: &mut [f32],
    scores: &mut Vec<f32>,
) {
    assert_eq!(n % ball_size, 0, "n must be divisible by ball size");
    let scale = 1.0 / (d as f32).sqrt();
    let chunk = ball_size * d;
    for b in 0..n / ball_size {
        let r = b * chunk..(b + 1) * chunk;
        attend_streaming_reference(
            &q[r.clone()],
            &k[r.clone()],
            &v[r.clone()],
            ball_size,
            ball_size,
            d,
            scale,
            &mut out[r],
            scores,
        );
    }
}

/// Compression pooling phi = mean (paper eq. 5): mean-pool
/// non-overlapping blocks of `block` tokens, `(n, d) -> (n/block, d)`,
/// parallel over block chunks. Built only from the element-parallel
/// [`simd::add_assign`] / [`simd::scale`] panels, so it stays
/// **bitwise equal** to [`compress_mean_reference`] at every SIMD
/// level and thread count.
pub fn compress_mean(x: &[f32], n: usize, d: usize, block: usize, threads: usize, out: &mut [f32]) {
    assert_eq!(n % block, 0, "n must be divisible by block");
    let nb = n / block;
    assert_eq!(out.len(), nb * d, "compress out len");
    let inv = 1.0 / block as f32;
    let lvl = simd::active();
    pool::par_rows(out, d, threads, |b0, ochunk| {
        for (bi, orow) in ochunk.chunks_exact_mut(d).enumerate() {
            let b = b0 + bi;
            orow.fill(0.0);
            for t in 0..block {
                simd::add_assign_at(lvl, orow, &x[(b * block + t) * d..(b * block + t + 1) * d]);
            }
            simd::scale_at(lvl, orow, inv);
        }
    });
}

/// Scalar twin of [`compress_mean`].
pub fn compress_mean_reference(x: &[f32], n: usize, d: usize, block: usize, out: &mut [f32]) {
    assert_eq!(n % block, 0, "n must be divisible by block");
    let nb = n / block;
    assert_eq!(out.len(), nb * d, "compress out len");
    let inv = 1.0 / block as f32;
    for b in 0..nb {
        let orow = &mut out[b * d..(b + 1) * d];
        orow.fill(0.0);
        for t in 0..block {
            let xrow = &x[(b * block + t) * d..(b * block + t + 1) * d];
            for (o, &v) in orow.iter_mut().zip(xrow) {
                *o += v;
            }
        }
        for o in orow.iter_mut() {
            *o *= inv;
        }
    }
}

/// Group-averaged importance scores S-bar (paper eq. 12): scores of the
/// group-mean query against each compressed key, **unscaled** (they only
/// rank blocks, matching `ref_group_scores`). `q` is `(n, d)`, `kc` is
/// `(nb, d)`, `out` is `(n/group, nb)`; `qg` is `(n/group) * d` scratch.
#[allow(clippy::too_many_arguments)]
pub fn group_scores(
    q: &[f32],
    kc: &[f32],
    n: usize,
    d: usize,
    group: usize,
    nb: usize,
    threads: usize,
    qg: &mut Vec<f32>,
    out: &mut [f32],
) {
    assert_eq!(n % group, 0, "n must be divisible by group");
    let groups = n / group;
    qg.resize(groups * d, 0.0);
    compress_mean(q, n, d, group, threads, qg);
    matmul_nt(qg, kc, groups, d, nb, threads, out);
}

/// Scalar twin of [`group_scores`].
#[allow(clippy::too_many_arguments)]
pub fn group_scores_reference(
    q: &[f32],
    kc: &[f32],
    n: usize,
    d: usize,
    group: usize,
    nb: usize,
    qg: &mut Vec<f32>,
    out: &mut [f32],
) {
    assert_eq!(n % group, 0, "n must be divisible by group");
    let groups = n / group;
    qg.resize(groups * d, 0.0);
    compress_mean_reference(q, n, d, group, qg);
    matmul_nt_reference(qg, kc, groups, d, nb, out);
}

/// Mask scores of compressed blocks inside the query group's own ball
/// (paper Sec. 3.2): selection should reach *outside* the coverage ball
/// attention already provides. `scores` is `(groups, nb)`. Elementwise
/// and branch-free per cell, so it is its own reference (shared by the
/// parallel and reference forward paths).
pub fn mask_own_ball(scores: &mut [f32], groups: usize, nb: usize, group: usize, cmp_block: usize, ball_size: usize) {
    assert_eq!(scores.len(), groups * nb, "mask scores len");
    for gi in 0..groups {
        let gball = gi * group / ball_size;
        let row = &mut scores[gi * nb..(gi + 1) * nb];
        for (bi, s) in row.iter_mut().enumerate() {
            if bi * cmp_block / ball_size == gball {
                *s = NEG_INF;
            }
        }
    }
}

/// Per-group first-max argmax-and-suppress top-k for one score row
/// (bit-matching `ref_topk_indices`' tie-breaking: strict `>` keeps the
/// first occurrence, like `jnp.argmax`). `row` is clobbered.
fn topk_row(row: &mut [f32], k: usize, out: &mut [usize]) {
    for slot in out.iter_mut().take(k) {
        let mut best = 0usize;
        let mut bv = f32::NEG_INFINITY;
        for (i, &v) in row.iter().enumerate() {
            if v > bv {
                bv = v;
                best = i;
            }
        }
        *slot = best;
        row[best] -= 2e30;
    }
    out[..k].sort_unstable();
}

/// Top-k block indices per score row, ascending-sorted (contiguous
/// gathers downstream), parallel over group-row chunks. `out` is resized
/// to `groups * k`.
pub fn topk_indices(scores: &[f32], groups: usize, nb: usize, k: usize, threads: usize, out: &mut Vec<usize>) {
    assert_eq!(scores.len(), groups * nb, "topk scores len");
    assert!(k <= nb, "top_k {k} exceeds block count {nb}");
    out.clear();
    out.resize(groups * k, 0);
    if k == 0 {
        return;
    }
    pool::par_rows(out.as_mut_slice(), k, threads, |g0, ochunk| {
        let mut row = vec![0.0f32; nb];
        for (gi, oslot) in ochunk.chunks_exact_mut(k).enumerate() {
            row.copy_from_slice(&scores[(g0 + gi) * nb..(g0 + gi + 1) * nb]);
            topk_row(&mut row, k, oslot);
        }
    });
}

/// Scalar twin of [`topk_indices`]: k rounds of argmax-and-suppress per
/// row, single thread (ref.py avoids `lax.top_k` for AOT-toolchain
/// reasons; k* is 4 in the paper, so the loop is tiny either way).
pub fn topk_indices_reference(scores: &[f32], groups: usize, nb: usize, k: usize, out: &mut Vec<usize>) {
    assert_eq!(scores.len(), groups * nb, "topk scores len");
    assert!(k <= nb, "top_k {k} exceeds block count {nb}");
    out.clear();
    out.resize(groups * k, 0);
    if k == 0 {
        return;
    }
    let mut row = vec![0.0f32; nb];
    for gi in 0..groups {
        row.copy_from_slice(&scores[gi * nb..(gi + 1) * nb]);
        topk_row(&mut row, k, &mut out[gi * k..(gi + 1) * k]);
    }
}

/// Grouped selection attention (paper eqs. 6-8, 10-12): every query in
/// group `p` attends the `k*` selected blocks of `sel_block` tokens given
/// by `idx[p]`, parallel over group chunks (gather scratch is
/// per-thread). `q`/`k`/`v`/`out` are `(n, d)`; `idx` is `groups * k`
/// flat.
#[allow(clippy::too_many_arguments)]
pub fn select_attention(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    idx: &[usize],
    n: usize,
    d: usize,
    sel_block: usize,
    group: usize,
    top_k: usize,
    threads: usize,
    out: &mut [f32],
) {
    assert_eq!(n % group, 0, "n must be divisible by group");
    let groups = n / group;
    assert_eq!(idx.len(), groups * top_k, "idx len");
    assert_eq!(out.len(), n * d, "select_attention out len");
    let scale = 1.0 / (d as f32).sqrt();
    let blk = sel_block * d;
    let gd = group * d;
    pool::par_rows(out, gd, threads, |p0, ochunk| {
        let mut ksel = vec![0.0f32; top_k * blk];
        let mut vsel = vec![0.0f32; top_k * blk];
        for (pi, ogroup) in ochunk.chunks_exact_mut(gd).enumerate() {
            let p = p0 + pi;
            for (j, &bi) in idx[p * top_k..(p + 1) * top_k].iter().enumerate() {
                debug_assert!((bi + 1) * blk <= k.len(), "block index {bi} out of range");
                ksel[j * blk..(j + 1) * blk].copy_from_slice(&k[bi * blk..(bi + 1) * blk]);
                vsel[j * blk..(j + 1) * blk].copy_from_slice(&v[bi * blk..(bi + 1) * blk]);
            }
            attend_unit(&q[p * gd..(p + 1) * gd], &ksel, &vsel, group, top_k * sel_block, d, scale, ogroup);
        }
    });
}

/// Scalar twin of [`select_attention`] (caller-owned gather scratch,
/// like the original serial kernel; the per-group attention is the
/// scalar streaming loop, so `scores` stays tile-sized).
#[allow(clippy::too_many_arguments)]
pub fn select_attention_reference(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    idx: &[usize],
    n: usize,
    d: usize,
    sel_block: usize,
    group: usize,
    top_k: usize,
    out: &mut [f32],
    ksel: &mut Vec<f32>,
    vsel: &mut Vec<f32>,
    scores: &mut Vec<f32>,
) {
    assert_eq!(n % group, 0, "n must be divisible by group");
    let groups = n / group;
    assert_eq!(idx.len(), groups * top_k, "idx len");
    let scale = 1.0 / (d as f32).sqrt();
    let blk = sel_block * d;
    ksel.resize(top_k * blk, 0.0);
    vsel.resize(top_k * blk, 0.0);
    for p in 0..groups {
        for (j, &bi) in idx[p * top_k..(p + 1) * top_k].iter().enumerate() {
            debug_assert!((bi + 1) * blk <= k.len(), "block index {bi} out of range");
            ksel[j * blk..(j + 1) * blk].copy_from_slice(&k[bi * blk..(bi + 1) * blk]);
            vsel[j * blk..(j + 1) * blk].copy_from_slice(&v[bi * blk..(bi + 1) * blk]);
        }
        let qr = p * group * d..(p + 1) * group * d;
        attend_streaming_reference(
            &q[qr.clone()],
            ksel,
            vsel,
            group,
            top_k * sel_block,
            d,
            scale,
            &mut out[qr],
            scores,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::prng::Rng;

    fn rand(n: usize, seed: u64) -> Vec<f32> {
        Rng::new(seed).normals(n)
    }

    #[test]
    fn attend_uniform_when_keys_identical() {
        // identical keys => uniform weights => output = mean of values
        let d = 4;
        let q = rand(d, 0);
        let k = [vec![1.0f32; d], vec![1.0f32; d]].concat();
        let v = [vec![2.0f32; d], vec![4.0f32; d]].concat();
        let mut out = vec![0.0f32; d];
        let mut s = Vec::new();
        attend(&q, &k, &v, 1, 2, d, 0.5, 2, &mut out, &mut s);
        for &o in &out {
            assert!((o - 3.0).abs() < 1e-6);
        }
        // Bitwise vs the scalar streaming twin even with SIMD active:
        // identical keys give identical per-row logits at every level,
        // so max-subtraction yields exp(0) == 1.0 exactly everywhere and
        // only element-parallel (bitwise-tier) panels touch the data.
        let mut refr = vec![0.0f32; d];
        let mut s2 = Vec::new();
        attend_streaming_reference(&q, &k, &v, 1, 2, d, 0.5, &mut refr, &mut s2);
        assert_eq!(out, refr);
        // ...and within the documented 1e-5 tier of the materialized oracle.
        let mut oracle = vec![0.0f32; d];
        attend_reference(&q, &k, &v, 1, 2, d, 0.5, &mut oracle, &mut s);
        for (a, b) in out.iter().zip(&oracle) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }

    #[test]
    fn attend_streaming_matches_materialized_across_tile_boundaries() {
        // nk straddling STREAM_TILE: below, exactly one tile, one over,
        // and a two-tile-plus-tail width. The streaming result must stay
        // within the documented 1e-5 tier of the materialized oracle and
        // the scratch must stay tile-sized.
        let (nq, d) = (3usize, 5usize);
        for &nk in &[1usize, 2, STREAM_TILE - 1, STREAM_TILE, STREAM_TILE + 1, 2 * STREAM_TILE + 2] {
            let q = rand(nq * d, 40 + nk as u64);
            let k = rand(nk * d, 41 + nk as u64);
            let v = rand(nk * d, 42 + nk as u64);
            let scale = 1.0 / (d as f32).sqrt();
            let mut fast = vec![0.0f32; nq * d];
            let mut s1 = Vec::new();
            attend_streaming(&q, &k, &v, nq, nk, d, scale, 2, &mut fast, &mut s1);
            assert!(
                s1.capacity() <= STREAM_TILE,
                "streaming scratch grew to {} for nk={nk}",
                s1.capacity()
            );
            let mut oracle = vec![0.0f32; nq * d];
            let mut s2 = Vec::new();
            attend_reference(&q, &k, &v, nq, nk, d, scale, &mut oracle, &mut s2);
            for (a, b) in fast.iter().zip(&oracle) {
                assert!((a - b).abs() < 1e-5, "nk={nk}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn attend_streaming_shrinks_inherited_materialized_scratch() {
        // The satellite bugfix: a large materialized call grows the
        // caller-owned scratch to nq*nk; the next streaming call through
        // the same scratch must release that capacity, not pin it.
        let (nq, nk, d) = (6usize, STREAM_TILE, 4usize);
        let q = rand(nq * d, 50);
        let k = rand(nk * d, 51);
        let v = rand(nk * d, 52);
        let scale = 1.0 / (d as f32).sqrt();
        let mut s = Vec::new();
        let mut a = vec![0.0f32; nq * d];
        attend_materialized(&q, &k, &v, nq, nk, d, scale, 2, &mut a, &mut s);
        assert!(s.capacity() >= nq * nk, "materialized path should grow scratch");
        let mut b = vec![0.0f32; nq * d];
        attend(&q, &k, &v, nq, nk, d, scale, 2, &mut b, &mut s);
        assert!(
            s.capacity() <= STREAM_TILE,
            "streaming call left {} capacity pinned",
            s.capacity()
        );
        for (x, y) in a.iter().zip(&b) {
            assert!((x - y).abs() < 1e-5, "{x} vs {y}");
        }
    }

    #[test]
    fn attend_streaming_all_masked_rows_are_uniform_not_nan() {
        // Finite NEG_INF masking takes the ordinary path and softmaxes
        // to uniform; true -inf masking (every tile skipped) must hit
        // the l == 0 fallback and produce the same uniform mean, never
        // NaN. nk spans one full tile plus a tail so both the skip and
        // the tail interact.
        let (nq, d) = (2usize, 3usize);
        let nk = STREAM_TILE + 6;
        let mut q = vec![0.0f32; nq * d];
        for i in 0..nq {
            q[i * d] = 1.0; // rows [1, 0, 0]
        }
        let v = rand(nk * d, 60);
        let scale = 1.0;
        let mean: Vec<f32> = (0..d)
            .map(|c| (0..nk).map(|j| v[j * d + c]).sum::<f32>() / nk as f32)
            .collect();

        // finite mask: k rows [NEG_INF, 0, 0] => every logit NEG_INF
        let mut k = vec![0.0f32; nk * d];
        for j in 0..nk {
            k[j * d] = NEG_INF;
        }
        let mut out = vec![0.0f32; nq * d];
        let mut s = Vec::new();
        attend_streaming(&q, &k, &v, nq, nk, d, scale, 2, &mut out, &mut s);
        let mut oracle = vec![0.0f32; nq * d];
        let mut so = Vec::new();
        attend_reference(&q, &k, &v, nq, nk, d, scale, &mut oracle, &mut so);
        for i in 0..nq {
            for c in 0..d {
                let o = out[i * d + c];
                assert!(o.is_finite(), "finite-mask row {i} produced {o}");
                assert!((o - oracle[i * d + c]).abs() < 1e-5);
                assert!((o - mean[c]).abs() < 1e-4, "{o} vs mean {}", mean[c]);
            }
        }

        // true -inf mask: every tile max is -inf, whole sweep skipped
        for j in 0..nk {
            k[j * d] = f32::NEG_INFINITY;
        }
        let mut out2 = vec![0.0f32; nq * d];
        attend_streaming(&q, &k, &v, nq, nk, d, scale, 2, &mut out2, &mut s);
        // The masked path touches only element-parallel panels, so this
        // holds bitwise vs the scalar twin even with SIMD active.
        let mut refr = vec![0.0f32; nq * d];
        let mut sr = Vec::new();
        attend_streaming_reference(&q, &k, &v, nq, nk, d, scale, &mut refr, &mut sr);
        for (i, (&o, &r)) in out2.iter().zip(&refr).enumerate() {
            assert!(o.is_finite(), "-inf-mask element {i} produced {o}");
            assert_eq!(o, r);
        }
        for i in 0..nq {
            for c in 0..d {
                assert!((out2[i * d + c] - mean[c]).abs() < 1e-4);
            }
        }
    }

    #[test]
    fn ball_attention_is_blockwise_dense() {
        // one ball spanning everything == plain dense attention
        let (n, d) = (8, 4);
        let q = rand(n * d, 1);
        let k = rand(n * d, 2);
        let v = rand(n * d, 3);
        let mut whole = vec![0.0f32; n * d];
        let mut dense = vec![0.0f32; n * d];
        let mut s = Vec::new();
        ball_attention(&q, &k, &v, n, d, n, 2, &mut whole);
        attend_reference(&q, &k, &v, n, n, d, 1.0 / (d as f32).sqrt(), &mut dense, &mut s);
        // 1e-5 (not bitwise): with SIMD active the unit's reductions
        // reorder accumulation vs the scalar reference (the twin rule).
        for (a, b) in whole.iter().zip(&dense) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }

        // two balls: each half ignores the other (change the far half's
        // values, near half's output must not move)
        let mut halves = vec![0.0f32; n * d];
        ball_attention(&q, &k, &v, n, d, n / 2, 2, &mut halves);
        let mut v2 = v.clone();
        for x in &mut v2[n / 2 * d..] {
            *x += 100.0;
        }
        let mut halves2 = vec![0.0f32; n * d];
        ball_attention(&q, &k, &v2, n, d, n / 2, 2, &mut halves2);
        assert_eq!(halves[..n / 2 * d], halves2[..n / 2 * d]);
        assert_ne!(halves[n / 2 * d..], halves2[n / 2 * d..]);
    }

    #[test]
    fn compress_mean_pools_blocks() {
        // rows 0..3 constant per row, block 2 => means of row pairs
        let x = [0.0f32, 0.0, 1.0, 1.0, 2.0, 2.0, 4.0, 4.0];
        let mut out = vec![0.0f32; 4];
        compress_mean(&x, 4, 2, 2, 2, &mut out);
        assert_eq!(out, [0.5, 0.5, 3.0, 3.0]);
        let mut refr = vec![0.0f32; 4];
        compress_mean_reference(&x, 4, 2, 2, &mut refr);
        assert_eq!(out, refr);
    }

    #[test]
    fn own_ball_mask_hits_exactly_own_blocks() {
        // n=16, group 4, cmp 2, ball 8: groups 0-1 in ball 0, blocks 0-3
        let groups = 4;
        let nb = 8;
        let mut scores = vec![1.0f32; groups * nb];
        mask_own_ball(&mut scores, groups, nb, 4, 2, 8);
        for gi in 0..groups {
            for bi in 0..nb {
                let masked = scores[gi * nb + bi] == NEG_INF;
                let same_ball = (gi * 4) / 8 == (bi * 2) / 8;
                assert_eq!(masked, same_ball, "gi {gi} bi {bi}");
            }
        }
    }

    #[test]
    fn topk_picks_largest_sorted_and_first_on_ties() {
        let scores = [0.1f32, 5.0, 3.0, 5.0, -1.0, 4.0];
        let mut out = Vec::new();
        topk_indices(&scores, 1, 6, 3, 2, &mut out);
        // picks: 1 (first 5.0), 3 (second 5.0), 5 (4.0) -> sorted
        assert_eq!(out, vec![1, 3, 5]);
        let mut refr = Vec::new();
        topk_indices_reference(&scores, 1, 6, 3, &mut refr);
        assert_eq!(out, refr);
    }

    #[test]
    fn select_attention_equals_dense_when_selection_covers_all() {
        // top_k * sel_block == n and idx = all blocks => dense attention
        // per group of queries over the whole sequence.
        let (n, d, l, g) = (8usize, 4usize, 2usize, 4usize);
        let q = rand(n * d, 7);
        let k = rand(n * d, 8);
        let v = rand(n * d, 9);
        let top_k = n / l;
        let idx: Vec<usize> = (0..n / g).flat_map(|_| 0..top_k).collect();
        let mut sel = vec![0.0f32; n * d];
        select_attention(&q, &k, &v, &idx, n, d, l, g, top_k, 2, &mut sel);
        let mut sc = Vec::new();
        let mut dense = vec![0.0f32; n * d];
        attend_reference(&q, &k, &v, n, n, d, 1.0 / (d as f32).sqrt(), &mut dense, &mut sc);
        for (a, b) in sel.iter().zip(&dense) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
    }
}
