//! PJRT-compiled backend: a [`Backend`] facade over a loaded
//! `fwd_<tag>` executable and its pre-converted parameter literals.
//!
//! This is the fast path when `make artifacts` has produced compiled
//! HLO: parameters are converted to `xla::Literal`s once at
//! construction (the first serving implementation rebuilt ~5 MB of
//! literals per batch — EXPERIMENTS.md §Perf), and every `forward` is a
//! borrowed-literal execute plus one output download.

use std::sync::Arc;

use crate::runtime::{literal_to_tensor, tensor_to_literal, Engine, Executable};
use crate::tensor::Tensor;

use super::{Backend, BackendSpec};

/// Immutable parameter literals shared across serving workers.
///
/// SAFETY: `xla::Literal` wraps a heap buffer that is never mutated
/// after construction here; `forward` only passes borrowed pointers
/// into `execute`, which reads them. The raw pointer inside is the only
/// reason Send/Sync cannot be derived.
struct ParamLiterals(Vec<xla::Literal>);
unsafe impl Send for ParamLiterals {}
unsafe impl Sync for ParamLiterals {}

/// Backend over a compiled forward graph.
pub struct PjrtBackend {
    exe: Arc<Executable>,
    params: ParamLiterals,
    spec: BackendSpec,
}

impl PjrtBackend {
    /// Load graph `graph` from the engine and bind `params` (host
    /// tensors matching the graph's leading inputs, e.g. from a
    /// checkpoint or an init graph).
    pub fn new(engine: &Engine, graph: &str, params: Vec<Tensor>) -> anyhow::Result<PjrtBackend> {
        let exe = engine.load(graph)?;
        anyhow::ensure!(
            params.len() == exe.info.nparams,
            "graph {graph} needs {} params, got {}",
            exe.info.nparams,
            params.len()
        );
        for (t, spec) in params.iter().zip(&exe.info.inputs) {
            anyhow::ensure!(
                t.shape() == spec.dims.as_slice(),
                "param {} shape {:?} != graph {:?}",
                spec.name,
                t.shape(),
                spec.dims
            );
        }
        let lits: Vec<xla::Literal> = params
            .iter()
            .map(tensor_to_literal)
            .collect::<Result<_, _>>()?;
        let spec = BackendSpec {
            name: format!("pjrt:{graph}"),
            n: exe.info.n,
            batch: exe.info.batch,
            in_features: exe.info.in_features,
            out_features: exe.info.out_features,
        };
        Ok(PjrtBackend { exe, params: ParamLiterals(lits), spec })
    }

    /// The underlying executable (manifest metadata access).
    pub fn executable(&self) -> &Arc<Executable> {
        &self.exe
    }
}

impl Backend for PjrtBackend {
    fn spec(&self) -> &BackendSpec {
        &self.spec
    }

    fn forward(&self, x: &Tensor) -> anyhow::Result<Tensor> {
        let out = self.exe.run_with_tensors(&self.params.0, &[x])?;
        literal_to_tensor(&out[0])
    }
}

#[cfg(test)]
mod tests {
    // PjrtBackend needs compiled artifacts + a PJRT client; it is
    // exercised end-to-end (including the native-parity check) in
    // rust/tests/integration.rs.
}
