//! Inference backends: one trait, two engines.
//!
//! Everything above this module — the serving [`Router`](crate::coordinator::Router),
//! the TCP front-end, the benches — speaks to the model through the
//! [`Backend`] trait: *"here is a permuted `(B, N, F)` feature tensor,
//! give me `(B, N, O)` predictions"*. Two implementations exist:
//!
//! * [`PjrtBackend`] — wraps a compiled HLO artifact executed through the
//!   PJRT runtime ([`runtime::Engine`](crate::runtime::Engine)). Fastest
//!   when `make artifacts` has run; requires the artifact directory.
//! * [`NativeBackend`] — the full BSA forward pass (ball-windowed
//!   attention, strided block compression, top-k grouped selection,
//!   gated merge, RMSNorm + SwiGLU trunk) in pure Rust over the crate's
//!   [`Tensor`](crate::tensor::Tensor) substrate. Needs no artifacts, no
//!   Python toolchain, no PJRT — the whole serving hot path (router,
//!   ball-tree cache, zero-copy batching) runs on any host. It doubles
//!   as the semantic parity oracle for the compiled graphs (see
//!   `rust/tests/integration.rs::native_backend_matches_pjrt_forward`).
//!
//! # Parameter file format
//!
//! `NativeBackend` loads weights from the same flat-binary named-array
//! container the trainer already checkpoints (`.bsackpt`, see
//! [`checkpoint`](crate::coordinator::checkpoint)): `magic "BSAC" |
//! version | step | count | (name, dims, dtype, data)*`. Since format
//! version 2 each array carries a storage-dtype byte (0 = f32, 1 = IEEE
//! binary16 via [`crate::half`]); version-1 files have no dtype byte and
//! the loader up-converts them as all-f32. Array names are the
//! dotted pytree paths the AOT manifest uses (`blocks.0.attn.wq`,
//! `embed_w`, …); optimizer-moment arrays (`m.*` / `v.*`) in a full
//! training checkpoint are ignored, so a trainer checkpoint *is* a valid
//! native param file. `python/compile/aot.py` emits `params_<tag>.bsackpt`
//! alongside the HLO artifacts for the same purpose. The byte-level
//! specification (field widths, bounds, error cases) lives in
//! `docs/FORMATS.md` at the repo root.
//!
//! Select the backend on the CLI with `bsa serve --backend native|pjrt`.
//!
//! # Kernel conformance
//!
//! The native kernels come in pairs: a fast production version
//! (cache-blocked, thread-parallel over [`pool::par_rows`] chunks, inner
//! loops on the [`simd`] microkernels) and a `*_reference` scalar twin —
//! the plain loop nest that mirrors the jnp oracle in
//! `python/compile/kernels/ref.py`. The pairs are
//! [`linalg::matmul`]/[`linalg::matmul_reference`],
//! [`linalg::matmul_nt`]/[`linalg::matmul_nt_reference`],
//! [`linalg::softmax_rows`]/[`linalg::softmax_rows_reference`],
//! [`linalg::rms_norm`]/[`linalg::rms_norm_reference`],
//! [`kernels::attend_streaming`]/[`kernels::attend_streaming_reference`]
//! (with [`kernels::attend`] as the production alias of the streaming
//! path, [`kernels::attend_materialized`] keeping the old
//! materialize-then-softmax pipeline as a comparator, and
//! [`kernels::attend_reference`] the scalar materialized oracle both
//! variants are swept against),
//! [`kernels::ball_attention`]/[`kernels::ball_attention_reference`],
//! [`kernels::compress_mean`]/[`kernels::compress_mean_reference`],
//! [`kernels::group_scores`]/[`kernels::group_scores_reference`],
//! [`kernels::topk_indices`]/[`kernels::topk_indices_reference`], and
//! [`kernels::select_attention`]/[`kernels::select_attention_reference`]
//! (`kernels::mask_own_ball` is elementwise and serves as its own
//! reference).
//!
//! The twin contract has four tiers since the streaming/f16 layer
//! landed:
//!
//! * **1e-5 differential** — the acceptance bound every fast kernel
//!   meets against its twin at every SIMD level, shape, and thread
//!   count. SIMD horizontal reductions (`simd::dot`, `simd::sum_sq`,
//!   `simd::exp_sum`) reorder floating-point accumulation, so
//!   `matmul_nt`, `softmax_rows`, `rms_norm`, and the attention family
//!   genuinely differ from their twins in the last bits when SIMD is
//!   active.
//! * **streaming vs materialized (1e-5)** — the online-softmax
//!   [`kernels::attend_streaming`] path visits keys tile by tile and
//!   rescales its running accumulator, a different summation order from
//!   the materialize-then-softmax pipeline; conformance sweeps hold it
//!   to the same 1e-5 bound against [`kernels::attend_reference`] (the
//!   materialized scalar oracle) across tile-tail widths, thread
//!   counts, and SIMD levels. Against its *own* scalar twin
//!   ([`kernels::attend_streaming_reference`]) the usual tier rules
//!   apply: 1e-5 with SIMD active, bitwise with `BSA_NATIVE_SIMD=off`.
//! * **f16 forward (5e-2 relative)** — with `--precision f16` the
//!   native forward stores parameters and attention staging buffers as
//!   IEEE binary16 ([`crate::half`], per-element relative error ≤ 2⁻¹¹)
//!   while accumulating in f32; on unit-scale activations the forward
//!   outputs stay within `5e-2 · (1 + |a|)` of the f32 forward
//!   (asserted by `native::tests` and conformance). This is a storage
//!   tier, not a kernel tier — every kernel still runs the f32 contract
//!   above on the decoded values.
//! * **bitwise** — retained in three places: (1) with
//!   `BSA_NATIVE_SIMD=off` (or `--simd off`) every kernel runs the
//!   twin's exact scalar loops, so fast == reference bit for bit
//!   (`rust/tests/simd_off.rs`); (2) kernels built only from
//!   element-parallel panels ([`linalg::matmul`],
//!   [`kernels::compress_mean`], [`kernels::topk_indices`]) are bitwise
//!   twins at *every* level; (3) **across thread counts** always —
//!   chunks are contiguous whole output rows and a unit's computation
//!   never depends on which chunk or worker runs it, so the thread
//!   budget stays a pure latency knob and the forward pass is bitwise
//!   deterministic for any fixed SIMD level (f16 mode included: encode
//!   and decode are deterministic per element).
//!
//! Dispatch runs on [`pool`]'s **persistent worker pool** (lazy-init,
//! work queue, parked workers, at most [`pool::MAX_THREADS`] threads per
//! process) rather than spawning scoped threads per call; which worker
//! executes a chunk is invisible to the numerics, so pool reuse across
//! thousands of dispatches cannot change a single bit — conformance
//! sweeps assert exactly that, plus that dropping an explicit
//! [`pool::WorkerPool`] joins every worker. On top of the row-parallel
//! kernels, [`native`]'s attention is head-parallel: (batch, head) units
//! run as pool jobs with per-thread scratch and write disjoint blocks of
//! a head-major staging buffer (see the [`native`] module docs).
//!
//! `rust/tests/conformance.rs` is the differential harness that enforces
//! all of this: randomized shape sweeps (uneven ball sizes, degenerate
//! single-point balls, tie-heavy top-k rows, panel-boundary-crossing
//! GEMMs, lane-tail lengths N%8 in 1..=7, streaming tile tails
//! nk % [`kernels::STREAM_TILE`] in 1..=7, single-key units, all-masked
//! rows, single-row panels, subnormal/huge logits) comparing fast vs
//! reference within 1e-5,
//! pool-reuse and pool-lifecycle checks, a concurrent bit-determinism
//! check on a shared `Arc<dyn Backend>`, and the native-vs-pjrt fixture
//! gate; `rust/tests/simd_off.rs` pins the `BSA_NATIVE_SIMD=off`
//! bitwise-equals-scalar guarantee. **To add a new kernel:** (1) write
//! the scalar `*_reference` twin first and unit-test its math; (2)
//! build the fast version on `pool::par_rows` over disjoint output
//! rows, with inner loops on the [`simd`] microkernels — element-wise
//! work on the bitwise panels (`axpy`/`add_assign`/`scale`), reductions
//! on `dot`/`sum_sq`/`exp_sum`/`row_max` (each row computed identically
//! regardless of chunk); (3) add a `conf_*` sweep to conformance.rs
//! that randomizes shapes *and* thread counts, including the degenerate
//! edges (unit dims, lane tails, one chunk per thread, more threads
//! than rows).
//!
//! # Gradient kernels
//!
//! Since the native trainer landed, every forward kernel above has a
//! backward companion in [`grad`], held to the **same tiers**: fast
//! gradient kernels get scalar `*_reference` twins (1e-5 with SIMD on,
//! bitwise with `BSA_NATIVE_SIMD=off`, bitwise across thread counts
//! always), purely element-parallel ones ([`grad::linalg::matmul_tn`],
//! [`grad::linalg::bias_grad`], [`grad::linalg::swiglu_backward`]) are
//! bitwise at every level, and each is additionally checked against a
//! directional finite-difference oracle (1e-3 relative) plus a numpy
//! mirror validated against `jax.grad` of the `ref.py` oracle. The
//! per-tier table and the how-to-add-a-gradient-kernel recipe live in
//! the [`grad`] module docs; the normative training spec is
//! `docs/TRAINING.md`.

pub mod grad;
pub mod kernels;
pub mod linalg;
pub mod native;
pub mod params;
pub mod pjrt;
pub mod pool;
pub mod simd;

pub use native::NativeBackend;
pub use params::NativeParams;
pub use pjrt::PjrtBackend;

use crate::tensor::Tensor;

/// Static shape/identity contract a backend exposes to the router: the
/// batcher preallocates its `(B, N, F)` input from these and validates
/// requests against them before any tree or buffer work happens.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BackendSpec {
    /// Human-readable identity for logs ("pjrt:fwd_bsa_air_n4096_b1",
    /// "native:bsa").
    pub name: String,
    /// Sequence length (points per sample after ball-tree padding).
    pub n: usize,
    /// Batch dimension the backend consumes per forward call.
    pub batch: usize,
    /// Per-point input features.
    pub in_features: usize,
    /// Per-point prediction features.
    pub out_features: usize,
}

/// A model engine the serving stack can drive.
///
/// Implementations must be shareable across the worker pool
/// (`Send + Sync`); `forward` may be called concurrently.
pub trait Backend: Send + Sync {
    /// Shape contract (see [`BackendSpec`]).
    fn spec(&self) -> &BackendSpec;

    /// Run the model on a ball-order-permuted `(batch, n, in_features)`
    /// tensor; returns `(batch, n, out_features)` predictions.
    fn forward(&self, x: &Tensor) -> anyhow::Result<Tensor>;
}

/// Which backend implementation to construct (CLI `--backend` flag).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BackendKind {
    /// Compiled HLO artifacts through the PJRT runtime.
    Pjrt,
    /// Pure-Rust BSA forward pass (artifact-free).
    Native,
}

impl std::str::FromStr for BackendKind {
    type Err = anyhow::Error;

    fn from_str(s: &str) -> anyhow::Result<BackendKind> {
        match s {
            "pjrt" => Ok(BackendKind::Pjrt),
            "native" => Ok(BackendKind::Native),
            other => Err(anyhow::anyhow!(
                "unknown backend {other:?} (expected \"pjrt\" or \"native\")"
            )),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backend_kind_parses() {
        assert_eq!("pjrt".parse::<BackendKind>().unwrap(), BackendKind::Pjrt);
        assert_eq!("native".parse::<BackendKind>().unwrap(), BackendKind::Native);
        let err = "xla".parse::<BackendKind>().unwrap_err().to_string();
        assert!(err.contains("xla"), "error names the bad value: {err}");
    }
}
