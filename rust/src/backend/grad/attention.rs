//! Gradients of the three sparse attention branches in
//! [`super::super::kernels`].
//!
//! The core is [`attend_backward`], a flash-style backward: instead of
//! stashing the `nq * nk` probability matrix from the forward, it
//! **recomputes** each query row's online-softmax statistics `(m, l)`
//! with the *exact* [`super::super::kernels`] streaming recurrence
//! (same [`STREAM_TILE`] tiling, same [`simd`] panels, same rescale
//! branch), then reconstitutes probabilities one tile at a time. Peak
//! memory in the backward is `O(nq)` stats plus one stack tile — the
//! same contract the forward's streaming kernel keeps.
//!
//! With `O = P V`, `P = softmax(S)`, `S = scale * Q K^T`, the standard
//! flash backward identities apply per query row `i`:
//!
//! ```text
//! D_i    = dot(dO_i, O_i)
//! dS_ij  = P_ij * (dot(dO_i, V_j) - D_i)
//! dQ_i  += scale * sum_j dS_ij K_j      (query-major pass)
//! dK_j  += scale * sum_i dS_ij Q_i      (key-major pass, ascending i)
//! dV_j  += sum_i P_ij dO_i              (key-major pass, ascending i)
//! ```
//!
//! Both passes have a fixed reduction order, so results are identical
//! at every thread count; the exps and dots ride the [`simd`] `*_at`
//! panels, making each kernel a 1e-5 twin of its `*_reference`
//! (bitwise under `BSA_NATIVE_SIMD=off`), mirroring the forward tiers.
//!
//! All-masked rows mirror the forward's uniform-instead-of-NaN
//! contract: a row whose sweep ends with `l <= 0` produced the uniform
//! value mean in the forward, so its backward is `dV_j += dO_i / nk`
//! with no `dQ`/`dK` contribution (the uniform weights are constant in
//! `q` and `k`).
//!
//! Selection's top-k is **straight-through**: [`select_attention_backward`]
//! replays the forward's index set and routes no gradient into the
//! ranking scores — the Rust analogue of `ref.py`'s
//! `jax.lax.stop_gradient(idx)`. The argmax is locally constant, so
//! finite differences agree with this convention everywhere off the
//! (measure-zero) ranking ties.

use crate::backend::kernels::STREAM_TILE;
use crate::backend::linalg::sigmoid;
use crate::backend::simd;

/// One query row's online-softmax stats `(m, l)` — the exact
/// [`super::super::kernels`] `stream_row` recurrence minus the value
/// accumulation, at an explicit SIMD level. Must never drift from the
/// forward: the reconstituted probabilities divide by this `l`.
fn row_stats_at(
    lvl: simd::Level,
    qrow: &[f32],
    k: &[f32],
    nk: usize,
    d: usize,
    scale: f32,
    tile: &mut [f32; STREAM_TILE],
) -> (f32, f32) {
    let mut m = f32::NEG_INFINITY;
    let mut l = 0.0f32;
    let mut j0 = 0usize;
    while j0 < nk {
        let tl = STREAM_TILE.min(nk - j0);
        let t = &mut tile[..tl];
        simd::tile_scores_at(lvl, qrow, &k[j0 * d..(j0 + tl) * d], d, scale, t);
        let tmax = simd::row_max_at(lvl, t);
        if tmax == f32::NEG_INFINITY {
            j0 += tl;
            continue;
        }
        if tmax > m {
            if l > 0.0 {
                l *= simd::exp_one_at(lvl, m - tmax);
            }
            m = tmax;
        }
        l += simd::exp_sum_at(lvl, t, m);
        j0 += tl;
    }
    (m, l)
}

/// Shared body of the streaming attention backward at an explicit SIMD
/// level. See the module docs for the identities; serial by contract
/// (parallelism lives a layer up, at the (batch, head) unit grain, like
/// the forward's `attend_unit`). **Accumulates** into `dq`/`dk`/`dv`.
#[allow(clippy::too_many_arguments)]
fn attend_backward_at(
    lvl: simd::Level,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    o: &[f32],
    dout: &[f32],
    nq: usize,
    nk: usize,
    d: usize,
    scale: f32,
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
) {
    debug_assert_eq!(q.len(), nq * d, "attend_backward q len");
    debug_assert_eq!(k.len(), nk * d, "attend_backward k len");
    debug_assert_eq!(v.len(), nk * d, "attend_backward v len");
    debug_assert_eq!(o.len(), nq * d, "attend_backward o len");
    debug_assert_eq!(dout.len(), nq * d, "attend_backward dout len");
    let mut tile = [0.0f32; STREAM_TILE];

    // Pass A: per-row stats (m, l) and D = dot(dO, O).
    let mut stats = vec![(0.0f32, 0.0f32); nq];
    let mut dcoef = vec![0.0f32; nq];
    for i in 0..nq {
        stats[i] = row_stats_at(lvl, &q[i * d..(i + 1) * d], k, nk, d, scale, &mut tile);
        dcoef[i] = simd::dot_at(lvl, &dout[i * d..(i + 1) * d], &o[i * d..(i + 1) * d]);
    }

    // Pass B: dQ, query-major (each query row touched once; tiles
    // reconstitute the probabilities the forward never stored).
    for i in 0..nq {
        let (m, l) = stats[i];
        if l <= 0.0 {
            continue; // uniform fallback row: constant in q
        }
        let qrow = &q[i * d..(i + 1) * d];
        let dorow = &dout[i * d..(i + 1) * d];
        let dqrow = &mut dq[i * d..(i + 1) * d];
        let mut j0 = 0usize;
        while j0 < nk {
            let tl = STREAM_TILE.min(nk - j0);
            let t = &mut tile[..tl];
            simd::tile_scores_at(lvl, qrow, &k[j0 * d..(j0 + tl) * d], d, scale, t);
            for (jj, &s) in t.iter().enumerate() {
                let j = j0 + jj;
                if s == f32::NEG_INFINITY {
                    continue;
                }
                let p = simd::exp_one_at(lvl, s - m) / l;
                let dp = simd::dot_at(lvl, dorow, &v[j * d..(j + 1) * d]);
                let ds = p * (dp - dcoef[i]);
                simd::axpy_at(lvl, ds * scale, &k[j * d..(j + 1) * d], dqrow);
            }
            j0 += tl;
        }
    }

    // Pass C: dK/dV, key-major with an ascending-i inner loop — every
    // (key, query) pair lands in a fixed order, so the accumulation is
    // thread-count-invariant wherever a caller parallelizes over keys.
    for j in 0..nk {
        let krow = &k[j * d..(j + 1) * d];
        let vrow = &v[j * d..(j + 1) * d];
        for i in 0..nq {
            let (m, l) = stats[i];
            let dorow = &dout[i * d..(i + 1) * d];
            if l <= 0.0 {
                // uniform fallback: o = mean(v), so dv += dO / nk
                simd::axpy_at(lvl, 1.0 / nk as f32, dorow, &mut dv[j * d..(j + 1) * d]);
                continue;
            }
            let s = scale * simd::dot_at(lvl, &q[i * d..(i + 1) * d], krow);
            if s == f32::NEG_INFINITY {
                continue;
            }
            let p = simd::exp_one_at(lvl, s - m) / l;
            let dp = simd::dot_at(lvl, dorow, vrow);
            let ds = p * (dp - dcoef[i]);
            simd::axpy_at(lvl, ds * scale, &q[i * d..(i + 1) * d], &mut dk[j * d..(j + 1) * d]);
            simd::axpy_at(lvl, p, dorow, &mut dv[j * d..(j + 1) * d]);
        }
    }
}

/// Flash-style backward of [`super::super::kernels::attend`]:
/// recomputed online stats, no `nq * nk` materialization. `o` is the
/// forward output; **accumulates** into `dq (nq, d)` / `dk (nk, d)` /
/// `dv (nk, d)`. Serial per call (the parallel grain is the
/// (batch, head) unit, as in the forward); 1e-5 twin of
/// [`attend_backward_reference`] at SIMD levels, bitwise under
/// `BSA_NATIVE_SIMD=off`.
#[allow(clippy::too_many_arguments)]
pub fn attend_backward(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    o: &[f32],
    dout: &[f32],
    nq: usize,
    nk: usize,
    d: usize,
    scale: f32,
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
) {
    attend_backward_at(simd::active(), q, k, v, o, dout, nq, nk, d, scale, dq, dk, dv);
}

/// Scalar twin of [`attend_backward`]: the same three passes pinned at
/// [`simd::Level::Scalar`].
#[allow(clippy::too_many_arguments)]
pub fn attend_backward_reference(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    o: &[f32],
    dout: &[f32],
    nq: usize,
    nk: usize,
    d: usize,
    scale: f32,
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
) {
    attend_backward_at(simd::Level::Scalar, q, k, v, o, dout, nq, nk, d, scale, dq, dk, dv);
}

/// Backward of [`super::super::kernels::ball_attention`]: the flash
/// backward per disjoint ball. `o` is the forward's ball output;
/// **accumulates** into `dq`/`dk`/`dv` (`(n, d)` each). Serial — called
/// from inside the per-unit parallel sweep, like the forward's per-ball
/// body. 1e-5 twin of [`ball_attention_backward_reference`] at SIMD
/// levels, bitwise under `BSA_NATIVE_SIMD=off`.
#[allow(clippy::too_many_arguments)]
pub fn ball_attention_backward(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    o: &[f32],
    dout: &[f32],
    n: usize,
    d: usize,
    ball_size: usize,
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
) {
    ball_attention_backward_at(simd::active(), q, k, v, o, dout, n, d, ball_size, dq, dk, dv);
}

/// Scalar twin of [`ball_attention_backward`].
#[allow(clippy::too_many_arguments)]
pub fn ball_attention_backward_reference(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    o: &[f32],
    dout: &[f32],
    n: usize,
    d: usize,
    ball_size: usize,
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
) {
    ball_attention_backward_at(simd::Level::Scalar, q, k, v, o, dout, n, d, ball_size, dq, dk, dv);
}

#[allow(clippy::too_many_arguments)]
fn ball_attention_backward_at(
    lvl: simd::Level,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    o: &[f32],
    dout: &[f32],
    n: usize,
    d: usize,
    ball_size: usize,
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
) {
    assert_eq!(n % ball_size, 0, "n must be divisible by ball size");
    let scale = 1.0 / (d as f32).sqrt();
    let chunk = ball_size * d;
    for b in 0..n / ball_size {
        let r = b * chunk..(b + 1) * chunk;
        attend_backward_at(
            lvl,
            &q[r.clone()],
            &k[r.clone()],
            &v[r.clone()],
            &o[r.clone()],
            &dout[r.clone()],
            ball_size,
            ball_size,
            d,
            scale,
            &mut dq[r.clone()],
            &mut dk[r.clone()],
            &mut dv[r],
        );
    }
}

/// Backward of [`super::super::kernels::select_attention`] with
/// **straight-through top-k**: the forward's `idx` (`groups * top_k`
/// flat, ascending per group) is replayed verbatim, gradients flow into
/// the selected key/value blocks, and the ranking scores receive
/// nothing (`stop_gradient(idx)` semantics). A block selected by
/// several groups accumulates each group's contribution in ascending
/// group order — fixed, so thread counts a layer up never reorder it.
/// `o` is the forward's selection output; **accumulates** into
/// `dq`/`dk`/`dv`. Serial per call; 1e-5 twin of
/// [`select_attention_backward_reference`] at SIMD levels, bitwise
/// under `BSA_NATIVE_SIMD=off`.
#[allow(clippy::too_many_arguments)]
pub fn select_attention_backward(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    o: &[f32],
    dout: &[f32],
    idx: &[usize],
    n: usize,
    d: usize,
    sel_block: usize,
    group: usize,
    top_k: usize,
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
) {
    select_attention_backward_at(
        simd::active(),
        q,
        k,
        v,
        o,
        dout,
        idx,
        n,
        d,
        sel_block,
        group,
        top_k,
        dq,
        dk,
        dv,
    );
}

/// Scalar twin of [`select_attention_backward`].
#[allow(clippy::too_many_arguments)]
pub fn select_attention_backward_reference(
    q: &[f32],
    k: &[f32],
    v: &[f32],
    o: &[f32],
    dout: &[f32],
    idx: &[usize],
    n: usize,
    d: usize,
    sel_block: usize,
    group: usize,
    top_k: usize,
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
) {
    select_attention_backward_at(
        simd::Level::Scalar,
        q,
        k,
        v,
        o,
        dout,
        idx,
        n,
        d,
        sel_block,
        group,
        top_k,
        dq,
        dk,
        dv,
    );
}

#[allow(clippy::too_many_arguments)]
fn select_attention_backward_at(
    lvl: simd::Level,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    o: &[f32],
    dout: &[f32],
    idx: &[usize],
    n: usize,
    d: usize,
    sel_block: usize,
    group: usize,
    top_k: usize,
    dq: &mut [f32],
    dk: &mut [f32],
    dv: &mut [f32],
) {
    assert_eq!(n % group, 0, "n must be divisible by group");
    let groups = n / group;
    assert_eq!(idx.len(), groups * top_k, "idx len");
    let scale = 1.0 / (d as f32).sqrt();
    let blk = sel_block * d;
    let gd = group * d;
    let mut ksel = vec![0.0f32; top_k * blk];
    let mut vsel = vec![0.0f32; top_k * blk];
    let mut dksel = vec![0.0f32; top_k * blk];
    let mut dvsel = vec![0.0f32; top_k * blk];
    for p in 0..groups {
        for (j, &bi) in idx[p * top_k..(p + 1) * top_k].iter().enumerate() {
            debug_assert!((bi + 1) * blk <= k.len(), "block index {bi} out of range");
            ksel[j * blk..(j + 1) * blk].copy_from_slice(&k[bi * blk..(bi + 1) * blk]);
            vsel[j * blk..(j + 1) * blk].copy_from_slice(&v[bi * blk..(bi + 1) * blk]);
        }
        dksel.fill(0.0);
        dvsel.fill(0.0);
        let qr = p * gd..(p + 1) * gd;
        attend_backward_at(
            lvl,
            &q[qr.clone()],
            &ksel,
            &vsel,
            &o[qr.clone()],
            &dout[qr.clone()],
            group,
            top_k * sel_block,
            d,
            scale,
            &mut dq[qr],
            &mut dksel,
            &mut dvsel,
        );
        // scatter-add the gathered blocks back (ascending slot order)
        for (j, &bi) in idx[p * top_k..(p + 1) * top_k].iter().enumerate() {
            simd::add_assign_at(lvl, &mut dk[bi * blk..(bi + 1) * blk], &dksel[j * blk..(j + 1) * blk]);
            simd::add_assign_at(lvl, &mut dv[bi * blk..(bi + 1) * blk], &dvsel[j * blk..(j + 1) * blk]);
        }
    }
}

/// Backward of [`super::super::kernels::compress_mean`]: the mean-pool
/// adjoint spreads each compressed row's gradient uniformly over its
/// `block` source tokens, `dx[t] += dc[t / block] / block`. Pure serial
/// scalar broadcast — self-referential (no twin), deterministic at any
/// setting. **Accumulates** into `dx (n, d)` from `dc (n/block, d)`.
pub fn compress_mean_backward(dc: &[f32], n: usize, d: usize, block: usize, dx: &mut [f32]) {
    assert_eq!(n % block, 0, "n must be divisible by block");
    let nb = n / block;
    assert_eq!(dc.len(), nb * d, "compress_mean_backward dc len");
    assert_eq!(dx.len(), n * d, "compress_mean_backward dx len");
    let inv = 1.0 / block as f32;
    for b in 0..nb {
        let crow = &dc[b * d..(b + 1) * d];
        for t in 0..block {
            let xrow = &mut dx[(b * block + t) * d..(b * block + t + 1) * d];
            for (o, &g) in xrow.iter_mut().zip(crow) {
                *o += g * inv;
            }
        }
    }
}

/// Backward of the gated merge (paper eq. 9) for one (batch, head)
/// unit: `merge = sig(gb) o_ball + sig(gc) o_cmp + sig(gs) o_slc`
/// per token, with `logits (n, 3)` row-major `[gb, gc, gs]` and the
/// branch outputs `(n, d)`. Writes
///
/// ```text
/// dlogits[t, b] = sig_b (1 - sig_b) * dot(dmerge_t, branch_b[t])
/// dbranch_b[t]  = sig_b * dmerge_t
/// ```
///
/// Serial scalar chains (the dot is an ascending loop) —
/// self-referential, deterministic at any setting. Overwrites all four
/// outputs.
#[allow(clippy::too_many_arguments)]
pub fn merge_backward(
    logits: &[f32],
    o_ball: &[f32],
    o_cmp: &[f32],
    o_slc: &[f32],
    dmerge: &[f32],
    n: usize,
    d: usize,
    dlogits: &mut [f32],
    d_ball: &mut [f32],
    d_cmp: &mut [f32],
    d_slc: &mut [f32],
) {
    assert_eq!(logits.len(), n * 3, "merge_backward logits len");
    assert_eq!(dlogits.len(), n * 3, "merge_backward dlogits len");
    for (buf, name) in [
        (o_ball.len(), "o_ball"),
        (o_cmp.len(), "o_cmp"),
        (o_slc.len(), "o_slc"),
        (dmerge.len(), "dmerge"),
        (d_ball.len(), "d_ball"),
        (d_cmp.len(), "d_cmp"),
        (d_slc.len(), "d_slc"),
    ] {
        assert_eq!(buf, n * d, "merge_backward {name} len");
    }
    for t in 0..n {
        let r = t * d..(t + 1) * d;
        let dm = &dmerge[r.clone()];
        for (b, (branch, dbranch)) in [
            (&o_ball[r.clone()], &mut d_ball[r.clone()]),
            (&o_cmp[r.clone()], &mut d_cmp[r.clone()]),
            (&o_slc[r.clone()], &mut d_slc[r.clone()]),
        ]
        .into_iter()
        .enumerate()
        {
            let sig = sigmoid(logits[t * 3 + b]);
            let mut dot = 0.0f32;
            for (o, (&dmj, &bj)) in dbranch.iter_mut().zip(dm.iter().zip(branch.iter())) {
                dot += dmj * bj;
                *o = sig * dmj;
            }
            dlogits[t * 3 + b] = sig * (1.0 - sig) * dot;
        }
    }
}
