//! Backward passes for the native BSA forward — the layer that makes
//! `bsa train --backend native` possible with no Python/XLA artifacts
//! (ROADMAP item 2; the Rust analogue of "Natively Trainable Sparse
//! Attention for Hierarchical Point Cloud Datasets", arXiv 2508.10758).
//!
//! The module splits the same way the forward does:
//!
//! * [`linalg`] — gradients of the dense trunk ops: the transposed
//!   GEMM [`linalg::matmul_tn`] (weight gradients), bias/column sums,
//!   RMSNorm, SwiGLU, and the MSE loss.
//! * [`attention`] — gradients of the three sparse branches: the
//!   flash-style streaming attention backward (no `nq * nk` score
//!   matrix in the backward either — per-row online `(max, exp-sum)`
//!   stats are *recomputed* with the exact forward recurrence), ball
//!   and selection wrappers, mean-pool compression, and the gated
//!   merge. Top-k selection is a **straight-through** index set: the
//!   forward's argmax indices are replayed verbatim and no gradient
//!   flows through the ranking scores, matching the jax reference's
//!   `stop_gradient(idx)`.
//! * [`tape`] — the whole-model composition: a forward pass that
//!   stashes the per-block activations a reverse sweep needs, the
//!   reverse sweep itself, and [`tape::loss_and_grads`] which is the
//!   one call [`crate::coordinator::train::NativeTrainer`] makes per
//!   step.
//! * [`adam`] — a bias-corrected, decoupled-weight-decay Adam (AdamW)
//!   with per-array first/second moments, the same update the fused
//!   pjrt train graph applies.
//!
//! # Gradient-kernel conformance
//!
//! Backward kernels obey the same twin contract as the forward (see
//! "Kernel conformance" in [`super`]), with the same tiers:
//!
//! | kernel | vs its scalar twin | across thread counts |
//! |---|---|---|
//! | [`linalg::matmul_tn`] | **bitwise** at every SIMD level | **bitwise** |
//! | [`linalg::bias_grad`], [`linalg::swiglu_backward`] | **bitwise** at every SIMD level | **bitwise** |
//! | [`linalg::rms_norm_backward`] | 1e-5 (bitwise when SIMD off) | **bitwise** |
//! | [`attention::attend_backward`] | 1e-5 (bitwise when SIMD off) | **bitwise** (serial per unit) |
//! | [`attention::ball_attention_backward`], [`attention::select_attention_backward`] | 1e-5 (bitwise when SIMD off) | **bitwise** (serial per unit) |
//! | [`attention::compress_mean_backward`], [`attention::merge_backward`], [`linalg::mse_loss_grad`] | serial scalar — self-referential | **bitwise** |
//!
//! On top of the twin checks, every kernel has a **finite-difference
//! oracle** (`rust/tests/grad_conformance.rs`, directional derivatives
//! at 1e-3 relative tolerance) and a **numpy mirror**
//! (`python/tests/test_grad_mirror.py`) whose composite unit backward
//! is validated against `jax.grad` of the repo's `ref_bsa_attention`.
//!
//! # How to add a gradient kernel
//!
//! The recipe, in order — each step catches a different failure mode:
//!
//! 1. **Write the math in the numpy mirror first**
//!    (`python/tests/test_grad_mirror.py`): a forward mirror, the
//!    hand-derived backward, and a central-difference check in f64.
//!    If the task has a jax reference, `jax.grad` it and compare.
//!    Only transcribe to Rust once the mirror passes — debugging
//!    calculus in numpy is an order of magnitude faster than in a
//!    parallel f32 kernel.
//! 2. **Write the fast kernel** against the [`super::simd`] `*_at`
//!    panels with an explicit [`super::simd::Level`] parameter, and
//!    dispatch rows with [`super::pool::par_rows`] so chunk boundaries
//!    can never change the arithmetic (reductions stay within a row,
//!    in a fixed order).
//! 3. **Write the scalar twin** (`*_reference`): the *same* loop
//!    pinned at [`super::simd::Level::Scalar`], serial. Do not
//!    re-derive the math — share helpers with the fast path so the
//!    twin can only differ by SIMD level and dispatch.
//! 4. **Add the conformance tests** (`rust/tests/grad_conformance.rs`):
//!    fast-vs-twin at the tier from the table above, bitwise across
//!    thread counts, and a directional finite-difference oracle
//!    (`dot(grad, u)` vs `(f(x + eps*u) - f(x - eps*u)) / 2eps`).
//! 5. **Document the tier** in the table above and in
//!    `docs/TRAINING.md` — the tiers are normative, not descriptive.
//!
//! # Buffer conventions
//!
//! Weight-gradient kernels (`matmul_tn`, `bias_grad`,
//! `rms_norm_backward`, `swiglu_backward`) **overwrite** their outputs
//! — every parameter's gradient has exactly one producing expression.
//! Attention backward kernels (`attend_backward` and its ball/select
//! wrappers, `compress_mean_backward`) **accumulate** (`+=`) into
//! `dq`/`dk`/`dv`, because the three branches all contribute to the
//! same projection gradients; callers zero the buffers once per unit.

pub mod adam;
pub mod attention;
pub mod linalg;
pub mod tape;

pub use adam::Adam;
pub use tape::{loss_and_grads, Tape};
