//! Gradients of the dense trunk ops in [`super::super::linalg`].
//!
//! The star is [`matmul_tn`] (`out = a^T b`), the shape every weight
//! gradient takes: for a forward `y = x w` with `x (m, k)`, `w (k, n)`,
//! the chain rule gives `dw = x^T dy` and `dx = dy w^T` — the latter is
//! the existing forward kernel [`super::super::linalg::matmul_nt`], so
//! only the transposed-A product is new here.
//!
//! Tiers (see the table in [`super`]): `matmul_tn`, [`bias_grad`] and
//! [`swiglu_backward`] are built purely from element-parallel panels /
//! scalar chains with a fixed reduction order, so they are **bitwise**
//! twins of their references at every SIMD level and thread count.
//! [`rms_norm_backward`] recomputes the forward's `1/rms` with
//! [`super::super::simd::sum_sq_at`], whose lane tree depends on the
//! SIMD level — a 1e-5 twin (bitwise under `BSA_NATIVE_SIMD=off`).

use crate::backend::linalg::{sigmoid, silu, RMS_EPS};
use crate::backend::{pool, simd};

/// `out = a^T @ b` where `a` is `(m, k)`, `b` is `(m, n)`, `out` is
/// `(k, n)` — the weight-gradient GEMM (`dw = x^T dy`). Parallel over
/// the `k` output rows; output row `r` is the ascending-`i` sum
/// `sum_i a[i, r] * b[i, :]`, accumulated with the element-parallel
/// [`simd::axpy_at`] panel, so the reduction order is fixed by the loop
/// (not the lane count) and the kernel is **bitwise** equal to
/// [`matmul_tn_reference`] at every SIMD level and thread count.
/// Overwrites `out`.
pub fn matmul_tn(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, threads: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k, "matmul_tn a len");
    assert_eq!(b.len(), m * n, "matmul_tn b len");
    assert_eq!(out.len(), k * n, "matmul_tn out len");
    let lvl = simd::active();
    pool::par_rows(out, n, threads, |r0, ochunk| {
        for (ri, orow) in ochunk.chunks_exact_mut(n).enumerate() {
            let r = r0 + ri;
            orow.fill(0.0);
            for i in 0..m {
                simd::axpy_at(lvl, a[i * k + r], &b[i * n..(i + 1) * n], orow);
            }
        }
    });
}

/// Scalar twin of [`matmul_tn`]: the same ascending-`i` axpy chain
/// pinned at [`simd::Level::Scalar`], serial.
pub fn matmul_tn_reference(a: &[f32], b: &[f32], m: usize, k: usize, n: usize, out: &mut [f32]) {
    assert_eq!(a.len(), m * k, "matmul_tn a len");
    assert_eq!(b.len(), m * n, "matmul_tn b len");
    assert_eq!(out.len(), k * n, "matmul_tn out len");
    for r in 0..k {
        let orow = &mut out[r * n..(r + 1) * n];
        orow.fill(0.0);
        for i in 0..m {
            simd::axpy_at(simd::Level::Scalar, a[i * k + r], &b[i * n..(i + 1) * n], orow);
        }
    }
}

/// Bias gradient: column sums of `dy (rows, n)` into `out (n,)` — the
/// backward of [`super::super::linalg::add_bias`]. Parallel over
/// columns; each column is one ascending scalar chain, so the kernel is
/// **bitwise** at every SIMD level and thread count. Overwrites `out`.
pub fn bias_grad(dy: &[f32], rows: usize, n: usize, threads: usize, out: &mut [f32]) {
    assert_eq!(dy.len(), rows * n, "bias_grad dy len");
    assert_eq!(out.len(), n, "bias_grad out len");
    pool::par_rows(out, 1, threads, |c0, chunk| {
        for (ci, o) in chunk.iter_mut().enumerate() {
            let c = c0 + ci;
            let mut acc = 0.0f32;
            for r in 0..rows {
                acc += dy[r * n + c];
            }
            *o = acc;
        }
    });
}

/// Scalar twin of [`bias_grad`]: the same per-column chains, serial.
pub fn bias_grad_reference(dy: &[f32], rows: usize, n: usize, out: &mut [f32]) {
    assert_eq!(dy.len(), rows * n, "bias_grad dy len");
    assert_eq!(out.len(), n, "bias_grad out len");
    for (c, o) in out.iter_mut().enumerate() {
        let mut acc = 0.0f32;
        for r in 0..rows {
            acc += dy[r * n + c];
        }
        *o = acc;
    }
}

/// Shared body of the RMSNorm backward at an explicit SIMD level.
///
/// Forward (`y = x * s / rms`, `rms = sqrt(mean(x^2) + eps)`); with
/// `inv = 1/rms` the backward per row is
///
/// ```text
/// dx_j     = dy_j * s_j * inv  -  x_j * inv^3 / C * sum_i(dy_i s_i x_i)
/// dscale_j = sum_rows dy_j * x_j * inv
/// ```
///
/// `inv` is recomputed per row with the same [`simd::sum_sq_at`]
/// reduction the forward uses (flash-style recompute: no stash of the
/// normalizer), then shared by the `dx` rows and the `dscale` columns.
fn rms_norm_backward_at(
    lvl: simd::Level,
    x: &[f32],
    scale: &[f32],
    dy: &[f32],
    rows: usize,
    cols: usize,
    threads: usize,
    dx: &mut [f32],
    dscale: &mut [f32],
) {
    assert_eq!(x.len(), rows * cols, "rms_norm_backward x len");
    assert_eq!(dy.len(), rows * cols, "rms_norm_backward dy len");
    assert_eq!(scale.len(), cols, "rms_norm_backward scale len");
    assert_eq!(dx.len(), rows * cols, "rms_norm_backward dx len");
    assert_eq!(dscale.len(), cols, "rms_norm_backward dscale len");
    let mut inv = vec![0.0f32; rows];
    pool::par_rows(&mut inv, 1, threads, |r0, chunk| {
        for (ri, o) in chunk.iter_mut().enumerate() {
            let r = r0 + ri;
            let ms = simd::sum_sq_at(lvl, &x[r * cols..(r + 1) * cols]) / cols as f32;
            *o = 1.0 / (ms + RMS_EPS).sqrt();
        }
    });
    pool::par_rows(dx, cols, threads, |r0, chunk| {
        for (ri, drow) in chunk.chunks_exact_mut(cols).enumerate() {
            let r = r0 + ri;
            let xrow = &x[r * cols..(r + 1) * cols];
            let dyrow = &dy[r * cols..(r + 1) * cols];
            let iv = inv[r];
            let mut proj = 0.0f32;
            for j in 0..cols {
                proj += dyrow[j] * scale[j] * xrow[j];
            }
            let coef = iv * iv * iv / cols as f32 * proj;
            for j in 0..cols {
                drow[j] = dyrow[j] * scale[j] * iv - xrow[j] * coef;
            }
        }
    });
    pool::par_rows(dscale, 1, threads, |c0, chunk| {
        for (ci, o) in chunk.iter_mut().enumerate() {
            let c = c0 + ci;
            let mut acc = 0.0f32;
            for r in 0..rows {
                acc += dy[r * cols + c] * x[r * cols + c] * inv[r];
            }
            *o = acc;
        }
    });
}

/// Backward of [`super::super::linalg::rms_norm`]: writes `dx (rows,
/// cols)` and `dscale (cols,)`. 1e-5 twin of
/// [`rms_norm_backward_reference`] at SIMD levels (the recomputed
/// `1/rms` reduction), **bitwise** under `BSA_NATIVE_SIMD=off` and at
/// every thread count (all cross-element reductions are fixed-order
/// scalar chains). Overwrites both outputs.
#[allow(clippy::too_many_arguments)]
pub fn rms_norm_backward(
    x: &[f32],
    scale: &[f32],
    dy: &[f32],
    rows: usize,
    cols: usize,
    threads: usize,
    dx: &mut [f32],
    dscale: &mut [f32],
) {
    rms_norm_backward_at(simd::active(), x, scale, dy, rows, cols, threads, dx, dscale);
}

/// Scalar twin of [`rms_norm_backward`]: the same body pinned at
/// [`simd::Level::Scalar`], single thread.
pub fn rms_norm_backward_reference(
    x: &[f32],
    scale: &[f32],
    dy: &[f32],
    rows: usize,
    cols: usize,
    dx: &mut [f32],
    dscale: &mut [f32],
) {
    rms_norm_backward_at(simd::Level::Scalar, x, scale, dy, rows, cols, 1, dx, dscale);
}

/// Backward of the SwiGLU gate `g = silu(h1) * h3` (elementwise):
///
/// ```text
/// dh1 = dg * h3 * silu'(h1),   silu'(x) = sig(x) * (1 + x * (1 - sig(x)))
/// dh3 = dg * silu(h1)
/// ```
///
/// Pure elementwise scalar math — **bitwise** equal to
/// [`swiglu_backward_reference`] at every SIMD level and thread count.
/// Overwrites `dh1`/`dh3`.
pub fn swiglu_backward(
    h1: &[f32],
    h3: &[f32],
    dg: &[f32],
    threads: usize,
    dh1: &mut [f32],
    dh3: &mut [f32],
) {
    assert_eq!(h1.len(), dg.len(), "swiglu_backward h1 len");
    assert_eq!(h3.len(), dg.len(), "swiglu_backward h3 len");
    assert_eq!(dh1.len(), dg.len(), "swiglu_backward dh1 len");
    assert_eq!(dh3.len(), dg.len(), "swiglu_backward dh3 len");
    pool::par_rows(dh1, 1, threads, |i0, chunk| {
        for (i, o) in chunk.iter_mut().enumerate() {
            let x = h1[i0 + i];
            let s = sigmoid(x);
            *o = dg[i0 + i] * h3[i0 + i] * (s * (1.0 + x * (1.0 - s)));
        }
    });
    pool::par_rows(dh3, 1, threads, |i0, chunk| {
        for (i, o) in chunk.iter_mut().enumerate() {
            *o = dg[i0 + i] * silu(h1[i0 + i]);
        }
    });
}

/// Scalar twin of [`swiglu_backward`], serial.
pub fn swiglu_backward_reference(
    h1: &[f32],
    h3: &[f32],
    dg: &[f32],
    dh1: &mut [f32],
    dh3: &mut [f32],
) {
    swiglu_backward(h1, h3, dg, 1, dh1, dh3);
}

/// MSE loss and its gradient: `L = mean((pred - y)^2)` over every
/// element, `dpred = 2 (pred - y) / len`. Returns the loss. Serial
/// scalar chain (f64 accumulator for the loss sum) — self-referential,
/// deterministic at any thread/SIMD setting.
pub fn mse_loss_grad(pred: &[f32], y: &[f32], dpred: &mut [f32]) -> f32 {
    assert_eq!(pred.len(), y.len(), "mse_loss_grad y len");
    assert_eq!(pred.len(), dpred.len(), "mse_loss_grad dpred len");
    assert!(!pred.is_empty(), "mse_loss_grad on empty prediction");
    let inv = 2.0 / pred.len() as f32;
    let mut acc = 0.0f64;
    for i in 0..pred.len() {
        let e = pred[i] - y[i];
        acc += (e as f64) * (e as f64);
        dpred[i] = inv * e;
    }
    (acc / pred.len() as f64) as f32
}
