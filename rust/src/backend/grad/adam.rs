//! Bias-corrected Adam with decoupled weight decay (AdamW) over a
//! [`NativeParams`] tree — the same update rule the fused pjrt train
//! graph bakes in (aot.py), so a run is resumable across backends in
//! principle and its checkpoints are shape-compatible in practice.
//!
//! Per parameter `p` with gradient `g`, step count `t` (1-based):
//!
//! ```text
//! m     = b1 * m + (1 - b1) * g
//! v     = b2 * v + (1 - b2) * g^2
//! mhat  = m / (1 - b1^t)
//! vhat  = v / (1 - b2^t)
//! p    -= lr * (mhat / (sqrt(vhat) + eps) + wd * p)
//! ```
//!
//! The decay term is **decoupled** (applied to `p` directly, not mixed
//! into the moments) and — matching the reference training setup —
//! applied uniformly to every array, norms and biases included.
//! Defaults: `b1 = 0.9`, `b2 = 0.999`, `eps = 1e-8`; `wd` comes from
//! `TrainConfig::weight_decay` (0.01 by default, the paper's value).
//!
//! The update is a serial elementwise sweep in parameter order
//! ([`NativeParams::named_arrays`]) — deterministic at any thread or
//! SIMD setting, and cheap next to the backward GEMMs it follows. The
//! moment tensors live here as two [`NativeParams`] trees so they
//! serialize through the same named-array machinery as the model
//! (`m.<name>` / `v.<name>` in a v3 checkpoint; see `docs/TRAINING.md`).

use crate::backend::params::NativeParams;

/// Adam/AdamW optimizer state: per-array first/second moments plus the
/// completed-step count that drives bias correction.
pub struct Adam {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    /// Decoupled weight-decay coefficient (0 disables decay).
    pub weight_decay: f32,
    /// First-moment estimates, same shapes as the parameters.
    pub m: NativeParams,
    /// Second-moment estimates, same shapes as the parameters.
    pub v: NativeParams,
    /// Completed optimization steps (bias correction uses `t + 1`
    /// during the step, i.e. the step being applied is 1-based).
    pub t: u64,
}

impl Adam {
    /// Fresh optimizer state (zeroed moments, step 0) shaped like
    /// `params`, with the paper's hyperparameters.
    pub fn new(params: &NativeParams, weight_decay: f32) -> Adam {
        Adam {
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay,
            m: params.zeros_like(),
            v: params.zeros_like(),
            t: 0,
        }
    }

    /// Apply one update in place. `grads` must be shaped like `params`
    /// (it is the output of [`super::tape::backward`], which guarantees
    /// that). Advances the step count.
    pub fn step(&mut self, lr: f32, params: &mut NativeParams, grads: &NativeParams) {
        self.t += 1;
        // Bias corrections in f64: b2^t underflows f32 visibly past a
        // few thousand steps.
        let bc1 = (1.0 - (self.beta1 as f64).powi(self.t as i32)) as f32;
        let bc2 = (1.0 - (self.beta2 as f64).powi(self.t as i32)) as f32;
        let (b1, b2) = (self.beta1, self.beta2);
        let (eps, wd) = (self.eps, self.weight_decay);

        let pv = params.named_arrays_mut();
        let gv = grads.named_arrays();
        let mv = self.m.named_arrays_mut();
        let vv = self.v.named_arrays_mut();
        debug_assert_eq!(pv.len(), gv.len(), "adam: grads arity");
        for (((p, g), m), v) in pv.into_iter().zip(gv).zip(mv).zip(vv) {
            debug_assert_eq!(p.0, g.0, "adam: array order drift");
            let pd = p.1.data_mut();
            let gd = g.1.data();
            let md = m.1.data_mut();
            let vd = v.1.data_mut();
            debug_assert_eq!(pd.len(), gd.len(), "adam: {} shape drift", p.0);
            for i in 0..pd.len() {
                let gi = gd[i];
                md[i] = b1 * md[i] + (1.0 - b1) * gi;
                vd[i] = b2 * vd[i] + (1.0 - b2) * gi * gi;
                let mhat = md[i] / bc1;
                let vhat = vd[i] / bc2;
                pd[i] -= lr * (mhat / (vhat.sqrt() + eps) + wd * pd[i]);
            }
        }
    }
}
