//! Whole-model forward-with-stash and reverse sweep — the composition
//! layer that turns the per-op gradients in [`super::linalg`] and
//! [`super::attention`] into `dL/dtheta` for a full
//! [`NativeParams`] trunk.
//!
//! [`forward`] replays the exact computation of
//! [`crate::backend::NativeBackend::forward`] (f32 path) while
//! stashing the activations the reverse sweep needs: residual-stream
//! inputs, RMS-normed rows, Q/K/V/gate projections, the three branch
//! outputs per (batch, head) unit, the gated merge, and the SwiGLU
//! intermediates. What it deliberately does **not** stash:
//!
//! * attention probabilities — the flash backward recomputes the
//!   online `(max, exp-sum)` stats per row ([`super::attention`]);
//! * compressed keys/values and the top-k index sets — both are cheap,
//!   deterministic functions of the stashed K/Q, recomputed per unit
//!   in the backward (the replayed argmax is what makes top-k
//!   straight-through: identical indices, no score gradient).
//!
//! [`backward`] walks the blocks in reverse. The per-(batch, head)
//! unit gradients are dispatched over the worker pool exactly like the
//! forward's attention units: each unit writes its `dQ`/`dK`/`dV`/
//! `dgate` slices into a disjoint chunk of a unit-major staging
//! buffer, and a serial fold scatters them back to token-major rows —
//! every element written exactly once, so gradients are **bitwise
//! identical at every thread count**, like the forward.
//!
//! [`loss_and_grads`] glues in the MSE loss and is the one call
//! [`crate::coordinator::train::NativeTrainer`] makes per step.

use crate::backend::native::AttnHyper;
use crate::backend::params::NativeParams;
use crate::backend::{kernels, linalg, pool, simd};

use super::attention as gatt;
use super::linalg as glin;

/// Per-block activation stash (all row-major flat, `rows = batch * n`).
struct BlockStash {
    /// Residual-stream input to the block (`(rows, C)`).
    x_attn_in: Vec<f32>,
    /// `rms_norm(x_attn_in, norm1)` — input to the Q/K/V/gate projections.
    nrm1: Vec<f32>,
    /// Q/K/V projections (`(rows, C)` each).
    q: Vec<f32>,
    k: Vec<f32>,
    v: Vec<f32>,
    /// Gate logits (`(rows, 3H)`).
    gates: Vec<f32>,
    /// Branch outputs, unit-major: for unit `u = bi * H + hd` the
    /// chunk `u * 3*n*dh ..` holds `[o_ball | o_cmp | o_slc]`,
    /// `(n, dh)` each.
    branches_hm: Vec<f32>,
    /// Token-major gated merge (`(rows, C)`) — input to `wo`.
    merged: Vec<f32>,
    /// Residual-stream input to the MLP half (`(rows, C)`).
    x_mlp_in: Vec<f32>,
    /// `rms_norm(x_mlp_in, norm2)`.
    nrm2: Vec<f32>,
    /// SwiGLU pre-activation `nrm2 @ w1` (`(rows, hid)`).
    h1: Vec<f32>,
    /// Value branch `nrm2 @ w3` (`(rows, hid)`).
    h3: Vec<f32>,
    /// Gated hidden `silu(h1) * h3` — input to `w2`.
    g: Vec<f32>,
}

/// Activation record of one [`forward`] call; feed to [`backward`].
pub struct Tape {
    blocks: Vec<BlockStash>,
    /// Residual-stream input to the final norm (`(rows, C)`).
    x_final: Vec<f32>,
    /// `rms_norm(x_final, norm_out)` — input to the head projection.
    nrmf: Vec<f32>,
    /// Model output (`(rows, out_features)` flat) — predictions.
    pub pred: Vec<f32>,
}

/// Forward pass with activation stashing. `x` is `(batch, n,
/// in_features)` flat; numerically identical to the backend's f32
/// forward (same kernels, same order), bitwise stable across thread
/// counts.
pub fn forward(
    params: &NativeParams,
    hyper: &AttnHyper,
    x: &[f32],
    batch: usize,
    n: usize,
    threads: usize,
) -> Tape {
    let c = params.dim();
    let h_cnt = params.num_heads();
    let dh = c / h_cnt;
    let f = params.in_features();
    let of = params.out_features();
    let rows = batch * n;
    assert_eq!(x.len(), rows * f, "tape::forward input len");
    let th = pool::resolve_threads(threads);
    let hid = params.blocks[0].mlp.w1.cols();

    // embed
    let mut h = vec![0.0f32; rows * c];
    linalg::matmul(x, params.embed_w.data(), rows, f, c, th, &mut h);
    linalg::add_bias(&mut h, params.embed_b.data(), rows, c);

    let mut blocks = Vec::with_capacity(params.blocks.len());
    let mut branch = vec![0.0f32; rows * c];
    for blk in &params.blocks {
        let x_attn_in = h.clone();
        let mut nrm1 = vec![0.0f32; rows * c];
        linalg::rms_norm(&h, blk.norm1.data(), rows, c, th, &mut nrm1);

        // projections
        let mut q = vec![0.0f32; rows * c];
        let mut k = vec![0.0f32; rows * c];
        let mut v = vec![0.0f32; rows * c];
        let mut gates = vec![0.0f32; rows * 3 * h_cnt];
        linalg::matmul(&nrm1, blk.attn.wq.data(), rows, c, c, th, &mut q);
        linalg::matmul(&nrm1, blk.attn.wk.data(), rows, c, c, th, &mut k);
        linalg::matmul(&nrm1, blk.attn.wv.data(), rows, c, c, th, &mut v);
        linalg::matmul(&nrm1, blk.attn.wg.data(), rows, c, 3 * h_cnt, th, &mut gates);

        // three branches per (batch, head) unit, unit-major staging
        let mut branches_hm = vec![0.0f32; batch * h_cnt * 3 * n * dh];
        run_units_forward(hyper, &q, &k, &v, &mut branches_hm, batch, n, h_cnt, dh, th);

        // gated merge (eq. 9), folded straight to token-major
        let mut merged = vec![0.0f32; rows * c];
        let units = batch * h_cnt;
        for u in 0..units {
            let (bi, hd) = (u / h_cnt, u % h_cnt);
            let base = u * 3 * n * dh;
            let (o_ball, o_cmp, o_slc) = branch_slices(&branches_hm, base, n * dh);
            for t in 0..n {
                let grow = (bi * n + t) * 3 * h_cnt;
                let gb = linalg::sigmoid(gates[grow + hd]);
                let gc = linalg::sigmoid(gates[grow + h_cnt + hd]);
                let gs = linalg::sigmoid(gates[grow + 2 * h_cnt + hd]);
                let src = t * dh;
                let dst = (bi * n + t) * c + hd * dh;
                for j in 0..dh {
                    merged[dst + j] = gb * o_ball[src + j]
                        + gc * o_cmp[src + j]
                        + gs * o_slc[src + j];
                }
            }
        }
        linalg::matmul(&merged, blk.attn.wo.data(), rows, c, c, th, &mut branch);
        simd::add_assign(&mut h, &branch);

        let x_mlp_in = h.clone();
        let mut nrm2 = vec![0.0f32; rows * c];
        linalg::rms_norm(&h, blk.norm2.data(), rows, c, th, &mut nrm2);
        let mut h1 = vec![0.0f32; rows * hid];
        let mut h3 = vec![0.0f32; rows * hid];
        linalg::matmul(&nrm2, blk.mlp.w1.data(), rows, c, hid, th, &mut h1);
        linalg::matmul(&nrm2, blk.mlp.w3.data(), rows, c, hid, th, &mut h3);
        let mut g = vec![0.0f32; rows * hid];
        for i in 0..rows * hid {
            g[i] = linalg::silu(h1[i]) * h3[i];
        }
        linalg::matmul(&g, blk.mlp.w2.data(), rows, hid, c, th, &mut branch);
        simd::add_assign(&mut h, &branch);

        blocks.push(BlockStash {
            x_attn_in,
            nrm1,
            q,
            k,
            v,
            gates,
            branches_hm,
            merged,
            x_mlp_in,
            nrm2,
            h1,
            h3,
            g,
        });
    }

    // head
    let x_final = h;
    let mut nrmf = vec![0.0f32; rows * c];
    linalg::rms_norm(&x_final, params.norm_out.data(), rows, c, th, &mut nrmf);
    let mut pred = vec![0.0f32; rows * of];
    linalg::matmul(&nrmf, params.head_w.data(), rows, c, of, th, &mut pred);
    linalg::add_bias(&mut pred, params.head_b.data(), rows, of);

    Tape { blocks, x_final, nrmf, pred }
}

/// Split a unit's `[o_ball | o_cmp | o_slc]` staging chunk.
fn branch_slices(buf: &[f32], base: usize, nd: usize) -> (&[f32], &[f32], &[f32]) {
    (
        &buf[base..base + nd],
        &buf[base + nd..base + 2 * nd],
        &buf[base + 2 * nd..base + 3 * nd],
    )
}

/// Forward attention branches for every (batch, head) unit, parallel
/// over units (disjoint staging chunks; kernels inside a unit run
/// serial — determinism does not depend on the split).
#[allow(clippy::too_many_arguments)]
fn run_units_forward(
    hyper: &AttnHyper,
    q: &[f32],
    k: &[f32],
    v: &[f32],
    branches_hm: &mut [f32],
    batch: usize,
    n: usize,
    h_cnt: usize,
    dh: usize,
    threads: usize,
) {
    let c = h_cnt * dh;
    let m = hyper.ball_size;
    let l = hyper.cmp_block;
    let g = hyper.group_size;
    let top_k = hyper.top_k;
    let nb = n / l;
    let groups = n / g;
    let scale = 1.0 / (dh as f32).sqrt();
    let nd = n * dh;
    pool::par_rows(branches_hm, 3 * nd, threads, |u0, chunk| {
        let mut qs = vec![0.0f32; nd];
        let mut ks = vec![0.0f32; nd];
        let mut vs = vec![0.0f32; nd];
        let mut kc = vec![0.0f32; nb * dh];
        let mut vc = vec![0.0f32; nb * dh];
        let mut qg: Vec<f32> = Vec::new();
        let mut gsc = vec![0.0f32; groups * nb];
        let mut idx: Vec<usize> = Vec::new();
        let mut scores: Vec<f32> = Vec::new();
        for (ui, ublock) in chunk.chunks_exact_mut(3 * nd).enumerate() {
            let u = u0 + ui;
            let (bi, hd) = (u / h_cnt, u % h_cnt);
            let col0 = hd * dh;
            for t in 0..n {
                let src = (bi * n + t) * c + col0;
                qs[t * dh..(t + 1) * dh].copy_from_slice(&q[src..src + dh]);
                ks[t * dh..(t + 1) * dh].copy_from_slice(&k[src..src + dh]);
                vs[t * dh..(t + 1) * dh].copy_from_slice(&v[src..src + dh]);
            }
            let (o_ball, rest) = ublock.split_at_mut(nd);
            let (o_cmp, o_slc) = rest.split_at_mut(nd);
            kernels::ball_attention(&qs, &ks, &vs, n, dh, m, 1, o_ball);
            kernels::compress_mean(&ks, n, dh, l, 1, &mut kc);
            kernels::compress_mean(&vs, n, dh, l, 1, &mut vc);
            kernels::attend(&qs, &kc, &vc, n, nb, dh, scale, 1, o_cmp, &mut scores);
            kernels::group_scores(&qs, &kc, n, dh, g, nb, 1, &mut qg, &mut gsc);
            kernels::mask_own_ball(&mut gsc, groups, nb, g, l, m);
            kernels::topk_indices(&gsc, groups, nb, top_k, 1, &mut idx);
            kernels::select_attention(&qs, &ks, &vs, &idx, n, dh, l, g, top_k, 1, o_slc);
        }
    });
}

/// Reverse sweep: given the upstream gradient `dpred` (`(rows,
/// out_features)` flat, e.g. from [`glin::mse_loss_grad`]), produce
/// `dL/dtheta` as a [`NativeParams`] of the same shapes. `x` must be
/// the input [`forward`] saw. Bitwise identical at every thread count.
#[allow(clippy::too_many_arguments)]
pub fn backward(
    params: &NativeParams,
    hyper: &AttnHyper,
    x: &[f32],
    batch: usize,
    n: usize,
    threads: usize,
    tape: &Tape,
    dpred: &[f32],
) -> NativeParams {
    let c = params.dim();
    let h_cnt = params.num_heads();
    let f = params.in_features();
    let of = params.out_features();
    let rows = batch * n;
    assert_eq!(x.len(), rows * f, "tape::backward input len");
    assert_eq!(dpred.len(), rows * of, "tape::backward dpred len");
    assert_eq!(tape.blocks.len(), params.blocks.len(), "tape/params block count");
    let th = pool::resolve_threads(threads);
    let hid = params.blocks[0].mlp.w1.cols();
    let mut grads = params.zeros_like();

    // head: pred = nrmf @ head_w + head_b
    glin::matmul_tn(&tape.nrmf, dpred, rows, c, of, th, grads.head_w.data_mut());
    glin::bias_grad(dpred, rows, of, th, grads.head_b.data_mut());
    let mut dnrm = vec![0.0f32; rows * c];
    linalg::matmul_nt(dpred, params.head_w.data(), rows, of, c, th, &mut dnrm);
    let mut dh = vec![0.0f32; rows * c];
    glin::rms_norm_backward(
        &tape.x_final,
        params.norm_out.data(),
        &dnrm,
        rows,
        c,
        th,
        &mut dh,
        grads.norm_out.data_mut(),
    );

    let mut dx = vec![0.0f32; rows * c];
    let mut tmp = vec![0.0f32; rows * c];
    let mut dhid = vec![0.0f32; rows * hid];
    let mut dh1 = vec![0.0f32; rows * hid];
    let mut dh3 = vec![0.0f32; rows * hid];
    for (blk, gblk, stash) in itertools_rev(params, &mut grads, &tape.blocks) {
        // MLP half: dh is d(block output) = d(x_mlp_in + mlp_out)
        linalg::matmul_nt(&dh, blk.mlp.w2.data(), rows, c, hid, th, &mut dhid);
        glin::matmul_tn(&stash.g, &dh, rows, hid, c, th, gblk.mlp.w2.data_mut());
        glin::swiglu_backward(&stash.h1, &stash.h3, &dhid, th, &mut dh1, &mut dh3);
        glin::matmul_tn(&stash.nrm2, &dh1, rows, c, hid, th, gblk.mlp.w1.data_mut());
        glin::matmul_tn(&stash.nrm2, &dh3, rows, c, hid, th, gblk.mlp.w3.data_mut());
        linalg::matmul_nt(&dh1, blk.mlp.w1.data(), rows, hid, c, th, &mut dnrm);
        linalg::matmul_nt(&dh3, blk.mlp.w3.data(), rows, hid, c, th, &mut tmp);
        simd::add_assign(&mut dnrm, &tmp);
        glin::rms_norm_backward(
            &stash.x_mlp_in,
            blk.norm2.data(),
            &dnrm,
            rows,
            c,
            th,
            &mut dx,
            gblk.norm2.data_mut(),
        );
        simd::add_assign(&mut dh, &dx); // dh is now d(x_mlp_in)

        // attention half: dattn = dh
        glin::matmul_tn(&stash.merged, &dh, rows, c, c, th, gblk.attn.wo.data_mut());
        let mut dmerged = vec![0.0f32; rows * c];
        linalg::matmul_nt(&dh, blk.attn.wo.data(), rows, c, c, th, &mut dmerged);

        let (dq, dk, dv, dgates) =
            run_units_backward(hyper, stash, &dmerged, batch, n, h_cnt, c / h_cnt, th);

        glin::matmul_tn(&stash.nrm1, &dq, rows, c, c, th, gblk.attn.wq.data_mut());
        glin::matmul_tn(&stash.nrm1, &dk, rows, c, c, th, gblk.attn.wk.data_mut());
        glin::matmul_tn(&stash.nrm1, &dv, rows, c, c, th, gblk.attn.wv.data_mut());
        glin::matmul_tn(&stash.nrm1, &dgates, rows, c, 3 * h_cnt, th, gblk.attn.wg.data_mut());
        linalg::matmul_nt(&dq, blk.attn.wq.data(), rows, c, c, th, &mut dnrm);
        linalg::matmul_nt(&dk, blk.attn.wk.data(), rows, c, c, th, &mut tmp);
        simd::add_assign(&mut dnrm, &tmp);
        linalg::matmul_nt(&dv, blk.attn.wv.data(), rows, c, c, th, &mut tmp);
        simd::add_assign(&mut dnrm, &tmp);
        linalg::matmul_nt(&dgates, blk.attn.wg.data(), rows, 3 * h_cnt, c, th, &mut tmp);
        simd::add_assign(&mut dnrm, &tmp);
        glin::rms_norm_backward(
            &stash.x_attn_in,
            blk.norm1.data(),
            &dnrm,
            rows,
            c,
            th,
            &mut dx,
            gblk.norm1.data_mut(),
        );
        simd::add_assign(&mut dh, &dx); // dh is now d(x_attn_in)
    }

    // embed: h0 = x @ embed_w + embed_b
    glin::matmul_tn(x, &dh, rows, f, c, th, grads.embed_w.data_mut());
    glin::bias_grad(&dh, rows, c, th, grads.embed_b.data_mut());
    grads
}

/// Zip blocks/grad-blocks/stashes in reverse order. Written as a free
/// function so the borrow of `grads` stays disjoint from the loop body.
fn itertools_rev<'a>(
    params: &'a NativeParams,
    grads: &'a mut NativeParams,
    stashes: &'a [BlockStash],
) -> impl Iterator<Item = (&'a crate::backend::params::BlockParams, &'a mut crate::backend::params::BlockParams, &'a BlockStash)>
{
    params
        .blocks
        .iter()
        .zip(grads.blocks.iter_mut())
        .zip(stashes.iter())
        .map(|((b, g), s)| (b, g, s))
        .rev()
}

/// Backward through the three branches and the gated merge for every
/// (batch, head) unit. Parallel over units: each unit writes
/// `[dqs | dks | dvs | dlogits]` into its disjoint chunk of a
/// unit-major staging buffer (the compressed K/V and the top-k index
/// set are recomputed from the stash — deterministic, so the replayed
/// indices match the forward exactly); a serial fold then scatters the
/// chunks to token-major `dq`/`dk`/`dv`/`dgates`, each element written
/// once. Bitwise identical at every thread count.
#[allow(clippy::too_many_arguments)]
fn run_units_backward(
    hyper: &AttnHyper,
    stash: &BlockStash,
    dmerged: &[f32],
    batch: usize,
    n: usize,
    h_cnt: usize,
    dh: usize,
    threads: usize,
) -> (Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>) {
    let c = h_cnt * dh;
    let m = hyper.ball_size;
    let l = hyper.cmp_block;
    let g = hyper.group_size;
    let top_k = hyper.top_k;
    let nb = n / l;
    let groups = n / g;
    let scale = 1.0 / (dh as f32).sqrt();
    let nd = n * dh;
    let units = batch * h_cnt;
    let w = 3 * nd + 3 * n; // [dqs | dks | dvs | dlogits]

    let mut unit_grads = vec![0.0f32; units * w];
    let branches = &stash.branches_hm[..];
    let (qf, kf, vf, gatesf) = (&stash.q[..], &stash.k[..], &stash.v[..], &stash.gates[..]);
    pool::par_rows(&mut unit_grads, w, threads, |u0, chunk| {
        let mut qs = vec![0.0f32; nd];
        let mut ks = vec![0.0f32; nd];
        let mut vs = vec![0.0f32; nd];
        let mut dmerge_u = vec![0.0f32; nd];
        let mut logits = vec![0.0f32; n * 3];
        let mut d_ball = vec![0.0f32; nd];
        let mut d_cmp = vec![0.0f32; nd];
        let mut d_slc = vec![0.0f32; nd];
        let mut kc = vec![0.0f32; nb * dh];
        let mut vc = vec![0.0f32; nb * dh];
        let mut dkc = vec![0.0f32; nb * dh];
        let mut dvc = vec![0.0f32; nb * dh];
        let mut qg: Vec<f32> = Vec::new();
        let mut gsc = vec![0.0f32; groups * nb];
        let mut idx: Vec<usize> = Vec::new();
        for (ui, ublock) in chunk.chunks_exact_mut(w).enumerate() {
            let u = u0 + ui;
            let (bi, hd) = (u / h_cnt, u % h_cnt);
            let col0 = hd * dh;
            for t in 0..n {
                let src = (bi * n + t) * c + col0;
                qs[t * dh..(t + 1) * dh].copy_from_slice(&qf[src..src + dh]);
                ks[t * dh..(t + 1) * dh].copy_from_slice(&kf[src..src + dh]);
                vs[t * dh..(t + 1) * dh].copy_from_slice(&vf[src..src + dh]);
                dmerge_u[t * dh..(t + 1) * dh].copy_from_slice(&dmerged[src..src + dh]);
                let grow = (bi * n + t) * 3 * h_cnt;
                logits[t * 3] = gatesf[grow + hd];
                logits[t * 3 + 1] = gatesf[grow + h_cnt + hd];
                logits[t * 3 + 2] = gatesf[grow + 2 * h_cnt + hd];
            }
            let base = u * 3 * nd;
            let (o_ball, o_cmp, o_slc) = branch_slices(branches, base, nd);
            let (dqkv, dlogits) = ublock.split_at_mut(3 * nd);
            let (dqs, rest) = dqkv.split_at_mut(nd);
            let (dks, dvs) = rest.split_at_mut(nd);
            // chunks arrive zeroed (fresh buffer); kernels accumulate.
            gatt::merge_backward(
                &logits, o_ball, o_cmp, o_slc, &dmerge_u, n, dh, dlogits, &mut d_ball,
                &mut d_cmp, &mut d_slc,
            );
            gatt::ball_attention_backward(&qs, &ks, &vs, o_ball, &d_ball, n, dh, m, dqs, dks, dvs);
            kernels::compress_mean(&ks, n, dh, l, 1, &mut kc);
            kernels::compress_mean(&vs, n, dh, l, 1, &mut vc);
            dkc.fill(0.0);
            dvc.fill(0.0);
            gatt::attend_backward(
                &qs, &kc, &vc, o_cmp, &d_cmp, n, nb, dh, scale, dqs, &mut dkc, &mut dvc,
            );
            gatt::compress_mean_backward(&dkc, n, dh, l, dks);
            gatt::compress_mean_backward(&dvc, n, dh, l, dvs);
            kernels::group_scores(&qs, &kc, n, dh, g, nb, 1, &mut qg, &mut gsc);
            kernels::mask_own_ball(&mut gsc, groups, nb, g, l, m);
            kernels::topk_indices(&gsc, groups, nb, top_k, 1, &mut idx);
            gatt::select_attention_backward(
                &qs, &ks, &vs, o_slc, &d_slc, &idx, n, dh, l, g, top_k, dqs, dks, dvs,
            );
        }
    });

    // serial fold: unit-major chunks -> token-major rows (pure copy,
    // each destination element written exactly once)
    let rows = batch * n;
    let mut dq = vec![0.0f32; rows * c];
    let mut dk = vec![0.0f32; rows * c];
    let mut dv = vec![0.0f32; rows * c];
    let mut dgates = vec![0.0f32; rows * 3 * h_cnt];
    for u in 0..units {
        let (bi, hd) = (u / h_cnt, u % h_cnt);
        let col0 = hd * dh;
        let ublock = &unit_grads[u * w..(u + 1) * w];
        let (dqs, rest) = ublock.split_at(nd);
        let (dks, rest) = rest.split_at(nd);
        let (dvs, dlogits) = rest.split_at(nd);
        for t in 0..n {
            let dst = (bi * n + t) * c + col0;
            dq[dst..dst + dh].copy_from_slice(&dqs[t * dh..(t + 1) * dh]);
            dk[dst..dst + dh].copy_from_slice(&dks[t * dh..(t + 1) * dh]);
            dv[dst..dst + dh].copy_from_slice(&dvs[t * dh..(t + 1) * dh]);
            let grow = (bi * n + t) * 3 * h_cnt;
            dgates[grow + hd] = dlogits[t * 3];
            dgates[grow + h_cnt + hd] = dlogits[t * 3 + 1];
            dgates[grow + 2 * h_cnt + hd] = dlogits[t * 3 + 2];
        }
    }
    (dq, dk, dv, dgates)
}

/// One training step's math: forward with stash, MSE loss against `y`
/// (`(rows, out_features)` flat), reverse sweep. Returns `(loss, tape,
/// grads)` — the tape carries the predictions for callers that also
/// want them (eval reuses the same forward).
pub fn loss_and_grads(
    params: &NativeParams,
    hyper: &AttnHyper,
    x: &[f32],
    y: &[f32],
    batch: usize,
    n: usize,
    threads: usize,
) -> (f32, Tape, NativeParams) {
    let tape = forward(params, hyper, x, batch, n, threads);
    assert_eq!(y.len(), tape.pred.len(), "loss_and_grads target len");
    let mut dpred = vec![0.0f32; tape.pred.len()];
    let loss = glin::mse_loss_grad(&tape.pred, y, &mut dpred);
    let grads = backward(params, hyper, x, batch, n, threads, &tape, &dpred);
    (loss, tape, grads)
}
