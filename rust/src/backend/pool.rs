//! Work splitting for the native kernels: std scoped threads, no deps.
//!
//! Every parallel kernel in [`super::linalg`] and [`super::kernels`]
//! funnels through [`par_rows`]: the output buffer is split into
//! contiguous chunks of whole rows (a "row" being whatever unit the
//! kernel parallelizes over — a GEMM output row, a ball, a selection
//! group), each chunk is handed to a scoped thread, and the closure
//! computes its rows exactly like the serial `*_reference` twin would.
//! Because chunks are contiguous and each output element's accumulation
//! order is untouched, the parallel kernels are bitwise equal to their
//! scalar twins — the property `rust/tests/conformance.rs` enforces.
//!
//! Thread-count resolution (see [`resolve_threads`]): an explicit
//! request wins, then the `BSA_NATIVE_THREADS` environment override,
//! then `std::thread::available_parallelism()`. The resolved count is an
//! upper bound — `par_rows` never spawns more threads than it has rows,
//! the last chunk always runs on the caller's thread, and a count of 1
//! runs inline with zero spawn overhead.
//!
//! Deliberate simplicity trade-off: threads are spawned per `par_rows`
//! call (scoped, joined before return) rather than parked in a
//! persistent pool. At the model's GEMM-dominated kernel sizes each
//! call carries milliseconds of work, so spawn cost is low-single-digit
//! percent; if profiling ever shows otherwise, the upgrade path is a
//! persistent worker pool behind this same `par_rows` signature —
//! callers and the bitwise chunking contract stay untouched (tracked in
//! ROADMAP.md).

use std::ops::Range;
use std::sync::OnceLock;

/// Hard upper bound on kernel threads (sanity cap for typo'd overrides).
pub const MAX_THREADS: usize = 64;

/// Name of the environment override consulted by [`resolve_threads`].
pub const THREADS_ENV: &str = "BSA_NATIVE_THREADS";

fn hardware_threads() -> usize {
    static HW: OnceLock<usize> = OnceLock::new();
    *HW.get_or_init(|| {
        std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1)
    })
}

/// Resolve a kernel thread count: `requested > 0` wins, else the
/// `BSA_NATIVE_THREADS` env var (if set to a positive integer), else the
/// machine's available parallelism. Always in `1..=MAX_THREADS`.
pub fn resolve_threads(requested: usize) -> usize {
    if requested > 0 {
        return requested.min(MAX_THREADS);
    }
    if let Ok(s) = std::env::var(THREADS_ENV) {
        if let Ok(t) = s.trim().parse::<usize>() {
            if t > 0 {
                return t.min(MAX_THREADS);
            }
        }
    }
    hardware_threads().min(MAX_THREADS)
}

/// Split `rows` items into at most `threads` contiguous, near-equal
/// ranges covering `0..rows` in order (the chunking [`par_rows`] uses).
pub fn chunk_rows(rows: usize, threads: usize) -> Vec<Range<usize>> {
    let t = threads.max(1).min(rows.max(1));
    let per = (rows + t - 1) / t;
    let mut out = Vec::new();
    let mut start = 0;
    while start < rows {
        let end = (start + per).min(rows);
        out.push(start..end);
        start = end;
    }
    out
}

/// Run `f(first_row, chunk)` over disjoint contiguous whole-row chunks
/// of `out` (`row_width` elements per row), one chunk per thread. The
/// chunks are exactly [`chunk_rows`]`(rows, threads)`; the **last**
/// chunk always runs inline on the caller's thread (it would otherwise
/// sit idle in the scope join), so a call spawns at most
/// `chunks - 1` threads and `threads <= 1` (or a single row) spawns
/// none at all.
///
/// `f` must compute rows identically regardless of which chunk they
/// land in; every caller in this crate guarantees that by delegating to
/// (or matching) its scalar `*_reference` twin, which is what keeps
/// parallel kernels bitwise deterministic across thread counts.
pub fn par_rows<T, F>(out: &mut [T], row_width: usize, threads: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    if out.is_empty() {
        return;
    }
    assert!(row_width > 0, "par_rows row_width must be positive");
    assert_eq!(out.len() % row_width, 0, "par_rows out not whole rows");
    let rows = out.len() / row_width;
    let t = threads.max(1).min(rows);
    if t == 1 {
        f(0, out);
        return;
    }
    let chunks = chunk_rows(rows, t);
    let last = chunks.len() - 1;
    std::thread::scope(|s| {
        let mut rest = out;
        for (ci, range) in chunks.iter().enumerate() {
            let take = range.end - range.start;
            let (chunk, tail) = {
                let r = std::mem::take(&mut rest);
                r.split_at_mut(take * row_width)
            };
            rest = tail;
            if ci == last {
                f(range.start, chunk);
            } else {
                let fr = &f;
                let row0 = range.start;
                s.spawn(move || fr(row0, chunk));
            }
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn resolve_explicit_wins_and_is_capped() {
        assert_eq!(resolve_threads(3), 3);
        assert_eq!(resolve_threads(10_000), MAX_THREADS);
        assert!(resolve_threads(0) >= 1);
    }

    #[test]
    fn chunk_rows_partitions_in_order() {
        for rows in [0usize, 1, 5, 7, 16, 33] {
            for t in [1usize, 2, 3, 8, 64] {
                let chunks = chunk_rows(rows, t);
                let mut next = 0;
                for r in &chunks {
                    assert_eq!(r.start, next, "contiguous");
                    assert!(r.end > r.start, "non-empty");
                    next = r.end;
                }
                assert_eq!(next, rows, "covers 0..{rows}");
                assert!(chunks.len() <= t.max(1));
            }
        }
    }

    #[test]
    fn par_rows_touches_every_row_once() {
        for threads in [1usize, 2, 3, 7] {
            let rows = 23;
            let width = 4;
            let mut out = vec![0.0f32; rows * width];
            let calls = AtomicUsize::new(0);
            par_rows(&mut out, width, threads, |row0, chunk| {
                calls.fetch_add(1, Ordering::Relaxed);
                for (i, row) in chunk.chunks_exact_mut(width).enumerate() {
                    for v in row.iter_mut() {
                        *v += (row0 + i) as f32 + 1.0;
                    }
                }
            });
            for (i, row) in out.chunks_exact(width).enumerate() {
                for &v in row {
                    assert_eq!(v, i as f32 + 1.0, "row {i} threads {threads}");
                }
            }
            assert!(calls.load(Ordering::Relaxed) <= threads);
        }
    }

    #[test]
    fn par_rows_handles_empty_and_single_row() {
        let mut empty: Vec<f32> = vec![];
        par_rows(&mut empty, 8, 4, |_, _| panic!("must not be called"));
        let mut one = vec![0.0f32; 6];
        par_rows(&mut one, 6, 8, |row0, chunk| {
            assert_eq!(row0, 0);
            chunk.fill(1.0);
        });
        assert!(one.iter().all(|&v| v == 1.0));
    }

    #[test]
    fn par_rows_works_for_usize_rows() {
        // topk writes index rows; par_rows is generic over Send elements
        let mut out = vec![0usize; 12];
        par_rows(&mut out, 3, 4, |row0, chunk| {
            for (i, row) in chunk.chunks_exact_mut(3).enumerate() {
                row.fill(row0 + i);
            }
        });
        for (i, row) in out.chunks_exact(3).enumerate() {
            assert!(row.iter().all(|&v| v == i));
        }
    }
}
